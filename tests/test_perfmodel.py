"""Validate the performance model against the paper's own numbers
(Tables 2/3/4, §2.2.3, §4.1 scenario theorems)."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import perfmodel as pm
from repro.stencil import StencilSpec, alpha, fused_num_points

B21 = StencilSpec("box", 2, 1)
B23 = StencilSpec("box", 2, 3)
B27 = StencilSpec("box", 2, 7)
B31 = StencilSpec("box", 3, 1)
S21 = StencilSpec("star", 2, 1)


class TestAlpha:
    def test_paper_box2d1r_t3(self):
        # paper §2.2.3: fused 7x7 kernel = 49 ops vs 27 sequential
        assert fused_num_points(B21, 3) == 49
        assert alpha(B21, 3) == pytest.approx(49 / 27)
        assert alpha(B21, 3) == pytest.approx(1.81, abs=0.01)  # Table 2 row 5

    def test_paper_box2d1r_t7(self):
        assert alpha(B21, 7) == pytest.approx(3.57, abs=0.01)  # Table 2 rows 7/9

    def test_box_closed_form(self):
        # Eq. 10
        for d, r, t in [(2, 1, 3), (2, 3, 2), (3, 1, 3), (3, 2, 2)]:
            spec = StencilSpec("box", d, r)
            expect = (2 * r * t + 1) ** d / (t * (2 * r + 1) ** d)
            assert alpha(spec, t) == pytest.approx(expect)

    def test_star_fused_is_l1_ball(self):
        # unit-radius star kernels compose into L1 balls:
        # 2D radius t -> 2t^2 + 2t + 1 points (NOT a star -- this is why
        # alpha must be computed from the composed support, not a formula)
        for t in (2, 3, 4):
            assert fused_num_points(StencilSpec("star", 2, 1), t) \
                == 2 * t * t + 2 * t + 1
        # r=2 star sumset: box(r=2) plus axis spurs to distance 4 = 33
        assert fused_num_points(StencilSpec("star", 2, 2), 2) == 33

    def test_alpha_t1_is_1(self):
        for spec in (B21, B27, B31, S21):
            assert alpha(spec, 1) == 1.0


class TestTable2:
    """Analytical C and I columns of paper Table 2."""

    @pytest.mark.parametrize("spec,t,D,C,I", [
        (B21, 3, 8, 54, 3.38), (B23, 1, 8, 98, 6.12),
        (B21, 7, 4, 126, 15.75), (B27, 1, 4, 450, 56.25),
    ])
    def test_ebisu_rows(self, spec, t, D, C, I):
        w = pm.StencilWorkload(spec, t, D)
        assert w.flops_vector() == C
        assert w.intensity_vector() == pytest.approx(I, abs=0.01)

    @pytest.mark.parametrize("spec,t,D,S,C,I", [
        (B21, 3, 8, 0.5, 196, 12.25),      # ConvStencil
        (B21, 7, 4, 0.5, 900, 112.5),      # ConvStencil float
        (B21, 7, 4, 0.47, 960, 120.0),     # SPIDER (S=0.47 rounds C to 957)
    ])
    def test_tensor_core_rows(self, spec, t, D, S, C, I):
        w = pm.StencilWorkload(spec, t, D)
        assert w.flops_matrix(S) == pytest.approx(C, rel=0.01)
        assert w.intensity_matrix(S) == pytest.approx(I, rel=0.01)


class TestRidgePoints:
    def test_table3_ridges(self):
        assert pm.A100_DOUBLE.ridge_vector == pytest.approx(5, abs=0.1)
        assert pm.A100_DOUBLE.ridge_matrix == pytest.approx(10, abs=0.1)
        assert pm.A100_FLOAT.ridge_vector == pytest.approx(10, abs=0.1)
        assert pm.A100_FLOAT.ridge_matrix == pytest.approx(81, abs=1)
        assert pm.A100_FLOAT.ridge_sparse == pytest.approx(161, abs=1)


class TestScenarios:
    """Paper Table 3: six representative cases."""

    def test_case1_mb_to_cb_degrades(self):
        c = pm.compare(pm.StencilWorkload(B21, 3, 8), pm.A100_DOUBLE, 0.5)
        assert c.scenario is pm.Scenario.MB_CB
        assert c.speedup < 1.0                      # 27% degradation observed

    def test_case2_boundary(self):
        c = pm.compare(pm.StencilWorkload(B23, 1, 8), pm.A100_DOUBLE, 0.5)
        assert c.scenario is pm.Scenario.CB_CB
        assert c.speedup == pytest.approx(1.0, abs=0.01)   # ~equal perf

    def test_case3_case4_break_ceiling(self):
        for spec in (B21, B27):
            t = 7 if spec is B21 else 1
            c = pm.compare(pm.StencilWorkload(spec, t, 4), pm.A100_FLOAT,
                           0.47, use_sparse_unit=True)
            assert c.scenario is pm.Scenario.CB_MB
            assert c.speedup > 1.0

    def test_case5_case6_outside_sweet_spot(self):
        c5 = pm.compare(pm.StencilWorkload(B31, 3, 8), pm.A100_DOUBLE, 0.5)
        assert c5.scenario is pm.Scenario.CB_CB and c5.speedup < 1.0
        c6 = pm.compare(pm.StencilWorkload(B31, 7, 4), pm.A100_FLOAT, 0.47,
                        use_sparse_unit=True)
        assert c6.scenario is pm.Scenario.CB_CB and c6.speedup < 1.0

    def test_table4_sptc_bottleneck_flip(self):
        w = pm.StencilWorkload(B21, 7, 4)
        dense = pm.perf_matrix(w, pm.A100_FLOAT, 0.47)
        sparse = pm.perf_sparse_matrix(w, pm.A100_FLOAT, 0.47)
        assert dense.bound is pm.Bound.COMPUTE       # I=120 > ridge 81
        assert sparse.bound is pm.Bound.MEMORY       # I=120 < ridge 161
        # model predicts 1.49x from roofline terms alone; the paper's 3.06x
        # empirical gain includes the dense baseline underachieving its roof
        assert sparse.actual_flops / dense.actual_flops > 1.4


class TestScenarioTheorems:
    """Eq. 14/16/17: the scenario inequalities hold for ANY valid inputs."""

    @given(d=st.integers(1, 3), r=st.integers(1, 4), t=st.integers(1, 8),
           D=st.sampled_from([2, 4, 8]),
           S=st.floats(0.05, 1.0),
           shape=st.sampled_from(["box", "star"]))
    @settings(max_examples=200, deadline=None)
    def test_inequalities(self, d, r, t, D, S, shape):
        w = pm.StencilWorkload(StencilSpec(shape, d, r), t, D)
        c = pm.compare(w, pm.A100_FLOAT, S)
        if c.scenario is pm.Scenario.MB_MB:
            assert c.speedup == pytest.approx(1.0, rel=1e-6)   # Eq. 14
        elif c.scenario is pm.Scenario.MB_CB:
            assert c.speedup < 1.0 + 1e-9                      # Eq. 16
        elif c.scenario is pm.Scenario.CB_MB:
            assert c.speedup > 1.0 - 1e-9                      # Eq. 17
        else:
            # Eq. 18/19: profitable iff alpha < S * P_TC / P_CU
            lhs = w.alpha
            assert c.profitable == (lhs < c.sweet_spot_alpha_limit)

    @given(t=st.integers(1, 16))
    @settings(max_examples=50, deadline=None)
    def test_fusion_scales_intensity_linearly(self, t):
        w1 = pm.StencilWorkload(B21, 1, 4)
        wt = pm.StencilWorkload(B21, t, 4)
        assert wt.intensity_vector() == pytest.approx(t * w1.intensity_vector())


class TestSelector:
    def test_transition_depths(self):
        # paper §4.2 (A100 float): box transitions ~t=3, star ~t=5
        from repro.core.selector import transition_depth
        tb = transition_depth(B21, 4, pm.A100_FLOAT)
        ts = transition_depth(S21, 4, pm.A100_FLOAT)
        assert tb is not None and ts is not None
        assert tb <= 5 and ts >= tb   # star needs deeper fusion than box

    def test_selector_returns_valid_backend(self):
        from repro.core.selector import select_backend
        for t in (1, 3, 8):
            d = select_backend(B21, t, 4)
            expect = ("direct", "matmul") if t == 1 else \
                ("fused_direct", "fused_matmul", "fused_matmul_reuse")
            assert d.backend in expect
            assert d.reason

    def test_banded_sparsity_grows_with_radius(self):
        s1 = pm.sparsity_banded(1, 128)
        s8 = pm.sparsity_banded(8, 128)
        assert 0 < s1 < s8 < 1


class TestReuseRegime:
    """The intermediate-reuse MXU regime (DESIGN.md §4): alpha=1, priced by
    the halo-recompute factor beta instead."""

    def test_beta_formula(self):
        assert pm.halo_recompute_factor(1, 1) == 1.0
        assert pm.halo_recompute_factor(2, 4, strip_m=32) == \
            pytest.approx(1 + 2 * 3 / 32)
        # beta -> 1 as strips grow; monotone in t and r
        assert pm.halo_recompute_factor(1, 8, 1024) < \
            pm.halo_recompute_factor(1, 8, 32) < \
            pm.halo_recompute_factor(3, 8, 32)

    def test_beta_column_tiled_adds_x_axis(self):
        """On the column-tiled substrate (DESIGN.md §10) the carried
        x-halo is recomputed per step like the leading halos: the tile
        width joins the product mean, full-width betas are unchanged."""
        assert pm.reuse_beta(B21, 4, 32) == \
            pm.halo_recompute_factor(1, 4, 32)           # full width: 2D
        got = pm.reuse_beta(B21, 4, 32, w_tile=64)
        want = pm.halo_recompute_factor_nd(1, 4, (32, 64))
        assert got == pytest.approx(want)
        assert got > pm.reuse_beta(B21, 4, 32)           # strictly costlier
        # 3D: (z_slab, strip_m, w_tile) product mean
        spec3 = type(B21)("box", 3, 1)
        got3 = pm.reuse_beta(spec3, 2, 16, z_slab=8, w_tile=64)
        assert got3 == pytest.approx(
            pm.halo_recompute_factor_nd(1, 2, (8, 16, 64)))
        # lifted 1D never column-tiles and never recomputes
        spec1 = type(B21)("box", 1, 1)
        assert pm.reuse_beta(spec1, 4, 1) == 1.0

    def test_intensity_formula(self):
        # I_reuse = beta * t * K / (S * D)  (ISSUE: t*K/(S*D) as beta -> 1)
        w = pm.StencilWorkload(B21, 4, 4)
        S = pm.sparsity_banded(1, 128)
        beta = pm.halo_recompute_factor(1, 4, 128)
        assert w.intensity_matrix_reuse(S, 128) == \
            pytest.approx(beta * 4 * 9 / (S * 4))
        # no alpha anywhere: executed flops scale with beta, not alpha
        assert w.flops_matrix_reuse(S) < w.flops_matrix(S)

    def test_actual_deflates_by_s_over_beta(self):
        w = pm.StencilWorkload(B21, 7, 4)
        p = pm.perf_matrix_reuse(w, pm.A100_FLOAT, 0.47, strip_m=128)
        beta = pm.halo_recompute_factor(1, 7, 128)
        assert p.actual_flops == pytest.approx(0.47 / beta * p.raw_flops)
        assert p.unit == "matrix_reuse"

    def test_reuse_beats_monolithic_at_depth(self):
        """At SPIDER-like S the reuse regime dominates monolithic fusion
        (beta ~ 1.05 vs alpha ~ 3.57 at t=7) -- and the selector says so."""
        from repro.core.selector import select_backend
        w = pm.StencilWorkload(B21, 7, 4)
        mono = pm.perf_matrix(w, pm.A100_FLOAT, 0.47)
        reuse = pm.perf_matrix_reuse(w, pm.A100_FLOAT, 0.47)
        assert reuse.actual_flops > mono.actual_flops
        d = select_backend(B21, 7, 4, hw=pm.A100_FLOAT, sparsity=0.47)
        assert d.backend == "fused_matmul_reuse"
        assert "alpha=1" in d.reason
        assert d.predicted_speedup > 1.0

    def test_t1_reuse_degenerates_to_matmul(self):
        w = pm.StencilWorkload(B21, 1, 4)
        S = pm.sparsity_banded(1, 128)
        assert w.flops_matrix_reuse(S) == pytest.approx(w.flops_matrix(S))
