"""Per-axis BoundarySpec (DESIGN.md §15): resolution, kernel correctness
across modes/backends/ranks, the default-periodic bitwise pin, plan-cache
key distinctness, validation error paths, auditor mode-awareness (with
tamper-negatives), serve pass-through, and the distributed stepper's
boundary + overlap behavior (subprocess, multi-device).
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro import audit
from repro.kernels import clear_plan_cache, explain, stencil_apply, \
    stencil_plan
from repro.kernels import registry
from repro.kernels.common import _check_reflect_extent, _check_wrap_radius, \
    validate_tiling
from repro.kernels.plan import plan_signature
from repro.stencil import BOUNDARY_MODES, StencilSpec, is_periodic, \
    jacobi_weights, make_weights, resolve_boundary
from repro.stencil.boundary import boundary_label
from repro.stencil.reference import apply_stencil_steps, pad_boundary

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
RNG = np.random.default_rng(7)


@pytest.fixture(autouse=True)
def _hygiene():
    clear_plan_cache()
    yield
    clear_plan_cache()


def _x(shape):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32))


def _oracle(x, w, t, boundary):
    return apply_stencil_steps(x, jnp.asarray(w, x.dtype), t, boundary)


# ---------------------------------------------------------------------------
# Spec resolution
# ---------------------------------------------------------------------------
class TestResolve:
    def test_defaults_and_forms(self):
        assert resolve_boundary(None, 2) == ("periodic", "periodic")
        assert resolve_boundary("reflect", 3) == ("reflect",) * 3
        assert resolve_boundary(("zero", None), 2) == ("zero", "periodic")
        assert is_periodic(None) and is_periodic(("periodic",) * 2)
        assert not is_periodic(("periodic", "reflect"))
        assert boundary_label(("reflect", "periodic")) == "reflect×periodic"
        assert set(BOUNDARY_MODES) == {"periodic", "zero", "reflect",
                                       "replicate"}

    def test_rejections(self):
        with pytest.raises(ValueError, match="unknown boundary mode"):
            resolve_boundary("mirror", 2)
        with pytest.raises(ValueError, match="unknown boundary mode"):
            resolve_boundary(("periodic", "mirror"), 2)
        with pytest.raises(ValueError, match="1 entries for a 2-D grid"):
            resolve_boundary(("periodic",), 2)

    def test_pad_boundary_matches_np_pad(self):
        x = _x((5, 6))
        for mode, np_mode in [("zero", "constant"), ("reflect", "reflect"),
                              ("replicate", "edge"), ("periodic", "wrap")]:
            got = pad_boundary(x, 2, (mode, "periodic"))
            want = np.pad(np.asarray(x), ((2, 2), (0, 0)), mode=np_mode)
            want = np.pad(want, ((0, 0), (2, 2)), mode="wrap")
            assert np.array_equal(np.asarray(got), want), mode


# ---------------------------------------------------------------------------
# Mixed-mode grids vs padded oracle (satellite: the full small matrix)
# ---------------------------------------------------------------------------
class TestMixedModeGrids:
    @pytest.mark.parametrize("wid", [257, 300])
    @pytest.mark.parametrize("t", [1, 2])
    @pytest.mark.parametrize("shape,r", [("box", 1), ("box", 2),
                                         ("star", 1), ("star", 2)])
    def test_2d_periodic_x_reflect_y(self, shape, r, t, wid):
        """periodic-x × reflect-y on remainder widths, both unit families."""
        w = make_weights(StencilSpec(shape, 2, r), seed=r)
        x = _x((64, wid))
        ref = _oracle(x, w, t, ("reflect", "periodic"))
        for backend in ("direct", "fused_matmul_reuse"):
            y = stencil_apply(x, w, t, backend=backend,
                              boundary=("reflect", "periodic"),
                              interpret=True)
            err = float(jnp.max(jnp.abs(y - ref)))
            assert err < 5e-4, (backend, shape, r, t, wid, err)

    def test_3d_mixed_modes(self):
        w = make_weights(StencilSpec("star", 3, 1), seed=1)
        x = _x((8, 16, 128))
        b = ("replicate", "reflect", "periodic")
        ref = _oracle(x, w, 2, b)
        for backend in ("fused_direct", "fused_matmul_reuse"):
            y = stencil_apply(x, w, 2, backend=backend, boundary=b,
                              interpret=True)
            err = float(jnp.max(jnp.abs(y - ref)))
            assert err < 5e-4, (backend, err)


# ---------------------------------------------------------------------------
# Every mode, every backend family (one geometry), 1D lift included
# ---------------------------------------------------------------------------
class TestAllModesAllBackends:
    BACKENDS = ("direct", "fused_direct", "matmul", "fused_matmul_reuse",
                "sparse_matmul", "fused_sparse_matmul")

    @pytest.mark.parametrize("mode", ["zero", "reflect", "replicate"])
    def test_uniform_mode_2d(self, mode):
        w = make_weights(StencilSpec("star", 2, 2), seed=2)
        x = _x((64, 128))
        ref = _oracle(x, w, 2, mode)
        for backend in self.BACKENDS:
            y = stencil_apply(x, w, 2, backend=backend, boundary=mode,
                              interpret=True)
            err = float(jnp.max(jnp.abs(y - ref)))
            assert err < 5e-4, (backend, mode, err)

    @pytest.mark.parametrize("mode", ["zero", "reflect", "replicate"])
    def test_uniform_mode_1d(self, mode):
        w = make_weights(StencilSpec("box", 1, 2), seed=3)
        x = _x((512,))
        ref = _oracle(x, w, 2, mode)
        for backend in ("direct", "fused_direct", "fused_matmul_reuse"):
            y = stencil_apply(x, w, 2, backend=backend, boundary=mode,
                              interpret=True)
            err = float(jnp.max(jnp.abs(y - ref)))
            assert err < 5e-4, (backend, mode, err)

    def test_monolithic_fusion_rejects_nonperiodic_multistep(self):
        """fused_matmul bakes ONE boundary extension into t steps -- it
        must refuse rather than silently drift from the per-step oracle."""
        w = jacobi_weights(StencilSpec("box", 2, 1))
        with pytest.raises(ValueError, match="monolithic fusion"):
            stencil_plan(w, (64, 128), np.float32, 2, backend="fused_matmul",
                         boundary="zero", interpret=True)
        # t=1: the composed kernel IS one step -- every mode is legal.
        x = _x((64, 128))
        y = stencil_apply(x, w, 1, backend="fused_matmul", boundary="zero",
                          interpret=True)
        err = float(jnp.max(jnp.abs(y - _oracle(x, w, 1, "zero"))))
        assert err < 5e-4

    def test_auto_selection_avoids_monolithic_on_nonperiodic(self):
        w = jacobi_weights(StencilSpec("box", 2, 1))
        p = stencil_plan(w, (256, 512), np.float32, 4, boundary="reflect",
                         interpret=True)
        assert p.backend != "fused_matmul"
        x = _x((256, 512))
        err = float(jnp.max(jnp.abs(p(x) - _oracle(x, w, 4, "reflect"))))
        assert err < 5e-4


# ---------------------------------------------------------------------------
# Default-periodic pin: bitwise + cache-key + reason-string invariance
# ---------------------------------------------------------------------------
class TestPeriodicPin:
    def test_default_bitwise_and_shared_cache_entry(self):
        w = jacobi_weights(StencilSpec("box", 2, 1))
        grid = (64, 128)
        k_none = plan_signature(w, grid, np.float32, 2)[0]
        k_str = plan_signature(w, grid, np.float32, 2, boundary="periodic")[0]
        k_tup = plan_signature(w, grid, np.float32, 2,
                               boundary=("periodic", "periodic"))[0]
        assert k_none == k_str == k_tup
        p0 = stencil_plan(w, grid, np.float32, 2, interpret=True)
        p1 = stencil_plan(w, grid, np.float32, 2, boundary="periodic",
                          interpret=True)
        assert p1 is p0, "all-periodic spellings must share one cached plan"
        x = _x(grid)
        assert bool(jnp.all(p0(x) == p1(x)))

    def test_nonperiodic_keys_distinct(self):
        w = jacobi_weights(StencilSpec("box", 2, 1))
        grid = (64, 128)
        keys = {plan_signature(w, grid, np.float32, 2, boundary=b)[0]
                for b in [None, "zero", "reflect", "replicate",
                          ("reflect", "periodic"), ("periodic", "reflect")]}
        assert len(keys) == 6, "every distinct spec needs its own plan"

    def test_reason_string_only_changes_when_nonperiodic(self):
        w = jacobi_weights(StencilSpec("box", 2, 1))
        base = explain(w, 2, grid_shape=(256, 512))
        again = explain(w, 2, grid_shape=(256, 512), boundary="periodic")
        assert base.reason == again.reason
        assert "boundary=" not in base.reason
        refl = explain(w, 2, grid_shape=(256, 512),
                       boundary=("reflect", "periodic"))
        assert "boundary=reflect×periodic" in refl.reason

    def test_explain_lists_boundary_line(self):
        w = jacobi_weights(StencilSpec("box", 2, 1))
        p = stencil_plan(w, (64, 128), np.float32, 2,
                         boundary=("reflect", "periodic"), interpret=True)
        assert "boundary : reflect×periodic" in p.explain()
        p0 = stencil_plan(w, (64, 128), np.float32, 2, interpret=True)
        assert "boundary" not in p0.explain()


# ---------------------------------------------------------------------------
# Validation error paths (satellite: 1D/2D/3D mode-specific guards)
# ---------------------------------------------------------------------------
class TestValidationErrorPaths:
    def test_wrap_radius_messages(self):
        # periodic keeps the historical message (and w == r stays legal)
        _check_wrap_radius(2, 2, "periodic")
        with pytest.raises(ValueError, match="wrap radius .* lower the"):
            _check_wrap_radius(1, 2, "periodic")
        # non-periodic: r >= w is degenerate, mode named in the message
        for mode in ("zero", "reflect", "replicate"):
            with pytest.raises(ValueError, match=f"whole {mode!r} axis"):
                _check_wrap_radius(2, 2, mode)
            _check_wrap_radius(3, 2, mode)

    def test_reflect_extent_guard(self):
        with pytest.raises(ValueError, match="mirror cells"):
            _check_reflect_extent(2, 2, "x", "reflect")
        _check_reflect_extent(3, 2, "x", "reflect")
        _check_reflect_extent(2, 2, "x", "zero")  # only reflect needs depth

    def test_1d_error_path(self):
        w = jacobi_weights(StencilSpec("box", 1, 2))
        with pytest.raises(ValueError, match="whole 'zero' axis"):
            stencil_plan(w, (2,), np.float32, 1, backend="direct",
                         boundary="zero", interpret=True)
        # reflect mirror-depth binds when the FUSED halo t*r exceeds the
        # per-step radius: extent 4 > r=2 but < halo+1 = 5
        with pytest.raises(ValueError, match="mirror cells"):
            stencil_plan(w, (4,), np.float32, 2, backend="fused_direct",
                         boundary="reflect", interpret=True)

    def test_2d_error_path(self):
        # rows axis: reflect needs extent >= halo+1
        with pytest.raises(ValueError, match="mirror cells"):
            validate_tiling((2, 128), 2, 128, 2, radius=1,
                            boundary=("reflect", "periodic"))
        # same shape, periodic rows: the historical no-guard behavior
        validate_tiling((2, 128), 2, 128, 2, radius=1)
        # columns axis: r >= w on a non-periodic axis
        w = jacobi_weights(StencilSpec("box", 2, 2))
        with pytest.raises(ValueError, match="whole 'replicate' axis"):
            stencil_plan(w, (64, 2), np.float32, 1, backend="direct",
                         boundary=("periodic", "replicate"), interpret=True)

    def test_3d_error_path(self):
        with pytest.raises(ValueError, match="whole 'replicate' axis"):
            validate_tiling((2, 64, 128), 64, 128, 2, radius=2,
                            boundary=("replicate", "periodic", "periodic"))
        with pytest.raises(ValueError, match="mirror cells"):
            validate_tiling((2, 64, 128), 64, 128, 2, radius=1,
                            boundary=("reflect", "periodic", "periodic"))
        validate_tiling((2, 64, 128), 64, 128, 2, radius=2)


# ---------------------------------------------------------------------------
# Auditor: mode-aware coverage, positive + tamper-negative
# ---------------------------------------------------------------------------
def _ctx(grid, t=2, boundary=None, shape="box", r=1):
    spec = StencilSpec(shape, len(grid), r)
    w = make_weights(spec, seed=r)
    return registry.PlanContext(
        spec=spec, weights=w, grid_shape=tuple(grid),
        dtype=np.dtype(np.float32), t=t, tile_m=None, tile_n=None,
        interpret=True, h_block=None, z_slab=None, z_block=None,
        w_tile=None, w_block=None,
        boundary=resolve_boundary(boundary, len(grid)))


class TestAuditorBoundary:
    @pytest.mark.parametrize("mode", ["periodic", "zero", "reflect",
                                      "replicate"])
    def test_coverage_passes_every_mode(self, mode):
        for backend in ("fused_direct", "fused_matmul_reuse"):
            rep = audit.audit_context(_ctx((256, 512), boundary=mode),
                                      backend)
            assert rep.ok, (mode, backend, rep.summary())

    def test_mixed_mode_3d_audit(self):
        rep = audit.audit_context(
            _ctx((32, 64, 128), boundary=("reflect", "periodic", "zero")),
            "fused_direct")
        assert rep.ok, rep.summary()

    def test_tamper_periodic_maps_declared_reflect_is_caught(self):
        """Wrong-mode index maps (periodic mod-wrap under a declared
        reflect axis) must fail scratch/coverage-global -- the halo
        off-by-one class this check exists for."""
        launch = registry.get_backend("fused_direct").audit(
            _ctx((256, 512))).launches[0]
        lg = launch.launch_geometry()      # periodic maps
        bad = dataclasses.replace(lg, boundary=("reflect", "periodic"))
        checks = audit.audit_scratch(bad, launch)
        viol = {c.name for c in checks if not c.passed and not c.skipped}
        assert "scratch/coverage-global" in viol

    def test_tamper_reflect_maps_declared_periodic_is_caught(self):
        launch = registry.get_backend("fused_direct").audit(
            _ctx((256, 512), boundary=("reflect", "periodic"))).launches[0]
        lg = launch.launch_geometry()      # reflect maps
        bad = dataclasses.replace(lg, boundary=("periodic", "periodic"))
        checks = audit.audit_scratch(bad, launch)
        viol = {c.name for c in checks if not c.passed and not c.skipped}
        assert "scratch/coverage-global" in viol


# ---------------------------------------------------------------------------
# Serve: boundary rides the plan signature through submit()
# ---------------------------------------------------------------------------
class TestServeBoundary:
    def test_submit_with_boundary_matches_oracle(self):
        from repro.serve import StencilServer
        w = jacobi_weights(StencilSpec("box", 2, 1))
        x = RNG.normal(size=(8, 8)).astype(np.float32)
        ref = np.asarray(_oracle(jnp.asarray(x), w, 2,
                                 ("reflect", "periodic")))
        with StencilServer(max_batch=4, queue_timeout_ms=20) as server:
            per = server.submit(w, x, t=2).result(timeout=60)
            got = server.submit(w, x, t=2,
                                boundary=("reflect", "periodic")) \
                        .result(timeout=60)
        assert np.allclose(got, ref, rtol=1e-5, atol=1e-5)
        assert not np.allclose(per, got), \
            "boundary must change the served result (distinct plan key)"


# ---------------------------------------------------------------------------
# Distributed: stepwise honors modes; overlap is bitwise + interleaved
# ---------------------------------------------------------------------------
def _run_with_devices(n, code):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=560)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


class TestDistributedBoundary:
    def test_stepwise_modes_and_overlap_bitwise(self):
        out = _run_with_devices(4, """
            import jax, numpy as np, jax.numpy as jnp
            from jax.sharding import Mesh
            from repro.stencil import StencilSpec, make_weights
            from repro.stencil.reference import apply_stencil_steps
            from repro.stencil.distributed import (
                make_distributed_stepper, overlap_stats,
                reset_overlap_stats, overlap_independence_report)
            mesh = Mesh(np.array(jax.devices()), ("i",))
            w = make_weights(StencilSpec("star", 2, 1), seed=4)
            x = jnp.asarray(np.random.default_rng(5)
                            .normal(size=(64, 96)).astype(np.float32))
            for t in (1, 3):
                for b in (None, ("reflect", "periodic"),
                          ("zero", "replicate")):
                    ref = apply_stencil_steps(
                        x, jnp.asarray(w), t,
                        "periodic" if b is None else b)
                    sw = make_distributed_stepper(
                        mesh, ("i", None), w, t=t, mode="stepwise",
                        boundary=b)
                    ov = make_distributed_stepper(
                        mesh, ("i", None), w, t=t, mode="overlap",
                        boundary=b)
                    ysw, yov = sw(x), ov(x)
                    assert float(jnp.max(jnp.abs(ysw - ref))) < 5e-5, (t, b)
                    # overlap re-schedules, never re-orders: bit for bit
                    assert bool(jnp.all(ysw == yov)), (t, b)
            # trace-time interleave: interior constructed before any recv
            reset_overlap_stats()
            step = make_distributed_stepper(mesh, ("i", None), w, t=2,
                                            mode="overlap")
            step(x)
            st = overlap_stats()
            assert st["interior_before_recv_consumed"] >= 2, st
            assert st["edge_launches"] == 2 * st["overlap_steps"], st
            # jaxpr taint proof: the reassembly concat's interior operand
            # never touches a ppermute result
            rep = overlap_independence_report(mesh, ("i", None), w, x)
            assert rep["interior_independent"], rep
            assert rep["ppermute_eqns"] == 2, rep
            # fused + non-periodic must refuse
            try:
                make_distributed_stepper(mesh, ("i", None), w, t=2,
                                         mode="fused", boundary="reflect")
                raise SystemExit("fused accepted a non-periodic spec")
            except ValueError:
                pass
            # overlap needs exactly one sharded dim
            try:
                make_distributed_stepper(
                    Mesh(np.array(jax.devices()).reshape(2, 2),
                         ("i", "j")), ("i", "j"), w, mode="overlap")
                raise SystemExit("overlap accepted two sharded dims")
            except ValueError:
                pass
            print("OK")
        """)
        assert "OK" in out

    def test_plan_level_overlap_halo_plan(self):
        out = _run_with_devices(2, """
            import jax, numpy as np, jax.numpy as jnp
            from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
            from repro.kernels import stencil_plan
            from repro.stencil import StencilSpec, jacobi_weights
            from repro.stencil.reference import apply_stencil_steps
            mesh = Mesh(np.array(jax.devices()), ("x",))
            w = jacobi_weights(StencilSpec("box", 2, 1))
            x = np.random.default_rng(6).normal(size=(64, 64)) \
                  .astype(np.float32)
            xs = jax.device_put(x, NamedSharding(mesh, P("x", None)))
            p = stencil_plan(w, (64, 64), np.float32, 2, mesh=mesh,
                             shard_spec=("x", None), dist_mode="overlap",
                             backend="fused_direct",
                             boundary=("reflect", "periodic"))
            hp = p.halo_plan
            assert hp["mode"] == "overlap" and hp["exchanges_per_call"] == 2
            assert 0 < hp["interior_fraction"] < 1
            assert "interior_fraction" in p.explain()
            ref = apply_stencil_steps(jnp.asarray(x), jnp.asarray(w), 2,
                                      ("reflect", "periodic"))
            assert float(jnp.max(jnp.abs(p(xs) - ref))) < 5e-5
            print("OK")
        """)
        assert "OK" in out
