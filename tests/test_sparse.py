"""Sparse band-compaction backends (DESIGN.md §14): the compacted MXU
contraction is bitwise-equal to the dense banded path, the closed-form
sparsity/kept-row formulas match the materialized operands, the static
auditor proves (and catches tampering of) the compaction metadata, and
the selector's sparse sweet spot agrees between ``ops.explain`` and the
built plan."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro import audit
from repro.core import perfmodel as pm
from repro.kernels import (band_sparsity, build_bands, build_bands_nd,
                           clear_plan_cache, explain, stencil_plan)
from repro.kernels import registry
from repro.kernels.plan import plan_signature
from repro.kernels.ref import stencil_direct_ref
from repro.kernels.stencil_sparse import (band_row_meta, compact_bands,
                                          kept_row_fraction)
from repro.stencil import StencilSpec, make_weights

RNG = np.random.default_rng(7)


@pytest.fixture(autouse=True)
def _fresh_cache():
    clear_plan_cache()
    yield
    clear_plan_cache()


def _weights(shape, r, seed=0):
    return make_weights(StencilSpec(shape, 2, r), seed=seed)


def _ctx(grid, t=2, shape="star", r=1, tile_n=None):
    spec = StencilSpec(shape, len(grid), r)
    w = make_weights(spec, seed=r)
    return registry.PlanContext(
        spec=spec, weights=w, grid_shape=tuple(grid),
        dtype=np.dtype(np.float32), t=t, tile_m=None, tile_n=tile_n,
        interpret=True, h_block=None, z_slab=None, z_block=None,
        w_tile=None, w_block=None)


# ---------------------------------------------------------------------------
# Satellites 1+2: closed-form band_sparsity and vectorized build_bands
# cross-checked against materialized/reference constructions
# ---------------------------------------------------------------------------
class TestBandConstruction:
    @pytest.mark.parametrize("shape", ["box", "star"])
    @pytest.mark.parametrize("r", [1, 2, 3])
    @pytest.mark.parametrize("tile_n", [32, 128])
    def test_closed_form_sparsity_vs_materialized(self, shape, r, tile_n):
        """band_sparsity's closed form == nonzeros of the built operand."""
        w = np.asarray(_weights(shape, r), dtype=np.float32)
        _, bands = build_bands_nd(w, tile_n)
        measured = np.count_nonzero(bands) / bands.size
        assert band_sparsity(w, tile_n) == pytest.approx(measured, rel=1e-12)

    @pytest.mark.parametrize("shape", ["box", "star"])
    @pytest.mark.parametrize("r", [1, 2, 3])
    def test_vectorized_build_matches_reference_loop(self, shape, r):
        """The vectorized diagonal fill == the naive triple loop."""
        w = np.asarray(_weights(shape, r), dtype=np.float32)
        tile_n = 64
        rows, kx = w.shape
        ref = np.zeros((rows, tile_n + 2 * r, tile_n), dtype=w.dtype)
        for row in range(rows):
            for dx in range(kx):
                for j in range(tile_n):
                    ref[row, j + dx, j] = w[row, dx]
        np.testing.assert_array_equal(build_bands(w, tile_n), ref)

    @pytest.mark.parametrize("shape,r", [("box", 1), ("box", 2),
                                         ("star", 1), ("star", 2),
                                         ("star", 3)])
    def test_compaction_hull(self, shape, r):
        """compact_bands keeps exactly the contiguous nonzero hull: the
        packed rows scatter back to the dense bands, the packed row count
        matches the kept_row_fraction closed form, and box kernels (every
        band row populated) compact to S = 1."""
        w = np.asarray(_weights(shape, r), dtype=np.float32)
        tile_n = 32
        offsets, bands = build_bands_nd(w, tile_n)
        row_index, packed = compact_bands(offsets, bands)
        assert len(row_index) == len(offsets)
        rebuilt = np.zeros_like(bands)
        start = 0
        for p, ix in enumerate(row_index):
            rebuilt[p, ix] = packed[start:start + ix.size]
            start += ix.size
        np.testing.assert_array_equal(rebuilt, bands)
        assert start == packed.shape[0] == sum(ix.size for ix in row_index)
        S = packed.shape[0] / (len(offsets) * (tile_n + 2 * r))
        assert kept_row_fraction(w, tile_n) == pytest.approx(S, rel=1e-12)
        if shape == "box":
            assert S == 1.0
        else:
            assert S < 1.0

    def test_row_meta_spans(self):
        w = np.asarray(_weights("star", 2), dtype=np.float32)
        offsets, bands = build_bands_nd(w, 32)
        row_index, packed = compact_bands(offsets, bands)
        meta = band_row_meta(row_index, 32)
        assert len(meta) == len(offsets)
        starts = [row_start for _, _, row_start in meta]
        assert starts == sorted(starts) and starts[0] == 0
        for lo, span, row_start in meta:
            assert 0 <= lo and 0 <= span and lo + span <= 4
        assert meta[-1][2] + 32 + meta[-1][1] == packed.shape[0]


# ---------------------------------------------------------------------------
# Satellite 3: bitwise equivalence of the compacted contraction
# ---------------------------------------------------------------------------
def _plans(w, grid, dtype, t, **pins):
    """(sparse, dense) plan pair at matched geometry for fusion depth t."""
    sp, dn = (("sparse_matmul", "matmul") if t == 1
              else ("fused_sparse_matmul", "fused_matmul_reuse"))
    mk = lambda b: stencil_plan(np.asarray(w), grid, dtype, t, backend=b,
                                interpret=True, **pins)
    return mk(sp), mk(dn)


class TestBitwiseEquivalence:
    """The compaction contract: dropping structurally-zero band rows is
    graph-equivalent, so sparse output == dense matmul output BITWISE
    (not merely close) on every shape/radius/depth/dtype/width."""

    @pytest.mark.parametrize("shape", ["box", "star"])
    @pytest.mark.parametrize("r", [1, 2, 3])
    @pytest.mark.parametrize("t", [1, 2, 4])
    def test_shape_radius_depth(self, shape, r, t):
        w = _weights(shape, r, seed=r)
        x = jnp.asarray(RNG.normal(size=(32, 257)).astype(np.float32))
        sp, dn = _plans(w, x.shape, np.float32, t, tile_m=16)
        ys, yd = np.asarray(sp(x)), np.asarray(dn(x))
        assert np.array_equal(ys, yd), \
            f"sparse != dense bitwise: {shape} r={r} t={t}"
        ref = np.asarray(stencil_direct_ref(x, jnp.asarray(w), t))
        np.testing.assert_allclose(ys, ref, atol=1e-3, rtol=1e-4)

    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    @pytest.mark.parametrize("wid", [257, 300])
    def test_dtype_and_remainder_width(self, dtype, wid):
        """Remainder chunks (257 -> 1-wide, 300 -> 44-wide tails at
        tile_n=128) re-expand to the dense prefix, keeping bitwise parity
        in both dtypes."""
        w = _weights("star", 2, seed=2)
        x = jnp.asarray(RNG.normal(size=(32, wid))).astype(dtype)
        sp, dn = _plans(w, x.shape, x.dtype.type, 2, tile_m=16)
        assert np.array_equal(np.asarray(sp(x)), np.asarray(dn(x))), \
            f"sparse != dense bitwise: {dtype} W={wid}"


# ---------------------------------------------------------------------------
# Audit: the compaction proofs pass -- and catch mis-compaction
# ---------------------------------------------------------------------------
class TestSparseAudit:
    @pytest.mark.parametrize("backend", ["sparse_matmul",
                                         "fused_sparse_matmul"])
    @pytest.mark.parametrize("shape", ["box", "star"])
    def test_zero_violations(self, backend, shape):
        t = 1 if backend == "sparse_matmul" else 2
        rep = audit.audit_context(_ctx((256, 512), t=t, shape=shape),
                                  backend)
        assert rep.exempt is None
        assert rep.ok, rep.summary()
        names = {c.name for c in rep.checks if not c.skipped}
        assert "flops/sparse-compaction" in names
        assert "scratch/gather-window" in names

    def test_3d_star_zero_violations(self):
        rep = audit.audit_context(_ctx((24, 48, 100), t=2, shape="star"),
                                  "fused_sparse_matmul")
        assert rep.ok, rep.summary()

    def _tampered(self, **replacements):
        ctx = _ctx((256, 512), t=2, shape="star")
        bd = registry.get_backend("fused_sparse_matmul")
        spec = bd.audit(ctx)
        bad = dataclasses.replace(spec.launches[0], **replacements)
        return ctx, bd, spec, bad

    def test_inflated_bands_shape_is_caught(self):
        """A wrong packed-row count (claiming fewer MXU FLOPs than the
        kernel executes) must fail the jaxpr-counted compaction proof,
        the structural mirror AND the gather-window bookkeeping."""
        ctx, bd, spec, l0 = self._tampered()
        bad = dataclasses.replace(
            l0, bands_shape=(l0.bands_shape[0] - 8, l0.bands_shape[1]))
        checks = audit.audit_flops(
            ctx, dataclasses.replace(spec, launches=(bad,)), bd.build(ctx))
        viol = {c.name for c in checks if not c.passed and not c.skipped}
        assert "flops/sparse-compaction" in viol
        assert "flops/structural" in viol
        gw = audit.audit_scratch(bad.launch_geometry(), bad)
        assert any(c.name == "scratch/gather-window" and not c.passed
                   for c in gw)

    def test_out_of_support_gather_is_caught(self):
        """A gather window escaping the dense band support [0, 2r] would
        read rows that do not exist -- scratch/gather-window flags it."""
        ctx, bd, spec, l0 = self._tampered()
        bad = dataclasses.replace(l0, band_lo=(99,) + l0.band_lo[1:])
        checks = audit.audit_scratch(bad.launch_geometry(), bad)
        assert any(c.name == "scratch/gather-window" and not c.passed
                   for c in checks)

    def test_span_mismatch_is_caught(self):
        ctx, bd, spec, l0 = self._tampered()
        bad = dataclasses.replace(l0, band_spans=(l0.band_spans[0] + 1,)
                                  + l0.band_spans[1:])
        checks = audit.audit_scratch(bad.launch_geometry(), bad)
        assert any(c.name == "scratch/gather-window" and not c.passed
                   for c in checks)

    def test_missing_metadata_is_caught(self):
        ctx, bd, spec, l0 = self._tampered()
        bad = dataclasses.replace(l0, band_lo=None, band_spans=None)
        checks = audit.audit_scratch(bad.launch_geometry(), bad)
        assert any(c.name == "scratch/gather-window" and not c.passed
                   for c in checks)


# ---------------------------------------------------------------------------
# Selector: the sparse sweet spot flips selection, explain == plan
# ---------------------------------------------------------------------------
FLIP = dict(grid=(256, 512), t=2, tile_n=32)


class TestSparseSelection:
    def test_star_flips_to_sparse(self):
        """At tile_n=32 the star kernel's kept-row fraction (S=0.9608)
        times the gather overhead beats the dense candidates on the
        compute-bound side -- the sparse unit flips the selection."""
        w = make_weights(StencilSpec("star", 2, 1), seed=1)
        base = dict(dtype_bytes=4, grid_shape=FLIP["grid"],
                    tile_n=FLIP["tile_n"])
        dense = explain(w, FLIP["t"], **base)
        d = explain(w, FLIP["t"], use_sparse_unit=True, **base)
        assert dense.backend != "fused_sparse_matmul"
        assert d.backend == "fused_sparse_matmul"
        assert "sparse sweet spot" in d.reason
        assert "S=" in d.reason

    def test_box_never_flips(self):
        """Box kernels compact to S = 1: the overhead term keeps the
        dense path ahead even with the sparse unit admitted."""
        w = make_weights(StencilSpec("box", 2, 1), seed=1)
        d = explain(w, FLIP["t"], dtype_bytes=4, grid_shape=FLIP["grid"],
                    tile_n=FLIP["tile_n"], use_sparse_unit=True)
        assert d.backend != "fused_sparse_matmul"

    def test_explain_matches_plan_decision(self):
        """Acceptance: ops.explain and the built plan report the same
        backend and the same sweet-spot boundary on the flip workload."""
        w = make_weights(StencilSpec("star", 2, 1), seed=1)
        d = explain(w, FLIP["t"], dtype_bytes=4, grid_shape=FLIP["grid"],
                    tile_n=FLIP["tile_n"], use_sparse_unit=True)
        p = stencil_plan(np.asarray(w), FLIP["grid"], np.float32, FLIP["t"],
                         tile_n=FLIP["tile_n"], use_sparse_unit=True,
                         interpret=True)
        assert p.backend == d.backend == "fused_sparse_matmul"
        assert p.decision.reason == d.reason

    def test_plan_key_includes_sparse_flag(self):
        """use_sparse_unit changes the selection, so it must be part of
        the plan cache key."""
        w = np.asarray(make_weights(StencilSpec("star", 2, 1), seed=1))
        base = lambda **kw: plan_signature(w, FLIP["grid"], np.float32,
                                           FLIP["t"], tile_n=FLIP["tile_n"],
                                           interpret=True, **kw)
        assert base(use_sparse_unit=True) != base(use_sparse_unit=False)
        assert base() == base(use_sparse_unit=False)


# ---------------------------------------------------------------------------
# Perfmodel: the sparse-banded unit's formulas and guards
# ---------------------------------------------------------------------------
class TestSparsePerfModel:
    def test_compaction_overhead(self):
        assert pm.compaction_overhead(128) == pytest.approx(1 / 256)
        assert pm.compaction_overhead(32) == pytest.approx(1 / 64)
        with pytest.raises(ValueError, match="positive"):
            pm.compaction_overhead(0)

    def test_kept_bounds_checked(self):
        w = pm.StencilWorkload(StencilSpec("star", 2, 1), 2, 4)
        for kept in (0.0, -0.5, 1.5):
            with pytest.raises(ValueError, match="kept"):
                pm.perf_sparse_banded(w, pm.TPU_V5E_BF16, 0.5, kept)
            with pytest.raises(ValueError, match="kept"):
                pm.perf_sparse_banded_reuse(w, pm.TPU_V5E_BF16, 0.5, kept)

    def test_mxu_fallback_peak(self):
        """Parts without a sparse unit price the compacted contraction
        on the plain MXU; kept=1 with zero overhead must then reproduce
        the dense matrix-reuse evaluation exactly."""
        w = pm.StencilWorkload(StencilSpec("star", 2, 1), 2, 4)
        sp = pm.perf_sparse_banded_reuse(w, pm.TPU_V5E_BF16, 0.5, 1.0, 0.0)
        dn = pm.perf_matrix_reuse(w, pm.TPU_V5E_BF16, 0.5)
        assert sp.raw_flops == pytest.approx(dn.raw_flops)
        assert sp.actual_flops == pytest.approx(dn.actual_flops)
        assert sp.raw_flops <= pm.TPU_V5E_BF16.p_matrix

    def test_sparse_unit_raises_ceiling(self):
        """On A100 the SpTC peak applies: a compute-bound compacted
        workload must strictly beat the dense matrix path."""
        hw = pm.A100_FLOAT
        assert hw.p_sparse is not None
        w = pm.StencilWorkload(StencilSpec("star", 2, 1), 8, 4)
        kept = 0.9
        sp = pm.perf_sparse_banded(w, hw, 0.5, kept)
        dn = pm.perf_matrix(w, hw, 0.5)
        if sp.bound is pm.Bound.COMPUTE and dn.bound is pm.Bound.COMPUTE:
            assert sp.actual_flops > dn.actual_flops
        assert pm._sparse_peak(hw) == hw.p_sparse
        assert pm._sparse_peak(pm.TPU_V5E_BF16) == pm.TPU_V5E_BF16.p_matrix
