"""Static plan auditor (repro.audit, DESIGN.md §13): the block-access /
scratch / FLOP proofs pass on every non-legacy backend across ranks and
remainder widths, the plan layer attaches reports and counts, the
explain reason-string read-amp has an audited third witness, and every
violation class is demonstrably CAUGHT by the negative harness (fault
injection, corrupted geometry, monkeypatched model)."""
import dataclasses
import math

import numpy as np
import pytest

from repro import audit
from repro.audit.blocks import audited_read_amp, enumerate_fetches
from repro.core import perfmodel as pm
from repro.kernels import clear_plan_cache, explain, plan_cache_stats, \
    stencil_plan
from repro.kernels import registry
from repro.kernels.common import launch_geometry, resolve_substrate_geom
from repro.stencil import StencilSpec, make_weights
from repro.testing import faults

CORE_BACKENDS = ("direct", "fused_direct", "matmul", "fused_matmul",
                 "fused_matmul_reuse")
FOIL_BACKENDS = tuple(f"{b}_wholestrip" for b in CORE_BACKENDS)


@pytest.fixture(autouse=True)
def _hygiene():
    faults.reset_faults()
    clear_plan_cache()
    yield
    faults.reset_faults()
    clear_plan_cache()


def _ctx(grid, t=2, shape="box", r=1, **pins):
    spec = StencilSpec(shape, len(grid), r)
    w = make_weights(spec, seed=r)
    return registry.PlanContext(
        spec=spec, weights=w, grid_shape=tuple(grid),
        dtype=np.dtype(np.float32), t=t, tile_m=None, tile_n=None,
        interpret=True, h_block=pins.get("h_block"),
        z_slab=pins.get("z_slab"), z_block=pins.get("z_block"),
        w_tile=pins.get("w_tile"), w_block=pins.get("w_block"))


# ---------------------------------------------------------------------------
# Positive sweep: audited structure == analytic model everywhere
# ---------------------------------------------------------------------------
class TestAuditSweep:
    """Satellite (c): audited bytes equal the analytic formula on awkward
    widths and off-128 3D grids, every non-legacy backend, including the
    edge-tile remainder path."""

    @pytest.mark.parametrize("backend", CORE_BACKENDS)
    @pytest.mark.parametrize("wid", [257, 300, 1000])
    def test_remainder_width_2d(self, backend, wid):
        # Pinned w_tile forces the column-tiled walk; 257 and 300 take
        # the non-dividing edge-tile path, 1000 a dividing-but-odd one.
        ctx = _ctx((128, wid), t=2, w_tile=128 if wid != 1000 else 125,
                   w_block=32 if wid != 1000 else 25)
        rep = audit.audit_context(ctx, backend)
        assert rep.exempt is None
        assert rep.ok, rep.summary()
        byte_checks = [c for c in rep.checks
                       if c.name == "blocks/grid-bytes-model"
                       and not c.skipped]
        assert byte_checks, "byte-model check must run on these grids"
        for c in byte_checks:
            assert c.expected == c.actual

    @pytest.mark.parametrize("backend", CORE_BACKENDS)
    @pytest.mark.parametrize("grid", [(32, 64, 128), (24, 48, 100)])
    def test_3d_grids(self, backend, grid):
        rep = audit.audit_context(_ctx(grid, t=2), backend)
        assert rep.exempt is None
        assert rep.ok, rep.summary()

    @pytest.mark.parametrize("backend", FOIL_BACKENDS)
    def test_wholestrip_foils(self, backend):
        rep = audit.audit_context(_ctx((256, 512), t=2), backend)
        assert rep.exempt is None
        assert rep.ok, rep.summary()

    @pytest.mark.parametrize("backend", CORE_BACKENDS)
    def test_1d_lift(self, backend):
        rep = audit.audit_context(_ctx((1000,), t=2), backend)
        assert rep.ok, rep.summary()

    def test_legacy_and_reference_exempt(self):
        for name in ("legacy_direct", "legacy_matmul", "reference"):
            rep = audit.audit_context(_ctx((128, 256)), name)
            assert rep.exempt is not None
            assert rep.ok and not rep.checks

    def test_fetch_enumeration_matches_formula_exactly(self):
        """The dedup'd walk is integer-exact against the closed form on a
        non-degenerate sub-blocked geometry."""
        ctx = _ctx((256, 512), t=2)
        launch = registry.get_backend("fused_direct").audit(ctx).launches[0]
        lg = launch.launch_geometry()
        counts, n_steps = enumerate_fetches(lg)
        audited = sum(c * math.prod(lg.in_block) for c in counts) * 4
        from repro.kernels.common import hbm_read_bytes_per_step
        g = launch.geom
        assert audited == hbm_read_bytes_per_step(
            (256, 512), g.strip_m, 4, h_block=g.h_block,
            w_tile=g.w_tile, w_block=g.w_block)
        assert n_steps == math.prod(lg.grid)


# ---------------------------------------------------------------------------
# Explain parity: the reason string's read-amp gets an audited witness
# ---------------------------------------------------------------------------
class TestReasonReadAmpParity:
    """Satellite (d): explain()'s reason-string read-amp, the plan's
    priced SubstrateGeom.read_amp, and the audited BlockSpec walk all
    agree -- three independent witnesses of one number."""

    @pytest.mark.parametrize("grid,t", [((256, 512), 2), ((192, 160), 4),
                                        ((32, 64, 128), 2), ((1000,), 2)])
    def test_three_witnesses(self, grid, t):
        spec = StencilSpec("box", len(grid), 1)
        w = make_weights(spec, seed=1)
        d = explain(w, t, dtype_bytes=4, grid_shape=grid)
        geom_px = resolve_substrate_geom(grid, t * spec.radius, 4,
                                         None, None, None, None, None, None)
        check = audit.audit_reason_read_amp(d.reason, grid, geom_px,
                                            t * spec.radius, 4)
        assert check.passed and not check.skipped, check.to_dict()
        lg = launch_geometry(grid, geom_px, t * spec.radius,
                             t * spec.radius if geom_px.w_tile else 0)
        assert math.isclose(audited_read_amp(lg, 4), geom_px.read_amp,
                            rel_tol=1e-9)

    def test_missing_read_amp_in_reason_is_a_violation(self):
        geom_px = resolve_substrate_geom((256, 512), 2, 4,
                                         None, None, None, None, None, None)
        check = audit.audit_reason_read_amp("no geometry here", (256, 512),
                                            geom_px, 2, 4)
        assert not check.passed

    def test_wrong_quoted_amp_is_a_violation(self):
        geom_px = resolve_substrate_geom((256, 512), 2, 4,
                                         None, None, None, None, None, None)
        check = audit.audit_reason_read_amp(
            "scenario x | substrate read_amp=2.999x (geom)", (256, 512),
            geom_px, 2, 4)
        assert not check.passed


# ---------------------------------------------------------------------------
# Plan attachment and counters
# ---------------------------------------------------------------------------
class TestPlanAttachment:
    def test_audit_true_attaches_clean_report_and_counts(self):
        before = plan_cache_stats()
        plan = stencil_plan(make_weights(StencilSpec("box", 2, 1), seed=0),
                            (256, 512), np.float32, 2, interpret=True,
                            audit=True)
        assert plan.audit_report is not None
        assert plan.audit_report.ok, plan.audit_report.summary()
        assert any(c.name == "blocks/reason-read-amp"
                   for c in plan.audit_report.checks)
        after = plan_cache_stats()
        assert after["audits_run"] == before["audits_run"] + 1
        assert after["audit_violations"] == before["audit_violations"]

    def test_default_is_off_and_env_flag_turns_on(self, monkeypatch):
        w = make_weights(StencilSpec("box", 2, 1), seed=0)
        plan = stencil_plan(w, (128, 256), np.float32, 1, interpret=True,
                            use_cache=False)
        assert plan.audit_report is None
        monkeypatch.setenv("REPRO_AUDIT", "1")
        plan = stencil_plan(w, (128, 256), np.float32, 1, interpret=True,
                            use_cache=False)
        assert plan.audit_report is not None

    def test_batched_plan_is_exempt_not_violating(self):
        plan = stencil_plan(make_weights(StencilSpec("box", 2, 1), seed=0),
                            (128, 256), np.float32, 1, interpret=True,
                            audit=True, batch=2, use_cache=False)
        assert plan.audit_report.exempt is not None
        assert plan.audit_report.ok

    def test_violations_count_but_never_fail_the_build(self):
        before = plan_cache_stats()["audit_violations"]
        with faults.inject("geometry", times=math.inf):
            plan = stencil_plan(
                make_weights(StencilSpec("box", 2, 1), seed=0),
                (256, 512), np.float32, 2, backend="fused_direct",
                interpret=True, audit=True, use_cache=False)
        assert plan.audit_report is not None
        assert not plan.audit_report.ok
        assert plan_cache_stats()["audit_violations"] > before


# ---------------------------------------------------------------------------
# Negative tests: every violation class is caught
# ---------------------------------------------------------------------------
class TestViolationClassesCaught:
    def test_geometry_fault_breaks_read_model(self):
        """Class 1 (read-model mismatch): the PR-6 'geometry' fault warps
        the block walk; the auditor must flag bytes AND coverage."""
        with faults.inject("geometry", times=math.inf):
            rep = audit.audit_context(_ctx((256, 512), t=2), "fused_direct",
                                      flops=False)
        names = {c.name for c in rep.violations}
        assert "blocks/grid-bytes-model" in names
        assert "scratch/coverage-global" in names

    def test_corrupted_index_map_via_monkeypatch(self):
        """Same class, without the fault harness: a hand-warped index map
        (the kind of off-by-one PR 5 fixed) is caught."""
        launch = registry.get_backend("fused_direct").audit(
            _ctx((256, 512), t=2)).launches[0]
        lg = launch.launch_geometry()
        orig = lg.in_index_maps[0]
        warped = lambda *ix: tuple(b + (1 if k == 0 else 0)
                                   for k, b in enumerate(orig(*ix)))
        bad = dataclasses.replace(lg, in_index_maps=(warped,))
        checks = audit.audit_blocks(bad, launch, 4) \
            + audit.audit_scratch(bad, launch)
        assert any(not c.passed and not c.skipped for c in checks)

    def test_shrunken_read_window_is_a_coverage_hole(self):
        """Class 2 (scratch coverage hole): a read window short of the
        halo -- the silent-wrong-answer class -- is caught."""
        launch = registry.get_backend("fused_direct").audit(
            _ctx((256, 512), t=2)).launches[0]
        lg = launch.launch_geometry()
        (lo, hi), rest = lg.read_bounds[0], lg.read_bounds[1:]
        bad = dataclasses.replace(lg, read_bounds=((lo + 1, hi - 1),) + rest)
        checks = audit.audit_scratch(bad, launch)
        viol = [c for c in checks if not c.passed and not c.skipped]
        assert any(c.name == "scratch/read-window" for c in viol)

    def test_overlapping_slots_are_conflicting_writes(self):
        launch = registry.get_backend("fused_direct").audit(
            _ctx((256, 512), t=2)).launches[0]
        lg = launch.launch_geometry()
        bad = dataclasses.replace(
            lg, scratch_shape=(lg.scratch_shape[0] - lg.block_dims[0],)
            + lg.scratch_shape[1:])
        checks = audit.audit_scratch(bad, launch)
        viol = {c.name for c in checks if not c.passed and not c.skipped}
        assert "scratch/slots-partition" in viol

    def test_monkeypatched_reuse_beta_breaks_flop_model(self, monkeypatch):
        """Class 3 (FLOP/redundancy mismatch): a wrong beta in the model
        is caught by the jaxpr-counted ground truth."""
        orig = pm.reuse_beta
        monkeypatch.setattr(pm, "reuse_beta",
                            lambda *a, **k: orig(*a, **k) * 1.5)
        rep = audit.audit_context(_ctx((256, 512), t=2),
                                  "fused_matmul_reuse")
        names = {c.name for c in rep.violations}
        assert "flops/beta" in names

    def test_clean_run_has_zero_violations_and_exact_flops(self):
        """Control for the negatives: the same audits pass clean, with the
        structural FLOP check integer-exact."""
        for backend in ("fused_direct", "fused_matmul_reuse"):
            rep = audit.audit_context(_ctx((256, 512), t=2), backend)
            assert rep.ok, rep.summary()
            (c,) = [c for c in rep.checks if c.name == "flops/structural"]
            assert c.expected == c.actual
