"""Coalescer properties (DESIGN.md §12) as DETERMINISTIC sweeps: the
bucketing policy is a pure function of (requests, knobs), so every
property is checked over seeded pseudo-random request sequences instead
of hypothesis strategies -- same coverage intent, reproducible by
construction, and no dependency on an optional package.
"""
import numpy as np
import pytest

from repro.core.envutil import env_int_list
from repro.serve import (Batch, ServeRequest, choose_bucket, coalesce,
                         serve_buckets, serve_max_batch,
                         serve_queue_timeout_ms, stack_batch)
from repro.serve.coalesce import (DEFAULT_BUCKETS, DEFAULT_MAX_BATCH,
                                  DEFAULT_QUEUE_TIMEOUT_MS)


def _req(sig, seq, grid=(4, 4), dtype=np.float32, fill=None):
    """A minimal ServeRequest: the coalescer only reads .signature (and
    stack_batch only .x), so everything else can be inert."""
    x = np.full(grid, seq if fill is None else fill, dtype=dtype)
    return ServeRequest(x=x, weights=None, grid_shape=grid, dtype=dtype,
                        t=1, plan_kwargs={}, signature=sig, future=None,
                        submit_s=0.0, seq=seq)


def _stream(rng, n, n_sigs):
    """A seeded interleaved request stream over n_sigs signatures."""
    return [_req(("sig", int(k)), i)
            for i, k in enumerate(rng.integers(0, n_sigs, size=n))]


class TestChooseBucket:
    def test_pads_to_next_allowed(self):
        for n, want in [(1, 1), (2, 2), (3, 4), (5, 8), (8, 8), (9, 16),
                        (17, 32), (33, 32)]:   # 33 > ladder: largest allowed
            assert choose_bucket(n, DEFAULT_BUCKETS, 32) == want

    def test_max_batch_filters_ladder(self):
        assert choose_bucket(7, DEFAULT_BUCKETS, 4) == 4
        assert choose_bucket(3, (1, 2, 4, 8), 8) == 4

    def test_ladder_entirely_above_cap(self):
        # no allowed bucket at all: batches are exactly the cap
        assert choose_bucket(3, (64, 128), 16) == 16

    def test_unsorted_duplicate_ladder(self):
        assert choose_bucket(3, (8, 2, 8, 1, 4), 32) == 4

    def test_n_below_one_raises(self):
        with pytest.raises(ValueError, match=">= 1"):
            choose_bucket(0, DEFAULT_BUCKETS, 32)


class TestCoalesceProperties:
    """Each property swept over 20 seeded streams of varying shape."""

    def _sweep(self):
        for seed in range(20):
            rng = np.random.default_rng(seed)
            n = int(rng.integers(1, 120))
            n_sigs = int(rng.integers(1, 6))
            yield seed, _stream(rng, n, n_sigs)

    def test_batches_never_mix_signatures(self):
        for seed, reqs in self._sweep():
            for b in coalesce(reqs, buckets=(1, 2, 4, 8), max_batch=8):
                sigs = {r.signature for r in b.requests}
                assert len(sigs) == 1 and sigs == {b.signature}, seed

    def test_every_request_lands_exactly_once(self):
        for seed, reqs in self._sweep():
            out = coalesce(reqs, buckets=(1, 2, 4, 8), max_batch=8)
            seen = sorted(r.seq for b in out for r in b.requests)
            assert seen == sorted(r.seq for r in reqs), seed

    def test_arrival_order_preserved_within_signature(self):
        for seed, reqs in self._sweep():
            out = coalesce(reqs, buckets=(1, 2, 4, 8), max_batch=8)
            by_sig = {}
            for b in out:
                by_sig.setdefault(b.signature, []).extend(
                    r.seq for r in b.requests)
            for sig, seqs in by_sig.items():
                assert seqs == sorted(seqs), (seed, sig)

    def test_bucket_bounds_and_pad_accounting(self):
        for seed, reqs in self._sweep():
            for b in coalesce(reqs, buckets=(1, 2, 4, 8), max_batch=8):
                assert 1 <= len(b.requests) <= b.bucket <= 8, seed
                assert b.pad == b.bucket - len(b.requests)
                assert 0.0 < b.occupancy <= 1.0
                # padding never exceeds what the next-smaller bucket
                # would have held -- otherwise the bucket choice is wrong
                if b.bucket > 1:
                    assert len(b.requests) > b.bucket // 2 \
                        or b.bucket == 1, seed

    def test_replay_determinism(self):
        for seed, reqs in self._sweep():
            a = coalesce(reqs, buckets=(1, 2, 4, 8), max_batch=8)
            b = coalesce(list(reqs), buckets=(1, 2, 4, 8), max_batch=8)
            assert [(x.signature, x.bucket,
                     [r.seq for r in x.requests]) for x in a] \
                == [(x.signature, x.bucket,
                     [r.seq for r in x.requests]) for x in b], seed

    def test_cap_chunks_large_groups(self):
        reqs = [_req("s", i) for i in range(10)]
        out = coalesce(reqs, buckets=(1, 2, 4), max_batch=4)
        assert [len(b.requests) for b in out] == [4, 4, 2]
        assert [b.bucket for b in out] == [4, 4, 2]


class TestStackBatch:
    def test_slices_bitwise_and_padding_zero(self):
        reqs = [_req("s", i, fill=float(i + 1)) for i in range(3)]
        b = Batch(signature="s", requests=reqs, bucket=4)
        xb = stack_batch(b)
        assert xb.shape == (4, 4, 4) and xb.dtype == np.float32
        for i, r in enumerate(reqs):
            np.testing.assert_array_equal(xb[i], r.x)
        # the padded slot is zero grids, never garbage
        np.testing.assert_array_equal(xb[3], np.zeros((4, 4), np.float32))

    def test_dtype_follows_requests(self):
        try:
            import jax.numpy as jnp
            dt = jnp.bfloat16
        except ImportError:                    # pragma: no cover
            pytest.skip("jax required")
        reqs = [_req("s", 0, dtype=np.dtype(dt))]
        xb = stack_batch(Batch(signature="s", requests=reqs, bucket=2))
        assert xb.dtype == np.dtype(dt)


class TestServeEnvKnobs:
    """REPRO_SERVE_* knobs parse through envutil: defaults, overrides,
    and actionable errors on garbage."""

    def test_defaults(self, monkeypatch):
        for var in ("REPRO_SERVE_BUCKETS", "REPRO_SERVE_MAX_BATCH",
                    "REPRO_SERVE_QUEUE_TIMEOUT_MS"):
            monkeypatch.delenv(var, raising=False)
        assert serve_buckets() == DEFAULT_BUCKETS
        assert serve_max_batch() == DEFAULT_MAX_BATCH
        assert serve_queue_timeout_ms() == DEFAULT_QUEUE_TIMEOUT_MS

    def test_overrides(self, monkeypatch):
        monkeypatch.setenv("REPRO_SERVE_BUCKETS", "8, 2,2,16")
        monkeypatch.setenv("REPRO_SERVE_MAX_BATCH", "16")
        monkeypatch.setenv("REPRO_SERVE_QUEUE_TIMEOUT_MS", "0")
        assert serve_buckets() == (2, 8, 16)   # sorted, deduped
        assert serve_max_batch() == 16
        assert serve_queue_timeout_ms() == 0   # 0 is legal: no linger

    @pytest.mark.parametrize("var,raw,match", [
        ("REPRO_SERVE_BUCKETS", "1,two,4", "REPRO_SERVE_BUCKETS"),
        ("REPRO_SERVE_BUCKETS", "0,2", ">= 1"),
        ("REPRO_SERVE_MAX_BATCH", "none", "REPRO_SERVE_MAX_BATCH"),
        ("REPRO_SERVE_MAX_BATCH", "0", ">= 1"),
        ("REPRO_SERVE_QUEUE_TIMEOUT_MS", "-5", ">= 0"),
        ("REPRO_SERVE_QUEUE_TIMEOUT_MS", "fast", "integer"),
    ])
    def test_garbage_raises_naming_the_knob(self, monkeypatch, var, raw,
                                            match):
        monkeypatch.setenv(var, raw)
        fn = {"REPRO_SERVE_BUCKETS": serve_buckets,
              "REPRO_SERVE_MAX_BATCH": serve_max_batch,
              "REPRO_SERVE_QUEUE_TIMEOUT_MS": serve_queue_timeout_ms}[var]
        with pytest.raises(ValueError, match=match):
            fn()


class TestEnvIntList:
    def test_unset_and_blank_use_default(self, monkeypatch):
        monkeypatch.delenv("REPRO_TEST_LIST", raising=False)
        assert env_int_list("REPRO_TEST_LIST", (1, 2)) == (1, 2)
        monkeypatch.setenv("REPRO_TEST_LIST", "   ")
        assert env_int_list("REPRO_TEST_LIST", (1, 2)) == (1, 2)

    def test_blank_items_skipped(self, monkeypatch):
        monkeypatch.setenv("REPRO_TEST_LIST", "1,,4, ,8,")
        assert env_int_list("REPRO_TEST_LIST", ()) == (1, 4, 8)

    def test_all_blank_items_fall_back_to_default(self, monkeypatch):
        monkeypatch.setenv("REPRO_TEST_LIST", ",, ,")
        assert env_int_list("REPRO_TEST_LIST", (3,)) == (3,)

    def test_garbage_item_named_in_error(self, monkeypatch):
        monkeypatch.setenv("REPRO_TEST_LIST", "1,x7,4")
        with pytest.raises(ValueError, match=r"'x7'"):
            env_int_list("REPRO_TEST_LIST", ())

    def test_below_minimum_rejected(self, monkeypatch):
        monkeypatch.setenv("REPRO_TEST_LIST", "4,-1")
        with pytest.raises(ValueError, match=">= 1"):
            env_int_list("REPRO_TEST_LIST", ())
        monkeypatch.setenv("REPRO_TEST_LIST", "0")
        with pytest.raises(ValueError, match=">= 2"):
            env_int_list("REPRO_TEST_LIST", (), minimum=2)
