"""Integration: the Pallas kernels run as the distributed stepper's local
update (the full production path: halo exchange -> VPU/MXU kernel)."""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(n, code):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=560)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


class TestKernelAsLocalApply:
    def test_fused_direct_kernel_inside_shard_map(self):
        out = run_with_devices(4, """
            import jax, numpy as np, jax.numpy as jnp
            from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
            from repro.stencil import StencilSpec, make_weights, fuse_weights
            from repro.stencil.reference import apply_stencil_steps
            from repro.stencil.distributed import make_distributed_stepper
            from repro.kernels.stencil_direct import stencil_direct
            from repro.kernels.stencil_matmul import stencil_matmul

            mesh = Mesh(np.array(jax.devices()).reshape(2,2), ("x","y"))
            spec = StencilSpec("box", 2, 1)
            w = make_weights(spec, seed=3)
            t = 2
            n = 64
            x = np.random.default_rng(0).normal(size=(n,n)).astype(np.float32)
            xs = jax.device_put(x, NamedSharding(mesh, P("x","y")))
            ref = apply_stencil_steps(jnp.asarray(x), jnp.asarray(w), t)

            # VPU kernel path: fused t steps in one kernel on the extended
            # block; kernel's modulo-wrap periodicity is harmless on the
            # interior because the stepper discards the halo ring.
            def local_vpu(xe, w_, steps):
                r = (np.asarray(w_).shape[0]-1)//2 if hasattr(w_,'shape') else 1
                h = steps * 1
                full = stencil_direct(xe, w, t=steps, tile_m=xe.shape[0],
                                      tile_n=xe.shape[1], interpret=True)
                return full[h:-h, h:-h]

            step = make_distributed_stepper(mesh, ("x","y"), w, t=t,
                                            mode="fused", local_apply=local_vpu)
            with mesh:
                y = step(xs)
            err = float(jnp.abs(y - ref).max())
            assert err < 1e-4, err

            # MXU kernel path: composed weights, one banded contraction
            wf = fuse_weights(w, t)
            def local_mxu(xe, w_, steps):
                h = t * 1
                full = stencil_matmul(xe, wf, tile_m=xe.shape[0],
                                      tile_n=xe.shape[1], interpret=True)
                return full[h:-h, h:-h]

            step2 = make_distributed_stepper(mesh, ("x","y"), w, t=t,
                                             mode="fused", local_apply=local_mxu)
            with mesh:
                y2 = step2(xs)
            err2 = float(jnp.abs(y2 - ref).max())
            assert err2 < 1e-4, err2
            print("OK", err, err2)
        """)
        assert "OK" in out

    def test_pallas_local_apply_column_tiled(self):
        """A W-sharded mesh whose local update runs the COLUMN-TILED
        substrate (DESIGN.md §10): the column walk's wrap only pollutes
        the discarded halo ring, exactly like the row wrap, so the
        stepper still reproduces the global oracle."""
        out = run_with_devices(2, """
            import jax, numpy as np, jax.numpy as jnp
            from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
            from repro.stencil import StencilSpec, make_weights
            from repro.stencil.reference import apply_stencil_steps
            from repro.stencil.distributed import (make_distributed_stepper,
                                                   pallas_local_apply)

            mesh = Mesh(np.array(jax.devices()), ("w",))
            w = make_weights(StencilSpec("box", 2, 1), seed=5)
            t = 2
            x = np.random.default_rng(1).normal(size=(32, 128)) \\
                  .astype(np.float32)
            xs = jax.device_put(x, NamedSharding(mesh, P(None, "w")))
            ref = apply_stencil_steps(jnp.asarray(x), jnp.asarray(w), t)

            # the halo-extended local block is (32+2t*r, 64+2t*r) = (36, 68):
            # tile_m divides the extended rows; 68 is not a multiple of
            # w_tile=32, so this also exercises the remainder path
            for backend in ("fused_direct", "fused_matmul_reuse"):
                la = pallas_local_apply(backend, interpret=True,
                                        tile_m=18, h_block=9,
                                        w_tile=32, w_block=4)
                step = make_distributed_stepper(mesh, (None, "w"), w, t=t,
                                                mode="fused",
                                                local_apply=la)
                with mesh:
                    y = step(xs)
                err = float(jnp.abs(y - ref).max())
                assert err < 1e-4, (backend, err)
            print("OK")
        """)
        assert "OK" in out

    def test_pallas_local_apply_plugin(self):
        """The packaged plug-in (stencil.distributed.pallas_local_apply)
        drives every fused kernel regime -- including the new
        intermediate-reuse MXU path -- inside shard_map."""
        out = run_with_devices(4, """
            import jax, numpy as np, jax.numpy as jnp
            from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
            from repro.stencil import StencilSpec, make_weights
            from repro.stencil.reference import apply_stencil_steps
            from repro.stencil.distributed import (make_distributed_stepper,
                                                   pallas_local_apply)

            mesh = Mesh(np.array(jax.devices()).reshape(2,2), ("x","y"))
            w = make_weights(StencilSpec("box", 2, 1), seed=3)
            t, n = 2, 64
            x = np.random.default_rng(0).normal(size=(n,n)).astype(np.float32)
            xs = jax.device_put(x, NamedSharding(mesh, P("x","y")))
            ref = apply_stencil_steps(jnp.asarray(x), jnp.asarray(w), t)

            for backend in ("fused_direct", "fused_matmul",
                            "fused_matmul_reuse"):
                la = pallas_local_apply(backend, interpret=True)
                step = make_distributed_stepper(mesh, ("x","y"), w, t=t,
                                                mode="fused", local_apply=la)
                with mesh:
                    y = step(xs)
                err = float(jnp.abs(y - ref).max())
                assert err < 1e-4, (backend, err)
            print("OK")
        """)
        assert "OK" in out


class TestKernel3DLocalApply:
    def test_pallas_local_apply_on_3d_sharded_mesh(self):
        """The halo-plane substrate runs as the local update of a
        3D-sharded mesh: z and y sharded across the ring, x local, for
        both the VPU and the intermediate-reuse MXU regimes -- and the
        mesh-parameterized 3D plan drives the same stepper with a halo
        plan matching the analytic traffic model."""
        out = run_with_devices(4, """
            import jax, numpy as np, jax.numpy as jnp
            from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
            from repro.stencil import StencilSpec, make_weights
            from repro.stencil.distributed import (halo_bytes_per_step,
                                                   make_distributed_stepper,
                                                   pallas_local_apply)
            from repro.stencil.reference import apply_stencil_steps
            from repro.kernels import stencil_plan

            mesh = Mesh(np.array(jax.devices()).reshape(2,2), ("x","y"))
            w = make_weights(StencilSpec("box", 3, 1), seed=3)
            t, shape = 2, (16, 32, 32)
            x = np.random.default_rng(0).normal(size=shape).astype(np.float32)
            xs = jax.device_put(x, NamedSharding(mesh, P("x","y",None)))
            ref = apply_stencil_steps(jnp.asarray(x), jnp.asarray(w), t)

            for backend in ("fused_direct", "fused_matmul_reuse"):
                la = pallas_local_apply(backend, interpret=True)
                step = make_distributed_stepper(mesh, ("x","y",None), w, t=t,
                                                mode="fused", local_apply=la)
                with mesh:
                    y = step(xs)
                err = float(jnp.abs(y - ref).max())
                assert err < 1e-4, (backend, err)

            for mode in ("stepwise", "fused"):
                plan = stencil_plan(w, shape, np.float32, t, mesh=mesh,
                                    shard_spec=("x","y",None), dist_mode=mode)
                err = float(jnp.abs(plan(xs) - ref).max())
                assert err < 1e-4, (mode, err)
                hp = plan.halo_plan
                assert hp["local_shape"] == (8, 16, 32)
                assert hp["halo_bytes_per_call"] == halo_bytes_per_step(
                    (8, 16, 32), ("x","y",None), 1, t, mode, 4)
            print("OK")
        """)
        assert "OK" in out


class TestDistributedPlan:
    def test_mesh_parameterized_plan(self):
        """A mesh-parameterized StencilPlan drives the halo-exchange stepper
        through the same object as local plans: plan(x) on the sharded grid,
        plan.halo_plan matching the analytic traffic model, and a cache key
        that separates sharded from local signatures."""
        out = run_with_devices(4, """
            import jax, numpy as np, jax.numpy as jnp
            from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
            from repro.stencil import StencilSpec, make_weights
            from repro.stencil.distributed import halo_bytes_per_step
            from repro.stencil.reference import apply_stencil_steps
            from repro.kernels import stencil_plan, plan_cache_stats

            mesh = Mesh(np.array(jax.devices()).reshape(2,2), ("x","y"))
            w = make_weights(StencilSpec("box", 2, 1), seed=3)
            t, n = 2, 64
            x = np.random.default_rng(0).normal(size=(n,n)).astype(np.float32)
            xs = jax.device_put(x, NamedSharding(mesh, P("x","y")))
            ref = apply_stencil_steps(jnp.asarray(x), jnp.asarray(w), t)

            for mode in ("stepwise", "fused"):
                plan = stencil_plan(w, (n, n), np.float32, t, mesh=mesh,
                                    shard_spec=("x", "y"), dist_mode=mode)
                err = float(jnp.abs(plan(xs) - ref).max())
                assert err < 1e-4, (mode, err)
                hp = plan.halo_plan
                assert hp["local_shape"] == (n//2, n//2)
                assert hp["exchanges_per_call"] == (t if mode == "stepwise"
                                                    else 1)
                assert hp["halo_bytes_per_call"] == halo_bytes_per_step(
                    (n//2, n//2), ("x","y"), 1, t, mode, 4)
                assert "halo plan" in plan.explain()

            # same signature => cached; local signature => distinct plan
            before = plan_cache_stats()
            again = stencil_plan(w, (n, n), np.float32, t, mesh=mesh,
                                 shard_spec=("x", "y"), dist_mode="fused")
            assert plan_cache_stats()["hits"] == before["hits"] + 1
            local = stencil_plan(w, (n, n), np.float32, t)
            assert local is not again
            err = float(jnp.abs(again.run(xs, 2)
                                - apply_stencil_steps(jnp.asarray(x),
                                                      jnp.asarray(w),
                                                      2*t)).max())
            assert err < 1e-4, err
            print("OK")
        """)
        assert "OK" in out
