"""GPipe pipeline over a mesh axis: output == sequential layer stack."""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(n, code):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=560)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


class TestPipeline:
    def test_matches_sequential(self):
        out = run_with_devices(2, """
            import jax, numpy as np, jax.numpy as jnp
            from repro.parallel.pipeline import make_pipelined_step, bubble_fraction
            mesh = jax.make_mesh((2,), ("pod",))
            L, D, B = 4, 16, 8
            rng = np.random.default_rng(0)
            ws = jnp.asarray(rng.normal(size=(L, D, D)).astype(np.float32) / np.sqrt(D))
            x = jnp.asarray(rng.normal(size=(B, D)).astype(np.float32))
            def layer(w, x):
                return jnp.tanh(x @ w)
            step = make_pipelined_step(layer, L, mesh, microbatches=4)
            with mesh:
                y = jax.jit(step)(ws, x)
            ref = x
            for i in range(L):
                ref = layer(ws[i], ref)
            err = float(jnp.abs(y - ref).max())
            assert err < 1e-5, err
            assert abs(bubble_fraction(2, 4) - 1/5) < 1e-9
            print("OK", err)
        """)
        assert "OK" in out

    def test_collectives_scale_with_ticks(self):
        out = run_with_devices(2, """
            import jax, numpy as np, jax.numpy as jnp
            from repro.parallel.pipeline import make_pipelined_step
            from repro.core.hlo_cost import analyze_hlo
            mesh = jax.make_mesh((2,), ("pod",))
            L, D, B = 4, 16, 8
            ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
            x = jax.ShapeDtypeStruct((B, D), jnp.float32)
            def layer(w, x):
                return jnp.tanh(x @ w)
            step = make_pipelined_step(layer, L, mesh, microbatches=4)
            with mesh:
                c = jax.jit(step).lower(ws, x).compile()
            pc = analyze_hlo(c.as_text())
            n_perm = pc.coll_counts.get("collective-permute", 0)
            # (M + S - 1) = 5 ticks, 1 boundary permute per tick (+1 final bcast)
            assert 5 <= n_perm <= 8, n_perm
            print("OK", n_perm)
        """)
        assert "OK" in out
