"""Strip-mined halo substrate: equivalence sweeps vs the jnp oracle, the
intermediate-reuse MXU regime's exactness guarantee, tiling validation
error paths, and the substrate's traffic accounting (3 loads vs the seed
scheme's 9)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import common, legacy
from repro.kernels.common import choose_strip, validate_tiling
from repro.kernels.ref import stencil_direct_ref
from repro.kernels.stencil_direct import stencil_direct
from repro.kernels.stencil_matmul import stencil_matmul
from repro.stencil import StencilSpec, make_weights

RNG = np.random.default_rng(0)


def _x(h, w, dtype="float32"):
    x = jnp.asarray(RNG.normal(size=(h, w)).astype(np.float32))
    return x.astype(dtype)


TOL = {"float32": 2e-4, "bfloat16": 6e-2}


class TestStripEquivalence:
    """New strip kernels vs ref.stencil_direct_ref across the ISSUE sweep:
    shape x r in {1,2,3} x t in {1..4} x dtype in {f32, bf16}."""

    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    @pytest.mark.parametrize("t", [1, 2, 3, 4])
    @pytest.mark.parametrize("r", [1, 2, 3])
    @pytest.mark.parametrize("shape", ["box", "star"])
    def test_fused_direct_matches_oracle(self, shape, r, t, dtype):
        spec = StencilSpec(shape, 2, r)
        w = make_weights(spec, seed=r)
        x = _x(48, 96, dtype)
        y = stencil_direct(x, w, t=t, tile_m=24, interpret=True)
        ref = stencil_direct_ref(x.astype(jnp.float32), w, t)
        np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(ref),
                                   atol=TOL[dtype])

    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    @pytest.mark.parametrize("t", [1, 2, 3, 4])
    @pytest.mark.parametrize("r", [1, 2, 3])
    @pytest.mark.parametrize("shape", ["box", "star"])
    def test_matmul_reuse_matches_oracle(self, shape, r, t, dtype):
        spec = StencilSpec(shape, 2, r)
        w = make_weights(spec, seed=r)
        x = _x(48, 96, dtype)
        y = stencil_matmul(x, w, t=t, tile_m=24, tile_n=32, interpret=True)
        ref = stencil_direct_ref(x.astype(jnp.float32), w, t)
        np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(ref),
                                   atol=TOL[dtype])

    def test_multi_strip_equals_single_strip(self):
        """Strip decomposition is invisible: gm=1 vs gm=4 bitwise equal."""
        w = make_weights(StencilSpec("box", 2, 1), seed=0)
        x = _x(64, 64)
        a = stencil_direct(x, w, t=2, tile_m=64, interpret=True)
        b = stencil_direct(x, w, t=2, tile_m=16, interpret=True)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestReuseRegimeExactness:
    """The intermediate-reuse kernel executes the SAME per-point banded dot
    products as t sequential MXU steps, so in f32 it is bit-for-bit equal
    to the sequential-matmul execution (no alpha redundancy to perturb
    rounding) -- the strongest equivalence the regime admits."""

    @pytest.mark.parametrize("r,t", [(1, 2), (1, 4), (2, 3), (3, 2)])
    @pytest.mark.parametrize("shape", ["box", "star"])
    def test_bitwise_vs_sequential_matmul(self, shape, r, t):
        w = make_weights(StencilSpec(shape, 2, r), seed=r)
        x = _x(64, 64)
        fused = stencil_matmul(x, w, t=t, tile_m=32, tile_n=32, interpret=True)
        seq = x
        for _ in range(t):
            seq = stencil_matmul(seq, w, t=1, tile_m=32, tile_n=32,
                                 interpret=True)
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(seq))


class TestValidateTiling:
    def test_rows_not_divisible(self):
        w = make_weights(StencilSpec("box", 2, 1), seed=0)
        with pytest.raises(ValueError, match="divisible"):
            stencil_direct(_x(60, 64), w, tile_m=32, interpret=True)

    def test_cols_not_divisible_matmul(self):
        w = make_weights(StencilSpec("box", 2, 1), seed=0)
        with pytest.raises(ValueError, match="divisible"):
            stencil_matmul(_x(64, 60), w, tile_m=32, tile_n=32, interpret=True)

    def test_halo_exceeds_strip(self):
        w = make_weights(StencilSpec("box", 2, 3), seed=0)
        with pytest.raises(ValueError, match="halo"):
            stencil_direct(_x(64, 64), w, t=6, tile_m=16, interpret=True)

    def test_halo_exceeds_width(self):
        with pytest.raises(ValueError, match="width"):
            validate_tiling((32, 8), 16, 8, 9)

    def test_valid_passes(self):
        validate_tiling((64, 128), 32, 32, 4)


class TestChooseStrip:
    def test_divides_and_covers_halo(self):
        for h, halo in [(256, 3), (96, 8), (128, 24)]:
            s = choose_strip(h, 512, halo)
            assert h % s == 0 and s >= halo

    def test_prefers_mxu_height(self):
        assert choose_strip(1024, 512, 2) == 128

    def test_vmem_pressure_shrinks_strip(self):
        big = choose_strip(4096, 4096, 1, vmem_budget=2**40)
        small = choose_strip(4096, 4096, 1, vmem_budget=2**20)
        assert small < big

    def test_small_grid_single_strip(self):
        assert choose_strip(32, 32, 4) == 32

    def test_auto_tiles_in_dispatch(self):
        """tile_m=None routes through choose_strip/choose_tile: grids not
        divisible by 128 work out of the box."""
        w = make_weights(StencilSpec("box", 2, 1), seed=0)
        x = _x(192, 160)                     # 192 % 128 != 0, 160 % 128 != 0
        ref = stencil_direct_ref(x, w, 2)
        yd = stencil_direct(x, w, t=2, interpret=True)
        ym = stencil_matmul(x, w, t=2, interpret=True)
        np.testing.assert_allclose(np.asarray(yd), np.asarray(ref), atol=1e-4)
        np.testing.assert_allclose(np.asarray(ym), np.asarray(ref), atol=1e-4)

    def test_narrow_grid_deep_fusion(self):
        """Width only constrains the per-step wrap radius r, not t*r: a
        16-wide grid takes t=8 fused steps of an r=3 stencil."""
        w = make_weights(StencilSpec("box", 2, 3), seed=0)
        x = _x(64, 16)
        ref = stencil_direct_ref(x, w, 8)
        y = stencil_direct(x, w, t=8, tile_m=32, interpret=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-3)


class TestTrafficAccounting:
    """The acceptance criterion: <= 4 neighbor-block loads per output tile
    on the strip substrate, vs 9 in the seed scheme."""

    def test_loads_per_output_tile(self):
        assert len(common.strip_in_specs(32, 128, 4)) == 3 <= 4
        assert len(legacy.neighbor_in_specs(32, 32, 4, 4)) == 9

    def test_read_amplification_3x_vs_9x(self):
        shape = (256, 256)
        new = common.hbm_read_bytes_per_step(shape, 32, 4)
        old = legacy.hbm_read_bytes_per_step(shape, 32, 32, 4)
        grid_bytes = 256 * 256 * 4
        assert new == 3 * grid_bytes
        assert old == 9 * grid_bytes

    def test_legacy_kernels_still_correct(self):
        """legacy.py backs the old-vs-new benchmark; keep it honest."""
        w = make_weights(StencilSpec("box", 2, 1), seed=0)
        x = _x(64, 64)
        ref = stencil_direct_ref(x, w, 2)
        yd = legacy.stencil_direct_9pt(x, w, t=2, tile_m=32, tile_n=32,
                                       interpret=True)
        np.testing.assert_allclose(np.asarray(yd), np.asarray(ref), atol=1e-4)
