"""Strip-mined halo substrate: equivalence sweeps vs the jnp oracle, the
halo-row sub-blocked substrate's bit-for-bit equality with the whole-strip
kernels, the intermediate-reuse MXU regime's exactness guarantee, tiling
validation error paths, and the substrate's traffic accounting
(1 + 2h/strip_m vs 3 vs the seed scheme's 9)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import common, legacy
from repro.kernels.common import (choose_hblock, choose_strip,
                                  choose_strip_blocks, substrate_read_amp,
                                  validate_tiling)
from repro.kernels.ref import stencil_direct_ref
from repro.kernels.stencil_direct import stencil_direct
from repro.kernels.stencil_matmul import stencil_matmul
from repro.stencil import StencilSpec, make_weights

RNG = np.random.default_rng(0)


def _x(h, w, dtype="float32"):
    x = jnp.asarray(RNG.normal(size=(h, w)).astype(np.float32))
    return x.astype(dtype)


TOL = {"float32": 2e-4, "bfloat16": 6e-2}


class TestStripEquivalence:
    """New strip kernels vs ref.stencil_direct_ref across the ISSUE sweep:
    shape x r in {1,2,3} x t in {1..4} x dtype in {f32, bf16}."""

    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    @pytest.mark.parametrize("t", [1, 2, 3, 4])
    @pytest.mark.parametrize("r", [1, 2, 3])
    @pytest.mark.parametrize("shape", ["box", "star"])
    def test_fused_direct_matches_oracle(self, shape, r, t, dtype):
        spec = StencilSpec(shape, 2, r)
        w = make_weights(spec, seed=r)
        x = _x(48, 96, dtype)
        y = stencil_direct(x, w, t=t, tile_m=24, interpret=True)
        ref = stencil_direct_ref(x.astype(jnp.float32), w, t)
        np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(ref),
                                   atol=TOL[dtype])

    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    @pytest.mark.parametrize("t", [1, 2, 3, 4])
    @pytest.mark.parametrize("r", [1, 2, 3])
    @pytest.mark.parametrize("shape", ["box", "star"])
    def test_matmul_reuse_matches_oracle(self, shape, r, t, dtype):
        spec = StencilSpec(shape, 2, r)
        w = make_weights(spec, seed=r)
        x = _x(48, 96, dtype)
        y = stencil_matmul(x, w, t=t, tile_m=24, tile_n=32, interpret=True)
        ref = stencil_direct_ref(x.astype(jnp.float32), w, t)
        np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(ref),
                                   atol=TOL[dtype])

    def test_multi_strip_equals_single_strip(self):
        """Strip decomposition is invisible: gm=1 vs gm=4 bitwise equal."""
        w = make_weights(StencilSpec("box", 2, 1), seed=0)
        x = _x(64, 64)
        a = stencil_direct(x, w, t=2, tile_m=64, interpret=True)
        b = stencil_direct(x, w, t=2, tile_m=16, interpret=True)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestSubblockedEquivalence:
    """The halo-row sub-blocked substrate assembles byte-identical extended
    strips, so its outputs are BIT-FOR-BIT equal to the whole-strip kernels
    in f32 -- the ISSUE's acceptance sweep: box/star x r{1,2,3} x t{1,2,4}
    x h_block dividing strip_m."""

    STRIP_M = 24

    def _hblocks(self, r, t):
        halo = r * t
        return [d for d in (1, 2, 3, 4, 6, 8, 12, 24)
                if self.STRIP_M % d == 0 and d >= halo]

    @pytest.mark.parametrize("t", [1, 2, 4])
    @pytest.mark.parametrize("r", [1, 2, 3])
    @pytest.mark.parametrize("shape", ["box", "star"])
    def test_direct_bitwise_vs_wholestrip(self, shape, r, t):
        w = make_weights(StencilSpec(shape, 2, r), seed=r)
        x = _x(48, 64)
        whole = stencil_direct(x, w, t=t, tile_m=self.STRIP_M, h_block=0,
                               interpret=True)
        for hb in self._hblocks(r, t):
            sub = stencil_direct(x, w, t=t, tile_m=self.STRIP_M, h_block=hb,
                                 interpret=True)
            np.testing.assert_array_equal(np.asarray(sub), np.asarray(whole))

    @pytest.mark.parametrize("t", [1, 2, 4])
    @pytest.mark.parametrize("r", [1, 2, 3])
    @pytest.mark.parametrize("shape", ["box", "star"])
    def test_matmul_bitwise_vs_wholestrip(self, shape, r, t):
        w = make_weights(StencilSpec(shape, 2, r), seed=r)
        x = _x(48, 64)
        whole = stencil_matmul(x, w, t=t, tile_m=self.STRIP_M, tile_n=32,
                               h_block=0, interpret=True)
        for hb in self._hblocks(r, t):
            sub = stencil_matmul(x, w, t=t, tile_m=self.STRIP_M, tile_n=32,
                                 h_block=hb, interpret=True)
            np.testing.assert_array_equal(np.asarray(sub), np.asarray(whole))

    def test_single_strip_wraps_to_itself(self):
        """gm=1: both substrates take the periodic halo from the strip
        itself (modulo wrap), matching the oracle."""
        w = make_weights(StencilSpec("box", 2, 2), seed=0)
        x = _x(32, 32)
        ref = stencil_direct_ref(x, w, 2)
        y = stencil_direct(x, w, t=2, tile_m=32, h_block=8, interpret=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4)

    def test_auto_hblock_end_to_end(self):
        """h_block=None auto-sizes (tile_m given and not) and still matches
        the oracle on a grid not divisible by 128."""
        w = make_weights(StencilSpec("star", 2, 1), seed=1)
        x = _x(192, 160)
        ref = stencil_direct_ref(x, w, 2)
        np.testing.assert_allclose(
            np.asarray(stencil_direct(x, w, t=2, interpret=True)),
            np.asarray(ref), atol=1e-4)
        np.testing.assert_allclose(
            np.asarray(stencil_matmul(x, w, t=2, tile_m=48, interpret=True)),
            np.asarray(ref), atol=1e-4)


class TestChooseHBlock:
    def test_divides_and_covers_halo(self):
        for strip_m, halo in [(32, 1), (32, 4), (128, 8), (24, 12), (48, 5)]:
            hb = choose_hblock(strip_m, halo)
            assert strip_m % hb == 0 and hb >= halo

    def test_degenerates_to_whole_strip_at_full_halo(self):
        assert choose_hblock(32, 32) == 32
        assert substrate_read_amp(32, 32) == 3.0

    def test_amp_small_when_halo_allows(self):
        strip_m, hb = choose_strip_blocks(1024, 512, 2)
        assert substrate_read_amp(strip_m, hb) <= 1.25

    def test_joint_choice_consistent_with_choose_strip(self):
        for h, halo in [(256, 3), (96, 8), (128, 24)]:
            strip_m, hb = choose_strip_blocks(h, 512, halo)
            assert strip_m == choose_strip(h, 512, halo)
            assert strip_m % hb == 0 and hb >= halo


class TestReuseRegimeExactness:
    """The intermediate-reuse kernel executes the SAME per-point banded dot
    products as t sequential MXU steps, so in f32 it is bit-for-bit equal
    to the sequential-matmul execution (no alpha redundancy to perturb
    rounding) -- the strongest equivalence the regime admits."""

    @pytest.mark.parametrize("r,t", [(1, 2), (1, 4), (2, 3), (3, 2)])
    @pytest.mark.parametrize("shape", ["box", "star"])
    def test_bitwise_vs_sequential_matmul(self, shape, r, t):
        w = make_weights(StencilSpec(shape, 2, r), seed=r)
        x = _x(64, 64)
        fused = stencil_matmul(x, w, t=t, tile_m=32, tile_n=32, interpret=True)
        seq = x
        for _ in range(t):
            seq = stencil_matmul(seq, w, t=1, tile_m=32, tile_n=32,
                                 interpret=True)
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(seq))


class TestValidateTiling:
    def test_rows_not_divisible(self):
        w = make_weights(StencilSpec("box", 2, 1), seed=0)
        with pytest.raises(ValueError, match="divisible"):
            stencil_direct(_x(60, 64), w, tile_m=32, interpret=True)

    def test_cols_not_divisible_matmul(self):
        w = make_weights(StencilSpec("box", 2, 1), seed=0)
        with pytest.raises(ValueError, match="divisible"):
            stencil_matmul(_x(64, 60), w, tile_m=32, tile_n=32, interpret=True)

    def test_halo_exceeds_strip(self):
        w = make_weights(StencilSpec("box", 2, 3), seed=0)
        with pytest.raises(ValueError, match="halo"):
            stencil_direct(_x(64, 64), w, t=6, tile_m=16, interpret=True)

    def test_halo_exceeds_width(self):
        with pytest.raises(ValueError, match="width"):
            validate_tiling((32, 8), 16, 8, 9)

    def test_valid_passes(self):
        validate_tiling((64, 128), 32, 32, 4)
        validate_tiling((64, 128), 32, 32, 4, h_block=8)

    def test_hblock_not_dividing_strip(self):
        w = make_weights(StencilSpec("box", 2, 1), seed=0)
        with pytest.raises(ValueError, match="h_block"):
            stencil_direct(_x(64, 64), w, tile_m=32, h_block=5,
                           interpret=True)

    def test_hblock_smaller_than_halo(self):
        w = make_weights(StencilSpec("box", 2, 2), seed=0)
        with pytest.raises(ValueError, match="h_block"):
            stencil_matmul(_x(64, 64), w, t=2, tile_m=32, tile_n=32,
                           h_block=2, interpret=True)


class TestChooseStrip:
    def test_divides_and_covers_halo(self):
        for h, halo in [(256, 3), (96, 8), (128, 24)]:
            s = choose_strip(h, 512, halo)
            assert h % s == 0 and s >= halo

    def test_prefers_mxu_height(self):
        assert choose_strip(1024, 512, 2) == 128

    def test_vmem_pressure_shrinks_strip(self):
        big = choose_strip(4096, 4096, 1, vmem_budget=2**40)
        small = choose_strip(4096, 4096, 1, vmem_budget=2**20)
        assert small < big

    def test_small_grid_single_strip(self):
        assert choose_strip(32, 32, 4) == 32

    def test_auto_tiles_in_dispatch(self):
        """tile_m=None routes through choose_strip/choose_tile: grids not
        divisible by 128 work out of the box."""
        w = make_weights(StencilSpec("box", 2, 1), seed=0)
        x = _x(192, 160)                     # 192 % 128 != 0, 160 % 128 != 0
        ref = stencil_direct_ref(x, w, 2)
        yd = stencil_direct(x, w, t=2, interpret=True)
        ym = stencil_matmul(x, w, t=2, interpret=True)
        np.testing.assert_allclose(np.asarray(yd), np.asarray(ref), atol=1e-4)
        np.testing.assert_allclose(np.asarray(ym), np.asarray(ref), atol=1e-4)

    def test_narrow_grid_deep_fusion(self):
        """Width only constrains the per-step wrap radius r, not t*r: a
        16-wide grid takes t=8 fused steps of an r=3 stencil."""
        w = make_weights(StencilSpec("box", 2, 3), seed=0)
        x = _x(64, 16)
        ref = stencil_direct_ref(x, w, 8)
        y = stencil_direct(x, w, t=8, tile_m=32, interpret=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-3)


class TestTrafficAccounting:
    """The acceptance criteria: analytic reads fall 9x (seed) -> 3x
    (whole-strip) -> 1 + 2h/strip_m (sub-blocked)."""

    def test_loads_per_output_tile(self):
        assert len(common.strip_in_specs(32, 128, 4)) == 3 <= 4
        assert len(legacy.neighbor_in_specs(32, 32, 4, 4)) == 9

    def test_read_amplification_3x_vs_9x(self):
        shape = (256, 256)
        new = common.hbm_read_bytes_per_step(shape, 32, 4)
        old = legacy.hbm_read_bytes_per_step(shape, 32, 32, 4)
        grid_bytes = 256 * 256 * 4
        assert new == 3 * grid_bytes
        assert old == 9 * grid_bytes

    def test_subblocked_read_bytes_formula(self):
        """Analytic read_bytes == (1 + 2h/strip_m) * H*W*D exactly, for
        every h_block dividing the strip."""
        H, W, D = 256, 256, 4
        grid_bytes = H * W * D
        for strip_m in (32, 64, 128):
            for hb in (d for d in range(1, strip_m + 1) if strip_m % d == 0):
                got = common.hbm_read_bytes_per_step((H, W), strip_m, D,
                                                     h_block=hb)
                want = (1 + 2 * hb / strip_m) * grid_bytes
                assert got == want
                assert substrate_read_amp(strip_m, hb) == \
                    pytest.approx(got / grid_bytes)

    def test_subblocked_amp_at_default_strips(self):
        """At default joint sizing the amplification is <= 1.3x for shallow
        halos (the ISSUE's acceptance bound vs 3.0x whole-strip)."""
        for halo in (1, 2, 4):
            strip_m, hb = choose_strip_blocks(1024, 1024, halo)
            assert substrate_read_amp(strip_m, hb) <= 1.3
        assert substrate_read_amp(strip_m, 0) == 3.0      # whole-strip foil
        with pytest.raises(ValueError, match="auto"):
            substrate_read_amp(strip_m, None)             # None != whole-strip

    def test_bands_charged_identically(self):
        """The banded operand term is substrate-independent (one fetch per
        output strip)."""
        bands = (3, 40, 32)
        base = common.hbm_read_bytes_per_step((256, 256), 32, 4)
        with_b = common.hbm_read_bytes_per_step((256, 256), 32, 4,
                                                bands_shape=bands)
        sub = common.hbm_read_bytes_per_step((256, 256), 32, 4, h_block=8)
        sub_b = common.hbm_read_bytes_per_step((256, 256), 32, 4,
                                               bands_shape=bands, h_block=8)
        assert with_b - base == sub_b - sub == 8 * 3 * 40 * 32 * 4

    def test_legacy_kernels_still_correct(self):
        """legacy.py backs the old-vs-new benchmark; keep it honest."""
        w = make_weights(StencilSpec("box", 2, 1), seed=0)
        x = _x(64, 64)
        ref = stencil_direct_ref(x, w, 2)
        yd = legacy.stencil_direct_9pt(x, w, t=2, tile_m=32, tile_n=32,
                                       interpret=True)
        np.testing.assert_allclose(np.asarray(yd), np.asarray(ref), atol=1e-4)
