"""Strip-mined halo substrate: equivalence sweeps vs the jnp oracle, the
halo-row sub-blocked substrate's bit-for-bit equality with the whole-strip
kernels, the intermediate-reuse MXU regime's exactness guarantee, tiling
validation error paths, and the substrate's traffic accounting
(1 + 2h/strip_m vs 3 vs the seed scheme's 9) -- plus the N-D halo-plane
generalization (DESIGN.md §9): 3D slab-substrate equivalence
(sub-blocked vs whole-slab foil vs oracle), the 3D read-amplification
product formula, and the 1D lift through the 2D substrate."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import common, legacy
from repro.kernels.common import (SubstrateGeom, choose_col_blocks,
                                  choose_hblock, choose_slab_blocks,
                                  choose_strip, choose_strip_blocks,
                                  choose_tile, hbm_read_bytes_per_step_3d,
                                  resolve_substrate_geom,
                                  substrate_read_amp, validate_tiling,
                                  vmem_budget_bytes)
from repro.kernels.ref import stencil_direct_ref
from repro.kernels.stencil_direct import stencil_direct
from repro.kernels.stencil_matmul import stencil_matmul
from repro.stencil import StencilSpec, make_weights

RNG = np.random.default_rng(0)


def _x(h, w, dtype="float32"):
    x = jnp.asarray(RNG.normal(size=(h, w)).astype(np.float32))
    return x.astype(dtype)


def _x3(z, h, w, dtype="float32"):
    x = jnp.asarray(RNG.normal(size=(z, h, w)).astype(np.float32))
    return x.astype(dtype)


TOL = {"float32": 2e-4, "bfloat16": 6e-2}


class TestStripEquivalence:
    """New strip kernels vs ref.stencil_direct_ref across the ISSUE sweep:
    shape x r in {1,2,3} x t in {1..4} x dtype in {f32, bf16}."""

    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    @pytest.mark.parametrize("t", [1, 2, 3, 4])
    @pytest.mark.parametrize("r", [1, 2, 3])
    @pytest.mark.parametrize("shape", ["box", "star"])
    def test_fused_direct_matches_oracle(self, shape, r, t, dtype):
        spec = StencilSpec(shape, 2, r)
        w = make_weights(spec, seed=r)
        x = _x(48, 96, dtype)
        y = stencil_direct(x, w, t=t, tile_m=24, interpret=True)
        ref = stencil_direct_ref(x.astype(jnp.float32), w, t)
        np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(ref),
                                   atol=TOL[dtype])

    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    @pytest.mark.parametrize("t", [1, 2, 3, 4])
    @pytest.mark.parametrize("r", [1, 2, 3])
    @pytest.mark.parametrize("shape", ["box", "star"])
    def test_matmul_reuse_matches_oracle(self, shape, r, t, dtype):
        spec = StencilSpec(shape, 2, r)
        w = make_weights(spec, seed=r)
        x = _x(48, 96, dtype)
        y = stencil_matmul(x, w, t=t, tile_m=24, tile_n=32, interpret=True)
        ref = stencil_direct_ref(x.astype(jnp.float32), w, t)
        np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(ref),
                                   atol=TOL[dtype])

    def test_multi_strip_equals_single_strip(self):
        """Strip decomposition is invisible: gm=1 vs gm=4 bitwise equal."""
        w = make_weights(StencilSpec("box", 2, 1), seed=0)
        x = _x(64, 64)
        a = stencil_direct(x, w, t=2, tile_m=64, interpret=True)
        b = stencil_direct(x, w, t=2, tile_m=16, interpret=True)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestSubblockedEquivalence:
    """The halo-row sub-blocked substrate assembles byte-identical extended
    strips, so its outputs are BIT-FOR-BIT equal to the whole-strip kernels
    in f32 -- the ISSUE's acceptance sweep: box/star x r{1,2,3} x t{1,2,4}
    x h_block dividing strip_m."""

    STRIP_M = 24

    def _hblocks(self, r, t):
        halo = r * t
        return [d for d in (1, 2, 3, 4, 6, 8, 12, 24)
                if self.STRIP_M % d == 0 and d >= halo]

    @pytest.mark.parametrize("t", [1, 2, 4])
    @pytest.mark.parametrize("r", [1, 2, 3])
    @pytest.mark.parametrize("shape", ["box", "star"])
    def test_direct_bitwise_vs_wholestrip(self, shape, r, t):
        w = make_weights(StencilSpec(shape, 2, r), seed=r)
        x = _x(48, 64)
        whole = stencil_direct(x, w, t=t, tile_m=self.STRIP_M, h_block=0,
                               interpret=True)
        for hb in self._hblocks(r, t):
            sub = stencil_direct(x, w, t=t, tile_m=self.STRIP_M, h_block=hb,
                                 interpret=True)
            np.testing.assert_array_equal(np.asarray(sub), np.asarray(whole))

    @pytest.mark.parametrize("t", [1, 2, 4])
    @pytest.mark.parametrize("r", [1, 2, 3])
    @pytest.mark.parametrize("shape", ["box", "star"])
    def test_matmul_bitwise_vs_wholestrip(self, shape, r, t):
        w = make_weights(StencilSpec(shape, 2, r), seed=r)
        x = _x(48, 64)
        whole = stencil_matmul(x, w, t=t, tile_m=self.STRIP_M, tile_n=32,
                               h_block=0, interpret=True)
        for hb in self._hblocks(r, t):
            sub = stencil_matmul(x, w, t=t, tile_m=self.STRIP_M, tile_n=32,
                                 h_block=hb, interpret=True)
            np.testing.assert_array_equal(np.asarray(sub), np.asarray(whole))

    def test_single_strip_wraps_to_itself(self):
        """gm=1: both substrates take the periodic halo from the strip
        itself (modulo wrap), matching the oracle."""
        w = make_weights(StencilSpec("box", 2, 2), seed=0)
        x = _x(32, 32)
        ref = stencil_direct_ref(x, w, 2)
        y = stencil_direct(x, w, t=2, tile_m=32, h_block=8, interpret=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4)

    def test_auto_hblock_end_to_end(self):
        """h_block=None auto-sizes (tile_m given and not) and still matches
        the oracle on a grid not divisible by 128."""
        w = make_weights(StencilSpec("star", 2, 1), seed=1)
        x = _x(192, 160)
        ref = stencil_direct_ref(x, w, 2)
        np.testing.assert_allclose(
            np.asarray(stencil_direct(x, w, t=2, interpret=True)),
            np.asarray(ref), atol=1e-4)
        np.testing.assert_allclose(
            np.asarray(stencil_matmul(x, w, t=2, tile_m=48, interpret=True)),
            np.asarray(ref), atol=1e-4)


class TestSubstrate3D:
    """The ISSUE's 3D acceptance sweep: sub-blocked vs whole-slab foil vs
    the kernels/ref.py oracle, box/star x r{1,2} x t{1,2} x f32/bf16.
    Both substrates assemble byte-identical halo-extended slabs, so their
    outputs are BIT-for-bit equal in every dtype; the VPU box path even
    reproduces the roll oracle bitwise in f32 (identical tap order)."""

    Z, H, W = 12, 24, 32
    SLAB, STRIP = 6, 12

    TOL3 = {"float32": 2e-4, "bfloat16": 6e-2}

    def _blocks(self, halo):
        return choose_hblock(self.SLAB, halo), choose_hblock(self.STRIP, halo)

    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    @pytest.mark.parametrize("t", [1, 2])
    @pytest.mark.parametrize("r", [1, 2])
    @pytest.mark.parametrize("shape", ["box", "star"])
    def test_direct_bitwise_vs_wholeslab_and_oracle(self, shape, r, t, dtype):
        w = make_weights(StencilSpec(shape, 3, r), seed=r)
        x = _x3(self.Z, self.H, self.W, dtype)
        zb, hb = self._blocks(r * t)
        whole = stencil_direct(x, w, t=t, tile_m=self.STRIP, h_block=0,
                               z_slab=self.SLAB, interpret=True)
        sub = stencil_direct(x, w, t=t, tile_m=self.STRIP, h_block=hb,
                             z_slab=self.SLAB, z_block=zb, interpret=True)
        np.testing.assert_array_equal(np.asarray(sub), np.asarray(whole))
        ref = stencil_direct_ref(x.astype(jnp.float32), w, t)
        if shape == "box" and dtype == "float32" and (r == 1 or t == 1):
            # no structural zero taps => identical accumulation order =>
            # the kernel IS the oracle, bit for bit (at r=2 AND t=2 XLA's
            # FMA formation on the intermediate diverges by 1 ulp)
            np.testing.assert_array_equal(np.asarray(sub), np.asarray(ref))
        else:
            np.testing.assert_allclose(np.asarray(sub, np.float32),
                                       np.asarray(ref),
                                       atol=self.TOL3[dtype])

    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    @pytest.mark.parametrize("t", [1, 2])
    @pytest.mark.parametrize("r", [1, 2])
    @pytest.mark.parametrize("shape", ["box", "star"])
    def test_matmul_bitwise_vs_wholeslab(self, shape, r, t, dtype):
        w = make_weights(StencilSpec(shape, 3, r), seed=r)
        x = _x3(self.Z, self.H, self.W, dtype)
        zb, hb = self._blocks(r * t)
        whole = stencil_matmul(x, w, t=t, tile_m=self.STRIP, tile_n=16,
                               h_block=0, z_slab=self.SLAB, interpret=True)
        sub = stencil_matmul(x, w, t=t, tile_m=self.STRIP, tile_n=16,
                             h_block=hb, z_slab=self.SLAB, z_block=zb,
                             interpret=True)
        np.testing.assert_array_equal(np.asarray(sub), np.asarray(whole))
        ref = stencil_direct_ref(x.astype(jnp.float32), w, t)
        np.testing.assert_allclose(np.asarray(sub, np.float32),
                                   np.asarray(ref), atol=self.TOL3[dtype])

    def test_reuse_bitwise_vs_sequential_matmul_3d(self):
        """The 3D reuse regime executes the same banded dot products as t
        sequential contractions -- bit-for-bit in f32, as in 2D."""
        w = make_weights(StencilSpec("star", 3, 1), seed=0)
        x = _x3(12, 24, 32)
        fused = stencil_matmul(x, w, t=2, tile_m=12, tile_n=16,
                               z_slab=6, interpret=True)
        seq = x
        for _ in range(2):
            seq = stencil_matmul(seq, w, t=1, tile_m=12, tile_n=16,
                                 z_slab=6, interpret=True)
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(seq))

    def test_auto_geometry_end_to_end(self):
        """Fully auto (z_slab/tile_m/h_block/z_block all None) on a grid
        with no 128-divisible axis still matches the oracle."""
        w = make_weights(StencilSpec("box", 3, 1), seed=2)
        x = _x3(10, 20, 24)
        ref = stencil_direct_ref(x, w, 2)
        np.testing.assert_allclose(
            np.asarray(stencil_direct(x, w, t=2, interpret=True)),
            np.asarray(ref), atol=1e-4)
        np.testing.assert_allclose(
            np.asarray(stencil_matmul(x, w, t=2, interpret=True)),
            np.asarray(ref), atol=1e-4)

    def test_read_bytes_product_formula(self):
        """Analytic 3D reads == (1 + 2h/strip)(1 + 2zb/slab) * Z*H*W*D for
        every (z_block | z_slab, h_block | strip_m); the whole-slab foil
        reads exactly 9x."""
        Z, H, W, D = 16, 32, 64, 4
        grid_bytes = Z * H * W * D
        for zs, sm in [(8, 16), (16, 32), (4, 8)]:
            for zb in (d for d in range(1, zs + 1) if zs % d == 0):
                for hb in (d for d in range(1, sm + 1) if sm % d == 0):
                    g = SubstrateGeom(dim=3, strip_m=sm, h_block=hb,
                                      z_slab=zs, z_block=zb)
                    got = hbm_read_bytes_per_step_3d((Z, H, W), g, D)
                    want = ((1 + 2 * hb / sm) * (1 + 2 * zb / zs)
                            * grid_bytes)
                    assert got == pytest.approx(want)
                    assert g.read_amp == pytest.approx(got / grid_bytes)
            foil = SubstrateGeom(dim=3, strip_m=sm, h_block=0,
                                 z_slab=zs, z_block=0)
            assert hbm_read_bytes_per_step_3d((Z, H, W), foil, D) == \
                9 * grid_bytes
            assert foil.read_amp == 9.0

    def test_subblocked_amp_strictly_below_wholeslab(self):
        """Auto joint sizing always beats the 9x foil (the acceptance
        bound), and by a wide margin for shallow halos."""
        for halo in (1, 2, 4):
            zs, zb, sm, hb, wt, wb = choose_slab_blocks(64, 256, 512, halo)
            assert (wt, wb) == (0, 0)     # full width fits at this size
            g = SubstrateGeom(dim=3, strip_m=sm, h_block=hb,
                              z_slab=zs, z_block=zb)
            assert g.read_amp < 9.0
            if halo <= 2:
                assert g.read_amp <= 2.0

    def test_band_sparsity_measures_every_rank(self):
        """The measured-S sanity helper covers the 1D/3D operands this PR
        adds (it measures exactly what the N-D kernel loads)."""
        from repro.kernels import band_sparsity
        for spec in (StencilSpec("box", 1, 1), StencilSpec("box", 2, 1),
                     StencilSpec("box", 3, 1), StencilSpec("star", 3, 2)):
            s = band_sparsity(make_weights(spec, seed=0), 32)
            assert 0.0 < s <= 1.0

    def test_choose_slab_blocks_divides_and_covers(self):
        for (z, h, halo) in [(64, 256, 3), (48, 96, 8), (16, 32, 4)]:
            zs, zb, sm, hb, wt, wb = choose_slab_blocks(z, h, 128, halo)
            assert z % zs == 0 and h % sm == 0
            assert zs % zb == 0 and sm % hb == 0
            assert zb >= halo and hb >= halo
            assert (wt, wb) == (0, 0)     # full width fits at this size

    def test_validate_errors(self):
        w = make_weights(StencilSpec("box", 3, 1), seed=0)
        with pytest.raises(ValueError, match="z_slab"):
            stencil_direct(_x3(12, 24, 32), w, tile_m=12, z_slab=5,
                           interpret=True)
        with pytest.raises(ValueError, match="z_block"):
            stencil_direct(_x3(12, 24, 32), w, t=2, tile_m=12, z_slab=6,
                           h_block=2, z_block=1, interpret=True)
        with pytest.raises(ValueError, match="whole-slab"):
            resolve_substrate_geom((12, 24, 32), 1, 4, tile_m=12,
                                   h_block=2, z_slab=6, z_block=0)
        with pytest.raises(ValueError, match="rank"):
            stencil_direct(_x3(12, 24, 32), w[0], interpret=True)


class Test1DLift:
    """1D grids route through the 2D substrate lifted to (1, N): no crash,
    no vertical halo, read amplification exactly 1."""

    @pytest.mark.parametrize("t", [1, 3])
    @pytest.mark.parametrize("r", [1, 2])
    def test_direct_and_matmul_match_oracle(self, r, t):
        w = make_weights(StencilSpec("box", 1, r), seed=r)
        x = jnp.asarray(RNG.normal(size=(96,)).astype(np.float32))
        ref = stencil_direct_ref(x, w, t)
        np.testing.assert_allclose(
            np.asarray(stencil_direct(x, w, t=t, interpret=True)),
            np.asarray(ref), atol=2e-5)
        np.testing.assert_allclose(
            np.asarray(stencil_matmul(x, w, t=t, interpret=True)),
            np.asarray(ref), atol=2e-5)

    def test_lifted_geometry_reads_once(self):
        g = resolve_substrate_geom((128,), 0, 4)
        assert g.dim == 1 and g.strip_m == 1 and g.read_amp == 1.0

    def test_h_block_pins_coerce_like_plans(self):
        """Kernel-level h_block pins on 1D grids coerce exactly as the
        plan-level rule does (0 stays the foil, anything else becomes 1)
        -- no pin a plan accepts may crash the kernel."""
        w = make_weights(StencilSpec("box", 1, 1), seed=0)
        x = jnp.asarray(RNG.normal(size=(64,)).astype(np.float32))
        base = stencil_direct(x, w, t=1, interpret=True)
        for hb in (0, 1, 4):
            np.testing.assert_array_equal(
                np.asarray(stencil_direct(x, w, t=1, h_block=hb,
                                          interpret=True)),
                np.asarray(base))
            np.testing.assert_array_equal(
                np.asarray(stencil_matmul(x, w, t=1, h_block=hb,
                                          interpret=True)),
                np.asarray(stencil_matmul(x, w, t=1, interpret=True)))


class TestChooseHBlock:
    def test_divides_and_covers_halo(self):
        for strip_m, halo in [(32, 1), (32, 4), (128, 8), (24, 12), (48, 5)]:
            hb = choose_hblock(strip_m, halo)
            assert strip_m % hb == 0 and hb >= halo

    def test_degenerates_to_whole_strip_at_full_halo(self):
        assert choose_hblock(32, 32) == 32
        assert substrate_read_amp(32, 32) == 3.0

    def test_amp_small_when_halo_allows(self):
        strip_m, hb = choose_strip_blocks(1024, 512, 2)
        assert substrate_read_amp(strip_m, hb) <= 1.25

    def test_joint_choice_consistent_with_choose_strip(self):
        for h, halo in [(256, 3), (96, 8), (128, 24)]:
            strip_m, hb = choose_strip_blocks(h, 512, halo)
            assert strip_m == choose_strip(h, 512, halo)
            assert strip_m % hb == 0 and hb >= halo


class TestReuseRegimeExactness:
    """The intermediate-reuse kernel executes the SAME per-point banded dot
    products as t sequential MXU steps, so in f32 it is bit-for-bit equal
    to the sequential-matmul execution (no alpha redundancy to perturb
    rounding) -- the strongest equivalence the regime admits."""

    @pytest.mark.parametrize("r,t", [(1, 2), (1, 4), (2, 3), (3, 2)])
    @pytest.mark.parametrize("shape", ["box", "star"])
    def test_bitwise_vs_sequential_matmul(self, shape, r, t):
        w = make_weights(StencilSpec(shape, 2, r), seed=r)
        x = _x(64, 64)
        fused = stencil_matmul(x, w, t=t, tile_m=32, tile_n=32, interpret=True)
        seq = x
        for _ in range(t):
            seq = stencil_matmul(seq, w, t=1, tile_m=32, tile_n=32,
                                 interpret=True)
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(seq))


class TestValidateTiling:
    def test_rows_not_divisible(self):
        w = make_weights(StencilSpec("box", 2, 1), seed=0)
        with pytest.raises(ValueError, match="divisible"):
            stencil_direct(_x(60, 64), w, tile_m=32, interpret=True)

    def test_cols_not_divisible_matmul_runs_remainder(self):
        """tile_n no longer needs to divide W: the final narrower chunk
        contracts against the banded operand's leading submatrix (the
        choose_tile cap-policy satellite) and matches the oracle."""
        w = make_weights(StencilSpec("box", 2, 1), seed=0)
        x = _x(64, 60)
        y = stencil_matmul(x, w, tile_m=32, tile_n=32, interpret=True)
        ref = stencil_direct_ref(x, w, 1)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=2e-5)
        with pytest.raises(ValueError, match="column tile"):
            stencil_matmul(x, w, tile_m=32, tile_n=0, interpret=True)

    def test_halo_exceeds_strip(self):
        w = make_weights(StencilSpec("box", 2, 3), seed=0)
        with pytest.raises(ValueError, match="halo"):
            stencil_direct(_x(64, 64), w, t=6, tile_m=16, interpret=True)

    def test_halo_exceeds_width(self):
        with pytest.raises(ValueError, match="width"):
            validate_tiling((32, 8), 16, 8, 9)

    def test_valid_passes(self):
        validate_tiling((64, 128), 32, 32, 4)
        validate_tiling((64, 128), 32, 32, 4, h_block=8)

    def test_hblock_not_dividing_strip(self):
        w = make_weights(StencilSpec("box", 2, 1), seed=0)
        with pytest.raises(ValueError, match="h_block"):
            stencil_direct(_x(64, 64), w, tile_m=32, h_block=5,
                           interpret=True)

    def test_hblock_smaller_than_halo(self):
        w = make_weights(StencilSpec("box", 2, 2), seed=0)
        with pytest.raises(ValueError, match="h_block"):
            stencil_matmul(_x(64, 64), w, t=2, tile_m=32, tile_n=32,
                           h_block=2, interpret=True)


class TestChooseStrip:
    def test_divides_and_covers_halo(self):
        for h, halo in [(256, 3), (96, 8), (128, 24)]:
            s = choose_strip(h, 512, halo)
            assert h % s == 0 and s >= halo

    def test_prefers_mxu_height(self):
        assert choose_strip(1024, 512, 2) == 128

    def test_vmem_pressure_shrinks_strip(self):
        big = choose_strip(4096, 4096, 1, vmem_budget=2**40)
        small = choose_strip(4096, 4096, 1, vmem_budget=2**20)
        assert small < big

    def test_small_grid_single_strip(self):
        assert choose_strip(32, 32, 4) == 32

    def test_auto_tiles_in_dispatch(self):
        """tile_m=None routes through choose_strip/choose_tile: grids not
        divisible by 128 work out of the box."""
        w = make_weights(StencilSpec("box", 2, 1), seed=0)
        x = _x(192, 160)                     # 192 % 128 != 0, 160 % 128 != 0
        ref = stencil_direct_ref(x, w, 2)
        yd = stencil_direct(x, w, t=2, interpret=True)
        ym = stencil_matmul(x, w, t=2, interpret=True)
        np.testing.assert_allclose(np.asarray(yd), np.asarray(ref), atol=1e-4)
        np.testing.assert_allclose(np.asarray(ym), np.asarray(ref), atol=1e-4)

    def test_narrow_grid_deep_fusion(self):
        """Width only constrains the per-step wrap radius r, not t*r: a
        16-wide grid takes t=8 fused steps of an r=3 stencil."""
        w = make_weights(StencilSpec("box", 2, 3), seed=0)
        x = _x(64, 16)
        ref = stencil_direct_ref(x, w, 8)
        y = stencil_direct(x, w, t=8, tile_m=32, interpret=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-3)


class TestTrafficAccounting:
    """The acceptance criteria: analytic reads fall 9x (seed) -> 3x
    (whole-strip) -> 1 + 2h/strip_m (sub-blocked)."""

    def test_loads_per_output_tile(self):
        assert len(common.strip_in_specs(32, 128, 4)) == 3 <= 4
        assert len(legacy.neighbor_in_specs(32, 32, 4, 4)) == 9

    def test_read_amplification_3x_vs_9x(self):
        shape = (256, 256)
        new = common.hbm_read_bytes_per_step(shape, 32, 4)
        old = legacy.hbm_read_bytes_per_step(shape, 32, 32, 4)
        grid_bytes = 256 * 256 * 4
        assert new == 3 * grid_bytes
        assert old == 9 * grid_bytes

    def test_subblocked_read_bytes_formula(self):
        """Analytic read_bytes == (1 + 2h/strip_m) * H*W*D exactly, for
        every h_block dividing the strip."""
        H, W, D = 256, 256, 4
        grid_bytes = H * W * D
        for strip_m in (32, 64, 128):
            for hb in (d for d in range(1, strip_m + 1) if strip_m % d == 0):
                got = common.hbm_read_bytes_per_step((H, W), strip_m, D,
                                                     h_block=hb)
                want = (1 + 2 * hb / strip_m) * grid_bytes
                assert got == want
                assert substrate_read_amp(strip_m, hb) == \
                    pytest.approx(got / grid_bytes)

    def test_subblocked_amp_at_default_strips(self):
        """At default joint sizing the amplification is <= 1.3x for shallow
        halos (the ISSUE's acceptance bound vs 3.0x whole-strip)."""
        for halo in (1, 2, 4):
            strip_m, hb = choose_strip_blocks(1024, 1024, halo)
            assert substrate_read_amp(strip_m, hb) <= 1.3
        assert substrate_read_amp(strip_m, 0) == 3.0      # whole-strip foil
        with pytest.raises(ValueError, match="auto"):
            substrate_read_amp(strip_m, None)             # None != whole-strip

    def test_bands_charged_identically(self):
        """The banded operand term is substrate-independent (one fetch per
        output strip)."""
        bands = (3, 40, 32)
        base = common.hbm_read_bytes_per_step((256, 256), 32, 4)
        with_b = common.hbm_read_bytes_per_step((256, 256), 32, 4,
                                                bands_shape=bands)
        sub = common.hbm_read_bytes_per_step((256, 256), 32, 4, h_block=8)
        sub_b = common.hbm_read_bytes_per_step((256, 256), 32, 4,
                                               bands_shape=bands, h_block=8)
        assert with_b - base == sub_b - sub == 8 * 3 * 40 * 32 * 4

    def test_legacy_kernels_still_correct(self):
        """legacy.py backs the old-vs-new benchmark; keep it honest."""
        w = make_weights(StencilSpec("box", 2, 1), seed=0)
        x = _x(64, 64)
        ref = stencil_direct_ref(x, w, 2)
        yd = legacy.stencil_direct_9pt(x, w, t=2, tile_m=32, tile_n=32,
                                       interpret=True)
        np.testing.assert_allclose(np.asarray(yd), np.asarray(ref), atol=1e-4)


class TestChooseTile:
    """The choose_tile bugfix satellite: pad-or-cap policy, never a
    degenerate tile (the old largest-divisor rule returned 1 on primes
    and off-lane divisors like 65 on near-misses)."""

    def test_never_degenerate_sweep(self):
        """The acceptance sweep: for every n <= 4096 the tile is
        min(n, 128) -- never below min(n, 8), never above n."""
        for n in range(1, 4097):
            tile = choose_tile(n)
            assert tile == min(n, 128)
            assert tile >= min(n, 8)
            assert tile <= n

    def test_issue_cases(self):
        assert choose_tile(257) == 128        # was 1 (prime width)
        assert choose_tile(130) == 128        # was 65 (off-lane divisor)
        assert choose_tile(100) == 100
        assert choose_tile(4096) == 128
        assert choose_tile(300, preferred=256) == 256

    def test_rejects_nonpositive(self):
        with pytest.raises(ValueError, match="positive"):
            choose_tile(0)


class TestChooseHBlockProperty:
    """The choose_hblock satellite: integer ceil-division floor, plus the
    exhaustive property sweep (divides the strip, covers the halo)."""

    def test_property_sweep(self):
        for strip_m in range(1, 129):
            for halo in range(0, strip_m + 1):
                hb = choose_hblock(strip_m, halo)
                assert isinstance(hb, int)
                assert strip_m % hb == 0, (strip_m, halo, hb)
                assert hb >= halo, (strip_m, halo, hb)
                # the 1/16 floor is integer ceil division
                assert hb >= min(strip_m, -(-strip_m // 16))

    def test_floor_is_integer_ceil(self):
        # strip_m=24: ceil(24/16)=2; the smallest halo-0 divisor >= 2 is 2
        assert choose_hblock(24, 0) == 2
        assert choose_hblock(32, 0) == 2
        assert choose_hblock(17, 0) == 17     # prime: no proper divisor


class TestWrapRadiusGuard:
    """The shared wrap-radius guard satellite: one check, every rank
    (the 1D/2D/3D branches used to carry their own copies; the 3D path
    was untested)."""

    @pytest.mark.parametrize("shape,kwargs", [
        ((8,), {}),
        ((32, 8), {}),
        ((16, 32, 8), dict(z_slab=16)),
    ])
    def test_all_ranks_raise(self, shape, kwargs):
        with pytest.raises(ValueError, match="wrap radius"):
            validate_tiling(shape, 16, 8, 9, **kwargs)

    def test_3d_kernel_path(self):
        w = make_weights(StencilSpec("box", 3, 3), seed=0)
        with pytest.raises(ValueError, match="wrap radius"):
            stencil_direct(_x3(8, 16, 2), w, tile_m=8, z_slab=8,
                           interpret=True)

    def test_valid_radius_passes(self):
        validate_tiling((8,), 1, 8, 4)
        validate_tiling((16, 32, 32), 16, 32, 4, z_slab=16)


class TestColumnTiled:
    """The PR's tentpole: the column-tiled W substrate (DESIGN.md §10).
    Substrate equivalence (column-tiled vs whole-width), the remainder
    path on awkward widths, the three-factor traffic formula, and the
    auto sizing's budget-driven escalation."""

    #: Awkward widths of the ISSUE's acceptance sweep: prime, composite
    #: with no 128-friendly divisor, and 8-divisible-but-not-128.
    AWKWARD_W = (257, 300, 1000)

    @pytest.mark.parametrize("wid", (64,) + AWKWARD_W)
    @pytest.mark.parametrize("r", [1, 2])
    @pytest.mark.parametrize("shape", ["box", "star"])
    def test_direct_t1_vs_wholewidth(self, shape, r, wid):
        """Single-step VPU: column-tiled (aligned AND remainder paths) is
        BIT-for-bit the whole-width kernel in f32 for box kernels (no
        structural zero taps => same tap sequence => same FMA formation);
        star kernels' skipped taps let XLA contract differently on some
        widths, perturbing the last ulp (the seed 3D-oracle caveat)."""
        w = make_weights(StencilSpec(shape, 2, r), seed=r)
        x = _x(48, wid)
        whole = stencil_direct(x, w, t=1, tile_m=24, h_block=12,
                               interpret=True)
        sub = stencil_direct(x, w, t=1, tile_m=24, h_block=12, w_tile=32,
                             interpret=True)
        if shape == "box":
            np.testing.assert_array_equal(np.asarray(sub), np.asarray(whole))
        else:
            np.testing.assert_allclose(np.asarray(sub), np.asarray(whole),
                                       atol=1e-6)

    @pytest.mark.parametrize("wid", (64,) + AWKWARD_W)
    @pytest.mark.parametrize("r,t", [(1, 1), (1, 2), (2, 2), (3, 4)])
    @pytest.mark.parametrize("shape", ["box", "star"])
    def test_matmul_bitwise_vs_wholewidth(self, shape, r, t, wid):
        """The MXU banded path is BIT-for-bit equal between the
        column-tiled and whole-width substrates at every depth, aligned
        and remainder widths alike: each output column contracts the
        same taps against the same band column, and zero band entries
        are exact no-ops -- the satellite's substrate-equivalence sweep
        on W in {257, 300, 1000}."""
        w = make_weights(StencilSpec(shape, 2, r), seed=r)
        x = _x(48, wid)
        whole = stencil_matmul(x, w, t=t, tile_m=24, h_block=12,
                               interpret=True)
        sub = stencil_matmul(x, w, t=t, tile_m=24, h_block=12, w_tile=32,
                             interpret=True)
        np.testing.assert_array_equal(np.asarray(sub), np.asarray(whole))

    @pytest.mark.parametrize("wid", (64, 257, 300))
    @pytest.mark.parametrize("r,t", [(1, 2), (2, 2), (1, 4)])
    def test_direct_depth_close_vs_wholewidth(self, r, t, wid):
        """Fused VPU steps: the carried-x-halo graph differs from the
        re-wrap graph, so XLA's FMA formation may perturb the last ulp
        (exactly the seed caveat for the 3D oracle at r=2, t=2) -- the
        values agree to float32 resolution."""
        w = make_weights(StencilSpec("star", 2, r), seed=r)
        x = _x(48, wid)
        whole = stencil_direct(x, w, t=t, tile_m=24, h_block=12,
                               interpret=True)
        sub = stencil_direct(x, w, t=t, tile_m=24, h_block=12, w_tile=32,
                             interpret=True)
        np.testing.assert_allclose(np.asarray(sub), np.asarray(whole),
                                   atol=1e-6)

    @pytest.mark.parametrize("wid", [32, 37, 257])
    @pytest.mark.parametrize("shape", ["box", "star"])
    def test_3d_column_tiled(self, shape, wid):
        """3D slab substrate with a column-tiled W: matmul bit-for-bit vs
        whole-width, direct bitwise at t=1 for box (allclose for star --
        see test_direct_t1_vs_wholewidth) and oracle-close at depth."""
        w = make_weights(StencilSpec(shape, 3, 1), seed=1)
        x = _x3(12, 24, wid)
        pins = dict(tile_m=12, z_slab=6, h_block=2, z_block=2,
                    interpret=True)
        whole = stencil_direct(x, w, t=1, **pins)
        sub = stencil_direct(x, w, t=1, w_tile=16, **pins)
        if shape == "box":
            np.testing.assert_array_equal(np.asarray(sub), np.asarray(whole))
        else:
            np.testing.assert_allclose(np.asarray(sub), np.asarray(whole),
                                       atol=1e-6)
        mw = stencil_matmul(x, w, t=2, tile_n=16, **pins)
        ms = stencil_matmul(x, w, t=2, tile_n=16, w_tile=16, **pins)
        np.testing.assert_array_equal(np.asarray(ms), np.asarray(mw))
        ref = stencil_direct_ref(x, w, 2)
        np.testing.assert_allclose(np.asarray(ms), np.asarray(ref),
                                   atol=2e-4)

    def test_reuse_bitwise_vs_sequential_column_tiled(self):
        """The reuse regime's exactness guarantee survives column tiling:
        t fused radius-r contractions == t sequential launches, bitwise."""
        w = make_weights(StencilSpec("box", 2, 1), seed=0)
        x = _x(48, 64)
        fused = stencil_matmul(x, w, t=3, tile_m=24, h_block=12, w_tile=32,
                               interpret=True)
        seq = x
        for _ in range(3):
            seq = stencil_matmul(seq, w, t=1, tile_m=24, h_block=12,
                                 w_tile=32, interpret=True)
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(seq))

    def test_wide_grid_exceeding_budget_executes_bitwise(self, monkeypatch):
        """THE acceptance criterion: 2D and 3D grids whose FULL-WIDTH
        working set exceeds the VMEM budget execute through auto
        resolution (which column-tiles), bit-for-bit equal to the
        reference oracle in f32, with the resolved geometry carrying a
        positive w_tile."""
        monkeypatch.setenv("REPRO_VMEM_BUDGET", "16384")
        assert vmem_budget_bytes() == 16384

        # 2D: even the thinnest full-width strip needs ~33 KB > budget
        assert min(common._strip_working_set(d, choose_hblock(d, 1),
                                             1024, 1, 4)
                   for d in (1, 2, 4, 8, 16, 32)) > 16384
        g2 = resolve_substrate_geom((32, 1024), 1, 4)
        assert g2.w_tile > 0 and g2.w_block >= 1
        w = make_weights(StencilSpec("box", 2, 1), seed=3)
        x = _x(32, 1024)
        ref = stencil_direct_ref(x, w, 1)
        y = stencil_direct(x, w, t=1, interpret=True)     # all-auto
        np.testing.assert_array_equal(np.asarray(y), np.asarray(ref))

        # 3D
        g3 = resolve_substrate_geom((8, 16, 512), 1, 4)
        assert g3.w_tile > 0
        w3 = make_weights(StencilSpec("box", 3, 1), seed=3)
        x3 = _x3(8, 16, 512)
        ref3 = stencil_direct_ref(x3, w3, 1)
        y3 = stencil_direct(x3, w3, t=1, interpret=True)  # all-auto
        np.testing.assert_array_equal(np.asarray(y3), np.asarray(ref3))

    def test_auto_stays_fullwidth_when_it_fits(self):
        """Default budget, modest widths: the resolution never
        column-tiles, so every pre-existing geometry is unchanged."""
        for shape in [(192, 160), (64, 64), (256, 512)]:
            g = resolve_substrate_geom(shape, 2, 4)
            assert g.w_tile == 0 and g.w_block == 0
        g = resolve_substrate_geom((12, 24, 32), 2, 4)
        assert g.w_tile == 0

    def test_read_bytes_three_factor_formula(self):
        """Analytic reads == (1 + 2h/strip)(1 + 2wb/wt) * H*W*D in 2D and
        the (z, y, w) product in 3D, exactly, for aligned widths."""
        H, W, D = 64, 256, 4
        grid_bytes = H * W * D
        for sm, hb in [(16, 4), (32, 8)]:
            for wt, wb in [(32, 8), (64, 16), (128, 32)]:
                got = common.hbm_read_bytes_per_step(
                    (H, W), sm, D, h_block=hb, w_tile=wt, w_block=wb)
                want = (1 + 2 * hb / sm) * (1 + 2 * wb / wt) * grid_bytes
                assert got == pytest.approx(want)
                g = SubstrateGeom(dim=2, strip_m=sm, h_block=hb,
                                  w_tile=wt, w_block=wb)
                assert g.read_amp == pytest.approx(got / grid_bytes)
        Z = 16
        grid_bytes3 = Z * H * W * D
        g3 = SubstrateGeom(dim=3, strip_m=16, h_block=4, z_slab=8,
                           z_block=2, w_tile=64, w_block=16)
        got3 = hbm_read_bytes_per_step_3d((Z, H, W), g3, D)
        want3 = ((1 + 2 * 4 / 16) * (1 + 2 * 2 / 8) * (1 + 2 * 16 / 64)
                 * grid_bytes3)
        assert got3 == pytest.approx(want3)
        assert g3.read_amp == pytest.approx(got3 / grid_bytes3)

    def test_choose_col_blocks_divides_and_covers(self):
        for (h, wid, halo) in [(64, 4096, 2), (128, 1000, 3), (32, 257, 1)]:
            sm, hb, wt, wb = choose_col_blocks(h, wid, halo,
                                               vmem_budget=64 * 1024)
            assert h % sm == 0 and sm % hb == 0 and hb >= halo
            assert wt % wb == 0 and wb >= halo and 0 < wt < wid

    def test_validate_errors(self):
        w = make_weights(StencilSpec("box", 2, 1), seed=0)
        x = _x(48, 64)
        with pytest.raises(ValueError, match="does not divide w_tile"):
            stencil_direct(x, w, tile_m=24, h_block=12, w_tile=32,
                           w_block=5, interpret=True)
        w2 = make_weights(StencilSpec("box", 2, 2), seed=0)
        with pytest.raises(ValueError, match="x-halo"):
            stencil_direct(x, w2, t=2, tile_m=24, h_block=12, w_tile=32,
                           w_block=2, interpret=True)
        with pytest.raises(ValueError, match="full-width|foil"):
            stencil_direct(x, w, tile_m=24, h_block=0, w_tile=32,
                           interpret=True)
        with pytest.raises(ValueError, match="w_tile"):
            resolve_substrate_geom((48, 64), 1, 4, w_block=8)

    def test_lone_wblock_rejected_on_every_path(self, monkeypatch):
        """A w_block pin without a w_tile is rejected uniformly: its
        acceptance must not flip when the VMEM budget forces the
        column-tiled escalation (the auto w_tile need not be divisible
        by an arbitrary pinned block)."""
        monkeypatch.setenv("REPRO_VMEM_BUDGET", "16384")
        with pytest.raises(ValueError, match="w_tile"):
            resolve_substrate_geom((32, 1024), 1, 4, w_block=5)
        with pytest.raises(ValueError, match="w_tile"):
            resolve_substrate_geom((8, 16, 512), 1, 4, w_block=5)
        with pytest.raises(ValueError, match="exceeds grid width"):
            validate_tiling((48, 64), 24, 64, 1, h_block=12, w_tile=128,
                            w_block=8)
        # whole-slab foil + column tiling rejected in 3D too
        with pytest.raises(ValueError, match="full-width|foil"):
            resolve_substrate_geom((12, 24, 32), 1, 4, tile_m=12,
                                   z_slab=6, h_block=0, w_tile=16)

    def test_wtile_at_grid_width_is_fullwidth_fast_path(self):
        """w_tile >= W normalizes to the full-width fast path: identical
        geometry, identical (bitwise) results."""
        g = resolve_substrate_geom((48, 64), 1, 4, w_tile=64)
        assert g.w_tile == 0
        w = make_weights(StencilSpec("box", 2, 1), seed=0)
        x = _x(48, 64)
        a = stencil_direct(x, w, tile_m=24, interpret=True)
        b = stencil_direct(x, w, tile_m=24, w_tile=64, interpret=True)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
