"""The trip-count-aware HLO cost analyzer: the dry-run's 'profiler'."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hlo_cost import analyze_hlo, _while_trip_count, _parse_op_line

D = 128


def _flops_of(fn, *avals):
    c = jax.jit(fn).lower(*avals).compile()
    return analyze_hlo(c.as_text()).flops


class TestTripCounts:
    def test_scan_multiplied(self):
        def f(h, ws):
            return jax.lax.scan(lambda h, w: (jnp.tanh(h @ w), None), h, ws)[0]

        h = jax.ShapeDtypeStruct((D, D), jnp.float32)
        ws = jax.ShapeDtypeStruct((8, D, D), jnp.float32)
        expect = 2 * 8 * D**3
        got = _flops_of(f, h, ws)
        assert abs(got - expect) / expect < 0.05
        # contrast: XLA's own cost_analysis counts the body ONCE
        c = jax.jit(f).lower(h, ws).compile()
        ca = c.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        assert ca.get("flops", 0) < expect / 4

    def test_nested_scan(self):
        def f(h, ws):
            def outer(h, w):
                inner = jax.lax.scan(lambda h2, _: (jnp.tanh(h2 @ w), None),
                                     h, None, length=4)[0]
                return inner, None
            return jax.lax.scan(outer, h, ws)[0]

        h = jax.ShapeDtypeStruct((D, D), jnp.float32)
        ws = jax.ShapeDtypeStruct((8, D, D), jnp.float32)
        expect = 2 * 8 * 4 * D**3
        got = _flops_of(f, h, ws)
        assert abs(got - expect) / expect < 0.05

    def test_unrolled_reference(self):
        def f(h, ws):
            for i in range(8):
                h = jnp.tanh(h @ ws[i])
            return h

        h = jax.ShapeDtypeStruct((D, D), jnp.float32)
        ws = jax.ShapeDtypeStruct((8, D, D), jnp.float32)
        expect = 2 * 8 * D**3
        got = _flops_of(f, h, ws)
        assert abs(got - expect) / expect < 0.05

    def test_trip_count_extraction(self):
        lines = [
            "  %p = (s32[], f32[4]) parameter(0)",
            "  %c = s32[] constant(42)",
            "  %i = s32[] get-tuple-element(%p), index=0",
            "  ROOT %cmp = pred[] compare(%i, %c), direction=LT",
        ]
        assert _while_trip_count(lines) == 42


class TestOpLineParsing:
    def test_simple(self):
        r = _parse_op_line("  %dot.1 = f32[16,16]{1,0} dot(%a, %b), xx")
        assert r == ("dot.1", "f32[16,16]{1,0}", "dot")

    def test_tuple_with_comment(self):
        line = ("  %while.1 = (s32[], f32[8,16]{1,0}, /*index=5*/ pred[]) "
                "while(%t), condition=%c, body=%b")
        r = _parse_op_line(line)
        assert r[0] == "while.1" and r[2] == "while"

    def test_root_prefix(self):
        r = _parse_op_line("  ROOT %out = f32[4]{0} add(%x, %y)")
        assert r == ("out", "f32[4]{0}", "add")

    def test_non_op_line(self):
        assert _parse_op_line("}") is None
        assert _parse_op_line("// comment") is None


class TestBytesModel:
    def test_matmul_bytes_reasonable(self):
        def f(a, b):
            return a @ b

        a = jax.ShapeDtypeStruct((512, 512), jnp.float32)
        c = jax.jit(f).lower(a, a).compile()
        pc = analyze_hlo(c.as_text())
        lo = 3 * 512 * 512 * 4 * 0.5        # operands+result, some fused
        hi = 3 * 512 * 512 * 4 * 4
        assert lo <= pc.bytes_major <= hi, pc.bytes_major
