"""Batched plan equivalence: ``stencil_plan(..., batch=B)`` must be
BITWISE-equal to a loop of unbatched plans -- across rank, stencil shape,
dtype, batch size and both fold modes.  This is the contract the serving
engine's throughput claim stands on (DESIGN.md §12): batching that
changed a single bit would be a different computation, not an
optimization.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import clear_plan_cache, plan_cache_stats, stencil_plan
from repro.kernels.plan import BATCH_MODES, _resolve_batch_mode, plan_signature
from repro.stencil import StencilSpec, jacobi_weights

#: (dim, grid, t): 3D stays at t=1 -- interpret-mode emulation makes deep
#: 3D fusion the slowest thing in the suite and depth is orthogonal to
#: the batch fold being tested.
_GEOM = {2: ((16, 16), 2), 3: ((8, 8, 8), 1)}


def _case(dim, shape, dtype_name):
    spec = StencilSpec(shape, dim, 1)
    w = jacobi_weights(spec)
    grid, t = _GEOM[dim]
    dt = jnp.bfloat16 if dtype_name == "bfloat16" else np.float32
    rng = np.random.default_rng(dim * 7 + len(shape))
    xs = jnp.asarray(rng.normal(size=(8,) + grid), dtype=dt)
    return w, grid, t, xs


class TestBatchedBitwiseSweep:
    """The ISSUE-7 acceptance sweep: 2D/3D x box/star x f32/bf16 x
    B in {1, 3, 8}, both fold modes, vs a loop of unbatched plans."""

    @pytest.mark.parametrize("dtype_name", ["float32", "bfloat16"])
    @pytest.mark.parametrize("shape", ["box", "star"])
    @pytest.mark.parametrize("dim", [2, 3])
    @pytest.mark.parametrize("mode", ["map", "vmap"])
    @pytest.mark.parametrize("B", [1, 3, 8])
    def test_batched_equals_unbatched_loop(self, dim, shape, dtype_name,
                                           mode, B):
        w, grid, t, xs = _case(dim, shape, dtype_name)
        xb = xs[:B]
        unbatched = stencil_plan(w, grid, xb.dtype, t)
        want = np.stack([np.asarray(jax.block_until_ready(unbatched(x)))
                         for x in xb])
        batched = stencil_plan(w, grid, xb.dtype, t, batch=B,
                               batch_mode=mode)
        got = np.asarray(jax.block_until_ready(batched(xb)))
        assert got.dtype == want.dtype
        np.testing.assert_array_equal(got, want), \
            f"{shape}-{dim}D {dtype_name} B={B} mode={mode}"


class TestBatchedPlanShape:
    def test_input_shape_and_rank_check(self):
        w, grid, t, xs = _case(2, "box", "float32")
        p = stencil_plan(w, grid, np.float32, t, batch=4)
        assert p.input_shape == (4,) + grid
        with pytest.raises(ValueError, match="built for input"):
            p(xs[0])                          # unbatched input to batched plan

    def test_unbatched_plan_rejects_batched_input(self):
        w, grid, t, xs = _case(2, "box", "float32")
        p = stencil_plan(w, grid, np.float32, t)
        assert p.input_shape == grid
        with pytest.raises(ValueError, match="built for input"):
            p(xs[:4])

    def test_explain_names_batch(self):
        w, grid, t, _ = _case(2, "star", "float32")
        p = stencil_plan(w, grid, np.float32, t, batch=8, batch_mode="map")
        assert "batch=8" in p.explain() and "map" in p.explain()


class TestBatchInCacheKey:
    """The batch axis (and the RESOLVED fold mode) are part of the plan
    signature: a batched plan must never be served where an unbatched one
    was requested, and vmap/map plans must never alias."""

    def _sig(self, **kw):
        w = jacobi_weights(StencilSpec("box", 2, 1))
        key, _, _, _ = plan_signature(w, (16, 16), np.float32, 2,
                                      interpret=True, **kw)
        return key

    def test_batch_changes_key(self):
        assert self._sig() != self._sig(batch=8)
        assert self._sig(batch=4) != self._sig(batch=8)

    def test_fold_mode_changes_key(self):
        assert self._sig(batch=8, batch_mode="map") \
            != self._sig(batch=8, batch_mode="vmap")

    def test_auto_aliases_its_resolution(self):
        # under interpret, auto == map (one plan, not two)
        assert self._sig(batch=8, batch_mode="auto") \
            == self._sig(batch=8, batch_mode="map")
        assert _resolve_batch_mode("auto", True) == "map"
        assert _resolve_batch_mode("auto", False) == "vmap"
        assert set(BATCH_MODES) == {"auto", "vmap", "map"}

    def test_cache_hit_on_batched_replan(self):
        clear_plan_cache()
        w, grid, t, _ = _case(2, "box", "float32")
        p1 = stencil_plan(w, grid, np.float32, t, batch=8)
        p2 = stencil_plan(w, grid, np.float32, t, batch=8)
        assert p1 is p2
        st = plan_cache_stats()
        assert st["hits"] == 1 and st["misses"] == 1

    def test_batch_validation(self):
        w = jacobi_weights(StencilSpec("box", 2, 1))
        with pytest.raises(ValueError, match="batch must be >= 1"):
            stencil_plan(w, (16, 16), np.float32, 1, batch=0)
        with pytest.raises(ValueError, match="batch_mode"):
            stencil_plan(w, (16, 16), np.float32, 1, batch=2,
                         batch_mode="scan")
