"""Serve CLI argument validation: degenerate loop bounds must die with a
usage error, not an UnboundLocalError deep in the prefill loop."""
import pytest

from repro.launch.serve import build_parser, parse_args


class TestServeArgValidation:
    @pytest.mark.parametrize("flag,value", [
        ("--batch", "0"), ("--batch", "-1"),
        ("--prompt-len", "0"), ("--prompt-len", "-3"),
        ("--gen", "0"), ("--gen", "-2"),
    ])
    def test_non_positive_bounds_exit_with_usage_error(self, flag, value,
                                                       capsys):
        with pytest.raises(SystemExit) as ei:
            parse_args([flag, value])
        assert ei.value.code == 2                      # argparse convention
        err = capsys.readouterr().err
        assert "must be >= 1" in err
        assert flag in err

    def test_valid_bounds_parse(self):
        args = parse_args(["--batch", "2", "--prompt-len", "4", "--gen", "8"])
        assert (args.batch, args.prompt_len, args.gen) == (2, 4, 8)
        assert args.arch == "llama3.2-1b"

    def test_non_integer_rejected_by_argparse(self, capsys):
        with pytest.raises(SystemExit) as ei:
            parse_args(["--batch", "two"])
        assert ei.value.code == 2

    def test_parser_has_no_side_effects(self):
        # build_parser is importable without touching jax/model state, so
        # CLI docs/tests can introspect flags cheaply
        ap = build_parser()
        flags = {a.option_strings[0] for a in ap._actions
                 if a.option_strings}
        assert {"--batch", "--prompt-len", "--gen",
                "--arch", "--check"} <= flags
