"""Fault-tolerance behaviour of the training loop: crash-resume continuity,
watchdog, and gradient-compression training."""
import jax
import numpy as np
import pytest

from repro.configs.registry import ModelConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.api import get_model
from repro.optim import adamw
from repro.train.loop import LoopConfig, StragglerWatchdog, train

TINY = ModelConfig("loop-tiny", "dense", 2, 32, 2, 1, 64, 128,
                   rope_theta=10000.0)


def _setup():
    model = get_model(TINY)
    data = SyntheticLM(DataConfig(vocab=TINY.vocab, seq_len=16,
                                  global_batch=4, seed=1))
    ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=30)
    return model, data, ocfg


class TestLoop:
    def test_loss_improves(self, tmp_path):
        model, data, ocfg = _setup()
        _, _, hist = train(model, data, ocfg,
                           LoopConfig(steps=25, ckpt_dir=None, log_every=100))
        assert hist[-1]["loss"] < hist[0]["loss"]

    def test_crash_resume_continues_exactly(self, tmp_path):
        """Train 20 straight vs 10 + resume 10: same final loss (stateless
        data + checkpointed params+opt make restarts bit-reproducible)."""
        model, data, ocfg = _setup()
        _, _, hist_straight = train(
            model, data, ocfg, LoopConfig(steps=20, ckpt_dir=None,
                                          log_every=100))

        ck = str(tmp_path / "ck")
        train(model, data, ocfg,
              LoopConfig(steps=10, ckpt_every=10, ckpt_dir=ck, log_every=100))
        _, _, hist_resumed = train(
            model, data, ocfg,
            LoopConfig(steps=20, ckpt_every=10, ckpt_dir=ck, log_every=100))
        assert hist_resumed[0]["step"] == 11       # resumed, not restarted
        a = hist_straight[-1]["loss"]
        b = hist_resumed[-1]["loss"]
        assert a == pytest.approx(b, rel=1e-4), (a, b)

    def test_watchdog_flags_outliers(self):
        dog = StragglerWatchdog(factor=3.0)
        for _ in range(10):
            assert not dog.observe(0.1)
        assert dog.observe(1.0)                    # 10x median -> straggler
        assert dog.flagged == 1

    def test_int8_compressed_training_converges(self):
        model, data, ocfg = _setup()
        _, _, hist = train(model, data, ocfg,
                           LoopConfig(steps=25, ckpt_dir=None, log_every=100,
                                      grad_compression="int8"))
        assert hist[-1]["loss"] < hist[0]["loss"]


class TestShardingRules:
    def test_divisibility_fallback(self):
        """Non-divisible dims silently fall back to replication."""
        import jax
        from repro.parallel import sharding
        # needs >= 2 devices to be meaningful; on 1 device mesh sizes are 1
        # so everything divides -- test the resolver logic directly instead
        from jax.sharding import PartitionSpec as P

        class FakeMesh:
            shape = {"data": 16, "model": 16}
            axis_names = ("data", "model")

        rules = {"heads": "model", "batch": ("data",), None: None}
        spec = sharding.resolve_axes(("batch", "heads"), rules, (32, 8),
                                     FakeMesh())
        assert spec == P("data", None)             # 8 heads % 16 -> replicate
        spec = sharding.resolve_axes(("batch", "heads"), rules, (32, 32),
                                     FakeMesh())
        assert spec == P("data", "model")

    def test_param_pspecs_cover_all_leaves(self):
        import jax
        from repro.configs import SMOKE
        from repro.models.api import get_model
        from repro.models import base

        for arch in ("llama3.2-1b", "qwen3-moe-235b-a22b", "rwkv6-1.6b"):
            defs = get_model(SMOKE[arch]).param_defs()
            n_defs = len(jax.tree.leaves(defs, is_leaf=base.is_def))
            axes = jax.tree.leaves(base.axes_tree(defs),
                                   is_leaf=lambda x: isinstance(x, tuple))
            assert len(axes) == n_defs
