"""Per-architecture smoke tests (reduced configs): forward shapes, loss
finite, one train step, decode step; decode<->forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKE, ARCHS, SHAPES, cells_for
from repro.models.api import get_model
from repro.optim import adamw
from repro.train.steps import make_train_step, make_serve_step

RNG = np.random.default_rng(0)


def _batch(cfg, B=2, S=32):
    b = {"tokens": RNG.integers(0, cfg.vocab, size=(B, S + 1)).astype(np.int32)}
    if cfg.family == "whisper":
        b["frames"] = RNG.standard_normal((B, S, cfg.d_model)).astype(np.float32)
    if cfg.family == "vlm":
        b["img_embeds"] = RNG.standard_normal(
            (B, cfg.n_img_patches, cfg.d_model)).astype(np.float32)
    return b


@pytest.mark.parametrize("arch", list(SMOKE))
class TestArchSmoke:
    def test_loss_finite(self, arch):
        cfg = SMOKE[arch]
        model = get_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        loss, aux = jax.jit(model.loss_fn)(params, _batch(cfg))
        assert np.isfinite(float(loss))
        assert float(loss) > 0

    def test_train_step_reduces_loss(self, arch):
        cfg = SMOKE[arch]
        model = get_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(
            model, adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=8)))
        st = adamw.init(params)
        batch = _batch(cfg)
        losses = []
        for _ in range(4):
            params, st, m = step(params, st, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0]
        assert all(np.isfinite(l) for l in losses)

    def test_decode_step_shapes(self, arch):
        cfg = SMOKE[arch]
        model = get_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        B, S = 2, 16
        caches = model.init_caches(B, S)
        step = jax.jit(make_serve_step(model))
        tok = jnp.zeros((B, 1), jnp.int32)
        nxt, caches2 = step(params, caches, tok, jnp.asarray(0, jnp.int32))
        assert nxt.shape == (B, 1)
        assert nxt.dtype == jnp.int32
        assert (np.asarray(nxt) >= 0).all() and (np.asarray(nxt) < cfg.vocab).all()
        # cache structure preserved
        jax.tree.map(lambda a, b: None, caches, caches2)


class TestDecodeConsistency:
    """Greedy decode with KV cache == argmax of the full forward pass."""

    @pytest.mark.parametrize("arch", ["llama3.2-1b", "rwkv6-1.6b"])
    def test_cached_decode_matches_forward(self, arch):
        cfg = SMOKE[arch]
        model = get_model(cfg)
        params = model.init_params(jax.random.PRNGKey(1))
        B, S = 2, 8
        toks = RNG.integers(0, cfg.vocab, size=(B, S)).astype(np.int32)

        if cfg.family == "rwkv":
            from repro.models import rwkv_model
            from repro.models import layers as nn
            h, _ = rwkv_model.forward(params, jnp.asarray(toks), cfg)
            full_logits = nn.lm_logits(params, h, cfg)
            # feed tokens one by one through the decode state
            state = rwkv_model.init_state(cfg, B)
            outs = []
            for t in range(S):
                nxt, state = rwkv_model.decode_step(
                    params, state, jnp.asarray(toks[:, t:t+1]), cfg)
                outs.append(np.asarray(nxt))
            want = np.asarray(jnp.argmax(full_logits, -1))
            got = np.concatenate(outs, axis=1)
            np.testing.assert_array_equal(got[:, :-1], want[:, :-1])
        else:
            from repro.models import transformer
            from repro.models import layers as nn
            h, _, _ = transformer.forward(params, jnp.asarray(toks), cfg)
            full_logits = nn.lm_logits(params, h, cfg)
            want = np.asarray(jnp.argmax(full_logits, -1))
            caches = transformer.init_caches(cfg, B, S + 1)
            outs = []
            for t in range(S):
                nxt, caches = transformer.decode_step(
                    params, caches, jnp.asarray(toks[:, t:t+1]), cfg,
                    jnp.asarray(t, jnp.int32))
                outs.append(np.asarray(nxt))
            got = np.concatenate(outs, axis=1)
            np.testing.assert_array_equal(got, want)


class TestInputSpecs:
    @pytest.mark.parametrize("arch", list(ARCHS))
    def test_all_cells_have_specs(self, arch):
        model = get_model(ARCHS[arch])
        for cell_name in cells_for(arch):
            specs = model.input_specs(SHAPES[cell_name])
            leaves = jax.tree.leaves(specs)
            assert leaves, f"{arch}/{cell_name} produced no input specs"
            for leaf in leaves:
                assert isinstance(leaf, jax.ShapeDtypeStruct)

    def test_long500k_skips_full_attention(self):
        runs_long = [a for a in ARCHS if "long_500k" in cells_for(a)]
        assert set(runs_long) == {"zamba2-1.2b", "rwkv6-1.6b"}
