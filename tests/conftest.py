import os
import sys

# Make `repro` importable without installation.  NOTE: we deliberately do
# NOT set xla_force_host_platform_device_count here -- smoke tests must see
# the real single CPU device; multi-device tests spawn subprocesses.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
