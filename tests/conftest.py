import os
import sys
import types

import pytest

# Make `repro` importable without installation.  NOTE: we deliberately do
# NOT set xla_force_host_platform_device_count here -- smoke tests must see
# the real single CPU device; multi-device tests spawn subprocesses.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


# ---------------------------------------------------------------------------
# Graceful degradation when `hypothesis` is not installed (bare interpreter):
# several test modules do `from hypothesis import given, settings, strategies`
# unconditionally.  Rather than failing collection, install a stub module
# whose @given replaces the test body with a skip.  With the real package
# present (see requirements-dev.txt) this shim is inert.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:
    def _given(*_args, **_kwargs):
        def decorate(fn):
            def skipper(*a, **k):
                pytest.skip("hypothesis not installed (see requirements-dev.txt)")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return decorate

    def _settings(*_args, **_kwargs):
        def decorate(fn):
            return fn
        return decorate

    class _Strategy:
        """Inert placeholder: only ever passed to the stub @given."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    _st = types.ModuleType("hypothesis.strategies")
    for _name in ("integers", "floats", "booleans", "sampled_from", "lists",
                  "tuples", "text", "just", "one_of"):
        setattr(_st, _name, lambda *a, **k: _Strategy())

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.HealthCheck = types.SimpleNamespace(too_slow=None)
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
