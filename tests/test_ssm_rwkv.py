"""Recurrence-core equivalence: chunked scan == naive per-token recurrence
for Mamba2-SSD and WKV-6 (the substrate of zamba2 / rwkv6)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.rwkv6 import wkv_chunked, wkv_step
from repro.models.ssm import ssm_scan_chunked, ssm_step


class TestSSD:
    @pytest.mark.parametrize("chunk", [8, 16, 64])
    def test_chunked_equals_naive(self, chunk):
        rng = np.random.default_rng(0)
        B, S, H, hd, N = 2, 64, 3, 4, 5
        xh = jnp.asarray(rng.normal(size=(B, S, H, hd)).astype(np.float32))
        b = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
        c = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
        dt = jnp.asarray(rng.uniform(0.1, 1, size=(B, S, H)).astype(np.float32))
        A = jnp.asarray(-rng.uniform(0.5, 2, size=(H,)).astype(np.float32))
        st0 = jnp.zeros((B, H, hd, N), jnp.float32)
        y_c, st_c = ssm_scan_chunked(xh, b, c, dt, A, st0, chunk=chunk)
        stt = st0
        ys = []
        for t in range(S):
            yt, stt = ssm_step(xh[:, t:t+1], b[:, t:t+1], c[:, t:t+1],
                               dt[:, t:t+1], A, stt)
            ys.append(np.asarray(yt))
        np.testing.assert_allclose(np.asarray(y_c),
                                   np.concatenate(ys, axis=1),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(st_c), np.asarray(stt),
                                   rtol=2e-4, atol=2e-4)

    def test_state_carries_across_calls(self):
        """Splitting a sequence across two chunked calls == one call."""
        rng = np.random.default_rng(1)
        B, S, H, hd, N = 1, 32, 2, 4, 4
        xh = jnp.asarray(rng.normal(size=(B, S, H, hd)).astype(np.float32))
        b = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
        c = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
        dt = jnp.asarray(rng.uniform(0.1, 1, size=(B, S, H)).astype(np.float32))
        A = jnp.asarray(-rng.uniform(0.5, 2, size=(H,)).astype(np.float32))
        st0 = jnp.zeros((B, H, hd, N), jnp.float32)
        y_full, st_full = ssm_scan_chunked(xh, b, c, dt, A, st0, chunk=8)
        h = S // 2
        y1, st1 = ssm_scan_chunked(xh[:, :h], b[:, :h], c[:, :h], dt[:, :h],
                                   A, st0, chunk=8)
        y2, st2 = ssm_scan_chunked(xh[:, h:], b[:, h:], c[:, h:], dt[:, h:],
                                   A, st1, chunk=8)
        np.testing.assert_allclose(np.asarray(y_full),
                                   np.concatenate([y1, y2], axis=1),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(st_full), np.asarray(st2),
                                   rtol=1e-4, atol=1e-4)


class TestWKV6:
    @pytest.mark.parametrize("chunk", [8, 16])
    def test_chunked_equals_naive(self, chunk):
        rng = np.random.default_rng(0)
        B, S, H, K = 2, 32, 3, 4
        r = jnp.asarray(rng.normal(size=(B, S, H, K)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(B, S, H, K)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(B, S, H, K)).astype(np.float32))
        lw = jnp.asarray(-rng.uniform(0.01, 3, size=(B, S, H, K))
                         .astype(np.float32))
        u = jnp.asarray(rng.normal(size=(H, K)).astype(np.float32))
        st0 = jnp.zeros((B, H, K, K), jnp.float32)
        y_c, st_c = wkv_chunked(r, k, v, lw, u, st0, chunk=chunk)
        stt = st0
        ys = []
        for t in range(S):
            yt, stt = wkv_step(r[:, t:t+1], k[:, t:t+1], v[:, t:t+1],
                               lw[:, t:t+1], u, stt)
            ys.append(np.asarray(yt))
        np.testing.assert_allclose(np.asarray(y_c),
                                   np.concatenate(ys, axis=1),
                                   rtol=3e-4, atol=3e-4)
        np.testing.assert_allclose(np.asarray(st_c), np.asarray(stt),
                                   rtol=3e-4, atol=3e-4)

    @given(seed=st.integers(0, 20))
    @settings(max_examples=10, deadline=None)
    def test_property_extreme_decay_is_stable(self, seed):
        """No overflow even with extreme data-dependent decays (the reason
        the chunked form keeps only non-positive exponents)."""
        rng = np.random.default_rng(seed)
        B, S, H, K = 1, 16, 1, 4
        r = jnp.asarray(rng.normal(size=(B, S, H, K)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(B, S, H, K)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(B, S, H, K)).astype(np.float32))
        # near-zero decay (w ~ exp(-150)): would overflow a naive 1/a form
        lw = jnp.asarray(-rng.uniform(50, 150, size=(B, S, H, K))
                         .astype(np.float32))
        u = jnp.asarray(rng.normal(size=(H, K)).astype(np.float32))
        st0 = jnp.zeros((B, H, K, K), jnp.float32)
        y, stf = wkv_chunked(r, k, v, lw, u, st0, chunk=8)
        assert np.isfinite(np.asarray(y)).all()
        assert np.isfinite(np.asarray(stf)).all()
