"""Guarded execution layer (DESIGN.md §11): failure taxonomy, degradation
ladder, fault injection, plan-cache integrity under failure, env-knob
hardening, the event ring buffer, and the benchmark case budget."""
import math
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import events
from repro.core.envutil import env_flag, env_int, env_str
from repro.kernels import (GuardedExecutionError, HaloExchangeError,
                           KernelCompileError, NumericalFaultError,
                           PlanBuildError, VmemOverflowError,
                           classify_failure, clear_plan_cache,
                           fallback_ladder, guarded_stencil_plan,
                           plan_cache_stats, stencil_plan)
from repro.kernels import plan as plan_mod
from repro.kernels.ref import stencil_direct_ref
from repro.stencil import StencilSpec, make_weights
from repro.testing import faults


@pytest.fixture(autouse=True)
def _guard_hygiene():
    """Every test starts and ends with no armed faults, an empty event
    log, and a cold plan cache -- guard state is process-global."""
    faults.reset_faults()
    events.clear()
    clear_plan_cache()
    yield
    faults.reset_faults()
    events.clear()
    clear_plan_cache()


W = make_weights(StencilSpec("box", 2, 1), seed=0)
X = np.random.default_rng(0).normal(size=(64, 128)).astype(np.float32)


def _ref(t=2):
    return np.asarray(stencil_direct_ref(jnp.asarray(X), jnp.asarray(W), t))


# ---------------------------------------------------------------------------
# Taxonomy
# ---------------------------------------------------------------------------
class TestTaxonomy:
    @pytest.mark.parametrize("msg,cls", [
        ("INTERNAL: Mosaic failed to compile TPU kernel", KernelCompileError),
        ("RESOURCE_EXHAUSTED: Ran out of memory in memory space vmem",
         VmemOverflowError),
        ("error during ppermute collective", HaloExchangeError),
        ("output contained NaN after step", NumericalFaultError),
        ("XLA lowering failed: unsupported op", KernelCompileError),
    ])
    def test_message_classification(self, msg, cls):
        err = classify_failure(RuntimeError(msg))
        assert isinstance(err, cls)
        assert err.cause == cls.cause
        assert isinstance(err.__cause__, RuntimeError)

    def test_stage_breaks_ties(self):
        blank = RuntimeError("something entirely unrecognized")
        assert isinstance(classify_failure(blank, stage="build"),
                          PlanBuildError)
        assert isinstance(classify_failure(blank, stage="execute"),
                          KernelCompileError)

    def test_already_classified_passes_through(self):
        err = VmemOverflowError("x")
        assert classify_failure(err) is err

    def test_all_causes_distinct(self):
        causes = {c.cause for c in (PlanBuildError, KernelCompileError,
                                    VmemOverflowError, NumericalFaultError,
                                    HaloExchangeError)}
        assert len(causes) == 5


# ---------------------------------------------------------------------------
# Fault harness + env knob parsing (the hardening satellite)
# ---------------------------------------------------------------------------
class TestFaultParsing:
    def test_syntax(self):
        specs = faults.parse_faults("compile, vmem:3, nan:2@1, halo:inf")
        assert [(s.kind, s.times, s.skip) for s in specs] == [
            ("compile", 1, 0), ("vmem", 3, 0), ("nan", 2, 1),
            ("halo", math.inf, 0)]

    @pytest.mark.parametrize("raw", ["bogus", "compile:x", "compile:0",
                                     "vmem:1@-1", "nan:1.5"])
    def test_malformed_terms_raise(self, raw):
        with pytest.raises(ValueError, match="REPRO_FAULTS"):
            faults.parse_faults(raw)

    def test_nth_fire_semantics(self):
        with faults.inject("compile", times=2, skip=1) as spec:
            faults.maybe_fail("compile")              # skipped
            for _ in range(2):
                with pytest.raises(RuntimeError, match="injected"):
                    faults.maybe_fail("compile")
            faults.maybe_fail("compile")              # exhausted
        assert spec.fired == 2 and spec.hits == 4
        faults.maybe_fail("compile")                  # scope ended: no-op

    def test_env_arming(self, monkeypatch):
        monkeypatch.setenv("REPRO_FAULTS", "vmem:1")
        faults.reset_faults()
        with pytest.raises(RuntimeError, match="VMEM|vmem"):
            faults.maybe_fail("vmem")
        faults.maybe_fail("vmem")                     # consumed
        assert faults.fault_hits()["vmem"] == 1

    def test_env_malformed_raises_on_use(self, monkeypatch):
        monkeypatch.setenv("REPRO_FAULTS", "garbage:kind")
        faults.reset_faults()
        with pytest.raises(ValueError, match="REPRO_FAULTS"):
            faults.maybe_fail("compile")


class TestEnvKnobs:
    def test_env_int_default_and_parse(self, monkeypatch):
        monkeypatch.delenv("REPRO_TEST_KNOB", raising=False)
        assert env_int("REPRO_TEST_KNOB", 7) == 7
        monkeypatch.setenv("REPRO_TEST_KNOB", "42")
        assert env_int("REPRO_TEST_KNOB", 7) == 42

    @pytest.mark.parametrize("raw", ["", "  ", ])
    def test_env_int_empty_is_unset(self, monkeypatch, raw):
        monkeypatch.setenv("REPRO_TEST_KNOB", raw)
        assert env_int("REPRO_TEST_KNOB", 7) == 7

    @pytest.mark.parametrize("raw,match", [
        ("zero", "integer"), ("8MB", "integer"),
        ("-3", ">= 1"), ("0", ">= 1"),
    ])
    def test_env_int_garbage_and_negative(self, monkeypatch, raw, match):
        monkeypatch.setenv("REPRO_TEST_KNOB", raw)
        with pytest.raises(ValueError, match=match):
            env_int("REPRO_TEST_KNOB", 7)

    def test_shared_helper_backs_the_runtime_knobs(self, monkeypatch):
        # both historical knobs now parse through env_int with the same
        # message shape (the hardening satellite's acceptance)
        from repro.kernels import plan_cache_max, vmem_budget_bytes
        for var, fn in (("REPRO_VMEM_BUDGET", vmem_budget_bytes),
                        ("REPRO_PLAN_CACHE_SIZE", plan_cache_max)):
            monkeypatch.setenv(var, "garbage")
            with pytest.raises(ValueError, match=f"{var} must be an integer"):
                fn()
            monkeypatch.setenv(var, "-1")
            with pytest.raises(ValueError, match=f"{var} must be >= 1"):
                fn()
            monkeypatch.delenv(var)
            assert fn() >= 1

    def test_env_flag(self, monkeypatch):
        monkeypatch.delenv("REPRO_TEST_FLAG", raising=False)
        assert env_flag("REPRO_TEST_FLAG") is False
        for raw, want in (("1", True), ("true", True), ("ON", True),
                          ("0", False), ("no", False)):
            monkeypatch.setenv("REPRO_TEST_FLAG", raw)
            assert env_flag("REPRO_TEST_FLAG") is want
        monkeypatch.setenv("REPRO_TEST_FLAG", "maybe")
        with pytest.raises(ValueError, match="boolean"):
            env_flag("REPRO_TEST_FLAG")

    def test_env_str_strips(self, monkeypatch):
        monkeypatch.setenv("REPRO_TEST_KNOB", "  x  ")
        assert env_str("REPRO_TEST_KNOB") == "x"


# ---------------------------------------------------------------------------
# Event ring buffer
# ---------------------------------------------------------------------------
class TestEventLog:
    def test_bounded_with_drop_accounting(self):
        log = events.EventLog(capacity=4)
        for i in range(10):
            log.record("k", i=i)
        snap = log.snapshot()
        assert len(snap["events"]) == 4
        assert snap["recorded"] == 10 and snap["dropped"] == 6
        assert [e["i"] for e in snap["events"]] == [6, 7, 8, 9]

    def test_kind_filter_and_clear(self):
        events.record("a", v=1)
        events.record("b", v=2)
        assert [e["kind"] for e in events.events("a")] == ["a"]
        events.clear()
        assert events.events() == [] and events.snapshot()["recorded"] == 0


# ---------------------------------------------------------------------------
# Degradation ladder + plan-cache integrity under failure
# ---------------------------------------------------------------------------
class TestLadder:
    def test_ladder_order_terminates_at_reference(self):
        ladder = fallback_ladder()
        assert ladder[0] == "fused_matmul_reuse"
        assert ladder[-1] == "reference"
        assert ladder.index("fused_matmul") < ladder.index("matmul") \
            < ladder.index("fused_direct") < ladder.index("direct") \
            < ladder.index("fused_direct_wholestrip") \
            < ladder.index("direct_wholestrip")
        # unranked names fall back onto the FULL ladder
        assert fallback_ladder(after="legacy_direct") == ladder

    def test_clean_run_is_invisible(self):
        p0 = stencil_plan(W, X.shape, np.float32, 2, backend="fused_direct")
        g = guarded_stencil_plan(W, X.shape, np.float32, 2,
                                 backend="fused_direct")
        assert g.plan is p0            # the identical cached plan object
        y = g(jnp.asarray(X))
        assert not g.degraded and g.history == []
        assert events.events() == []
        st = plan_cache_stats()
        assert st["build_failures"] == st["exec_failures"] \
            == st["fallbacks"] == 0
        np.testing.assert_array_equal(np.asarray(y), _ref())
        assert "clean" in g.explain()

    def test_compile_inf_bottoms_out_on_reference_bitwise(self):
        with faults.inject("compile", times=math.inf):
            g = guarded_stencil_plan(W, X.shape, np.float32, 2,
                                     backend="fused_matmul_reuse")
            y = g(jnp.asarray(X))
        assert g.backend == "reference" and g.degraded
        assert all(h["cause"] == "compile" for h in g.history)
        np.testing.assert_array_equal(np.asarray(y), _ref())
        assert "DEGRADED" in g.explain()

    def test_vmem_degrades_geometry_same_backend(self):
        with faults.inject("vmem", times=1):
            g = guarded_stencil_plan(W, X.shape, np.float32, 2,
                                     backend="fused_direct")
            y = g(jnp.asarray(X))
        assert g.rung == "fused_direct+degraded"
        assert [h["cause"] for h in g.history] == ["vmem"]
        assert g.backend == "fused_direct"     # same regime, smaller tiles
        np.testing.assert_array_equal(np.asarray(y), _ref())

    def test_user_errors_raise_raw_not_laddered(self):
        with pytest.raises(ValueError, match="fusion depth"):
            guarded_stencil_plan(W, X.shape, np.float32, 0)
        with pytest.raises(ValueError, match="unknown backend"):
            guarded_stencil_plan(W, X.shape, np.float32, 2, backend="nope")
        with pytest.raises(ValueError, match="rank"):
            guarded_stencil_plan(W, (8, 8, 8), np.float32, 2)
        assert events.events() == []           # none of those are failures

    def test_failed_signature_never_in_lru(self):
        """Cache-integrity satellite: after an injected compile fault, the
        LRU must not contain the failed signature, the surviving rung IS
        cached, and the counters stay consistent."""
        with faults.inject("compile", times=1):
            g = guarded_stencil_plan(W, X.shape, np.float32, 2,
                                     backend="fused_direct")
            g(jnp.asarray(X))
        failed_key = plan_mod.plan_signature(
            W, X.shape, np.float32, 2, backend="fused_direct")[0]
        assert failed_key not in plan_mod._CACHE
        assert plan_mod.failed_plan(failed_key) is not None
        assert g.plan.key in plan_mod._CACHE   # surviving rung cached
        st = plan_cache_stats()
        assert st["exec_failures"] == 1 and st["fallbacks"] == 1
        assert st["negative_size"] == 1
        # misses: failed rung + surviving rung; hits unchanged
        assert st["misses"] >= 2 and st["hits"] == 0

    def test_negative_entry_short_circuits_repeat_failures(self):
        with faults.inject("compile", times=1):
            g1 = guarded_stencil_plan(W, X.shape, np.float32, 2,
                                      backend="fused_direct")
            g1(jnp.asarray(X))
        before = plan_cache_stats()
        # no fault armed now -- but the signature is negative-cached, so
        # the known-bad rung is skipped WITHOUT re-attempting the build
        g2 = guarded_stencil_plan(W, X.shape, np.float32, 2,
                                  backend="fused_direct")
        assert g2.rung == "fused_direct+degraded"
        st = plan_cache_stats()
        assert st["negative_hits"] > before["negative_hits"]
        assert st["exec_failures"] == before["exec_failures"]  # no retry
        assert [e["kind"] for e in events.events()][-1] == "guard_skip"

    def test_negative_entry_expires_after_cache_churn(self, monkeypatch):
        monkeypatch.setenv("REPRO_PLAN_CACHE_SIZE", "3")
        with faults.inject("compile", times=1):
            g = guarded_stencil_plan(W, X.shape, np.float32, 2,
                                     backend="fused_direct")
            g(jnp.asarray(X))
        failed_key = plan_mod.plan_signature(
            W, X.shape, np.float32, 2, backend="fused_direct")[0]
        assert plan_mod.failed_plan(failed_key) is not None
        # churn the cache past the bound: 4 fresh signatures > 3
        for t in (3, 4, 5, 6):
            stencil_plan(W, X.shape, np.float32, t, backend="reference")
        assert plan_mod.failed_plan(failed_key) is None   # expired
        # and the rung is attemptable again (no fault armed -> it builds)
        g2 = guarded_stencil_plan(W, X.shape, np.float32, 2,
                                  backend="fused_direct")
        assert not g2.degraded

    def test_watchdog_recovers_step_and_demotes(self):
        with faults.inject("nan", times=1):
            g = guarded_stencil_plan(W, X.shape, np.float32, 2,
                                     backend="fused_direct", watchdog=True)
            y = g(jnp.asarray(X))
        assert [h["cause"] for h in g.history] == ["numerical"]
        assert events.events("guard_watchdog")
        np.testing.assert_array_equal(np.asarray(y), _ref())
        # demoted rung keeps serving oracle-grade output
        np.testing.assert_array_equal(np.asarray(g(jnp.asarray(X))), _ref())

    def test_watchdog_off_by_default_lets_nan_through(self):
        with faults.inject("nan", times=1):
            g = guarded_stencil_plan(W, X.shape, np.float32, 2,
                                     backend="fused_direct")
            y = g(jnp.asarray(X))
        assert not g.degraded
        assert np.isnan(np.asarray(y)).any()   # opt-in means OPT-IN

    def test_guarded_apply_wrapper(self):
        from repro.kernels import stencil_apply
        with faults.inject("compile", times=1):
            y = stencil_apply(jnp.asarray(X), W, t=2, backend="fused_direct",
                              guard=True)
        np.testing.assert_array_equal(np.asarray(y), _ref())
        assert plan_cache_stats()["fallbacks"] == 1


# ---------------------------------------------------------------------------
# Benchmark case budget
# ---------------------------------------------------------------------------
class TestCaseBudget:
    def test_trips_on_overrun(self):
        from benchmarks.timing import CaseTimeout, case_budget
        t0 = time.perf_counter()
        with pytest.raises(CaseTimeout):
            with case_budget(1):
                time.sleep(5)
        assert time.perf_counter() - t0 < 4

    def test_no_trip_within_budget_and_alarm_restored(self):
        import signal
        from benchmarks.timing import case_budget
        with case_budget(30):
            pass
        assert signal.getitimer(signal.ITIMER_REAL)[0] == 0

    def test_zero_disables(self, monkeypatch):
        from benchmarks.timing import bench_budget_s, case_budget
        monkeypatch.setenv("REPRO_BENCH_BUDGET_S", "0")
        assert bench_budget_s() == 0
        with case_budget():
            time.sleep(0.01)               # no alarm armed at all

    def test_nested_budget_defers_to_outer(self):
        import signal
        from benchmarks.timing import CaseTimeout, case_budget
        with pytest.raises(CaseTimeout):
            with case_budget(1):
                outer = signal.getitimer(signal.ITIMER_REAL)[0]
                assert outer > 0
                with case_budget(1000):    # must NOT cancel the outer timer
                    assert signal.getitimer(signal.ITIMER_REAL)[0] > 0
                    time.sleep(5)

    def test_garbage_env_budget_raises(self, monkeypatch):
        from benchmarks.timing import bench_budget_s
        monkeypatch.setenv("REPRO_BENCH_BUDGET_S", "soon")
        with pytest.raises(ValueError, match="REPRO_BENCH_BUDGET_S"):
            bench_budget_s()
