"""StencilServer end-to-end: futures, batching, metrics, fault paths --
plus the concurrency satellites this subsystem leans on (plan-LRU thread
safety, event-log stress, latency histogram).

Everything runs in interpret mode on CPU; grids stay tiny so the suite
exercises dispatch machinery, not kernels.
"""
import math
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import events
from repro.core.events import EventLog
from repro.kernels import clear_plan_cache, plan_cache_stats, stencil_plan
from repro.kernels.ref import stencil_direct_ref
from repro.serve import LatencyHistogram, ServeMetrics, StencilServer
from repro.stencil import StencilSpec, jacobi_weights
from repro.testing import faults

GRID = (8, 8)
W_BOX = jacobi_weights(StencilSpec("box", 2, 1))
W_STAR = jacobi_weights(StencilSpec("star", 2, 1))
RNG = np.random.default_rng(3)
XS = [RNG.normal(size=GRID).astype(np.float32) for _ in range(6)]


def _ref(w, x, t=1):
    return np.asarray(stencil_direct_ref(jnp.asarray(x), w, t))


def _unbatched(w, x, t=1, **kw):
    """The serving contract's oracle: the UNBATCHED plan of the same
    signature (auto backend selection included) -- batching must not
    change a bit relative to what a direct stencil_plan caller gets."""
    return np.asarray(stencil_plan(w, x.shape, x.dtype, t, **kw)(x))


class TestEngineRoundTrip:
    def test_futures_resolve_bitwise_across_signatures(self):
        with StencilServer(max_batch=8, queue_timeout_ms=20) as server:
            futs = [(w, x, server.submit(w, x, t=2))
                    for x in XS for w in (W_BOX, W_STAR)]
            for w, x, fut in futs:
                got = fut.result(timeout=60)
                # responses are HOST arrays: one device->host transfer per
                # batch, not a device round-trip per .result()
                assert isinstance(got, np.ndarray)
                np.testing.assert_array_equal(got, _unbatched(w, x, t=2))
            snap = server.stats()
        assert snap["submitted"] == snap["responded"] == len(futs)
        assert snap["failed"] == 0
        assert snap["distinct_signatures"] == 2
        assert snap["batches"] >= 2           # one per signature at least
        assert 0.0 < snap["batch_occupancy"] <= 1.0
        assert snap["latency"]["count"] == len(futs)
        assert snap["latency"]["p99_ms"] >= snap["latency"]["p50_ms"] > 0

    def test_plan_sharing_across_batches(self):
        clear_plan_cache()
        with StencilServer(max_batch=4, buckets=(4,),
                           queue_timeout_ms=20) as server:
            for _ in range(3):                # three full buckets, one sig
                futs = [server.submit(W_BOX, x) for x in XS[:4]]
                for fut in futs:
                    fut.result(timeout=60)
            snap = server.stats()
        # one (signature, bucket) plan serves every batch
        assert snap["engine_plans"] == 1
        st = plan_cache_stats()
        assert st["misses"] >= 1 and st["build_failures"] == 0

    def test_shutdown_drains_never_drops(self):
        server = StencilServer(max_batch=64, queue_timeout_ms=500)
        futs = [server.submit(W_BOX, x) for x in XS]
        server.shutdown()                      # drains the lingering queue
        for x, fut in zip(XS, futs):
            np.testing.assert_array_equal(fut.result(timeout=10),
                                          _unbatched(W_BOX, x))
        with pytest.raises(RuntimeError, match="shut down"):
            server.submit(W_BOX, XS[0])


class TestEngineErrorPaths:
    def test_submit_validates_in_caller_thread(self):
        with StencilServer(queue_timeout_ms=0) as server:
            with pytest.raises(ValueError, match="fusion depth"):
                server.submit(W_BOX, XS[0], t=0)
            with pytest.raises(ValueError, match="rank"):
                server.submit(W_BOX, np.zeros((4, 4, 4), np.float32))
            for bad in ("batch", "batch_mode", "mesh", "shard_spec"):
                with pytest.raises(ValueError, match=bad):
                    server.submit(W_BOX, XS[0], **{bad: 2})
            snap = server.stats()
        # rejected requests never entered the queue
        assert snap["submitted"] == snap["failed"] == 0

    def test_constructor_validation(self):
        with pytest.raises(ValueError, match="max_batch"):
            StencilServer(max_batch=0)
        with pytest.raises(ValueError, match="buckets"):
            StencilServer(buckets=(0, 2))
        with pytest.raises(ValueError, match="queue_timeout_ms"):
            StencilServer(queue_timeout_ms=-1)

    def test_env_knobs_reach_constructor(self, monkeypatch):
        monkeypatch.setenv("REPRO_SERVE_MAX_BATCH", "5")
        monkeypatch.setenv("REPRO_SERVE_BUCKETS", "4,1")
        with StencilServer(queue_timeout_ms=0) as server:
            assert server.max_batch == 5
            assert server.buckets == (1, 4)
        monkeypatch.setenv("REPRO_SERVE_MAX_BATCH", "zero")
        with pytest.raises(ValueError, match="REPRO_SERVE_MAX_BATCH"):
            StencilServer(queue_timeout_ms=0)

    def test_unguarded_kernel_failure_fails_the_futures(self):
        events.clear()
        with faults.inject("compile", times=math.inf):
            with StencilServer(guard=False, queue_timeout_ms=20,
                               max_batch=4) as server:
                futs = [server.submit(W_BOX, x, backend="fused_direct")
                        for x in XS[:3]]
                for fut in futs:
                    with pytest.raises(RuntimeError, match="injected"):
                        fut.result(timeout=60)
                snap = server.stats()
        assert snap["failed"] == 3
        assert snap["responded"] == 0
        assert snap["submitted"] == 3          # dispatch-time accounting
        events.clear()

    def test_vmem_fault_degrades_batch_but_answers_everyone(self):
        """ISSUE-7 acceptance: a vmem fault during the batched build walks
        PR 6's ladder (same backend, degraded geometry), the batch
        executes degraded, and every request still gets the bitwise
        answer."""
        events.clear()
        clear_plan_cache()
        with faults.inject("vmem", times=1):
            with StencilServer(guard=True, queue_timeout_ms=100,
                               max_batch=6, buckets=(8,)) as server:
                # t=1: fused_direct is bitwise-identical to the direct
                # oracle there, so the assertion isolates the DEGRADED
                # GEOMETRY rung, not fused-weight accumulation order
                futs = [server.submit(W_BOX, x, backend="fused_direct")
                        for x in XS]
                results = [fut.result(timeout=120) for fut in futs]
        for x, got in zip(XS, results):
            np.testing.assert_array_equal(got, _ref(W_BOX, x))
        snap = server.stats()
        assert snap["degraded_batches"] >= 1
        assert snap["failed"] == 0
        assert snap["responded"] == len(XS)
        # the ladder recorded the move; a clean-run gate would catch it
        assert any(e["kind"] == "fallback" or "vmem" in str(e)
                   for e in events.events())
        events.clear()
        clear_plan_cache()


class TestPlanCacheThreadSafety:
    def test_concurrent_lookups_keep_counters_consistent(self):
        """Satellite (a): N threads hammer stencil_plan over a handful of
        signatures; afterwards hits + misses == lookups exactly -- no
        lost updates under the cache lock -- and the LRU stays bounded."""
        clear_plan_cache()
        sigs = [(W_BOX, 1), (W_BOX, 2), (W_STAR, 1), (W_STAR, 2)]
        n_threads, per_thread = 8, 40
        errors = []

        def worker(tid):
            try:
                for i in range(per_thread):
                    w, t = sigs[(tid + i) % len(sigs)]
                    p = stencil_plan(w, GRID, np.float32, t,
                                     backend="reference")
                    assert p.input_shape == GRID
            except Exception as exc:  # noqa: BLE001
                errors.append(exc)

        threads = [threading.Thread(target=worker, args=(k,))
                   for k in range(n_threads)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        assert not errors
        st = plan_cache_stats()
        lookups = n_threads * per_thread
        assert st["hits"] + st["misses"] == lookups
        # every signature missed at least once; racing builders may each
        # count a miss for the same signature, so misses can exceed 4 but
        # the cache holds exactly the distinct signatures
        assert st["misses"] >= len(sigs)
        assert st["size"] == len(sigs)
        clear_plan_cache()


class TestEventLogStress:
    def test_threaded_no_lost_updates(self):
        """Satellite (b): 8 writers x 500 events into a 64-slot ring.
        Every record lands or is counted dropped -- recorded == total,
        dropped == total - capacity, retained seqs unique."""
        log = EventLog(capacity=64)
        n_threads, per_thread = 8, 500

        def writer(tid):
            for i in range(per_thread):
                log.record("stress", tid=tid, i=i)

        threads = [threading.Thread(target=writer, args=(k,))
                   for k in range(n_threads)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        snap = log.snapshot()
        total = n_threads * per_thread
        assert snap["recorded"] == total
        assert snap["dropped"] == total - 64
        assert len(snap["events"]) == len(log) == 64
        seqs = [e["seq"] for e in snap["events"]]
        assert len(set(seqs)) == 64
        assert max(seqs) == total - 1          # the newest event survived

    def test_capacity_validated(self):
        with pytest.raises(ValueError, match=">= 1"):
            EventLog(capacity=0)


class TestLatencyMetrics:
    def test_histogram_percentiles_bounded_by_observations(self):
        h = LatencyHistogram()
        lat = [i * 1e-4 for i in range(1, 101)]   # 0.1 .. 10 ms
        for s in lat:
            h.record(s)
        snap = h.snapshot()
        assert snap["count"] == 100
        assert snap["min_ms"] <= snap["p50_ms"] <= snap["p99_ms"] \
            <= snap["max_ms"]
        # log2 buckets bound the error to one bucket width (2x)
        assert snap["p50_ms"] == pytest.approx(5.0, rel=1.0)
        assert snap["mean_ms"] == pytest.approx(5.05, rel=1e-6)

    def test_histogram_rejects_negative_and_empty_is_zero(self):
        h = LatencyHistogram()
        with pytest.raises(ValueError, match=">= 0"):
            h.record(-1e-6)
        assert h.snapshot()["p99_ms"] == 0.0
        with pytest.raises(ValueError, match="quantile"):
            h.percentile(1.5)

    def test_serve_metrics_batch_accounting(self):
        m = ServeMetrics()
        m.record_submits(("sig",), 3, first_submit_s=100.0)
        m.record_batch(3, 4)
        m.record_responses([0.001, 0.002, 0.003])
        snap = m.snapshot()
        assert snap["submitted"] == snap["responded"] == 3
        assert snap["batches"] == 1 and snap["padded_slots"] == 1
        assert snap["batch_occupancy"] == 0.75
        assert snap["latency"]["count"] == 3
        m.reset()
        assert m.snapshot()["submitted"] == 0
