"""Stencil domain: specs, weights, fusion composition, references."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.stencil import (StencilSpec, box, star, make_weights,
                           jacobi_weights, fuse_weights, fused_num_points)
from repro.stencil.reference import (apply_stencil, apply_stencil_steps,
                                     apply_stencil_conv)


class TestSpec:
    def test_num_points(self):
        assert box(2, 1).num_points == 9
        assert box(2, 7).num_points == 225
        assert box(3, 1).num_points == 27
        assert star(2, 1).num_points == 5
        assert star(3, 2).num_points == 13

    def test_names(self):
        assert box(2, 1).name == "Box-2D1R"
        assert StencilSpec.from_name("Star-3D2R") == star(3, 2)

    def test_support_mask(self):
        m = star(2, 1).support_mask()
        assert m.sum() == 5 and m[1, 1] and m[0, 1] and not m[0, 0]

    def test_validation(self):
        with pytest.raises(ValueError):
            StencilSpec("hex", 2, 1)
        with pytest.raises(ValueError):
            StencilSpec("box", 0, 1)
        with pytest.raises(ValueError):
            StencilSpec("box", 2, 0)

    def test_intensity(self):
        assert box(2, 1).arithmetic_intensity(4) == 9 / 4


class TestWeights:
    def test_star_weights_masked(self):
        w = make_weights(star(2, 2), seed=0)
        assert np.count_nonzero(w) == 9
        assert w.sum() == pytest.approx(1.0, abs=1e-5)

    def test_fused_radius(self):
        w = make_weights(box(2, 1), seed=0)
        assert fuse_weights(w, 3).shape == (7, 7)

    @given(shape=st.sampled_from(["box", "star"]), d=st.integers(1, 2),
           r=st.integers(1, 2), t=st.integers(1, 4))
    @settings(max_examples=30, deadline=None)
    def test_fused_application_equals_sequential(self, shape, d, r, t):
        """Core linearity property behind the paper's kernel fusion."""
        spec = StencilSpec(shape, d, r)
        w = make_weights(spec, seed=1, dtype=np.float64)
        n = 32
        x = jnp.asarray(np.random.default_rng(0).normal(size=(n,) * d))
        seq = apply_stencil_steps(x, jnp.asarray(w), t)
        fused = apply_stencil(x, jnp.asarray(fuse_weights(w, t)))
        # jax computes in f32 (x64 disabled): tolerance is f32-scale
        np.testing.assert_allclose(np.asarray(seq), np.asarray(fused),
                                   rtol=2e-5, atol=2e-5)

    def test_fused_num_points_matches_support(self):
        for spec in (box(2, 1), star(2, 1), star(3, 1), box(3, 1)):
            for t in (1, 2, 3):
                w = jacobi_weights(spec, np.float64)
                assert fused_num_points(spec, t) == \
                    np.count_nonzero(fuse_weights(w, t))


class TestReference:
    @pytest.mark.parametrize("boundary", ["periodic", "zero"])
    @pytest.mark.parametrize("d", [1, 2, 3])
    def test_conv_oracle_agrees(self, boundary, d):
        spec = StencilSpec("box", d, 1)
        w = make_weights(spec, seed=2)
        n = 16
        x = jnp.asarray(np.random.default_rng(1).normal(size=(n,) * d)
                        .astype(np.float32))
        a = apply_stencil(x, jnp.asarray(w), boundary)
        b = apply_stencil_conv(x, jnp.asarray(w), boundary)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("shape", ["box", "star"])
    @pytest.mark.parametrize("d", [1, 3])
    def test_roll_vs_conv_cross_check_f32(self, d, shape):
        """The satellite's 1D/3D oracle cross-check: the roll-based and
        conv-based references agree through the single N-D path (no more
        per-rank special cases in _offsets / apply_stencil_conv)."""
        spec = StencilSpec(shape, d, 2)
        w = make_weights(spec, seed=3)
        x = jnp.asarray(np.random.default_rng(4).normal(size=(12,) * d)
                        .astype(np.float32))
        for boundary in ("periodic", "zero"):
            a = apply_stencil(x, jnp.asarray(w), boundary)
            b = apply_stencil_conv(x, jnp.asarray(w), boundary)
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("shape", ["box", "star"])
    @pytest.mark.parametrize("d", [1, 3])
    def test_roll_vs_conv_cross_check_f64(self, d, shape):
        """Same cross-check at f64: tolerances tighten by ~8 orders of
        magnitude, catching any dtype-dependent path divergence."""
        from jax.experimental import enable_x64

        with enable_x64():
            spec = StencilSpec(shape, d, 1)
            w = make_weights(spec, seed=5, dtype=np.float64)
            x = jnp.asarray(np.random.default_rng(6).normal(size=(10,) * d))
            assert x.dtype == jnp.float64
            for boundary in ("periodic", "zero"):
                a = apply_stencil(x, jnp.asarray(w), boundary)
                b = apply_stencil_conv(x, jnp.asarray(w), boundary)
                assert a.dtype == jnp.float64
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-13, atol=1e-13)

    def test_rank_mismatch_raises(self):
        w = make_weights(StencilSpec("box", 2, 1), seed=0)
        x = jnp.zeros((8, 8, 8), np.float32)
        with pytest.raises(ValueError, match="rank"):
            apply_stencil(x, jnp.asarray(w))
        with pytest.raises(ValueError, match="rank"):
            apply_stencil_conv(x, jnp.asarray(w))

    def test_jacobi_converges_to_mean(self):
        # repeated Jacobi smoothing with periodic BC converges to the mean
        spec = box(2, 1)
        w = jacobi_weights(spec)
        x = jnp.asarray(np.random.default_rng(2).normal(size=(16, 16))
                        .astype(np.float32))
        y = apply_stencil_steps(x, jnp.asarray(w), 200)
        np.testing.assert_allclose(np.asarray(y), float(x.mean()), atol=1e-3)
