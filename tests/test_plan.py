"""Plan API: compile-once plans, backend registry, cache keying/counters,
and the single-decision-path guarantee (``explain`` vs ``auto``)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import perfmodel as pm
from repro.core import selector
from repro.kernels import (BACKENDS, clear_plan_cache, explain, get_backend,
                           plan_cache_stats, register_backend,
                           registered_backends, stencil_apply, stencil_plan,
                           unregister_backend)
from repro.kernels.ref import stencil_direct_ref
from repro.stencil import StencilSpec, jacobi_weights, make_weights

RNG = np.random.default_rng(0)


def _x(h, w, dtype=np.float32):
    return jnp.asarray(RNG.normal(size=(h, w)).astype(dtype))


def _hms(stats=None):
    """The cache-churn core of plan_cache_stats(): hits/misses/size only
    (the guard counters -- build/exec failures, fallbacks, negative hits --
    have their own tests in test_guard.py and stay zero on clean runs)."""
    s = plan_cache_stats() if stats is None else stats
    return {k: s[k] for k in ("hits", "misses", "size")}


class TestPlanExecution:
    @pytest.mark.parametrize("backend", [b for b in BACKENDS if b != "auto"])
    def test_every_registered_backend_executes(self, backend):
        """plan(x) runs all five regimes + reference + legacy via the
        registry and matches the oracle."""
        w = make_weights(StencilSpec("box", 2, 1), seed=1)
        x = _x(64, 64)
        t = 3
        plan = stencil_plan(w, x.shape, x.dtype, t, backend=backend,
                            tile_m=32, tile_n=32)
        ref = stencil_direct_ref(x, w, t)
        np.testing.assert_allclose(np.asarray(plan(x)), np.asarray(ref),
                                   atol=1e-4)

    @pytest.mark.parametrize("backend", [b for b in BACKENDS if b != "auto"])
    def test_wrapper_parity_bitwise(self, backend):
        """stencil_apply == direct plan execution, bit-for-bit in f32."""
        w = make_weights(StencilSpec("star", 2, 2), seed=2)
        x = _x(64, 64)
        t = 2
        plan = stencil_plan(w, x.shape, x.dtype, t, backend=backend,
                            tile_m=32, tile_n=32)
        via_wrapper = stencil_apply(x, w, t=t, backend=backend,
                                    tile_m=32, tile_n=32)
        assert np.array_equal(np.asarray(plan(x)), np.asarray(via_wrapper))

    def test_step_and_run(self):
        w = make_weights(StencilSpec("box", 2, 1), seed=0)
        x = _x(32, 32)
        plan = stencil_plan(w, x.shape, x.dtype, 2, tile_m=16, tile_n=16)
        np.testing.assert_array_equal(np.asarray(plan.step(x)),
                                      np.asarray(plan(x)))
        two = plan(plan(x))
        np.testing.assert_array_equal(np.asarray(plan.run(x, 2)),
                                      np.asarray(two))
        np.testing.assert_array_equal(np.asarray(plan.run(x, 0)),
                                      np.asarray(x))

    def test_spec_input_uses_jacobi_weights(self):
        spec = StencilSpec("box", 2, 1)
        x = _x(32, 32)
        plan = stencil_plan(spec, x.shape, x.dtype, 1, tile_m=16, tile_n=16)
        ref = stencil_direct_ref(x, jacobi_weights(spec), 1)
        np.testing.assert_allclose(np.asarray(plan(x)), np.asarray(ref),
                                   atol=2e-5)

    def test_geometry_mismatch_raises(self):
        w = make_weights(StencilSpec("box", 2, 1), seed=0)
        plan = stencil_plan(w, (32, 32), np.float32, 1, tile_m=16, tile_n=16)
        with pytest.raises(ValueError, match="grid"):
            plan(_x(64, 64))

    def test_bad_depth_and_missing_shard_spec(self):
        w = make_weights(StencilSpec("box", 2, 1), seed=0)
        with pytest.raises(ValueError, match="fusion depth"):
            stencil_plan(w, (32, 32), np.float32, 0)
        with pytest.raises(ValueError, match="shard_spec"):
            stencil_plan(w, (32, 32), np.float32, 1, mesh=object())

    def test_explain_mentions_override(self):
        w = make_weights(StencilSpec("box", 2, 1), seed=0)
        plan = stencil_plan(w, (32, 32), np.float32, 4, backend="reference",
                            tile_m=16, tile_n=16)
        assert plan.backend == "reference"
        assert plan.decision.backend != "reference"   # oracle is unpriced
        assert "override" in plan.explain()
        assert plan.build_time_s >= 0.0


class TestPlanCache:
    def test_hit_miss_counters_and_keying(self):
        """Distinct dtype/t/hw/backend/tiling signatures get distinct plans;
        identical signatures hit."""
        clear_plan_cache()
        w = make_weights(StencilSpec("box", 2, 1), seed=5)
        base = dict(tile_m=16, tile_n=16)

        p1 = stencil_plan(w, (32, 32), np.float32, 2, **base)
        assert _hms() == {"hits": 0, "misses": 1, "size": 1}

        assert stencil_plan(w, (32, 32), np.float32, 2, **base) is p1
        assert plan_cache_stats()["hits"] == 1

        variants = [
            stencil_plan(w, (32, 32), jnp.bfloat16, 2, **base),     # dtype
            stencil_plan(w, (32, 32), np.float32, 3, **base),       # t
            stencil_plan(w, (32, 32), np.float32, 2,                # hw
                         hw=pm.A100_FLOAT, **base),
            stencil_plan(w, (32, 32), np.float32, 2,                # override
                         backend="reference", **base),
            stencil_plan(w, (32, 32), np.float32, 2,                # tiling
                         tile_m=32, tile_n=16),
            stencil_plan(w, (64, 32), np.float32, 2, **base),       # grid
        ]
        assert len({id(p) for p in variants + [p1]}) == len(variants) + 1
        stats = plan_cache_stats()
        assert stats["misses"] == 1 + len(variants)
        assert stats["size"] == 1 + len(variants)

    def test_distinct_weights_do_not_collide(self):
        """Same spec, different tap values => different plans (the cache
        keys on the weight content digest, not just the inferred spec)."""
        clear_plan_cache()
        wa = make_weights(StencilSpec("box", 2, 1), seed=1)
        wb = make_weights(StencilSpec("box", 2, 1), seed=2)
        x = _x(32, 32)
        pa = stencil_plan(wa, x.shape, x.dtype, 1, tile_m=16, tile_n=16)
        pb = stencil_plan(wb, x.shape, x.dtype, 1, tile_m=16, tile_n=16)
        assert pa is not pb
        np.testing.assert_allclose(np.asarray(pa(x)),
                                   np.asarray(stencil_direct_ref(x, wa, 1)),
                                   atol=2e-5)
        np.testing.assert_allclose(np.asarray(pb(x)),
                                   np.asarray(stencil_direct_ref(x, wb, 1)),
                                   atol=2e-5)

    def test_wrapper_reuses_plan_without_reselection(self):
        """Repeated stencil_apply with an identical signature: cache hits,
        and select_backend is NOT invoked again (the acceptance criterion)."""
        clear_plan_cache()
        w = make_weights(StencilSpec("box", 2, 2), seed=7)
        x = _x(32, 32)
        y1 = stencil_apply(x, w, t=2, backend="auto", tile_m=16, tile_n=16)
        after_first = selector.invocation_count()
        s1 = plan_cache_stats()
        for _ in range(3):
            y = stencil_apply(x, w, t=2, backend="auto", tile_m=16, tile_n=16)
            np.testing.assert_array_equal(np.asarray(y), np.asarray(y1))
        s2 = plan_cache_stats()
        assert selector.invocation_count() == after_first
        assert s2["hits"] == s1["hits"] + 3
        assert s2["misses"] == s1["misses"]

    def test_use_cache_false_bypasses(self):
        clear_plan_cache()
        w = make_weights(StencilSpec("box", 2, 1), seed=0)
        p1 = stencil_plan(w, (32, 32), np.float32, 1, tile_m=16, tile_n=16,
                          use_cache=False)
        p2 = stencil_plan(w, (32, 32), np.float32, 1, tile_m=16, tile_n=16,
                          use_cache=False)
        assert p1 is not p2
        assert plan_cache_stats()["size"] == 0

    def test_lru_eviction_env_bound_keeps_stats_consistent(self, monkeypatch):
        """REPRO_PLAN_CACHE_SIZE bounds the LRU; overflow evicts the
        least-recently-used plan, and the hit/miss counters stay consistent
        across eviction (an evicted signature re-misses; a surviving one
        still hits)."""
        from repro.kernels import plan_cache_max

        clear_plan_cache()
        monkeypatch.setenv("REPRO_PLAN_CACHE_SIZE", "2")
        assert plan_cache_max() == 2
        w = make_weights(StencilSpec("box", 2, 1), seed=9)
        base = dict(tile_m=16, tile_n=16)

        p1 = stencil_plan(w, (32, 32), np.float32, 1, **base)
        p2 = stencil_plan(w, (32, 32), np.float32, 2, **base)
        assert _hms() == {"hits": 0, "misses": 2, "size": 2}

        stencil_plan(w, (32, 32), np.float32, 3, **base)   # evicts t=1
        assert _hms() == {"hits": 0, "misses": 3, "size": 2}

        # surviving signature: hit, no rebuild
        assert stencil_plan(w, (32, 32), np.float32, 2, **base) is p2
        assert _hms() == {"hits": 1, "misses": 3, "size": 2}

        # evicted signature: full re-miss (fresh plan object)
        p1b = stencil_plan(w, (32, 32), np.float32, 1, **base)
        assert p1b is not p1
        s = plan_cache_stats()
        assert _hms(s) == {"hits": 1, "misses": 4, "size": 2}
        assert s["size"] <= plan_cache_max()

        monkeypatch.setenv("REPRO_PLAN_CACHE_SIZE", "zero")
        with pytest.raises(ValueError, match="integer"):
            plan_cache_max()
        # a malformed bound surfaces BEFORE the cache is touched: nothing
        # is inserted, so eviction can never be silently disabled
        size_before = plan_cache_stats()["size"]
        with pytest.raises(ValueError, match="integer"):
            stencil_plan(w, (32, 32), np.float32, 4, **base)
        assert plan_cache_stats()["size"] == size_before
        monkeypatch.setenv("REPRO_PLAN_CACHE_SIZE", "0")
        with pytest.raises(ValueError, match=">= 1"):
            plan_cache_max()
        monkeypatch.delenv("REPRO_PLAN_CACHE_SIZE")
        from repro.kernels import plan as plan_mod
        assert plan_cache_max() == plan_mod.PLAN_CACHE_MAX
        clear_plan_cache()


class TestSingleDecisionPath:
    """ops.explain and the auto branch can never disagree: both ARE
    plan.decision (regression for the pre-plan duplicated logic)."""

    @pytest.mark.parametrize("shape", ["box", "star"])
    @pytest.mark.parametrize("r", [1, 2])
    @pytest.mark.parametrize("t", [1, 2, 4, 8])
    def test_explain_equals_plan_decision(self, shape, r, t):
        w = make_weights(StencilSpec(shape, 2, r), seed=r)
        plan = stencil_plan(w, (128, 128), np.float32, t)
        d = explain(w, t, dtype_bytes=4, hw=plan.hw)
        assert d == plan.decision
        assert plan.backend == plan.decision.backend   # no override => same

    @pytest.mark.parametrize("grid", [(64, 64), (192, 160)])
    def test_explain_with_grid_matches_plan_on_that_grid(self, grid):
        """Plans price the geometry resolved for THEIR grid; explain agrees
        whenever it is told the grid (the parity contract off 128-row
        grids, where the pricing defaults no longer coincide)."""
        w = make_weights(StencilSpec("box", 2, 1), seed=3)
        for t in (1, 2, 4):
            plan = stencil_plan(w, grid, np.float32, t)
            d = explain(w, t, dtype_bytes=4, hw=plan.hw, grid_shape=grid)
            assert d == plan.decision

    def test_explain_with_grid_threads_pins(self):
        """Explicit tile_m/h_block pins resolve identically in explain and
        stencil_plan -- including h_block=0 (whole-strip pricing)."""
        w = make_weights(StencilSpec("box", 2, 1), seed=3)
        grid = (64, 64)
        for pins in ({"h_block": 0}, {"tile_m": 16},
                     {"tile_m": 32, "h_block": 8}):
            plan = stencil_plan(w, grid, np.float32, 2, **pins)
            d = explain(w, 2, dtype_bytes=4, hw=plan.hw, grid_shape=grid,
                        **pins)
            assert d == plan.decision

    def test_decision_candidates_are_priced_registry_subset(self):
        w = make_weights(StencilSpec("box", 2, 1), seed=0)
        d = explain(w, 4, 4)
        assert set(d.candidates) <= set(registered_backends())
        # unpriced backends never show up as candidates
        assert "reference" not in d.candidates
        assert "legacy_direct" not in d.candidates


class TestPlan3D:
    """The N-D tentpole's plan-layer acceptance: 3D plans build and run
    every halo-plane regime for the paper's Box/Star-3D workloads, and the
    decision path stays single (explain == plan.decision on grids whose
    resolved geometry differs from the pricing defaults)."""

    def _x3(self, z, h, w):
        return jnp.asarray(
            RNG.normal(size=(z, h, w)).astype(np.float32))

    @pytest.mark.parametrize("name", ["Box-3D1R", "Star-3D1R"])
    def test_all_registered_regimes_run_3d(self, name):
        spec = StencilSpec.from_name(name)
        w = make_weights(spec, seed=1)
        x = self._x3(12, 24, 32)
        t = 2
        ref = stencil_direct_ref(x, w, t)
        for backend in registered_backends():
            if backend.startswith("legacy_"):
                # the seed 9-tile foil is 2D-only by contract
                with pytest.raises(ValueError, match="2D"):
                    stencil_plan(w, x.shape, x.dtype, t, backend=backend,
                                 use_cache=False)
                continue
            plan = stencil_plan(w, x.shape, x.dtype, t, backend=backend)
            np.testing.assert_allclose(np.asarray(plan(x)), np.asarray(ref),
                                       atol=2e-4)

    def test_wrapper_parity_bitwise_3d(self):
        w = make_weights(StencilSpec("box", 3, 1), seed=2)
        x = self._x3(12, 24, 32)
        plan = stencil_plan(w, x.shape, x.dtype, 2, tile_m=12, z_slab=6)
        via_wrapper = stencil_apply(x, w, t=2, tile_m=12, z_slab=6)
        assert np.array_equal(np.asarray(plan(x)), np.asarray(via_wrapper))

    @pytest.mark.parametrize("grid", [(12, 24, 32), (10, 36, 40)])
    def test_explain_parity_on_non128_3d_grids(self, grid):
        """The satellite's parity contract: explain(grid_shape=...) equals
        plan.decision -- including the substrate read-amp factor and the
        resolved (z_slab, strip_m, h) geometry in the reason string -- on
        3D grids where no axis is 128-divisible."""
        w = make_weights(StencilSpec("box", 3, 1), seed=3)
        for t in (1, 2):
            plan = stencil_plan(w, grid, np.float32, t)
            d = explain(w, t, dtype_bytes=4, hw=plan.hw, grid_shape=grid)
            assert d == plan.decision
            assert "read_amp=" in d.reason
            assert "z_slab=" in d.reason and "strip_m=" in d.reason
        # pins thread identically, including the whole-slab foil
        for pins in ({"h_block": 0}, {"tile_m": 12, "z_slab": 6},
                     {"tile_m": 12, "h_block": 2, "z_slab": 6,
                      "z_block": 2}):
            plan = stencil_plan(w, (12, 24, 32), np.float32, 2, **pins)
            d = explain(w, 2, dtype_bytes=4, hw=plan.hw,
                        grid_shape=(12, 24, 32), **pins)
            assert d == plan.decision

    def test_explain_geometry_note_2d(self):
        """2D reasons carry the substrate note too (the satellite asks for
        both ranks)."""
        w = make_weights(StencilSpec("box", 2, 1), seed=0)
        d = explain(w, 2, 4, grid_shape=(64, 64))
        assert "read_amp=" in d.reason and "strip_m=" in d.reason

    def test_plan_1d_lift(self):
        """1D grids route through the lifted 2D substrate instead of
        crashing -- every priced regime runs and matches the oracle."""
        w = make_weights(StencilSpec("box", 1, 2), seed=4)
        x = jnp.asarray(RNG.normal(size=(96,)).astype(np.float32))
        ref = stencil_direct_ref(x, w, 3)
        for backend in ("direct", "fused_direct", "matmul", "fused_matmul",
                        "fused_matmul_reuse", "reference"):
            plan = stencil_plan(w, x.shape, x.dtype, 3, backend=backend)
            np.testing.assert_allclose(np.asarray(plan(x)), np.asarray(ref),
                                       atol=2e-5)
        d = explain(w, 3, 4, grid_shape=x.shape)
        assert d == stencil_plan(w, x.shape, x.dtype, 3).decision
        assert "1D lifted" in d.reason

    def test_rank_mismatch_raises(self):
        w = make_weights(StencilSpec("box", 2, 1), seed=0)
        with pytest.raises(ValueError, match="rank"):
            stencil_plan(w, (12, 24, 32), np.float32, 1)

    def test_hybrid_substrate_rejected_everywhere(self):
        """z_block=0 under a sub-blocked h_block names a substrate no
        kernel implements: the selector must refuse to price it exactly
        like resolve_substrate_geom refuses to build it (single-decision-
        path contract, with or without a grid)."""
        w = make_weights(StencilSpec("box", 3, 1), seed=0)
        with pytest.raises(ValueError, match="whole-slab"):
            explain(w, 2, 4, h_block=4, z_block=0)
        with pytest.raises(ValueError, match="whole-slab"):
            explain(w, 2, 4, grid_shape=(12, 24, 32), tile_m=12,
                    z_slab=6, h_block=2, z_block=0)
        with pytest.raises(ValueError, match="whole-slab"):
            stencil_plan(w, (12, 24, 32), np.float32, 2, tile_m=12,
                         z_slab=6, h_block=2, z_block=0)


class TestPlanColumnTiled:
    """The column-tiled W substrate at the plan layer (DESIGN.md §10):
    explain == plan.decision parity on awkward widths (the satellite's W
    in {257, 300, 1000} sweep, 2D and 3D), w_tile in the reason string
    and the cache key, and the budget-driven auto escalation."""

    @pytest.mark.parametrize("wid", [257, 300, 1000])
    def test_explain_parity_awkward_widths_2d(self, wid):
        w = make_weights(StencilSpec("box", 2, 1), seed=3)
        grid = (48, wid)
        for t in (1, 2):
            plan = stencil_plan(w, grid, np.float32, t)
            d = explain(w, t, dtype_bytes=4, hw=plan.hw, grid_shape=grid)
            assert d == plan.decision
            assert "w_tile=" in d.reason
        # pins thread identically, including explicit column tiles
        for pins in ({"w_tile": 0}, {"tile_m": 24, "h_block": 12,
                                     "w_tile": 64},
                     {"tile_m": 24, "h_block": 12, "w_tile": 64,
                      "w_block": 8}):
            plan = stencil_plan(w, grid, np.float32, 2, **pins)
            d = explain(w, 2, dtype_bytes=4, hw=plan.hw, grid_shape=grid,
                        **pins)
            assert d == plan.decision

    @pytest.mark.parametrize("wid", [257, 300, 1000])
    def test_explain_parity_awkward_widths_3d(self, wid):
        w = make_weights(StencilSpec("box", 3, 1), seed=3)
        grid = (12, 24, wid)
        for t in (1, 2):
            plan = stencil_plan(w, grid, np.float32, t)
            d = explain(w, t, dtype_bytes=4, hw=plan.hw, grid_shape=grid)
            assert d == plan.decision
            assert "w_tile=" in d.reason
        pins = {"tile_m": 12, "z_slab": 6, "h_block": 2, "z_block": 2,
                "w_tile": 32}
        plan = stencil_plan(w, grid, np.float32, 2, **pins)
        d = explain(w, 2, dtype_bytes=4, hw=plan.hw, grid_shape=grid, **pins)
        assert d == plan.decision
        assert "w_tile=32" in d.reason

    def test_fullwidth_reason_reports_w_tile(self):
        """Every 2D/3D reason now reports the resolved width policy --
        'w_tile=full' on the fast path."""
        w = make_weights(StencilSpec("box", 2, 1), seed=0)
        d = explain(w, 2, 4, grid_shape=(64, 64))
        assert "w_tile=full" in d.reason

    def test_awkward_width_plans_execute(self):
        """Plans on awkward widths execute through the remainder path and
        match the oracle -- with an explicit column tile AND fully auto."""
        w = make_weights(StencilSpec("star", 2, 1), seed=2)
        x = _x(48, 257)
        ref = stencil_direct_ref(x, w, 2)
        for pins in ({}, {"tile_m": 24, "h_block": 12, "w_tile": 64}):
            plan = stencil_plan(w, x.shape, x.dtype, 2, **pins)
            np.testing.assert_allclose(np.asarray(plan(x)), np.asarray(ref),
                                       atol=1e-4)

    def test_cache_keys_on_w_tile_and_budget(self, monkeypatch):
        """Distinct w_tile pins get distinct plans; retuning
        REPRO_VMEM_BUDGET invalidates (the auto geometry depends on it)."""
        from repro.kernels import clear_plan_cache, plan_cache_stats

        clear_plan_cache()
        w = make_weights(StencilSpec("box", 2, 1), seed=5)
        base = dict(tile_m=24, h_block=12)
        p1 = stencil_plan(w, (48, 256), np.float32, 1, **base)
        p2 = stencil_plan(w, (48, 256), np.float32, 1, w_tile=64, **base)
        p3 = stencil_plan(w, (48, 256), np.float32, 1, w_tile=64,
                          w_block=16, **base)
        assert len({id(p) for p in (p1, p2, p3)}) == 3
        assert stencil_plan(w, (48, 256), np.float32, 1, w_tile=64,
                            **base) is p2
        monkeypatch.setenv("REPRO_VMEM_BUDGET", "65536")
        p4 = stencil_plan(w, (48, 256), np.float32, 1, w_tile=64, **base)
        assert p4 is not p2                    # budget is part of the key
        monkeypatch.setenv("REPRO_VMEM_BUDGET", "not-a-number")
        with pytest.raises(ValueError, match="integer"):
            stencil_plan(w, (48, 256), np.float32, 1, **base)
        monkeypatch.delenv("REPRO_VMEM_BUDGET")
        clear_plan_cache()

    def test_budget_driven_auto_column_tiles_through_plan(self, monkeypatch):
        """Under a tiny budget the fully-auto plan column-tiles: the
        decision reason reports a positive w_tile and execution matches
        the oracle bit-for-bit (box VPU path, t=1)."""
        monkeypatch.setenv("REPRO_VMEM_BUDGET", "16384")
        w = make_weights(StencilSpec("box", 2, 1), seed=7)
        x = _x(32, 1024)
        plan = stencil_plan(w, x.shape, x.dtype, 1, backend="direct",
                            use_cache=False)
        assert "w_tile=" in plan.decision.reason
        assert "w_tile=full" not in plan.decision.reason
        ref = stencil_direct_ref(x, w, 1)
        np.testing.assert_array_equal(np.asarray(plan(x)), np.asarray(ref))


class TestRegistry:
    def test_unknown_backend_raises(self):
        w = make_weights(StencilSpec("box", 2, 1), seed=0)
        with pytest.raises(ValueError, match="unknown backend"):
            stencil_apply(_x(32, 32), w, backend="gpu")
        with pytest.raises(ValueError):
            get_backend("gpu")

    def test_duplicate_and_auto_registration_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            register_backend("direct", lambda ctx: None)
        with pytest.raises(ValueError, match="auto"):
            register_backend("auto", lambda ctx: None)

    def test_custom_backend_is_additive(self):
        """A plug-in backend (e.g. a future sparse unit) becomes dispatchable
        through stencil_apply just by registering."""
        name = "test_scaled_reference"

        def build(ctx):
            from repro.kernels import ref
            w, t = ctx.weights, ctx.t

            def run(x):
                return ref.stencil_direct_ref(x, w, t)
            return run

        register_backend(name, build, description="test-only")
        try:
            assert name in registered_backends()
            # BACKENDS is computed on access: plug-ins show up immediately
            import repro.kernels as K
            assert name in K.BACKENDS
            w = make_weights(StencilSpec("box", 2, 1), seed=0)
            x = _x(32, 32)
            y = stencil_apply(x, w, t=2, backend=name)
            np.testing.assert_allclose(np.asarray(y),
                                       np.asarray(stencil_direct_ref(x, w, 2)),
                                       atol=2e-5)
            # unpriced: never appears in selection candidates
            assert name not in explain(w, 2, 4).candidates
        finally:
            unregister_backend(name)
        import repro.kernels as K
        assert name not in K.BACKENDS

    def test_priced_plugin_participates_in_selection(self):
        """Registering a priced backend makes the selector consider it --
        and invalidates previously cached 'auto' plans, so what executes
        can never disagree with what explain() reports."""
        name = "test_always_wins"
        w = make_weights(StencilSpec("box", 2, 1), seed=0)
        x = _x(32, 32)
        stale = stencil_plan(w, x.shape, x.dtype, 3)     # cached pre-plugin

        def build(ctx):
            from repro.kernels import ref
            wts, t = ctx.weights, ctx.t
            return lambda x: ref.stencil_direct_ref(x, wts, t)

        register_backend(name, build, price=lambda p: float("inf"))
        try:
            d = explain(w, 3, 4)
            assert d.backend == name
            assert name in d.reason      # plug-ins get a plug-in reason
            plan = stencil_plan(w, x.shape, x.dtype, 3)  # NOT the stale plan
            assert plan is not stale
            assert plan.backend == name
            np.testing.assert_allclose(
                np.asarray(plan(x)),
                np.asarray(stencil_direct_ref(x, w, 3)), atol=2e-5)
        finally:
            unregister_backend(name)
        # after teardown a fresh build re-selects among the built-ins again
        plan = stencil_plan(w, x.shape, x.dtype, 3)
        assert plan.backend in registered_backends()
        assert plan.backend != name
