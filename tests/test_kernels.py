"""Pallas kernels vs pure-jnp oracles: shape/dtype/backend sweeps
(interpret mode on CPU; compiles through Mosaic on a real TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.perfmodel import sparsity_banded
from repro.kernels import (stencil_apply, stencil_direct, stencil_matmul,
                           band_sparsity, explain)
from repro.kernels.ref import stencil_direct_ref, stencil_matmul_ref
from repro.stencil import StencilSpec, make_weights, fuse_weights

RNG = np.random.default_rng(0)


def _x(h, w, dtype=np.float32):
    return jnp.asarray(RNG.normal(size=(h, w)).astype(dtype))


TOL = {"float32": 2e-5, "bfloat16": 3e-2}


class TestDirectKernel:
    @pytest.mark.parametrize("shape", ["box", "star"])
    @pytest.mark.parametrize("r", [1, 2, 3])
    def test_matches_oracle(self, shape, r):
        spec = StencilSpec(shape, 2, r)
        w = make_weights(spec, seed=r)
        x = _x(64, 128)
        y = stencil_direct(x, w, interpret=True, tile_m=32, tile_n=64)
        ref = stencil_direct_ref(x, w, 1)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=2e-5)

    @pytest.mark.parametrize("hw", [(32, 32), (64, 96), (128, 256)])
    def test_shape_sweep(self, hw):
        spec = StencilSpec("box", 2, 1)
        w = make_weights(spec, seed=0)
        x = _x(*hw)
        y = stencil_direct(x, w, interpret=True, tile_m=32, tile_n=32)
        ref = stencil_direct_ref(x, w, 1)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=2e-5)

    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    def test_dtypes(self, dtype):
        spec = StencilSpec("box", 2, 1)
        w = make_weights(spec, seed=0)
        x = _x(64, 64, np.dtype(jnp.bfloat16 if dtype == "bfloat16"
                                else jnp.float32))
        x = x.astype(dtype)
        y = stencil_direct(x, w, interpret=True, tile_m=32, tile_n=32)
        ref = stencil_direct_ref(x.astype(jnp.float32), w, 1)
        np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(ref),
                                   atol=TOL[dtype])

    def test_fused_t_steps(self):
        spec = StencilSpec("box", 2, 1)
        w = make_weights(spec, seed=0)
        x = _x(64, 64)
        y = stencil_direct(x, w, t=3, interpret=True, tile_m=32, tile_n=32)
        ref = stencil_direct_ref(x, w, 3)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4)

    def test_halo_exceeds_tile_raises(self):
        spec = StencilSpec("box", 2, 3)
        w = make_weights(spec, seed=0)
        with pytest.raises(ValueError, match="halo"):
            stencil_direct(_x(64, 64), w, t=6, tile_m=16, tile_n=16,
                           interpret=True)

    def test_non_divisible_raises(self):
        w = make_weights(StencilSpec("box", 2, 1), seed=0)
        with pytest.raises(ValueError, match="divisible"):
            stencil_direct(_x(60, 64), w, tile_m=32, tile_n=32, interpret=True)


class TestMatmulKernel:
    @pytest.mark.parametrize("shape", ["box", "star"])
    @pytest.mark.parametrize("r", [1, 2])
    def test_matches_oracle(self, shape, r):
        spec = StencilSpec(shape, 2, r)
        w = make_weights(spec, seed=r)
        x = _x(64, 128)
        y = stencil_matmul(x, w, interpret=True, tile_m=32, tile_n=64)
        ref = stencil_matmul_ref(x, w)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=2e-5)

    def test_fused_weights_path(self):
        """Monolithic kernel fusion: one banded contraction of the composed
        kernel == t sequential steps (the paper's TC fusion semantics)."""
        spec = StencilSpec("box", 2, 1)
        w = make_weights(spec, seed=3)
        x = _x(64, 64)
        wf = fuse_weights(w, 3)
        y = stencil_matmul(x, wf, interpret=True, tile_m=32, tile_n=32)
        ref = stencil_direct_ref(x, w, 3)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4)

    def test_band_sparsity_matches_model(self):
        """The built operands' sparsity == perfmodel.sparsity_banded."""
        for r, n in [(1, 128), (2, 128), (3, 64)]:
            w = make_weights(StencilSpec("box", 2, r), seed=0)
            assert band_sparsity(w, n) == pytest.approx(
                sparsity_banded(r, n), rel=1e-6)

    def test_bf16_compute(self):
        spec = StencilSpec("box", 2, 1)
        w = make_weights(spec, seed=0)
        x = _x(64, 64)
        y = stencil_matmul(x, w, interpret=True, tile_m=32, tile_n=32,
                           compute_dtype=jnp.bfloat16)
        ref = stencil_matmul_ref(x, w)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=3e-2)


class TestOps:
    @pytest.mark.parametrize("backend,t", [
        ("direct", 1), ("direct", 2), ("fused_direct", 3),
        ("matmul", 1), ("matmul", 2), ("fused_matmul", 3),
        ("fused_matmul_reuse", 2), ("fused_matmul_reuse", 3),
        ("reference", 2), ("auto", 2),
    ])
    def test_all_backends_agree(self, backend, t):
        spec = StencilSpec("box", 2, 1)
        w = make_weights(spec, seed=1)
        x = _x(64, 64)
        y = stencil_apply(x, w, t=t, backend=backend, tile_m=32, tile_n=32)
        ref = stencil_direct_ref(x, w, t)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4)

    def test_explain_decision(self):
        w = make_weights(StencilSpec("box", 2, 1), seed=0)
        d = explain(w, 4, 4)
        assert d.backend in ("fused_direct", "fused_matmul",
                             "fused_matmul_reuse")
        assert d.predicted_speedup > 0
        # every t>1 regime is priced
        assert set(d.candidates) == {"fused_direct", "fused_matmul",
                                     "fused_matmul_reuse"}
        assert all(v > 0 for v in d.candidates.values())

    def test_invalid_backend(self):
        w = make_weights(StencilSpec("box", 2, 1), seed=0)
        with pytest.raises(ValueError):
            stencil_apply(_x(32, 32), w, backend="gpu")

    @given(r=st.integers(1, 2), t=st.integers(1, 3),
           seed=st.integers(0, 10))
    @settings(max_examples=15, deadline=None)
    def test_property_backend_equivalence(self, r, t, seed):
        """direct and fused_matmul agree for random kernels/depths."""
        spec = StencilSpec("box", 2, r)
        w = make_weights(spec, seed=seed)
        x = _x(32, 32)
        a = stencil_apply(x, w, t=t, backend="direct", tile_m=16, tile_n=16)
        b = stencil_apply(x, w, t=t, backend="fused_matmul",
                          tile_m=16, tile_n=16)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
