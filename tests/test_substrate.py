"""Optimizer, data pipeline, checkpoint manager, gradient compression,
MoE routing invariants."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.optim import adamw
from repro.parallel.compress import fake_quantize_tree, _quantize, _dequantize


class TestAdamW:
    def test_converges_quadratic(self):
        cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                                total_steps=200, schedule="constant")
        params = {"w": jnp.asarray([3.0, -2.0])}
        state = adamw.init(params)
        for _ in range(150):
            grads = jax.tree.map(lambda p: 2 * p, params)   # d/dp ||p||^2
            params, state, m = adamw.apply(cfg, grads, state, params)
        assert float(jnp.abs(params["w"]).max()) < 0.05
        assert int(state.step) == 150

    def test_clip_norm(self):
        g = {"a": jnp.full((10,), 100.0)}
        clipped, gn = adamw.clip_by_global_norm(g, 1.0)
        assert float(gn) > 100
        assert adamw.global_norm(clipped) <= 1.0 + 1e-5

    def test_warmup_schedule(self):
        cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
        lrs = [float(adamw.lr_at(cfg, jnp.asarray(s))) for s in range(100)]
        assert lrs[0] < lrs[5] < lrs[9]          # warming up
        assert lrs[99] < lrs[20]                 # cosine decaying
        assert all(l > 0 for l in lrs)


class TestData:
    def test_deterministic_resume(self):
        cfg = DataConfig(vocab=100, seq_len=16, global_batch=4, seed=7)
        a = SyntheticLM(cfg).batch_at(123)
        b = SyntheticLM(cfg).batch_at(123)   # fresh pipeline, same step
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_steps_differ(self):
        cfg = DataConfig(vocab=100, seq_len=16, global_batch=4)
        p = SyntheticLM(cfg)
        assert not np.array_equal(p.batch_at(0)["tokens"],
                                  p.batch_at(1)["tokens"])

    def test_learnable_structure(self):
        # 80% of transitions follow the deterministic walk
        cfg = DataConfig(vocab=1000, seq_len=256, global_batch=8)
        t = SyntheticLM(cfg).batch_at(0)["tokens"]
        a, c = 6364136223846793005 % 1000, 1442695040888963407 % 1000
        follow = (t[:, :-1] * a + c) % 1000 == t[:, 1:]
        assert 0.7 < follow.mean() < 0.9

    def test_host_sharding_partitions(self):
        cfg = DataConfig(vocab=100, seq_len=8, global_batch=8)
        p = SyntheticLM(cfg)
        b = p.batch_at(0)
        parts = [p.shard_for_host(b, i, 4)["tokens"] for i in range(4)]
        np.testing.assert_array_equal(np.concatenate(parts), b["tokens"])


class TestCheckpoint:
    def _tree(self, seed=0):
        r = np.random.default_rng(seed)
        return {"layer": {"w": jnp.asarray(r.normal(size=(4, 4)).astype(np.float32)),
                          "b": jnp.asarray(r.normal(size=(4,)).astype(np.float32))},
                "step_arr": jnp.asarray(3, jnp.int32)}

    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = self._tree()
        mgr.save(10, tree)
        restored = mgr.restore(10, jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree))
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), tree, restored)

    def test_keep_k_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, self._tree(s))
        assert mgr.all_steps() == [3, 4]

    def test_restore_latest_and_missing(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3)
        like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                            self._tree())
        step, tree = mgr.restore_latest(like)
        assert step is None and tree is None
        mgr.save(5, self._tree(5))
        step, tree = mgr.restore_latest(like)
        assert step == 5

    def test_shape_mismatch_rejected(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, self._tree())
        bad = {"layer": {"w": jax.ShapeDtypeStruct((5, 4), jnp.float32),
                         "b": jax.ShapeDtypeStruct((4,), jnp.float32)},
               "step_arr": jax.ShapeDtypeStruct((), jnp.int32)}
        with pytest.raises(ValueError, match="shape"):
            mgr.restore(1, bad)

    def test_no_tmp_left_behind(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, self._tree())
        assert not [f for f in os.listdir(tmp_path) if ".tmp" in f]


class TestCompression:
    def test_roundtrip_error_bounded(self):
        x = jnp.asarray(np.random.default_rng(0).normal(size=(1000,))
                        .astype(np.float32))
        q, s = _quantize(x, jax.random.PRNGKey(0))
        err = np.abs(np.asarray(_dequantize(q, s)) - np.asarray(x))
        assert err.max() <= float(s) + 1e-6     # one quantization step

    @given(seed=st.integers(0, 50))
    @settings(max_examples=20, deadline=None)
    def test_stochastic_rounding_unbiased(self, seed):
        x = jnp.full((4000,), 0.3712)
        q, s = _quantize(x, jax.random.PRNGKey(seed))
        mean = float(_dequantize(q, s).mean())
        assert abs(mean - 0.3712) < 0.01

    def test_tree_structure_preserved(self):
        g = {"a": jnp.ones((3, 3)), "b": {"c": jnp.ones((2,))}}
        out = fake_quantize_tree(g)
        jax.tree.map(lambda x, y: None, g, out)


class TestMoE:
    def test_capacity_respected(self):
        from repro.configs import SMOKE
        from repro.models import moe as moe_lib
        from repro.models.base import init_tree
        cfg = SMOKE["olmoe-1b-7b"]
        defs = moe_lib.moe_defs(cfg, 0)
        params = init_tree(defs, jax.random.PRNGKey(0))
        B, S, D = 2, 32, cfg.d_model
        x = jnp.asarray(np.random.default_rng(0).normal(size=(B, S, D))
                        .astype(np.float32))
        y, aux = moe_lib.moe_mlp(params, x, cfg)
        assert y.shape == x.shape
        assert np.isfinite(np.asarray(y)).all()
        assert float(aux) >= 1.0 - 1e-3   # Switch aux >= 1 at uniformity

    def test_top1_single_expert_equals_dense(self):
        """E=1/top-1 MoE must reduce to its own dense expert MLP."""
        import dataclasses
        from repro.configs import SMOKE
        from repro.models import moe as moe_lib
        from repro.models.base import init_tree
        cfg = dataclasses.replace(
            SMOKE["olmoe-1b-7b"],
            moe=dataclasses.replace(SMOKE["olmoe-1b-7b"].moe,
                                    num_experts=1, top_k=1,
                                    capacity_factor=1.0))
        defs = moe_lib.moe_defs(cfg, 0)
        params = init_tree(defs, jax.random.PRNGKey(1))
        B, S, D = 1, 8, cfg.d_model
        x = jnp.asarray(np.random.default_rng(1).normal(size=(B, S, D))
                        .astype(np.float32))
        y, _ = moe_lib.moe_mlp(params, x, cfg)
        g = jnp.einsum("bsd,df->bsf", x, params["wg"][0])
        u = jnp.einsum("bsd,df->bsf", x, params["wu"][0])
        want = jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, params["wd"][0])
        np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)
