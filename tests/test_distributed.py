"""Multi-device tests (distributed stencil halo exchange, sharded train
step, HLO cost analyzer on partitioned programs).

jax pins the device count at first init, and the suite must see ONE device
(per the dry-run contract), so every test here runs in a subprocess with
its own XLA_FLAGS."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(n, code):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=560)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


class TestDistributedStencil:
    def test_halo_exchange_matches_global(self):
        out = run_with_devices(4, """
            import jax, numpy as np, jax.numpy as jnp
            from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
            from repro.stencil import StencilSpec, make_weights
            from repro.stencil.reference import apply_stencil_steps
            from repro.stencil.distributed import make_distributed_stepper
            mesh = Mesh(np.array(jax.devices()).reshape(2,2), ("x","y"))
            for shape in ("box","star"):
                for mode in ("stepwise","fused"):
                    spec = StencilSpec(shape,2,1); w = make_weights(spec, seed=1)
                    x = np.random.default_rng(0).normal(size=(64,64)).astype(np.float32)
                    xs = jax.device_put(x, NamedSharding(mesh, P("x","y")))
                    step = make_distributed_stepper(mesh, ("x","y"), w, t=3, mode=mode)
                    with mesh:
                        y = jax.jit(step)(xs)
                    ref = apply_stencil_steps(jnp.asarray(x), jnp.asarray(w), 3)
                    err = float(jnp.abs(y - ref).max())
                    assert err < 1e-5, (shape, mode, err)
            print("OK")
        """)
        assert "OK" in out

    def test_1d_sharding_and_3d_grid(self):
        out = run_with_devices(4, """
            import jax, numpy as np, jax.numpy as jnp
            from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
            from repro.stencil import StencilSpec, make_weights
            from repro.stencil.reference import apply_stencil_steps
            from repro.stencil.distributed import make_distributed_stepper
            mesh = Mesh(np.array(jax.devices()).reshape(4,), ("x",))
            spec = StencilSpec("box",3,1); w = make_weights(spec, seed=2)
            x = np.random.default_rng(1).normal(size=(32,16,16)).astype(np.float32)
            xs = jax.device_put(x, NamedSharding(mesh, P("x")))
            step = make_distributed_stepper(mesh, ("x",None,None), w, t=2, mode="fused")
            with mesh:
                y = jax.jit(step)(xs)
            ref = apply_stencil_steps(jnp.asarray(x), jnp.asarray(w), 2)
            assert float(jnp.abs(y-ref).max()) < 1e-5
            print("OK")
        """)
        assert "OK" in out

    def test_fused_mode_fewer_collectives(self):
        """Temporal fusion amortizes halo exchanges: the fused program
        must contain fewer collective-permutes than stepwise (paper's
        communication-side redundancy trade)."""
        out = run_with_devices(4, """
            import jax, numpy as np, jax.numpy as jnp
            from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
            from repro.stencil import StencilSpec, make_weights
            from repro.stencil.distributed import make_distributed_stepper
            from repro.core.hlo_cost import analyze_hlo
            mesh = Mesh(np.array(jax.devices()).reshape(2,2), ("x","y"))
            w = make_weights(StencilSpec("box",2,1), seed=1)
            aval = jax.ShapeDtypeStruct((64,64), jnp.float32)
            sh = NamedSharding(mesh, P("x","y"))
            counts = {}
            for mode in ("stepwise","fused"):
                step = make_distributed_stepper(mesh, ("x","y"), w, t=4, mode=mode)
                c = jax.jit(step, in_shardings=sh, out_shardings=sh).lower(aval).compile()
                pc = analyze_hlo(c.as_text())
                counts[mode] = pc.coll_counts.get("collective-permute", 0)
            assert counts["fused"] < counts["stepwise"], counts
            print("OK", counts)
        """)
        assert "OK" in out


class TestHaloBytes:
    """Unit coverage for the analytic halo-traffic formula (no devices).

    Cross-checked against a direct simulation of ``_extend``'s exchange
    order: when dim ``d`` is exchanged, EVERY earlier dim -- sharded
    (ppermute) or not (periodic pad) -- is already extended by 2h, so the
    exchanged face spans n+2h along it.  The seed formula skipped the
    extension for unsharded earlier dims, undercounting traffic whenever a
    later-processed dim is sharded."""

    @staticmethod
    def _simulated(local_shape, dim_axis_names, h, dtype_bytes):
        shape = list(local_shape)
        total = 0
        for dim, ax in enumerate(dim_axis_names):
            if ax is not None:
                face = 1
                for d2, n in enumerate(shape):
                    if d2 != dim:
                        face *= n
                total += 2 * h * face * dtype_bytes
            shape[dim] += 2 * h          # _extend grows every dim in order
        return total

    def test_matches_exchange_simulation(self):
        from repro.stencil.distributed import halo_bytes_per_step
        cases = [
            ((64, 64), ("x", "y"), 1),
            ((64, 64), (None, "y"), 2),          # later-sharded dim: the bug
            ((64, 64), ("x", None), 3),
            ((32, 16, 16), ("x", None, "z"), 2),
            ((32, 16, 16), (None, None, "z"), 4),
        ]
        for local, dims, h in cases:
            got = halo_bytes_per_step(local, dims, h, 1, "stepwise", 4)
            want = self._simulated(local, dims, h, 4)
            assert got == want, (local, dims, h, got, want)

    def test_fused_vs_stepwise_accounting(self):
        from repro.stencil.distributed import halo_bytes_per_step
        # fused: ONE exchange at depth t*r; stepwise: t exchanges at r
        st = halo_bytes_per_step((64, 64), ("x", "y"), 1, 4, "stepwise", 4)
        fu = halo_bytes_per_step((64, 64), ("x", "y"), 1, 4, "fused", 4)
        assert st == 4 * halo_bytes_per_step((64, 64), ("x", "y"), 1, 1,
                                             "stepwise", 4)
        # same leading-order bytes, but the fused face is wider (h=4)
        assert fu > st / 4

    def test_later_sharded_dim_not_undercounted(self):
        from repro.stencil.distributed import halo_bytes_per_step
        h = 2
        got = halo_bytes_per_step((64, 64), (None, "y"), h, 1, "stepwise", 4)
        # face along dim 0 is 64 + 2h (dim 0 already periodic-padded)
        assert got == 2 * h * (64 + 2 * h) * 4


class TestShardedTraining:
    def test_sharded_train_step_runs(self):
        """End-to-end pjit train step on a 2x2 (data, model) mesh with the
        production sharding rules, executed for real (not just lowered)."""
        out = run_with_devices(4, """
            import jax, numpy as np, jax.numpy as jnp
            from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
            from repro.configs import SMOKE
            from repro.models.api import get_model
            from repro.models import base
            from repro.optim import adamw
            from repro.parallel import sharding
            from repro.train.steps import make_train_step
            mesh = jax.make_mesh((2,2), ("data","model"))
            cfg = SMOKE["llama3.2-1b"]
            model = get_model(cfg)
            defs = model.param_defs()
            pspecs = sharding.param_pspecs(defs, mesh, cfg.fsdp)
            shards = sharding.param_shardings(defs, mesh, cfg.fsdp)
            params = model.init_params(jax.random.PRNGKey(0))
            params = jax.tree.map(jax.device_put, params, shards)
            opt = adamw.init(params)
            batch = {"tokens": np.random.default_rng(0).integers(
                0, cfg.vocab, size=(4, 33)).astype(np.int32)}
            step = make_train_step(model, adamw.AdamWConfig(lr=1e-3))
            with sharding.use_mesh(mesh, cfg.fsdp):
                p2, o2, m = jax.jit(step)(params, opt, batch)
            loss = float(m["loss"])
            assert np.isfinite(loss) and loss > 0
            # sharded == single-device result
            loss_ref, _ = model.loss_fn(jax.device_get(params), batch)
            assert abs(loss - float(loss_ref)) < 0.05, (loss, float(loss_ref))
            print("OK", loss)
        """)
        assert "OK" in out

    def test_cache_pspecs_resolve(self):
        out = run_with_devices(4, """
            import jax, jax.numpy as jnp
            from repro.configs import ARCHS
            from repro.models.api import get_model
            from repro.parallel import sharding
            mesh = jax.make_mesh((2,2), ("data","model"))
            for arch in ("llama3.2-1b","zamba2-1.2b","rwkv6-1.6b","whisper-base"):
                model = get_model(ARCHS[arch])
                caches = jax.eval_shape(lambda: model.init_caches(8, 64))
                specs = sharding.cache_pspecs(caches, mesh)
                jax.tree.map(lambda a, s: None, caches, specs)  # structure match
            print("OK")
        """)
        assert "OK" in out


class TestHloCostPartitioned:
    def test_collectives_counted(self):
        out = run_with_devices(4, """
            import jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.core.hlo_cost import analyze_hlo
            mesh = jax.make_mesh((4,), ("m",))
            def f(a, b):
                return a @ b
            sh_a = NamedSharding(mesh, P(None, "m"))
            sh_b = NamedSharding(mesh, P("m", None))
            a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
            c = jax.jit(f, in_shardings=(sh_a, sh_b),
                        out_shardings=NamedSharding(mesh, P())).lower(a, a).compile()
            pc = analyze_hlo(c.as_text())
            # contracting-dim sharding => all-reduce of the (256,256) output
            assert pc.coll.get("all-reduce", 0) >= 256*256*4, pc.coll
            # per-partition flops = full / 4
            assert abs(pc.flops - 2*256**3/4) / (2*256**3/4) < 0.05
            print("OK")
        """)
        assert "OK" in out
