"""End-to-end LM training driver: data pipeline -> model -> AdamW ->
checkpoint/restart -> straggler watchdog.

Presets scale the same llama-family architecture to the runtime budget:

    PYTHONPATH=src python examples/train_lm.py                 # nano, 200 steps
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
    PYTHONPATH=src python examples/train_lm.py --resume        # crash-restart

`--preset 100m` is the deliverable configuration (~100M params, a few
hundred steps); `nano` (~3M) makes the loss curve visible in CPU minutes.
Kill the process mid-run and re-invoke with --resume to exercise the
fault-tolerance path (atomic checkpoints + stateless data resume).
"""
import argparse
import dataclasses

from repro.configs.registry import ModelConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.api import get_model
from repro.optim import adamw
from repro.train.loop import LoopConfig, train

PRESETS = {
    "nano": ModelConfig("train-nano", "dense", 4, 128, 4, 2, 512, 2048,
                        rope_theta=10000.0),
    "30m": ModelConfig("train-30m", "dense", 6, 512, 8, 4, 2048, 8192,
                       rope_theta=10000.0),
    "100m": ModelConfig("train-100m", "dense", 12, 768, 12, 4, 3072, 32000,
                        rope_theta=10000.0),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="nano", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true",
                    help="resume from latest checkpoint in --ckpt-dir")
    ap.add_argument("--grad-compression", choices=["int8"], default=None)
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    model = get_model(cfg)
    print(f"preset={args.preset}: {model.param_count():,} params, "
          f"{cfg.n_layers}L d{cfg.d_model}, vocab {cfg.vocab}")

    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch, seed=0))
    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=20,
                                total_steps=args.steps)
    loop_cfg = LoopConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                          ckpt_dir=args.ckpt_dir if (args.resume or
                                                     args.ckpt_every) else None,
                          log_every=10,
                          grad_compression=args.grad_compression)
    if not args.resume:
        import shutil, os
        shutil.rmtree(args.ckpt_dir, ignore_errors=True)
        os.makedirs(args.ckpt_dir, exist_ok=True)

    params, _, history = train(model, data, opt_cfg, loop_cfg)
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"\nloss {first:.4f} -> {last:.4f} over {len(history)} steps "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
