"""Quickstart: compile a stencil execution plan once, run it many times.

``stencil_plan`` performs the paper's analytical backend selection, strip
sizing and weight preprocessing exactly once; ``plan(x)`` then executes
with zero re-analysis.  ``stencil_apply`` remains as the one-shot wrapper
(it builds-or-fetches the same plan from the process cache).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import perfmodel as pm
from repro.kernels import (plan_cache_stats, registered_backends,
                           stencil_apply, stencil_plan)
from repro.kernels.ref import stencil_direct_ref
from repro.stencil import StencilSpec, make_weights


def main():
    spec = StencilSpec("box", 2, 1)           # the classic Box-2D1R
    w = make_weights(spec, seed=0)
    x = jnp.asarray(np.random.default_rng(0)
                    .normal(size=(256, 256)).astype(np.float32))
    t = 4                                      # fuse 4 time steps

    print(f"stencil {spec.name}: K={spec.num_points} points, "
          f"C={spec.flops_per_point()} flops/pt, I={spec.arithmetic_intensity(4)}")

    # every registered backend executes through the same plan object
    ref = stencil_direct_ref(x, w, t)
    for backend in registered_backends():
        plan = stencil_plan(w, x.shape, x.dtype, t, backend=backend)
        err = float(jnp.abs(plan(x) - ref).max())
        print(f"  backend={backend:18s} max|err| vs oracle = {err:.2e}")

    # the paper's criteria as a scheduler (TPU v5e constants): selection runs
    # ONCE at plan build; plan.decision exposes the priced Decision
    plan = stencil_plan(w, x.shape, x.dtype, t, hw=pm.TPU_V5E_BF16)
    print(f"\nauto plan on {pm.TPU_V5E_BF16.name} "
          f"(built in {plan.build_time_s*1e3:.1f} ms):")
    print(plan.explain())

    # serving loop: millions of steps would hit this line only
    y = plan.run(x, n_steps=3)                 # 3 * t = 12 time steps
    ref12 = stencil_direct_ref(x, w, 3 * t)
    print(f"  plan.run(x, 3) err  : {float(jnp.abs(y - ref12).max()):.2e}")

    # the compatibility wrapper fetches the SAME cached plan
    y2 = stencil_apply(x, w, t=t, backend="auto", hw=pm.TPU_V5E_BF16)
    print(f"  wrapper parity      : "
          f"{'bit-identical' if bool((y2 == plan(x)).all()) else 'MISMATCH'}")
    print(f"  plan cache          : {plan_cache_stats()}")


if __name__ == "__main__":
    main()
