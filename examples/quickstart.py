"""Quickstart: run a stencil through every backend and let the paper's
criteria pick the execution unit.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import perfmodel as pm
from repro.kernels import stencil_apply, explain
from repro.kernels.ref import stencil_direct_ref
from repro.stencil import StencilSpec, make_weights


def main():
    spec = StencilSpec("box", 2, 1)           # the classic Box-2D1R
    w = make_weights(spec, seed=0)
    x = jnp.asarray(np.random.default_rng(0)
                    .normal(size=(256, 256)).astype(np.float32))
    t = 4                                      # fuse 4 time steps

    print(f"stencil {spec.name}: K={spec.num_points} points, "
          f"C={spec.flops_per_point()} flops/pt, I={spec.arithmetic_intensity(4)}")

    ref = stencil_direct_ref(x, w, t)
    for backend in ("direct", "fused_direct", "matmul", "fused_matmul",
                    "fused_matmul_reuse"):
        y = stencil_apply(x, w, t=t, backend=backend)
        err = float(jnp.abs(y - ref).max())
        print(f"  backend={backend:13s} max|err| vs oracle = {err:.2e}")

    # the paper's criteria as a scheduler (TPU v5e constants)
    d = explain(w, t, dtype_bytes=4, hw=pm.TPU_V5E_BF16)
    print(f"\nauto-dispatch on {pm.TPU_V5E_BF16.name}:")
    print(f"  scenario           : {d.scenario}")
    print(f"  predicted speedup  : {d.predicted_speedup:.2f}x (matrix vs vector)")
    print(f"  chosen backend     : {d.backend}")
    print(f"  reason             : {d.reason}")

    y = stencil_apply(x, w, t=t, backend="auto", hw=pm.TPU_V5E_BF16)
    print(f"  auto result err    : {float(jnp.abs(y - ref).max()):.2e}")


if __name__ == "__main__":
    main()
