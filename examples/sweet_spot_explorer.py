"""Sweet-spot explorer: reproduce the paper's Figures 9/13/14 decision
surfaces as tables, for the paper's A100 and for our TPU v5e target.

    PYTHONPATH=src python examples/sweet_spot_explorer.py
"""
from repro.core import perfmodel as pm
from repro.stencil import StencilSpec


def surface(hw, sparsity_fn, use_sparse, title):
    print(f"\n=== {title} ===")
    print("pattern      " + "".join(f"  t={t:<3}" for t in range(1, 9)))
    for name in ("Box-2D1R", "Box-2D3R", "Star-2D1R", "Box-3D1R", "Box-2D7R"):
        spec = StencilSpec.from_name(name)
        row = []
        for t in range(1, 9):
            s = sparsity_fn(spec, t)
            c = pm.compare(pm.StencilWorkload(spec, t, 4), hw, s,
                           use_sparse_unit=use_sparse)
            mark = {1: "=", 2: "x", 3: "O", 4: "o" if c.profitable else "x"}[
                c.scenario.value]
            row.append(mark)
        print(f"{name:12s} " + "".join(f"    {m} " for m in row))
    print("  O = breaks the ceiling (scenario 3)   o = sweet spot (scenario 4)")
    print("  x = matrix unit loses                 = = equal (both memory-bound)")


def main():
    # paper setting: ConvStencil-style S=0.5 on A100 float
    surface(pm.A100_FLOAT, lambda s, t: 0.5, False,
            "A100 fp32, dense Tensor Cores, S=0.5 (Fig. 9)")
    # paper §4.3: Sparse Tensor Cores widen the region (Fig. 13/14)
    surface(pm.A100_FLOAT, lambda s, t: 0.47, True,
            "A100 fp32, SPARSE Tensor Cores, S=0.47 (Fig. 14)")
    # our TPU target with the banded scheme's structural sparsity
    surface(pm.TPU_V5E_BF16,
            lambda s, t: pm.sparsity_banded(s.radius * t, 128), False,
            "TPU v5e bf16, MXU banded scheme (this work)")
    print("""
Reading the TPU surface: the 128-wide MXU tiles make S far smaller than on
Tensor Cores, so the profitable region shifts toward LARGE effective radii
(big r or deep fusion) -- the paper's criteria, instantiated for the MXU,
tell us exactly when the banded path is worth it (cf. benchmarks/fig16).""")


if __name__ == "__main__":
    main()
