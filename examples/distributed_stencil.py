"""Distributed stencil with halo exchange on a simulated 8-device mesh.

Shows the paper's temporal-fusion trade at cluster scale: fused execution
does ONE deep halo exchange per t steps (vs t shallow ones), paying with
redundant halo compute -- the distributed alpha.

Needs its own process so jax can fake 8 devices:

    PYTHONPATH=src python examples/distributed_stencil.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax                                                    # noqa: E402
import jax.numpy as jnp                                       # noqa: E402
import numpy as np                                            # noqa: E402
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core.hlo_cost import analyze_hlo                   # noqa: E402
from repro.kernels import stencil_plan                        # noqa: E402
from repro.stencil import StencilSpec, make_weights           # noqa: E402
from repro.stencil.reference import apply_stencil_steps       # noqa: E402


def main():
    mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("x", "y"))
    spec = StencilSpec("box", 2, 1)
    w = make_weights(spec, seed=0)
    t = 4
    n = 512
    x = np.random.default_rng(0).normal(size=(n, n)).astype(np.float32)
    xs = jax.device_put(x, NamedSharding(mesh, P("x", "y")))
    print(f"domain {n}x{n} over mesh {dict(mesh.shape)}; {spec.name}, t={t}")

    ref = apply_stencil_steps(jnp.asarray(x), jnp.asarray(w), t)
    for mode in ("stepwise", "fused"):
        # one plan object drives local AND distributed execution: mesh +
        # shard_spec route it through the halo-exchange stepper, with the
        # exchange schedule planned once at build time (plan.halo_plan)
        plan = stencil_plan(w, (n, n), np.float32, t, mesh=mesh,
                            shard_spec=("x", "y"), dist_mode=mode,
                            backend="reference")
        y = plan(xs)
        err = float(jnp.abs(y - ref).max())
        pc = analyze_hlo(plan.fn.lower(
            jax.ShapeDtypeStruct(x.shape, jnp.float32)).compile().as_text())
        rounds = pc.coll_counts.get("collective-permute", 0)
        hb = plan.halo_plan["halo_bytes_per_call"]
        print(f"  {mode:9s}: max|err|={err:.1e}  collective-permutes={rounds:.0f}"
              f"  halo-bytes/shard/{t}steps={hb}")
    print("fused mode: 1 exchange round instead of t -- latency amortized,")
    print("halo overlap recomputed locally (the paper's alpha, distributed).")


if __name__ == "__main__":
    main()
