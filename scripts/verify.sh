#!/usr/bin/env bash
# Tier-1 verification: the test suite plus the benchmark harness in
# interpret mode (no TPU required).  Run from anywhere:
#
#   scripts/verify.sh            # quick benchmark sweep (BENCH_QUICK=1)
#   BENCH_FULL=1 scripts/verify.sh   # full Box/Star x r x t traffic grid
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
if [ -z "${BENCH_FULL:-}" ]; then
  export BENCH_QUICK=1
fi

# Every REPRO_* env knob must be consumed through core/envutil (one
# parser, loud failures); cheap AST lint, so it runs before anything else.
python scripts/lint_env.py

# Tier-1 (ROADMAP.md).  The seed test debt is zero: any failure is a real
# regression, so fail fast before the benchmark smoke.
python -m pytest -x -q

# Static plan audit (DESIGN.md §13): prove the analytic traffic/FLOP
# model against the launch structure of every non-legacy backend across
# the 1D/2D/3D x remainder-width matrix.  Exits nonzero on any violation.
python scripts/audit.py

# Stamp the harness start so the serving gate below can prove its JSON was
# produced by THIS run.  (The traffic harness no longer needs the mtime
# inference: benchmarks/run.py writes a per-run manifest, gated by name.)
BENCH_STAMP="$(mktemp)"
export BENCH_STAMP

python benchmarks/run.py

# The harness swallows per-module failures so the sweep always finishes;
# the manifest it writes names every failed module.  Gate on it directly.
python - <<'EOF'
import json
with open("BENCH_run.json") as f:
    manifest = json.load(f)
failed = manifest["failed"]
assert not failed, f"benchmark module(s) failed: {', '.join(failed)}"
assert "traffic" in manifest["succeeded"], \
    "traffic module missing from the benchmark manifest"
print(f"verify: {len(manifest['succeeded'])} benchmark modules OK")
EOF

# The benchmark smoke must include at least one freshly measured 3D
# halo-plane traffic case (DESIGN.md §9), with the sub-blocked
# amplification strictly below the whole-slab foil's 9x (the ISSUE-4
# acceptance criterion), and at least one wide-grid column-tiled case
# (DESIGN.md §10) whose read amplification stays below the whole-width
# 3x foil with a genuinely positive resolved w_tile (ISSUE-5).
python - <<'EOF'
import json, os
path = "BENCH_kernels.quick.json" if os.environ.get("BENCH_QUICK") \
    else "BENCH_kernels.json"
with open(path) as f:
    data = json.load(f)
# Per-case wall-clock budgets (benchmarks/timing.case_budget) may record
# a case as timed_out instead of wedging the run; tolerate those rows but
# require the surviving measurements to be non-empty.
timed_out = [c["case"] for group in ("cases", "cases_3d", "cases_wide")
             for c in data[group] if c.get("timed_out")]
if timed_out:
    print(f"verify: WARNING {len(timed_out)} case(s) timed out: {timed_out}")
cases = [c for c in data["cases_3d"] if not c.get("timed_out")]
assert cases, f"no (surviving) 3D traffic cases in {path}"
for c in cases:
    assert c["read_bytes_step_direct_subblocked"] < \
        c["read_bytes_step_direct_wholestrip"], c["case"]
    assert c["read_amp_subblocked"] < c["read_amp_wholestrip"], c["case"]
# Sparse-compaction gate (DESIGN.md §14): every 2D and 3D case records
# the star-vs-box sparsity sweep.  The compacted contraction must be
# bitwise-equal to the dense reuse plan everywhere; star cases must
# execute strictly fewer MXU FLOPs per step (kept-row fraction S < 1),
# box cases exactly the dense count (S = 1 -- no structural zeros to
# drop).  At least one star case must survive in each rank.
sparse2d = [c for c in data["cases"]
            if not c.get("timed_out") and "kept_row_fraction" in c]
sparse = sparse2d + cases
assert any(c["shape"] == "star" for c in sparse2d), \
    "no surviving 2D star case for the sparse sweep"
assert any(c["shape"] == "star" for c in cases), \
    "no surviving 3D star case for the sparse sweep"
for c in sparse:
    assert c["sparse_bitwise_equal"], \
        f"sparse output diverged from dense: {c['case']}"
    if c["shape"] == "star":
        assert c["mxu_flops_step_sparse"] < c["mxu_flops_step_dense"], \
            (f"star case {c['case']} did not shrink MXU FLOPs: "
             f"{c['mxu_flops_step_sparse']} !< {c['mxu_flops_step_dense']}")
        assert c["kept_row_fraction"] < 1.0, c["case"]
    else:
        assert c["mxu_flops_step_sparse"] == c["mxu_flops_step_dense"], \
            f"box case {c['case']} changed MXU FLOPs under compaction"
        assert c["kept_row_fraction"] == 1.0, c["case"]
# Boundary-mode rows (DESIGN.md §15): every timed mode must match its
# mode-matched oracle, and the distributed overlap pair must be
# bitwise-equal to the serialized foil with a nonzero interleave
# counter (the timing comparison itself is recorded, not gated -- CPU
# wall-clock is too noisy for CI).
bnd = [c for c in data.get("cases_boundary", []) if not c.get("timed_out")]
assert bnd, f"no (surviving) boundary-mode cases in {path}"
for c in bnd:
    assert c["oracle_max_err"] < 5e-4, (c["case"], c["oracle_max_err"])
ov = data.get("halo_overlap", {})
if "us_step_overlap" in ov:
    assert ov["bitwise_equal"], "overlap stepper != serialized foil"
    assert ov["interleave_counters"]["interior_before_recv_consumed"] > 0
wide = [c for c in data["cases_wide"] if not c.get("timed_out")]
assert wide, f"no (surviving) wide-grid column-tiled cases in {path}"
for c in wide:
    assert c["w_tile"] > 0 and c["w_block"] > 0, c["case"]
    assert c["read_amp_coltiled"] < c["read_amp_wholestrip"], c["case"]
    assert c["read_bytes_step_direct_coltiled"] < \
        c["read_bytes_step_direct_wholestrip"], c["case"]
# A clean run must degrade NOTHING: the guard layer's event log (dumped
# into the JSON by benchmarks/traffic.py) has to be empty -- any entry
# means a kernel failed and silently fell down the degradation ladder.
guard = data.get("guard_events", {})
assert guard.get("events", []) == [], \
    f"guard events on a clean run: {guard['events']}"
assert guard.get("dropped", 0) == 0, "guard event ring buffer overflowed"
stats = data.get("plan_stats", {})
for k in ("build_failures", "exec_failures", "fallbacks"):
    assert stats.get(k, 0) == 0, f"clean run but plan_stats[{k!r}]={stats[k]}"
n_star = sum(c["shape"] == "star" for c in sparse)
print(f"verify: {len(cases)} 3D traffic case(s) in {path}, "
      "sub-blocked < whole-slab; "
      f"{len(wide)} wide case(s), column-tiled < whole-width foil; "
      f"{len(sparse)} sparse case(s) bitwise-equal "
      f"({n_star} star < dense MXU FLOPs); "
      f"{len(bnd)} boundary case(s) oracle-matched; guard event log clean")
EOF

# Serving gate (DESIGN.md §12): the batched engine must beat per-request
# dispatch on identical traffic, bitwise-equal, with P50/P99 freshly
# measured into BENCH_serving.json, and the plan cache must prove the
# sharing contract -- at least (requests - distinct signatures) hits.
python benchmarks/serving.py ${BENCH_QUICK:+--quick}

python - <<'EOF'
import json, os
path = "BENCH_serving.json"
assert os.path.getmtime(path) >= os.path.getmtime(os.environ["BENCH_STAMP"]), \
    f"{path} was not rewritten by this run (serving benchmark failed?)"
with open(path) as f:
    d = json.load(f)
seq, bat = d["sequential"], d["batched"]
assert bat["requests_per_s"] > seq["requests_per_s"], \
    (f"batched engine lost to per-request dispatch: "
     f"{bat['requests_per_s']:.0f} <= {seq['requests_per_s']:.0f} req/s")
assert d["bitwise_match"], "batched responses diverged from unbatched plans"
lat = bat["latency"]
for k in ("p50_ms", "p99_ms"):
    assert lat.get(k, 0) > 0, f"batched latency {k} missing or zero"
assert bat["failed"] == 0, f"{bat['failed']} serving request(s) failed"
assert bat["responded"] == bat["submitted"], \
    f"lost requests: responded {bat['responded']} != submitted {bat['submitted']}"
# Plan-sharing contract: every request past the first per signature must
# hit the cache (sequential side alone guarantees this many hits; the
# engine's (signature, bucket) plans add more).
pc = d["plan_cache"]
need = seq["requests"] - len(d["signatures"])
assert pc["hits_delta"] >= need, \
    f"plan cache hits {pc['hits_delta']} < requests - signatures = {need}"
guard = d["guard_events"]
assert guard.get("events", []) == [], \
    f"serving batch degraded on a clean run: {guard['events']}"
assert guard.get("dropped", 0) == 0, "guard event ring buffer overflowed"
print(f"verify: serving {bat['requests_per_s']:.0f} req/s batched vs "
      f"{seq['requests_per_s']:.0f} sequential ({d['speedup']:.2f}x), "
      f"P50 {lat['p50_ms']:.1f} ms P99 {lat['p99_ms']:.1f} ms, "
      f"{pc['hits_delta']} plan-cache hits, bitwise OK")
EOF
rm -f "$BENCH_STAMP"
