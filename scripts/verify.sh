#!/usr/bin/env bash
# Tier-1 verification: the test suite plus the benchmark harness in
# interpret mode (no TPU required).  Run from anywhere:
#
#   scripts/verify.sh            # quick benchmark sweep (BENCH_QUICK=1)
#   BENCH_FULL=1 scripts/verify.sh   # full Box/Star x r x t traffic grid
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
if [ -z "${BENCH_FULL:-}" ]; then
  export BENCH_QUICK=1
fi

# Tier-1 (ROADMAP.md).  The seed test debt is zero: any failure is a real
# regression, so fail fast before the benchmark smoke.
python -m pytest -x -q

# Stamp the harness start so the gate below can prove the traffic JSON was
# produced by THIS run (benchmarks/run.py deliberately swallows per-module
# failures, and a stale gitignored .quick.json would otherwise satisfy it).
BENCH_STAMP="$(mktemp)"
export BENCH_STAMP

python benchmarks/run.py

# The benchmark smoke must include at least one freshly measured 3D
# halo-plane traffic case (DESIGN.md §9), with the sub-blocked
# amplification strictly below the whole-slab foil's 9x (the ISSUE-4
# acceptance criterion), and at least one wide-grid column-tiled case
# (DESIGN.md §10) whose read amplification stays below the whole-width
# 3x foil with a genuinely positive resolved w_tile (ISSUE-5).
python - <<'EOF'
import json, os
path = "BENCH_kernels.quick.json" if os.environ.get("BENCH_QUICK") \
    else "BENCH_kernels.json"
assert os.path.getmtime(path) >= os.path.getmtime(os.environ["BENCH_STAMP"]), \
    f"{path} was not rewritten by this run (traffic benchmark failed?)"
with open(path) as f:
    data = json.load(f)
# Per-case wall-clock budgets (benchmarks/timing.case_budget) may record
# a case as timed_out instead of wedging the run; tolerate those rows but
# require the surviving measurements to be non-empty.
timed_out = [c["case"] for group in ("cases", "cases_3d", "cases_wide")
             for c in data[group] if c.get("timed_out")]
if timed_out:
    print(f"verify: WARNING {len(timed_out)} case(s) timed out: {timed_out}")
cases = [c for c in data["cases_3d"] if not c.get("timed_out")]
assert cases, f"no (surviving) 3D traffic cases in {path}"
for c in cases:
    assert c["read_bytes_step_direct_subblocked"] < \
        c["read_bytes_step_direct_wholestrip"], c["case"]
    assert c["read_amp_subblocked"] < c["read_amp_wholestrip"], c["case"]
wide = [c for c in data["cases_wide"] if not c.get("timed_out")]
assert wide, f"no (surviving) wide-grid column-tiled cases in {path}"
for c in wide:
    assert c["w_tile"] > 0 and c["w_block"] > 0, c["case"]
    assert c["read_amp_coltiled"] < c["read_amp_wholestrip"], c["case"]
    assert c["read_bytes_step_direct_coltiled"] < \
        c["read_bytes_step_direct_wholestrip"], c["case"]
# A clean run must degrade NOTHING: the guard layer's event log (dumped
# into the JSON by benchmarks/traffic.py) has to be empty -- any entry
# means a kernel failed and silently fell down the degradation ladder.
guard = data.get("guard_events", {})
assert guard.get("events", []) == [], \
    f"guard events on a clean run: {guard['events']}"
assert guard.get("dropped", 0) == 0, "guard event ring buffer overflowed"
stats = data.get("plan_stats", {})
for k in ("build_failures", "exec_failures", "fallbacks"):
    assert stats.get(k, 0) == 0, f"clean run but plan_stats[{k!r}]={stats[k]}"
print(f"verify: {len(cases)} 3D traffic case(s) in {path}, "
      "sub-blocked < whole-slab; "
      f"{len(wide)} wide case(s), column-tiled < whole-width foil; "
      "guard event log clean")
EOF
rm -f "$BENCH_STAMP"
