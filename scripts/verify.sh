#!/usr/bin/env bash
# Tier-1 verification: the test suite plus the benchmark harness in
# interpret mode (no TPU required).  Run from anywhere:
#
#   scripts/verify.sh            # quick benchmark sweep (BENCH_QUICK=1)
#   BENCH_FULL=1 scripts/verify.sh   # full Box/Star x r x t traffic grid
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
if [ -z "${BENCH_FULL:-}" ]; then
  export BENCH_QUICK=1
fi

# Tier-1 (ROADMAP.md).  The seed test debt is zero: any failure is a real
# regression, so fail fast before the benchmark smoke.
python -m pytest -x -q

python benchmarks/run.py
