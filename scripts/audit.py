#!/usr/bin/env python
"""Static plan audit matrix: prove model==code across the registry.

Runs :func:`repro.audit.audit_context` for every registered backend over
the 1D / 2D / 3D grid matrix -- including the column-tiled remainder
widths (W not divisible by w_tile, DESIGN.md §10) and off-128 3D grids
-- writes the machine-readable ``AUDIT_report.json`` (uploaded as a CI
artifact), prints one summary line per audit, and exits nonzero if ANY
check is violated.  Everything is static: no kernel executes, so the
sweep runs in seconds on a CPU-only container.

    PYTHONPATH=src python scripts/audit.py [--out AUDIT_report.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

import numpy as np  # noqa: E402

from repro import audit  # noqa: E402
from repro.kernels import registry  # noqa: E402
from repro.stencil.spec import StencilSpec  # noqa: E402
from repro.stencil.weights import jacobi_weights  # noqa: E402

# (grid, t, spec kwargs, pinned substrate kwargs): the paper's three ranks,
# both halo substrates, divisible and remainder widths.  Pinned w_tile on
# the remainder cases forces the edge-tile path regardless of the VMEM
# budget's auto choice.
MATRIX = [
    ((1000,), 2, dict(dim=1, radius=1, shape="star"), {}),
    ((4096,), 3, dict(dim=1, radius=2, shape="star"), {}),
    ((256, 512), 2, dict(dim=2, radius=1, shape="box"), {}),
    ((256, 512), 3, dict(dim=2, radius=2, shape="star"), {}),
    ((128, 257), 2, dict(dim=2, radius=1, shape="box"),
     dict(w_tile=128, w_block=32)),
    ((128, 300), 2, dict(dim=2, radius=1, shape="star"),
     dict(w_tile=128, w_block=32)),
    ((32, 64, 128), 2, dict(dim=3, radius=1, shape="box"), {}),
    ((24, 48, 100), 2, dict(dim=3, radius=1, shape="star"), {}),
    # Boundary-mode rows (DESIGN.md §15): non-periodic index maps swap
    # mod-wrap for reflect-at-block; the mode-aware coverage check must
    # hold on every rank, including a remainder width and a mixed 3D
    # spec.  fused_matmul rejects t>1 non-periodic (ValueError ->
    # incompatible_configs), which is itself part of the contract.
    ((256, 512), 2, dict(dim=2, radius=1, shape="box"),
     dict(boundary="reflect")),
    ((256, 512), 2, dict(dim=2, radius=2, shape="star"),
     dict(boundary=("zero", "replicate"))),
    ((128, 300), 2, dict(dim=2, radius=1, shape="star"),
     dict(w_tile=128, w_block=32, boundary=("reflect", "periodic"))),
    ((1000,), 2, dict(dim=1, radius=1, shape="star"),
     dict(boundary="replicate")),
    ((32, 64, 128), 2, dict(dim=3, radius=1, shape="box"),
     dict(boundary=("reflect", "periodic", "zero"))),
]


def _context(grid, t, spec_kw, pinned):
    from repro.stencil.boundary import resolve_boundary
    spec = StencilSpec(**spec_kw)
    return registry.PlanContext(
        spec=spec, weights=jacobi_weights(spec), grid_shape=grid,
        dtype=np.dtype(np.float32), t=t, tile_m=None, tile_n=None,
        interpret=True,
        h_block=pinned.get("h_block"), z_slab=pinned.get("z_slab"),
        z_block=pinned.get("z_block"), w_tile=pinned.get("w_tile"),
        w_block=pinned.get("w_block"),
        boundary=resolve_boundary(pinned.get("boundary"), len(grid)))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="AUDIT_report.json",
                    help="report path (default AUDIT_report.json)")
    args = ap.parse_args(argv)

    reports, skipped_cfg = [], []
    violations = 0
    for grid, t, spec_kw, pinned in MATRIX:
        for name in registry.registered_backends():
            ctx = _context(grid, t, spec_kw, pinned)
            try:
                rep = audit.audit_context(ctx, name)
            except ValueError as e:
                # The backend itself rejects this configuration (e.g. the
                # whole-strip foil refuses column tiling) -- the builder
                # raises identically, so there is no plan to audit.
                skipped_cfg.append({"backend": name, "grid": list(grid),
                                    "t": t, "reason": str(e)})
                continue
            print(rep.summary())
            reports.append(rep)
            violations += len(rep.violations)

    audited = [r for r in reports if r.exempt is None]
    payload = {
        "ok": violations == 0,
        "n_audits": len(audited),
        "n_exempt": len(reports) - len(audited),
        "n_violations": violations,
        "n_checks": sum(len(r.checks) for r in reports),
        "incompatible_configs": skipped_cfg,
        "reports": [r.to_dict() for r in reports],
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"audit: {len(audited)} audits ({payload['n_checks']} checks), "
          f"{payload['n_exempt']} exempt, "
          f"{len(skipped_cfg)} incompatible configs, "
          f"{violations} violations -> {args.out}")
    return 1 if violations else 0


if __name__ == "__main__":
    raise SystemExit(main())
