#!/usr/bin/env python
"""Lint: every REPRO_* environment READ must go through core/envutil.

The repo's env knobs (REPRO_VMEM_BUDGET, REPRO_PLAN_CACHE_SIZE,
REPRO_FAULTS, REPRO_AUDIT, ...) are parsed and validated in ONE place --
``repro.core.envutil`` -- so malformed values fail loudly with a uniform
message and tests can reason about caching.  A scattered
``os.environ.get("REPRO_...")`` silently reintroduces ad-hoc parsing;
this AST walker flags any such read outside the allowlist:

  * ``core/envutil.py``     -- the accessor itself;
  * ``kernels/guard.py``    -- the VMEM-retune context manager MUTATES
    the var and must save/restore the raw value verbatim (round-tripping
    through a parser would destroy malformed-but-restorable values);
  * ``testing/faults.py``   -- the fast-path presence probe (`in
    os.environ`) that keeps unarmed fault hooks at nanoseconds.

WRITES (`os.environ[k] = v`, `.pop`, `del`) are allowed everywhere:
the rule governs how configuration is consumed, not produced.

    python scripts/lint_env.py [root]    # exit 1 on violations
"""
from __future__ import annotations

import ast
import os
import sys

ALLOWLIST = {
    os.path.join("core", "envutil.py"),
    os.path.join("kernels", "guard.py"),
    os.path.join("testing", "faults.py"),
}

PREFIX = "REPRO_"


def _const_str(node):
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def _is_environ(node) -> bool:
    """Matches ``os.environ`` and bare ``environ`` (from-imports)."""
    if isinstance(node, ast.Attribute) and node.attr == "environ":
        return True
    return isinstance(node, ast.Name) and node.id == "environ"


def _names_repro(tree, node) -> bool:
    """Does this expression name a REPRO_* key?  Literal keys only --
    the repo's env vars are all referenced by literal or by a module
    constant whose literal value we resolve from the same file."""
    s = _const_str(node)
    if s is not None:
        return s.startswith(PREFIX)
    if isinstance(node, ast.Name):
        val = _module_constants(tree).get(node.id)
        return val is not None and val.startswith(PREFIX)
    return False


_CONST_CACHE: dict = {}


def _module_constants(tree):
    key = id(tree)
    if key not in _CONST_CACHE:
        consts = {}
        for stmt in ast.walk(tree):
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                    and isinstance(stmt.targets[0], ast.Name):
                v = _const_str(stmt.value)
                if v is not None:
                    consts[stmt.targets[0].id] = v
        _CONST_CACHE[key] = consts
    return _CONST_CACHE[key]


def find_violations(path: str, src: str):
    """(line, snippet) for each direct REPRO_* env READ in ``src``."""
    tree = ast.parse(src, filename=path)
    out = []

    def flag(node, what):
        out.append((node.lineno, what))

    for node in ast.walk(tree):
        # os.environ.get("REPRO_X") / os.getenv("REPRO_X")
        if isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Attribute) and f.attr == "get" \
                    and _is_environ(f.value) and node.args \
                    and _names_repro(tree, node.args[0]):
                flag(node, "os.environ.get of a REPRO_* key")
            if isinstance(f, ast.Attribute) and f.attr == "getenv" \
                    and node.args and _names_repro(tree, node.args[0]):
                flag(node, "os.getenv of a REPRO_* key")
            if isinstance(f, ast.Name) and f.id == "getenv" \
                    and node.args and _names_repro(tree, node.args[0]):
                flag(node, "getenv of a REPRO_* key")
        # os.environ["REPRO_X"] in Load context (subscript reads)
        if isinstance(node, ast.Subscript) and _is_environ(node.value) \
                and isinstance(node.ctx, ast.Load) \
                and _names_repro(tree, node.slice):
            flag(node, "os.environ[...] read of a REPRO_* key")
        # "REPRO_X" in os.environ (presence probes are reads too)
        if isinstance(node, ast.Compare) \
                and any(isinstance(op, (ast.In, ast.NotIn))
                        for op in node.ops) \
                and _names_repro(tree, node.left) \
                and any(_is_environ(c) for c in node.comparators):
            flag(node, "membership probe of a REPRO_* key in os.environ")
    return out


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    root = argv[0] if argv else os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", "src", "repro")
    root = os.path.abspath(root)
    bad = 0
    checked = 0
    for dirpath, _dirnames, filenames in os.walk(root):
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            rel = os.path.relpath(path, root)
            if rel in ALLOWLIST:
                continue
            checked += 1
            with open(path) as f:
                src = f.read()
            for line, what in find_violations(path, src):
                print(f"lint_env: {rel}:{line}: {what}; route REPRO_* "
                      "reads through repro.core.envutil")
                bad += 1
    print(f"lint_env: {checked} files checked, {bad} violation(s)")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
