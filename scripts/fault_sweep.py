#!/usr/bin/env python
"""Fault-injection sweep: prove every degradation-ladder rung reachable.

For each injected failure class (compile, vmem, nan, halo) the sweep
asserts the ISSUE-6 acceptance bar:

  * execution COMPLETES (no raw traceback escapes the guard layer),
  * the surviving rung's f32 output is bit-for-bit equal to the
    reference oracle,
  * the recorded cause matches the injected fault,

and the ``clean`` leg asserts the converse -- with nothing armed, the
guard degrades NOTHING: the guarded plan IS the cached unguarded plan
object, the event log stays empty, and outputs are bitwise identical.
The ``boundary`` leg re-runs the halo fault on a non-periodic
(reflect x periodic) distributed plan: degradation must preserve the
boundary spec (DESIGN.md §15), bitwise vs the mode-matched oracle.

Each leg runs in a subprocess with the fault armed via the REPRO_FAULTS
environment variable (exactly how the CI matrix legs arm it), so plan
caches, fault counters, and the XLA device count are isolated per leg.

  python scripts/fault_sweep.py                # all legs
  python scripts/fault_sweep.py vmem nan       # a subset
  REPRO_FAULTS=compile:inf \\
      python scripts/fault_sweep.py --child compile   # one leg in-process
"""
from __future__ import annotations

import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")
sys.path.insert(0, SRC)

#: leg -> (REPRO_FAULTS value, extra env)
LEGS = {
    "clean": ("", {}),
    "compile": ("compile:inf", {}),
    "vmem": ("vmem", {}),
    "nan": ("nan", {"REPRO_NAN_WATCHDOG": "1"}),
    "halo": ("halo", {"XLA_FLAGS": "--xla_force_host_platform_device_count=2"}),
    "boundary": ("halo",
                 {"XLA_FLAGS": "--xla_force_host_platform_device_count=2"}),
    "sparse": ("vmem", {}),
    "sparse_ladder": ("compile:inf", {}),
}


def _setup2d():
    import numpy as np
    from repro.stencil import StencilSpec, make_weights
    from repro.kernels.ref import stencil_direct_ref
    import jax.numpy as jnp

    w = make_weights(StencilSpec("box", 2, 1), seed=0)
    x = np.random.default_rng(0).normal(size=(64, 128)).astype(np.float32)
    ref = np.asarray(stencil_direct_ref(jnp.asarray(x), jnp.asarray(w), 2))
    return w, x, ref


def _bitwise(y, ref, label):
    import numpy as np
    assert np.array_equal(np.asarray(y), ref), \
        f"{label}: surviving rung not bit-for-bit vs reference oracle"


def leg_clean():
    """Nothing armed: the guard must be invisible."""
    import jax.numpy as jnp
    from repro.core import events
    from repro.kernels import (guarded_stencil_plan, plan_cache_stats,
                               stencil_plan)

    w, x, ref = _setup2d()
    p0 = stencil_plan(w, x.shape, x.dtype.type, 2, backend="fused_direct")
    g = guarded_stencil_plan(w, x.shape, x.dtype.type, 2,
                             backend="fused_direct")
    assert g.plan is p0, "clean: guarded plan != cached unguarded plan"
    y = g(jnp.asarray(x))
    assert not g.degraded and g.history == []
    assert events.events() == [], f"clean: events {events.events()}"
    st = plan_cache_stats()
    for k in ("build_failures", "exec_failures", "fallbacks"):
        assert st[k] == 0, (k, st)
    _bitwise(y, ref, "clean")
    _bitwise(p0(jnp.asarray(x)), ref, "clean-unguarded")


def leg_compile():
    """Every Pallas compile fails: the ladder must bottom out on the
    reference oracle with cause 'compile' at every failed rung."""
    import jax.numpy as jnp
    from repro.kernels import guarded_stencil_plan

    w, x, ref = _setup2d()
    g = guarded_stencil_plan(w, x.shape, x.dtype.type, 2,
                             backend="fused_matmul_reuse")
    y = g(jnp.asarray(x))
    assert g.backend == "reference", g.rung
    assert g.history and all(h["cause"] == "compile" for h in g.history), \
        g.history
    _bitwise(y, ref, "compile")


def leg_vmem():
    """One VMEM overflow: the degraded-geometry rung of the SAME backend
    must survive (budget halved, geometry re-resolved)."""
    import jax.numpy as jnp
    from repro.kernels import guarded_stencil_plan

    w, x, ref = _setup2d()
    g = guarded_stencil_plan(w, x.shape, x.dtype.type, 2,
                             backend="fused_direct")
    y = g(jnp.asarray(x))
    assert g.rung == "fused_direct+degraded", g.rung
    assert [h["cause"] for h in g.history] == ["vmem"], g.history
    _bitwise(y, ref, "vmem")


def leg_nan():
    """A NaN-corrupted step: the watchdog (armed via REPRO_NAN_WATCHDOG)
    must recover THIS step through the checked backend, record cause
    'numerical', and demote the rung for future calls."""
    import jax.numpy as jnp
    from repro.core import events
    from repro.kernels import guarded_stencil_plan

    w, x, ref = _setup2d()
    g = guarded_stencil_plan(w, x.shape, x.dtype.type, 2,
                             backend="fused_direct")
    assert g.watchdog, "REPRO_NAN_WATCHDOG=1 not honored"
    y = g(jnp.asarray(x))
    assert [h["cause"] for h in g.history] == ["numerical"], g.history
    assert events.events("guard_watchdog"), "no watchdog event recorded"
    _bitwise(y, ref, "nan")
    # the demoted rung keeps producing oracle-grade output
    _bitwise(g(jnp.asarray(x)), ref, "nan-demoted")


def leg_halo():
    """A failed halo exchange on a 2-device mesh: the guard retries on
    the next rung (deterministic from the plan key, so both shards
    agree) and the stepper completes."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.stencil import StencilSpec, make_weights
    from repro.stencil.reference import apply_stencil_steps
    from repro.kernels import guarded_stencil_plan

    assert len(jax.devices()) >= 2, "halo leg needs a multi-device mesh"
    mesh = Mesh(np.array(jax.devices()[:2]), ("x",))
    w = make_weights(StencilSpec("box", 2, 1), seed=0)
    t, n = 2, 64
    x = np.random.default_rng(0).normal(size=(n, n)).astype(np.float32)
    xs = jax.device_put(x, NamedSharding(mesh, P("x", None)))
    ref = np.asarray(apply_stencil_steps(jnp.asarray(x), jnp.asarray(w), t))

    g = guarded_stencil_plan(w, (n, n), np.float32, t, mesh=mesh,
                             shard_spec=("x", None), dist_mode="fused",
                             backend="fused_direct")
    y = g(xs)
    assert [h["cause"] for h in g.history] == ["halo"], g.history
    assert g.degraded
    _bitwise(y, ref, "halo")


def leg_boundary():
    """A failed halo exchange on a NON-PERIODIC distributed plan
    (DESIGN.md §15): the PR 6 ladder must degrade exactly as on the
    periodic path -- cause 'halo' recorded, both shards landing on the
    same rung -- and the surviving rung must still honor the boundary
    spec, bitwise vs the mode-matched oracle."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.stencil import StencilSpec, make_weights
    from repro.stencil.reference import apply_stencil_steps
    from repro.kernels import guarded_stencil_plan

    assert len(jax.devices()) >= 2, "boundary leg needs a multi-device mesh"
    mesh = Mesh(np.array(jax.devices()[:2]), ("x",))
    w = make_weights(StencilSpec("box", 2, 1), seed=0)
    t, n, boundary = 2, 64, ("reflect", "periodic")
    x = np.random.default_rng(0).normal(size=(n, n)).astype(np.float32)
    xs = jax.device_put(x, NamedSharding(mesh, P("x", None)))
    ref = np.asarray(apply_stencil_steps(jnp.asarray(x), jnp.asarray(w), t,
                                         boundary))

    # stepwise, not fused: fused halo exchange rejects non-periodic specs
    # (it would bake step-1 boundary values into both steps).
    g = guarded_stencil_plan(w, (n, n), np.float32, t, mesh=mesh,
                             shard_spec=("x", None), dist_mode="stepwise",
                             backend="fused_direct", boundary=boundary)
    y = g(xs)
    assert [h["cause"] for h in g.history] == ["halo"], g.history
    assert g.degraded
    _bitwise(y, ref, "boundary")


def leg_sparse():
    """One VMEM overflow on the sparse-compacted rung: the degraded
    geometry of the SAME sparse backend must survive -- bitwise vs the
    dense MXU plan (the compaction contract, DESIGN.md §14) and allclose
    vs the oracle (the MXU contraction orders its f32 sums differently
    from the direct reference, so bitwise is dense-vs-sparse, not
    MXU-vs-VPU)."""
    import numpy as np
    import jax.numpy as jnp
    from repro.kernels import guarded_stencil_plan, stencil_plan

    w, x, ref = _setup2d()
    g = guarded_stencil_plan(w, x.shape, x.dtype.type, 2,
                             backend="fused_sparse_matmul")
    y = g(jnp.asarray(x))
    assert g.rung == "fused_sparse_matmul+degraded", g.rung
    assert [h["cause"] for h in g.history] == ["vmem"], g.history
    dense = stencil_plan(w, x.shape, x.dtype.type, 2,
                         backend="fused_matmul_reuse")
    _bitwise(y, np.asarray(dense(jnp.asarray(x))), "sparse-vs-dense")
    assert np.allclose(np.asarray(y), ref, rtol=1e-5, atol=1e-5), \
        "sparse: surviving rung drifted from the reference oracle"


def leg_sparse_ladder():
    """Every Pallas compile fails from the sparse rung: the walk must
    pass straight down the dense ladder and bottom out on the reference
    oracle with cause 'compile' at every failed rung."""
    import jax.numpy as jnp
    from repro.kernels import guarded_stencil_plan

    w, x, ref = _setup2d()
    g = guarded_stencil_plan(w, x.shape, x.dtype.type, 2,
                             backend="fused_sparse_matmul")
    y = g(jnp.asarray(x))
    assert g.backend == "reference", g.rung
    assert g.history and all(h["cause"] == "compile" for h in g.history), \
        g.history
    _bitwise(y, ref, "sparse_ladder")


def run_child(leg: str) -> None:
    fn = {"clean": leg_clean, "compile": leg_compile, "vmem": leg_vmem,
          "nan": leg_nan, "halo": leg_halo, "boundary": leg_boundary,
          "sparse": leg_sparse, "sparse_ladder": leg_sparse_ladder}[leg]
    fn()
    print(f"PASS {leg}")


def main(argv) -> int:
    if argv[:1] == ["--child"]:
        run_child(argv[1])
        return 0
    legs = argv or list(LEGS)
    unknown = [l for l in legs if l not in LEGS]
    if unknown:
        print(f"unknown leg(s) {unknown}; choose from {list(LEGS)}",
              file=sys.stderr)
        return 2
    failures = []
    for leg in legs:
        faults, extra = LEGS[leg]
        env = dict(os.environ)
        env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
        env.pop("REPRO_FAULTS", None)
        if faults:
            env["REPRO_FAULTS"] = faults
        env.update(extra)
        r = subprocess.run(
            [sys.executable, os.path.abspath(__file__), "--child", leg],
            capture_output=True, text=True, env=env, timeout=900)
        status = "PASS" if r.returncode == 0 else "FAIL"
        print(f"fault_sweep: {status} {leg} "
              f"(REPRO_FAULTS={faults or '<unset>'})")
        if r.returncode != 0:
            failures.append(leg)
            print(r.stdout, file=sys.stderr)
            print(r.stderr, file=sys.stderr)
    if failures:
        print(f"fault_sweep: FAILED legs: {failures}", file=sys.stderr)
        return 1
    print(f"fault_sweep: all {len(legs)} leg(s) passed -- every ladder "
          "rung reachable, causes recorded, outputs bitwise vs oracle")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
