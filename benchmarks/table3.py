"""Paper Table 3: the six representative cases -- scenario classification,
bottlenecks, and predicted performance direction, reproduced from the
analytical criteria.  The paper's empirically observed direction is listed
for comparison (down / approx / up)."""
from __future__ import annotations

from repro.core import perfmodel as pm
from repro.stencil import StencilSpec

CASES = [
    # (pattern, t, dtype_bytes, hw, S, sparse_unit, paper_observed)
    ("Box-2D1R", 3, 8, pm.A100_DOUBLE, 0.5, False, "down"),
    ("Box-2D3R", 1, 8, pm.A100_DOUBLE, 0.5, False, "approx"),
    ("Box-2D1R", 7, 4, pm.A100_FLOAT, 0.47, True, "up"),
    ("Box-2D7R", 1, 4, pm.A100_FLOAT, 0.47, True, "up"),
    ("Box-3D1R", 3, 8, pm.A100_DOUBLE, 0.5, False, "down"),
    ("Box-3D1R", 7, 4, pm.A100_FLOAT, 0.47, True, "down"),
]


def _direction(speedup: float) -> str:
    if speedup > 1.05:
        return "up"
    if speedup < 0.95:
        return "down"
    return "approx"


def run() -> list[str]:
    out = ["table3.case,pattern,t,hw,scenario,I_vec,I_mat,ridge_vec,ridge_mat,"
           "bottleneck_vec,bottleneck_mat,pred_speedup,pred_dir,paper_dir,match"]
    for i, (name, t, D, hw, S, sp, observed) in enumerate(CASES, 1):
        spec = StencilSpec.from_name(name)
        w = pm.StencilWorkload(spec, t, D)
        c = pm.compare(w, hw, S, use_sparse_unit=sp)
        pred = _direction(c.speedup)
        ridge_m = hw.ridge_sparse if sp else hw.ridge_matrix
        out.append(
            f"table3.case{i},{name},{t},{hw.name.split()[0]},S{c.scenario.value},"
            f"{c.vector.intensity:.2f},{c.matrix.intensity:.2f},"
            f"{hw.ridge_vector:.0f},{ridge_m:.0f},"
            f"{c.vector.bound.value},{c.matrix.bound.value},"
            f"{c.speedup:.3f},{pred},{observed},"
            f"{'YES' if pred == observed else 'NO'}")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
