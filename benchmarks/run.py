"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` style CSV lines; each sub-benchmark
documents its own columns in the header line it emits."""
from __future__ import annotations

import time
import traceback


def main() -> None:
    from benchmarks import table2, table3, table4, fig10, fig16, halo, scaling

    for mod in (table2, table3, table4, fig10, fig16, halo, scaling):
        t0 = time.perf_counter()
        try:
            lines = mod.run()
            dt = (time.perf_counter() - t0) * 1e6
            for line in lines:
                print(line)
            print(f"bench.{mod.__name__.split('.')[-1]}.total,"
                  f"{dt:.0f},us_wall")
        except Exception as e:
            traceback.print_exc()
            print(f"bench.{mod.__name__.split('.')[-1]}.FAILED,0,{e}")


if __name__ == "__main__":
    main()
