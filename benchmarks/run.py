"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` style CSV lines; each sub-benchmark
documents its own columns in the header line it emits.

Wall-clock numbers inside the benchmarks come from ``benchmarks.timing
.time_us`` (warmup + ``block_until_ready`` per call), so they measure
steady-state execution, never import or trace+compile.  The harness-level
``bench.<mod>.total`` line is bookkeeping (how long the module took to
produce its lines), timed AFTER all modules are imported.

Set BENCH_QUICK=1 to trim the slowest sweeps (used by scripts/verify.sh).

Per-module failures are swallowed (the sweep must finish and report every
module it can) but never lost: each run writes ``BENCH_run.json`` -- the
manifest of which modules succeeded and which failed, with the error
string -- and ``scripts/verify.sh`` gates on that manifest BY NAME
instead of inferring health from output-file timestamps.
"""
from __future__ import annotations

import json
import os
import sys
import time
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), ".."))

MANIFEST_PATH = "BENCH_run.json"


def main() -> None:
    # Import everything up front: module import cost must never leak into
    # any timed region.
    from benchmarks import (fig10, fig16, halo, scaling, table2, table3,
                            table4, traffic)
    from repro.kernels import plan_cache_stats

    modules = []
    for mod in (table2, table3, table4, fig10, fig16, halo, scaling, traffic):
        name = mod.__name__.split(".")[-1]
        t0 = time.perf_counter()
        try:
            lines = mod.run()
            dt = (time.perf_counter() - t0) * 1e6
            for line in lines:
                print(line)
            print(f"bench.{name}.total,{dt:.0f},us_wall")
            modules.append({"module": name, "ok": True,
                            "wall_us": round(dt)})
        except Exception as e:
            traceback.print_exc()
            print(f"bench.{name}.FAILED,0,{e}")
            modules.append({"module": name, "ok": False,
                            "error": f"{type(e).__name__}: {e}"})

    # bookkeeping: one plan per distinct kernel signature across the whole
    # harness; hits = timed paths that reused an already-built plan
    st = plan_cache_stats()
    print(f"bench.plan_cache,{st['misses']},plans_built,"
          f"{st['hits']},cache_hits")

    with open(MANIFEST_PATH, "w") as f:
        json.dump({
            "quick": bool(os.environ.get("BENCH_QUICK")),
            "modules": modules,
            "failed": [m["module"] for m in modules if not m["ok"]],
            "succeeded": [m["module"] for m in modules if m["ok"]],
        }, f, indent=1)


if __name__ == "__main__":
    main()
