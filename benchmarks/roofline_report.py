"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from the
cached dry-run JSONs (results/dryrun/*.json).

    PYTHONPATH=src:. python -m benchmarks.roofline_report [--update]

--update rewrites the AUTOGEN block inside EXPERIMENTS.md in place.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..")
DRY = os.path.join(ROOT, "results", "dryrun")

ARCH_ORDER = ["llama3.2-1b", "glm4-9b", "deepseek-7b", "tinyllama-1.1b",
              "internvl2-2b", "whisper-base", "zamba2-1.2b", "olmoe-1b-7b",
              "qwen3-moe-235b-a22b", "rwkv6-1.6b"]
CELL_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _load(tag_filter=""):
    recs = []
    for f in sorted(glob.glob(os.path.join(DRY, "*.json"))):
        r = json.load(open(f))
        if r.get("tag", "") == tag_filter or (tag_filter == "" and "tag" not in r):
            recs.append(r)
    return recs


def _fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/2**30:.2f}GiB"


def _ms(s):
    return f"{s*1e3:.1f}"


def paper_scenario(r):
    """Annotate each cell with its paper-§4.1 scenario analogue."""
    t = r["roofline"]
    bt = t["bottleneck"]
    if bt == "memory":
        return "S1-like (MB: matrix units indifferent)"
    if bt == "compute":
        return "S4-like (CB: matrix-unit ceiling is the limit)"
    return "collective-bound (beyond the paper's single-chip model)"


def roofline_table(recs, mesh):
    lines = [
        "| arch | cell | chips | compute(ms) | memory(ms) | collective(ms) | "
        "bottleneck | MODEL_FLOPs/chip | useful frac | peak HBM/dev |",
        "|---|---|--:|--:|--:|--:|---|--:|--:|--:|",
    ]
    for arch in ARCH_ORDER + sorted({r["arch"] for r in recs
                                     if r["arch"].startswith("stencil")}):
        for cell in CELL_ORDER + ["t2", "t4"]:
            for r in recs:
                if r["arch"] != arch or r["cell"] != cell or r["mesh"] != mesh:
                    continue
                if not r.get("ok"):
                    lines.append(f"| {arch} | {cell} | - | FAILED: "
                                 f"{r.get('error','')[:60]} |")
                    continue
                t = r["roofline"]
                mf = t.get("model_flops")
                uf = t.get("useful_fraction")
                peak = (r.get("memory") or {}).get("peak_bytes")
                lines.append(
                    f"| {arch} | {cell} | {r.get('n_chips','-')} | "
                    f"{_ms(t['compute_s'])} | {_ms(t['memory_s'])} | "
                    f"{_ms(t['collective_s'])} | **{t['bottleneck']}** | "
                    f"{(mf or 0)/ (r.get('n_chips') or 1)/1e12:.2f}T | "
                    f"{uf if uf is None else round(uf,3)} | {_fmt_bytes(peak)} |")
    return lines


def summary(recs):
    n_ok = sum(1 for r in recs if r.get("ok"))
    by_bottleneck = {}
    for r in recs:
        if r.get("ok"):
            b = r["roofline"]["bottleneck"]
            by_bottleneck[b] = by_bottleneck.get(b, 0) + 1
    return n_ok, len(recs), by_bottleneck


def render(tag=""):
    recs = _load(tag)
    out = []
    n_ok, n, bb = summary(recs)
    out.append(f"**{n_ok}/{n} cells compiled OK** "
               f"(bottleneck distribution: {bb}).\n")
    for mesh in ("single", "multi"):
        chips = 256 if mesh == "single" else 512
        out.append(f"\n### Mesh: {mesh} "
                   f"({'16x16 (data,model)' if mesh=='single' else '2x16x16 (pod,data,model)'},"
                   f" {chips} chips)\n")
        out.extend(roofline_table(recs, mesh))
    return "\n".join(out)


BEGIN = "<!-- AUTOGEN:ROOFLINE BEGIN -->"
END = "<!-- AUTOGEN:ROOFLINE END -->"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--update", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    text = render(args.tag)
    if args.update:
        path = os.path.join(ROOT, "EXPERIMENTS.md")
        doc = open(path).read()
        pre, rest = doc.split(BEGIN)
        _, post = rest.split(END)
        open(path, "w").write(pre + BEGIN + "\n" + text + "\n" + END + post)
        print(f"updated {path}")
    else:
        print(text)


if __name__ == "__main__":
    main()
