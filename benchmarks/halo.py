"""Distributed-stencil communication benchmark (beyond-paper: the paper's
fusion-redundancy trade measured on the cluster axis).

For the production 16x16 decomposition of the paper's 10240^2 domain,
report per-t-steps halo traffic of stepwise vs fused execution and the
redundant-compute fraction fused execution pays (the distributed alpha) --
all analytic, cross-checked in tests against compiled collective counts."""
from __future__ import annotations

import numpy as np

from repro.stencil import StencilSpec
from repro.stencil.distributed import halo_bytes_per_step

CASES = [
    ("Box-2D1R", (10240 // 16, 10240 // 16), ("data", "model"), 4),
    ("Box-2D1R", (10240 // 16, 10240 // 16), ("data", "model"), 8),
    ("Star-2D3R", (10240 // 16, 10240 // 16), ("data", "model"), 2),
    ("Box-3D1R", (1024 // 16, 1024 // 16, 1024), ("data", "model", None), 4),
]


def run() -> list[str]:
    # NOTE: total halo BYTES per t steps are ~equal between modes (t small
    # exchanges vs 1 deep exchange); what fused execution buys is a t-fold
    # reduction in exchange ROUNDS (latency/message overhead, the term that
    # dominates at 256+ chips), paid for with redundant halo compute --
    # the distributed incarnation of the paper's alpha.
    out = ["halo.pattern,t,exchange_rounds_stepwise,exchange_rounds_fused,"
           "round_ratio,halo_bytes_per_t_steps,redundant_compute_frac"]
    for name, local, dims, t in CASES:
        spec = StencilSpec.from_name(name)
        r = spec.radius
        bf = halo_bytes_per_step(local, dims, r, t, "fused", 4)
        # redundant compute of fused mode: halo shells recomputed locally
        interior = np.prod(local)
        ext = np.prod([n + 2 * r * t if d is not None else n
                       for n, d in zip(local, dims)])
        redundant = (ext - interior) / interior
        out.append(f"halo.{name},{t},{t},1,{t}.00x,{bf},{redundant:.3f}")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
