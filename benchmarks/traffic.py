"""Substrate HBM-traffic benchmark: seed 9-neighbor scheme vs whole-strip
pipeline vs halo-row sub-blocked strips.

The paper's whole argument is that stencils are memory-bound (I = K/D,
Eq. 6), so the substrate's HBM traffic model IS the experiment: the seed
scheme streamed nine full (tile, tile) blocks per output tile (9x read
amplification); the whole-strip scheme loads three full-width strips (3x);
the sub-blocked scheme (DESIGN.md §3) loads each strip's own h-row blocks
plus ONE h-block per vertical neighbor (1 + 2h/strip_m, ~1.1-1.25x at the
benchmark strips), with the horizontal periodic halo materialized in-VMEM
for free in all strip schemes.

For Box/Star x r in {1,2,3} x t in {1,2,4,8} this emits, per substrate:
  * neighbor-block loads issued per output tile/strip (9 vs 3 vs
    strip_m/h + 2, analytic from the BlockSpec structure),
  * per-step HBM read bytes (analytic, including the banded operand on the
    MXU paths) -- the ``read_bytes_step_*_subblocked`` columns show the
    amplification falling from 3.0x to 1.125-1.25x for shallow halos
    (halo <= strip_m/8, the whole BENCH_QUICK sweep), climbing back toward
    3.0x only where t*r approaches the 32-row strip height,
  * measured us/step of the Pallas kernels (interpret mode on CPU -- honest
    relative numbers, labeled as such), VPU path and MXU path (seed
    monolithic vs strip ``fused_matmul_reuse``), executed through compiled
    ``stencil_plan`` objects so per-trial timing excludes selection, tile
    sizing and weight composition -- plan-build time is recorded separately
    (``plan_build_us_*`` in the JSON).

Results also land in BENCH_kernels.json (repo root) for cross-PR
trajectory tracking.
"""
from __future__ import annotations

import json
import os
import sys

import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), ".."))

from benchmarks.timing import time_us
from repro.kernels import common, legacy, stencil_plan
from repro.kernels.common import choose_hblock, substrate_read_amp
from repro.kernels.stencil_matmul import build_bands
from repro.stencil import StencilSpec, fuse_weights, make_weights

N = 128            # grid edge (small: interpret-mode kernels on CPU)
TILE = 32          # seed tile edge == strip height (fair per-cell VMEM)
SHAPES = ("box", "star")
RADII = (1, 2, 3)
DEPTHS = (1, 2, 4, 8)
#: BENCH_QUICK=1 trims the sweep (CI / verify.sh); default is the full
#: Box/Star x r{1,2,3} x t{1,2,4,8} grid of the ISSUE.
QUICK_RADII = (1,)
QUICK_DEPTHS = (1, 4)
DTYPE_BYTES = 4
#: Full sweeps land in BENCH_kernels.json (the cross-PR trajectory file);
#: BENCH_QUICK=1 sweeps go to a sibling .quick file so CI smoke runs never
#: clobber tracked full-grid data.
JSON_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_kernels.json")
JSON_PATH_QUICK = os.path.join(os.path.dirname(__file__), "..",
                               "BENCH_kernels.quick.json")


def _case(shape: str, r: int, t: int, x) -> dict:
    spec = StencilSpec(shape, 2, r)
    w = make_weights(spec, seed=r)
    wf = fuse_weights(w, t)
    halo = r * t                      # fused-regime vertical halo at TILE strips
    hb = choose_hblock(TILE, halo)

    bands_new = build_bands(w.astype(np.float32), TILE).shape
    bands_old = build_bands(wf.astype(np.float32), TILE).shape

    row = {
        "case": f"{spec.name}-t{t}", "shape": shape, "r": r, "t": t,
        "loads_per_tile_old": len(legacy.NEIGHBOR_OFFSETS_2D),
        "loads_per_tile_new": common.STRIP_NEIGHBOR_LOADS,
        "loads_per_tile_subblocked": TILE // hb + 2,
        "h_block": hb,
        "read_amp_subblocked": substrate_read_amp(TILE, hb),
        # one fused launch advances t steps: per-step read traffic
        "read_bytes_step_direct_old": legacy.hbm_read_bytes_per_step(
            (N, N), TILE, TILE, DTYPE_BYTES) / t,
        "read_bytes_step_direct_new": common.hbm_read_bytes_per_step(
            (N, N), TILE, DTYPE_BYTES) / t,
        "read_bytes_step_direct_subblocked": common.hbm_read_bytes_per_step(
            (N, N), TILE, DTYPE_BYTES, h_block=hb) / t,
        "read_bytes_step_matmul_old": legacy.hbm_read_bytes_per_step(
            (N, N), TILE, TILE, DTYPE_BYTES, bands_shape=bands_old) / t,
        "read_bytes_step_matmul_new": common.hbm_read_bytes_per_step(
            (N, N), TILE, DTYPE_BYTES, bands_shape=bands_new) / t,
        "read_bytes_step_matmul_subblocked": common.hbm_read_bytes_per_step(
            (N, N), TILE, DTYPE_BYTES, bands_shape=bands_new,
            h_block=hb) / t,
    }

    # Execution goes through compiled plans: selection/sizing/weight
    # composition happen at build (accounted separately below), the plan's
    # jitted callable is what gets timed -- time_us's warmup still absorbs
    # trace+compile, so the timed iterations are steady-state execution with
    # zero re-analysis.  Backends map the three substrates: the seed 9-tile
    # foil registers as legacy_*, the whole-strip pipeline as
    # *_wholestrip, and the default sub-blocked substrate as fused_direct /
    # fused_matmul_reuse (all degenerate to the plain kernels at t=1).
    paths = {
        "us_step_direct_old": stencil_plan(
            w, x.shape, x.dtype, t, backend="legacy_direct",
            tile_m=TILE, tile_n=TILE, interpret=True),
        "us_step_direct_new": stencil_plan(
            w, x.shape, x.dtype, t, backend="fused_direct_wholestrip",
            tile_m=TILE, interpret=True),
        "us_step_direct_subblocked": stencil_plan(
            w, x.shape, x.dtype, t, backend="fused_direct",
            tile_m=TILE, h_block=hb, interpret=True),
        # MXU paths: seed monolithic fusion vs strip intermediate reuse
        "us_step_matmul_old": stencil_plan(
            w, x.shape, x.dtype, t, backend="legacy_matmul",
            tile_m=TILE, tile_n=TILE, interpret=True),
        "us_step_matmul_new": stencil_plan(
            w, x.shape, x.dtype, t, backend="fused_matmul_reuse_wholestrip",
            tile_m=TILE, tile_n=TILE, interpret=True),
        "us_step_matmul_subblocked": stencil_plan(
            w, x.shape, x.dtype, t, backend="fused_matmul_reuse",
            tile_m=TILE, tile_n=TILE, h_block=hb, interpret=True),
    }
    iters = 2 if os.environ.get("BENCH_QUICK") else 5
    for key, plan in paths.items():
        row[key] = time_us(plan, x, iters=iters) / t
        # host-side plan construction (selection + sizing + composition),
        # paid once per signature -- never part of the per-step numbers
        row[key.replace("us_step_", "plan_build_us_")] = \
            plan.build_time_s * 1e6
    return row


def run() -> list[str]:
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(N, N)).astype(np.float32))
    quick = bool(os.environ.get("BENCH_QUICK"))
    radii = QUICK_RADII if quick else RADII
    depths = QUICK_DEPTHS if quick else DEPTHS
    rows = [_case(shape, r, t, x)
            for shape in SHAPES for r in radii for t in depths]

    with open(JSON_PATH_QUICK if quick else JSON_PATH, "w") as f:
        json.dump({"grid": N, "tile": TILE, "dtype_bytes": DTYPE_BYTES,
                   "quick": quick, "radii": list(radii),
                   "depths": list(depths),
                   "timing": "interpret-mode CPU (relative only)",
                   "cases": rows}, f, indent=1)

    out = ["traffic.case,loads_old/new/sub,read_amp_direct_new,"
           "read_amp_direct_sub,rdMB_step_mm_old,rdMB_step_mm_new,"
           "rdMB_step_mm_sub,us_dir_old,us_dir_new,us_dir_sub,"
           "us_mm_old,us_mm_new,us_mm_sub"]
    grid_bytes = N * N * DTYPE_BYTES
    for c in rows:
        amp_new = c["read_bytes_step_direct_new"] * c["t"] / grid_bytes
        amp_sub = c["read_bytes_step_direct_subblocked"] * c["t"] / grid_bytes
        out.append(
            f"traffic.{c['case']},{c['loads_per_tile_old']}/"
            f"{c['loads_per_tile_new']}/{c['loads_per_tile_subblocked']},"
            f"{amp_new:.2f}x,{amp_sub:.2f}x,"
            f"{c['read_bytes_step_matmul_old']/2**20:.3f},"
            f"{c['read_bytes_step_matmul_new']/2**20:.3f},"
            f"{c['read_bytes_step_matmul_subblocked']/2**20:.3f},"
            f"{c['us_step_direct_old']:.0f},{c['us_step_direct_new']:.0f},"
            f"{c['us_step_direct_subblocked']:.0f},"
            f"{c['us_step_matmul_old']:.0f},{c['us_step_matmul_new']:.0f},"
            f"{c['us_step_matmul_subblocked']:.0f}")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
