"""Substrate HBM-traffic benchmark: seed 9-neighbor scheme vs whole-strip
pipeline vs halo-row sub-blocked strips.

The paper's whole argument is that stencils are memory-bound (I = K/D,
Eq. 6), so the substrate's HBM traffic model IS the experiment: the seed
scheme streamed nine full (tile, tile) blocks per output tile (9x read
amplification); the whole-strip scheme loads three full-width strips (3x);
the sub-blocked scheme (DESIGN.md §3) loads each strip's own h-row blocks
plus ONE h-block per vertical neighbor (1 + 2h/strip_m, ~1.1-1.25x at the
benchmark strips), with the horizontal periodic halo materialized in-VMEM
for free in all strip schemes.

For Box/Star x r in {1,2,3} x t in {1,2,4,8} this emits, per substrate:
  * neighbor-block loads issued per output tile/strip (9 vs 3 vs
    strip_m/h + 2, analytic from the BlockSpec structure),
  * per-step HBM read bytes (analytic, including the banded operand on the
    MXU paths) -- the ``read_bytes_step_*_subblocked`` columns show the
    amplification falling from 3.0x to 1.125-1.25x for shallow halos
    (halo <= strip_m/8, the whole BENCH_QUICK sweep), climbing back toward
    3.0x only where t*r approaches the 32-row strip height,
  * measured us/step of the Pallas kernels (interpret mode on CPU -- honest
    relative numbers, labeled as such), VPU path and MXU path (seed
    monolithic vs strip ``fused_matmul_reuse``), executed through compiled
    ``stencil_plan`` objects so per-trial timing excludes selection, tile
    sizing and weight composition -- plan-build time is recorded separately
    (``plan_build_us_*`` in the JSON).

The 3D halo-plane substrate (DESIGN.md §9) gets its own sweep
(``cases_3d``): Box/Star-3D x r{1,2} x t{1,2} at fixed benchmark slab
sizes, whole-slab foil (9x) vs sub-blocked halo planes
((1 + 2h/strip_m)(1 + 2z_block/z_slab)x), with analytic
``read_bytes_step_*_{wholestrip,subblocked}`` columns and plan-timed
us/step for the VPU and intermediate-reuse MXU paths.

The sparse-compacted MXU regime (DESIGN.md §14) rides every 2D/3D case:
``band_sparsity`` / ``kept_row_fraction`` quantify the star-vs-box
structural sparsity of the banded operand, ``mxu_flops_step_sparse`` vs
``mxu_flops_step_dense`` show the compacted contraction executing exactly
S * dense MXU FLOPs (star < dense, box == dense), and
``us_step_matmul_sparse`` / ``sparse_bitwise_equal`` time the
``fused_sparse_matmul`` plan and prove its output bit-identical to the
dense reuse plan -- ``scripts/verify.sh`` gates on both.

The column-tiled W substrate (DESIGN.md §10) gets the wide-grid sweep
(``cases_wide``): a grid whose FULL-WIDTH strips exceed the VMEM budget
(REPRO_VMEM_BUDGET pinned for the case, so the auto sizing genuinely
escalates), whole-width 3-load foil (3x) vs the column-tiled substrate
((1 + 2h/strip_m)(1 + 2w_block/w_tile)x), with the resolved
(w_tile, w_block) recorded and ``scripts/verify.sh`` asserting the
column-tiled amplification stays below the whole-width foil.

Per-axis boundary modes (DESIGN.md §15) ride the sweep two ways: every
row carries a ``boundary`` column (the legacy sweeps are all-periodic,
the ``cases_boundary`` sweep times the sub-blocked VPU/MXU plans under
zero/reflect/replicate/mixed specs with a mode-matched oracle check),
and ``halo_overlap`` records the distributed overlap-vs-serialized
timing pair: a 2-device subprocess times the ``overlap`` stepper (one
dispatch, interior concurrent with the exchange) against the
serialized-exchange foil (per step: exchange dispatch, host sync,
compute dispatch -- the execution a runtime without overlap pays),
bitwise-equal outputs, with the trace-time interleave counters
(``interior_before_recv_consumed``) proving the interior launch never
waited on a recv.

Results also land in BENCH_kernels.json (repo root) for cross-PR
trajectory tracking.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), ".."))

from benchmarks.timing import CaseTimeout, case_budget, time_us
from repro.core import events as guard_events
from repro.kernels import common, legacy, plan_cache_stats, stencil_plan
from repro.kernels.common import (SubstrateGeom, choose_hblock,
                                  hbm_read_bytes_per_step_3d,
                                  resolve_substrate_geom,
                                  substrate_read_amp)
from repro.kernels.stencil_matmul import (band_sparsity, build_bands,
                                          build_bands_nd)
from repro.kernels.stencil_sparse import compact_bands, kept_row_fraction
from repro.stencil import StencilSpec, fuse_weights, make_weights
from repro.stencil.boundary import boundary_label, resolve_boundary
from repro.stencil.reference import apply_stencil_steps

N = 128            # grid edge (small: interpret-mode kernels on CPU)
TILE = 32          # seed tile edge == strip height (fair per-cell VMEM)
SHAPES = ("box", "star")
RADII = (1, 2, 3)
DEPTHS = (1, 2, 4, 8)
#: BENCH_QUICK=1 trims the sweep (CI / verify.sh); default is the full
#: Box/Star x r{1,2,3} x t{1,2,4,8} grid of the ISSUE.
QUICK_RADII = (1,)
QUICK_DEPTHS = (1, 4)
DTYPE_BYTES = 4
#: 3D halo-plane substrate sweep (DESIGN.md §9): Box/Star-3D at the
#: paper's Table 3 workloads, measured whole-slab foil (9x) vs sub-blocked
#: ((1 + 2h/strip_m)(1 + 2z_block/z_slab)x).  Small grid + fixed
#: (z_slab, strip_m) so interpret-mode timing stays honest and the
#: analytic amplification is exact at the benchmark slab sizes.
N3 = (16, 32, 32)      # (Z, H, W)
SLAB3, STRIP3, TILE3 = 8, 16, 32
CASES_3D = [(s, r, t) for s in SHAPES for r in (1, 2) for t in (1, 2)]
QUICK_CASES_3D = [("box", 1, 2), ("star", 1, 2)]
#: Wide-grid column-tiled sweep (DESIGN.md §10): a width whose FULL-WIDTH
#: strip working set exceeds the VMEM budget, so auto resolution
#: column-tiles W.  The default 8 MB budget would need W in the hundreds
#: of thousands -- far beyond honest interpret-mode timing -- so the case
#: pins REPRO_VMEM_BUDGET (the satellite's env override, folded into plan
#: cache keys) to a budget the benchmark width genuinely exceeds.
N_WIDE = (32, 1024)    # (H, W): full-width needs >= ~66 KB at t=2
WIDE_BUDGET = 16 * 1024
CASES_WIDE = [("box", 1, 1), ("box", 1, 2), ("star", 1, 2)]
QUICK_CASES_WIDE = [("box", 1, 2)]
#: Full sweeps land in BENCH_kernels.json (the cross-PR trajectory file);
#: BENCH_QUICK=1 sweeps go to a sibling .quick file so CI smoke runs never
#: clobber tracked full-grid data.
JSON_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_kernels.json")
JSON_PATH_QUICK = os.path.join(os.path.dirname(__file__), "..",
                               "BENCH_kernels.quick.json")


def _mxu_step_flops(w, tile_n: int, width: int, m_rows: int):
    """(dense, sparse) per-step MXU FLOPs of the radius-r banded
    contraction over the grid: the kernels' exact chunk walk, with
    full-width chunks compacted to the packed band rows and remainder
    chunks dense (DESIGN.md §14).  On tile-aligned widths
    sparse == kept_row_fraction * dense, integer-exact -- the same
    identity ``repro.audit``'s ``flops/sparse-compaction`` proves on the
    traced jaxpr."""
    offsets, bands = build_bands_nd(np.asarray(w, dtype=np.float32), tile_n)
    _, packed = compact_bands(offsets, bands)
    r = (bands.shape[1] - bands.shape[2]) // 2
    dense = sparse = 0
    start = 0
    while start < width:
        wcur = min(tile_n, width - start)
        d = len(offsets) * 2 * m_rows * wcur * (wcur + 2 * r)
        dense += d
        sparse += 2 * m_rows * wcur * packed.shape[0] \
            if wcur == tile_n else d
        start += wcur
    return dense, sparse


def _case(shape: str, r: int, t: int, x) -> dict:
    spec = StencilSpec(shape, 2, r)
    w = make_weights(spec, seed=r)
    wf = fuse_weights(w, t)
    halo = r * t                      # fused-regime vertical halo at TILE strips
    hb = choose_hblock(TILE, halo)

    bands_new = build_bands(w.astype(np.float32), TILE).shape
    bands_old = build_bands(wf.astype(np.float32), TILE).shape

    row = {
        "case": f"{spec.name}-t{t}", "shape": shape, "r": r, "t": t,
        "boundary": "periodic",
        "loads_per_tile_old": len(legacy.NEIGHBOR_OFFSETS_2D),
        "loads_per_tile_new": common.STRIP_NEIGHBOR_LOADS,
        "loads_per_tile_subblocked": TILE // hb + 2,
        "h_block": hb,
        "read_amp_subblocked": substrate_read_amp(TILE, hb),
        # one fused launch advances t steps: per-step read traffic
        "read_bytes_step_direct_old": legacy.hbm_read_bytes_per_step(
            (N, N), TILE, TILE, DTYPE_BYTES) / t,
        "read_bytes_step_direct_new": common.hbm_read_bytes_per_step(
            (N, N), TILE, DTYPE_BYTES) / t,
        "read_bytes_step_direct_subblocked": common.hbm_read_bytes_per_step(
            (N, N), TILE, DTYPE_BYTES, h_block=hb) / t,
        "read_bytes_step_matmul_old": legacy.hbm_read_bytes_per_step(
            (N, N), TILE, TILE, DTYPE_BYTES, bands_shape=bands_old) / t,
        "read_bytes_step_matmul_new": common.hbm_read_bytes_per_step(
            (N, N), TILE, DTYPE_BYTES, bands_shape=bands_new) / t,
        "read_bytes_step_matmul_subblocked": common.hbm_read_bytes_per_step(
            (N, N), TILE, DTYPE_BYTES, bands_shape=bands_new,
            h_block=hb) / t,
    }
    # Star-vs-box sparsity sweep (DESIGN.md §14): element sparsity of the
    # banded operand, the achievable kept-row fraction S, and the per-step
    # MXU FLOPs with/without compaction (sparse == S * dense on this
    # tile-aligned width; star keeps only its tap rows, box keeps all).
    dense_f, sparse_f = _mxu_step_flops(w, TILE, N, N)
    row["band_sparsity"] = band_sparsity(w.astype(np.float32), TILE)
    row["kept_row_fraction"] = kept_row_fraction(w, TILE)
    row["mxu_flops_step_dense"] = dense_f
    row["mxu_flops_step_sparse"] = sparse_f

    # Execution goes through compiled plans: selection/sizing/weight
    # composition happen at build (accounted separately below), the plan's
    # jitted callable is what gets timed -- time_us's warmup still absorbs
    # trace+compile, so the timed iterations are steady-state execution with
    # zero re-analysis.  Backends map the three substrates: the seed 9-tile
    # foil registers as legacy_*, the whole-strip pipeline as
    # *_wholestrip, and the default sub-blocked substrate as fused_direct /
    # fused_matmul_reuse (all degenerate to the plain kernels at t=1).
    paths = {
        "us_step_direct_old": stencil_plan(
            w, x.shape, x.dtype, t, backend="legacy_direct",
            tile_m=TILE, tile_n=TILE, interpret=True),
        "us_step_direct_new": stencil_plan(
            w, x.shape, x.dtype, t, backend="fused_direct_wholestrip",
            tile_m=TILE, interpret=True),
        "us_step_direct_subblocked": stencil_plan(
            w, x.shape, x.dtype, t, backend="fused_direct",
            tile_m=TILE, h_block=hb, interpret=True),
        # MXU paths: seed monolithic fusion vs strip intermediate reuse
        "us_step_matmul_old": stencil_plan(
            w, x.shape, x.dtype, t, backend="legacy_matmul",
            tile_m=TILE, tile_n=TILE, interpret=True),
        "us_step_matmul_new": stencil_plan(
            w, x.shape, x.dtype, t, backend="fused_matmul_reuse_wholestrip",
            tile_m=TILE, tile_n=TILE, interpret=True),
        "us_step_matmul_subblocked": stencil_plan(
            w, x.shape, x.dtype, t, backend="fused_matmul_reuse",
            tile_m=TILE, tile_n=TILE, h_block=hb, interpret=True),
        # sparse-compacted MXU path, same substrate pins as the reuse plan
        "us_step_matmul_sparse": stencil_plan(
            w, x.shape, x.dtype, t, backend="fused_sparse_matmul",
            tile_m=TILE, tile_n=TILE, h_block=hb, interpret=True),
    }
    iters = 2 if os.environ.get("BENCH_QUICK") else 5
    for key, plan in paths.items():
        row[key] = time_us(plan, x, iters=iters) / t
        # host-side plan construction (selection + sizing + composition),
        # paid once per signature -- never part of the per-step numbers
        row[key.replace("us_step_", "plan_build_us_")] = \
            plan.build_time_s * 1e6
    row["sparse_bitwise_equal"] = bool(np.array_equal(
        np.asarray(paths["us_step_matmul_sparse"](x)),
        np.asarray(paths["us_step_matmul_subblocked"](x))))
    return row


def _case3d(shape: str, r: int, t: int, x3) -> dict:
    """One 3D traffic case: whole-slab foil vs sub-blocked halo planes."""
    spec = StencilSpec(shape, 3, r)
    w = make_weights(spec, seed=r)
    halo = r * t
    hb = choose_hblock(STRIP3, halo)
    zb = choose_hblock(SLAB3, halo)
    sub = SubstrateGeom(dim=3, strip_m=STRIP3, h_block=hb,
                        z_slab=SLAB3, z_block=zb)
    whole = SubstrateGeom(dim=3, strip_m=STRIP3, h_block=0,
                          z_slab=SLAB3, z_block=0)
    bands = build_bands_nd(w.astype(np.float32), TILE3)[1].shape

    row = {
        "case": f"{spec.name}-t{t}", "shape": shape, "dim": 3, "r": r, "t": t,
        "boundary": "periodic",
        "z_slab": SLAB3, "strip_m": STRIP3, "h_block": hb, "z_block": zb,
        "loads_per_cell_wholestrip": 9,
        "loads_per_cell_subblocked": (SLAB3 // zb + 2) * (STRIP3 // hb + 2),
        "read_amp_wholestrip": whole.read_amp,
        "read_amp_subblocked": sub.read_amp,
        # one fused launch advances t steps: per-step read traffic
        "read_bytes_step_direct_wholestrip": hbm_read_bytes_per_step_3d(
            N3, whole, DTYPE_BYTES) / t,
        "read_bytes_step_direct_subblocked": hbm_read_bytes_per_step_3d(
            N3, sub, DTYPE_BYTES) / t,
        "read_bytes_step_matmul_wholestrip": hbm_read_bytes_per_step_3d(
            N3, whole, DTYPE_BYTES, bands_shape=bands) / t,
        "read_bytes_step_matmul_subblocked": hbm_read_bytes_per_step_3d(
            N3, sub, DTYPE_BYTES, bands_shape=bands) / t,
    }
    dense_f, sparse_f = _mxu_step_flops(w, TILE3, N3[2], N3[0] * N3[1])
    row["band_sparsity"] = band_sparsity(w.astype(np.float32), TILE3)
    row["kept_row_fraction"] = kept_row_fraction(w, TILE3)
    row["mxu_flops_step_dense"] = dense_f
    row["mxu_flops_step_sparse"] = sparse_f

    pins = dict(tile_m=STRIP3, z_slab=SLAB3, interpret=True)
    paths = {
        "us_step_direct_wholestrip": stencil_plan(
            w, N3, x3.dtype, t, backend="fused_direct_wholestrip", **pins),
        "us_step_direct_subblocked": stencil_plan(
            w, N3, x3.dtype, t, backend="fused_direct",
            h_block=hb, z_block=zb, **pins),
        "us_step_matmul_wholestrip": stencil_plan(
            w, N3, x3.dtype, t, backend="fused_matmul_reuse_wholestrip",
            tile_n=TILE3, **pins),
        "us_step_matmul_subblocked": stencil_plan(
            w, N3, x3.dtype, t, backend="fused_matmul_reuse",
            tile_n=TILE3, h_block=hb, z_block=zb, **pins),
        "us_step_matmul_sparse": stencil_plan(
            w, N3, x3.dtype, t, backend="fused_sparse_matmul",
            tile_n=TILE3, h_block=hb, z_block=zb, **pins),
    }
    iters = 1 if os.environ.get("BENCH_QUICK") else 3
    for key, plan in paths.items():
        row[key] = time_us(plan, x3, iters=iters) / t
        row[key.replace("us_step_", "plan_build_us_")] = \
            plan.build_time_s * 1e6
    row["sparse_bitwise_equal"] = bool(np.array_equal(
        np.asarray(paths["us_step_matmul_sparse"](x3)),
        np.asarray(paths["us_step_matmul_subblocked"](x3))))
    return row


def _case_wide(shape: str, r: int, t: int, xw) -> dict:
    """One wide-grid case: whole-width 3-load foil vs the column-tiled
    substrate that auto resolution picks when full width cannot fit the
    (reduced) VMEM budget.  Per-step reads follow the three-factor
    product (1 + 2h/strip_m)(1 + 2w_block/w_tile)·H·W·D vs the foil's 3x.
    """
    spec = StencilSpec(shape, 2, r)
    w = make_weights(spec, seed=r)
    halo = r * t
    old_budget = os.environ.get("REPRO_VMEM_BUDGET")
    os.environ["REPRO_VMEM_BUDGET"] = str(WIDE_BUDGET)
    try:
        geom = resolve_substrate_geom(N_WIDE, halo, DTYPE_BYTES)
        assert geom.w_tile > 0, \
            f"wide case failed to column-tile: {geom} (budget {WIDE_BUDGET})"
        bands = build_bands(w.astype(np.float32),
                            common.choose_tile(N_WIDE[-1])).shape

        row = {
            "case": f"{spec.name}-t{t}-wide", "shape": shape, "r": r, "t": t,
            "boundary": "periodic",
            "grid": list(N_WIDE), "vmem_budget": WIDE_BUDGET,
            "strip_m": geom.strip_m, "h_block": geom.h_block,
            "w_tile": geom.w_tile, "w_block": geom.w_block,
            "read_amp_wholestrip": substrate_read_amp(geom.strip_m, 0),
            "read_amp_coltiled": geom.read_amp,
            "read_bytes_step_direct_wholestrip":
                common.hbm_read_bytes_per_step(
                    N_WIDE, geom.strip_m, DTYPE_BYTES) / t,
            "read_bytes_step_direct_coltiled":
                common.hbm_read_bytes_per_step(
                    N_WIDE, geom.strip_m, DTYPE_BYTES,
                    h_block=geom.h_block, w_tile=geom.w_tile,
                    w_block=geom.w_block) / t,
            "read_bytes_step_matmul_coltiled":
                common.hbm_read_bytes_per_step(
                    N_WIDE, geom.strip_m, DTYPE_BYTES, bands_shape=bands,
                    h_block=geom.h_block, w_tile=geom.w_tile,
                    w_block=geom.w_block) / t,
        }

        pins = dict(tile_m=geom.strip_m, interpret=True)
        col = dict(h_block=geom.h_block, w_tile=geom.w_tile,
                   w_block=geom.w_block)
        paths = {
            # the whole-width foil executes in interpret mode regardless
            # of VMEM -- it is the analytic+timed foil, not a TPU claim
            "us_step_direct_wholestrip": stencil_plan(
                w, N_WIDE, xw.dtype, t, backend="fused_direct_wholestrip",
                **pins),
            "us_step_direct_coltiled": stencil_plan(
                w, N_WIDE, xw.dtype, t, backend="fused_direct",
                **col, **pins),
            "us_step_matmul_coltiled": stencil_plan(
                w, N_WIDE, xw.dtype, t, backend="fused_matmul_reuse",
                **col, **pins),
        }
        iters = 1 if os.environ.get("BENCH_QUICK") else 3
        for key, plan in paths.items():
            row[key] = time_us(plan, xw, iters=iters) / t
            row[key.replace("us_step_", "plan_build_us_")] = \
                plan.build_time_s * 1e6
        return row
    finally:
        if old_budget is None:
            os.environ.pop("REPRO_VMEM_BUDGET", None)
        else:
            os.environ["REPRO_VMEM_BUDGET"] = old_budget


#: Boundary-mode sweep (DESIGN.md §15): the sub-blocked VPU and
#: intermediate-reuse MXU plans under each non-periodic mode (plus the
#: periodic pin and a mixed per-axis spec), oracle-checked per row.
CASES_BOUNDARY = ["periodic", "zero", "reflect", "replicate",
                  ("reflect", "periodic")]
QUICK_CASES_BOUNDARY = ["periodic", "reflect"]
#: Overlap-vs-serialized pair geometry (2-device subprocess).
OVERLAP_GRID, OVERLAP_T = (256, 256), 4


def _case_boundary(mode, x) -> dict:
    """Time the sub-blocked plans under one boundary spec; per-step
    boundary fills are VPU row-selects, so non-periodic rows should sit
    within noise of the periodic pin -- the column makes that claim
    checkable across PRs."""
    spec = StencilSpec("box", 2, 1)
    w = make_weights(spec, seed=1)
    t = 2
    modes = resolve_boundary(mode, 2)
    row = {"case": f"boundary-{boundary_label(modes)}", "shape": "box",
           "r": 1, "t": t, "boundary": boundary_label(modes)}
    paths = {
        "us_step_direct_subblocked": stencil_plan(
            w, x.shape, x.dtype, t, backend="fused_direct",
            tile_m=TILE, boundary=mode, interpret=True),
        "us_step_matmul_subblocked": stencil_plan(
            w, x.shape, x.dtype, t, backend="fused_matmul_reuse",
            tile_m=TILE, tile_n=TILE, boundary=mode, interpret=True),
    }
    iters = 2 if os.environ.get("BENCH_QUICK") else 5
    for key, plan in paths.items():
        row[key] = time_us(plan, x, iters=iters) / t
        row[key.replace("us_step_", "plan_build_us_")] = \
            plan.build_time_s * 1e6
    ref = np.asarray(apply_stencil_steps(x, jnp.asarray(w, x.dtype), t,
                                         modes))
    row["oracle_max_err"] = max(
        float(np.max(np.abs(np.asarray(p(x)) - ref)))
        for p in paths.values())
    return row


def _case_halo_overlap() -> dict:
    """Distributed overlap-vs-serialized timing pair (2 host devices).

    The serialized-exchange foil executes each step as two dispatches
    with a host sync between them -- the exchange must COMPLETE before
    the compute launches, which is exactly what a runtime without
    overlap pays.  The overlap stepper is one dispatch for all t steps
    with the interior scheduled against the in-flight ppermute pair.
    Runs in a subprocess because the host-device count pins at first
    jax init (the benchmark process itself must stay single-device).
    """
    code = textwrap.dedent("""
        import json, time
        import jax, numpy as np, jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.stencil import StencilSpec, make_weights
        from repro.stencil.distributed import (
            _extend, apply_stencil_valid, make_distributed_stepper,
            overlap_stats, reset_overlap_stats)

        (h, wdt), t, r = %(grid)s, %(t)d, 1
        mesh = Mesh(np.array(jax.devices()), ("i",))
        dims = ("i", None)
        w = make_weights(StencilSpec("box", 2, r), seed=0)
        x = np.random.default_rng(0).normal(size=(h, wdt)) \\
              .astype(np.float32)
        xd = jax.device_put(jnp.asarray(x), NamedSharding(mesh,
                                                          P("i", None)))
        spec = P("i", None)
        wj = jnp.asarray(w)
        ext = jax.jit(shard_map(lambda a: _extend(a, r, dims), mesh=mesh,
                                in_specs=(spec,), out_specs=spec,
                                check_rep=False))
        comp = jax.jit(shard_map(lambda a: apply_stencil_valid(a, wj),
                                 mesh=mesh, in_specs=(spec,),
                                 out_specs=spec, check_rep=False))

        def serialized(a):
            for _ in range(t):
                e = ext(a)
                e.block_until_ready()      # exchange completes first
                a = comp(e)
            return a.block_until_ready()

        reset_overlap_stats()
        overlap = jax.jit(make_distributed_stepper(mesh, dims, w, t=t,
                                                   mode="overlap"))
        y_ser = serialized(xd)                       # warmup + reference
        y_ov = overlap(xd).block_until_ready()       # traces counters
        stats = overlap_stats()

        def best_us(fn, iters=5):
            best = float("inf")
            for _ in range(iters):
                t0 = time.perf_counter()
                fn()
                best = min(best, time.perf_counter() - t0)
            return best * 1e6

        us_ser = best_us(lambda: serialized(xd)) / t
        us_ov = best_us(lambda: overlap(xd).block_until_ready()) / t
        print(json.dumps({
            "devices": len(jax.devices()), "grid": [h, wdt], "t": t,
            "r": r, "us_step_serialized": us_ser,
            "us_step_overlap": us_ov,
            "overlap_faster": us_ov < us_ser,
            "bitwise_equal": bool(jnp.all(y_ser == y_ov)),
            "interleave_counters": stats,
        }))
    """) % {"grid": OVERLAP_GRID, "t": OVERLAP_T}
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", "src") \
        + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=560)
    if r.returncode != 0:
        print(f"traffic: halo_overlap subprocess failed:\n{r.stderr}",
              file=sys.stderr)
        return {"case": "halo-overlap", "error": r.stderr[-2000:]}
    row = json.loads(r.stdout.strip().splitlines()[-1])
    row["case"] = "halo-overlap"
    return row


def _budgeted(fn, label: str, *args) -> dict:
    """Run one case under the per-case wall-clock budget; a blown budget
    records a ``timed_out`` row instead of wedging the whole sweep."""
    try:
        with case_budget():
            return fn(*args)
    except CaseTimeout as e:
        print(f"traffic: case {label} timed out ({e}); continuing",
              file=sys.stderr)
        return {"case": label, "timed_out": True, "error": str(e)}


def run() -> list[str]:
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(N, N)).astype(np.float32))
    x3 = jnp.asarray(rng.normal(size=N3).astype(np.float32))
    quick = bool(os.environ.get("BENCH_QUICK"))
    radii = QUICK_RADII if quick else RADII
    depths = QUICK_DEPTHS if quick else DEPTHS
    rows = [_budgeted(_case, f"{shape}2d-r{r}-t{t}", shape, r, t, x)
            for shape in SHAPES for r in radii for t in depths]
    cases3d = QUICK_CASES_3D if quick else CASES_3D
    rows3d = [_budgeted(_case3d, f"{shape}3d-r{r}-t{t}", shape, r, t, x3)
              for shape, r, t in cases3d]
    xw = jnp.asarray(rng.normal(size=N_WIDE).astype(np.float32))
    cases_wide = QUICK_CASES_WIDE if quick else CASES_WIDE
    rows_wide = [_budgeted(_case_wide, f"{shape}2d-r{r}-t{t}-wide",
                           shape, r, t, xw)
                 for shape, r, t in cases_wide]
    cases_boundary = QUICK_CASES_BOUNDARY if quick else CASES_BOUNDARY
    rows_boundary = [_budgeted(_case_boundary, f"boundary-{mode}", mode, x)
                     for mode in cases_boundary]
    row_overlap = _budgeted(_case_halo_overlap, "halo-overlap")

    with open(JSON_PATH_QUICK if quick else JSON_PATH, "w") as f:
        json.dump({"grid": N, "tile": TILE, "dtype_bytes": DTYPE_BYTES,
                   "quick": quick, "radii": list(radii),
                   "depths": list(depths),
                   "grid_3d": list(N3),
                   "slab_3d": [SLAB3, STRIP3, TILE3],
                   "grid_wide": list(N_WIDE),
                   "vmem_budget_wide": WIDE_BUDGET,
                   "timing": "interpret-mode CPU (relative only)",
                   "cases": rows, "cases_3d": rows3d,
                   "cases_wide": rows_wide,
                   "cases_boundary": rows_boundary,
                   "halo_overlap": row_overlap,
                   # Guard-layer record of the sweep: empty on a clean
                   # run (asserted by scripts/verify.sh) -- any event
                   # here means a kernel failed and degraded mid-bench.
                   "guard_events": guard_events.snapshot(),
                   "plan_stats": plan_cache_stats()}, f, indent=1)
    rows = [c for c in rows if not c.get("timed_out")]
    rows3d = [c for c in rows3d if not c.get("timed_out")]
    rows_wide = [c for c in rows_wide if not c.get("timed_out")]
    rows_boundary = [c for c in rows_boundary if not c.get("timed_out")]

    out = ["traffic.case,loads_old/new/sub,read_amp_direct_new,"
           "read_amp_direct_sub,rdMB_step_mm_old,rdMB_step_mm_new,"
           "rdMB_step_mm_sub,us_dir_old,us_dir_new,us_dir_sub,"
           "us_mm_old,us_mm_new,us_mm_sub,us_mm_sparse,kept_S,"
           "sparse_bitwise"]
    grid_bytes = N * N * DTYPE_BYTES
    for c in rows:
        amp_new = c["read_bytes_step_direct_new"] * c["t"] / grid_bytes
        amp_sub = c["read_bytes_step_direct_subblocked"] * c["t"] / grid_bytes
        out.append(
            f"traffic.{c['case']},{c['loads_per_tile_old']}/"
            f"{c['loads_per_tile_new']}/{c['loads_per_tile_subblocked']},"
            f"{amp_new:.2f}x,{amp_sub:.2f}x,"
            f"{c['read_bytes_step_matmul_old']/2**20:.3f},"
            f"{c['read_bytes_step_matmul_new']/2**20:.3f},"
            f"{c['read_bytes_step_matmul_subblocked']/2**20:.3f},"
            f"{c['us_step_direct_old']:.0f},{c['us_step_direct_new']:.0f},"
            f"{c['us_step_direct_subblocked']:.0f},"
            f"{c['us_step_matmul_old']:.0f},{c['us_step_matmul_new']:.0f},"
            f"{c['us_step_matmul_subblocked']:.0f},"
            f"{c['us_step_matmul_sparse']:.0f},"
            f"{c['kept_row_fraction']:.4f},{c['sparse_bitwise_equal']}")

    out.append("traffic3d.case,read_amp_whole,read_amp_sub,"
               "rdMB_step_mm_whole,rdMB_step_mm_sub,us_dir_whole,us_dir_sub,"
               "us_mm_whole,us_mm_sub,us_mm_sparse,kept_S,sparse_bitwise")
    for c in rows3d:
        out.append(
            f"traffic3d.{c['case']},{c['read_amp_wholestrip']:.2f}x,"
            f"{c['read_amp_subblocked']:.2f}x,"
            f"{c['read_bytes_step_matmul_wholestrip']/2**20:.3f},"
            f"{c['read_bytes_step_matmul_subblocked']/2**20:.3f},"
            f"{c['us_step_direct_wholestrip']:.0f},"
            f"{c['us_step_direct_subblocked']:.0f},"
            f"{c['us_step_matmul_wholestrip']:.0f},"
            f"{c['us_step_matmul_subblocked']:.0f},"
            f"{c['us_step_matmul_sparse']:.0f},"
            f"{c['kept_row_fraction']:.4f},{c['sparse_bitwise_equal']}")

    out.append("trafficwide.case,w_tile/w_block,read_amp_whole,"
               "read_amp_coltiled,rdMB_step_dir_whole,rdMB_step_dir_col,"
               "us_dir_whole,us_dir_col,us_mm_col")
    for c in rows_wide:
        out.append(
            f"trafficwide.{c['case']},{c['w_tile']}/{c['w_block']},"
            f"{c['read_amp_wholestrip']:.2f}x,{c['read_amp_coltiled']:.2f}x,"
            f"{c['read_bytes_step_direct_wholestrip']/2**20:.3f},"
            f"{c['read_bytes_step_direct_coltiled']/2**20:.3f},"
            f"{c['us_step_direct_wholestrip']:.0f},"
            f"{c['us_step_direct_coltiled']:.0f},"
            f"{c['us_step_matmul_coltiled']:.0f}")

    out.append("trafficboundary.case,boundary,us_dir_sub,us_mm_sub,"
               "oracle_max_err")
    for c in rows_boundary:
        out.append(
            f"trafficboundary.{c['case']},{c['boundary']},"
            f"{c['us_step_direct_subblocked']:.0f},"
            f"{c['us_step_matmul_subblocked']:.0f},"
            f"{c['oracle_max_err']:.2e}")
    if "us_step_overlap" in row_overlap:
        c = row_overlap
        out.append("trafficoverlap.case,devices,t,us_step_serialized,"
                   "us_step_overlap,overlap_faster,bitwise,"
                   "interior_before_recv")
        out.append(
            f"trafficoverlap.halo-overlap,{c['devices']},{c['t']},"
            f"{c['us_step_serialized']:.0f},{c['us_step_overlap']:.0f},"
            f"{c['overlap_faster']},{c['bitwise_equal']},"
            f"{c['interleave_counters']['interior_before_recv_consumed']}")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
