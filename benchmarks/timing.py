"""Shared measurement plumbing for the benchmark harness.

Every wall-clock number a benchmark emits must come from ``time_us``: it
warms the call up (triggering trace+compile OUTSIDE the timed region) and
blocks on device completion per iteration, so BENCH_*.json numbers are
comparable across PRs instead of measuring import+compile noise.
"""
from __future__ import annotations

import time

import jax


def time_us(fn, *args, iters: int = 3, warmup: int = 1) -> float:
    """Mean microseconds per call of ``fn(*args)``, warmed up and synced."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6
