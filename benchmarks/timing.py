"""Shared measurement plumbing for the benchmark harness.

Every wall-clock number a benchmark emits must come from ``time_us``: it
warms the call up (triggering trace+compile OUTSIDE the timed region) and
blocks on device completion per iteration, so BENCH_*.json numbers are
comparable across PRs instead of measuring import+compile noise.

``case_budget`` bounds one case's wall clock: a pathological compile (the
exact failure mode the guard layer exists for) raises :class:`CaseTimeout`
instead of wedging ``scripts/verify.sh`` forever; the harness records the
case as ``timed_out`` and continues.
"""
from __future__ import annotations

import signal
import threading
import time
from contextlib import contextmanager

import jax

from repro.core.envutil import env_int

#: Default per-case wall-clock budget (seconds); override with
#: REPRO_BENCH_BUDGET_S.  0 disables the budget entirely.
BENCH_BUDGET_S = 300


class CaseTimeout(RuntimeError):
    """One benchmark case exceeded its wall-clock budget."""


def bench_budget_s() -> int:
    return env_int("REPRO_BENCH_BUDGET_S", BENCH_BUDGET_S, minimum=0)


@contextmanager
def case_budget(seconds: int = None):
    """Raise :class:`CaseTimeout` if the block runs longer than the budget.

    SIGALRM-based, so it interrupts a wedged XLA compile mid-flight --
    a cooperative deadline check could not.  Degrades to a no-op when the
    budget is 0/disabled, off the main thread (signals unavailable), or
    when an outer alarm is already pending (nested budgets must not
    cancel the enclosing deadline).
    """
    if seconds is None:
        seconds = bench_budget_s()
    usable = (seconds > 0
              and threading.current_thread() is threading.main_thread()
              and signal.getitimer(signal.ITIMER_REAL)[0] == 0)
    if not usable:
        yield
        return

    def on_alarm(signum, frame):
        raise CaseTimeout(f"benchmark case exceeded {seconds}s budget")

    prior = signal.signal(signal.SIGALRM, on_alarm)
    signal.setitimer(signal.ITIMER_REAL, seconds)
    try:
        yield
    finally:
        signal.setitimer(signal.ITIMER_REAL, 0)
        signal.signal(signal.SIGALRM, prior)


def time_us(fn, *args, iters: int = 3, warmup: int = 1) -> float:
    """Mean microseconds per call of ``fn(*args)``, warmed up and synced."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6
