"""Paper Table 4: dense vs sparse Tensor Cores (Box-2D1R, t=7, float).

Reproduces the ridge shift (81 -> 161), the bottleneck flip
(compute -> memory) and the predicted gain; the paper measures 3.06x
wall-clock (their dense baseline sits below its roofline).  Also evaluates
the TPU analogue question from DESIGN.md §8: does the int8 MXU ceiling
(394 TOPS) re-open the sweet spot on v5e the way SpTCs do on A100?"""
from __future__ import annotations

from repro.core import perfmodel as pm
from repro.stencil import StencilSpec


def run() -> list[str]:
    out = ["table4.unit,I,ridge,bottleneck,P_actual_TFLOPs,gain"]
    spec = StencilSpec("box", 2, 1)
    w = pm.StencilWorkload(spec, 7, 4)
    dense = pm.perf_matrix(w, pm.A100_FLOAT, 0.47)
    sparse = pm.perf_sparse_matrix(w, pm.A100_FLOAT, 0.47)
    out.append(f"table4.A100-dense-TC,{dense.intensity:.0f},{dense.ridge:.0f},"
               f"{dense.bound.value},{dense.actual_flops/1e12:.2f},1.00x")
    out.append(f"table4.A100-sparse-TC,{sparse.intensity:.0f},{sparse.ridge:.0f},"
               f"{sparse.bound.value},{sparse.actual_flops/1e12:.2f},"
               f"{sparse.actual_flops/dense.actual_flops:.2f}x")
    # TPU: bf16 MXU vs int8 ceiling (the v5e "raised roof" analogue)
    s_tpu = pm.sparsity_banded(spec.radius * 7, 128)
    wt = pm.StencilWorkload(spec, 7, 2)
    mxu = pm.perf_matrix(wt, pm.TPU_V5E_INT8_CEILING, s_tpu)
    mxu8 = pm.perf_sparse_matrix(wt, pm.TPU_V5E_INT8_CEILING, s_tpu)
    out.append(f"table4.v5e-bf16-MXU,{mxu.intensity:.0f},{mxu.ridge:.0f},"
               f"{mxu.bound.value},{mxu.actual_flops/1e12:.3f},1.00x")
    out.append(f"table4.v5e-int8-MXU,{mxu8.intensity:.0f},{mxu8.ridge:.0f},"
               f"{mxu8.bound.value},{mxu8.actual_flops/1e12:.3f},"
               f"{mxu8.actual_flops/mxu.actual_flops:.2f}x")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
