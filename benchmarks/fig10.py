"""Paper Figure 10: memory-/compute-bound classification of stencil
configurations vs temporal fusion depth, on A100 (paper) and TPU v5e
(our target).  Reports the transition depth t* per configuration --
the paper's §4.2 finding is box ~ t=3, star ~ t=5 on A100/float."""
from __future__ import annotations

from repro.core import perfmodel as pm
from repro.core.selector import transition_depth
from repro.stencil import StencilSpec

CONFIGS = [
    ("Box-2D1R", 4), ("Box-2D3R", 4), ("Box-2D7R", 4),
    ("Star-2D1R", 4), ("Star-2D3R", 4),
    ("Box-3D1R", 4), ("Box-3D2R", 4), ("Star-3D1R", 4),
    ("Box-2D1R", 8), ("Box-3D1R", 8), ("Star-2D1R", 8),
]


def run() -> list[str]:
    out = ["fig10.pattern,dtype,hw,transition_t,bound_at_t1,bound_at_t8"]
    for hw in (pm.A100_FLOAT, pm.TPU_V5E_BF16):
        for name, D in CONFIGS:
            spec = StencilSpec.from_name(name)
            tstar = transition_depth(spec, D, hw, t_max=64)
            b1 = pm.bound_state(hw.p_vector, hw.bandwidth,
                                pm.StencilWorkload(spec, 1, D).intensity_vector())
            b8 = pm.bound_state(hw.p_vector, hw.bandwidth,
                                pm.StencilWorkload(spec, 8, D).intensity_vector())
            out.append(f"fig10.{name},{'f32' if D == 4 else 'f64'},"
                       f"{hw.name.split()[0]},{tstar},{b1.value},{b8.value}")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
