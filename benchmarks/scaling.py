"""Strong-scaling analysis across the two production meshes (beyond-paper).

From the cached dry-run artifacts: per-cell single-pod (256 chips) vs
multi-pod (512 chips) roofline terms, the parallel efficiency of the
dominant term, and whether the pod axis paid for itself.  No compiles --
reads results/dryrun/*.json."""
from __future__ import annotations

import glob
import json
import os

DRY = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def run() -> list[str]:
    recs = {}
    for f in glob.glob(os.path.join(DRY, "*.json")):
        r = json.load(open(f))
        if r.get("ok") and not r.get("tag"):
            recs[(r["arch"], r["cell"], r["mesh"])] = r["roofline"]
    out = ["scaling.arch,cell,dom_single_ms,dom_multi_ms,speedup,"
           "ideal,parallel_efficiency"]
    for (arch, cell, mesh), t in sorted(recs.items()):
        if mesh != "single":
            continue
        m = recs.get((arch, cell, "multi"))
        if not m:
            continue
        dom_s = max(t["compute_s"], t["memory_s"], t["collective_s"])
        dom_m = max(m["compute_s"], m["memory_s"], m["collective_s"])
        if dom_m <= 0:
            continue
        speed = dom_s / dom_m
        eff = speed / 2.0          # ideal strong scaling 256 -> 512 = 2x
        out.append(f"scaling.{arch},{cell},{dom_s*1e3:.1f},{dom_m*1e3:.1f},"
                   f"{speed:.2f}x,2.00x,{eff:.0%}")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
