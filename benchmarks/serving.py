"""Serving benchmark: batched plan-sharing engine vs per-request dispatch.

The serving engine's claim (DESIGN.md §12) is that coalescing requests
that share a plan signature into batched launches beats dispatching each
request by itself.  This benchmark measures both sides on identical
traffic and writes BENCH_serving.json (repo root):

  * **sequential baseline** -- a closed loop that, per request, looks up
    the plan (``stencil_plan``: LRU hit after the first), executes it and
    blocks on the result.  This is the strongest honest baseline: it
    already amortizes selection/compile through the plan cache, so the
    delta vs the engine isolates *batching*, not caching.
  * **batched engine** -- the same requests through ``StencilServer``
    with a per-signature closed-loop window, so the dispatcher sees full
    queues and the coalescer emits full buckets.  Latency histograms and
    occupancy come from ``ServeMetrics``.

Both phases replay the same inputs; every engine response is compared
bitwise against the sequential plan's output for that input
(``bitwise_match`` in the JSON) -- throughput that changed the answer
would not count.

Traffic is interleaved across signatures (the coalescer's whole job);
warmup absorbs trace+compile on both sides so the measured window is
steady-state dispatch, matching the ``benchmarks/timing.time_us``
convention.  ``scripts/verify.sh`` asserts the engine beats the baseline
and that plan-cache hits grew by at least (requests - distinct
signatures) -- the plan-sharing contract.

Unlike BENCH_kernels.json, the quick sweep does NOT go to a sibling
file: P50/P99 must land in BENCH_serving.json on every verify.sh run, so
the file is always rewritten with a ``quick`` marker.
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import sys
import time
from collections import deque
from contextlib import contextmanager

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), ".."))

from benchmarks.timing import CaseTimeout, case_budget
from repro.core import events as guard_events
from repro.kernels import plan_cache_stats, stencil_plan
from repro.serve import LatencyHistogram, StencilServer
from repro.stencil import StencilSpec, make_weights

GRID = (32, 32)      # small grids + t=1: dispatch overhead dominates the
                     # per-request cost, which is exactly the regime the
                     # batching engine exists for (deep-t fused launches
                     # are compute-bound and amortize on their own)
WINDOW = 128         # outstanding requests per signature (closed loop);
                     # doubles as the single batch bucket -- measured
                     # sweet spot where per-batch dispatch amortizes past
                     # the per-request future/queue overhead without the
                     # P99 blowup larger windows buy (256 -> ~50 ms tails)
N_INPUTS = 8         # distinct input grids per signature, reused round-robin
#: (shape, radius, t, dtype) per signature; quick keeps two so the
#: coalescer still has signatures to keep apart.  All f32: on the CPU
#: interpret substrate a scanned bf16 batch runs ~4x slower per element
#: than the unbatched bf16 call (XLA's bf16 emulation inside the scan
#: body), so bf16 batching is a loss here regardless of engine quality --
#: it stays covered by the bitwise equivalence sweep, not the throughput
#: claim.
SIGS_FULL = [("box", 1, 1, "float32"), ("star", 1, 1, "float32"),
             ("box", 2, 1, "float32"), ("star", 3, 1, "float32")]
SIGS_QUICK = SIGS_FULL[:2]
REQS_FULL = 8192     # requests per signature (multiples of WINDOW; sized
REQS_QUICK = 4096    # so each measured phase runs a few hundred ms --
                     # 20 ms windows measure the OS scheduler, not the
                     # engine)
JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_serving.json")


@contextmanager
def _gc_quiesced():
    """Collect, then hold the cyclic GC off for one measured phase --
    applied identically to BOTH phases.  A generational collection
    landing mid-window scans jax's whole module graph (measured ~70 ms
    pauses, 6x the P99 it lands in); that measures CPython's collector
    defaults, not the dispatch path under test.  Serving deployments
    tune or freeze the GC for exactly this reason."""
    gc.collect()
    was_enabled = gc.isenabled()
    gc.disable()
    try:
        yield
    finally:
        if was_enabled:
            gc.enable()


class _Workload:
    """One plan signature's traffic: weights, inputs, reference outputs."""

    def __init__(self, shape: str, r: int, t: int, dtype: str, rng):
        self.spec = StencilSpec(shape, len(GRID), r)
        self.t = t
        self.dtype_name = dtype
        dt = jnp.bfloat16 if dtype == "bfloat16" else np.float32
        self.weights = make_weights(self.spec, seed=r)
        # HOST arrays, like a real serving client would hold: device
        # inputs would make the engine's stack_batch pay one
        # device->host copy per request (and gift the sequential
        # baseline a transfer it never paid for)
        self.xs = [np.asarray(jnp.asarray(rng.normal(size=GRID), dtype=dt))
                   for _ in range(N_INPUTS)]
        self.y_ref = None            # filled by the sequential phase

    @property
    def name(self) -> str:
        return f"{self.spec.name}-t{self.t}-{self.dtype_name}"


def _run_sequential(workloads, n_requests: int):
    """Per-request dispatch: plan lookup + execute + block, one at a time,
    interleaved across signatures.  Also produces the bitwise reference
    outputs (one unbatched plan call per distinct input)."""
    for wl in workloads:                       # warmup: compile + oracle
        plan = stencil_plan(wl.weights, GRID, wl.xs[0].dtype, wl.t)
        wl.y_ref = [np.asarray(jax.block_until_ready(plan(x)))
                    for x in wl.xs]

    hist = LatencyHistogram()
    t0 = time.perf_counter()
    for i in range(n_requests):
        wl = workloads[i % len(workloads)]
        r0 = time.perf_counter()
        plan = stencil_plan(wl.weights, GRID, wl.xs[0].dtype, wl.t)
        jax.block_until_ready(plan(wl.xs[i % N_INPUTS]))
        hist.record(time.perf_counter() - r0)
    wall = time.perf_counter() - t0
    return {"requests": n_requests, "wall_s": wall,
            "requests_per_s": n_requests / wall,
            "latency": hist.snapshot()}


def _run_batched(workloads, n_requests: int):
    """The same traffic through the engine, issued as double-buffered
    bursts: each burst submits one full WINDOW per signature, and two
    bursts stay in flight -- while the client blocks on burst N's
    results (GIL released), the dispatcher executes burst N+1's full
    buckets.  One-future-at-a-time popping measures worse here not
    because the engine is slower but because the client's per-result
    GIL wakeups starve the dispatcher and leave drains half-full.
    Returns the metrics snapshot plus the bitwise verdict."""
    per_sig = n_requests // len(workloads)
    rounds = per_sig // WINDOW
    # buckets pin the launch size to the window; max_batch is the drain's
    # fill target, so it counts the whole interleaved queue -- one window
    # PER signature -- or mixed drains would split into half-empty buckets
    with StencilServer(buckets=(WINDOW,),
                       max_batch=WINDOW * len(workloads)) as server:
        # warmup: one full window per signature compiles the batched plan
        done = [server.submit(wl.weights, wl.xs[i % N_INPUTS], t=wl.t)
                for wl in workloads for i in range(WINDOW)]
        for fut in done:
            fut.result()
        server.metrics.reset()                 # keep plans, drop the stats

        pending = deque()
        results = []
        issued = 0
        t0 = time.perf_counter()
        while issued < rounds or pending:
            while issued < rounds and len(pending) < 2:
                base = issued * WINDOW
                pending.append(
                    [(k, base + j,
                      server.submit(wl.weights,
                                    wl.xs[(base + j) % N_INPUTS],
                                    t=wl.t))
                     for k, wl in enumerate(workloads)
                     for j in range(WINDOW)])
                issued += 1
            for k, i, fut in pending.popleft():
                results.append((k, i, fut.result()))
        wall = time.perf_counter() - t0
        snap = server.stats()
    # bitwise audit OUTSIDE the timed window (the comparisons are host
    # work the serving path never does)
    bitwise = all(
        np.array_equal(np.asarray(y), workloads[k].y_ref[i % N_INPUTS])
        for k, i, y in results)
    snap["wall_s"] = wall
    snap["bitwise_match"] = bitwise
    return snap


def run(quick: bool) -> list[str]:
    sig_defs = SIGS_QUICK if quick else SIGS_FULL
    per_sig = REQS_QUICK if quick else REQS_FULL
    rng = np.random.default_rng(0)
    workloads = [_Workload(*s, rng) for s in sig_defs]
    n_requests = per_sig * len(workloads)

    # Two alternating measurement passes, best-of per side: a background
    # scheduling burst that lands inside ONE phase's window cannot flip
    # the comparison (slow-moving machine noise already hits both phases
    # of a pass equally).  The bitwise audit must hold on every pass.
    pc0 = plan_cache_stats()
    seq_passes, bat_passes = [], []
    for _ in range(2):
        with _gc_quiesced():
            seq_passes.append(_run_sequential(workloads, n_requests))
        with _gc_quiesced():
            bat_passes.append(_run_batched(workloads, n_requests))
    pc1 = plan_cache_stats()
    seq = max(seq_passes, key=lambda s: s["requests_per_s"])
    batched = max(bat_passes, key=lambda b: b["requests_per_s"])
    batched["bitwise_match"] = all(b["bitwise_match"] for b in bat_passes)

    blat = batched["latency"]
    payload = {
        "quick": quick, "grid": list(GRID), "window": WINDOW,
        "requests_per_signature": per_sig,
        "signatures": [wl.name for wl in workloads],
        "sequential": seq,
        "batched": batched,
        "speedup": batched["requests_per_s"] / seq["requests_per_s"]
                   if seq["requests_per_s"] else 0.0,
        "bitwise_match": batched.pop("bitwise_match"),
        "plan_cache": {
            "before": pc0, "after": pc1,
            "hits_delta": pc1["hits"] - pc0["hits"],
            "misses_delta": pc1["misses"] - pc0["misses"],
        },
        # clean-run contract, same as BENCH_kernels.json: any guard event
        # means a serving batch silently degraded mid-benchmark
        "guard_events": guard_events.snapshot(),
    }
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=1)

    out = ["serving.metric,seq_rps,batched_rps,speedup,b_p50_ms,b_p99_ms,"
           "occupancy,bitwise"]
    out.append(
        f"serving.{'quick' if quick else 'full'},"
        f"{seq['requests_per_s']:.0f},{batched['requests_per_s']:.0f},"
        f"{payload['speedup']:.2f}x,{blat['p50_ms']:.2f},"
        f"{blat['p99_ms']:.2f},{batched['batch_occupancy']:.2f},"
        f"{'OK' if payload['bitwise_match'] else 'MISMATCH'}")
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(prog="benchmarks.serving")
    ap.add_argument("--quick", action="store_true",
                    default=bool(os.environ.get("BENCH_QUICK")),
                    help="trimmed sweep (also via BENCH_QUICK=1)")
    args = ap.parse_args(argv)
    try:
        with case_budget():
            lines = run(args.quick)
    except CaseTimeout as e:
        print(f"serving: benchmark timed out ({e})", file=sys.stderr)
        raise SystemExit(1)
    print("\n".join(lines))


if __name__ == "__main__":
    main()
