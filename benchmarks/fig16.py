"""Paper Figure 16: overall throughput comparison across execution paths.

Two complementary views (no TPU in this container):
  * MODEL: predicted GStencils/s on TPU v5e for the vector path
    (direct/fused_direct) and matrix path (banded fused_matmul), from the
    enhanced-roofline model with our scheme's structural sparsity;
  * WALL: measured us/call of the CPU-runnable jnp execution paths
    (reference rolls vs conv lowering) -- honest CPU numbers, labeled as
    such, per the "one per paper table" harness contract.
"""
from __future__ import annotations

import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), ".."))

from benchmarks.timing import time_us
from repro.core import perfmodel as pm
from repro.stencil import StencilSpec, make_weights
from repro.stencil.reference import apply_stencil_steps, apply_stencil_conv

PATTERNS = ["Box-2D1R", "Star-2D1R", "Box-2D3R", "Box-2D7R", "Box-3D1R"]


def _gstencils(spec, t, hw, backend) -> float:
    w = pm.StencilWorkload(spec, t, 4)
    if backend == "vector":
        p = pm.perf_vector(w, hw)
    else:
        s = pm.sparsity_banded(spec.radius * t, 128)
        p = pm.perf_matrix(w, hw, s)
    # GStencils/s = updates/s; one update = one (point, step); t amortized
    return p.stencil_throughput(w) * t / 1e9


_wall_us = time_us   # warmup + block_until_ready per call (benchmarks.timing)


def run() -> list[str]:
    out = ["fig16.pattern,t,model_vec_GSt/s,model_mat_GSt/s,model_winner,"
           "cpu_rolls_us,cpu_conv_us"]
    for name in PATTERNS:
        spec = StencilSpec.from_name(name)
        t = 4 if spec.dim == 2 else 2
        gv = _gstencils(spec, t, pm.TPU_V5E_BF16, "vector")
        gm = _gstencils(spec, t, pm.TPU_V5E_BF16, "matrix")
        winner = "vector" if gv >= gm else "matrix"
        # CPU wall-clock of the two oracle lowerings (small grid)
        n = 256 if spec.dim == 2 else 48
        x = jnp.asarray(np.random.default_rng(0)
                        .normal(size=(n,) * spec.dim).astype(np.float32))
        w = jnp.asarray(make_weights(spec, seed=0))
        f1 = jax.jit(lambda x: apply_stencil_steps(x, w, t))
        f2 = jax.jit(lambda x: apply_stencil_conv(x, w))
        us1 = _wall_us(f1, x)
        us2 = _wall_us(f2, x)
        out.append(f"fig16.{name},{t},{gv:.1f},{gm:.1f},{winner},"
                   f"{us1:.0f},{us2:.0f}")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
