"""Paper Table 2: analytical vs measured C / M / I.

Analytical columns come from the performance model (Eq. 8/11).  "Measured"
columns are counted from the COMPILED XLA programs of our own execution
paths via the trip-count-aware HLO analyzer (this container's stand-in for
ncu): the vector path is the temporally-fused stencil program, the matrix
path is the banded-contraction program with the same shapes the Pallas
kernel issues to the MXU."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import perfmodel as pm
from repro.core.hlo_cost import analyze_hlo
from repro.kernels.stencil_matmul import build_bands
from repro.stencil import StencilSpec, make_weights, fuse_weights
from repro.stencil.reference import apply_stencil_steps

N = 512          # benchmark grid edge (points counted per-point at the end)
TILE_N = 128


def _measured_vector(spec, t, dtype):
    """Compiled flops/point of the t-fused vector-unit execution.

    Uses the production local path (halo-extended valid application, the
    distributed runtime's kernel) rather than the roll-based oracle, whose
    wraparound index plumbing would inflate the elementwise count."""
    import numpy as np
    from repro.stencil.distributed import apply_stencil_valid

    w = make_weights(spec, seed=0).astype(dtype)
    sup = np.asarray(w) != 0
    r = spec.radius
    x = jax.ShapeDtypeStruct((N + 2 * t * r, N + 2 * t * r), jnp.dtype(dtype))

    def run(xe):
        for _ in range(t):
            xe = apply_stencil_valid(xe, jnp.asarray(w), support=sup)
        return xe

    pc = analyze_hlo(jax.jit(run).lower(x).compile().as_text())
    return pc.flops / (N * N)


def _measured_matrix(spec, t, dtype):
    """Compiled flops/point of the banded-matmul (monolithic fusion) path.

    Mirrors kernels/stencil_matmul.py: per kernel-row banded contraction on
    (TILE_M, TILE_N + 2R) x (TILE_N + 2R, TILE_N) operands."""
    wf = fuse_weights(make_weights(spec, seed=0), t).astype(dtype)
    R = (wf.shape[0] - 1) // 2
    bands = jnp.asarray(build_bands(wf.astype(np.float32), TILE_N).astype(dtype))
    xt = jax.ShapeDtypeStruct((128, TILE_N + 2 * R), jnp.dtype(dtype))

    def run(a):
        acc = jnp.zeros((128, TILE_N), jnp.float32)
        for dy in range(2 * R + 1):
            acc = acc + jax.lax.dot(a, bands[dy],
                                    preferred_element_type=jnp.float32)
        return acc

    pc = analyze_hlo(jax.jit(run).lower(xt).compile().as_text())
    return pc.flops / (128 * TILE_N) * t / t   # per output point, t fused


ROWS = [
    # (impl, spec, t, dtype_bytes, S) -- S None = vector path
    ("vector(EBISU-like)", StencilSpec("box", 2, 1), 3, 8, None),
    ("vector(EBISU-like)", StencilSpec("box", 2, 3), 1, 8, None),
    ("vector(EBISU-like)", StencilSpec("box", 2, 1), 7, 4, None),
    ("vector(EBISU-like)", StencilSpec("box", 2, 7), 1, 4, None),
    ("matrix(banded-MXU)", StencilSpec("box", 2, 1), 3, 8, "banded"),
    ("matrix(banded-MXU)", StencilSpec("box", 2, 1), 7, 4, "banded"),
    ("matrix(ConvStencil-S)", StencilSpec("box", 2, 1), 3, 8, 0.5),
    ("matrix(SPIDER-S)", StencilSpec("box", 2, 1), 7, 4, 0.47),
]


def run() -> list[str]:
    out = ["table2.impl,pattern,t,dtype,C_analytic,C_measured,dC%,I_analytic,M_ideal"]
    for impl, spec, t, D, S in ROWS:
        w = pm.StencilWorkload(spec, t, D)
        dtype = jnp.float32 if D == 4 else jnp.float64
        if S is None:
            c_model = w.flops_vector()
            c_meas = _measured_vector(spec, t, dtype)
            i_model = w.intensity_vector()
        else:
            s_val = pm.sparsity_banded(spec.radius * t, TILE_N) \
                if S == "banded" else S
            c_model = w.flops_matrix(s_val)
            i_model = w.intensity_matrix(s_val)
            if S == "banded":
                c_meas = _measured_matrix(spec, t, dtype)
            else:
                c_meas = c_model     # published-scheme S: no local kernel
        d = 100 * (c_meas - c_model) / c_model
        out.append(f"table2.{impl},{spec.name},{t},{'f32' if D==4 else 'f64'},"
                   f"{c_model:.1f},{c_meas:.1f},{d:+.1f}%,{i_model:.2f},"
                   f"{w.bytes_per_output()}")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
