"""The async serving engine: submit -> coalesce -> batched guarded plans.

:class:`StencilServer` is the subsystem's hot loop (DESIGN.md §12).
``submit`` stamps the request with its unbatched plan signature and
returns a ``concurrent.futures.Future`` immediately; a dispatcher thread
drains the queue (lingering up to ``queue_timeout_ms`` for the queue to
fill toward ``max_batch``), coalesces by signature into power-of-two
buckets (``repro.serve.coalesce``), and executes each bucket through ONE
batched plan -- ``stencil_plan(..., batch=B)``, guarded by default, so
PR 6's degradation ladder applies per-batch and a Mosaic failure demotes
the bucket instead of crashing the server.  ``jax.block_until_ready``
fires exactly once per batch, at the response boundary, never per
request.

Plan reuse happens at two levels: the engine keeps its own
(signature, bucket) -> plan table (so steady-state dispatch is one dict
hit), and the table populates through the process-wide plan LRU (so two
engines, or an engine plus direct ``stencil_plan`` callers, share
compiled executables -- the LRU's lock makes that safe from dispatcher
threads).

Caller bugs stay in the caller: ``submit`` validates arguments through
``plan_signature`` synchronously and raises there; only *kernel*
failures reach the guarded dispatch path.  A batch whose every rung
fails resolves each of its futures with the terminal
``GuardedExecutionError`` -- the dispatcher thread itself never dies.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core import perfmodel as pm
from repro.kernels import guard as _guard
from repro.kernels import plan as _plan
from .coalesce import (Batch, ServeRequest, coalesce, serve_buckets,
                       serve_max_batch, serve_queue_timeout_ms, stack_batch)
from .metrics import ServeMetrics


class StencilServer:
    """Batched plan-sharing stencil server.

    Args:
      max_batch: cap on requests per batched launch (None = the
        ``REPRO_SERVE_MAX_BATCH`` knob).
      buckets: allowed batch bucket ladder (None = ``REPRO_SERVE_BUCKETS``).
      queue_timeout_ms: dispatcher linger after the first queued request
        (None = ``REPRO_SERVE_QUEUE_TIMEOUT_MS``); 0 dispatches whatever
        is queued the moment the dispatcher wakes.
      guard: route batches through :func:`guarded_stencil_plan` (default).
        ``False`` executes raw plans -- kernel failures then fail the
        affected futures with the raw exception.
      watchdog: NaN/Inf watchdog for guarded batches (None = the
        ``REPRO_NAN_WATCHDOG`` env flag).
      hw: hardware model consulted by the selector for every plan.
      interpret / batch_mode / compute_dtype: forwarded to every plan.

    Use as a context manager or call :meth:`shutdown`; queued requests
    are drained (never dropped) on shutdown.
    """

    def __init__(self, *,
                 max_batch: Optional[int] = None,
                 buckets: Optional[Sequence[int]] = None,
                 queue_timeout_ms: Optional[int] = None,
                 guard: bool = True,
                 watchdog: Optional[bool] = None,
                 hw: pm.HardwareSpec = pm.TPU_V5E_BF16,
                 interpret: Optional[bool] = None,
                 batch_mode: str = "auto",
                 compute_dtype=None):
        self.max_batch = serve_max_batch() if max_batch is None \
            else int(max_batch)
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        self.buckets = serve_buckets() if buckets is None \
            else tuple(sorted(set(int(b) for b in buckets)))
        if not self.buckets or self.buckets[0] < 1:
            raise ValueError(f"buckets must be positive ints, "
                             f"got {self.buckets}")
        timeout_ms = serve_queue_timeout_ms() if queue_timeout_ms is None \
            else int(queue_timeout_ms)
        if timeout_ms < 0:
            raise ValueError(f"queue_timeout_ms must be >= 0, "
                             f"got {timeout_ms}")
        self.queue_timeout_s = timeout_ms / 1e3
        self.guard = bool(guard)
        self.watchdog = watchdog
        self.hw = hw
        self.interpret = interpret
        self.batch_mode = batch_mode
        self.compute_dtype = compute_dtype

        self.metrics = ServeMetrics()
        self._cv = threading.Condition()
        self._queue: List[ServeRequest] = []
        self._seq = 0
        self._stopping = False
        # (signature, bucket) -> plan; touched ONLY by the dispatcher
        # thread, so no lock -- the process-wide plan LRU underneath has
        # its own.
        self._plans: Dict[Tuple[tuple, int], object] = {}
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, name="repro-serve-dispatch",
            daemon=True)
        self._dispatcher.start()

    # -- client side -----------------------------------------------------
    def submit(self, weights, x, t: int = 1, **plan_kwargs) -> Future:
        """Queue one request; returns its future.

        ``weights``/``t``/``plan_kwargs`` mirror ``stencil_plan`` (backend
        override, geometry pins, ...); the grid shape and dtype come from
        ``x`` itself.  Argument errors raise HERE, in the caller's
        thread -- a request that cannot even be keyed never enters the
        queue."""
        if self._stopping:
            raise RuntimeError("StencilServer is shut down")
        for k in ("batch", "batch_mode", "mesh", "shard_spec"):
            if k in plan_kwargs:
                raise ValueError(f"submit() forbids {k!r}: batching is the "
                                 "engine's job and meshes do not compose "
                                 "with batched serving")
        if not hasattr(x, "dtype"):
            x = np.asarray(x)
        kwargs = dict(plan_kwargs)
        kwargs.setdefault("hw", self.hw)
        kwargs.setdefault("interpret", self.interpret)
        kwargs.setdefault("compute_dtype", self.compute_dtype)
        key, w, grid_shape, _ = _plan.plan_signature(
            weights, np.shape(x), x.dtype, t, **kwargs)

        fut: Future = Future()
        with self._cv:
            if self._stopping:
                raise RuntimeError("StencilServer is shut down")
            req = ServeRequest(
                x=x, weights=w, grid_shape=grid_shape, dtype=x.dtype, t=t,
                plan_kwargs=kwargs, signature=key, future=fut,
                submit_s=time.perf_counter(), seq=self._seq)
            self._seq += 1
            self._queue.append(req)
            # Wake the dispatcher only at the edges that matter: the
            # empty->non-empty transition (it may be idle) and hitting
            # the fill target (it may be lingering).  Notifying on EVERY
            # submit turns the linger into a wakeup storm -- the
            # dispatcher re-checks the fill level once per request and
            # the GIL ping-pong costs more than the batch itself.
            # (Submission metrics are likewise deferred to dispatch --
            # record_submits -- keeping this path to one lock.)
            n = len(self._queue)
            if n == 1 or n >= self.max_batch:
                self._cv.notify()
        return fut

    # -- lifecycle -------------------------------------------------------
    def shutdown(self, timeout: Optional[float] = 30.0) -> None:
        """Stop accepting requests, drain the queue, join the dispatcher."""
        with self._cv:
            if self._stopping:
                return
            self._stopping = True
            self._cv.notify_all()
        self._dispatcher.join(timeout)

    def __enter__(self) -> "StencilServer":
        return self

    def __exit__(self, *exc) -> bool:
        self.shutdown()
        return False

    def stats(self) -> dict:
        """Metrics snapshot plus plan bookkeeping (engine table size and
        the process-wide plan-cache counters)."""
        out = self.metrics.snapshot()
        out["engine_plans"] = len(self._plans)
        out["plan_cache"] = _plan.plan_cache_stats()
        return out

    # -- dispatcher side -------------------------------------------------
    def _drain(self) -> List[ServeRequest]:
        """Block until work exists (or shutdown), linger up to the queue
        timeout for the batch to fill, then take the whole queue."""
        with self._cv:
            while not self._queue:
                if self._stopping:
                    return []
                self._cv.wait(timeout=0.05)
            if self.queue_timeout_s > 0:
                deadline = time.perf_counter() + self.queue_timeout_s
                while (len(self._queue) < self.max_batch
                       and not self._stopping):
                    remaining = deadline - time.perf_counter()
                    if remaining <= 0:
                        break
                    self._cv.wait(timeout=remaining)
            drained, self._queue = self._queue, []
            return drained

    def _dispatch_loop(self) -> None:
        while True:
            drained = self._drain()
            if not drained:
                return                     # stopping and queue empty
            for batch in coalesce(drained, buckets=self.buckets,
                                  max_batch=self.max_batch):
                self._execute(batch)

    def _plan_for(self, batch: Batch):
        key = (batch.signature, batch.bucket)
        plan = self._plans.get(key)
        if plan is None:
            lead = batch.requests[0]
            kw = dict(lead.plan_kwargs)
            hw = kw.pop("hw", self.hw)
            if self.guard:
                plan = _guard.guarded_stencil_plan(
                    lead.weights, lead.grid_shape, lead.dtype, lead.t,
                    watchdog=self.watchdog, hw=hw, batch=batch.bucket,
                    batch_mode=self.batch_mode, **kw)
            else:
                plan = _plan.stencil_plan(
                    lead.weights, lead.grid_shape, lead.dtype, lead.t,
                    hw=hw, batch=batch.bucket, batch_mode=self.batch_mode,
                    **kw)
            self._plans[key] = plan
        return plan

    def _execute(self, batch: Batch) -> None:
        # submission accounting lands here, at dispatch, derived from the
        # drained requests -- counted whether the batch then succeeds or
        # fails, so submitted == responded + failed once the queue drains
        self.metrics.record_submits(
            batch.signature, len(batch.requests),
            min(req.submit_s for req in batch.requests))
        try:
            plan = self._plan_for(batch)
            xb = stack_batch(batch)
            yb = plan(jax.numpy.asarray(xb))
            # THE response boundary: one device sync per batch.  Every
            # other sync in the serving path would serialize the pipeline.
            jax.block_until_ready(yb)
            # One device->host transfer for the whole batch.  Responses
            # are numpy: slicing the on-device array per request would
            # dispatch a fresh device computation per slice -- measured at
            # ~10x the batched kernel itself on small grids.
            yb = np.asarray(yb)
        except Exception as exc:  # noqa: BLE001 -- resolves futures, never dies
            self.metrics.record_failure(len(batch.requests))
            for req in batch.requests:
                if not req.future.cancelled():
                    req.future.set_exception(exc)
            return
        done_s = time.perf_counter()
        # strip padding: slots >= len(requests) are never observable
        for i, req in enumerate(batch.requests):
            if not req.future.cancelled():
                req.future.set_result(yb[i])
        self.metrics.record_responses(
            [done_s - req.submit_s for req in batch.requests])
        self.metrics.record_batch(len(batch.requests), batch.bucket,
                                  degraded=bool(getattr(plan, "degraded",
                                                        False)))
