"""repro.serve: batched plan-sharing serving engine (DESIGN.md §12).

Production stencil traffic is many concurrent small problems sharing a
handful of plan signatures -- the same amortization problem the paper's
profitability criteria solve one level down.  This package executes
millions of requests through a handful of compiled plans:

  * ``coalesce``  -- group queued requests by plan signature and pad them
    into power-of-two batch buckets (``repro.serve.coalesce``);
  * ``StencilServer`` -- the async engine: ``submit`` returns a future,
    a dispatcher thread runs batched guarded plans, and
    ``jax.block_until_ready`` fires only at response boundaries
    (``repro.serve.engine``);
  * ``ServeMetrics`` -- requests/s, batch occupancy, and P50/P99 latency
    histograms (``repro.serve.metrics``), dumped to BENCH_serving.json by
    ``benchmarks/serving.py``.

Knobs: ``REPRO_SERVE_BUCKETS``, ``REPRO_SERVE_MAX_BATCH``,
``REPRO_SERVE_QUEUE_TIMEOUT_MS`` (all via ``repro.core.envutil``).
"""
from .coalesce import (Batch, ServeRequest, choose_bucket, coalesce,
                       serve_buckets, serve_max_batch,
                       serve_queue_timeout_ms, stack_batch)
from .engine import StencilServer
from .metrics import LatencyHistogram, ServeMetrics

__all__ = [
    "Batch", "LatencyHistogram", "ServeMetrics", "ServeRequest",
    "StencilServer", "choose_bucket", "coalesce", "serve_buckets",
    "serve_max_batch", "serve_queue_timeout_ms", "stack_batch",
]
