"""Serving metrics: requests/s, batch occupancy, P50/P99 latency.

Latencies land in a fixed log2 histogram (:class:`LatencyHistogram`) --
bounded memory at millions of requests, unlike a reservoir -- with exact
count/sum/min/max kept alongside so the mean is not quantized.
Percentiles interpolate linearly inside the winning bucket, which bounds
the error to one bucket width (a factor of 2 in latency); for serving
dashboards that resolution is the standard trade (HDR-histogram style).

:class:`ServeMetrics` is the engine-facing aggregate: thread-safe (the
dispatcher records completions while clients record submissions), cheap
to record into (one lock, O(1) work), and ``snapshot()`` emits the
JSON-ready dict ``benchmarks/serving.py`` dumps into BENCH_serving.json.
"""
from __future__ import annotations

import math
import threading
import time
from typing import Any, Dict, List, Optional

#: Histogram buckets: bucket ``i`` holds latencies in [2^i, 2^(i+1)) us.
#: 40 buckets span 1 us .. ~12.7 days -- nothing a serving path can
#: produce falls off either end (sub-us clamps into bucket 0).
_N_BUCKETS = 40


class LatencyHistogram:
    """Fixed-size log2 latency histogram over microseconds.

    Not thread-safe on its own -- :class:`ServeMetrics` serializes access;
    standalone users (tests, benchmarks) record from one thread.
    """

    def __init__(self):
        self.counts: List[int] = [0] * _N_BUCKETS
        self.count = 0
        self.sum_s = 0.0
        self.min_s = math.inf
        self.max_s = 0.0

    @staticmethod
    def _bucket(seconds: float) -> int:
        us = seconds * 1e6
        if us < 1.0:
            return 0
        return min(int(math.log2(us)), _N_BUCKETS - 1)

    def record(self, seconds: float) -> None:
        if seconds < 0:
            raise ValueError(f"latency must be >= 0, got {seconds}")
        self.counts[self._bucket(seconds)] += 1
        self.count += 1
        self.sum_s += seconds
        self.min_s = min(self.min_s, seconds)
        self.max_s = max(self.max_s, seconds)

    def percentile(self, q: float) -> float:
        """The latency (seconds) at quantile ``q`` in [0, 1]: linear
        interpolation inside the bucket holding the q-th record, clamped
        to the observed min/max so tiny samples stay sane."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        if self.count == 0:
            return 0.0
        rank = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if seen + c >= rank:
                lo, hi = float(2 ** i), float(2 ** (i + 1))
                frac = (rank - seen) / c
                est = (lo + frac * (hi - lo)) * 1e-6
                return min(max(est, self.min_s), self.max_s)
            seen += c
        return self.max_s

    def snapshot(self) -> Dict[str, Any]:
        out = {
            "count": self.count,
            "mean_ms": (self.sum_s / self.count * 1e3) if self.count else 0.0,
            "min_ms": (self.min_s * 1e3) if self.count else 0.0,
            "max_ms": self.max_s * 1e3,
            "p50_ms": self.percentile(0.50) * 1e3,
            "p99_ms": self.percentile(0.99) * 1e3,
            # only the occupied buckets, upper-edge labeled
            "buckets": [{"le_us": 2 ** (i + 1), "count": c}
                        for i, c in enumerate(self.counts) if c],
        }
        return out


class ServeMetrics:
    """Thread-safe serving aggregate: latency histogram + throughput +
    batch-occupancy accounting.

    The wall-clock window for requests/s runs from the first submit to
    the last response (both recorded here), so a snapshot taken mid-burst
    and one taken after drain agree on the completed-request rate.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._lat = LatencyHistogram()
        self._submitted = 0
        self._responded = 0
        self._failed = 0
        self._batches = 0
        self._batch_slots = 0      # sum of bucket sizes launched
        self._padded_slots = 0
        self._degraded_batches = 0
        self._signatures = set()
        self._first_submit_s: Optional[float] = None
        self._last_response_s: Optional[float] = None

    # -- recording (engine + submit path) -------------------------------
    def record_submit(self, signature: tuple) -> None:
        with self._lock:
            self._submitted += 1
            self._signatures.add(signature)
            if self._first_submit_s is None:
                self._first_submit_s = time.perf_counter()

    def record_submits(self, signature: tuple, n: int,
                       first_submit_s: float) -> None:
        """Batch variant, called by the DISPATCHER when a batch launches
        rather than by clients per request: the submit path stays
        lock-free (its cost is paid on every request of every client),
        and everything here -- count, signature, the earliest submit
        stamp -- is derivable from the drained requests themselves."""
        with self._lock:
            self._submitted += n
            self._signatures.add(signature)
            if self._first_submit_s is None \
                    or first_submit_s < self._first_submit_s:
                self._first_submit_s = first_submit_s

    def record_batch(self, n_requests: int, bucket: int,
                     degraded: bool = False) -> None:
        with self._lock:
            self._batches += 1
            self._batch_slots += bucket
            self._padded_slots += bucket - n_requests
            if degraded:
                self._degraded_batches += 1

    def record_response(self, latency_s: float) -> None:
        with self._lock:
            self._lat.record(latency_s)
            self._responded += 1
            self._last_response_s = time.perf_counter()

    def record_responses(self, latencies_s) -> None:
        """Batch variant: one lock round-trip for a whole batch's worth
        of completions (the engine resolves batches, not requests)."""
        with self._lock:
            for latency_s in latencies_s:
                self._lat.record(latency_s)
            self._responded += len(latencies_s)
            self._last_response_s = time.perf_counter()

    def record_failure(self, n_requests: int = 1) -> None:
        with self._lock:
            self._failed += n_requests

    # -- reading ---------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """JSON-ready aggregate; atomic under the lock."""
        with self._lock:
            window_s = 0.0
            if self._first_submit_s is not None \
                    and self._last_response_s is not None:
                window_s = max(self._last_response_s - self._first_submit_s,
                               0.0)
            occ = ((self._batch_slots - self._padded_slots)
                   / self._batch_slots) if self._batch_slots else 0.0
            return {
                "submitted": self._submitted,
                "responded": self._responded,
                "failed": self._failed,
                "distinct_signatures": len(self._signatures),
                "batches": self._batches,
                "batch_slots": self._batch_slots,
                "padded_slots": self._padded_slots,
                "batch_occupancy": occ,
                "degraded_batches": self._degraded_batches,
                "window_s": window_s,
                "requests_per_s": (self._responded / window_s)
                                  if window_s > 0 else 0.0,
                "latency": self._lat.snapshot(),
            }

    def reset(self) -> None:
        """Back to pristine (benchmark warmup hygiene); keeps the lock."""
        with self._lock:
            self._lat = LatencyHistogram()
            self._submitted = self._responded = self._failed = 0
            self._batches = self._batch_slots = self._padded_slots = 0
            self._degraded_batches = 0
            self._signatures = set()
            self._first_submit_s = None
            self._last_response_s = None
