"""Request coalescing: group by plan signature, pad into batch buckets.

The serving engine's whole premise (DESIGN.md §12) is that traffic
clusters on a handful of plan signatures, so dispatch should amortize one
batched launch over every queued request that shares one.  This module is
the pure-policy half of that: :func:`coalesce` turns a drained queue into
an ordered list of :class:`Batch` objects, each holding requests of ONE
signature padded up to a power-of-two bucket size.  It never touches
device state, which is what makes the bucketing property-testable:

  * batches never mix plan signatures (a batched plan is specialized to
    one signature -- mixing would execute the wrong kernel);
  * bucket choice is a deterministic pure function of the request
    sequence and the knobs (no timestamps, no randomness), so a replayed
    queue coalesces identically;
  * padding is accounted per batch (``Batch.pad``) and stripped by the
    engine before any response -- padded slots can never leak.

Power-of-two buckets keep the number of DISTINCT compiled batched plans
per signature logarithmic in the max batch (each (signature, bucket)
pair is its own plan-cache entry): arbitrary batch sizes would compile a
new executable per queue-depth fluctuation.
"""
from __future__ import annotations

import numpy as np
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.envutil import env_int, env_int_list

#: Default bucket ladder: powers of two up to the default max batch.
DEFAULT_BUCKETS = (1, 2, 4, 8, 16, 32)
#: Default cap on requests per batched launch.
DEFAULT_MAX_BATCH = 32
#: Default dispatcher linger: after the first request arrives, wait this
#: long for the queue to fill toward max_batch before launching.  0 means
#: dispatch whatever is queued immediately.
DEFAULT_QUEUE_TIMEOUT_MS = 2


def serve_buckets() -> Tuple[int, ...]:
    """The effective bucket ladder: ``REPRO_SERVE_BUCKETS`` (comma list of
    positive ints) if set, else :data:`DEFAULT_BUCKETS`; always returned
    sorted ascending with duplicates dropped."""
    return tuple(sorted(set(
        env_int_list("REPRO_SERVE_BUCKETS", DEFAULT_BUCKETS, minimum=1))))


def serve_max_batch() -> int:
    """``REPRO_SERVE_MAX_BATCH`` if set (positive int), else
    :data:`DEFAULT_MAX_BATCH`."""
    return env_int("REPRO_SERVE_MAX_BATCH", DEFAULT_MAX_BATCH, minimum=1)


def serve_queue_timeout_ms() -> int:
    """``REPRO_SERVE_QUEUE_TIMEOUT_MS`` if set (>= 0), else
    :data:`DEFAULT_QUEUE_TIMEOUT_MS`."""
    return env_int("REPRO_SERVE_QUEUE_TIMEOUT_MS",
                   DEFAULT_QUEUE_TIMEOUT_MS, minimum=0)


@dataclass
class ServeRequest:
    """One queued stencil request, signature-stamped at submit time.

    ``signature`` is the UNBATCHED plan-signature key
    (``repro.kernels.plan.plan_signature`` without ``batch``) -- the
    coalescing identity.  ``plan_kwargs`` carries everything the engine
    needs to rebuild the plan per bucket (backend override, geometry
    pins, interpret, compute_dtype, hw)."""

    x: object                      # the input grid (numpy or jax array)
    weights: np.ndarray
    grid_shape: Tuple[int, ...]
    dtype: object
    t: int
    plan_kwargs: dict
    signature: tuple
    future: object                 # concurrent.futures.Future
    submit_s: float                # perf_counter stamp for latency
    seq: int                       # arrival order (deterministic tiebreak)


@dataclass
class Batch:
    """Requests of one plan signature, padded to ``bucket`` slots."""

    signature: tuple
    requests: List[ServeRequest]
    bucket: int

    @property
    def pad(self) -> int:
        """Padded slots executed but never returned to any caller."""
        return self.bucket - len(self.requests)

    @property
    def occupancy(self) -> float:
        """Useful fraction of the launch: 1.0 = no padding."""
        return len(self.requests) / self.bucket


def choose_bucket(n: int, buckets: Sequence[int], max_batch: int) -> int:
    """The bucket a group of ``n`` requests pads up to: the smallest
    allowed bucket >= ``n``.  Buckets above ``max_batch`` are never used;
    if the ladder has no entry >= ``n`` the largest allowed bucket is
    returned (callers chunk groups to that cap first).  Deterministic:
    depends only on the arguments."""
    if n < 1:
        raise ValueError(f"bucket request count must be >= 1, got {n}")
    allowed = [b for b in sorted(set(buckets)) if b <= max_batch]
    if not allowed:
        # ladder entirely above the cap: batches are exactly the cap
        return max_batch
    for b in allowed:
        if b >= n:
            return b
    return allowed[-1]


def coalesce(requests: Sequence[ServeRequest], *,
             buckets: Optional[Sequence[int]] = None,
             max_batch: Optional[int] = None) -> List[Batch]:
    """Turn a drained queue into signature-pure, bucket-padded batches.

    Requests are grouped by ``signature`` preserving arrival order (both
    across groups -- first-seen signature dispatches first -- and within
    a group), each group is chunked to at most ``cap = min(max_batch,
    largest allowed bucket)`` requests, and each chunk pads up to
    :func:`choose_bucket` of its length.  Pure function of
    ``(requests, buckets, max_batch)``.
    """
    if buckets is None:
        buckets = serve_buckets()
    if max_batch is None:
        max_batch = serve_max_batch()
    allowed = [b for b in sorted(set(buckets)) if b <= max_batch]
    cap = allowed[-1] if allowed else max_batch

    groups: Dict[tuple, List[ServeRequest]] = {}
    for req in requests:
        groups.setdefault(req.signature, []).append(req)

    out: List[Batch] = []
    for sig, reqs in groups.items():
        for lo in range(0, len(reqs), cap):
            chunk = reqs[lo:lo + cap]
            out.append(Batch(signature=sig, requests=chunk,
                             bucket=choose_bucket(len(chunk), buckets,
                                                  max_batch)))
    return out


def stack_batch(batch: Batch) -> np.ndarray:
    """The batched input: request grids stacked along a new leading axis,
    padded slots filled with zero grids.  The engine slices responses to
    ``len(batch.requests)``, so padded outputs are computed (the launch
    shape is the bucket) but never observable.

    Preallocate-and-assign rather than ``np.stack``: the assignment loop
    zero-fills padding for free and skips stack's per-element
    expand_dims/concatenate machinery on the dispatch hot path."""
    first = np.asarray(batch.requests[0].x)
    out = np.zeros((batch.bucket,) + first.shape, first.dtype)
    for i, r in enumerate(batch.requests):
        out[i] = r.x
    return out
