"""Decoder-only transformer LM: dense (llama/glm/deepseek/tinyllama),
MoE (olmoe/qwen3-moe) and VLM (internvl2 backbone + stub patch embeds).

Layers are scanned with stacked parameters (one-layer HLO regardless of
depth -- critical for 94-layer dry-run compile times) and optionally
remat'ed (``cfg.remat``).  Decode threads a stacked KV-cache pytree through
the same scan.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.registry import ModelConfig
from repro.models import layers as nn
from repro.models import moe as moe_lib
from repro.models.base import ParamDef
from repro.parallel.sharding import logical


def param_defs(cfg: ModelConfig):
    L = cfg.n_layers
    block: Dict[str, Any] = {
        "ln1": ParamDef((L, cfg.d_model), ("layers", None), init="ones"),
        "ln2": ParamDef((L, cfg.d_model), ("layers", None), init="ones"),
        "attn": nn.attn_defs(cfg, L),
    }
    if cfg.family == "moe":
        block["moe"] = moe_lib.moe_defs(cfg, L)
    else:
        block["mlp"] = nn.mlp_defs(cfg, L)
    defs = {"blocks": block, **nn.embed_defs(cfg)}
    if cfg.family == "vlm":
        # stub frontend -> backbone projector (patch embeds arrive precomputed)
        defs["img_proj"] = ParamDef((cfg.d_model, cfg.d_model),
                                    ("w_embed", "w_embed2"))
    return defs


def _block(cfg, h, lp, positions, cache=None):
    """One transformer block.  Returns (h, new_cache, aux)."""
    a_in = nn.rmsnorm(h, lp["ln1"], cfg.norm_eps)
    attn_out, new_cache = nn.attention(lp["attn"], a_in, cfg, positions,
                                       cache=cache)
    h = h + attn_out
    m_in = nn.rmsnorm(h, lp["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        m_out, aux = moe_lib.moe_mlp(lp["moe"], m_in, cfg)
    else:
        m_out, aux = nn.mlp(lp["mlp"], m_in, cfg), 0.0
    h = h + m_out
    return logical(h, "batch", "seq", "embed"), new_cache, aux


def forward(params, tokens, cfg: ModelConfig, img_embeds=None, caches=None,
            positions=None):
    """Run the backbone.  Returns (hidden, new_caches, aux_loss).

    * train/prefill: caches=None, tokens (B, S) [+ img_embeds (B, P, D)].
    * decode: caches = stacked KV pytree, tokens (B, 1).
    """
    dtype = jnp.dtype(cfg.dtype)
    h = nn.embed(params, tokens, cfg, dtype)
    if cfg.family == "vlm" and img_embeds is not None:
        img = jnp.einsum("bpd,de->bpe", img_embeds.astype(dtype),
                         params["img_proj"].astype(dtype))
        h = jnp.concatenate([img, h], axis=1)
        h = logical(h, "batch", "seq", "embed")
    B, S, _ = h.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    blocks = params["blocks"]

    if caches is None:
        def body(carry, lp):
            h, aux = carry
            h, _, a = _block(cfg, h, lp, positions)
            return (h, aux + a), None

        body_fn = jax.checkpoint(body, policy=None) if cfg.remat else body
        (h, aux), _ = jax.lax.scan(body_fn, (h, jnp.zeros((), jnp.float32)),
                                   blocks)
        return h, None, aux

    def body(h, xs):
        lp, cache = xs
        h, new_cache, _ = _block(cfg, h, lp, positions, cache=cache)
        return h, new_cache

    h, new_caches = jax.lax.scan(body, h, (blocks, caches))
    return h, new_caches, jnp.zeros((), jnp.float32)


def loss_fn(params, batch, cfg: ModelConfig):
    """batch: {tokens (B,S) int32, [img_embeds (B,P,D)]}.  Next-token CE."""
    tokens = batch["tokens"]
    img = batch.get("img_embeds")
    inp = tokens[:, :-1]
    labels = tokens[:, 1:]
    h, _, aux = forward(params, inp, cfg, img_embeds=img)
    if img is not None:
        h = h[:, img.shape[1]:]          # loss on the text positions only
    loss = nn.chunked_xent(params, h, labels, cfg)
    return loss + 0.01 * aux, {"xent": loss, "aux": aux}


def init_caches(cfg: ModelConfig, batch: int, max_seq: int):
    """Stacked (L-leading) KV caches for decode."""
    one = nn.init_kv_cache(cfg, batch, max_seq, jnp.dtype(cfg.dtype))
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_layers,) + x.shape), one
    )


def prefill(params, tokens, cfg: ModelConfig, max_seq: int, img_embeds=None):
    """Full-sequence pass that also fills the KV caches (no sampling here).

    Implemented as forward + per-layer recompute of K/V: for dry-run and
    serving-bench purposes we fill caches by scanning blocks WITH cache
    writes at full sequence length."""
    B, S = tokens.shape
    caches = init_caches(cfg, B, max_seq)
    dtype = jnp.dtype(cfg.dtype)
    h = nn.embed(params, tokens, cfg, dtype)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(h, xs):
        lp, cache = xs
        a_in = nn.rmsnorm(h, lp["ln1"], cfg.norm_eps)
        k = jnp.einsum("bsd,dhk->bshk", a_in, lp["attn"]["wk"].astype(dtype))
        v = jnp.einsum("bsd,dhk->bshk", a_in, lp["attn"]["wv"].astype(dtype))
        k = nn.rope(k, positions, cfg.rope_theta)
        cache = dict(cache)
        cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
        cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
        cache["pos"] = jnp.full((), S, jnp.int32)
        h, _, _ = _block(cfg, h, lp, positions)
        return h, cache

    h, caches = jax.lax.scan(body, h, (params["blocks"], caches))
    logits = nn.lm_logits(params, h[:, -1:], cfg)
    return logits, caches


def decode_step(params, caches, token, cfg: ModelConfig, pos):
    """One greedy decode step.  token (B,1) -> (next (B,1), new caches)."""
    B = token.shape[0]
    positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
    h, new_caches, _ = forward(params, token, cfg, caches=caches,
                               positions=positions)
    logits = nn.lm_logits(params, h, cfg)
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return nxt, new_caches
