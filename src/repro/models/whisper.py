"""Whisper-style encoder-decoder backbone.  The conv/log-mel frontend is a
STUB per the assignment: ``input_specs`` feeds precomputed frame embeddings
(B, S_frames, d_model) straight into the encoder.

Decode = decoder one-token step with self-attn KV cache + cross-attn over
cached encoder K/V.  RoPE replaces Whisper's absolute embeddings
(DESIGN.md simplification; the backbone compute/communication profile is
what the dry-run measures).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.registry import ModelConfig
from repro.models import layers as nn
from repro.models.base import ParamDef
from repro.parallel.sharding import logical


def param_defs(cfg: ModelConfig):
    L, Ld = cfg.n_layers, cfg.dec_layers
    D = cfg.d_model
    enc_block = {
        "ln1": ParamDef((L, D), ("layers", None), init="ones"),
        "ln2": ParamDef((L, D), ("layers", None), init="ones"),
        "attn": nn.attn_defs(cfg, L),
        "mlp": nn.mlp_defs(cfg, L),
    }
    dec_block = {
        "ln1": ParamDef((Ld, D), ("layers", None), init="ones"),
        "ln2": ParamDef((Ld, D), ("layers", None), init="ones"),
        "ln3": ParamDef((Ld, D), ("layers", None), init="ones"),
        "self_attn": nn.attn_defs(cfg, Ld),
        "cross_attn": nn.attn_defs(cfg, Ld),
        "mlp": nn.mlp_defs(cfg, Ld),
    }
    return {"encoder": enc_block, "decoder": dec_block,
            # Whisper's ln_post: the encoder residual stream is normalized
            # before cross-attention K/V consume it.  Without it, enc_h's
            # magnitude (seeded by unit-variance frame embeddings and grown
            # by every residual add) leaks straight into the decoder through
            # _cross_kv, blowing up early gradients ~50x vs the other archs.
            "enc_ln_post": ParamDef((D,), (None,), init="ones"),
            **nn.embed_defs(cfg)}


def encode(params, frames, cfg: ModelConfig):
    """frames: (B, S_f, D) precomputed embeddings (stub frontend output)."""
    dtype = jnp.dtype(cfg.dtype)
    h = logical(frames.astype(dtype), "batch", "seq", "embed")
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(h, lp):
        a_in = nn.rmsnorm(h, lp["ln1"], cfg.norm_eps)
        a, _ = nn.attention(lp["attn"], a_in, cfg, positions, causal=False)
        h = h + a
        m_in = nn.rmsnorm(h, lp["ln2"], cfg.norm_eps)
        h = h + nn.mlp(lp["mlp"], m_in, cfg)
        return logical(h, "batch", "seq", "embed"), None

    body_fn = jax.checkpoint(body, policy=None) if cfg.remat else body
    h, _ = jax.lax.scan(body_fn, h, params["encoder"])
    return nn.rmsnorm(h, params["enc_ln_post"], cfg.norm_eps)


def _cross_kv(lp, enc_h, cfg):
    """Precompute cross-attention K/V from encoder states (per dec layer)."""
    dtype = enc_h.dtype
    k = jnp.einsum("bsd,dhk->bshk", enc_h, lp["cross_attn"]["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_h, lp["cross_attn"]["wv"].astype(dtype))
    return k, v


def decode_train(params, tokens, enc_h, cfg: ModelConfig):
    """Teacher-forced decoder pass over full target sequence."""
    dtype = jnp.dtype(cfg.dtype)
    h = nn.embed(params, tokens, cfg, dtype)
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(h, lp):
        a_in = nn.rmsnorm(h, lp["ln1"], cfg.norm_eps)
        a, _ = nn.attention(lp["self_attn"], a_in, cfg, positions, causal=True)
        h = h + a
        c_in = nn.rmsnorm(h, lp["ln2"], cfg.norm_eps)
        ck, cv = _cross_kv(lp, enc_h, cfg)
        c, _ = nn.attention(lp["cross_attn"], c_in, cfg, positions,
                            cross_kv=(ck, cv), use_rope=False)
        h = h + c
        m_in = nn.rmsnorm(h, lp["ln3"], cfg.norm_eps)
        h = h + nn.mlp(lp["mlp"], m_in, cfg)
        return logical(h, "batch", "seq", "embed"), None

    body_fn = jax.checkpoint(body, policy=None) if cfg.remat else body
    h, _ = jax.lax.scan(body_fn, h, params["decoder"])
    return h


def loss_fn(params, batch, cfg: ModelConfig):
    """batch: {frames (B,Sf,D), tokens (B,St)}."""
    enc_h = encode(params, batch["frames"], cfg)
    tokens = batch["tokens"]
    h = decode_train(params, tokens[:, :-1], enc_h, cfg)
    loss = nn.chunked_xent(params, h, tokens[:, 1:], cfg)
    return loss, {"xent": loss}


def init_caches(cfg: ModelConfig, batch: int, max_seq: int, enc_seq: int):
    Ld = cfg.dec_layers
    kv = nn.init_kv_cache(cfg, batch, max_seq, jnp.dtype(cfg.dtype))
    KVH, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    stack = lambda x: jnp.broadcast_to(x[None], (Ld,) + x.shape)
    return {
        "self": jax.tree.map(stack, kv),
        "cross_k": jnp.zeros((Ld, batch, enc_seq, KVH, hd), dt),
        "cross_v": jnp.zeros((Ld, batch, enc_seq, KVH, hd), dt),
    }


def prefill(params, frames, cfg: ModelConfig, batch: int, max_seq: int):
    """Encode audio + precompute cross K/V for decoding."""
    enc_h = encode(params, frames, cfg)
    caches = init_caches(cfg, batch, max_seq, frames.shape[1])

    def body(_, xs):
        lp, = xs
        ck, cv = _cross_kv(lp, enc_h, cfg)
        return None, (ck, cv)

    _, (cks, cvs) = jax.lax.scan(body, None, (params["decoder"],))
    caches["cross_k"] = cks.astype(caches["cross_k"].dtype)
    caches["cross_v"] = cvs.astype(caches["cross_v"].dtype)
    return caches


def decode_step(params, caches, token, cfg: ModelConfig, pos):
    dtype = jnp.dtype(cfg.dtype)
    B = token.shape[0]
    h = nn.embed(params, token, cfg, dtype)
    positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)

    def body(h, xs):
        lp, cache, ck, cv = xs
        a_in = nn.rmsnorm(h, lp["ln1"], cfg.norm_eps)
        a, new_cache = nn.attention(lp["self_attn"], a_in, cfg, positions,
                                    cache=cache)
        h = h + a
        c_in = nn.rmsnorm(h, lp["ln2"], cfg.norm_eps)
        c, _ = nn.attention(lp["cross_attn"], c_in, cfg, positions,
                            cross_kv=(ck.astype(dtype), cv.astype(dtype)),
                            use_rope=False)
        h = h + c
        m_in = nn.rmsnorm(h, lp["ln3"], cfg.norm_eps)
        h = h + nn.mlp(lp["mlp"], m_in, cfg)
        return h, new_cache

    h, new_self = jax.lax.scan(
        body, h,
        (params["decoder"], caches["self"], caches["cross_k"], caches["cross_v"]))
    new_caches = dict(caches, self=new_self)
    logits = nn.lm_logits(params, h, cfg)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_caches
