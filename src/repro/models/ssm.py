"""Mamba2-style selective SSM block (SSD, scalar-per-head decay), chunked.

Production path is the chunked (SSD) algorithm: within a chunk the
contribution matrix is dense (MXU-friendly einsums); across chunks a scan
carries the (B, H, hd, N) state.  A naive per-token scan oracle lives in
tests for equivalence checking.  Decode is the O(1) recurrence step.

Simplifications vs the full Mamba2 (noted in DESIGN.md): single B/C group,
conv only on the x-branch, no RMSNorm-in-block variants.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.base import ParamDef
from repro.parallel.sharding import logical


def ssm_dims(cfg):
    d_inner = cfg.ssm.expand * cfg.d_model
    hd = cfg.ssm.head_dim
    nheads = d_inner // hd
    return d_inner, nheads, hd, cfg.ssm.state_dim


def ssm_defs(cfg, L: int) -> Dict[str, ParamDef]:
    D = cfg.d_model
    d_inner, H, hd, N = ssm_dims(cfg)
    cw = cfg.ssm.conv_width
    lead = (L,) if L else ()
    la = ("layers",) if L else ()
    return {
        # fused in-projection: [z, x, B, C, dt]
        "w_in": ParamDef(lead + (D, 2 * d_inner + 2 * N + H),
                         la + ("w_embed", "mlp")),
        "conv": ParamDef(lead + (cw, d_inner), la + ("conv", "mlp"),
                         init="normal", scale=0.5),
        "A_log": ParamDef(lead + (H,), la + ("heads",), init="zeros"),
        "dt_bias": ParamDef(lead + (H,), la + ("heads",), init="zeros"),
        "Dskip": ParamDef(lead + (H,), la + ("heads",), init="ones"),
        "w_out": ParamDef(lead + (d_inner, D), la + ("mlp", "w_embed")),
    }


def _split_proj(proj, cfg):
    d_inner, H, hd, N = ssm_dims(cfg)
    z, xc, b, c, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N], axis=-1
    )
    return z, xc, b, c, dt


def _causal_conv(x, w, state: Optional[jax.Array] = None):
    """Depthwise causal conv over seq.  x:(B,S,C), w:(cw,C).

    state (B, cw-1, C) carries the left context for decode; returns
    (y, new_state)."""
    cw = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(w[i].astype(x.dtype) * xp[:, i : i + x.shape[1]] for i in range(cw))
    new_state = xp[:, -(cw - 1):] if cw > 1 else None
    return y, new_state


def _segsum(lw):
    """lw: (..., C) log-decays -> (..., C, C) lower-tri pairwise sums.

    out[i, j] = sum_{s=j+1..i} lw[s]  (j < i),  0 on diagonal, -inf above.
    """
    C = lw.shape[-1]
    cs = jnp.cumsum(lw, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]      # cum[i] - cum[j]
    mask = jnp.tril(jnp.ones((C, C), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssm_scan_chunked(xh, b, c, dt, A, state, chunk: int = 64):
    """Chunked SSD.  xh:(B,S,H,hd)  b,c:(B,S,N)  dt:(B,S,H)  A:(H,) < 0.

    state: (B,H,hd,N) carried across chunks.  Returns (y, final_state).
    """
    B, S, H, hd = xh.shape
    N = b.shape[-1]
    nchunks = max(1, S // chunk)
    chunk = S // nchunks

    lw = (dt * A[None, None, :]).astype(jnp.float32)        # log-decay (B,S,H)
    xdt = xh * dt[..., None].astype(xh.dtype)               # dt-weighted input

    def scanned(carry, inputs):
        st = carry                                          # (B,H,hd,N) f32
        xc_, bc_, cc_, lwc_ = inputs                        # chunk slices
        # (B,H,C,C) pairwise decay factors
        seg = _segsum(jnp.moveaxis(lwc_, 1, -1))            # (B,H,C,C)
        decay = jnp.exp(seg)
        # intra-chunk: scores_ij = (c_i . b_j) * decay_ij   (causal incl diag)
        g = jnp.einsum("bin,bjn->bij", cc_.astype(jnp.float32),
                       bc_.astype(jnp.float32))             # (B,C,C)
        scores = g[:, None] * decay                         # (B,H,C,C)
        y_intra = jnp.einsum("bhij,bjhd->bihd", scores, xdt_f(xc_))
        # inter-chunk: y_i += c_i . (decay_to_i * state)
        cum = jnp.cumsum(jnp.moveaxis(lwc_, 1, -1), axis=-1)  # (B,H,C)
        dec_in = jnp.exp(cum)                               # decay incl token i
        y_inter = jnp.einsum("bin,bhdn,bhi->bihd", cc_.astype(jnp.float32),
                             st, dec_in)
        # state update: st' = exp(cum_C) st + sum_j exp(cum_C - cum_j) b_j x_j
        dec_out = jnp.exp(cum[..., -1:] - cum)              # (B,H,C)
        st_new = jnp.exp(cum[..., -1])[..., None, None] * st + jnp.einsum(
            "bjn,bjhd,bhj->bhdn", bc_.astype(jnp.float32), xdt_f(xc_), dec_out
        )
        return st_new, (y_intra + y_inter)

    def xdt_f(xc_):
        return xc_.astype(jnp.float32)

    xr = xdt.reshape(B, nchunks, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    br = b.reshape(B, nchunks, chunk, N).transpose(1, 0, 2, 3)
    cr = c.reshape(B, nchunks, chunk, N).transpose(1, 0, 2, 3)
    lr = lw.reshape(B, nchunks, chunk, H).transpose(1, 0, 2, 3)
    final, ys = jax.lax.scan(scanned, state.astype(jnp.float32), (xr, br, cr, lr))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)
    return y.astype(xh.dtype), final


def ssm_step(xh, b, c, dt, A, state):
    """O(1) decode step.  xh:(B,1,H,hd) -> (y, new_state)."""
    lw = (dt[:, 0] * A[None, :]).astype(jnp.float32)        # (B,H)
    a = jnp.exp(lw)[..., None, None]                        # (B,H,1,1)
    upd = jnp.einsum("bn,bhd->bhdn", b[:, 0].astype(jnp.float32),
                     (xh[:, 0] * dt[:, 0, :, None]).astype(jnp.float32))
    st = a * state + upd
    y = jnp.einsum("bn,bhdn->bhd", c[:, 0].astype(jnp.float32), st)
    return y[:, None].astype(xh.dtype), st


def mamba_block(p, x, cfg, state=None, conv_state=None, chunk: int = 64):
    """Full Mamba2 block.  state None => chunked full-sequence training path.

    Returns (y, (ssm_state, conv_state)).
    """
    B, S, D = x.shape
    d_inner, H, hd, N = ssm_dims(cfg)
    proj = jnp.einsum("bsd,dp->bsp", x, p["w_in"].astype(x.dtype))
    z, xc, b, c, dt_raw = _split_proj(proj, cfg)
    xc, conv_state = _causal_conv(xc, p["conv"], conv_state)
    xc = jax.nn.silu(xc)
    xc = logical(xc, "batch", None, "mlp")
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))  # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))            # (H,) < 0
    xh = xc.reshape(B, S, H, hd)
    if state is None:
        state0 = jnp.zeros((B, H, hd, N), jnp.float32)
        y, new_state = ssm_scan_chunked(xh, b, c, dt, A, state0, chunk)
    else:
        y, new_state = ssm_step(xh, b, c, dt, A, state)
    y = y + p["Dskip"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(B, S, d_inner) * jax.nn.silu(z)
    out = jnp.einsum("bsp,pd->bsd", y, p["w_out"].astype(x.dtype))
    return logical(out, "batch", "seq", "embed"), (new_state, conv_state)


def init_ssm_cache(cfg, batch: int):
    d_inner, H, hd, N = ssm_dims(cfg)
    cw = cfg.ssm.conv_width
    return {
        "ssm": jnp.zeros((batch, H, hd, N), jnp.float32),
        "conv": jnp.zeros((batch, cw - 1, d_inner), jnp.float32),
    }
