"""Mixture-of-Experts layer: top-k routing with capacity, expert parallelism.

t5x/mesh-style dispatch: tokens are grouped by batch row; within each group
every expert accepts at most ``capacity`` tokens (deterministic shapes --
required for pjit).  Dispatch/combine are one-hot einsums; with experts
sharded over the `model` axis the dispatched activations reshard
group-sharded -> expert-sharded, which XLA lowers to the canonical MoE
all-to-all.  Dropped tokens (over capacity) fall through on the residual.

Load-balancing auxiliary loss follows Switch/OLMoE: aux = E * sum_e f_e * p_e.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.base import ParamDef
from repro.parallel.sharding import logical


def moe_defs(cfg, L: int) -> Dict[str, ParamDef]:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    lead = (L,) if L else ()
    la = ("layers",) if L else ()
    return {
        "router": ParamDef(lead + (D, E), la + ("w_embed", None), scale=0.1),
        "wg": ParamDef(lead + (E, D, F), la + ("experts", "w_embed", "expert_mlp")),
        "wu": ParamDef(lead + (E, D, F), la + ("experts", "w_embed", "expert_mlp")),
        "wd": ParamDef(lead + (E, F, D), la + ("experts", "expert_mlp", "w_embed")),
    }


def moe_mlp(p, x, cfg):
    """x: (B, S, D) -> (B, S, D), plus scalar aux loss.

    ``cfg.moe_group > 0`` routes within sequence groups of that size
    (t5x-style): capacity -- and with it the (tokens, E, cap)
    dispatch/combine tensors and their resharding collectives -- shrinks
    linearly with group size (EXPERIMENTS.md §Perf, qwen3 cell)."""
    B, S, D = x.shape
    g = getattr(cfg, "moe_group", 0) or 0
    if g and g < S and S % g == 0:
        ng = S // g
        xg = x.reshape(B * ng, g, D)
        yg, aux = _moe_mlp_grouped(p, xg, cfg)
        return yg.reshape(B, S, D), aux
    return _moe_mlp_grouped(p, x, cfg)


def _moe_mlp_grouped(p, x, cfg):
    B, S, D = x.shape
    E, K = cfg.moe.num_experts, cfg.moe.top_k
    cap = max(1, int(cfg.moe.capacity_factor * S * K / E))

    gate_logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(x.dtype))
    gate_logits = gate_logits.astype(jnp.float32)
    probs = jax.nn.softmax(gate_logits, axis=-1)            # (B,S,E)

    topk_p, topk_i = jax.lax.top_k(probs, K)                # (B,S,K)
    topk_p = topk_p / jnp.sum(topk_p, axis=-1, keepdims=True)

    # position of each (token, k) inside its expert's buffer
    onehot = jax.nn.one_hot(topk_i, E, dtype=jnp.float32)   # (B,S,K,E)
    flat = onehot.reshape(B, S * K, E)
    pos = jnp.cumsum(flat, axis=1) - flat                   # slots before me
    pos = pos.reshape(B, S, K, E)
    within = (pos < cap) * onehot                           # keep-mask
    slot = jnp.einsum("bske,bske->bsk", pos, onehot)        # my slot id

    # dispatch tensor (B, S, E, cap): 1 where token s -> expert e slot c.
    # bf16 + explicit expert-sharding keep the resharding collectives at
    # reduce-scatter size instead of a full-tensor f32 all-reduce
    # (EXPERIMENTS.md §Perf B1/B3); slot arithmetic above stays f32.
    slot_oh = jax.nn.one_hot(slot.astype(jnp.int32), cap,
                             dtype=jnp.float32)   # (B,S,K,cap)
    dispatch = jnp.einsum("bske,bskc->bsec", within, slot_oh).astype(x.dtype)
    combine = jnp.einsum("bsk,bske,bskc->bsec", topk_p, within,
                         slot_oh).astype(x.dtype)
    dispatch = logical(dispatch, "batch", None, "experts", None)
    combine = logical(combine, "batch", None, "experts", None)

    xin = jnp.einsum("bsec,bsd->ebcd", dispatch, x)
    xin = logical(xin, "experts", "batch", None, None)
    g = jnp.einsum("ebcd,edf->ebcf", xin, p["wg"].astype(x.dtype))
    u = jnp.einsum("ebcd,edf->ebcf", xin, p["wu"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    eout = jnp.einsum("ebcf,efd->ebcd", h, p["wd"].astype(x.dtype))
    eout = logical(eout, "experts", "batch", None, None)
    y = jnp.einsum("ebcd,bsec->bsd", eout, combine)

    # Switch-style load balance aux
    density = jnp.mean(onehot.sum(2), axis=(0, 1))          # fraction routed
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(density / K * mean_prob)
    return logical(y, "batch", "seq", "embed"), aux
