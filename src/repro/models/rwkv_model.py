"""RWKV-6 full model: scanned (time_mix + channel_mix) layers over the
shared embedding/head.  State pytree (per layer): last-token streams for
both mixes + the (B,H,K,V) WKV state -- O(1) in sequence length, which is
why rwkv6 runs the long_500k decode cell."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.registry import ModelConfig
from repro.models import layers as nn
from repro.models import rwkv6
from repro.models.base import ParamDef


def param_defs(cfg: ModelConfig):
    L = cfg.n_layers
    return {
        "blocks": {
            "ln1": ParamDef((L, cfg.d_model), ("layers", None), init="ones"),
            "ln2": ParamDef((L, cfg.d_model), ("layers", None), init="ones"),
            "tm": rwkv6.timemix_defs(cfg, L),
            "cm": rwkv6.chanmix_defs(cfg, L),
        },
        **nn.embed_defs(cfg),
    }


def init_state(cfg: ModelConfig, batch: int):
    H, hd = rwkv6.rwkv_dims(cfg)
    L, D = cfg.n_layers, cfg.d_model
    return {
        "tm_last": jnp.zeros((L, batch, 1, D), jnp.dtype(cfg.dtype)),
        "cm_last": jnp.zeros((L, batch, 1, D), jnp.dtype(cfg.dtype)),
        "wkv": jnp.zeros((L, batch, H, hd, hd), jnp.float32),
    }


def forward(params, tokens, cfg: ModelConfig, state=None):
    dtype = jnp.dtype(cfg.dtype)
    h = nn.embed(params, tokens, cfg, dtype)
    B = h.shape[0]
    if state is None:
        state = init_state(cfg, B)

    def body(h, xs):
        lp, tm_last, cm_last, wkv = xs
        a_in = nn.rmsnorm(h, lp["ln1"], cfg.norm_eps)
        a, (tm_last2, wkv2) = rwkv6.time_mix(lp["tm"], a_in, cfg, tm_last, wkv)
        h = h + a
        c_in = nn.rmsnorm(h, lp["ln2"], cfg.norm_eps)
        c, cm_last2 = rwkv6.channel_mix(lp["cm"], c_in, cfg, cm_last)
        h = h + c
        return h, (tm_last2.astype(tm_last.dtype),
                   cm_last2.astype(cm_last.dtype), wkv2)

    xs = (params["blocks"], state["tm_last"], state["cm_last"], state["wkv"])
    if cfg.remat and tokens.shape[1] > 1:
        body = jax.checkpoint(body, policy=None)
    h, (tm2, cm2, wkv2) = jax.lax.scan(body, h, xs)
    return h, {"tm_last": tm2, "cm_last": cm2, "wkv": wkv2}


def loss_fn(params, batch, cfg: ModelConfig):
    tokens = batch["tokens"]
    h, _ = forward(params, tokens[:, :-1], cfg)
    loss = nn.chunked_xent(params, h, tokens[:, 1:], cfg)
    return loss, {"xent": loss}


def decode_step(params, state, token, cfg: ModelConfig, pos=None):
    h, new_state = forward(params, token, cfg, state=state)
    logits = nn.lm_logits(params, h, cfg)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_state
