"""Minimal pure-JAX module system: parameter definitions with logical axes.

No flax/optax offline -- parameters are plain pytrees.  Each model builds a
nested dict of ``ParamDef`` (shape + logical axis names + initializer); from
that single source of truth we derive
  * initialized parameter pytrees (``init_tree``),
  * ``PartitionSpec`` pytrees via the logical->mesh rules
    (``repro.parallel.sharding``),
so parameters and their shardings can never drift apart.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]          # logical axis name per dim
    init: str = "normal"                     # normal | zeros | ones | scaled
    scale: float = 1.0
    dtype: Any = jnp.float32

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes} rank mismatch")


def _init_one(key, d: ParamDef):
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init == "normal":
        # fan-in scaled truncated-normal-ish init (last dim = fan-out conv.)
        fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
        std = d.scale / math.sqrt(max(1, fan_in))
        return std * jax.random.normal(key, d.shape, d.dtype)
    if d.init == "embed":
        return d.scale * jax.random.normal(key, d.shape, d.dtype)
    raise ValueError(f"unknown init {d.init}")


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_tree(defs, key):
    """Initialize a pytree of ParamDef into a pytree of arrays."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))
    arrs = [_init_one(k, d) for k, d in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, arrs)


def axes_tree(defs):
    """Pytree of logical-axes tuples, matching init_tree's structure."""
    return jax.tree.map(lambda d: d.axes, defs, is_leaf=is_def)


def shape_tree(defs):
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs, is_leaf=is_def
    )


def param_count(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=is_def)
    return int(sum(np.prod(d.shape) for d in leaves))
