"""Unified model interface: one entry point per family for the launcher,
dry-run, trainer and tests.

    model = get_model(cfg)
    model.param_defs()      -> ParamDef pytree
    model.loss_fn(params, batch)            (train/prefill compute)
    model.init_caches(batch, seq)           (decode state)
    model.decode_step(params, caches, token, pos)
    model.input_specs(shape_cell)           ShapeDtypeStructs for dry-run
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.registry import ModelConfig, ShapeCell
from repro.models import base
from repro.models import transformer, zamba, whisper, rwkv_model


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    _defs: Callable
    _loss: Callable
    _init_caches: Optional[Callable]
    _decode: Optional[Callable]

    def param_defs(self):
        return self._defs(self.cfg)

    def param_shapes(self):
        return base.shape_tree(self.param_defs())

    def init_params(self, key):
        return base.init_tree(self.param_defs(), key)

    def param_count(self) -> int:
        return base.param_count(self.param_defs())

    def loss_fn(self, params, batch):
        return self._loss(params, batch, self.cfg)

    def init_caches(self, batch: int, max_seq: int):
        return self._init_caches(self.cfg, batch, max_seq)

    def decode_step(self, params, caches, token, pos):
        return self._decode(params, caches, token, self.cfg, pos)

    # ------------------------------------------------------------------
    # Dry-run input avals
    # ------------------------------------------------------------------
    def input_specs(self, cell: ShapeCell) -> Dict[str, Any]:
        cfg = self.cfg
        B, S = cell.global_batch, cell.seq_len
        i32 = jnp.int32
        dt = jnp.dtype(cfg.dtype)
        sds = jax.ShapeDtypeStruct
        if cell.kind == "train":
            if cfg.family == "whisper":
                return {"frames": sds((B, S, cfg.d_model), dt),
                        "tokens": sds((B, S + 1), i32)}
            if cfg.family == "vlm":
                P = cfg.n_img_patches
                return {"tokens": sds((B, S - P + 1), i32),
                        "img_embeds": sds((B, P, cfg.d_model), dt)}
            return {"tokens": sds((B, S + 1), i32)}
        if cell.kind == "prefill":
            if cfg.family == "whisper":
                return {"frames": sds((B, S, cfg.d_model), dt),
                        "tokens": sds((B, S + 1), i32)}
            if cfg.family == "vlm":
                P = cfg.n_img_patches
                return {"tokens": sds((B, S - P + 1), i32),
                        "img_embeds": sds((B, P, cfg.d_model), dt)}
            return {"tokens": sds((B, S + 1), i32)}
        # decode: caches at full length + one token
        caches = jax.eval_shape(lambda: self.init_caches(B, S))
        return {"caches": caches,
                "token": sds((B, 1), i32),
                "pos": sds((), i32)}


def _whisper_caches(cfg, batch, max_seq):
    # encoder context scales with the cell seq too; enc_seq == max_seq
    return whisper.init_caches(cfg, batch, max_seq, max_seq)


_FAMILIES = {
    "dense": (transformer.param_defs, transformer.loss_fn,
              transformer.init_caches, transformer.decode_step),
    "moe": (transformer.param_defs, transformer.loss_fn,
            transformer.init_caches, transformer.decode_step),
    "vlm": (transformer.param_defs, transformer.loss_fn,
            transformer.init_caches, transformer.decode_step),
    "hybrid": (zamba.param_defs, zamba.loss_fn,
               zamba.init_caches, zamba.decode_step),
    "whisper": (whisper.param_defs, whisper.loss_fn,
                _whisper_caches, whisper.decode_step),
    "rwkv": (rwkv_model.param_defs, rwkv_model.loss_fn,
             lambda cfg, b, s: rwkv_model.init_state(cfg, b),
             rwkv_model.decode_step),
}


def get_model(cfg: ModelConfig) -> Model:
    defs, loss, caches, decode = _FAMILIES[cfg.family]
    return Model(cfg, defs, loss, caches, decode)
