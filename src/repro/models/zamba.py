"""Zamba2-style hybrid: Mamba2 backbone + a SHARED attention block applied
every ``ssm.shared_attn_every`` layers (weights shared, activations and KV
caches distinct per application site).

Layer scan carries (h, aux) and the stacked per-site KV cache; the shared
block fires under ``lax.cond`` on the layer index (both branches traced
once -- HLO stays one-layer-sized).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.registry import ModelConfig
from repro.models import layers as nn
from repro.models import ssm as ssm_lib
from repro.models.base import ParamDef
from repro.parallel.sharding import logical


def n_shared_sites(cfg) -> int:
    k = cfg.ssm.shared_attn_every
    return (cfg.n_layers + k - 1) // k


def param_defs(cfg: ModelConfig):
    L = cfg.n_layers
    return {
        "mamba": {
            "ln": ParamDef((L, cfg.d_model), ("layers", None), init="ones"),
            "block": ssm_lib.ssm_defs(cfg, L),
        },
        "shared": {                       # ONE set of weights, many sites
            "ln1": ParamDef((cfg.d_model,), (None,), init="ones"),
            "ln2": ParamDef((cfg.d_model,), (None,), init="ones"),
            "attn": nn.attn_defs(cfg, 0),
            "mlp": nn.mlp_defs(cfg, 0),
        },
        **nn.embed_defs(cfg),
    }


def _shared_block(cfg, params, h, positions, cache=None):
    sp = params["shared"]
    a_in = nn.rmsnorm(h, sp["ln1"], cfg.norm_eps)
    attn_out, new_cache = nn.attention(sp["attn"], a_in, cfg, positions,
                                       cache=cache)
    h = h + attn_out
    m_in = nn.rmsnorm(h, sp["ln2"], cfg.norm_eps)
    h = h + nn.mlp(sp["mlp"], m_in, cfg)
    return h, new_cache


def forward(params, tokens, cfg: ModelConfig, caches=None, positions=None):
    """caches: {"kv": stacked (sites,...) KV, "ssm": (L,...), "conv": (L,...)}"""
    dtype = jnp.dtype(cfg.dtype)
    h = nn.embed(params, tokens, cfg, dtype)
    B, S, _ = h.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    every = cfg.ssm.shared_attn_every
    L = cfg.n_layers
    decode = caches is not None

    if decode:
        kv_cache = caches["kv"]
        lp_st = ({"ln": params["mamba"]["ln"], "block": params["mamba"]["block"]},
                 caches["ssm"], caches["conv"])

        def body2(carry, xs):
            h, kv = carry
            (lp, st, cv), idx = xs

            def with_attn(h, kv):
                site = idx // every
                c = jax.tree.map(lambda x: jax.lax.dynamic_index_in_dim(
                    x, site, 0, keepdims=False), kv)
                h2, new_c = _shared_block(cfg, params, h, positions, cache=c)
                kv2 = jax.tree.map(
                    lambda full, one: jax.lax.dynamic_update_index_in_dim(
                        full, one.astype(full.dtype), site, 0),
                    kv, new_c)
                return h2, kv2

            h, kv = jax.lax.cond(idx % every == 0, with_attn,
                                 lambda h, kv: (h, kv), h, kv)
            m_in = nn.rmsnorm(h, lp["ln"], cfg.norm_eps)
            out, (st2, cv2) = ssm_lib.mamba_block(lp["block"], m_in, cfg,
                                                  state=st, conv_state=cv)
            return (h + out, kv), (st2, cv2)

        (h, kv_cache), (ssm2, conv2) = jax.lax.scan(
            body2, (h, kv_cache), (lp_st, jnp.arange(L)))
        new_caches = {"kv": kv_cache, "ssm": ssm2, "conv": conv2}
        return h, new_caches, jnp.zeros((), jnp.float32)

    # ---- training / full-sequence path (no KV cache: chunked attention)
    def body(carry, xs):
        h = carry
        lp, idx = xs

        def with_attn(h):
            h2, _ = _shared_block(cfg, params, h, positions)
            return h2

        h = jax.lax.cond(idx % every == 0, with_attn, lambda h: h, h)
        m_in = nn.rmsnorm(h, lp["ln"], cfg.norm_eps)
        out, _ = ssm_lib.mamba_block(lp["block"], m_in, cfg)
        return h + out, None

    body_fn = jax.checkpoint(body, policy=None) if cfg.remat else body
    lp = {"ln": params["mamba"]["ln"], "block": params["mamba"]["block"]}
    h, _ = jax.lax.scan(body_fn, h, (lp, jnp.arange(L)))
    return h, None, jnp.zeros((), jnp.float32)


def loss_fn(params, batch, cfg: ModelConfig):
    tokens = batch["tokens"]
    h, _, _ = forward(params, tokens[:, :-1], cfg)
    loss = nn.chunked_xent(params, h, tokens[:, 1:], cfg)
    return loss, {"xent": loss}


def init_caches(cfg: ModelConfig, batch: int, max_seq: int):
    sites = n_shared_sites(cfg)
    kv = nn.init_kv_cache(cfg, batch, max_seq, jnp.dtype(cfg.dtype))
    kv = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (sites,) + x.shape), kv)
    s = ssm_lib.init_ssm_cache(cfg, batch)
    L = cfg.n_layers
    return {
        "kv": kv,
        "ssm": jnp.broadcast_to(s["ssm"][None], (L,) + s["ssm"].shape),
        "conv": jnp.broadcast_to(s["conv"][None], (L,) + s["conv"].shape),
    }


def decode_step(params, caches, token, cfg: ModelConfig, pos):
    B = token.shape[0]
    positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
    h, new_caches, _ = forward(params, token, cfg, caches=caches,
                               positions=positions)
    logits = nn.lm_logits(params, h, cfg)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_caches
