"""RWKV-6 ("Finch") block: time-mix with data-dependent per-channel decay,
plus channel-mix.  Attention-free; O(1) decode state.

Time-mix recurrence (per head, K = V = head_dim):
    S_t = diag(w_t) S_{t-1} + k_t (x) v_t
    y_t = r_t . (S_{t-1} + diag(u) k_t (x) v_t)
with w_t = exp(-exp(wx_t)) data-dependent (projected from x) -- the paper's
(arXiv:2404.05892) signature feature.  Training uses a chunked form whose
pairwise decay factors are exp of non-positive sums (numerically safe);
tests check it against the naive per-token recurrence.

Simplification vs full RWKV-6 (DESIGN.md): static token-shift lerp
coefficients (not the LoRA-produced dynamic mix), no GroupNorm (RMSNorm).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.base import ParamDef
from repro.parallel.sharding import logical


def rwkv_dims(cfg):
    hd = cfg.d_model // cfg.n_heads
    return cfg.n_heads, hd


def timemix_defs(cfg, L: int) -> Dict[str, ParamDef]:
    D = cfg.d_model
    H, hd = rwkv_dims(cfg)
    lead = (L,) if L else ()
    la = ("layers",) if L else ()
    return {
        "mix": ParamDef(lead + (5, D), la + (None, "w_embed"), init="zeros"),
        "wr": ParamDef(lead + (D, D), la + ("w_embed", "mlp")),
        "wk": ParamDef(lead + (D, D), la + ("w_embed", "mlp")),
        "wv": ParamDef(lead + (D, D), la + ("w_embed", "mlp")),
        "wg": ParamDef(lead + (D, D), la + ("w_embed", "mlp")),
        "ww": ParamDef(lead + (D, D), la + ("w_embed", "mlp"), scale=0.1),
        "w_bias": ParamDef(lead + (D,), la + ("w_embed",), init="zeros"),
        "u": ParamDef(lead + (D,), la + ("w_embed",), init="zeros"),
        "wo": ParamDef(lead + (D, D), la + ("mlp", "w_embed")),
        "ln_w": ParamDef(lead + (D,), la + (None,), init="ones"),
    }


def chanmix_defs(cfg, L: int) -> Dict[str, ParamDef]:
    D, F = cfg.d_model, cfg.d_ff
    lead = (L,) if L else ()
    la = ("layers",) if L else ()
    return {
        "mix": ParamDef(lead + (2, D), la + (None, "w_embed"), init="zeros"),
        "wk": ParamDef(lead + (D, F), la + ("w_embed", "mlp")),
        "wv": ParamDef(lead + (F, D), la + ("mlp", "w_embed")),
        "wr": ParamDef(lead + (D, D), la + ("w_embed", "mlp")),
    }


def _token_shift(x, last):
    """x_{t-1} stream; ``last`` (B,1,D) carries state across decode steps."""
    if x.shape[1] == 1:
        return last
    prev = jnp.concatenate([last, x[:, :-1]], axis=1)
    return prev


def _lerp(x, prev, mu):
    return x + (prev - x) * mu.astype(x.dtype)


def wkv_chunked(r, k, v, lw, u, state, chunk: int = 32):
    """Chunked WKV-6.  r,k,v: (B,S,H,K); lw = log w_t (<=0): (B,S,H,K).

    state: (B,H,K,V) f32.  Returns (y, new_state).  All pairwise decay
    factors are exp() of non-positive sums -- numerically safe for any w.
    """
    B, S, H, K = r.shape
    V = v.shape[-1]
    nchunks = max(1, S // chunk)
    chunk = S // nchunks

    def one(st, inp):
        rc, kc, vc, lc = inp                                 # (B,C,H,K), v:(B,C,H,V)
        cum = jnp.cumsum(lc, axis=1)                         # (B,C,H,K) inclusive
        cum_prev = cum - lc
        dmat = cum_prev[:, :, None] - cum[:, None, :]        # (B,Ci,Cj,H,K)
        causal = jnp.tril(jnp.ones((chunk, chunk), bool), -1)
        dec = jnp.exp(jnp.where(causal[None, :, :, None, None], dmat, -jnp.inf))
        scores = jnp.einsum("bihk,bijhk,bjhk->bhij", rc, dec, kc)
        y = jnp.einsum("bhij,bjhv->bihv", scores, vc)
        bonus = jnp.einsum("bihk,hk,bihk->bih", rc, u, kc)
        y = y + bonus[..., None] * vc
        y = y + jnp.einsum("bihk,bhkv->bihv", rc * jnp.exp(cum_prev), st)
        dec_out = jnp.exp(cum[:, -1:] - cum)                 # (B,C,H,K)
        st_new = (jnp.exp(cum[:, -1])[..., None] * st
                  + jnp.einsum("bjhk,bjhv->bhkv", kc * dec_out, vc))
        return st_new, y

    def _chunked(a, d):
        a = a.reshape(B, nchunks, chunk, H, d).transpose(1, 0, 2, 3, 4)
        return logical(a, None, "batch", None, "heads", None)

    rr, kr, vr, lr = (_chunked(r, K), _chunked(k, K), _chunked(v, V),
                      _chunked(lw, K))
    final, ys = jax.lax.scan(one, state, (rr, kr, vr, lr))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, V)
    return y, final


def wkv_chunked_factored(r, k, v, lw, u, state, chunk: int = 16):
    """Beyond-baseline WKV-6: factored intra-chunk decay (no (C,C,K) tensor).

    scores_ij = sum_k [r_ik e^{cumprev_ik}] [k_jk e^{-cum_jk}]  (j<i masked)

    eliminates the (B,C,C,H,K) pairwise tensor of ``wkv_chunked`` -- the
    dominant HBM traffic of rwkv6 training (EXPERIMENTS.md §Perf).  The
    e^{-cum} factor grows with in-chunk position, so safety requires
    chunk * max|log w| <= ~64: callers must clamp lw to [-4, 0] and keep
    chunk <= 16 (enforced here).  Numerics vs the pairwise form are
    identical in f32 up to reassociation (tested)."""
    B, S, H, K = r.shape
    V = v.shape[-1]
    assert chunk * 4.0 <= 66, "factored WKV needs chunk*clamp <= ~64"
    nchunks = max(1, S // chunk)
    chunk = S // nchunks

    def one(st, inp):
        rc, kc, vc, lc = inp                                 # (B,C,H,K)
        cum = jnp.cumsum(lc, axis=1)
        cum_prev = cum - lc
        r_ = rc * jnp.exp(cum_prev)                          # <= |r|
        k_ = kc * jnp.exp(-cum)                              # <= |k| e^{64}
        scores = jnp.einsum("bihk,bjhk->bhij", r_, k_)
        causal = jnp.tril(jnp.ones((chunk, chunk), r.dtype), -1)
        scores = scores * causal[None, None]
        y = jnp.einsum("bhij,bjhv->bihv", scores, vc)
        bonus = jnp.einsum("bihk,hk,bihk->bih", rc, u, kc)
        y = y + bonus[..., None] * vc
        y = y + jnp.einsum("bihk,bhkv->bihv", r_, st)
        dec_out = jnp.exp(cum[:, -1:] - cum)
        st_new = (jnp.exp(cum[:, -1])[..., None] * st
                  + jnp.einsum("bjhk,bjhv->bhkv", kc * dec_out, vc))
        return st_new, y

    def _chunked(a, d):
        a = a.reshape(B, nchunks, chunk, H, d).transpose(1, 0, 2, 3, 4)
        return logical(a, None, "batch", None, "heads", None)

    rr, kr, vr, lr = (_chunked(r, K), _chunked(k, K), _chunked(v, V),
                      _chunked(lw, K))
    final, ys = jax.lax.scan(one, state, (rr, kr, vr, lr))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, V)
    return y, final


def wkv_step(r, k, v, lw, u, state):
    """One-token WKV (B,1,H,K).  y_t = r.(S + u*k v);  S' = w*S + k v."""
    kv = jnp.einsum("bhk,bhv->bhkv", k[:, 0], v[:, 0])
    y = jnp.einsum("bhk,bhkv->bhv", r[:, 0], state + u[None, :, :, None] * kv)
    st = jnp.exp(lw[:, 0])[..., None] * state + kv
    return y[:, None], st


def time_mix(p, x, cfg, last, state, chunk: int = 32):
    """RWKV-6 attention substitute.  Returns (y, (last_x, wkv_state))."""
    B, S, D = x.shape
    H, hd = rwkv_dims(cfg)
    prev = _token_shift(x, last)
    mu = p["mix"].astype(jnp.float32)
    xr = _lerp(x, prev, mu[0])
    xk = _lerp(x, prev, mu[1])
    xv = _lerp(x, prev, mu[2])
    xw = _lerp(x, prev, mu[3])
    xg = _lerp(x, prev, mu[4])

    r = jnp.einsum("bsd,de->bse", xr, p["wr"].astype(x.dtype))
    k = jnp.einsum("bsd,de->bse", xk, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", xv, p["wv"].astype(x.dtype))
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["wg"].astype(x.dtype)))
    # data-dependent decay; clamp keeps exp(-exp(.)) in a sane range.
    # factored mode needs |log w| <= 4 (see wkv_chunked_factored safety).
    wx = jnp.einsum("bsd,de->bse", xw, p["ww"].astype(x.dtype))
    wx = wx.astype(jnp.float32) + p["w_bias"].astype(jnp.float32)
    lo = jnp.log(4.0) if getattr(cfg, "wkv_factored", False) else 1.0
    lw = -jnp.exp(jnp.clip(wx, -8.0, lo))                   # log w_t in [-4,0)
    lw = jnp.maximum(lw, -4.0)

    # Explicit head-sharding constraints: after the S->(chunks, C) reshape
    # XLA loses the axis mapping and all-gathers the full chunk streams
    # (EXPERIMENTS.md §Perf A2); pinning (batch, *, heads, *) keeps the WKV
    # math local per head shard.
    def _heads(a):
        return logical(a.reshape(B, S, H, hd), "batch", None, "heads", None)

    rh = _heads(r.astype(jnp.float32))
    kh = _heads(k.astype(jnp.float32))
    vh = _heads(v.astype(jnp.float32))
    lwh = _heads(lw)
    u = p["u"].astype(jnp.float32).reshape(H, hd)

    if S == 1 and state is not None:
        y, st = wkv_step(rh, kh, vh, lwh, u, state)
    else:
        st0 = state if state is not None else jnp.zeros((B, H, hd, hd), jnp.float32)
        if getattr(cfg, "wkv_factored", False):
            y, st = wkv_chunked_factored(rh, kh, vh, lwh, u, st0,
                                         min(chunk, 16))
        else:
            y, st = wkv_chunked(rh, kh, vh, lwh, u, st0, chunk)

    y = y.reshape(B, S, D).astype(x.dtype)
    from repro.models.layers import rmsnorm
    y = rmsnorm(y, p["ln_w"], cfg.norm_eps) * g
    out = jnp.einsum("bse,ed->bsd", y, p["wo"].astype(x.dtype))
    new_last = x[:, -1:]
    return logical(out, "batch", "seq", "embed"), (new_last, st)


def channel_mix(p, x, cfg, last):
    prev = _token_shift(x, last)
    mu = p["mix"].astype(jnp.float32)
    xk = _lerp(x, prev, mu[0])
    xr = _lerp(x, prev, mu[1])
    k = jnp.einsum("bsd,df->bsf", xk, p["wk"].astype(x.dtype))
    kv = jnp.einsum("bsf,fd->bsd", jnp.square(jax.nn.relu(k)),
                    p["wv"].astype(x.dtype))
    rgate = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"].astype(x.dtype)))
    return logical(rgate * kv, "batch", "seq", "embed"), x[:, -1:]
