"""Shared neural layers: norms, RoPE, GQA attention (train/prefill/decode),
MLPs, embeddings, chunked cross-entropy.  Pure functions over param pytrees.

Sharding: activations/weights are annotated with *logical* axis names via
``repro.parallel.sharding.logical`` -- resolved only inside a
``use_mesh(...)`` context.  Conventions:
  weights:      w_embed (d_model dim; FSDP-shards over data when enabled),
                heads/mlp/vocab/experts (TP dims over `model`)
  activations:  batch (DP), seq (sequence-sharded residual stream over
                `model`), kv_seq (decode KV cache sequence over `model`)

Attention is exact query-chunked ("lazy flash"): per chunk of queries the
full key row is scored, masked, softmaxed -- O(S^2) FLOPs but O(C*S) live
memory, which is what lets 32k prefill fit HBM in the dry-run.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.base import ParamDef
from repro.parallel.sharding import logical


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rmsnorm(x, w, eps=1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w.astype(x.dtype)


def layernorm(x, w, b, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * w.astype(x.dtype) + b.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope(x, positions, theta: float):
    """x: (..., S, H, hd), positions: (..., S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def _even_chunk(s: int, target: int) -> int:
    """Largest divisor of ``s`` that is <= target (handles e.g. the VLM's
    S - n_patches = 3840 text positions against a 512 target)."""
    c = min(target, s)
    while s % c:
        c -= 1
    return c


# ---------------------------------------------------------------------------
# Attention (GQA) -- param defs
# ---------------------------------------------------------------------------
def attn_defs(cfg, L: int, prefix_dims=()) -> Dict[str, ParamDef]:
    D, H, KVH = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    lead = (L,) if L else ()
    la = ("layers",) if L else ()
    return {
        "wq": ParamDef(lead + (D, H, hd), la + ("w_embed", "heads", "head_dim")),
        "wk": ParamDef(lead + (D, KVH, hd), la + ("w_embed", "heads", "head_dim")),
        "wv": ParamDef(lead + (D, KVH, hd), la + ("w_embed", "heads", "head_dim")),
        "wo": ParamDef(lead + (H, hd, D), la + ("heads", "head_dim", "w_embed")),
    }


def _expand_kv(k, n_heads):
    """(B,S,KVH,hd) -> (B,S,H,hd) by group replication."""
    b, s, kvh, hd = k.shape
    g = n_heads // kvh
    return jnp.repeat(k, g, axis=2)


def _chunked_attention(q, k, v, positions_q, positions_k, causal, chunk):
    """Exact chunked attention, flash-style residency.  q:(B,Sq,H,hd).

    * dots run on bf16 operands with f32 accumulation (MXU semantics);
      only the softmax runs in f32;
    * each chunk is jax.checkpoint'ed: backward recomputes scores/probs
      from (qc, k, v) instead of saving the (Sq, Sk) attention matrix --
      the live footprint stays O(chunk * Sk) like flash attention.
    """
    b, sq, h, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    chunk = _even_chunk(sq, chunk)
    nchunks = sq // chunk

    @jax.checkpoint
    def one_chunk(qc, pq):
        # qc:(B,C,H,hd) x k:(B,Sk,H,hd) -> scores (B,H,C,Sk), f32 accum
        scores = jax.lax.dot_general(
            (qc * scale).astype(q.dtype), k,
            (((3,), (3,)), ((0, 2), (0, 2))),
            preferred_element_type=jnp.float32)
        if causal:
            mask = pq[:, None, :, None] >= positions_k[:, None, None, :]
            scores = jnp.where(mask, scores, -1e30)
        p = jax.nn.softmax(scores, axis=-1)
        # p:(B,H,C,Sk) x v:(B,Sk,H,hd) -> (B,H,C,hd), f32 accum
        out = jax.lax.dot_general(
            p.astype(q.dtype), v,
            (((3,), (1,)), ((0, 1), (0, 2))),
            preferred_element_type=jnp.float32)
        return out.transpose(0, 2, 1, 3).astype(q.dtype)   # (B,C,H,hd)

    if nchunks == 1:
        return one_chunk(q, positions_q)

    qr = q.reshape(b, nchunks, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    pr = positions_q.reshape(b, nchunks, chunk).transpose(1, 0, 2)
    out = jax.lax.map(lambda args: one_chunk(*args), (qr, pr))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, hd)


def attention(
    p, x, cfg, positions,
    cache: Optional[Dict[str, Any]] = None,
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
    causal: bool = True,
    use_rope: bool = True,
):
    """GQA attention.  Returns (out, new_cache).

    * train/prefill: cache=None, full-sequence chunked attention.
    * decode: cache={"k","v","pos"}; x is (B,1,D); KV cache is sequence-
      sharded over `model` (logical "kv_seq") -- softmax over the sharded
      key dim lowers to the flash-decoding partial-softmax + combine.
    * cross attention: cross_kv=(k,v) precomputed encoder keys/values.
    """
    B, S, D = x.shape
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim

    # Megatron-SP: all-gather the sequence-sharded residual ONCE at attention
    # entry; k/v below then derive seq-gathered (avoids the SPMD
    # seq->heads "involuntary full rematerialization" reshard).
    x = logical(x, "batch", None, "embed")
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
    q = logical(q, "batch", None, "heads", None)

    if cross_kv is not None:
        k, v = cross_kv
        pos_k = jnp.broadcast_to(jnp.arange(k.shape[1])[None], k.shape[:2])
        k = _expand_kv(k, H)
        v = _expand_kv(v, H)
        out = _chunked_attention(q, k, v, positions, pos_k, False, cfg.attn_chunk)
        new_cache = cache
    elif cache is None:
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
        if use_rope:
            k = rope(k, positions, cfg.rope_theta)
        k = logical(_expand_kv(k, H), "batch", None, "heads", None)
        v = logical(_expand_kv(v, H), "batch", None, "heads", None)
        out = _chunked_attention(q, k, v, positions, positions, causal,
                                 cfg.attn_chunk)
        new_cache = None
    else:
        # --- single-token decode against a sequence-sharded KV cache -------
        k_new = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
        v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
        if use_rope:
            k_new = rope(k_new, positions, cfg.rope_theta)
        pos = cache["pos"]
        kc = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                          (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                          (0, pos, 0, 0))
        kc = logical(kc, "batch", "kv_seq", None, None)
        vc = logical(vc, "batch", "kv_seq", None, None)
        Sk = kc.shape[1]
        g = H // KVH
        qg = q.reshape(B, 1, KVH, g, hd)
        scores = jnp.einsum("bqhgd,bkhd->bhgk", qg.astype(jnp.float32),
                            kc.astype(jnp.float32)) / math.sqrt(hd)
        mask = jnp.arange(Sk)[None] <= pos                   # valid prefix
        scores = jnp.where(mask[:, None, None], scores, -1e30)
        pr = jax.nn.softmax(scores, axis=-1)
        outg = jnp.einsum("bhgk,bkhd->bhgd", pr, vc.astype(jnp.float32))
        out = outg.reshape(B, 1, H, hd).astype(x.dtype)
        new_cache = {"k": kc, "v": vc, "pos": pos + 1}

    y = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), p["wo"].astype(x.dtype))
    return logical(y, "batch", "seq", "embed"), new_cache


def init_kv_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16):
    KVH, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, max_seq, KVH, hd), dtype),
        "v": jnp.zeros((batch, max_seq, KVH, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def mlp_defs(cfg, L: int) -> Dict[str, ParamDef]:
    D, F = cfg.d_model, cfg.d_ff
    lead = (L,) if L else ()
    la = ("layers",) if L else ()
    if cfg.mlp_act == "swiglu":
        return {
            "wg": ParamDef(lead + (D, F), la + ("w_embed", "mlp")),
            "wu": ParamDef(lead + (D, F), la + ("w_embed", "mlp")),
            "wd": ParamDef(lead + (F, D), la + ("mlp", "w_embed")),
        }
    return {
        "wi": ParamDef(lead + (D, F), la + ("w_embed", "mlp")),
        "wd": ParamDef(lead + (F, D), la + ("mlp", "w_embed")),
    }


def mlp(p, x, cfg):
    if cfg.mlp_act == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(x.dtype))
        u = jnp.einsum("bsd,df->bsf", x, p["wu"].astype(x.dtype))
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["wi"].astype(x.dtype)))
    h = logical(h, "batch", None, "mlp")
    y = jnp.einsum("bsf,fd->bsd", h, p["wd"].astype(x.dtype))
    return logical(y, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# Embedding / LM head / loss
# ---------------------------------------------------------------------------
def embed_defs(cfg) -> Dict[str, ParamDef]:
    return {
        "tok_embed": ParamDef((cfg.vocab, cfg.d_model), ("vocab", "w_embed"),
                              init="embed", scale=0.02),
        "lm_head": ParamDef((cfg.d_model, cfg.vocab), ("w_embed", "vocab")),
        "final_norm": ParamDef((cfg.d_model,), (None,), init="ones"),
    }


def embed(p, tokens, cfg, dtype):
    h = jnp.take(p["tok_embed"], tokens, axis=0).astype(dtype)
    return logical(h, "batch", "seq", "embed")


def lm_logits(p, h, cfg):
    h = rmsnorm(h, p["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, p["lm_head"].astype(h.dtype))
    return logical(logits, "batch", None, "vocab")


def chunked_xent(p, h, labels, cfg, chunk: int = 512):
    """Mean next-token CE without materializing (B,S,V) at once.

    h is pre-final-norm hidden states; labels are already shifted.
    """
    B, S, D = h.shape
    chunk = _even_chunk(S, chunk)
    nchunks = S // chunk
    hn = rmsnorm(h, p["final_norm"], cfg.norm_eps)

    @jax.checkpoint
    def one(hc, lc):
        logits = jnp.einsum("bsd,dv->bsv", hc, p["lm_head"].astype(hc.dtype))
        logits = logical(logits, "batch", None, "vocab").astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.sum(
            jnp.where(
                jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2) == lc[..., None],
                logits, 0.0,
            ),
            axis=-1,
        )
        return jnp.sum(lse - ll)

    if nchunks == 1:
        total = one(hn, labels)
    else:
        hr = hn.reshape(B, nchunks, chunk, D).transpose(1, 0, 2, 3)
        lr = labels.reshape(B, nchunks, chunk).transpose(1, 0, 2)
        total = jnp.sum(jax.lax.map(lambda args: one(*args), (hr, lr)))
    return total / (B * S)
