"""Trip-count-aware cost accounting over optimized HLO text.

``compiled.cost_analysis()`` counts every computation ONCE -- a scan of 94
layers reports 1/94th of the real FLOPs (verified empirically; see
tests/test_hlo_cost.py).  Since XLA:SPMD collectives also only exist in the
post-partitioning HLO, we need our own pass anyway; this module parses
``compiled.as_text()`` into computations, builds the call graph
(while/conditional/call/fusion/async), extracts while trip counts from the
loop-condition constants, and accumulates per-computation costs times the
product of enclosing trip counts:

  * flops            -- dot ops: 2 * prod(result dims) * prod(contracting dims)
                        (+1 flop/element for other non-copy ops -- elementwise)
  * bytes            -- operand + result bytes of dot/fusion/copy/dus/gather/
                        scatter/convert ops (a materialized-buffer proxy)
  * collective bytes -- result bytes of all-gather/all-reduce/reduce-scatter/
                        all-to-all/collective-permute, per kind

This is the dry-run "profiler": no wall clock exists on this CPU-only
container, so the perf loop (EXPERIMENTS.md §Perf) reads these terms.
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_ONE = re.compile(r"(\w+)\[([\d,]*)\]")
_NAME_EQ = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_PCT_REF = re.compile(r"%([\w.\-]+)")


def _operand_refs(argstr: str) -> List[str]:
    """Operand names from an HLO argument list.

    Modern XLA prints typed operands -- ``dot(f32[128,128]{1,0} %Arg_0.1,
    f32[128,128]{1,0} %rhs)`` -- so bare-token scraping picks up dtype and
    layout fragments instead of names.  Prefer the ``%``-sigiled refs;
    fall back to loose tokens only for sigil-free dumps."""
    refs = _PCT_REF.findall(argstr)
    if refs:
        return refs
    return re.findall(r"([\w.\-]+)", argstr)


def _parse_op_line(line: str):
    """Parse '  %name = SHAPE opcode(...' -> (name, shape_str, opcode).

    SHAPE may be a tuple '(s32[], f32[...], /*index=5*/ ...)' containing
    '=' inside comments, so we balance parens instead of regexing."""
    m = _NAME_EQ.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    shape = rest[: i + 1]
                    tail = rest[i + 1:]
                    break
        else:
            return None
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        shape = rest[:sp]
        tail = rest[sp:]
    om = re.match(r"\s*([\w\-]+)\(", tail)
    if not om:
        return None
    return name, shape.strip(), om.group(1)


class _OpLineShim:
    """Back-compat shim: _OP_LINE.match(line).group(1|2|3)."""

    def match(self, line):
        r = _parse_op_line(line)
        if r is None:
            return None

        class _M:
            def group(self, i):
                return r[i - 1]

        return _M()


_OP_LINE = _OpLineShim()
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")
_CALLED = re.compile(r"(?:condition|body|true_computation|false_computation|"
                     r"called_computations?|to_apply|calls)=\{?%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")


def _parse_dims(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _shape_elems_bytes(shape_str: str) -> Tuple[int, int]:
    """Total (elements, bytes) over possibly-tuple shape string."""
    elems = 0
    byts = 0
    for m in _SHAPE_ONE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = _parse_dims(dims)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


@dataclasses.dataclass
class OpInfo:
    name: str
    shape: str
    opcode: str
    line: str


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0        # every materialized result (cost_analysis-like)
    bytes_major: float = 0.0  # fusion-aware HBM-traffic estimate (TPU view):
    #   dots (operands+result), fusions (result), parameters (read once),
    #   copies, DUS/gather/scatter, reduces, collectives.  Elementwise /
    #   convert / broadcast results are assumed fused away on TPU.
    coll: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_counts: Dict[str, float] = dataclasses.field(default_factory=dict)
    calls: List[Tuple[str, float]] = dataclasses.field(default_factory=list)


def split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        m = _COMP_HDR.match(line.strip())
        if m and ("{" in line):
            cur = m.group(1)
            comps[cur] = []
            continue
        if cur is not None:
            if line.strip().startswith("}"):
                cur = None
                continue
            comps[cur].append(line)
    return comps


def _entry_name(hlo: str) -> Optional[str]:
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo)
    return m.group(1) if m else None


def _dot_flops(line: str, shape_str: str, shapes: Dict[str, str]) -> float:
    """2 * prod(result) * prod(contracting dims of lhs)."""
    relems, _ = _shape_elems_bytes(shape_str)
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    mo = re.search(r"dot\(([^)]*)\)", line)
    lhs_ref = None
    if mo:
        refs = _operand_refs(mo.group(1))
        lhs_ref = refs[0] if refs else None
    contract = 1
    if mc and lhs_ref:
        lhs_shape = shapes.get(lhs_ref)
        if lhs_shape:
            dims_m = _SHAPE_ONE.search(lhs_shape)
            if dims_m:
                dims = [int(d) for d in dims_m.group(2).split(",") if d]
                for ci in mc.group(1).split(","):
                    if ci and int(ci) < len(dims):
                        contract *= dims[int(ci)]
    return 2.0 * relems * contract


_BYTES_OPS = {"dot", "fusion", "copy", "dynamic-update-slice", "gather",
              "scatter", "convert", "transpose", "reshape", "concatenate",
              "broadcast", "iota", "reduce", "select", "compare", "add",
              "multiply", "subtract", "divide", "exponential", "tanh",
              "convolution", "pad", "slice", "dynamic-slice", "rsqrt",
              "parameter", "constant", "log", "maximum", "minimum",
              "custom-call"}
# ops whose RESULT bytes count toward the HBM-traffic proxy (materialized
# buffers post-fusion; parameters/constants count as reads once)
_SKIP_BYTES = {"tuple", "get-tuple-element", "bitcast", "after-all",
               "partition-id", "replica-id"}


def _dus_update_bytes(line: str, shapes: Dict[str, str]) -> Optional[int]:
    """For '... dynamic-update-slice(%buf, %upd, ...)' return bytes(upd).

    XLA aliases DUS buffers in place: the real write is the update slice,
    not the whole buffer (a scan backward DUS-ing into a stacked residual
    buffer would otherwise be overcounted by the trip count)."""
    m = re.search(r"dynamic-update-slice\(([^)]*)\)", line)
    if not m:
        return None
    refs = _operand_refs(m.group(1))
    if len(refs) >= 2 and refs[1] in shapes:
        return _shape_elems_bytes(shapes[refs[1]])[1]
    return None


def analyze_computation(lines: List[str], shapes: Dict[str, str],
                        is_entry: bool = False,
                        fusion_roots: Optional[Dict[str, str]] = None) -> CompCost:
    """bytes_major model ("external-read + materialization-write"):
      * reads: entry parameters (once) and dot operands NOT produced inside
        this computation (loop-carried / captured buffers re-read per
        iteration).  Intra-computation producer->consumer chains are assumed
        VMEM-resident (what a fused TPU lowering achieves);
      * writes: results of fusion / copy / DUS / gather / scatter / reduce /
        sort ops (materialization points) -- dot results are assumed to flow
        into their consuming fusion;
      * collectives: payload counted in both bytes and the collective term.
    """
    cost = CompCost()
    produced = set()
    for line in lines:
        m = _OP_LINE.match(line)
        if m:
            produced.add(m.group(1))
    for line in lines:
        m = _OP_LINE.match(line)
        if not m:
            # still harvest call edges (e.g. from lines regexes miss)
            for cm in _CALLED.finditer(line):
                cost.calls.append((cm.group(1), 1.0))
            continue
        name, shape_str, opcode = m.group(1), m.group(2).strip(), m.group(3)
        elems, byts = _shape_elems_bytes(shape_str)
        base_op = opcode.replace("-start", "").replace("-done", "")
        if opcode.endswith("-done"):
            continue  # counted at -start
        if base_op in COLLECTIVES:
            cost.coll[base_op] = cost.coll.get(base_op, 0.0) + byts
            cost.coll_counts[base_op] = cost.coll_counts.get(base_op, 0) + 1
            cost.bytes += byts
            cost.bytes_major += byts
            continue
        if base_op == "dot":
            cost.flops += _dot_flops(line, shape_str, shapes)
            cost.bytes += byts
            # operands: count only computation-external reads
            for opn in re.findall(r"dot\(([^)]*)\)", line)[:1]:
                for ref in _operand_refs(opn):
                    s = shapes.get(ref)
                    if s:
                        ob = _shape_elems_bytes(s)[1]
                        cost.bytes += ob
                        if ref not in produced:
                            cost.bytes_major += ob
        elif base_op == "convolution":
            cost.flops += 2.0 * elems
            cost.bytes += byts
            cost.bytes_major += byts
        elif base_op in ("while", "conditional", "call", "custom-call",
                         "async-start"):
            cost.bytes += 0.0
        elif base_op == "fusion":
            cost.bytes += byts
            dus_b = None
            if fusion_roots is not None:
                cm = re.search(r"calls=%?([\w.\-]+)", line)
                root_line = fusion_roots.get(cm.group(1)) if cm else None
                if root_line and "dynamic-update-slice(" in root_line:
                    dus_b = _dus_update_bytes(root_line, shapes)
            cost.bytes_major += dus_b if dus_b is not None else byts
            cost.flops += elems  # ~1 flop per produced element (fused chain)
        elif base_op not in _SKIP_BYTES:
            # elementwise & data movement: result bytes + 1 flop/elem for math
            cost.bytes += byts
            if base_op == "parameter":
                # entry params = real HBM input reads; loop-body/fusion
                # params are the caller's buffers (no new traffic)
                if is_entry:
                    cost.bytes_major += byts
            elif base_op == "dynamic-update-slice":
                ub = _dus_update_bytes(line, shapes)
                cost.bytes_major += ub if ub is not None else byts
            elif base_op in ("copy", "gather", "scatter", "reduce",
                             "reduce-window", "sort"):
                cost.bytes_major += byts
            if base_op not in ("parameter", "constant", "iota", "copy",
                               "transpose", "reshape", "broadcast", "slice",
                               "concatenate", "pad"):
                cost.flops += elems
        # call edges
        for cm in _CALLED.finditer(line):
            cost.calls.append((cm.group(1), 1.0))
        bm = _BRANCHES.search(line)
        if bm:
            for ref in _operand_refs(bm.group(1)):
                cost.calls.append((ref, 1.0))
    return cost


def _while_trip_count(cond_lines: List[str]) -> Optional[int]:
    """Loop bound from the condition's comparison constant."""
    consts = {}
    for line in cond_lines:
        m = re.match(r"\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*\S+\s+constant\((\d+)\)",
                     line)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for line in cond_lines:
        if "compare(" in line:
            args = re.search(r"compare\(([^)]*)\)", line)
            if args:
                refs = _operand_refs(args.group(1))
                for r in refs:
                    if r in consts:
                        return consts[r]
    if consts:
        return max(consts.values())
    return None


@dataclasses.dataclass
class ProgramCost:
    flops: float
    bytes: float
    bytes_major: float
    coll: Dict[str, float]
    coll_counts: Dict[str, float]
    unknown_loops: int

    @property
    def collective_bytes(self) -> float:
        return sum(self.coll.values())


def analyze_hlo(hlo: str) -> ProgramCost:
    comps = split_computations(hlo)
    # global result-shape symbol table (op names are module-unique in HLO)
    shapes: Dict[str, str] = {}
    for lines in comps.values():
        for line in lines:
            m = _OP_LINE.match(line)
            if m:
                shapes[m.group(1)] = m.group(2).strip()

    entry_for_costs = _entry_name(hlo)
    # fusion computation -> its ROOT op line (for in-place DUS detection)
    fusion_roots: Dict[str, str] = {}
    for name, lines in comps.items():
        for line in lines:
            if line.strip().startswith("ROOT"):
                fusion_roots[name] = line
                break
    costs = {name: analyze_computation(lines, shapes,
                                       is_entry=(name == entry_for_costs),
                                       fusion_roots=fusion_roots)
             for name, lines in comps.items()}

    # while ops: find (body, cond) pairs + trip counts at call sites
    trip_of_body: Dict[str, float] = {}
    unknown = 0
    for name, lines in comps.items():
        for line in lines:
            if re.search(r"\bwhile\(", line):
                cm = re.search(r"condition=%?([\w.\-]+)", line)
                bm = re.search(r"body=%?([\w.\-]+)", line)
                if cm and bm:
                    tc = _while_trip_count(comps.get(cm.group(1), []))
                    if tc is None:
                        tc = 1
                        unknown += 1
                    trip_of_body[bm.group(1)] = float(tc)

    # accumulate: DFS from entry with multipliers
    entry = _entry_name(hlo)
    total = ProgramCost(0.0, 0.0, 0.0, defaultdict(float),
                        defaultdict(float), unknown)
    seen_stack = set()

    def visit(name: str, mult: float):
        if name not in costs or name in seen_stack:
            return
        seen_stack.add(name)
        c = costs[name]
        total.flops += mult * c.flops
        total.bytes += mult * c.bytes
        total.bytes_major += mult * c.bytes_major
        for k, v in c.coll.items():
            total.coll[k] += mult * v
        for k, v in c.coll_counts.items():
            total.coll_counts[k] += mult * v
        for callee, _ in c.calls:
            m2 = mult * trip_of_body.get(callee, 1.0)
            visit(callee, m2)
        seen_stack.discard(name)

    if entry:
        visit(entry, 1.0)
    else:  # fallback: sum everything once
        for name in costs:
            visit(name, 1.0)
    total.coll = dict(total.coll)
    total.coll_counts = dict(total.coll_counts)
    return total
