"""Core contribution: the enhanced roofline model, criteria, and selector."""
from .perfmodel import (
    HardwareSpec,
    StencilWorkload,
    UnitPerf,
    Comparison,
    Scenario,
    Bound,
    A100_DOUBLE,
    A100_FLOAT,
    TPU_V5E_BF16,
    compare,
    perf_vector,
    perf_matrix,
    perf_matrix_reuse,
    perf_sparse_matrix,
    halo_recompute_factor,
    sparsity_banded,
    sparsity_convstencil,
    sparsity_spider,
)
from .selector import Decision, select_backend, classify_problem, transition_depth
