"""Analytic execution-unit selector -- the paper's criteria as a scheduler.

Given a stencil workload and a hardware description, decide which execution
path the runtime should take among the five regimes the kernel substrate
implements (vector unit fused/unfused, matrix unit sequential / monolithic
fusion / intermediate reuse), and predict the speedup.
``repro.kernels.ops.stencil_apply(backend="auto")`` consults this module,
making the paper's analytical criteria (§4.1) -- extended with the
intermediate-reuse regime of DESIGN.md §4 -- a first-class deployable
feature rather than a post-hoc analysis.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.stencil.spec import StencilSpec
from repro.core import perfmodel as pm


@dataclasses.dataclass(frozen=True)
class Decision:
    backend: str                  # "direct" | "fused_direct" | "matmul" |
                                  # "fused_matmul" | "fused_matmul_reuse"
    scenario: Optional[pm.Scenario]
    predicted_speedup: float      # best matrix regime vs vector unit, effective
    comparison: pm.Comparison     # vector vs MONOLITHIC matrix (paper Fig. 8)
    reason: str
    candidates: Dict[str, float] = dataclasses.field(default_factory=dict)
    #: effective stencil throughput (useful FLOP/s) per candidate backend


@dataclasses.dataclass(frozen=True)
class PricingContext:
    """Workload + hardware context handed to each registered backend's
    ``price`` hook (repro.kernels.registry): everything shared across
    candidates is computed once here, so adding a candidate costs only its
    own throughput formula."""

    workload: pm.StencilWorkload
    hw: pm.HardwareSpec
    comparison: pm.Comparison     # vector vs monolithic matrix (shared)
    s_mono: float                 # structural S at the fused radius t*r
    s_reuse: float                # structural S at the base radius r
    strip_m: int
    #: Resolved halo sub-block height (0 = whole-strip) -- INFORMATIONAL
    #: for plug-in pricers: its read amplification is already folded into
    #: ``workload.read_amp``, which is the canonical channel.
    h_block: Optional[int] = None
    use_sparse_unit: bool = False
    #: Kept-row fractions of the sparse-compacted operands (DESIGN.md
    #: §14) at the fused radius t*r (monolithic) and base radius r
    #: (reuse), plus the column-chunk width their gather overhead
    #: amortizes over.  Resolved from the spec's structural pattern only
    #: when ``use_sparse_unit`` (1.0 otherwise -- the sparse pricers gate
    #: on the flag first).
    kept_mono: float = 1.0
    kept_reuse: float = 1.0
    tile_n: int = 128
    #: 3D workloads: resolved slab depth / halo-plane block (None for 2D).
    #: ``z_slab`` also feeds the reuse regime's dim-aware beta.
    z_slab: Optional[int] = None
    z_block: Optional[int] = None
    #: Column-tiled W substrate (DESIGN.md §10; 0 = full width).  Like
    #: h_block, the read amplification is already in ``workload.read_amp``;
    #: ``w_tile`` additionally feeds the reuse regime's beta (the carried
    #: x-halo is recomputed per step exactly like the leading axes).
    w_tile: int = 0
    w_block: int = 0


#: Total ``select_backend`` invocations this process -- lets tests assert a
#: cached plan never re-runs selection.
_invocations = 0


def invocation_count() -> int:
    return _invocations


def select_backend(
    spec: StencilSpec,
    t: int,
    dtype_bytes: int,
    hw: pm.HardwareSpec = pm.TPU_V5E_BF16,
    sparsity: Optional[float] = None,
    tile_n: int = 128,
    use_sparse_unit: bool = False,
    strip_m: int = 128,
    h_block: Optional[int] = None,
    z_slab: Optional[int] = None,
    z_block: Optional[int] = None,
    w_tile: Optional[int] = None,
    w_block: Optional[int] = None,
    boundary=None,
) -> Decision:
    """Pick the predicted-fastest backend for ``t`` fused steps of ``spec``.

    Candidates are enumerated from the backend registry
    (``repro.kernels.registry``): every registered backend with a ``price``
    hook that returns a throughput for this workload competes; the rest
    (reference oracle, legacy/whole-strip foils) are never selected.

    ``sparsity`` overrides the scheme's structural S for BOTH matrix
    regimes (useful to model published schemes); by default the monolithic
    regime uses the banded S at the fused radius t*r while the reuse regime
    uses S at the base radius r -- the structural reason reuse keeps its
    MXU efficiency at depth.

    ``h_block`` is the substrate's halo sub-block height (``None`` = the
    kernels' own auto choice, ``0`` = whole-strip): the workload's memory
    term M uses the resulting read amplification 1 + 2h/strip_m, so
    intensities -- and the VPU-vs-MXU crossover -- price the substrate
    that actually runs rather than the paper's ideal M = 2D.  3D
    workloads additionally take ``z_slab``/``z_block`` (pricing defaults:
    z_slab = strip_m, auto z_block) and price the product amplification
    (1 + 2h/strip_m)(1 + 2z_block/z_slab); 1D workloads always price the
    lifted substrate (strip_m = 1, read amplification exactly 1).
    ``w_tile``/``w_block`` (2D/3D) price the column-tiled W substrate
    (DESIGN.md §10): the read-amp product gains the (1 + 2w_block/w_tile)
    factor and the reuse beta the carried-x-halo recompute.  The resolved
    geometry and its read factor (including the resolved ``w_tile``) are
    appended to every reason string, so ``ops.explain`` surfaces what the
    substrate costs.

    ``boundary`` (DESIGN.md §15) does not move the crossover -- the
    boundary fills are FLOP-free select/concat lanes and the fetch count
    matches periodic's -- but a non-periodic spec is surfaced in the
    reason string so explain() shows what the plan will honor.
    """
    global _invocations
    _invocations += 1
    # Deferred: kernels.* pulls in the Pallas kernel modules, which must
    # not load just because repro.core was imported.
    from repro.kernels.common import pricing_geom
    from repro.kernels.registry import candidate_units, priced_candidates

    # Auto blocks resolve at the FUSED-regime halo t*r.  This prices every
    # candidate's substrate faithfully: the fused regimes build with exactly
    # this halo, and the sequential regimes (direct/matmul) only price at
    # t=1 -- their t>1 hooks return None -- where t*r == r.  pricing_geom
    # shares resolve_substrate_geom's pin rules (including the hybrid
    # z_block=0 rejection), so the priced substrate is always buildable.
    geom = pricing_geom(spec.dim, t * spec.radius, strip_m, h_block,
                        z_slab, z_block, w_tile, w_block)
    read_amp = geom.read_amp
    w = pm.StencilWorkload(spec, t, dtype_bytes, read_amp=read_amp)
    s_mono = sparsity if sparsity is not None else \
        pm.sparsity_banded(spec.radius * t, tile_n)
    s_reuse = sparsity if sparsity is not None else \
        pm.sparsity_banded(spec.radius, tile_n)
    # The scenario comparison prices the hardware's sparse unit only when
    # one exists (A100-style p_sparse); on MXU-only parts the compacted
    # contraction runs on the SAME dense unit, so the vector-vs-matrix
    # scenario stays the dense comparison and the sparse backends compete
    # through their own pricers below (DESIGN.md §14).
    cmp_ = pm.compare(w, hw, s_mono,
                      use_sparse_unit=use_sparse_unit
                      and hw.p_sparse is not None)

    kept_mono = kept_reuse = 1.0
    if use_sparse_unit:
        # Structural kept-row fractions of the compacted operands
        # (DESIGN.md §14): the zero pattern is fully determined by the
        # spec, so a representative kernel on its support prices every
        # concrete weight set.
        from repro.kernels.stencil_sparse import kept_row_fraction
        from repro.stencil.weights import fuse_weights, jacobi_weights
        wj = jacobi_weights(spec)
        kept_reuse = kept_row_fraction(wj, tile_n)
        kept_mono = kept_row_fraction(fuse_weights(wj, t), tile_n) \
            if t > 1 else kept_reuse

    candidates = priced_candidates(PricingContext(
        workload=w, hw=hw, comparison=cmp_, s_mono=s_mono, s_reuse=s_reuse,
        strip_m=geom.strip_m, h_block=geom.h_block,
        use_sparse_unit=use_sparse_unit,
        kept_mono=kept_mono, kept_reuse=kept_reuse, tile_n=tile_n,
        z_slab=geom.z_slab if spec.dim == 3 else None,
        z_block=geom.z_block if spec.dim == 3 else None,
        w_tile=geom.w_tile if spec.dim >= 2 else 0,
        w_block=geom.w_block if spec.dim >= 2 else 0))
    if not candidates:
        raise RuntimeError("no registered backend priced this workload")
    from repro.stencil.boundary import boundary_label, is_periodic
    if t > 1 and boundary is not None and not is_periodic(boundary):
        # Monolithic fusion bakes one boundary extension into t steps, so
        # its build rejects non-periodic specs (DESIGN.md §15) -- never
        # select it into a failing build.
        candidates.pop("fused_matmul", None)
        if not candidates:
            raise RuntimeError(
                "no registered backend can honor non-periodic boundaries "
                "for this workload")

    vec = cmp_.vector.actual_flops
    units = candidate_units()
    backend = max(candidates, key=lambda k: candidates[k])
    matrix_perfs = [v for k, v in candidates.items()
                    if units.get(k) == "matrix"]
    best_matrix = max(matrix_perfs) if matrix_perfs else vec

    if backend == "fused_matmul_reuse":
        beta = pm.reuse_beta(spec, t, geom.strip_m,
                             geom.z_slab if spec.dim == 3 else None,
                             geom.w_tile or None)
        reason = (
            f"intermediate-reuse regime wins: alpha=1 (vs monolithic "
            f"alpha={w.alpha:.3f}), S_r={s_reuse:.3f} at base radius (vs "
            f"S_rt={s_mono:.3f} fused), halo-recompute beta={beta:.3f} "
            f"(DESIGN.md §4)"
        )
    elif backend in ("sparse_matmul", "fused_sparse_matmul"):
        kept = kept_reuse if backend == "fused_sparse_matmul" else kept_mono
        ov = pm.compaction_overhead(tile_n)
        cost = kept * (1.0 + ov)
        side = "inside" if cost < 1.0 else "outside"
        reason = (
            f"sparse-compacted regime wins: kept-row fraction S={kept:.4f} "
            f"* (1 + gather overhead {ov:.4f}) = {cost:.4f} vs 1 dense -- "
            f"{spec.shape} kernel {side} the sparse sweet spot (star "
            f"stencils keep only their tap rows, box compacts to S=1; "
            f"DESIGN.md §14)"
        )
    elif backend in ("direct", "fused_direct", "matmul", "fused_matmul"):
        reason = _explain(cmp_)
    else:
        # a registered plug-in won: the Fig. 8 scenario prose below only
        # describes the built-in vector/monolithic-matrix comparison
        reason = (
            f"registered backend {backend!r} priced highest "
            f"({candidates[backend]:.3g} effective FLOP/s) among "
            f"{sorted(candidates)}"
        )
    # Every reason carries the resolved substrate geometry + read factor
    # (DESIGN.md §9): decide()/explain()/plan.decision all format it from
    # the same resolved numbers, so they agree verbatim.
    reason = f"{reason} | {geom.describe()}"
    # Boundary handling is throughput-neutral (fills are FLOP-free
    # select/concat; fetch counts match periodic's -- DESIGN.md §15), so
    # it never changes the ranking among eligible regimes; surface it in
    # the reason only when non-periodic to keep historical reason strings
    # byte-identical.
    if boundary is not None and not is_periodic(boundary):
        reason = f"{reason} | boundary={boundary_label(boundary)}"
    return Decision(
        backend=backend,
        scenario=cmp_.scenario,
        predicted_speedup=best_matrix / vec,
        comparison=cmp_,
        reason=reason,
        candidates=candidates,
    )


def _explain(c: pm.Comparison) -> str:
    s = c.scenario
    if s is pm.Scenario.MB_MB:
        return (
            "both units memory-bound: effective performance identical (Eq. 14); "
            "prefer vector unit (no transformation overhead)"
        )
    if s is pm.Scenario.MB_CB:
        return (
            "vector unit memory-bound but transformation pushed matrix unit "
            "compute-bound: matrix unit strictly worse (Eq. 16)"
        )
    if s is pm.Scenario.CB_MB:
        return (
            "vector unit compute-bound, matrix unit memory-bound: matrix unit "
            "breaks the vector-unit ceiling (Eq. 17)"
        )
    ok = "inside" if c.workload.alpha < c.sweet_spot_alpha_limit else "outside"
    return (
        f"both compute-bound: conditional sweet spot (Eq. 19) -- alpha="
        f"{c.workload.alpha:.3f} vs limit S*P_mat/P_vec="
        f"{c.sweet_spot_alpha_limit:.3f} ({ok} sweet spot)"
    )


def classify_problem(
    spec: StencilSpec,
    t: int,
    dtype_bytes: int,
    hw: pm.HardwareSpec,
) -> pm.Bound:
    """Paper §4.2 (Fig. 10): is the temporally-fused problem compute-bound
    on the *vector* unit?  (The precondition for matrix units to pay off.)"""
    w = pm.StencilWorkload(spec, t, dtype_bytes)
    return pm.bound_state(hw.p_vector, hw.bandwidth, w.intensity_vector())


def transition_depth(
    spec: StencilSpec,
    dtype_bytes: int,
    hw: pm.HardwareSpec,
    t_max: int = 64,
) -> Optional[int]:
    """Smallest fusion depth at which the problem becomes compute-bound on
    the vector unit (paper §4.2: box transitions at t=3, star at t=5 for the
    A100/float setting)."""
    for t in range(1, t_max + 1):
        if classify_problem(spec, t, dtype_bytes, hw) is pm.Bound.COMPUTE:
            return t
    return None
