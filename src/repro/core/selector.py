"""Analytic execution-unit selector -- the paper's criteria as a scheduler.

Given a stencil workload and a hardware description, decide which execution
path (vector unit vs matrix unit, fused or not) the runtime should take, and
predict the speedup.  ``repro.kernels.ops.stencil_apply(backend="auto")``
consults this module, making the paper's analytical criteria (§4.1) a
first-class deployable feature rather than a post-hoc analysis.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.stencil.spec import StencilSpec
from repro.core import perfmodel as pm


@dataclasses.dataclass(frozen=True)
class Decision:
    backend: str                  # "direct" | "fused_direct" | "matmul" | "fused_matmul"
    scenario: Optional[pm.Scenario]
    predicted_speedup: float      # matrix-unit vs vector-unit, effective
    comparison: pm.Comparison
    reason: str


def select_backend(
    spec: StencilSpec,
    t: int,
    dtype_bytes: int,
    hw: pm.HardwareSpec = pm.TPU_V5E_BF16,
    sparsity: Optional[float] = None,
    tile_n: int = 128,
    use_sparse_unit: bool = False,
) -> Decision:
    """Pick the predicted-fastest backend for ``t`` fused steps of ``spec``.

    ``sparsity`` defaults to the banded-matmul scheme's structural S for the
    *fused* effective radius (the matrix-unit path always executes the fused
    kernel as one banded contraction -- paper §2.2.3's "monolithic" fusion).
    """
    w = pm.StencilWorkload(spec, t, dtype_bytes)
    if sparsity is None:
        sparsity = pm.sparsity_banded(spec.radius * t, tile_n)
    cmp_ = pm.compare(w, hw, sparsity, use_sparse_unit=use_sparse_unit)

    matrix_wins = cmp_.profitable
    if t == 1:
        backend = "matmul" if matrix_wins else "direct"
    else:
        backend = "fused_matmul" if matrix_wins else "fused_direct"

    reason = _explain(cmp_)
    return Decision(
        backend=backend,
        scenario=cmp_.scenario,
        predicted_speedup=cmp_.speedup,
        comparison=cmp_,
        reason=reason,
    )


def _explain(c: pm.Comparison) -> str:
    s = c.scenario
    if s is pm.Scenario.MB_MB:
        return (
            "both units memory-bound: effective performance identical (Eq. 14); "
            "prefer vector unit (no transformation overhead)"
        )
    if s is pm.Scenario.MB_CB:
        return (
            "vector unit memory-bound but transformation pushed matrix unit "
            "compute-bound: matrix unit strictly worse (Eq. 16)"
        )
    if s is pm.Scenario.CB_MB:
        return (
            "vector unit compute-bound, matrix unit memory-bound: matrix unit "
            "breaks the vector-unit ceiling (Eq. 17)"
        )
    ok = "inside" if c.workload.alpha < c.sweet_spot_alpha_limit else "outside"
    return (
        f"both compute-bound: conditional sweet spot (Eq. 19) -- alpha="
        f"{c.workload.alpha:.3f} vs limit S*P_mat/P_vec="
        f"{c.sweet_spot_alpha_limit:.3f} ({ok} sweet spot)"
    )


def classify_problem(
    spec: StencilSpec,
    t: int,
    dtype_bytes: int,
    hw: pm.HardwareSpec,
) -> pm.Bound:
    """Paper §4.2 (Fig. 10): is the temporally-fused problem compute-bound
    on the *vector* unit?  (The precondition for matrix units to pay off.)"""
    w = pm.StencilWorkload(spec, t, dtype_bytes)
    return pm.bound_state(hw.p_vector, hw.bandwidth, w.intensity_vector())


def transition_depth(
    spec: StencilSpec,
    dtype_bytes: int,
    hw: pm.HardwareSpec,
    t_max: int = 64,
) -> Optional[int]:
    """Smallest fusion depth at which the problem becomes compute-bound on
    the vector unit (paper §4.2: box transitions at t=3, star at t=5 for the
    A100/float setting)."""
    for t in range(1, t_max + 1):
        if classify_problem(spec, t, dtype_bytes, hw) is pm.Bound.COMPUTE:
            return t
    return None
