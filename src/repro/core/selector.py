"""Analytic execution-unit selector -- the paper's criteria as a scheduler.

Given a stencil workload and a hardware description, decide which execution
path the runtime should take among the five regimes the kernel substrate
implements (vector unit fused/unfused, matrix unit sequential / monolithic
fusion / intermediate reuse), and predict the speedup.
``repro.kernels.ops.stencil_apply(backend="auto")`` consults this module,
making the paper's analytical criteria (§4.1) -- extended with the
intermediate-reuse regime of DESIGN.md §4 -- a first-class deployable
feature rather than a post-hoc analysis.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.stencil.spec import StencilSpec
from repro.core import perfmodel as pm


@dataclasses.dataclass(frozen=True)
class Decision:
    backend: str                  # "direct" | "fused_direct" | "matmul" |
                                  # "fused_matmul" | "fused_matmul_reuse"
    scenario: Optional[pm.Scenario]
    predicted_speedup: float      # best matrix regime vs vector unit, effective
    comparison: pm.Comparison     # vector vs MONOLITHIC matrix (paper Fig. 8)
    reason: str
    candidates: Dict[str, float] = dataclasses.field(default_factory=dict)
    #: effective stencil throughput (useful FLOP/s) per candidate backend


def select_backend(
    spec: StencilSpec,
    t: int,
    dtype_bytes: int,
    hw: pm.HardwareSpec = pm.TPU_V5E_BF16,
    sparsity: Optional[float] = None,
    tile_n: int = 128,
    use_sparse_unit: bool = False,
    strip_m: int = 128,
) -> Decision:
    """Pick the predicted-fastest backend for ``t`` fused steps of ``spec``.

    ``sparsity`` overrides the scheme's structural S for BOTH matrix
    regimes (useful to model published schemes); by default the monolithic
    regime uses the banded S at the fused radius t*r while the reuse regime
    uses S at the base radius r -- the structural reason reuse keeps its
    MXU efficiency at depth.
    """
    w = pm.StencilWorkload(spec, t, dtype_bytes)
    s_mono = sparsity if sparsity is not None else \
        pm.sparsity_banded(spec.radius * t, tile_n)
    s_reuse = sparsity if sparsity is not None else \
        pm.sparsity_banded(spec.radius, tile_n)
    cmp_ = pm.compare(w, hw, s_mono, use_sparse_unit=use_sparse_unit)

    vec = cmp_.vector.actual_flops
    candidates = {
        ("direct" if t == 1 else "fused_direct"): vec,
        ("matmul" if t == 1 else "fused_matmul"): cmp_.matrix.actual_flops,
    }
    if t > 1:
        # t=1 reuse degenerates to "matmul"; only offered at depth.  The
        # sparse unit has no reuse analogue modeled (DESIGN.md §8).
        reuse = pm.perf_matrix_reuse(w, hw, s_reuse, strip_m)
        candidates["fused_matmul_reuse"] = reuse.actual_flops

    backend = max(candidates, key=lambda k: candidates[k])
    best_matrix = max(v for k, v in candidates.items() if "matmul" in k)

    if backend == "fused_matmul_reuse":
        beta = pm.halo_recompute_factor(spec.radius, t, strip_m)
        reason = (
            f"intermediate-reuse regime wins: alpha=1 (vs monolithic "
            f"alpha={w.alpha:.3f}), S_r={s_reuse:.3f} at base radius (vs "
            f"S_rt={s_mono:.3f} fused), halo-recompute beta={beta:.3f} "
            f"(DESIGN.md §4)"
        )
    else:
        reason = _explain(cmp_)
    return Decision(
        backend=backend,
        scenario=cmp_.scenario,
        predicted_speedup=best_matrix / vec,
        comparison=cmp_,
        reason=reason,
        candidates=candidates,
    )


def _explain(c: pm.Comparison) -> str:
    s = c.scenario
    if s is pm.Scenario.MB_MB:
        return (
            "both units memory-bound: effective performance identical (Eq. 14); "
            "prefer vector unit (no transformation overhead)"
        )
    if s is pm.Scenario.MB_CB:
        return (
            "vector unit memory-bound but transformation pushed matrix unit "
            "compute-bound: matrix unit strictly worse (Eq. 16)"
        )
    if s is pm.Scenario.CB_MB:
        return (
            "vector unit compute-bound, matrix unit memory-bound: matrix unit "
            "breaks the vector-unit ceiling (Eq. 17)"
        )
    ok = "inside" if c.workload.alpha < c.sweet_spot_alpha_limit else "outside"
    return (
        f"both compute-bound: conditional sweet spot (Eq. 19) -- alpha="
        f"{c.workload.alpha:.3f} vs limit S*P_mat/P_vec="
        f"{c.sweet_spot_alpha_limit:.3f} ({ok} sweet spot)"
    )


def classify_problem(
    spec: StencilSpec,
    t: int,
    dtype_bytes: int,
    hw: pm.HardwareSpec,
) -> pm.Bound:
    """Paper §4.2 (Fig. 10): is the temporally-fused problem compute-bound
    on the *vector* unit?  (The precondition for matrix units to pay off.)"""
    w = pm.StencilWorkload(spec, t, dtype_bytes)
    return pm.bound_state(hw.p_vector, hw.bandwidth, w.intensity_vector())


def transition_depth(
    spec: StencilSpec,
    dtype_bytes: int,
    hw: pm.HardwareSpec,
    t_max: int = 64,
) -> Optional[int]:
    """Smallest fusion depth at which the problem becomes compute-bound on
    the vector unit (paper §4.2: box transitions at t=3, star at t=5 for the
    A100/float setting)."""
    for t in range(1, t_max + 1):
        if classify_problem(spec, t, dtype_bytes, hw) is pm.Bound.COMPUTE:
            return t
    return None
