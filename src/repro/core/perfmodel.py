"""Enhanced roofline performance model for stencils on matrix units.

This module is the paper's primary contribution (§3--§4) in executable form:

  * workload terms  C, M, I  for the original problem (Eq. 6),
  * temporally-fused vector-unit execution  I_CU^(t) = t*K/D  (Eq. 8),
  * matrix-unit execution with sparsity factor S and fusion redundancy
    alpha:  I_TC^(t) = t*(alpha/S)*K/D,
    P_TC,actual = (S/alpha) * min(P_TC, B*I_TC)  (Eq. 11/12),
  * the four-scenario classification and the sweet-spot criterion
    ``alpha < S * P_TC / P_CU``  (Eq. 13--19),
  * the intermediate-reuse matrix-unit regime (DESIGN.md §4): t radius-r
    banded contractions with VMEM-resident intermediates -- alpha = 1, paid
    for by the halo-recompute factor  beta = 1 + r*(t-1)/strip_m,  giving
    I_TC,reuse^(t) = beta * t * K / (S * D)  with S evaluated at the BASE
    radius r (not t*r as in monolithic fusion),
  * the Sparse-Tensor-Core extension (Eq. 20) -- the raised-ceiling model
    (``perf_sparse_matrix``) plus the EXECUTED band-compaction regime
    (``perf_sparse_banded{,_reuse}``, DESIGN.md §14): the banded operand
    keeps only its structurally-nonzero contraction rows (kept-row
    fraction ``kept`` = kernels.stencil_sparse.kept_row_fraction),
    shrinking executed MXU FLOPs and the streamed K-dimension by ``kept``
    at a small in-kernel gather overhead ``compaction_overhead(tile_n)``.

Naming note: the paper says "CUDA Core" / "Tensor Core"; we use the neutral
``vector`` / ``matrix`` unit names so the same model covers TPU VPU / MXU.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional

from repro.stencil.spec import StencilSpec
from repro.stencil.weights import alpha as fusion_alpha


# ---------------------------------------------------------------------------
# Hardware description
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Peak throughputs (FLOP/s) and memory bandwidth (B/s) of one chip.

    ``p_vector``  -- general-purpose ALUs (CUDA cores / TPU VPU)
    ``p_matrix``  -- matrix unit (Tensor Core / TPU MXU)
    ``p_sparse``  -- sparse matrix unit ceiling (SpTC); None if absent
    ``bandwidth`` -- main-memory (HBM) bandwidth
    """

    name: str
    p_vector: float
    p_matrix: float
    bandwidth: float
    p_sparse: Optional[float] = None

    @property
    def ridge_vector(self) -> float:
        """Ridge point I* of the vector-unit roofline (FLOP/Byte)."""
        return self.p_vector / self.bandwidth

    @property
    def ridge_matrix(self) -> float:
        return self.p_matrix / self.bandwidth

    @property
    def ridge_sparse(self) -> float:
        if self.p_sparse is None:
            raise ValueError(f"{self.name} has no sparse matrix unit")
        return self.p_sparse / self.bandwidth


# NVIDIA A100-80GB PCIe, the paper's evaluation platform (§5.1).  The ridge
# points in paper Table 3 (5 / 10 / 81 / 161) pin B ~= 1.94e12 B/s:
#   9.7e12/1.94e12 = 5.0,  19.5e12/1.94e12 = 10.05,
#   156e12/1.94e12 = 80.4, 312e12/1.94e12 = 160.8.
A100_DOUBLE = HardwareSpec(
    "A100-80GB (fp64)", p_vector=9.7e12, p_matrix=19.5e12, bandwidth=1.94e12,
    p_sparse=None,  # no fp64 SpTC
)
A100_FLOAT = HardwareSpec(
    # float path: CUDA-core fp32 19.5 TF; TC tf32->fp32 156 TF; SpTC 312 TF
    "A100-80GB (fp32)", p_vector=19.5e12, p_matrix=156e12, bandwidth=1.94e12,
    p_sparse=312e12,
)
# TPU v5e (per chip).  MXU bf16 = 197 TFLOP/s; HBM = 819 GB/s.  The VPU
# throughput is not separately published; 197/16 ~= 12.3 TFLOP/s is the
# vector-lane estimate we expose as a *parameter* (it plays the paper's
# P_CU role, and every criterion below takes it from the HardwareSpec).
TPU_V5E_BF16 = HardwareSpec(
    "TPU v5e (bf16)", p_vector=197e12 / 16, p_matrix=197e12, bandwidth=819e9,
    # No sparse MXU.  The int8 MXU ceiling (394 TOP/s) answers the same
    # "raised ceiling" design question for quantized stencils (DESIGN.md §8).
    p_sparse=None,
)
TPU_V5E_INT8_CEILING = dataclasses.replace(
    TPU_V5E_BF16, name="TPU v5e (bf16 + int8 ceiling)", p_sparse=394e12
)


# ---------------------------------------------------------------------------
# Workload formulation (paper §3.2)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class StencilWorkload:
    """A stencil problem instance bound to a fusion depth and dtype.

    ``read_amp`` is the substrate's grid-read amplification: 1.0 models the
    paper's ideal (each point read once), 1 + 2h/strip_m the halo-row
    sub-blocked strip substrate, 3.0 whole neighbor strips, 9.0 the seed
    scheme (see ``repro.kernels.common.substrate_read_amp``).  It scales
    M and therefore every intensity below -- the substrate's traffic model
    IS the experiment (Eq. 6), so the selector prices the substrate it
    actually runs on.
    """

    spec: StencilSpec
    t: int = 1                   # fusion depth
    dtype_bytes: int = 4         # D
    read_amp: float = 1.0        # substrate read amplification (>= 1)

    @property
    def K(self) -> int:
        return self.spec.num_points

    @property
    def alpha(self) -> float:
        """Fusion redundancy factor (Eq. 9/10); exact for any shape."""
        return fusion_alpha(self.spec, self.t)

    # ---- vector-unit (CUDA-core-like) execution, temporal fusion (Eq. 8)
    def flops_vector(self) -> float:
        """C_CU^(t) per output point (t steps amortized into one)."""
        return self.t * 2 * self.K

    def bytes_per_output(self) -> float:
        """M = (read_amp + 1)·D: amplified read + one write; fusion keeps
        this constant (= the paper's 2D at the ideal read_amp of 1)."""
        return (self.read_amp + 1.0) * self.dtype_bytes

    def intensity_vector(self) -> float:
        return self.flops_vector() / self.bytes_per_output()

    # ---- matrix-unit execution with kernel fusion (Eq. 11)
    def flops_matrix(self, sparsity: float) -> float:
        """C_TC^(t) = (alpha/S) * C^(t) per output point (Eq. 3)."""
        _check_sparsity(sparsity)
        return (self.alpha / sparsity) * self.flops_vector()

    def intensity_matrix(self, sparsity: float) -> float:
        return self.flops_matrix(sparsity) / self.bytes_per_output()

    # ---- matrix-unit execution with intermediate reuse (DESIGN.md §4)
    def flops_matrix_reuse(self, sparsity: float, strip_m: int = 128,
                           z_slab: Optional[int] = None,
                           w_tile: Optional[int] = None) -> float:
        """C_TC,reuse^(t) = (beta/S) * C^(t) per output point.

        t radius-r banded contractions with intermediates resident in VMEM:
        the fused kernel never materializes so alpha drops to 1; instead the
        shrinking leading-axis halos are recomputed, inflating executed work
        by ``beta = reuse_beta(spec, t, strip_m, z_slab, w_tile)`` (the 2D
        ``halo_recompute_factor`` for d=2; the (z, y) product mean for d=3;
        exactly 1 for lifted 1D, which has no leading halo; the column-tiled
        substrate (``w_tile``, DESIGN.md §10) adds the carried x-halo as one
        more recomputed axis).  ``sparsity`` is the scheme's S at the BASE
        radius r.
        """
        _check_sparsity(sparsity)
        beta = reuse_beta(self.spec, self.t, strip_m, z_slab, w_tile)
        return (beta / sparsity) * self.flops_vector()

    def intensity_matrix_reuse(self, sparsity: float, strip_m: int = 128,
                               z_slab: Optional[int] = None,
                               w_tile: Optional[int] = None) -> float:
        return (self.flops_matrix_reuse(sparsity, strip_m, z_slab, w_tile)
                / self.bytes_per_output())

    # ---- sparse-compacted matrix-unit execution (DESIGN.md §14)
    def flops_sparse_matrix(self, sparsity: float, kept: float,
                            overhead: float = 0.0) -> float:
        """C_SpTC^(t) = kept*(1+overhead) * C_TC^(t) per output point.

        ``kept`` is the compacted operand's kept-row fraction S (row
        compaction drops exactly the all-zero contraction rows, so the
        executed MXU FLOPs shrink by precisely this factor -- proven
        integer-exact by repro.audit's flops/sparse-compaction check);
        ``overhead`` the relative cost of the in-kernel input-row gather
        (``compaction_overhead``).
        """
        _check_kept(kept)
        return kept * (1.0 + overhead) * self.flops_matrix(sparsity)

    def intensity_sparse_matrix(self, sparsity: float, kept: float,
                                overhead: float = 0.0) -> float:
        return (self.flops_sparse_matrix(sparsity, kept, overhead)
                / self.bytes_per_output())

    def flops_sparse_matrix_reuse(self, sparsity: float, kept: float,
                                  overhead: float = 0.0, strip_m: int = 128,
                                  z_slab: Optional[int] = None,
                                  w_tile: Optional[int] = None) -> float:
        """Reuse regime on the compacted operand: kept*(1+overhead) times
        the dense reuse FLOPs (beta at the BASE radius, like the dense
        reuse regime; ``kept`` likewise at the base radius)."""
        _check_kept(kept)
        return kept * (1.0 + overhead) * self.flops_matrix_reuse(
            sparsity, strip_m, z_slab, w_tile)

    def intensity_sparse_matrix_reuse(self, sparsity: float, kept: float,
                                      overhead: float = 0.0,
                                      strip_m: int = 128,
                                      z_slab: Optional[int] = None,
                                      w_tile: Optional[int] = None) -> float:
        return (self.flops_sparse_matrix_reuse(sparsity, kept, overhead,
                                               strip_m, z_slab, w_tile)
                / self.bytes_per_output())


def halo_recompute_factor(radius: int, t: int, strip_m: int = 128) -> float:
    """beta: executed rows / useful rows for the in-VMEM reuse pipeline.

    A strip of ``strip_m`` useful rows enters step s of t with a vertical
    halo of (t-s)*r rows per side; step s therefore computes
    strip_m + 2*r*(t-1-s) rows.  Summing over s and dividing by t*strip_m:

        beta = 1 + r*(t-1)/strip_m

    beta -> 1 as strips grow; it plays the role alpha plays for monolithic
    fusion but scales as r*t/strip_m instead of (r*t)^d/K -- the reason the
    reuse regime stays in the sweet spot at depths where monolithic fusion
    has long left it.
    """
    if t <= 1:
        return 1.0
    if strip_m <= 0:
        raise ValueError(f"strip height must be positive, got {strip_m}")
    return 1.0 + radius * (t - 1) / strip_m


def halo_recompute_factor_nd(radius: int, t: int, sizes) -> float:
    """beta for the N-D reuse pipeline: executed points / useful points.

    ``sizes`` lists the tile extent of every leading (non-wrap) axis of
    the substrate cell -- ``()`` for lifted 1D, ``(strip_m,)`` for 2D,
    ``(z_slab, strip_m)`` for 3D.  Step s of t computes
    ``prod_m (m + 2*r*(t-1-s))`` points per ``prod_m m`` useful ones, so

        beta = (1/t) * sum_j  prod_m (1 + 2*r*j/m),   j = 0..t-1

    which reduces to the closed-form 2D ``halo_recompute_factor`` for a
    single size and to 1 for an empty ``sizes`` (no leading halo at all).
    """
    sizes = tuple(sizes)
    if t <= 1 or not sizes:
        return 1.0
    if any(m <= 0 for m in sizes):
        raise ValueError(f"tile extents must be positive, got {sizes}")
    total = 0.0
    for j in range(t):
        f = 1.0
        for m in sizes:
            f *= 1.0 + 2.0 * radius * j / m
        total += f
    return total / t


def reuse_beta(spec: StencilSpec, t: int, strip_m: int = 128,
               z_slab: Optional[int] = None,
               w_tile: Optional[int] = None) -> float:
    """Dim-aware beta for the reuse regime: the single channel the
    workload, ``perf_matrix_reuse`` and the selector's reason string all
    consult, so priced and displayed betas can never disagree.

    d=2 keeps the closed-form ``halo_recompute_factor`` (bit-identical to
    the historical pricing); d=3 is the (z_slab, strip_m) product mean;
    d=1 is exactly 1 (the lifted substrate has no leading halo).  On the
    column-tiled substrate (``w_tile`` set, DESIGN.md §10) the carried
    x-halo shrinks per step exactly like the leading halos, so the tile
    width joins the product mean as one more recomputed axis; full-width
    substrates (``w_tile=None``) re-wrap in-VMEM at zero recompute.
    """
    if spec.dim == 1:
        return 1.0
    if spec.dim == 3:
        sizes = (z_slab if z_slab is not None else strip_m, strip_m)
    elif w_tile is None:
        return halo_recompute_factor(spec.radius, t, strip_m)
    else:
        sizes = (strip_m,)
    if w_tile is not None:
        sizes = sizes + (w_tile,)
    return halo_recompute_factor_nd(spec.radius, t, sizes)


def _check_sparsity(s: float) -> None:
    if not (0.0 < s <= 1.0):
        raise ValueError(f"sparsity factor must be in (0, 1], got {s}")


def _check_kept(kept: float) -> None:
    if not (0.0 < kept <= 1.0):
        raise ValueError(f"kept-row fraction must be in (0, 1], got {kept}")


def compaction_overhead(tile_n: int) -> float:
    """Relative in-kernel gather cost of the compacted contraction.

    Each kept contraction row is one gathered input element per output
    row (the shifted-slab slice at ``lo``), amortized over the 2*tile_n
    MACs that row feeds in the banded matmul:

        overhead = 1 / (2 * tile_n)

    -> 0 as chunks widen; ~0.4% at the default 128-wide tile.  Charged
    multiplicatively on the executed sparse FLOPs, it is the term that
    keeps near-dense compactions (box kernels, kept = 1) from ever
    out-pricing the dense path.
    """
    if tile_n <= 0:
        raise ValueError(f"tile_n must be positive, got {tile_n}")
    return 1.0 / (2.0 * tile_n)


# ---------------------------------------------------------------------------
# Roofline (paper §3.1, Eq. 5)
# ---------------------------------------------------------------------------
def attainable(peak: float, bandwidth: float, intensity: float) -> float:
    """P = min(P_peak, B * I)."""
    return min(peak, bandwidth * intensity)


class Bound(enum.Enum):
    MEMORY = "memory"
    COMPUTE = "compute"


def bound_state(peak: float, bandwidth: float, intensity: float) -> Bound:
    return Bound.MEMORY if bandwidth * intensity < peak else Bound.COMPUTE


# ---------------------------------------------------------------------------
# Per-unit performance (paper Eq. 8, 12, 20)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class UnitPerf:
    """Roofline evaluation of one workload on one execution unit."""

    unit: str                    # "vector" | "matrix" | "sparse_matrix"
    intensity: float             # I (FLOP/Byte), *as executed* (incl. redundancy)
    raw_flops: float             # min(P, B*I) -- counts redundant ops
    actual_flops: float          # deflated by S/alpha -- useful ops only
    bound: Bound
    ridge: float

    def stencil_throughput(self, workload: StencilWorkload) -> float:
        """Updates/sec per point-update (GStencils/s * 1e9 when scaled).

        The de-facto metric of the paper's §5.3: actual useful FLOPs divided
        by the useful FLOPs per (point, t-step-batch) = t*2K.
        """
        return self.actual_flops / workload.flops_vector()


def perf_vector(w: StencilWorkload, hw: HardwareSpec) -> UnitPerf:
    i = w.intensity_vector()
    p = attainable(hw.p_vector, hw.bandwidth, i)
    return UnitPerf("vector", i, p, p, bound_state(hw.p_vector, hw.bandwidth, i),
                    hw.ridge_vector)


def perf_matrix(w: StencilWorkload, hw: HardwareSpec, sparsity: float) -> UnitPerf:
    i = w.intensity_matrix(sparsity)
    raw = attainable(hw.p_matrix, hw.bandwidth, i)
    actual = (sparsity / w.alpha) * raw
    return UnitPerf("matrix", i, raw, actual,
                    bound_state(hw.p_matrix, hw.bandwidth, i), hw.ridge_matrix)


def perf_matrix_reuse(w: StencilWorkload, hw: HardwareSpec, sparsity: float,
                      strip_m: int = 128,
                      z_slab: Optional[int] = None,
                      w_tile: Optional[int] = None) -> UnitPerf:
    """Intermediate-reuse regime (DESIGN.md §4): alpha=1, halo-recompute beta
    (dim-aware: ``reuse_beta``; ``z_slab`` matters only for 3D workloads,
    ``w_tile`` only on the column-tiled substrate -- DESIGN.md §10).

    ``sparsity`` is the scheme's S at the base radius r (the per-step banded
    operand), NOT the monolithic S at radius t*r.
    """
    i = w.intensity_matrix_reuse(sparsity, strip_m, z_slab, w_tile)
    raw = attainable(hw.p_matrix, hw.bandwidth, i)
    beta = reuse_beta(w.spec, w.t, strip_m, z_slab, w_tile)
    actual = (sparsity / beta) * raw
    return UnitPerf("matrix_reuse", i, raw, actual,
                    bound_state(hw.p_matrix, hw.bandwidth, i), hw.ridge_matrix)


def perf_sparse_matrix(w: StencilWorkload, hw: HardwareSpec, sparsity: float) -> UnitPerf:
    """SpTC model (Eq. 20): same intensity, raised ceiling."""
    if hw.p_sparse is None:
        raise ValueError(f"{hw.name} has no sparse matrix unit")
    i = w.intensity_matrix(sparsity)
    raw = attainable(hw.p_sparse, hw.bandwidth, i)
    actual = (sparsity / w.alpha) * raw
    return UnitPerf("sparse_matrix", i, raw, actual,
                    bound_state(hw.p_sparse, hw.bandwidth, i), hw.ridge_sparse)


def _sparse_peak(hw: HardwareSpec) -> float:
    """Ceiling of the band-compacted contraction: the sparse unit where
    one exists (A100 SpTC), else the plain MXU -- compaction's
    effective-FLOP reduction is real on any matrix unit (it shrinks the
    executed K-dimension; no special hardware required)."""
    return hw.p_matrix if hw.p_sparse is None else hw.p_sparse


def perf_sparse_banded(w: StencilWorkload, hw: HardwareSpec, sparsity: float,
                       kept: float, overhead: float = 0.0) -> UnitPerf:
    """Executed band-compaction regime, monolithic fusion (DESIGN.md §14).

    Executed FLOPs shrink to kept*(1+overhead) of the dense matrix path
    (same useful work), so the useful-work deflator becomes
    S / (alpha * kept * (1+overhead)).  Compute-bound workloads gain the
    full 1/(kept*(1+overhead)) factor; memory-bound ones tie with the
    dense path to first order (B*I shrinks by exactly what the deflator
    regains), minus the overhead term -- the sparse sweet spot is the
    compute-bound region with  kept*(1+overhead) < 1  (star stencils;
    box kernels compact to kept = 1 and never profit).
    """
    _check_kept(kept)
    peak = _sparse_peak(hw)
    i = w.intensity_sparse_matrix(sparsity, kept, overhead)
    raw = attainable(peak, hw.bandwidth, i)
    actual = (sparsity / (w.alpha * kept * (1.0 + overhead))) * raw
    return UnitPerf("sparse_banded", i, raw, actual,
                    bound_state(peak, hw.bandwidth, i), peak / hw.bandwidth)


def perf_sparse_banded_reuse(w: StencilWorkload, hw: HardwareSpec,
                             sparsity: float, kept: float,
                             overhead: float = 0.0, strip_m: int = 128,
                             z_slab: Optional[int] = None,
                             w_tile: Optional[int] = None) -> UnitPerf:
    """Executed band-compaction regime with intermediate reuse: the dense
    reuse pipeline (alpha=1, dim-aware beta) on the compacted operand.
    ``sparsity`` and ``kept`` are both at the BASE radius r."""
    _check_kept(kept)
    peak = _sparse_peak(hw)
    i = w.intensity_sparse_matrix_reuse(sparsity, kept, overhead,
                                        strip_m, z_slab, w_tile)
    raw = attainable(peak, hw.bandwidth, i)
    beta = reuse_beta(w.spec, w.t, strip_m, z_slab, w_tile)
    actual = (sparsity / (beta * kept * (1.0 + overhead))) * raw
    return UnitPerf("sparse_banded_reuse", i, raw, actual,
                    bound_state(peak, hw.bandwidth, i), peak / hw.bandwidth)


# ---------------------------------------------------------------------------
# Scenario classification + criteria (paper §4.1, Eq. 13--19)
# ---------------------------------------------------------------------------
class Scenario(enum.Enum):
    """(vector-unit bound) -> (matrix-unit bound), paper Figure 8."""

    MB_MB = 1   # equal effective performance
    MB_CB = 2   # matrix unit strictly worse
    CB_MB = 3   # matrix unit strictly better ("breaks the ceiling")
    CB_CB = 4   # conditional: sweet spot iff alpha < S * P_TC / P_CU


@dataclasses.dataclass(frozen=True)
class Comparison:
    workload: StencilWorkload
    hardware: HardwareSpec
    sparsity: float
    vector: UnitPerf
    matrix: UnitPerf
    scenario: Scenario
    speedup: float               # P_TC,actual / P_CU,actual
    profitable: bool             # speedup > 1 (strictly)
    sweet_spot_alpha_limit: float  # S * P_TC / P_CU (Eq. 19 threshold)


def compare(
    w: StencilWorkload,
    hw: HardwareSpec,
    sparsity: float,
    use_sparse_unit: bool = False,
) -> Comparison:
    """Evaluate the paper's criteria for one workload on one chip."""
    v = perf_vector(w, hw)
    m = (perf_sparse_matrix if use_sparse_unit else perf_matrix)(w, hw, sparsity)
    scenario = {
        (Bound.MEMORY, Bound.MEMORY): Scenario.MB_MB,
        (Bound.MEMORY, Bound.COMPUTE): Scenario.MB_CB,
        (Bound.COMPUTE, Bound.MEMORY): Scenario.CB_MB,
        (Bound.COMPUTE, Bound.COMPUTE): Scenario.CB_CB,
    }[(v.bound, m.bound)]
    speedup = m.actual_flops / v.actual_flops
    p_mat = hw.p_sparse if use_sparse_unit else hw.p_matrix
    limit = sparsity * p_mat / hw.p_vector
    return Comparison(
        workload=w, hardware=hw, sparsity=sparsity, vector=v, matrix=m,
        scenario=scenario, speedup=speedup, profitable=speedup > 1.0 + 1e-9,
        sweet_spot_alpha_limit=limit,
    )


def sweet_spot_max_t(
    spec: StencilSpec,
    hw: HardwareSpec,
    sparsity: float,
    dtype_bytes: int = 4,
    t_max: int = 64,
    use_sparse_unit: bool = False,
) -> list[int]:
    """All fusion depths t in [1, t_max] where the matrix unit is profitable.

    This sweeps the paper's Figure 9/14 boundary for a concrete stencil.
    """
    out = []
    for t in range(1, t_max + 1):
        c = compare(StencilWorkload(spec, t, dtype_bytes), hw, sparsity,
                    use_sparse_unit=use_sparse_unit)
        if c.profitable:
            out.append(t)
    return out


# ---------------------------------------------------------------------------
# Transformation-scheme sparsity factors (paper §2.2.2; S is scheme-specific)
# ---------------------------------------------------------------------------
def sparsity_convstencil() -> float:
    """ConvStencil's stencil2row + dual tessellation: S = 0.5 (paper Table 2)."""
    return 0.5


def sparsity_spider() -> float:
    """SPIDER's strided swapping on SpTC: S = 0.47 (paper Table 2)."""
    return 0.47


def sparsity_banded(effective_radius: int, tile_n: int = 128) -> float:
    """Our TPU decompose-to-banded-matmul scheme (DESIGN.md §2).

    Each 1-D sub-convolution multiplies an (M, N+2R) input tile against an
    (N+2R, N) banded weight matrix whose columns carry the 2R+1 kernel taps:
    nonzeros = N*(2R+1) of (N+2R)*N entries ->  S = (2R+1) / (N + 2R).
    """
    r = effective_radius
    return (2 * r + 1) / (tile_n + 2 * r)
