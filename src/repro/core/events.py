"""Bounded, process-wide event log for guarded execution.

The guard layer (``repro.kernels.guard``) records every classified
failure and every degradation-ladder move here.  The log is a fixed-size
ring buffer: a pathological failure loop can never grow memory without
bound, and dropped events are counted so the benchmark dump still shows
that truncation happened.  ``benchmarks/traffic.py`` serialises
``snapshot()`` into BENCH_kernels.json; ``scripts/verify.sh`` asserts it
is empty on a clean run -- the guard layer must be invisible until
something actually fails.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Any, Dict, List, Optional

_CAPACITY = 256


class EventLog:
    """Thread-safe ring buffer of structured events."""

    def __init__(self, capacity: int = _CAPACITY):
        self._capacity = int(capacity)
        if self._capacity < 1:
            raise ValueError(
                f"EventLog capacity must be >= 1, got {capacity}")
        self._buf: deque = deque(maxlen=self._capacity)
        self._lock = threading.Lock()
        self._seq = 0
        self._dropped = 0

    def record(self, kind: str, **fields: Any) -> Dict[str, Any]:
        """Append an event; returns the stored dict (already sequenced)."""
        with self._lock:
            event = {"seq": self._seq, "kind": str(kind)}
            event.update(fields)
            self._seq += 1
            if len(self._buf) == self._capacity:
                self._dropped += 1
            self._buf.append(event)
            return event

    def events(self, kind: Optional[str] = None) -> List[Dict[str, Any]]:
        with self._lock:
            out = list(self._buf)
        if kind is not None:
            out = [e for e in out if e["kind"] == kind]
        return out

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()
            self._seq = 0
            self._dropped = 0

    def snapshot(self) -> Dict[str, Any]:
        """JSON-ready view: events plus loss accounting."""
        with self._lock:
            return {
                "capacity": self._capacity,
                "recorded": self._seq,
                "dropped": self._dropped,
                "events": list(self._buf),
            }

    def __len__(self) -> int:
        with self._lock:
            return len(self._buf)


# The process-wide log all guard paths share.  Module-level functions are
# the public API so callers never hold a reference to a stale instance
# across a clear().
EVENTS = EventLog()


def record(kind: str, **fields: Any) -> Dict[str, Any]:
    return EVENTS.record(kind, **fields)


def events(kind: Optional[str] = None) -> List[Dict[str, Any]]:
    return EVENTS.events(kind)


def clear() -> None:
    EVENTS.clear()


def snapshot() -> Dict[str, Any]:
    return EVENTS.snapshot()
