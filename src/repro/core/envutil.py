"""Shared environment-variable parsing for the runtime's tuning knobs.

Every ``REPRO_*`` knob (``REPRO_VMEM_BUDGET``, ``REPRO_PLAN_CACHE_SIZE``,
``REPRO_FAULTS``, ``REPRO_BENCH_BUDGET_S``, ``REPRO_NAN_WATCHDOG``, ...)
parses through these helpers, so a malformed value always produces the
same style of actionable message -- naming the variable, the offending
value, and the accepted form -- instead of a raw ``ValueError`` from
``int()`` deep inside a kernel-sizing path.  Values are re-read on every
call (no import-time caching): tests and long-running servers retune
without reimporting, matching the historical behavior of
``vmem_budget_bytes`` / ``plan_cache_max``.
"""
from __future__ import annotations

import os
from typing import Optional


def env_str(name: str, default: Optional[str] = None) -> Optional[str]:
    """The raw value of ``name``; empty/whitespace-only counts as unset
    (an empty export is a shell accident, never a meaningful knob)."""
    raw = os.environ.get(name)
    if raw is None or not raw.strip():
        return default
    return raw.strip()


def env_int(name: str, default: int, minimum: int = 1) -> int:
    """Integer knob ``name``: the parsed value if set, else ``default``.

    Raises ``ValueError`` with the variable name and offending text on
    garbage (``"zero"``, ``"8MB"``), and on values below ``minimum``
    (negative cache bounds / budgets are always configuration errors, not
    requests for "unbounded").
    """
    raw = env_str(name)
    if raw is None:
        return default
    try:
        value = int(raw)
    except ValueError:
        raise ValueError(
            f"{name} must be an integer, got {raw!r}") from None
    if value < minimum:
        raise ValueError(
            f"{name} must be >= {minimum}, got {value}")
    return value


def env_flag(name: str, default: bool = False) -> bool:
    """Boolean knob: ``1/true/yes/on`` enable, ``0/false/no/off`` disable
    (case-insensitive); anything else is a configuration error."""
    raw = env_str(name)
    if raw is None:
        return default
    low = raw.lower()
    if low in ("1", "true", "yes", "on"):
        return True
    if low in ("0", "false", "no", "off"):
        return False
    raise ValueError(
        f"{name} must be a boolean (1/0/true/false/yes/no/on/off), "
        f"got {raw!r}")
