"""Shared environment-variable parsing for the runtime's tuning knobs.

Every ``REPRO_*`` knob (``REPRO_VMEM_BUDGET``, ``REPRO_PLAN_CACHE_SIZE``,
``REPRO_FAULTS``, ``REPRO_BENCH_BUDGET_S``, ``REPRO_NAN_WATCHDOG``, the
``REPRO_SERVE_*`` family, ...) parses through these helpers, so a
malformed value always produces the same style of actionable message --
naming the variable, the offending value, and the accepted form --
instead of a raw ``ValueError`` from ``int()`` deep inside a
kernel-sizing path.  Values are re-read on every call (no import-time
caching): tests and long-running servers retune without reimporting,
matching the historical behavior of ``vmem_budget_bytes`` /
``plan_cache_max``.
"""
from __future__ import annotations

import os
from typing import Optional, Sequence, Tuple


def env_str(name: str, default: Optional[str] = None) -> Optional[str]:
    """The raw value of ``name``; empty/whitespace-only counts as unset
    (an empty export is a shell accident, never a meaningful knob)."""
    raw = os.environ.get(name)
    if raw is None or not raw.strip():
        return default
    return raw.strip()


#: (name, raw, minimum) -> parsed value.  The ENVIRONMENT is still read
#: on every call (retune-without-reimport stays intact); only the
#: parse+validate of an already-seen raw string is skipped -- knobs like
#: REPRO_VMEM_BUDGET sit on the per-request plan-signature path.
_INT_PARSE_CACHE: dict = {}


def env_int(name: str, default: int, minimum: int = 1) -> int:
    """Integer knob ``name``: the parsed value if set, else ``default``.

    Raises ``ValueError`` with the variable name and offending text on
    garbage (``"zero"``, ``"8MB"``), and on values below ``minimum``
    (negative cache bounds / budgets are always configuration errors, not
    requests for "unbounded").
    """
    raw = env_str(name)
    if raw is None:
        return default
    key = (name, raw, minimum)
    value = _INT_PARSE_CACHE.get(key)
    if value is not None:
        return value
    try:
        value = int(raw)
    except ValueError:
        raise ValueError(
            f"{name} must be an integer, got {raw!r}") from None
    if value < minimum:
        raise ValueError(
            f"{name} must be >= {minimum}, got {value}")
    _INT_PARSE_CACHE[key] = value
    return value


def env_int_list(name: str, default: Sequence[int],
                 minimum: int = 1) -> Tuple[int, ...]:
    """Comma-separated integer-list knob (e.g. ``REPRO_SERVE_BUCKETS``):
    the parsed tuple if set, else ``tuple(default)``.

    Empty/whitespace-only values count as unset (matching :func:`env_str`);
    empty items between commas (``"1,,4"``, trailing commas) are ignored.
    Garbage items and values below ``minimum`` raise ``ValueError`` naming
    the variable and the offending item -- a malformed bucket ladder must
    fail loudly, never silently serve unbatched.
    """
    raw = env_str(name)
    if raw is None:
        return tuple(default)
    out = []
    for item in raw.split(","):
        item = item.strip()
        if not item:
            continue
        try:
            value = int(item)
        except ValueError:
            raise ValueError(
                f"{name} must be a comma-separated list of integers, "
                f"got {item!r} in {raw!r}") from None
        if value < minimum:
            raise ValueError(
                f"{name} entries must be >= {minimum}, got {value}")
        out.append(value)
    if not out:
        return tuple(default)
    return tuple(out)


def env_flag(name: str, default: bool = False) -> bool:
    """Boolean knob: ``1/true/yes/on`` enable, ``0/false/no/off`` disable
    (case-insensitive); anything else is a configuration error."""
    raw = env_str(name)
    if raw is None:
        return default
    low = raw.lower()
    if low in ("1", "true", "yes", "on"):
        return True
    if low in ("0", "false", "no", "off"):
        return False
    raise ValueError(
        f"{name} must be a boolean (1/0/true/false/yes/no/on/off), "
        f"got {raw!r}")
