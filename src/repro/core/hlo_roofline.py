"""Generalized 3-term roofline from compiled XLA artifacts.

This module carries the paper's enhanced-roofline methodology (§3) to the
LM architectures: for each compiled (arch x shape x mesh) cell we derive

    compute term    = HLO_FLOPs       / (peak FLOP/s per chip)
    memory term     = HLO_bytes       / (HBM bytes/s per chip)
    collective term = collective_bytes/ (ICI bytes/s per chip)

from ``compiled.cost_analysis()`` (per-partition module) plus a pass over
the optimized HLO text summing operand bytes of every collective op.  The
``MODEL_FLOPS / HLO_FLOPs`` ratio is the paper's S/alpha "useful fraction"
generalized to arbitrary programs: remat recompute, padding and dispatch
overhead all surface as redundancy.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# TPU v5e per-chip constants (same as DESIGN.md / perfmodel)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link (directional approximation)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape literal like ``bf16[16,4096,512]``."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes of every collective op, keyed by op kind.

    HLO lines look like:
      %ag = bf16[16,4096,512]{...} all-gather(%x), replica_groups=...
    We count the RESULT shape (the payload that lands on the wire for
    all-gather; a conservative proxy for the others) and do not divide by
    group size -- this is a per-chip upper bound, consistent across cells.
    """
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        eq = s.find(" = ")
        if eq < 0:
            continue
        rhs = s[eq + 3:]
        for kind in _COLLECTIVES:
            # match "<shape> kind(" right after the equals sign
            m = re.match(r"^(\([^)]*\)|\S+)\s+" + kind + r"(-start|-done)?\(", rhs)
            if m:
                if m.group(2) == "-done":
                    break  # avoid double counting start/done pairs
                out[kind] += _shape_bytes(m.group(1))
                counts[kind] += 1
                break
    out_counts = {f"n_{k}": v for k, v in counts.items()}
    return {**out, **out_counts}


@dataclasses.dataclass
class RooflineTerms:
    flops: float                 # per-chip HLO flops
    hbm_bytes: float             # per-chip HLO bytes accessed
    collective_bytes: float      # per-chip collective payload
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: Optional[float] = None
    useful_fraction: Optional[float] = None   # MODEL_FLOPS / HLO_FLOPs

    def as_dict(self):
        return dataclasses.asdict(self)


def roofline_from_compiled(compiled, model_flops: Optional[float] = None,
                           n_chips: int = 1) -> RooflineTerms:
    """model_flops: whole-program useful FLOPs (e.g. 6*N*D*tokens); divided
    by n_chips to compare against the per-partition HLO flops.

    Costs come from the trip-count-aware HLO analyzer (core.hlo_cost):
    ``compiled.cost_analysis()`` counts loop bodies once and XLA:SPMD
    collectives only exist in post-partitioning HLO."""
    from repro.core import hlo_cost
    pc = hlo_cost.analyze_hlo(compiled.as_text())
    flops = pc.flops
    byts = pc.bytes_major     # fusion-aware TPU HBM-traffic estimate
    cbytes = pc.collective_bytes
    terms = RooflineTerms(
        flops=flops,
        hbm_bytes=byts,
        collective_bytes=cbytes,
        compute_s=flops / PEAK_FLOPS,
        memory_s=byts / HBM_BW,
        collective_s=cbytes / ICI_BW,
        bottleneck="",
        model_flops=model_flops,
    )
    tmap = {"compute": terms.compute_s, "memory": terms.memory_s,
            "collective": terms.collective_s}
    terms.bottleneck = max(tmap, key=tmap.get)
    if model_flops is not None and flops > 0:
        terms.useful_fraction = (model_flops / n_chips) / flops
    return terms


def model_flops_for(cfg, cell) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) per step, where D =
    tokens processed.  Decode cells process one token per sequence."""
    from repro.models.api import get_model
    n = get_model(cfg).param_count()
    if cfg.moe is not None:
        from repro.models import moe as _m
        # subtract inactive expert params: experts contribute top_k/E of
        # their weights per token
        e, k = cfg.moe.num_experts, cfg.moe.top_k
        expert_params = 3 * cfg.d_model * cfg.d_ff * e * cfg.n_layers
        n = n - expert_params + expert_params * (k / e)
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n * tokens
    tokens = cell.global_batch            # one new token per sequence
    return 2.0 * n * tokens
