"""Config module for ``glm4-9b`` (see registry.py for the numbers)."""
from repro.configs.registry import ARCHS, SMOKE, SHAPES, cells_for

ARCH = "glm4-9b"
FULL = ARCHS[ARCH]
SMOKE_CFG = SMOKE[ARCH]
CELLS = {name: SHAPES[name] for name in cells_for(ARCH)}
