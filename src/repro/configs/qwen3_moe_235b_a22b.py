"""Config module for ``qwen3-moe-235b-a22b`` (see registry.py for the numbers)."""
from repro.configs.registry import ARCHS, SMOKE, SHAPES, cells_for

ARCH = "qwen3-moe-235b-a22b"
FULL = ARCHS[ARCH]
SMOKE_CFG = SMOKE[ARCH]
CELLS = {name: SHAPES[name] for name in cells_for(ARCH)}
