"""Config module for ``rwkv6-1.6b`` (see registry.py for the numbers)."""
from repro.configs.registry import ARCHS, SMOKE, SHAPES, cells_for

ARCH = "rwkv6-1.6b"
FULL = ARCHS[ARCH]
SMOKE_CFG = SMOKE[ARCH]
CELLS = {name: SHAPES[name] for name in cells_for(ARCH)}
