"""Architecture configuration registry: the 10 assigned archs + paper grid.

Every architecture is a ``ModelConfig``; ``SMOKE[name]`` is the reduced
same-family variant used by CPU smoke tests.  Input shapes are the four
assigned (arch-independent) cells; per-arch skips follow DESIGN.md §5.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    shared_attn_every: int = 6   # zamba2: shared attention block cadence


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | rwkv | whisper | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    mlp_act: str = "swiglu"       # swiglu | gelu
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # whisper: encoder layers == n_layers, decoder layers:
    dec_layers: Optional[int] = None
    # vlm: number of image patch positions fed by the stub frontend
    n_img_patches: int = 0
    tie_embeddings: bool = False
    fsdp: bool = False            # shard params+opt over data axis too (ZeRO-3)
    remat: bool = True
    dtype: str = "bfloat16"       # activation/compute dtype
    sub_quadratic: bool = False   # True => can run long_500k
    attn_chunk: int = 512         # query-chunked exact attention
    # --- beyond-paper perf variants (EXPERIMENTS.md §Perf) ---
    wkv_factored: bool = False    # rwkv6: factored intra-chunk decay
    moe_group: int = 0            # moe: dispatch group size (0 = full seq)
    pure_dp: bool = False         # fold `model` into data parallelism
                                  # (attention-free archs: TP buys nothing)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads


ARCHS = {}
SMOKE = {}


def _reg(full: ModelConfig, smoke: ModelConfig):
    ARCHS[full.name] = full
    SMOKE[full.name] = smoke


# --- LM-family transformers (assigned pool) --------------------------------
_reg(
    ModelConfig("llama3.2-1b", "dense", 16, 2048, 32, 8, 8192, 128256, fsdp=True),
    ModelConfig("llama3.2-1b", "dense", 2, 64, 4, 2, 128, 256),
)
_reg(
    ModelConfig("glm4-9b", "dense", 40, 4096, 32, 2, 13696, 151552, fsdp=True),
    ModelConfig("glm4-9b", "dense", 2, 64, 4, 2, 160, 256),
)
_reg(
    ModelConfig("deepseek-7b", "dense", 30, 4096, 32, 32, 11008, 102400,
                rope_theta=10000.0, fsdp=True),
    ModelConfig("deepseek-7b", "dense", 2, 64, 4, 4, 128, 256,
                rope_theta=10000.0),
)
_reg(
    ModelConfig("tinyllama-1.1b", "dense", 22, 2048, 32, 4, 5632, 32000,
                rope_theta=10000.0, fsdp=True),
    ModelConfig("tinyllama-1.1b", "dense", 2, 64, 4, 2, 96, 256,
                rope_theta=10000.0),
)
_reg(
    ModelConfig("internvl2-2b", "vlm", 24, 2048, 16, 8, 8192, 92553,
                n_img_patches=256, fsdp=True),
    ModelConfig("internvl2-2b", "vlm", 2, 64, 4, 2, 128, 256, n_img_patches=16),
)
_reg(
    # pure_dp: d=512 is far too narrow for 16-way TP (§Perf D1: 5.9x);
    # the batch>=chips policy in dryrun falls back to TP for small-batch cells.
    ModelConfig("whisper-base", "whisper", 6, 512, 8, 8, 2048, 51865,
                mlp_act="gelu", dec_layers=6, pure_dp=True, fsdp=True),
    ModelConfig("whisper-base", "whisper", 2, 64, 4, 4, 128, 256,
                mlp_act="gelu", dec_layers=2),
)
_reg(
    ModelConfig("zamba2-1.2b", "hybrid", 38, 2048, 32, 32, 8192, 32000,
                ssm=SSMConfig(state_dim=64), sub_quadratic=True, fsdp=True),
    ModelConfig("zamba2-1.2b", "hybrid", 4, 64, 4, 4, 128, 256,
                ssm=SSMConfig(state_dim=16, head_dim=16), sub_quadratic=True),
)
_reg(
    ModelConfig("olmoe-1b-7b", "moe", 16, 2048, 16, 16, 1024, 50304,
                moe=MoEConfig(64, 8), fsdp=True),
    ModelConfig("olmoe-1b-7b", "moe", 2, 64, 4, 4, 64, 256,
                moe=MoEConfig(8, 2)),
)
_reg(
    ModelConfig("qwen3-moe-235b-a22b", "moe", 94, 4096, 64, 4, 1536, 151936,
                head_dim=128, moe=MoEConfig(128, 8), fsdp=True),
    ModelConfig("qwen3-moe-235b-a22b", "moe", 2, 64, 4, 2, 64, 256,
                moe=MoEConfig(8, 2)),
)
_reg(
    # production config ships the §Perf winners (wkv_factored + pure_dp);
    # paper-faithful baselines were recorded with both flags off.
    ModelConfig("rwkv6-1.6b", "rwkv", 24, 2048, 32, 32, 7168, 65536,
                sub_quadratic=True, fsdp=True, wkv_factored=True,
                pure_dp=True),
    ModelConfig("rwkv6-1.6b", "rwkv", 2, 64, 4, 4, 224, 256,
                sub_quadratic=True),
)


# --- Input shape cells ------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def cells_for(arch: str):
    """The shape cells actually lowered for an arch (DESIGN.md §5 skips)."""
    cfg = ARCHS[arch]
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        cells.append("long_500k")
    return cells


def get(name: str, smoke: bool = False) -> ModelConfig:
    return (SMOKE if smoke else ARCHS)[name]
