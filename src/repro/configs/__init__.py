from .registry import ARCHS, SMOKE, SHAPES, ModelConfig, MoEConfig, SSMConfig, ShapeCell, cells_for, get
