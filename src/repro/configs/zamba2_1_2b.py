"""Config module for ``zamba2-1.2b`` (see registry.py for the numbers)."""
from repro.configs.registry import ARCHS, SMOKE, SHAPES, cells_for

ARCH = "zamba2-1.2b"
FULL = ARCHS[ARCH]
SMOKE_CFG = SMOKE[ARCH]
CELLS = {name: SHAPES[name] for name in cells_for(ARCH)}
