"""Config module for ``whisper-base`` (see registry.py for the numbers)."""
from repro.configs.registry import ARCHS, SMOKE, SHAPES, cells_for

ARCH = "whisper-base"
FULL = ARCHS[ARCH]
SMOKE_CFG = SMOKE[ARCH]
CELLS = {name: SHAPES[name] for name in cells_for(ARCH)}
