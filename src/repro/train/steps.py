"""jit-able train / serve steps shared by the trainer, dry-run and tests."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.api import Model
from repro.optim import adamw
from repro.parallel import compress as compress_lib


def make_train_step(model: Model, opt_cfg: adamw.AdamWConfig,
                    grad_compression: Optional[str] = None):
    """Returns train_step(params, opt_state, batch) -> (params, state, metrics).

    ``grad_compression="int8"`` wraps gradients in quantize/dequantize with
    error feedback (see parallel.compress) -- the all-reduce then moves int8
    bytes.  Error-feedback residual lives in opt-state-adjacent metrics-free
    pytree carried inside opt_state.m's dtype? -- no: residual is a separate
    leaf carried alongside (kept simple: stateless stochastic rounding)."""

    def train_step(params, opt_state, batch):
        def lf(p):
            loss, aux = model.loss_fn(p, batch)
            return loss, aux

        (loss, aux), grads = jax.value_and_grad(lf, has_aux=True)(params)
        if grad_compression == "int8":
            grads = compress_lib.fake_quantize_tree(grads)
        params2, opt_state2, om = adamw.apply(opt_cfg, grads, opt_state, params)
        metrics = {"loss": loss, **aux, **om}
        return params2, opt_state2, metrics

    return train_step


def make_serve_step(model: Model):
    """One-token greedy decode step (the unit the decode cells lower)."""

    def serve_step(params, caches, token, pos):
        return model.decode_step(params, caches, token, pos)

    return serve_step


def make_eval_step(model: Model):
    def eval_step(params, batch):
        loss, aux = model.loss_fn(params, batch)
        return {"loss": loss, **aux}

    return eval_step
