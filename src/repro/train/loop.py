"""Fault-tolerant training loop: checkpoint/restart, straggler watchdog,
deterministic data resume, optional gradient compression.

Designed so that a SIGKILL at any step loses at most ``ckpt_every`` steps:
the data pipeline is stateless (batch_at(step)), checkpoints are atomic,
and restore reshards onto whatever mesh the restarted job has."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import SyntheticLM
from repro.models.api import Model
from repro.optim import adamw
from repro.train.steps import make_train_step


@dataclasses.dataclass
class LoopConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep_ckpts: int = 3
    log_every: int = 10
    # straggler watchdog: flag steps slower than watchdog_factor x the
    # running median (on real clusters this triggers requeue/hot-spare;
    # here it logs and counts -- the hook point is `on_straggler`)
    watchdog_factor: float = 3.0
    grad_compression: Optional[str] = None


class StragglerWatchdog:
    def __init__(self, factor: float):
        self.factor = factor
        self.times = []
        self.flagged = 0

    def observe(self, dt: float) -> bool:
        slow = False
        if len(self.times) >= 5:
            med = float(np.median(self.times[-50:]))
            slow = dt > self.factor * med
        self.times.append(dt)
        if slow:
            self.flagged += 1
        return slow


def train(model: Model, data: SyntheticLM, opt_cfg: adamw.AdamWConfig,
          loop_cfg: LoopConfig, params=None,
          on_metrics: Optional[Callable[[int, Dict], None]] = None):
    """Run (or resume) training.  Returns (params, opt_state, history)."""
    if params is None:
        params = model.init_params(jax.random.PRNGKey(0))
    opt_state = adamw.init(params)
    start_step = 0

    mgr = None
    if loop_cfg.ckpt_dir:
        mgr = CheckpointManager(loop_cfg.ckpt_dir, keep=loop_cfg.keep_ckpts)
        like = {"params": jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params),
                "opt": jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), opt_state)}
        step0, restored = mgr.restore_latest(like)
        if step0 is not None:
            params, opt_state = restored["params"], adamw.AdamWState(
                *restored["opt"])
            start_step = step0
            print(f"[resume] from step {step0}")

    step_fn = jax.jit(make_train_step(model, opt_cfg,
                                      loop_cfg.grad_compression),
                      donate_argnums=(0, 1))
    dog = StragglerWatchdog(loop_cfg.watchdog_factor)
    history = []
    tokens_per_batch = data.cfg.global_batch * data.cfg.seq_len

    for step in range(start_step, loop_cfg.steps):
        t0 = time.monotonic()
        batch = data.batch_at(step)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])          # blocks; honest step time
        dt = time.monotonic() - t0
        slow = dog.observe(dt)
        rec = {"step": step + 1, "loss": loss, "dt": dt,
               "tok_s": tokens_per_batch / dt, "straggler": slow}
        history.append(rec)
        if on_metrics:
            on_metrics(step + 1, rec)
        if (step + 1) % loop_cfg.log_every == 0 or step == start_step:
            print(f"[step {step+1:>5}] loss {loss:.4f}  {dt*1e3:7.1f} ms "
                  f"{rec['tok_s']:,.0f} tok/s"
                  + ("  [STRAGGLER]" if slow else ""))
        if mgr and (step + 1) % loop_cfg.ckpt_every == 0:
            path = mgr.save(step + 1, {"params": params,
                                       "opt": opt_state._asdict() if hasattr(
                                           opt_state, "_asdict") else opt_state})
            print(f"[ckpt] step {step+1} -> {path}")
    if dog.flagged:
        print(f"[watchdog] flagged {dog.flagged} straggler steps")
    return params, opt_state, history
