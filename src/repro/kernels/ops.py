"""Jit'd public entry points for the stencil kernels, with analytic dispatch.

``stencil_apply(x, weights, t, backend="auto")`` is the deployable form of
the paper: the enhanced-roofline criteria (repro.core.selector) pick the
execution unit, then the matching Pallas kernel runs on the strip-mined
halo substrate (3 neighbor-block loads per output strip, DESIGN.md §3).

Backends
  direct              t sequential VPU kernel steps      (halo r per step)
  fused_direct        one VPU kernel, t in-VMEM steps     (paper's temporal fusion)
  matmul              t sequential MXU banded contractions (halo r per step)
  fused_matmul        weights composed to radius t*r, one  (paper's monolithic
                      MXU banded contraction                kernel fusion, alpha>1)
  fused_matmul_reuse  one MXU kernel, t radius-r banded    (intermediate reuse:
                      contractions w/ VMEM intermediates    alpha=1, halo-recompute
                                                            beta -- DESIGN.md §4)
  reference           jnp oracle (debug)
  auto                selector decides among the above from the hardware model

``interpret`` defaults to True off-TPU so every path is CPU-checkable; on a
real TPU it compiles through Mosaic.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import perfmodel as pm
from repro.core.selector import Decision, select_backend
from repro.stencil.spec import StencilSpec
from repro.stencil.weights import fuse_weights
from .stencil_direct import stencil_direct
from .stencil_matmul import stencil_matmul
from . import ref as _ref

BACKENDS = ("direct", "fused_direct", "matmul", "fused_matmul",
            "fused_matmul_reuse", "reference", "auto")


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def spec_from_weights(weights) -> StencilSpec:
    """Infer (shape, d, r) from a dense kernel's support."""
    w = np.asarray(weights)
    radius = (w.shape[0] - 1) // 2
    dim = w.ndim
    box_points = np.count_nonzero(w)
    star_points = 2 * dim * radius + 1
    shape = "star" if box_points <= star_points else "box"
    return StencilSpec(shape, dim, radius)


def stencil_apply(
    x: jax.Array,
    weights,
    t: int = 1,
    backend: str = "auto",
    hw: pm.HardwareSpec = pm.TPU_V5E_BF16,
    tile_m: Optional[int] = None,
    tile_n: Optional[int] = None,
    interpret: Optional[bool] = None,
    compute_dtype=None,
) -> jax.Array:
    """Advance the grid ``t`` time steps with the selected backend.

    ``tile_m``/``tile_n`` default to ``None`` = auto-sized by the kernels
    (``choose_strip`` / ``choose_tile``); explicit values are validated
    strictly."""
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}")
    if t < 1:
        raise ValueError(f"fusion depth must be >= 1, got {t}")
    if interpret is None:
        interpret = _default_interpret()

    if backend == "auto":
        spec = spec_from_weights(weights)
        decision = select_backend(
            spec, t, dtype_bytes=x.dtype.itemsize, hw=hw,
            tile_n=tile_n if tile_n is not None else 128,
            strip_m=tile_m if tile_m is not None else 128,
        )
        backend = decision.backend

    if backend == "reference":
        return _ref.stencil_direct_ref(x, weights, t)
    if backend == "direct":
        y = x
        for _ in range(t):
            y = stencil_direct(y, weights, t=1, tile_m=tile_m, tile_n=tile_n,
                               interpret=interpret)
        return y
    if backend == "fused_direct":
        return stencil_direct(x, weights, t=t, tile_m=tile_m, tile_n=tile_n,
                              interpret=interpret)
    if backend == "matmul":
        y = x
        for _ in range(t):
            y = stencil_matmul(y, weights, t=1, tile_m=tile_m, tile_n=tile_n,
                               interpret=interpret, compute_dtype=compute_dtype)
        return y
    if backend == "fused_matmul":
        wf = fuse_weights(np.asarray(weights), t)
        return stencil_matmul(x, wf, t=1, tile_m=tile_m, tile_n=tile_n,
                              interpret=interpret, compute_dtype=compute_dtype)
    if backend == "fused_matmul_reuse":
        return stencil_matmul(x, weights, t=t, tile_m=tile_m, tile_n=tile_n,
                              interpret=interpret, compute_dtype=compute_dtype)
    raise AssertionError(backend)


def explain(
    weights, t: int, dtype_bytes: int = 4,
    hw: pm.HardwareSpec = pm.TPU_V5E_BF16, tile_n: int = 128,
    strip_m: int = 128,
) -> Decision:
    """Expose the dispatch decision (scenario, predicted speedup, reason)."""
    return select_backend(spec_from_weights(weights), t, dtype_bytes, hw,
                          tile_n=tile_n, strip_m=strip_m)
