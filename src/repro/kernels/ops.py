"""Compatibility entry points over the plan API (repro.kernels.plan).

``stencil_apply(x, weights, t, backend="auto")`` is the historical one-shot
form: it builds-or-fetches a :class:`~repro.kernels.plan.StencilPlan` for
the call signature and executes it.  Selection, strip/tile sizing, and
weight preprocessing therefore run once per DISTINCT signature and are
served from the plan cache afterwards -- serving-scale callers should hold
a plan directly (``stencil_plan``) instead.

Backends (see ``repro.kernels.registry``; any registered name is accepted)
  direct              t sequential VPU kernel steps      (halo r per step)
  fused_direct        one VPU kernel, t in-VMEM steps     (paper's temporal fusion)
  matmul              t sequential MXU banded contractions (halo r per step)
  fused_matmul        weights composed to radius t*r, one  (paper's monolithic
                      MXU banded contraction                kernel fusion, alpha>1)
  fused_matmul_reuse  one MXU kernel, t radius-r banded    (intermediate reuse:
                      contractions w/ VMEM intermediates    alpha=1, halo-recompute
                                                            beta -- DESIGN.md §4)
  sparse_matmul /     the two regimes above with the banded (sparse-tensor-core
  fused_sparse_matmul operand compacted to its nonzero band regime; priced only
                      rows -- K shrinks by the kept-row     under use_sparse_unit
                      fraction S, bitwise-equal outputs     -- DESIGN.md §14)
  reference           jnp oracle (debug)
  legacy_direct/      seed 9-tile substrate (benchmark foil)
  legacy_matmul
  *_wholestrip        the five regimes on the whole-strip 3-load substrate
                      (benchmark foils; default is halo-row sub-blocked)
  auto                selector decides among the priced backends

``interpret`` defaults to True off-TPU so every path is CPU-checkable; on a
real TPU it compiles through Mosaic.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.core import perfmodel as pm
from repro.core.selector import Decision
from . import registry
from .plan import decide, spec_from_weights, stencil_plan

def __getattr__(name):
    # BACKENDS is computed on access so late-registered plug-in backends
    # are visible: registered names + the "auto" selection policy.
    if name == "BACKENDS":
        return registry.registered_backends() + ("auto",)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def stencil_apply(
    x: jax.Array,
    weights,
    t: int = 1,
    backend: str = "auto",
    hw: pm.HardwareSpec = pm.TPU_V5E_BF16,
    tile_m: Optional[int] = None,
    tile_n: Optional[int] = None,
    h_block: Optional[int] = None,
    z_slab: Optional[int] = None,
    z_block: Optional[int] = None,
    w_tile: Optional[int] = None,
    w_block: Optional[int] = None,
    interpret: Optional[bool] = None,
    compute_dtype=None,
    use_sparse_unit: bool = False,
    guard: bool = False,
    watchdog: Optional[bool] = None,
    boundary=None,
) -> jax.Array:
    """Advance the grid ``t`` time steps with the selected backend.

    Thin wrapper: equivalent to building ``stencil_plan(weights, x.shape,
    x.dtype, t, ...)`` and calling it -- identical signatures share one
    cached plan.  1D, 2D and 3D grids are supported (the grid rank must
    match ``weights.ndim``).  ``tile_m``/``tile_n``/``z_slab``/``w_tile``
    default to ``None`` = auto-sized by the kernels
    (``resolve_substrate_geom`` / ``choose_tile``; ``w_tile`` stays full
    width unless the full-width working set exceeds the VMEM budget --
    DESIGN.md §10); explicit values are validated strictly.

    ``guard=True`` routes through the guarded execution layer
    (``repro.kernels.guard``, DESIGN.md §11): kernel failures degrade
    down the fallback ladder instead of raising, and ``watchdog``
    (None = the ``REPRO_NAN_WATCHDOG`` env flag) arms the NaN/Inf check
    with a checked re-run.  On a clean run both paths execute the
    identical cached plan.

    ``boundary`` selects the per-axis global edge mode (DESIGN.md §15):
    ``None``/``"periodic"`` is the historical wrap bit for bit; a string
    applies to every axis, a tuple names each axis, e.g.
    ``boundary=("reflect", "periodic")``."""
    kw = dict(
        hw=hw, backend=None if backend == "auto" else backend,
        tile_m=tile_m, tile_n=tile_n, h_block=h_block,
        z_slab=z_slab, z_block=z_block, w_tile=w_tile, w_block=w_block,
        interpret=interpret, compute_dtype=compute_dtype,
        use_sparse_unit=use_sparse_unit, boundary=boundary,
    )
    if guard:
        from .guard import guarded_stencil_plan
        plan = guarded_stencil_plan(weights, x.shape, x.dtype, t,
                                    watchdog=watchdog, **kw)
    else:
        plan = stencil_plan(weights, x.shape, x.dtype, t, **kw)
    return plan(x)


def explain(
    weights, t: int, dtype_bytes: int = 4,
    hw: pm.HardwareSpec = pm.TPU_V5E_BF16, tile_n: int = 128,
    strip_m: int = 128, h_block: Optional[int] = None,
    z_slab: Optional[int] = None, z_block: Optional[int] = None,
    w_tile: Optional[int] = None, w_block: Optional[int] = None,
    grid_shape=None, tile_m: Optional[int] = None,
    use_sparse_unit: bool = False,
    boundary=None,
) -> Decision:
    """Expose the dispatch decision (scenario, predicted speedup, reason).

    Delegates to ``repro.kernels.plan.decide`` -- the same single decision
    path plan building and the ``auto`` backend consult.  The reason
    string includes the substrate's read-amplification factor and the
    resolved (z_slab, strip_m, h_block, w_tile) geometry for every rank.
    Plans price the geometry they resolve FOR THEIR GRID, so pass
    ``grid_shape`` -- plus the same ``tile_m``/``h_block``/``z_slab``/
    ``z_block``/``w_tile``/``w_block`` pins you would hand
    ``stencil_plan`` -- and the identical resolution runs here,
    guaranteeing ``explain`` agrees with what such a plan actually
    executes (``strip_m`` is then superseded by the resolution).  Without
    ``grid_shape`` the decision is priced at the documented defaults
    (strip_m=128, z_slab=strip_m for 3D, auto blocks, full width), which
    only coincide with plans whose grids resolve to them."""
    spec = spec_from_weights(weights)
    if grid_shape is not None:
        from .common import resolve_substrate_geom
        geom = resolve_substrate_geom(
            tuple(int(n) for n in grid_shape), t * spec.radius, dtype_bytes,
            tile_m, h_block, z_slab, z_block, w_tile, w_block)
        strip_m, h_block = geom.strip_m, geom.h_block
        z_slab = geom.z_slab if geom.dim == 3 else None
        z_block = geom.z_block if geom.dim == 3 else None
        w_tile = geom.w_tile if geom.dim >= 2 else None
        w_block = geom.w_block if geom.dim >= 2 else None
    if boundary is not None:
        from repro.stencil.boundary import resolve_boundary
        boundary = resolve_boundary(boundary, spec.dim)
    return decide(spec, t, dtype_bytes, hw,
                  tile_n=tile_n, strip_m=strip_m, h_block=h_block,
                  z_slab=z_slab, z_block=z_block,
                  w_tile=w_tile, w_block=w_block,
                  use_sparse_unit=use_sparse_unit, boundary=boundary)
