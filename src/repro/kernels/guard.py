"""Guarded plan execution: failure taxonomy + deterministic degradation
ladder (DESIGN.md §11).

The paper's thesis is that MXU stencil execution only wins inside a
sweet spot; outside it -- awkward geometries, deep fusion, VMEM-tight
tiles -- the aggressive regimes are exactly where compiles fail and
numerics drift.  A serving deployment (ROADMAP north star) cannot crash
on the first Mosaic error.  This module makes every plan build and step
*survivable*:

Taxonomy
  Raw XLA / Mosaic / Pallas exceptions are classified by cause into
  :class:`PlanBuildError`, :class:`KernelCompileError`,
  :class:`VmemOverflowError`, :class:`NumericalFaultError`, or
  :class:`HaloExchangeError`, all subclasses of
  :class:`GuardedExecutionError` carrying ``.cause``.

Degradation ladder
  On failure, a :class:`GuardedPlan` retries deterministically:

    requested backend, normal geometry
      -> same backend, DEGRADED geometry (auto pins dropped, VMEM
         budget halved, so ``resolve_substrate_geom`` shrinks
         strip_m / z_slab / w_tile)
      -> registry backends by ``fallback_rank``
         (fused_matmul_reuse -> fused_matmul -> matmul -> fused_direct
          -> direct -> *_wholestrip foils -> reference oracle)

  Each rung failure is classified, recorded in the
  :mod:`repro.core.events` ring buffer, and noted in the plan module's
  negative-result registry (``note_plan_failure``) -- the LRU never
  retains a failed signature, and a repeat request short-circuits
  straight past known-bad rungs (``failed_plan``).  The ladder is a pure
  function of the plan signature and process env, so every shard of a
  distributed mesh lands on the same rung without communicating.

Watchdog
  Opt-in (``watchdog=True`` or ``REPRO_NAN_WATCHDOG=1``): each guarded
  step's output is checked for NaN/Inf on the host; a fault re-runs the
  offending step through the checked reference backend, records a
  :class:`NumericalFaultError` event, and demotes the rung for future
  calls.  Fused bf16 steps are the intended clients.

A clean run records nothing, skips nothing, and returns the *identical*
cached plan object an unguarded ``stencil_plan`` call would -- the guard
layer is invisible until something fails (the ISSUE 6 acceptance bar).
"""
from __future__ import annotations

import os
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import events as _events
from repro.core.envutil import env_flag
from repro.testing import faults as _faults
from . import plan as _plan
from . import registry


# ---------------------------------------------------------------------------
# Failure taxonomy
# ---------------------------------------------------------------------------
class GuardedExecutionError(RuntimeError):
    """Base of the guard taxonomy; ``cause`` is the machine-readable tag
    recorded in events and negative-cache entries."""

    cause = "unknown"

    def __init__(self, message: str, *, backend: Optional[str] = None,
                 stage: Optional[str] = None):
        super().__init__(message)
        self.backend = backend
        self.stage = stage


class PlanBuildError(GuardedExecutionError):
    """Host-side plan construction failed (sizing, validation, weight
    composition) before any kernel was traced."""

    cause = "plan_build"


class KernelCompileError(GuardedExecutionError):
    """The kernel failed to trace/lower/compile (Mosaic, XLA, Pallas)."""

    cause = "compile"


class VmemOverflowError(GuardedExecutionError):
    """The compiled working set exceeded VMEM (RESOURCE_EXHAUSTED and
    friends): the tile estimate lied; degrade the geometry."""

    cause = "vmem"


class NumericalFaultError(GuardedExecutionError):
    """A step produced NaN/Inf (watchdog) -- numerics drifted, typically
    deep fusion in bf16."""

    cause = "numerical"


class HaloExchangeError(GuardedExecutionError):
    """The distributed halo exchange (ppermute ring) failed."""

    cause = "halo"


#: Message fragments -> taxonomy, checked in order (most specific first).
#: These deliberately match both real XLA/Mosaic spellings and the
#: injected fakes of repro.testing.faults, so tests exercise the exact
#: classification path production errors take.
_VMEM_MARKERS = ("resource_exhausted", "vmem", "out of memory",
                 "scratch", "memory space")
_COMPILE_MARKERS = ("mosaic", "failed to compile", "lowering",
                    "unsupported", "internal:", "xla", "pallas",
                    "unimplemented", "mlir")
_HALO_MARKERS = ("halo exchange", "ppermute", "collective")
_NUMERIC_MARKERS = ("nan", "non-finite", "not finite", "inf produced")


def classify_failure(exc: BaseException,
                     stage: str = "execute",
                     backend: Optional[str] = None) -> GuardedExecutionError:
    """Wrap a raw exception in its taxonomy class (never raises).

    ``stage`` breaks ties when the message matches nothing: ``"build"``
    failures become :class:`PlanBuildError`, anything at trace/execute
    time defaults to :class:`KernelCompileError` (the conservative guess:
    retrying a different regime is always legal).
    """
    if isinstance(exc, GuardedExecutionError):
        return exc
    msg = str(exc)
    low = msg.lower()
    if any(m in low for m in _HALO_MARKERS):
        cls = HaloExchangeError
    elif any(m in low for m in _VMEM_MARKERS):
        cls = VmemOverflowError
    elif any(m in low for m in _NUMERIC_MARKERS):
        cls = NumericalFaultError
    elif any(m in low for m in _COMPILE_MARKERS):
        cls = KernelCompileError
    elif stage == "build":
        cls = PlanBuildError
    else:
        cls = KernelCompileError
    err = cls(f"[{cls.cause}] {msg}", backend=backend, stage=stage)
    err.__cause__ = exc
    return err


# ---------------------------------------------------------------------------
# Ladder construction
# ---------------------------------------------------------------------------
class _Rung:
    """One ladder position: a backend override + geometry mode."""

    __slots__ = ("backend", "degraded")

    def __init__(self, backend: Optional[str], degraded: bool):
        self.backend = backend      # None = auto (selector decides)
        self.degraded = degraded

    def label(self, resolved: Optional[str] = None) -> str:
        name = self.backend or (f"auto:{resolved}" if resolved else "auto")
        return f"{name}+degraded" if self.degraded else name

    def __repr__(self):
        return f"_Rung({self.label()!r})"


class _EnvPin:
    """Temporarily pin REPRO_VMEM_BUDGET (the degraded-geometry rung):
    auto sizing re-resolves under the shrunken budget and the halved
    value lands in the plan key, so degraded plans never alias normal
    ones.  Restores the prior value even on failure."""

    def __init__(self, budget: Optional[int]):
        self._budget = budget
        self._prior = None
        self._had = False

    def __enter__(self):
        if self._budget is not None:
            self._had = "REPRO_VMEM_BUDGET" in os.environ
            self._prior = os.environ.get("REPRO_VMEM_BUDGET")
            os.environ["REPRO_VMEM_BUDGET"] = str(self._budget)
        return self

    def __exit__(self, *exc):
        if self._budget is not None:
            if self._had:
                os.environ["REPRO_VMEM_BUDGET"] = self._prior
            else:
                os.environ.pop("REPRO_VMEM_BUDGET", None)
        return False


def _start_backend(weights, grid_shape, dtype, t, hw, backend,
                   tile_m, h_block, z_slab, z_block, w_tile, w_block,
                   use_sparse_unit=False):
    """The name the first rung executes: the override if given, else the
    selector's pick -- computed exactly as ``stencil_plan`` itself would,
    so the ladder agrees with the unguarded decision.  Returns ``None``
    when even pricing fails (then the fallback walk uses the full
    ladder)."""
    if backend is not None:
        return backend
    try:
        from .common import resolve_substrate_geom
        spec = _plan.spec_from_weights(weights)
        geom = resolve_substrate_geom(
            tuple(grid_shape), t * spec.radius, np.dtype(dtype).itemsize,
            tile_m, h_block, z_slab, z_block, w_tile, w_block)
        decision = _plan.decide(
            spec, t, dtype_bytes=np.dtype(dtype).itemsize, hw=hw,
            strip_m=geom.strip_m, h_block=geom.h_block,
            z_slab=geom.z_slab if geom.dim == 3 else None,
            z_block=geom.z_block if geom.dim == 3 else None,
            w_tile=geom.w_tile if geom.dim >= 2 else None,
            w_block=geom.w_block if geom.dim >= 2 else None,
            use_sparse_unit=use_sparse_unit)
        return decision.backend
    except Exception:
        return None


# ---------------------------------------------------------------------------
# GuardedPlan
# ---------------------------------------------------------------------------
class GuardedPlan:
    """A StencilPlan wrapper that survives failures by walking the
    degradation ladder.  Mirrors the plan API (``__call__``/``step``/
    ``run``/``explain``) and exposes:

      * ``plan``     -- the live underlying :class:`StencilPlan`;
      * ``backend``  -- the backend actually executing right now;
      * ``degraded`` -- True once any ladder move happened;
      * ``history``  -- ``[{"rung", "cause", "error"}]`` of failed rungs.
    """

    def __init__(self, plan_args: tuple, plan_kwargs: dict,
                 watchdog: Optional[bool] = None):
        self._args = plan_args          # (spec_or_weights, grid, dtype, t)
        self._kwargs = dict(plan_kwargs)
        if watchdog is None:
            watchdog = env_flag("REPRO_NAN_WATCHDOG", False)
        self.watchdog = bool(watchdog)
        self.history: List[dict] = []

        weights = plan_args[0]
        from repro.stencil.spec import StencilSpec
        if isinstance(weights, StencilSpec):
            from repro.stencil.weights import jacobi_weights
            weights = jacobi_weights(weights)
        self._start = _start_backend(
            np.asarray(weights), plan_args[1], plan_args[2], plan_args[3],
            self._kwargs.get("hw", _plan.pm.TPU_V5E_BF16),
            self._kwargs.get("backend"),
            self._kwargs.get("tile_m"), self._kwargs.get("h_block"),
            self._kwargs.get("z_slab"), self._kwargs.get("z_block"),
            self._kwargs.get("w_tile"), self._kwargs.get("w_block"),
            self._kwargs.get("use_sparse_unit", False))

        requested = self._kwargs.get("backend")  # None = auto
        self._rungs: List[_Rung] = [_Rung(requested, False),
                                    _Rung(requested, True)]
        for name in registry.fallback_ladder(after=self._start):
            self._rungs.append(_Rung(name, False))
        if not any(r.backend == "reference" for r in self._rungs):
            self._rungs.append(_Rung("reference", False))  # terminal rung
        self._idx = 0
        self._plan = None
        self._checked = None            # lazily built reference re-run plan
        self._build_current()

    # -- rung plumbing --------------------------------------------------
    def _rung_call_kwargs(self, rung: _Rung) -> dict:
        kw = dict(self._kwargs)
        kw["backend"] = rung.backend
        if rung.degraded:
            # Degraded geometry: drop explicit pins so the shared N-D rule
            # (resolve_substrate_geom) re-sizes everything under the
            # halved budget pinned by _EnvPin.  ``boundary`` is NOT in this
            # list: it is semantics, not geometry -- every rung (and the
            # checked reference re-run) must honor the plan's boundary
            # modes or the ladder would silently change the answer.
            for g in ("tile_m", "tile_n", "h_block", "z_slab", "z_block",
                      "w_tile", "w_block"):
                kw[g] = None
        kw.pop("hw", None)
        return kw

    def _rung_env(self, rung: _Rung) -> _EnvPin:
        if not rung.degraded:
            return _EnvPin(None)
        from .common import vmem_budget_bytes
        return _EnvPin(max(vmem_budget_bytes() // 2, 1))

    def _rung_key(self, rung: _Rung):
        kw = self._rung_call_kwargs(rung)
        kw.pop("use_cache", None)
        return _plan.plan_signature(
            *self._args, hw=self._kwargs.get("hw", _plan.pm.TPU_V5E_BF16),
            **kw)[0]

    def _note_failure(self, rung: _Rung, err: GuardedExecutionError,
                      stage: str) -> None:
        with self._rung_env(rung):
            key = self._rung_key(rung)
        _plan.note_plan_failure(key, err.cause, rung.label(self._start),
                                stage=stage)
        self.history.append({"rung": rung.label(self._start),
                             "cause": err.cause,
                             "error": str(err)[:200]})
        _events.record("guard_failure", cause=err.cause,
                       rung=rung.label(self._start), stage=stage,
                       error=str(err)[:200])

    def _advance(self, rung: _Rung) -> None:
        self._idx += 1
        if self._idx >= len(self._rungs):
            raise GuardedExecutionError(
                "degradation ladder exhausted (no rung survived); see "
                "plan_cache_stats() and repro.core.events for the record")
        _plan.record_fallback()
        _events.record("guard_fallback", frm=rung.label(self._start),
                       to=self._rungs[self._idx].label(self._start))

    def _build_current(self) -> None:
        """Build the plan for the current rung, advancing past rungs whose
        build fails or whose signature is already known-bad."""
        while True:
            rung = self._rungs[self._idx]
            with self._rung_env(rung):
                key = self._rung_key(rung)
                neg = _plan.failed_plan(key)
                if neg is not None:
                    _events.record("guard_skip", rung=rung.label(self._start),
                                   cause=neg["cause"])
                    self._idx += 1
                    if self._idx >= len(self._rungs):
                        raise GuardedExecutionError(
                            "degradation ladder exhausted: every rung is "
                            "negative-cached; clear_plan_cache() to retry")
                    continue
                try:
                    self._plan = _plan.stencil_plan(
                        *self._args,
                        hw=self._kwargs.get("hw", _plan.pm.TPU_V5E_BF16),
                        **self._rung_call_kwargs(rung))
                    return
                except Exception as exc:  # noqa: BLE001 -- classified below
                    err = classify_failure(exc, stage="build",
                                           backend=rung.label(self._start))
                    self._note_failure(rung, err, stage="build")
                    self._advance(rung)

    # -- introspection --------------------------------------------------
    @property
    def plan(self):
        return self._plan

    @property
    def backend(self) -> str:
        return self._plan.backend

    @property
    def degraded(self) -> bool:
        return self._idx > 0

    @property
    def rung(self) -> str:
        return self._rungs[self._idx].label(self._start)

    @property
    def grid_shape(self):
        return self._plan.grid_shape

    @property
    def batch(self):
        return self._plan.batch

    @property
    def input_shape(self):
        return self._plan.input_shape

    @property
    def decision(self):
        return self._plan.decision

    def explain(self) -> str:
        lines = [self._plan.explain()]
        if self.degraded:
            lines.append(f"  guard    : DEGRADED to rung {self.rung!r} "
                         f"after {len(self.history)} failure(s)")
            for h in self.history:
                lines.append(f"    - {h['rung']}: {h['cause']} "
                             f"({h['error'][:80]})")
        else:
            lines.append("  guard    : clean (no degradation)")
        return "\n".join(lines)

    def __repr__(self):
        return (f"GuardedPlan(rung={self.rung!r}, degraded={self.degraded}, "
                f"failures={len(self.history)})")

    # -- execution ------------------------------------------------------
    def _checked_rerun(self, x):
        """Re-run one step through the checked reference backend (the
        watchdog's recovery path -- never passes through fault hooks)."""
        if self._checked is None:
            kw = dict(self._kwargs)
            kw.pop("hw", None)
            kw.pop("use_cache", None)
            for g in ("tile_m", "tile_n", "h_block", "z_slab", "z_block",
                      "w_tile", "w_block"):
                kw.pop(g, None)
            kw["backend"] = "reference"
            self._checked = _plan.stencil_plan(
                *self._args, hw=self._kwargs.get("hw", _plan.pm.TPU_V5E_BF16),
                **kw)
        return self._checked(x)

    def __call__(self, x: jax.Array) -> jax.Array:
        if tuple(x.shape) != self._plan.input_shape:
            # caller bug, not a kernel failure: propagate raw
            return self._plan(x)
        tracing = isinstance(x, jax.core.Tracer)
        while True:
            rung = self._rungs[self._idx]
            try:
                y = self._plan(x)
                if not tracing:
                    y = _faults.corrupt_output(y)
                    jax.block_until_ready(y)
            except Exception as exc:  # noqa: BLE001 -- classified below
                err = classify_failure(exc, stage="execute",
                                       backend=rung.label(self._start))
                self._note_failure(rung, err, stage="execute")
                self._advance(rung)
                self._build_current()
                continue
            if self.watchdog and not tracing:
                if not bool(jnp.isfinite(y).all()):
                    err = NumericalFaultError(
                        f"[numerical] NaN/Inf in step output "
                        f"(backend {self.backend!r})",
                        backend=rung.label(self._start), stage="execute")
                    self._note_failure(rung, err, stage="execute")
                    _events.record("guard_watchdog",
                                   rung=rung.label(self._start),
                                   action="checked_rerun")
                    y = self._checked_rerun(x)
                    # demote for FUTURE calls; this step already recovered
                    self._advance(rung)
                    self._build_current()
            return y

    def step(self, x: jax.Array) -> jax.Array:
        return self(x)

    def run(self, x: jax.Array, n_steps: int) -> jax.Array:
        if n_steps < 0:
            raise ValueError(f"n_steps must be >= 0, got {n_steps}")
        for _ in range(n_steps):
            x = self(x)
        return x


def guarded_stencil_plan(spec_or_weights, grid_shape, dtype, t: int = 1,
                         *, watchdog: Optional[bool] = None,
                         **kwargs) -> GuardedPlan:
    """Build a :class:`GuardedPlan`: ``stencil_plan`` arguments plus
    ``watchdog`` (None = the ``REPRO_NAN_WATCHDOG`` env flag).

    Raw argument errors (bad ``t``, rank mismatch, unknown backend) raise
    immediately and unguarded -- the ladder only absorbs *kernel*
    failures, never caller bugs.

    ``batch=B`` plans are guarded per-batch (DESIGN.md §12): a failing
    rung demotes the WHOLE bucket -- every request in it -- and the
    degraded rung re-executes the full batched input, so no request is
    ever answered from a half-failed launch.  The ``batch``/``batch_mode``
    kwargs ride through every rung unchanged (only geometry pins are
    dropped on the degraded rung)."""
    # the raw-argument gate: validates before any rung is attempted
    _plan.plan_signature(spec_or_weights, grid_shape, dtype, t,
                         **{k: v for k, v in kwargs.items()
                            if k != "use_cache"})
    return GuardedPlan((spec_or_weights, tuple(int(n) for n in grid_shape),
                        dtype, t), kwargs, watchdog=watchdog)
