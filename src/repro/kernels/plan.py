"""StencilPlan: compile-once execution plans for the stencil runtime.

The paper's decision procedure (§4.1 criteria) is analytic -- it depends
only on (spec, t, dtype, hardware), never on the grid values -- so a
serving deployment (ROADMAP north star: millions of steps over a fixed
grid/spec) should run it ONCE.  ``stencil_plan`` does exactly that:

  * spec inference from dense weights (or an explicit ``StencilSpec``),
  * backend selection (``repro.core.selector.select_backend``, enumerating
    the backend registry's priced candidates),
  * strip/tile sizing and weight preprocessing (fused-kernel composition,
    tiling validation) inside the chosen backend's ``build`` hook,
  * halo-exchange planning when a device ``mesh`` is given,

then returns a :class:`StencilPlan` whose ``plan(x)`` / ``plan.step(x)`` /
``plan.run(x, n)`` execute with zero re-analysis -- the executable is a
single jitted callable, so repeated calls hit XLA's compile cache and
never re-enter selection, sizing, or weight composition.

Plans are cached process-wide, keyed on the full execution signature
(weights digest, grid shape, dtype, t, hardware, tiling, batch axis,
interpret, compute dtype, sharding, backend override) with hit/miss
counters (:func:`plan_cache_stats`).  ``repro.kernels.ops.stencil_apply``
survives as a thin wrapper that builds-or-fetches a plan per call.

``stencil_plan(..., batch=B)`` folds a leading batch axis through the
kernels (DESIGN.md §12): one plan invocation advances ``B`` independent
grids of the SAME geometry, bitwise-equal to a loop of ``B`` unbatched
invocations.  The serving engine (``repro.serve``) is the intended
client -- it coalesces queued requests by plan signature and dispatches
one batched launch per bucket.  Cache mutation is lock-protected: the
engine builds and fetches plans from worker threads.
"""
from __future__ import annotations

import hashlib
import threading
import time
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np

from repro.core import perfmodel as pm
from repro.core.selector import Decision, select_backend
from repro.stencil.boundary import (BoundaryLike, boundary_label,
                                    is_periodic, resolve_boundary)
from repro.stencil.spec import StencilSpec
from repro.stencil.weights import jacobi_weights
from . import registry


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def spec_from_weights(weights) -> StencilSpec:
    """Infer (shape, d, r) from a dense kernel's support."""
    w = np.asarray(weights)
    radius = (w.shape[0] - 1) // 2
    dim = w.ndim
    box_points = np.count_nonzero(w)
    star_points = 2 * dim * radius + 1
    shape = "star" if box_points <= star_points else "box"
    return StencilSpec(shape, dim, radius)


def decide(
    spec: StencilSpec, t: int, dtype_bytes: int,
    hw: pm.HardwareSpec = pm.TPU_V5E_BF16,
    tile_n: int = 128, strip_m: int = 128,
    h_block: Optional[int] = None,
    z_slab: Optional[int] = None,
    z_block: Optional[int] = None,
    w_tile: Optional[int] = None,
    w_block: Optional[int] = None,
    use_sparse_unit: bool = False,
    boundary: BoundaryLike = None,
) -> Decision:
    """THE decision path: plan building, ``stencil_apply(backend="auto")``
    and ``ops.explain`` all consult this one function, so they can never
    disagree about the priced ``Decision``.  ``z_slab``/``z_block`` matter
    only for 3D specs (the halo-plane substrate's depth geometry);
    ``w_tile``/``w_block`` price the column-tiled W substrate
    (DESIGN.md §10; ``None``/0 = full width); ``use_sparse_unit`` admits
    the sparse-compacted backends as priced candidates (DESIGN.md §14);
    ``boundary`` (DESIGN.md §15) is recorded in the decision's reason --
    the in-kernel fills are FLOP-free, so it never moves the pricing."""
    return select_backend(spec, t, dtype_bytes=dtype_bytes, hw=hw,
                          tile_n=tile_n, strip_m=strip_m, h_block=h_block,
                          z_slab=z_slab, z_block=z_block,
                          w_tile=w_tile, w_block=w_block,
                          use_sparse_unit=use_sparse_unit,
                          boundary=boundary)


class StencilPlan:
    """A compiled, reusable stencil execution plan.

    Built by :func:`stencil_plan`; calling the plan advances the grid ``t``
    time steps.  Attributes of interest:

      * ``decision``  -- the priced :class:`Decision` (what "auto" picks and
        why), always populated, even under a backend override;
      * ``backend``   -- the backend the plan actually executes;
      * ``halo_plan`` -- dict describing the halo-exchange schedule
        (distributed plans only, else ``None``);
      * ``build_time_s`` -- host seconds spent building (selection, sizing,
        weight composition; excludes XLA compilation, which happens on the
        first call);
      * ``fn``        -- the underlying jitted callable.
    """

    def __init__(self, *, spec, weights, grid_shape, dtype, t, hw, backend,
                 decision, fn, tile_m, tile_n, interpret, compute_dtype,
                 mesh=None, shard_spec=None, dist_mode=None, halo_plan=None,
                 key=None, build_time_s=0.0, batch=None, batch_mode=None,
                 ctx=None, boundary=None):
        self.spec = spec
        self.weights = weights
        self.grid_shape = grid_shape
        #: Resolved per-axis boundary modes (DESIGN.md §15); ``None`` =
        #: all periodic (the historical plans).
        self.boundary = boundary
        self.batch = batch
        self.batch_mode = batch_mode
        self.dtype = dtype
        self.t = t
        self.hw = hw
        self.backend = backend
        self.decision = decision
        self.fn = fn
        self.tile_m = tile_m
        self.tile_n = tile_n
        self.interpret = interpret
        self.compute_dtype = compute_dtype
        self.mesh = mesh
        self.shard_spec = shard_spec
        self.dist_mode = dist_mode
        self.halo_plan = halo_plan
        self.key = key
        self.build_time_s = build_time_s
        #: The registry PlanContext the plan was built from (None for
        #: plans reconstructed without one); lets the auditor re-derive
        #: the declared launch structure of an existing plan.
        self.ctx = ctx
        #: repro.audit.AuditReport attached at build time when auditing
        #: is enabled (``stencil_plan(..., audit=True)`` / REPRO_AUDIT=1);
        #: None otherwise.  Cached plans keep the report of their build.
        self.audit_report = None

    # -- execution ------------------------------------------------------
    @property
    def input_shape(self) -> Tuple[int, ...]:
        """The array shape one invocation consumes: ``grid_shape`` for an
        unbatched plan, ``(batch,) + grid_shape`` for a batched one."""
        if self.batch is None:
            return self.grid_shape
        return (self.batch,) + self.grid_shape

    def __call__(self, x: jax.Array) -> jax.Array:
        if tuple(x.shape) != self.input_shape:
            raise ValueError(
                f"plan was built for input {self.input_shape} "
                f"(grid {self.grid_shape}, batch {self.batch}), got "
                f"{x.shape}; build a new plan for a new geometry")
        return self.fn(x)

    def step(self, x: jax.Array) -> jax.Array:
        """Alias for ``plan(x)``: one invocation = ``t`` time steps."""
        return self(x)

    def run(self, x: jax.Array, n_steps: int) -> jax.Array:
        """``n_steps`` plan invocations (``n_steps * t`` time steps)."""
        if n_steps < 0:
            raise ValueError(f"n_steps must be >= 0, got {n_steps}")
        for _ in range(n_steps):
            x = self(x)
        return x

    # -- introspection --------------------------------------------------
    def explain(self) -> str:
        """Human-readable account of what the plan does and why."""
        d = self.decision
        lines = [
            f"StencilPlan {self.spec.name} t={self.t} grid={self.grid_shape} "
            + ("" if self.batch is None
               else f"batch={self.batch} ({self.batch_mode}) ")
            + f"dtype={np.dtype(self.dtype).name} on {self.hw.name}",
            f"  executes : {self.backend}"
            + ("" if self.backend == d.backend
               else f" (override; auto would pick {d.backend})"),
            f"  scenario : {d.scenario}",
            f"  speedup  : {d.predicted_speedup:.2f}x (best matrix vs vector)",
            f"  reason   : {d.reason}",
            "  candidates (effective FLOP/s): "
            + ", ".join(f"{k}={v:.3g}" for k, v in d.candidates.items()),
        ]
        if self.boundary is not None and not is_periodic(self.boundary):
            lines.insert(2, f"  boundary : {boundary_label(self.boundary)}")
        if self.halo_plan is not None:
            hp = self.halo_plan
            line = (f"  halo plan: mode={hp['mode']} depth={hp['halo_depth']} "
                    f"exchanges/call={hp['exchanges_per_call']} "
                    f"bytes/shard/call={hp['halo_bytes_per_call']}")
            if "interior_fraction" in hp:
                line += (" overlap: interior_fraction="
                         f"{hp['interior_fraction']:.3f}")
            lines.append(line)
        return "\n".join(lines)

    def __repr__(self) -> str:
        return (f"StencilPlan({self.spec.name}, t={self.t}, "
                f"grid={self.grid_shape}, backend={self.backend!r}, "
                + ("" if self.batch is None else f"batch={self.batch}, ")
                + f"distributed={self.mesh is not None})")


# ---------------------------------------------------------------------------
# Plan cache: bounded LRU (plans pin weights, jitted executables, and --
# for distributed plans -- the mesh, so a long-running server sweeping
# geometries must not grow without bound).
#
# One re-entrant lock serializes every cache/counter mutation: the serving
# engine (repro.serve.engine) builds and fetches plans from dispatcher
# threads, and the guard ladder mutates the negative registry from
# whichever thread hit the failure.  Plan BUILDING stays outside the lock
# (it traces and jits -- seconds, not microseconds); two threads racing to
# build the same signature both build, the second insert wins, and the
# counters stay consistent (hits + misses == lookups).
# ---------------------------------------------------------------------------
import os
from collections import OrderedDict

_LOCK = threading.RLock()

#: Default maximum cached plans; least-recently-used entries are evicted
#: beyond the bound.  Override per process with the REPRO_PLAN_CACHE_SIZE
#: environment variable (read at every eviction, so tests and long-running
#: servers can retune without reimporting).
PLAN_CACHE_MAX = 512

_CACHE: "OrderedDict" = OrderedDict()
_STATS = {"hits": 0, "misses": 0,
          # guard-layer counters (repro.kernels.guard): plan builds that
          # raised, plan executions that raised, degradation-ladder moves,
          # and negative-cache short-circuits.  All zero unless something
          # actually failed -- asserted by the clean-run acceptance tests.
          "build_failures": 0, "exec_failures": 0,
          "fallbacks": 0, "negative_hits": 0,
          # static-auditor counters (repro.audit): audited plan builds
          # and total check violations found there.  Violations never
          # block the build -- they count, attach, and surface through
          # plan_cache_stats so CI and the serving loop can gate on them.
          "audits_run": 0, "audit_violations": 0}

#: Negative-result registry: signature key -> {"cause", "backend", "stamp"}.
#: A signature lands here when its build/execution failed, so the guard
#: ladder short-circuits repeat failures straight past the known-bad rung
#: without re-attempting the (possibly slow) doomed compile.  Entries
#: expire after ``plan_cache_max()`` cache churn -- a transient failure
#: (e.g. memory pressure) must not blacklist a signature forever.
_NEGATIVE: "OrderedDict" = OrderedDict()
_churn = 0  # total successful + negative insertions, the expiry clock


def plan_cache_max() -> int:
    """The effective LRU bound: ``REPRO_PLAN_CACHE_SIZE`` if set (must be a
    positive integer), else :data:`PLAN_CACHE_MAX`."""
    from repro.core.envutil import env_int
    return env_int("REPRO_PLAN_CACHE_SIZE", PLAN_CACHE_MAX, minimum=1)


def plan_cache_stats() -> dict:
    """Cache + guard counters: hits/misses/size plus ``build_failures``,
    ``exec_failures``, ``fallbacks``, ``negative_hits``, ``negative_size``.
    The snapshot is atomic -- taken under the cache lock."""
    with _LOCK:
        out = dict(_STATS)
        out["size"] = len(_CACHE)
        out["negative_size"] = len(_NEGATIVE)
    return out


def clear_plan_cache() -> None:
    global _churn
    with _LOCK:
        _CACHE.clear()
        _NEGATIVE.clear()
        _churn = 0
        for k in _STATS:
            _STATS[k] = 0


def _tick_churn() -> None:
    """Advance the expiry clock and drop negative entries older than one
    full cache turnover (``plan_cache_max()`` insertions).  Callers must
    hold ``_LOCK``."""
    global _churn
    _churn += 1
    bound = plan_cache_max()
    while _NEGATIVE:
        stamp = next(iter(_NEGATIVE.values()))["stamp"]
        if _churn - stamp <= bound:
            break
        _NEGATIVE.popitem(last=False)


def note_plan_failure(key, cause: str, backend: str,
                      stage: str = "build") -> None:
    """Record a failed signature in the negative registry (guard layer).

    The failed plan itself is evicted from the LRU -- a failed build or a
    plan whose execution raised must never be served again."""
    with _LOCK:
        _CACHE.pop(key, None)
        _STATS["build_failures" if stage == "build" else "exec_failures"] += 1
        _NEGATIVE[key] = {"cause": cause, "backend": backend, "stamp": _churn}
        _NEGATIVE.move_to_end(key)
        _tick_churn()


def failed_plan(key):
    """The negative entry for ``key`` if present and unexpired, else None.
    A hit counts toward ``negative_hits`` -- it means the guard skipped a
    known-doomed rung."""
    with _LOCK:
        entry = _NEGATIVE.get(key)
        if entry is None:
            return None
        if _churn - entry["stamp"] > plan_cache_max():
            del _NEGATIVE[key]
            return None
        _STATS["negative_hits"] += 1
        return dict(entry)


def discard_plan(key) -> bool:
    """Evict ``key`` from the plan LRU (no-op if absent)."""
    with _LOCK:
        return _CACHE.pop(key, None) is not None


def record_fallback() -> None:
    """One degradation-ladder move (guard layer bookkeeping)."""
    with _LOCK:
        _STATS["fallbacks"] += 1


#: dtype -> canonical name memo.  ``np.dtype(dt).name`` walks numpy's
#: dtype-printing machinery (~5us); on the serving submit path that is
#: paid per REQUEST, so the handful of dtypes a process ever sees are
#: cached.  Keys are the raw ``dt`` arguments (dtype objects, scalar
#: types, strings -- all hashable and all stable aliases of their name).
_DTYPE_NAMES: Dict = {}


def _dtype_name(dt) -> str:
    name = _DTYPE_NAMES.get(dt)
    if name is None:
        name = _DTYPE_NAMES[dt] = np.dtype(dt).name
    return name


def _weights_key(w: np.ndarray) -> Tuple:
    digest = hashlib.sha1(np.ascontiguousarray(w).tobytes()).hexdigest()
    return (w.shape, _dtype_name(w.dtype), digest)


def _dtype_key(dt) -> str:
    return _dtype_name(dt)


# ---------------------------------------------------------------------------
# Plan construction
# ---------------------------------------------------------------------------
#: How a batched plan folds its leading batch axis (DESIGN.md §12):
#:   "vmap" -- jax.vmap over the single-grid runner (Pallas prepends a
#:            batch grid dimension: one launch covers the batch);
#:   "map"  -- jax.lax.map (a scanned loop of the single-grid runner
#:            inside ONE jitted computation: per-request VMEM working set
#:            identical to the unbatched plan, dispatch paid once);
#:   "auto" -- "map" under interpret mode (the scan amortizes Python
#:            dispatch, which dominates emulated kernels), "vmap" when
#:            compiling for real hardware (the batched grid dimension is
#:            free there).
#: Both are bitwise-equal to a loop of unbatched plans -- the equivalence
#: sweep in tests/test_serve_batch.py asserts it per backend/dtype/rank.
BATCH_MODES = ("auto", "vmap", "map")


def _resolve_batch_mode(batch_mode: str, interpret: bool) -> str:
    if batch_mode not in BATCH_MODES:
        raise ValueError(f"batch_mode must be one of {BATCH_MODES}, "
                         f"got {batch_mode!r}")
    if batch_mode == "auto":
        return "map" if interpret else "vmap"
    return batch_mode


def plan_signature(
    spec_or_weights: Union[StencilSpec, np.ndarray],
    grid_shape: Sequence[int],
    dtype,
    t: int = 1,
    *,
    hw: pm.HardwareSpec = pm.TPU_V5E_BF16,
    mesh=None,
    shard_spec: Optional[Sequence[Optional[str]]] = None,
    dist_mode: str = "fused",
    backend: Optional[str] = None,
    tile_m: Optional[int] = None,
    tile_n: Optional[int] = None,
    h_block: Optional[int] = None,
    z_slab: Optional[int] = None,
    z_block: Optional[int] = None,
    w_tile: Optional[int] = None,
    w_block: Optional[int] = None,
    batch: Optional[int] = None,
    batch_mode: str = "auto",
    interpret: Optional[bool] = None,
    compute_dtype=None,
    use_sparse_unit: bool = False,
    boundary: BoundaryLike = None,
) -> Tuple:
    """Validate plan arguments and return ``(key, weights, grid_shape,
    interpret)`` -- the deterministic cache signature WITHOUT building.

    This is the raw-argument gate: genuine user errors (bad ``t``, rank
    mismatch, unknown backend) raise here, unguarded, so the guard layer
    never mistakes a caller bug for a kernel failure.  The key is pure --
    it depends only on the arguments plus the process env (VMEM budget,
    registry generation), never on device state -- which is what lets
    every shard of a distributed mesh agree on the same fallback rung
    without communicating.
    """
    if t < 1:
        raise ValueError(f"fusion depth must be >= 1, got {t}")
    if batch is not None:
        if int(batch) < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        batch = int(batch)
        if mesh is not None:
            raise ValueError(
                "batched plans do not compose with distributed meshes yet; "
                "shard the request stream across hosts instead "
                "(repro.serve coalesces per host)")
    if backend is not None:
        registry.get_backend(backend)          # fail fast on unknown names
    if mesh is not None and shard_spec is None:
        raise ValueError("a mesh-parameterized plan needs shard_spec "
                         "(one mesh-axis name per grid dim, None=unsharded)")

    if isinstance(spec_or_weights, StencilSpec):
        weights = jacobi_weights(spec_or_weights)
    else:
        weights = np.asarray(spec_or_weights)
    grid_shape = tuple(int(n) for n in grid_shape)
    if len(grid_shape) != weights.ndim:
        raise ValueError(
            f"grid rank {len(grid_shape)} != kernel rank {weights.ndim}; "
            "the plan's grid_shape must match the stencil dimensionality")
    # Resolved per-axis modes land in the key: a reflect×periodic plan
    # must never alias the periodic plan of the same geometry.  Unknown
    # modes / length mismatches raise here, in the caller's frame.
    boundary_key = resolve_boundary(boundary, len(grid_shape))
    if interpret is None:
        interpret = _default_interpret()
    # The RESOLVED fold mode lands in the key (pure: a function of the
    # arguments + resolved interpret), so "auto" on CPU and an explicit
    # "map" share one plan while "vmap" plans never alias them.
    batch_key = None if batch is None \
        else (batch, _resolve_batch_mode(batch_mode, interpret))

    shard_key = None
    if mesh is not None:
        shard_key = (id(mesh), tuple(shard_spec), dist_mode)
    # registry.generation() invalidates plans whose selection (or builder,
    # under overwrite=True) predates a registry change -- a newly priced
    # backend must win future auto plans, not be masked by the cache.
    # The effective VMEM budget is part of the key: auto geometry depends
    # on it, so retuning REPRO_VMEM_BUDGET must never serve stale plans.
    from .common import vmem_budget_bytes
    key = (_weights_key(weights), grid_shape, _dtype_key(dtype), t, hw,
           shard_key, backend, tile_m, tile_n, h_block, z_slab, z_block,
           w_tile, w_block, batch_key, vmem_budget_bytes(), interpret,
           None if compute_dtype is None else _dtype_key(compute_dtype),
           bool(use_sparse_unit), boundary_key, registry.generation())
    return key, weights, grid_shape, interpret


def stencil_plan(
    spec_or_weights: Union[StencilSpec, np.ndarray],
    grid_shape: Sequence[int],
    dtype,
    t: int = 1,
    *,
    hw: pm.HardwareSpec = pm.TPU_V5E_BF16,
    mesh=None,
    shard_spec: Optional[Sequence[Optional[str]]] = None,
    dist_mode: str = "fused",
    backend: Optional[str] = None,
    tile_m: Optional[int] = None,
    tile_n: Optional[int] = None,
    h_block: Optional[int] = None,
    z_slab: Optional[int] = None,
    z_block: Optional[int] = None,
    w_tile: Optional[int] = None,
    w_block: Optional[int] = None,
    batch: Optional[int] = None,
    batch_mode: str = "auto",
    interpret: Optional[bool] = None,
    compute_dtype=None,
    use_sparse_unit: bool = False,
    use_cache: bool = True,
    audit: Optional[bool] = None,
    boundary: BoundaryLike = None,
) -> StencilPlan:
    """Build (or fetch from cache) a compiled stencil execution plan.

    Args:
      spec_or_weights: a dense ``(2r+1)^d`` kernel, or a ``StencilSpec``
        (then the deterministic Jacobi weights of that spec are used).
      grid_shape: global grid shape the plan is specialized to; its rank
        must match the kernel's (1D, 2D and 3D grids are supported --
        DESIGN.md §9).
      dtype: grid dtype.
      t: fusion depth -- time steps advanced per plan invocation.
      hw: hardware model consulted by the selector.
      mesh / shard_spec: when given, the plan drives the distributed
        halo-exchange stepper; ``shard_spec`` names one mesh axis per grid
        dim (``None`` entries = unsharded dims).  ``dist_mode`` is
        ``"fused"`` (one depth-``t*r`` exchange per invocation),
        ``"stepwise"`` (``t`` depth-``r`` exchanges) or ``"overlap"``
        (stepwise's schedule with the interior update overlapping each
        in-flight exchange; needs exactly one sharded dim).
      backend: override the selector's choice with any registered backend
        name (``repro.kernels.registry.registered_backends()``).
      tile_m/tile_n: explicit strip height / column-tile width (``None`` =
        auto-sized exactly as the kernels themselves would).
      h_block: halo sub-block height of the strip substrate (``None`` =
        auto, ``0`` = whole-strip/whole-slab foil); part of the cache key.
      z_slab/z_block: 3D grids only -- slab depth and halo-plane block of
        the halo-plane substrate (``None`` = auto); part of the cache key.
      w_tile/w_block: column-tiled W substrate (DESIGN.md §10; ``None`` =
        auto -- full width whenever it fits the VMEM budget, ``0`` pins
        full width); part of the cache key, as is the effective VMEM
        budget (``REPRO_VMEM_BUDGET``) the auto sizing consulted.
      batch: when given, the plan consumes ``(batch,) + grid_shape`` and
        advances ``batch`` independent grids per invocation, bitwise-equal
        to a loop of unbatched plans (DESIGN.md §12).  Geometry sizing and
        selection stay per-grid -- the batch axis never widens the VMEM
        working set of a "map" plan.  Part of the cache key.
      batch_mode: how the batch axis folds -- see :data:`BATCH_MODES`
        ("auto" = "map" under interpret, "vmap" compiled).
      interpret: Pallas interpret mode; ``None`` = off-TPU default.
      use_sparse_unit: admit the sparse-compacted backends
        (``sparse_matmul``/``fused_sparse_matmul``, DESIGN.md §14) as
        priced auto candidates; part of the cache key.
      boundary: per-axis boundary modes (DESIGN.md §15) -- one of
        ``periodic | zero | reflect | replicate`` per grid axis (a bare
        string applies to every axis; ``None`` entries and ``None``
        itself mean periodic, the historical behavior bit for bit), e.g.
        ``boundary=("reflect", "periodic")``.  Part of the cache key.
      use_cache: bypass the process-wide plan cache when ``False``.
      audit: run the static auditor (repro.audit) over the built plan and
        attach its report as ``plan.audit_report`` (``None`` defers to the
        ``REPRO_AUDIT`` env flag).  Violations never fail the build: they
        bump the ``audit_violations`` counter in :func:`plan_cache_stats`
        and surface in the attached report.  Not part of the cache key --
        a cached plan keeps the report of the build that audited it.
    """
    key, weights, grid_shape, interpret = plan_signature(
        spec_or_weights, grid_shape, dtype, t, hw=hw, mesh=mesh,
        shard_spec=shard_spec, dist_mode=dist_mode, backend=backend,
        tile_m=tile_m, tile_n=tile_n, h_block=h_block, z_slab=z_slab,
        z_block=z_block, w_tile=w_tile, w_block=w_block,
        batch=batch, batch_mode=batch_mode,
        interpret=interpret, compute_dtype=compute_dtype,
        use_sparse_unit=use_sparse_unit, boundary=boundary)
    modes = resolve_boundary(boundary, len(grid_shape))
    with _LOCK:
        if use_cache and key in _CACHE:
            _STATS["hits"] += 1
            _CACHE.move_to_end(key)
            return _CACHE[key]
        _STATS["misses"] += 1

    t0 = time.perf_counter()
    spec = spec_from_weights(weights)
    # Selection prices the geometry the kernels will actually resolve for
    # this grid (fused-regime halo t*r), so the read-amplification term in
    # the decision matches the substrate that runs; tile_n keeps its
    # historical 128 pricing default when unpinned.
    from .common import resolve_substrate_geom
    geom_px = resolve_substrate_geom(
        grid_shape, t * spec.radius, np.dtype(dtype).itemsize,
        tile_m, h_block, z_slab, z_block, w_tile, w_block)
    decision = decide(
        spec, t, dtype_bytes=np.dtype(dtype).itemsize, hw=hw,
        tile_n=tile_n if tile_n is not None else 128,
        strip_m=geom_px.strip_m, h_block=geom_px.h_block,
        z_slab=geom_px.z_slab if geom_px.dim == 3 else None,
        z_block=geom_px.z_block if geom_px.dim == 3 else None,
        w_tile=geom_px.w_tile if geom_px.dim >= 2 else None,
        w_block=geom_px.w_block if geom_px.dim >= 2 else None,
        use_sparse_unit=use_sparse_unit,
        boundary=modes,
    )
    exec_backend = backend if backend is not None else decision.backend

    ctx = registry.PlanContext(
        spec=spec, weights=weights, grid_shape=grid_shape,
        dtype=np.dtype(dtype), t=t, tile_m=tile_m, tile_n=tile_n,
        interpret=interpret, compute_dtype=compute_dtype, h_block=h_block,
        z_slab=z_slab, z_block=z_block, w_tile=w_tile, w_block=w_block,
        boundary=modes,
    )

    halo_plan = None
    resolved_mode = None
    if mesh is None:
        run = registry.get_backend(exec_backend).build(ctx)
        if batch is not None:
            from .common import fold_batch
            resolved_mode = _resolve_batch_mode(batch_mode, interpret)
            run = fold_batch(run, resolved_mode)
        fn = jax.jit(run)
    else:
        fn, halo_plan = _build_distributed(
            mesh, tuple(shard_spec), dist_mode, ctx, exec_backend)

    plan = StencilPlan(
        spec=spec, weights=weights, grid_shape=grid_shape,
        dtype=np.dtype(dtype), t=t, hw=hw, backend=exec_backend,
        decision=decision, fn=fn, tile_m=tile_m, tile_n=tile_n,
        interpret=interpret, compute_dtype=compute_dtype, mesh=mesh,
        shard_spec=None if shard_spec is None else tuple(shard_spec),
        dist_mode=dist_mode if mesh is not None else None,
        halo_plan=halo_plan, key=key,
        build_time_s=time.perf_counter() - t0,
        batch=None if batch is None else int(batch),
        batch_mode=resolved_mode,
        ctx=ctx, boundary=modes,
    )
    from repro.core.envutil import env_flag
    if audit if audit is not None else env_flag("REPRO_AUDIT"):
        _attach_audit(plan, ctx, exec_backend, decision, geom_px,
                      t * spec.radius)
    if use_cache:
        with _LOCK:
            # Read (and validate) the bound BEFORE inserting: a malformed
            # REPRO_PLAN_CACHE_SIZE must never leave the cache growing with
            # eviction disabled.
            bound = plan_cache_max()
            _CACHE[key] = plan
            while len(_CACHE) > bound:
                _CACHE.popitem(last=False)
            _tick_churn()
    return plan


def _attach_audit(plan, ctx, exec_backend, decision, geom_px,
                  priced_halo) -> None:
    """Run the static auditor over the freshly built plan and attach the
    report (repro.audit, DESIGN.md §13).  Never raises: violations count
    into the plan stats and live in ``plan.audit_report``; an auditor
    crash records itself as a violation rather than failing the build.
    Distributed and batched plans wrap the launch in collectives /
    batch folds the block-level auditor does not model, so they attach
    an exempt report instead of false violations.
    """
    from repro import audit as _audit

    try:
        if plan.mesh is not None or plan.batch is not None:
            report = _audit.AuditReport(
                backend=exec_backend, grid_shape=tuple(ctx.grid_shape),
                t=ctx.t, dtype=str(np.dtype(ctx.dtype)),
                exempt=("distributed stepper wraps the launch in halo "
                        "collectives" if plan.mesh is not None
                        else "batch fold wraps the launch"))
        else:
            report = _audit.audit_context(ctx, exec_backend)
            report.checks.append(_audit.audit_reason_read_amp(
                decision.reason, tuple(ctx.grid_shape), geom_px,
                priced_halo, np.dtype(ctx.dtype).itemsize))
    except Exception as e:  # pragma: no cover - auditor must not break builds
        report = _audit.AuditReport(
            backend=exec_backend, grid_shape=tuple(ctx.grid_shape),
            t=ctx.t, dtype=str(np.dtype(ctx.dtype)),
            checks=[_audit.AuditCheck("audit/crashed", False,
                                      actual=repr(e))])
    plan.audit_report = report
    with _LOCK:
        _STATS["audits_run"] += 1
        _STATS["audit_violations"] += len(report.violations)


def _build_distributed(mesh, axis_names, dist_mode, ctx, exec_backend):
    """Wire the halo-exchange stepper around the chosen local backend."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.stencil.distributed import (halo_bytes_per_step,
                                           make_distributed_stepper,
                                           pallas_local_apply)

    if len(axis_names) != len(ctx.grid_shape):
        raise ValueError(f"shard_spec {axis_names} must name one mesh axis "
                         f"per grid dim of {ctx.grid_shape}")
    local_shape = []
    for n, ax in zip(ctx.grid_shape, axis_names):
        parts = mesh.shape[ax] if ax is not None else 1
        if n % parts:
            raise ValueError(f"grid dim {n} not divisible by mesh axis "
                             f"{ax!r} ({parts} shards)")
        local_shape.append(n // parts)
    local_shape = tuple(local_shape)

    # reference executes through the stepper's built-in jnp local update;
    # every other registered backend plugs in as a Pallas local apply.
    local = None if exec_backend == "reference" else pallas_local_apply(
        exec_backend, interpret=ctx.interpret,
        tile_m=ctx.tile_m, tile_n=ctx.tile_n, h_block=ctx.h_block,
        z_slab=ctx.z_slab, z_block=ctx.z_block,
        w_tile=ctx.w_tile, w_block=ctx.w_block)
    # The LOCAL plan stays periodic whatever ctx.boundary says: the global
    # boundary is realized in the halo extension (mode pads + edge-shard
    # masks), and the kernel's modulo wrap only pollutes the discarded
    # halo ring (DESIGN.md §15).
    stepper = make_distributed_stepper(
        mesh, axis_names, ctx.weights, t=ctx.t, mode=dist_mode,
        local_apply=local, boundary=ctx.boundary)
    sharding = NamedSharding(mesh, P(*axis_names))
    fn = jax.jit(stepper, in_shardings=sharding, out_shardings=sharding)

    r = ctx.radius
    halo_plan = {
        "mode": dist_mode,
        "halo_depth": r * ctx.t if dist_mode == "fused" else r,
        "exchanges_per_call": 1 if dist_mode == "fused" else ctx.t,
        "halo_bytes_per_call": halo_bytes_per_step(
            local_shape, axis_names, r, ctx.t, dist_mode,
            np.dtype(ctx.dtype).itemsize),
        "local_shape": local_shape,
    }
    if dist_mode == "overlap":
        # Fraction of the local block whose update is computed while the
        # exchange is in flight -- the latency-hiding headroom explain()
        # surfaces.
        frac = 1.0
        for m, ax in zip(local_shape, axis_names):
            if ax is not None:
                frac *= max(m - 2 * r, 0) / m
        halo_plan["interior_fraction"] = frac
    return fn, halo_plan
