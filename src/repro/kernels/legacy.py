"""The seed 9-neighbor full-tile halo substrate, kept as a benchmark foil.

This is the original BlockSpec scheme this repo shipped with: one grid cell
per (tile_m, tile_n) output tile, with the SAME input referenced nine times
through shifted ``index_map``s so the Mosaic pipeline streams center + all
eight neighbor tiles HBM->VMEM, even though only halo-wide edges of the
eight neighbors are ever read.  Per output tile that is 9 full tile loads
-- a ~9x read amplification over the ideal 1x (DESIGN.md §3).

The production kernels now live in ``stencil_direct`` / ``stencil_matmul``
on the strip-mined substrate (3 loads per strip).  This module exists so
``benchmarks/traffic.py`` can measure old-vs-new HBM traffic and wall time
on identical problems; do not build new features on it.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .stencil_matmul import build_bands

NEIGHBOR_OFFSETS_2D = [(-1, -1), (-1, 0), (-1, 1),
                       (0, -1), (0, 0), (0, 1),
                       (1, -1), (1, 0), (1, 1)]


def neighbor_in_specs(tile_m: int, tile_n: int, grid_m: int, grid_n: int):
    """Nine BlockSpecs addressing (i+di, j+dj) mod grid for one 2D input."""
    specs = []
    for di, dj in NEIGHBOR_OFFSETS_2D:
        specs.append(
            pl.BlockSpec(
                (tile_m, tile_n),
                functools.partial(
                    lambda i, j, di=di, dj=dj: ((i + di) % grid_m, (j + dj) % grid_n)
                ),
            )
        )
    return specs


def assemble_extended(refs: Sequence, halo: int) -> jax.Array:
    """Build the (tile_m + 2h, tile_n + 2h) halo-extended tile in VMEM.

    ``refs`` are the nine neighbor refs in NEIGHBOR_OFFSETS_2D order.  Only
    the needed edges/corners of the neighbor tiles are read.
    """
    tl, t, tr, l, c, r, bl, b, br = [ref[...] for ref in refs]
    h = halo
    top = jnp.concatenate([tl[-h:, -h:], t[-h:, :], tr[-h:, :h]], axis=1)
    mid = jnp.concatenate([l[:, -h:], c, r[:, :h]], axis=1)
    bot = jnp.concatenate([bl[:h, -h:], b[:h, :], br[:h, :h]], axis=1)
    return jnp.concatenate([top, mid, bot], axis=0)


def _direct_kernel(*refs, weights, t: int, radius: int, out_dtype):
    """refs = 9 neighbor refs + out_ref; weights are host constants."""
    out_ref = refs[-1]
    halo = t * radius
    ext = assemble_extended(refs[:9], halo).astype(jnp.float32)
    k = 2 * radius + 1
    for _ in range(t):
        m = ext.shape[0] - 2 * radius
        n = ext.shape[1] - 2 * radius
        acc = jnp.zeros((m, n), jnp.float32)
        for dy in range(k):
            for dx in range(k):
                w = float(weights[dy, dx])
                if w == 0.0:   # star stencils: skip zero taps at trace time
                    continue
                acc = acc + w * ext[dy : dy + m, dx : dx + n]
        ext = acc
    out_ref[...] = ext.astype(out_dtype)


def stencil_direct_9pt(
    x: jax.Array,
    weights,
    t: int = 1,
    tile_m: int = 128,
    tile_n: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Seed VPU kernel: ``t`` fused steps on the 9-neighbor full-tile scheme."""
    w = np.asarray(weights)
    radius = (w.shape[0] - 1) // 2
    halo = t * radius
    h, wid = x.shape
    tile_m = min(tile_m, h)
    tile_n = min(tile_n, wid)
    _validate_square(x.shape, tile_m, tile_n, halo)
    gm, gn = h // tile_m, wid // tile_n

    kern = functools.partial(
        _direct_kernel, weights=w, t=t, radius=radius, out_dtype=x.dtype
    )
    return pl.pallas_call(
        kern,
        grid=(gm, gn),
        in_specs=neighbor_in_specs(tile_m, tile_n, gm, gn),
        out_specs=pl.BlockSpec((tile_m, tile_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(*([x] * 9))


def _matmul_kernel(*refs, radius: int, out_dtype, compute_dtype):
    # refs: 9 neighbor refs, bands ref, out ref
    out_ref = refs[-1]
    bands_ref = refs[-2]
    ext = assemble_extended(refs[:9], radius)          # (M+2R, N+2R)
    m = ext.shape[0] - 2 * radius
    n = ext.shape[1] - 2 * radius
    k = 2 * radius + 1
    acc = jnp.zeros((m, n), jnp.float32)
    for dy in range(k):
        a = ext[dy : dy + m, :].astype(compute_dtype)          # (M, N+2R)
        b = bands_ref[dy].astype(compute_dtype)                # (N+2R, N)
        acc = acc + jax.lax.dot(a, b, preferred_element_type=jnp.float32)
    out_ref[...] = acc.astype(out_dtype)


def stencil_matmul_9pt(
    x: jax.Array,
    weights,
    tile_m: int = 128,
    tile_n: int = 128,
    interpret: bool = False,
    compute_dtype=None,
) -> jax.Array:
    """Seed MXU kernel: one banded contraction on the 9-neighbor scheme."""
    w = np.asarray(weights)
    radius = (w.shape[0] - 1) // 2
    h, wid = x.shape
    tile_m = min(tile_m, h)
    tile_n = min(tile_n, wid)
    _validate_square(x.shape, tile_m, tile_n, radius)
    gm, gn = h // tile_m, wid // tile_n
    if compute_dtype is None:
        compute_dtype = x.dtype

    bands = jnp.asarray(build_bands(w.astype(np.float32), tile_n))

    kern = functools.partial(
        _matmul_kernel, radius=radius, out_dtype=x.dtype, compute_dtype=compute_dtype
    )
    in_specs = neighbor_in_specs(tile_m, tile_n, gm, gn) + [
        pl.BlockSpec(bands.shape, lambda i, j: (0, 0, 0))
    ]
    return pl.pallas_call(
        kern,
        grid=(gm, gn),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tile_m, tile_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(*([x] * 9), bands)


def _validate_square(shape, tile_m, tile_n, halo):
    """Seed-era tiling constraints (both tile dims bounded by the halo)."""
    h, w = shape
    if h % tile_m or w % tile_n:
        raise ValueError(f"grid {shape} not divisible by tiles ({tile_m},{tile_n})")
    if tile_m < halo or tile_n < halo:
        raise ValueError(
            f"halo {halo} exceeds tile ({tile_m},{tile_n}); "
            "lower fusion depth or enlarge tiles"
        )


def hbm_read_bytes_per_step(shape, tile_m: int, tile_n: int, dtype_bytes: int,
                            bands_shape=None) -> int:
    """Analytic HBM read traffic of one 9-neighbor kernel launch.

    Every output tile streams nine full (tile_m, tile_n) input tiles, so the
    grid is read 9x per step; the banded operand (if any) is re-streamed per
    grid cell.
    """
    h, w = shape
    gm, gn = h // tile_m, w // tile_n
    total = gm * gn * 9 * tile_m * tile_n * dtype_bytes
    if bands_shape is not None:
        total += gm * gn * int(np.prod(bands_shape)) * dtype_bytes
    return total
