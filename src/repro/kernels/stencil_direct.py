"""VPU-path N-D stencil kernel (the "CUDA core" baseline of the paper).

One output cell is a (STRIP_M, N) band (2D) or a (Z_SLAB, STRIP_M, N)
slab-strip (3D), lowered through the shared substrate launchers
(``common.strip_substrate_call`` / ``common.slab_substrate_call``).  On
the sub-blocked substrate (default, DESIGN.md §3/§9) the Pallas grid
walks halo blocks: each grid cell copies one input block into a VMEM
scratch -- the cell's own blocks plus the single ring of neighbor blocks
that can contain halo planes/rows -- and the final cell computes on the
assembled halo-extended region, so HBM reads per step are
(1 + 2*h_block/strip_m) x the grid in 2D and additionally
(1 + 2*z_block/z_slab) x in 3D, instead of 3x/9x (whole neighbor strips/
slabs) or 9x (seed scheme).  The periodic last-axis halo is materialized
in-VMEM by column wrap, and the stencil is an unrolled sum of shifted
slices times scalar taps -- pure element-wise VPU work, accumulated in
f32.  1D grids route through the 2D substrate lifted to (1, N): the
vertical halo is zero, so strips stream only their own rows.

Supports an in-kernel temporal-fusion depth ``t`` (the paper's CUDA-core
temporal fusion, §3.2.2): ``t`` sequential updates on leading-axis halos
of ``t*r``, intermediates living entirely in VMEM => per-point HBM traffic
stays flat while compute scales by t (I = t*K/D).  Because every row of
the extended region is a true global row, the last-axis wrap is re-applied
per step at radius ``r`` -- no 2*t*r horizontal halo is ever carried.
This kernel IS `stencil_fused`'s engine; ``t=1`` is the plain baseline.

``h_block=0`` selects the whole-strip/whole-slab foil substrate (kept for
the ``*_wholestrip`` benchmark foils); both substrates assemble
byte-identical extended regions, so their outputs are bit-for-bit equal.

Grids whose FULL-WIDTH working set exceeds the VMEM budget execute on
the column-tiled substrate (DESIGN.md §10): the grid gains a
(w_tile, w_block) dimension, the x-halo is assembled from neighbor
column blocks instead of the in-VMEM wrap, and the tap-sum CARRIES a
2*t*r-wide x support that shrinks per step (``wrap_x=False``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import (apply_boundary_fills, extend_columns, lift_boundary_1d,
                     resolve_substrate_geom, slab_substrate_call,
                     strip_substrate_call, validate_tiling)
from repro.stencil.boundary import resolve_boundary


def _stencil_steps(cur: jax.Array, edges, weights, t: int, radius: int,
                   modes, wrap_x: bool = True, x_pad: int = 0) -> jax.Array:
    """``t`` unrolled tap-sum updates on a halo-extended f32 region.

    N-D: ``weights`` has ``cur.ndim`` axes; each step consumes the
    per-axis kernel extent on every leading axis.  ``wrap_x`` (the
    full-width substrates, where every row is a complete global row)
    re-extends the last axis at ``radius`` per step under its boundary
    mode (periodic = the historical wrap); ``wrap_x=False`` (the
    column-tiled substrate, DESIGN.md §10 -- rows are partial, no
    re-extension is possible) instead CONSUMES the carried x-halo like a
    leading axis, shrinking the last dim by 2*radius per step.

    Non-periodic launches (``edges`` is not None) re-impose every
    non-periodic axis's boundary values on the current out-of-domain
    halo depth BEFORE each step -- the depth shrinks with the region,
    ``(t-k)*radius`` at step ``k`` -- matching the oracle, which
    re-pads the *updated* field every step (DESIGN.md §15); ``x_pad``
    is the remainder path's right-padding column count, shifting the
    last tile's x fill (the pad tail only feeds sliced-off columns).

    The barrier keeps XLA from fusing the region assembly (refs
    concatenated by the whole substrates, a scratch slice for the
    sub-blocked ones) into the tap sum -- assembly-dependent FMA
    formation would otherwise perturb the last ulp, and the substrates
    are asserted BIT-for-bit equal (tests/test_substrate_strips.py).
    """
    cur = jax.lax.optimization_barrier(cur)
    wshape = weights.shape
    for k in range(t):
        if edges is not None:
            cur = apply_boundary_fills(cur, modes, edges, (t - k) * radius,
                                       x_pad=x_pad, x_tiled=not wrap_x)
        if wrap_x:
            z = extend_columns(cur, radius, modes[-1])  # (..., n + 2r)
            n = cur.shape[-1]
        else:
            z = cur                           # halo carried in the region
            n = cur.shape[-1] - 2 * radius
        lead = tuple(cur.shape[i] - (wshape[i] - 1)
                     for i in range(cur.ndim - 1))
        acc = jnp.zeros(lead + (n,), jnp.float32)
        for idx in np.ndindex(*wshape):
            w = float(weights[idx])
            if w == 0.0:   # star stencils: skip zero taps at trace time
                continue
            sl = tuple(slice(idx[i], idx[i] + lead[i])
                       for i in range(len(lead)))
            acc = acc + w * z[sl + (slice(idx[-1], idx[-1] + n),)]
        cur = acc
    return cur


def stencil_direct(
    x: jax.Array,
    weights,
    t: int = 1,
    tile_m: int = None,
    tile_n: int = None,
    h_block: int = None,
    z_slab: int = None,
    z_block: int = None,
    w_tile: int = None,
    w_block: int = None,
    interpret: bool = False,
    boundary=None,
) -> jax.Array:
    """``t`` fused time steps of an N-D stencil, per-axis boundaries.

    ``boundary`` is a per-axis mode spec (DESIGN.md §15: ``periodic`` |
    ``zero`` | ``reflect`` | ``replicate``; ``None`` = all periodic,
    the historical behavior bit for bit).
    ``weights``: host-side (2r+1)^d ndarray (zeros outside support); the
    grid rank must match ``weights.ndim`` (1, 2 or 3).  ``tile_m`` is the
    strip height and ``h_block`` the halo sub-block height; 3D grids add
    ``z_slab`` (slab depth) and ``z_block`` (halo-plane block depth);
    2D/3D grids add ``w_tile``/``w_block`` (the column-tiled W substrate,
    DESIGN.md §10: ``w_tile=0`` pins full width, ``None`` auto-tiles only
    when full width exceeds the VMEM budget) -- any left ``None``
    (default) is auto-sized via ``resolve_substrate_geom`` (divisors,
    halo-covering, VMEM-budgeted); explicit values are validated
    strictly.  ``h_block=0`` disables sub-blocking (whole-strip 3-load /
    whole-slab 9-load foil substrate).  ``tile_n`` is accepted for
    signature parity with the MXU kernel but unused (the VPU path's only
    column tiling is the substrate's own).
    """
    del tile_n  # the VPU compute never chunks columns
    w = np.asarray(weights)
    if x.ndim != w.ndim:
        raise ValueError(f"grid rank {x.ndim} != kernel rank {w.ndim}")
    if x.ndim == 1:
        # The lifted (1, N) grid admits exactly two h_blocks (0 = foil,
        # 1 = sub-blocked) and never column-tiles; coerce like
        # resolve_substrate_geom's dim-1 rule so kernel-level and
        # plan-level pins can never disagree.  The synthetic row axis is
        # periodic (it has no halo); the real axis keeps its mode.
        hb = h_block if h_block in (None, 0) else 1
        y = stencil_direct(x[None, :], w[None, :], t=t, tile_m=1,
                           h_block=hb, w_tile=0, interpret=interpret,
                           boundary=lift_boundary_1d(boundary))
        return y[0]

    modes = resolve_boundary(boundary, x.ndim)
    radius = (w.shape[-1] - 1) // 2
    halo = t * ((w.shape[0] - 1) // 2)        # 0 for the lifted-1D kernel
    wid = x.shape[-1]
    x_halo = t * radius                       # carried if column-tiled
    geom = resolve_substrate_geom(x.shape, halo, x.dtype.itemsize,
                                  tile_m, h_block, z_slab, z_block,
                                  w_tile, w_block, x_halo)
    validate_tiling(x.shape, geom.strip_m, wid, halo, radius, geom.h_block,
                    geom.z_slab if x.ndim == 3 else None, geom.z_block,
                    geom.w_tile, geom.w_block, x_halo, boundary=modes)
    x_pad = (-wid) % geom.w_tile if geom.w_tile else 0  # remainder path

    def compute(cur, edges):
        return _stencil_steps(cur, edges, w, t, radius, modes,
                              wrap_x=not geom.w_tile, x_pad=x_pad)

    if x.ndim == 3:
        return slab_substrate_call(compute, x, geom, halo, interpret,
                                   x_halo=x_halo if geom.w_tile else 0,
                                   boundary=modes)
    return strip_substrate_call(compute, x, geom.strip_m, geom.h_block,
                                halo, interpret, w_tile=geom.w_tile,
                                w_block=geom.w_block,
                                x_halo=x_halo if geom.w_tile else 0,
                                boundary=modes)
