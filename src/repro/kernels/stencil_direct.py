"""VPU-path 2D stencil kernel (the "CUDA core" baseline of the paper).

One grid cell computes a (STRIP_M, N) output strip: the vertically
halo-extended strip is assembled in VMEM from three neighbor strips (top,
center, bottom -- 3 block loads instead of the seed's 9, DESIGN.md §3),
the periodic horizontal halo is materialized in-VMEM by column wrap, and
the stencil is an unrolled sum of shifted slices times scalar taps -- pure
element-wise VPU work, accumulated in f32.

Supports an in-kernel temporal-fusion depth ``t`` (the paper's CUDA-core
temporal fusion, §3.2.2): ``t`` sequential updates on a vertical halo of
``t*r``, intermediates living entirely in VMEM => per-point HBM traffic
stays 2D while compute scales by t (I = t*K/D).  Because every row of the
extended strip is a true global row, the horizontal wrap is re-applied per
step at radius ``r`` -- no 2*t*r horizontal halo is ever carried.  This
kernel IS `stencil_fused`'s engine; ``t=1`` is the plain baseline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import (assemble_strip, choose_strip, strip_in_specs,
                     validate_tiling, wrap_columns)


def _kernel(top_ref, mid_ref, bot_ref, out_ref, *, weights, t: int,
            radius: int, out_dtype):
    """Three neighbor-strip refs + out_ref; weights are host constants."""
    halo = t * radius
    cur = assemble_strip(top_ref, mid_ref, bot_ref, halo).astype(jnp.float32)
    k = 2 * radius + 1
    n = cur.shape[1]
    for _ in range(t):
        z = wrap_columns(cur, radius)              # (m_cur, n + 2r), periodic
        m = cur.shape[0] - 2 * radius
        acc = jnp.zeros((m, n), jnp.float32)
        for dy in range(k):
            for dx in range(k):
                w = float(weights[dy, dx])
                if w == 0.0:   # star stencils: skip zero taps at trace time
                    continue
                acc = acc + w * z[dy : dy + m, dx : dx + n]
        cur = acc
    out_ref[...] = cur.astype(out_dtype)


def stencil_direct(
    x: jax.Array,
    weights,
    t: int = 1,
    tile_m: int = None,
    tile_n: int = None,
    interpret: bool = False,
) -> jax.Array:
    """``t`` fused time steps of a 2D stencil, periodic boundary.

    ``weights``: host-side (2r+1, 2r+1) ndarray (zeros outside support).
    ``tile_m`` is the strip height -- ``None`` (default) picks one via
    ``choose_strip`` (divisor of H, >= halo, VMEM-budgeted); an explicit
    value is validated strictly.  ``tile_n`` is accepted for signature
    parity with the MXU kernel but unused (the VPU path never column-tiles).
    """
    import numpy as np

    del tile_n  # strips always span the full width
    w = np.asarray(weights)
    radius = (w.shape[0] - 1) // 2
    halo = t * radius
    h, wid = x.shape
    strip_m = choose_strip(h, wid, halo, x.dtype.itemsize) if tile_m is None \
        else min(tile_m, h)
    validate_tiling(x.shape, strip_m, wid, halo, radius)
    gm = h // strip_m

    kern = functools.partial(
        _kernel, weights=w, t=t, radius=radius, out_dtype=x.dtype
    )
    return pl.pallas_call(
        kern,
        grid=(gm,),
        in_specs=strip_in_specs(strip_m, wid, gm),
        out_specs=pl.BlockSpec((strip_m, wid), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, x, x)
