"""VPU-path 2D stencil kernel (the "CUDA core" baseline of the paper).

One output strip is a (STRIP_M, N) band, lowered through the shared
substrate launcher (``common.strip_substrate_call``).  On the sub-blocked
substrate (default, DESIGN.md §3) the Pallas grid is 2D over (strip,
h-block): each grid cell copies one (H_BLOCK, N) input block into a VMEM
scratch -- the strip's own blocks plus ONE halo block of each vertical
neighbor -- and the final cell of the strip computes on the assembled
halo-extended strip, so HBM reads per step are (1 + 2*h_block/strip_m) x
the grid instead of 3x (whole neighbor strips) or 9x (seed scheme).  The
periodic horizontal halo is materialized in-VMEM by column wrap, and the
stencil is an unrolled sum of shifted slices times scalar taps -- pure
element-wise VPU work, accumulated in f32.

Supports an in-kernel temporal-fusion depth ``t`` (the paper's CUDA-core
temporal fusion, §3.2.2): ``t`` sequential updates on a vertical halo of
``t*r``, intermediates living entirely in VMEM => per-point HBM traffic
stays 2D while compute scales by t (I = t*K/D).  Because every row of the
extended strip is a true global row, the horizontal wrap is re-applied per
step at radius ``r`` -- no 2*t*r horizontal halo is ever carried.  This
kernel IS `stencil_fused`'s engine; ``t=1`` is the plain baseline.

``h_block=0`` selects the PR-1 whole-strip 3-load substrate (kept for the
``*_wholestrip`` benchmark foils); both substrates assemble byte-identical
extended strips, so their outputs are bit-for-bit equal.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import (resolve_strip_blocks, strip_substrate_call,
                     validate_tiling, wrap_columns)


def _stencil_steps(cur: jax.Array, weights, t: int, radius: int) -> jax.Array:
    """``t`` unrolled tap-sum updates on a halo-extended f32 strip.

    The barrier keeps XLA from fusing the strip assembly (refs concatenated
    by the whole-strip substrate, a scratch slice for the sub-blocked one)
    into the tap sum -- assembly-dependent FMA formation would otherwise
    perturb the last ulp, and the two substrates are asserted BIT-for-bit
    equal (tests/test_substrate_strips.py).
    """
    cur = jax.lax.optimization_barrier(cur)
    k = 2 * radius + 1
    n = cur.shape[1]
    for _ in range(t):
        z = wrap_columns(cur, radius)              # (m_cur, n + 2r), periodic
        m = cur.shape[0] - 2 * radius
        acc = jnp.zeros((m, n), jnp.float32)
        for dy in range(k):
            for dx in range(k):
                w = float(weights[dy, dx])
                if w == 0.0:   # star stencils: skip zero taps at trace time
                    continue
                acc = acc + w * z[dy : dy + m, dx : dx + n]
        cur = acc
    return cur


def stencil_direct(
    x: jax.Array,
    weights,
    t: int = 1,
    tile_m: int = None,
    tile_n: int = None,
    h_block: int = None,
    interpret: bool = False,
) -> jax.Array:
    """``t`` fused time steps of a 2D stencil, periodic boundary.

    ``weights``: host-side (2r+1, 2r+1) ndarray (zeros outside support).
    ``tile_m`` is the strip height and ``h_block`` the halo sub-block
    height -- ``None`` (default) picks both via ``choose_strip_blocks``
    (divisors, halo-covering, VMEM-budgeted); explicit values are validated
    strictly.  ``h_block=0`` disables sub-blocking (whole-strip 3-load
    substrate).  ``tile_n`` is accepted for signature parity with the MXU
    kernel but unused (the VPU path never column-tiles).
    """
    import numpy as np

    del tile_n  # strips always span the full width
    w = np.asarray(weights)
    radius = (w.shape[0] - 1) // 2
    halo = t * radius
    wid = x.shape[1]
    strip_m, h_block = resolve_strip_blocks(x.shape, halo, x.dtype.itemsize,
                                            tile_m, h_block)
    validate_tiling(x.shape, strip_m, wid, halo, radius, h_block)

    def compute(cur):
        return _stencil_steps(cur, w, t, radius)

    return strip_substrate_call(compute, x, strip_m, h_block, halo,
                                interpret)
