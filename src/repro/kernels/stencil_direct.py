"""VPU-path 2D stencil kernel (the "CUDA core" baseline of the paper).

One grid cell computes a (TILE_M, TILE_N) output tile: the halo-extended
input tile is assembled in VMEM from nine neighbor blocks, then the stencil
is an unrolled sum of shifted tile slices times scalar taps -- pure
element-wise VPU work, accumulated in f32.

Supports an in-kernel temporal-fusion depth ``t`` (the paper's CUDA-core
temporal fusion, §3.2.2): ``t`` sequential updates on a halo of ``t*r``,
intermediates living entirely in VMEM => per-point HBM traffic stays 2D
while compute scales by t (I = t*K/D).  This kernel IS `stencil_fused`'s
engine; ``t=1`` is the plain baseline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import assemble_extended, neighbor_in_specs, validate_tiling


def _kernel(*refs, weights, t: int, radius: int, out_dtype):
    """refs = 9 neighbor refs + out_ref; weights are host constants."""
    out_ref = refs[-1]
    halo = t * radius
    ext = assemble_extended(refs[:9], halo).astype(jnp.float32)
    k = 2 * radius + 1
    for _ in range(t):
        m = ext.shape[0] - 2 * radius
        n = ext.shape[1] - 2 * radius
        acc = jnp.zeros((m, n), jnp.float32)
        for dy in range(k):
            for dx in range(k):
                w = float(weights[dy, dx])
                if w == 0.0:   # star stencils: skip zero taps at trace time
                    continue
                acc = acc + w * ext[dy : dy + m, dx : dx + n]
        ext = acc
    out_ref[...] = ext.astype(out_dtype)


def stencil_direct(
    x: jax.Array,
    weights,
    t: int = 1,
    tile_m: int = 128,
    tile_n: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """``t`` fused time steps of a 2D stencil, periodic boundary.

    ``weights``: host-side (2r+1, 2r+1) ndarray (zeros outside support).
    """
    import numpy as np

    w = np.asarray(weights)
    radius = (w.shape[0] - 1) // 2
    halo = t * radius
    h, wid = x.shape
    tile_m = min(tile_m, h)
    tile_n = min(tile_n, wid)
    validate_tiling(x.shape, tile_m, tile_n, halo)
    gm, gn = h // tile_m, wid // tile_n

    kern = functools.partial(
        _kernel, weights=w, t=t, radius=radius, out_dtype=x.dtype
    )
    return pl.pallas_call(
        kern,
        grid=(gm, gn),
        in_specs=neighbor_in_specs(tile_m, tile_n, gm, gn),
        out_specs=pl.BlockSpec((tile_m, tile_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(*([x] * 9))
