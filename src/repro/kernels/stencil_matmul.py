"""MXU-path N-D stencil kernel: decompose-to-banded-matmul (the paper's
"Tensor Core" adaptation, re-thought for the TPU systolic array).

2D is the base case below; 3D grids flatten their (z, y) shift pairs into
the same radius-r banded contractions along the last dim
(``build_bands_nd``, DESIGN.md §9) and lower through the halo-plane slab
substrate; 1D grids route through the 2D substrate lifted to (1, N).

Transformation (DESIGN.md §2):
  * decomposition: the (2R+1)^2 kernel splits into 2R+1 row vectors
    (paper §2.2.1 "Decomposing");
  * replication/alignment: each row vector w[dy, :] is materialized as a
    banded (Toeplitz) matrix  B_dy of shape (TILE_N + 2R, TILE_N) with
    B_dy[j+dx, j] = w[dy, dx]  -- this satisfies the MXU operand-size
    constraint (full 128-wide tiles) at the cost of zero padding
    (paper §2.2.2 "sparse redundancy"), with structural sparsity
        S = (2R+1) / (TILE_N + 2R)
    (see perfmodel.sparsity_banded);
  * contraction: out[:, j] += A_dy @ B_dy  where A_dy is the dy-shifted
    (STRIP_M, TILE_N + 2R) slab of the column tile j of the halo-extended
    strip.  Matmuls run in the input dtype with f32 accumulation (MXU
    semantics).

The substrate is the halo-row sub-blocked strip pipeline (kernels.common,
DESIGN.md §3): a 2D (strip, h-block) grid assembles each output strip's
halo-extended rows from (h_block, N) blocks -- (1 + 2*h_block/strip_m)x
HBM reads per step -- with the horizontal halo wrapped in-VMEM.
``h_block=0`` selects the whole-strip 3-load substrate (the
``*_wholestrip`` benchmark foils); both assemble byte-identical extended
strips, so outputs are bit-for-bit equal.  Widths exceeding the VMEM
budget column-tile the last axis too (DESIGN.md §10): the contraction
then consumes a CARRIED 2*t*r x-halo instead of re-wrapping, and a
final chunk narrower than ``tile_n`` (awkward/prime widths -- the
choose_tile cap policy) contracts against the banded operand's leading
submatrix, which IS the narrower band.

Two fusion regimes share this kernel (paper §2.2.3 + DESIGN.md §4):

  * monolithic (``t=1`` on composed weights): the wrapper is handed a
    fused kernel of radius R = t*r and runs ONE banded contraction -- no
    intermediate reuse, compute inflated by alpha, exactly the
    monolithic-fusion regime the paper models;
  * intermediate reuse (``t>1`` on base weights): ``t`` radius-r banded
    contractions execute inside one kernel with every intermediate resident
    in VMEM (vertical halo t*r, horizontal wrap re-applied per step).  The
    fused kernel never materializes, so alpha = 1; the price is a
    shrinking-halo recompute factor beta = 1 + r*(t-1)/strip_m
    (perfmodel.halo_recompute_factor) -- the paper's taxonomy implies this
    fifth regime but never implements it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import (apply_boundary_fills, choose_tile, extend_columns,
                     lift_boundary_1d, resolve_substrate_geom,
                     slab_substrate_call, strip_substrate_call,
                     validate_tiling)
from repro.stencil.boundary import resolve_boundary


def build_bands(weights: np.ndarray, tile_n: int) -> np.ndarray:
    """(ROWS, TILE_N + 2R, TILE_N) banded weight matrices, one per kernel row.

    ``weights`` is a 2D kernel whose LAST axis carries the x taps (radius
    R from that axis); rows may number 2R+1 (square 2D kernels) or 1 (the
    lifted-1D kernel).
    """
    w = np.asarray(weights)
    rows, kx = w.shape
    radius = (kx - 1) // 2
    bands = np.zeros((rows, tile_n + 2 * radius, tile_n), dtype=w.dtype)
    # Vectorized diagonal fill: tap dx of every row lands on the band
    # (j + dx, j); writing the zero taps too is identical to skipping
    # them, since the destination starts zeroed.
    j = np.arange(tile_n)
    for dx in range(kx):
        bands[:, j + dx, j] = w[:, dx, None]
    return bands


def build_bands_nd(weights: np.ndarray, tile_n: int):
    """Flatten an N-D kernel's leading shift tuples into banded operands.

    Returns ``(offsets, bands)``: ``offsets`` is the host-side list of
    leading-axis shift tuples (e.g. (dz, dy) for 3D) whose x-row
    ``weights[off + (:,)]`` is structurally nonzero, and ``bands`` stacks
    one (TILE_N + 2R, TILE_N) banded matrix per such row.  All-zero rows
    (most of a star stencil's (dz, dy) pairs) are dropped at build time --
    they would contract to exact zeros, so skipping them cuts both the
    banded operand and the per-step MXU work without touching the result.
    """
    w = np.asarray(weights)
    lead = w.shape[:-1]
    offsets = [off for off in np.ndindex(*lead)
               if np.count_nonzero(w[off + (slice(None),)])]
    rows = np.stack([w[off + (slice(None),)] for off in offsets])
    return offsets, build_bands(rows, tile_n)


def band_sparsity(weights: np.ndarray, tile_n: int) -> float:
    """Measured S of the built operands = nonzeros / total (sanity vs model).

    Closed form: each nonzero tap (off, dx) lands on its own diagonal
    (j + dx, j), contributing exactly ``tile_n`` entries with no
    collisions (dx = row - col is unique per element), so over the rows
    ``build_bands_nd`` keeps (all-zero leading rows of a 3D star already
    dropped)

        S = nnz_taps * tile_n / (n_rows * (tile_n + 2r) * tile_n)
          = nnz_taps / (n_rows * (tile_n + 2r)).

    Cross-checked against the materialized operand in tests; identical to
    the historical 2D measurement for 2D kernels, whose rows are never
    all-zero.
    """
    w = np.asarray(weights)
    if w.ndim == 1:
        w = w[None, :]
    radius = (w.shape[-1] - 1) // 2
    per_row = np.count_nonzero(w.reshape(-1, w.shape[-1]), axis=1)
    per_row = per_row[per_row > 0]
    return float(per_row.sum()) / (per_row.size * (tile_n + 2 * radius))


def _banded_step(z: jax.Array, bands_ref, offsets, lead_extents,
                 radius: int, tile_n: int, compute_dtype,
                 wrap_x: bool = True, mode_x: str = "periodic") -> jax.Array:
    """One radius-r banded contraction, any rank.

    ``z``: (..., n) rows; ``offsets`` the host-side leading shift tuples
    matching ``bands_ref`` rows (the flattened (z, y) shift pairs for
    3D, (dy,) singletons for 2D); ``lead_extents`` the kernel's
    leading-axis extents.  Returns the update with every leading axis
    shrunk by its kernel extent - 1, accumulated in f32 across column
    chunks of width ``tile_n``: each (dz, dy) shifted slab is flattened
    to rows and contracted against its banded operand.

    ``wrap_x`` (full-width substrates: rows are complete global rows)
    materializes the x-halo in-VMEM under ``mode_x`` (periodic = the
    historical wrap); ``wrap_x=False`` (the column-tiled substrate,
    DESIGN.md §10) consumes the CARRIED x-halo instead, shrinking the
    last axis by 2*radius.  A final chunk narrower than ``tile_n``
    (widths not divisible by the tile -- the choose_tile cap policy)
    contracts against the leading submatrix of the banded operand,
    which is exactly the narrower band.
    """
    if wrap_x:
        zw = extend_columns(z, radius, mode_x)         # (..., n + 2r)
        n_out = z.shape[-1]
    else:
        zw = z                                         # halo carried
        n_out = z.shape[-1] - 2 * radius
    lead = tuple(z.shape[i] - (lead_extents[i] - 1)
                 for i in range(len(lead_extents)))
    m = 1
    for d in lead:
        m *= d
    bands_w = bands_ref.shape[-1]
    cols = []
    start = 0
    while start < n_out:
        wcur = min(tile_n, n_out - start)
        acc = jnp.zeros((m, wcur), jnp.float32)
        for p, off in enumerate(offsets):
            sl = tuple(slice(off[i], off[i] + lead[i])
                       for i in range(len(lead)))
            a = zw[sl + (slice(start, start + wcur + 2 * radius),)]
            a = a.reshape(m, wcur + 2 * radius)
            b = bands_ref[p]                  # (bands_w + 2r, bands_w)
            if wcur != bands_w:
                b = b[:wcur + 2 * radius, :wcur]
            acc = acc + jax.lax.dot(a.astype(compute_dtype),
                                    b.astype(compute_dtype),
                                    preferred_element_type=jnp.float32)
        cols.append(acc)
        start += wcur
    out = cols[0] if len(cols) == 1 else jnp.concatenate(cols, axis=1)
    return out.reshape(lead + (n_out,))


def _banded_steps(cur: jax.Array, edges, bands_ref, offsets, lead_extents,
                  t: int, radius: int, tile_n: int, compute_dtype, modes,
                  wrap_x: bool = True, x_pad: int = 0) -> jax.Array:
    # Barrier between region assembly and contraction: keeps the
    # substrates' compute graphs identical so their outputs stay bit-for-bit
    # equal (see stencil_direct._stencil_steps).  Non-periodic launches
    # re-impose the boundary on the shrinking out-of-domain halo before
    # every step, exactly like the VPU kernel (DESIGN.md §15).
    cur = jax.lax.optimization_barrier(cur)
    for k in range(t):
        if edges is not None:
            cur = apply_boundary_fills(cur, modes, edges, (t - k) * radius,
                                       x_pad=x_pad, x_tiled=not wrap_x)
        cur = _banded_step(cur, bands_ref, offsets, lead_extents, radius,
                           tile_n, compute_dtype, wrap_x, modes[-1])
    return cur


def stencil_matmul(
    x: jax.Array,
    weights,
    t: int = 1,
    tile_m: int = None,
    tile_n: int = None,
    h_block: int = None,
    z_slab: int = None,
    z_block: int = None,
    w_tile: int = None,
    w_block: int = None,
    interpret: bool = False,
    compute_dtype=None,
    boundary=None,
) -> jax.Array:
    """``t`` stencil steps via banded MXU contractions, per-axis boundaries.

    ``boundary`` is a per-axis mode spec (DESIGN.md §15; ``None`` = all
    periodic, the historical behavior bit for bit).

    N-D: 2D and 3D grids contract their flattened leading shift tuples
    against per-row banded operands; 1D grids route through the 2D
    substrate lifted to (1, N).

    ``t=1``: one contraction of ``weights`` -- which may itself be a fused
    kernel of radius t*r (the paper's monolithic kernel fusion).
    ``t>1``: the intermediate-reuse regime -- t radius-r contractions of the
    BASE kernel with intermediates resident in VMEM (``fused_matmul_reuse``
    in repro.kernels.ops).

    ``tile_m`` is the strip height; ``tile_n`` the column-chunk width of
    each contraction (the banded operand is (rows, tile_n + 2r, tile_n);
    widths not divisible by ``tile_n`` contract a narrower final chunk
    against the operand's leading submatrix, so awkward/prime widths
    keep full-size chunks -- the ``choose_tile`` cap policy);
    ``h_block`` the halo sub-block height (``None`` = auto, 0 =
    whole-strip/whole-slab foil substrate); 3D grids add
    ``z_slab``/``z_block``; 2D/3D grids add ``w_tile``/``w_block`` (the
    column-tiled W substrate, DESIGN.md §10 -- each step then consumes a
    carried x-halo instead of re-wrapping).  Any left ``None`` is
    auto-chosen (``resolve_substrate_geom`` / ``choose_tile``); explicit
    values are validated strictly.
    """
    w = np.asarray(weights)
    if x.ndim != w.ndim:
        raise ValueError(f"grid rank {x.ndim} != kernel rank {w.ndim}")
    if x.ndim == 1:
        # coerce h_block exactly like resolve_substrate_geom's dim-1 rule
        # (see stencil_direct); 1D never column-tiles
        hb = h_block if h_block in (None, 0) else 1
        y = stencil_matmul(x[None, :], w[None, :], t=t, tile_m=1,
                           tile_n=tile_n, h_block=hb, w_tile=0,
                           interpret=interpret, compute_dtype=compute_dtype,
                           boundary=lift_boundary_1d(boundary))
        return y[0]

    modes = resolve_boundary(boundary, x.ndim)
    radius = (w.shape[-1] - 1) // 2
    halo = t * ((w.shape[0] - 1) // 2)        # 0 for the lifted-1D kernel
    wid = x.shape[-1]
    x_halo = t * radius                       # carried if column-tiled
    geom = resolve_substrate_geom(x.shape, halo, x.dtype.itemsize,
                                  tile_m, h_block, z_slab, z_block,
                                  w_tile, w_block, x_halo)
    tile_n = choose_tile(wid) if tile_n is None else min(tile_n, wid)
    validate_tiling(x.shape, geom.strip_m, tile_n, halo, radius,
                    geom.h_block, geom.z_slab if x.ndim == 3 else None,
                    geom.z_block, geom.w_tile, geom.w_block, x_halo,
                    boundary=modes)
    if compute_dtype is None:
        compute_dtype = x.dtype
    x_pad = (-wid) % geom.w_tile if geom.w_tile else 0  # remainder path

    offsets, bands_np = build_bands_nd(w.astype(np.float32), tile_n)
    bands = jnp.asarray(bands_np)
    lead_extents = w.shape[:-1]

    def compute(cur, edges, bands_ref):
        return _banded_steps(cur, edges, bands_ref, offsets, lead_extents,
                             t, radius, tile_n, compute_dtype, modes,
                             wrap_x=not geom.w_tile, x_pad=x_pad)

    if x.ndim == 3:
        return slab_substrate_call(compute, x, geom, halo, interpret,
                                   consts=(bands,),
                                   x_halo=x_halo if geom.w_tile else 0,
                                   boundary=modes)
    return strip_substrate_call(compute, x, geom.strip_m, geom.h_block,
                                halo, interpret, consts=(bands,),
                                w_tile=geom.w_tile, w_block=geom.w_block,
                                x_halo=x_halo if geom.w_tile else 0,
                                boundary=modes)
