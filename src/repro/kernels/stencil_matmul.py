"""MXU-path 2D stencil kernel: decompose-to-banded-matmul (the paper's
"Tensor Core" adaptation, re-thought for the TPU systolic array).

Transformation (DESIGN.md §2):
  * decomposition: the (2R+1)^2 kernel splits into 2R+1 row vectors
    (paper §2.2.1 "Decomposing");
  * replication/alignment: each row vector w[dy, :] is materialized as a
    banded (Toeplitz) matrix  B_dy of shape (TILE_N + 2R, TILE_N) with
    B_dy[j+dx, j] = w[dy, dx]  -- this satisfies the MXU operand-size
    constraint (full 128-wide tiles) at the cost of zero padding
    (paper §2.2.2 "sparse redundancy"), with structural sparsity
        S = (2R+1) / (TILE_N + 2R)
    (see perfmodel.sparsity_banded);
  * contraction: out[:, j] += A_dy @ B_dy  where A_dy is the dy-shifted
    (STRIP_M, TILE_N + 2R) slab of the column tile j of the halo-extended
    strip.  Matmuls run in the input dtype with f32 accumulation (MXU
    semantics).

The substrate is the halo-row sub-blocked strip pipeline (kernels.common,
DESIGN.md §3): a 2D (strip, h-block) grid assembles each output strip's
halo-extended rows from (h_block, N) blocks -- (1 + 2*h_block/strip_m)x
HBM reads per step -- with the horizontal halo wrapped in-VMEM.
``h_block=0`` selects the whole-strip 3-load substrate (the
``*_wholestrip`` benchmark foils); both assemble byte-identical extended
strips, so outputs are bit-for-bit equal.

Two fusion regimes share this kernel (paper §2.2.3 + DESIGN.md §4):

  * monolithic (``t=1`` on composed weights): the wrapper is handed a
    fused kernel of radius R = t*r and runs ONE banded contraction -- no
    intermediate reuse, compute inflated by alpha, exactly the
    monolithic-fusion regime the paper models;
  * intermediate reuse (``t>1`` on base weights): ``t`` radius-r banded
    contractions execute inside one kernel with every intermediate resident
    in VMEM (vertical halo t*r, horizontal wrap re-applied per step).  The
    fused kernel never materializes, so alpha = 1; the price is a
    shrinking-halo recompute factor beta = 1 + r*(t-1)/strip_m
    (perfmodel.halo_recompute_factor) -- the paper's taxonomy implies this
    fifth regime but never implements it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import (choose_tile, resolve_strip_blocks,
                     strip_substrate_call, validate_tiling, wrap_columns)


def build_bands(weights: np.ndarray, tile_n: int) -> np.ndarray:
    """(2R+1, TILE_N + 2R, TILE_N) banded weight matrices, one per kernel row."""
    w = np.asarray(weights)
    k = w.shape[0]
    radius = (k - 1) // 2
    bands = np.zeros((k, tile_n + 2 * radius, tile_n), dtype=w.dtype)
    for dy in range(k):
        for dx in range(k):
            if w[dy, dx] == 0.0:
                continue
            for j in range(tile_n):
                bands[dy, j + dx, j] = w[dy, dx]
    return bands


def band_sparsity(weights: np.ndarray, tile_n: int) -> float:
    """Measured S of the built operands = nonzeros / total (sanity vs model)."""
    bands = build_bands(weights, tile_n)
    return float(np.count_nonzero(bands)) / bands.size


def _banded_step(z: jax.Array, bands_ref, radius: int, tile_n: int,
                 compute_dtype) -> jax.Array:
    """One radius-r banded contraction on full-width rows.

    ``z``: (m_cur, n) rows that are complete global rows; returns the
    (m_cur - 2r, n) update, accumulated in f32 across column tiles.
    """
    n = z.shape[1]
    m = z.shape[0] - 2 * radius
    k = 2 * radius + 1
    zw = wrap_columns(z, radius)                       # (m_cur, n + 2r)
    cols = []
    for j in range(n // tile_n):
        acc = jnp.zeros((m, tile_n), jnp.float32)
        for dy in range(k):
            a = zw[dy : dy + m,
                   j * tile_n : j * tile_n + tile_n + 2 * radius]
            b = bands_ref[dy].astype(compute_dtype)    # (tile_n + 2r, tile_n)
            acc = acc + jax.lax.dot(a.astype(compute_dtype), b,
                                    preferred_element_type=jnp.float32)
        cols.append(acc)
    return cols[0] if len(cols) == 1 else jnp.concatenate(cols, axis=1)


def _banded_steps(cur: jax.Array, bands_ref, t: int, radius: int,
                  tile_n: int, compute_dtype) -> jax.Array:
    # Barrier between strip assembly and contraction: keeps the two
    # substrates' compute graphs identical so their outputs stay bit-for-bit
    # equal (see stencil_direct._stencil_steps).
    cur = jax.lax.optimization_barrier(cur)
    for _ in range(t):
        cur = _banded_step(cur, bands_ref, radius, tile_n, compute_dtype)
    return cur


def stencil_matmul(
    x: jax.Array,
    weights,
    t: int = 1,
    tile_m: int = None,
    tile_n: int = None,
    h_block: int = None,
    interpret: bool = False,
    compute_dtype=None,
) -> jax.Array:
    """``t`` stencil steps via banded MXU contractions, periodic boundary.

    ``t=1``: one contraction of ``weights`` -- which may itself be a fused
    kernel of radius t*r (the paper's monolithic kernel fusion).
    ``t>1``: the intermediate-reuse regime -- t radius-r contractions of the
    BASE kernel with intermediates resident in VMEM (``fused_matmul_reuse``
    in repro.kernels.ops).

    ``tile_m`` is the strip height; ``tile_n`` the column-tile width of each
    contraction (the banded operand is (2r+1, tile_n + 2r, tile_n));
    ``h_block`` the halo sub-block height (``None`` = auto, 0 = whole-strip
    substrate).  Any left ``None`` is auto-chosen (``choose_strip_blocks``
    / ``choose_tile``); explicit values are validated strictly.
    """
    w = np.asarray(weights)
    radius = (w.shape[0] - 1) // 2
    halo = t * radius
    wid = x.shape[1]
    strip_m, h_block = resolve_strip_blocks(x.shape, halo, x.dtype.itemsize,
                                            tile_m, h_block)
    tile_n = choose_tile(wid) if tile_n is None else min(tile_n, wid)
    validate_tiling(x.shape, strip_m, tile_n, halo, radius, h_block)
    if compute_dtype is None:
        compute_dtype = x.dtype

    bands = jnp.asarray(build_bands(w.astype(np.float32), tile_n))

    def compute(cur, bands_ref):
        return _banded_steps(cur, bands_ref, t, radius, tile_n, compute_dtype)

    return strip_substrate_call(compute, x, strip_m, h_block, halo,
                                interpret, consts=(bands,))
