"""MXU-path 2D stencil kernel: decompose-to-banded-matmul (the paper's
"Tensor Core" adaptation, re-thought for the TPU systolic array).

Transformation (DESIGN.md §2):
  * decomposition: the (2R+1)^2 kernel splits into 2R+1 row vectors
    (paper §2.2.1 "Decomposing");
  * replication/alignment: each row vector w[dy, :] is materialized as a
    banded (Toeplitz) matrix  B_dy of shape (TILE_N + 2R, TILE_N) with
    B_dy[j+dx, j] = w[dy, dx]  -- this satisfies the MXU operand-size
    constraint (full 128-wide tiles) at the cost of zero padding
    (paper §2.2.2 "sparse redundancy"), with structural sparsity
        S = (2R+1) / (TILE_N + 2R)
    (see perfmodel.sparsity_banded);
  * contraction: out += A_dy @ B_dy  where A_dy is the dy-shifted
    (TILE_M, TILE_N + 2R) slab of the halo-extended input tile.  Matmuls
    run in the input dtype with f32 accumulation (MXU semantics).

Kernel fusion (paper §2.2.3) is weight composition: the wrapper fuses t
steps into a single monolithic kernel of radius R = t*r before building the
bands -- no intermediate reuse, compute inflated by alpha, exactly the
monolithic-fusion regime the paper models.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .common import assemble_extended, neighbor_in_specs, validate_tiling


def build_bands(weights: np.ndarray, tile_n: int) -> np.ndarray:
    """(2R+1, TILE_N + 2R, TILE_N) banded weight matrices, one per kernel row."""
    w = np.asarray(weights)
    k = w.shape[0]
    radius = (k - 1) // 2
    bands = np.zeros((k, tile_n + 2 * radius, tile_n), dtype=w.dtype)
    for dy in range(k):
        for dx in range(k):
            if w[dy, dx] == 0.0:
                continue
            for j in range(tile_n):
                bands[dy, j + dx, j] = w[dy, dx]
    return bands


def band_sparsity(weights: np.ndarray, tile_n: int) -> float:
    """Measured S of the built operands = nonzeros / total (sanity vs model)."""
    bands = build_bands(weights, tile_n)
    return float(np.count_nonzero(bands)) / bands.size


def _kernel(*refs, radius: int, out_dtype, compute_dtype):
    # refs: 9 neighbor refs, bands ref, out ref
    out_ref = refs[-1]
    bands_ref = refs[-2]
    ext = assemble_extended(refs[:9], radius)          # (M+2R, N+2R)
    m = ext.shape[0] - 2 * radius
    n = ext.shape[1] - 2 * radius
    k = 2 * radius + 1
    acc = jnp.zeros((m, n), jnp.float32)
    for dy in range(k):
        a = ext[dy : dy + m, :].astype(compute_dtype)          # (M, N+2R)
        b = bands_ref[dy].astype(compute_dtype)                # (N+2R, N)
        acc = acc + jax.lax.dot(a, b, preferred_element_type=jnp.float32)
    out_ref[...] = acc.astype(out_dtype)


def stencil_matmul(
    x: jax.Array,
    weights,
    tile_m: int = 128,
    tile_n: int = 128,
    interpret: bool = False,
    compute_dtype=None,
) -> jax.Array:
    """One stencil step via banded MXU contractions, periodic boundary.

    ``weights`` may be a fused kernel (radius R = t*r) -- the monolithic
    kernel-fusion execution of the paper.
    """
    w = np.asarray(weights)
    radius = (w.shape[0] - 1) // 2
    h, wid = x.shape
    tile_m = min(tile_m, h)
    tile_n = min(tile_n, wid)
    validate_tiling(x.shape, tile_m, tile_n, radius)
    gm, gn = h // tile_m, wid // tile_n
    if compute_dtype is None:
        compute_dtype = x.dtype

    bands = jnp.asarray(build_bands(w.astype(np.float32), tile_n))

    kern = functools.partial(
        _kernel, radius=radius, out_dtype=x.dtype, compute_dtype=compute_dtype
    )
    in_specs = neighbor_in_specs(tile_m, tile_n, gm, gn) + [
        pl.BlockSpec(bands.shape, lambda i, j: (0, 0, 0))
    ]
    return pl.pallas_call(
        kern,
        grid=(gm, gn),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tile_m, tile_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(*([x] * 9), bands)
