"""Shared Pallas plumbing: strip-mined halo BlockSpecs and tile assembly.

TPU Pallas BlockSpecs address non-overlapping blocks (element offset = block
index * block shape), so halo reads cannot be expressed as one overlapping
block.  The seed substrate worked around that by referencing the SAME input
nine times with shifted ``index_map``s -- one full (tile_m, tile_n) block
per 2D neighbor -- which streams 9x the grid through HBM per step even
though only halo-wide edges of eight of those blocks are ever read.

The strip-mined scheme here fixes the traffic model (DESIGN.md §3):

  * the grid is 1D over ROW STRIPS of shape (strip_m, N) -- each strip spans
    the full grid width;
  * the vertical halo comes from just the top/bottom neighbor strips, so one
    input is referenced three times (modulo wrap in the index map = periodic
    rows), i.e. 3 block loads per output strip instead of 9;
  * the horizontal periodic halo costs no HBM traffic at all: every strip
    holds complete rows, so the wrap columns are materialized in-VMEM by
    concatenation (``wrap_columns``).

Read amplification drops from 9x to 3x, and because every row of the
extended strip is a TRUE global row, the horizontal wrap can be re-applied
to in-VMEM intermediates at every fused step -- the property that enables
the ``fused_matmul_reuse`` regime (DESIGN.md §4).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Vertical neighbor offsets of the strip scheme (up, center, down) -- the
#: strip analogue of the seed's 9-entry 2D offset table (kernels.legacy).
NEIGHBOR_OFFSETS_STRIP = (-1, 0, 1)

#: Per-output-strip input block loads issued by the strip substrate.  The
#: seed scheme issued 9 (see kernels.legacy.NEIGHBOR_OFFSETS_2D).
STRIP_NEIGHBOR_LOADS = len(NEIGHBOR_OFFSETS_STRIP)

#: Default VMEM working-set budget for ``choose_strip`` (bytes).  ~16 MB per
#: core on TPU v4/v5; leave half for double buffering and the output strip.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024


def strip_in_specs(strip_m: int, n: int, grid_m: int):
    """Three BlockSpecs addressing row strips (i-1, i, i+1) mod grid_m.

    Each spec covers a full-width (strip_m, n) band; modulo wrap in the
    index map yields periodic top/bottom boundaries for free (matching the
    ppermute ring of the distributed runtime).
    """
    specs = []
    for di in NEIGHBOR_OFFSETS_STRIP:
        specs.append(
            pl.BlockSpec(
                (strip_m, n),
                functools.partial(lambda i, di=di: ((i + di) % grid_m, 0)),
            )
        )
    return specs


def assemble_strip(top_ref, mid_ref, bot_ref, halo: int) -> jax.Array:
    """Build the (strip_m + 2h, n) vertically halo-extended strip in VMEM.

    Only the bottom ``halo`` rows of the top neighbor and the top ``halo``
    rows of the bottom neighbor are read.
    """
    h = halo
    return jnp.concatenate(
        [top_ref[...][-h:, :], mid_ref[...], bot_ref[...][:h, :]], axis=0
    )


def wrap_columns(x: jax.Array, halo: int) -> jax.Array:
    """Materialize the periodic horizontal halo in-VMEM: (m, n) -> (m, n+2h).

    Valid whenever every row of ``x`` is a complete global row -- true for
    strips and for all intermediates derived from them, which is what lets
    fused kernels re-wrap at every step instead of carrying a 2*t*r-wide
    horizontal halo.
    """
    h = halo
    return jnp.concatenate([x[:, -h:], x, x[:, :h]], axis=1)


def choose_tile(n: int, preferred: int = 128) -> int:
    """Largest divisor of ``n`` that is <= preferred (MXU-friendly when 128)."""
    if n <= preferred:
        return n
    for cand in range(preferred, 0, -1):
        if n % cand == 0:
            return cand
    return n


def choose_strip(
    h: int,
    n: int,
    halo: int,
    dtype_bytes: int = 4,
    vmem_budget: int = VMEM_BUDGET_BYTES,
    preferred: int = 128,
) -> int:
    """Pick a strip height: a divisor of ``h``, >= halo, fitting VMEM.

    The working set of one grid cell is the three input strips, the
    vertically+horizontally extended tile, and the output strip.  Among
    divisors that fit the budget, prefer the largest one <= ``preferred``
    (fewer grid cells amortize the fixed per-cell cost); if none fits, fall
    back to the smallest viable divisor so the kernel still launches and
    the compiler surfaces the VMEM pressure.
    """

    def working_set(d: int) -> int:
        return (3 * d * n + (d + 2 * halo) * (n + 2 * halo) + d * n) * dtype_bytes

    divisors = [d for d in range(1, h + 1) if h % d == 0]
    viable = [d for d in divisors if d >= halo] or [h]
    fitting = [d for d in viable if working_set(d) <= vmem_budget]
    pool = fitting or [min(viable)]
    under = [d for d in pool if d <= preferred]
    return max(under) if under else min(pool)


def validate_tiling(shape, strip_m: int, tile_n: int, halo: int,
                    radius: int = None) -> None:
    """Strip-substrate tiling constraints.

    ``strip_m`` is the strip height (rows per grid cell); ``tile_n`` is the
    column-tile width of the banded MXU contraction (pass the full width for
    the VPU path, which never column-tiles).  ``radius`` is the per-step
    wrap radius -- the only width constraint, since the horizontal halo is
    re-wrapped at radius r each step regardless of fusion depth (defaults
    to ``halo`` for callers that run a single step at the full radius).
    """
    h, w = shape
    if h % strip_m or w % tile_n:
        raise ValueError(
            f"grid {shape} not divisible by tiles ({strip_m},{tile_n})"
        )
    if strip_m < halo:
        raise ValueError(
            f"halo {halo} exceeds strip height {strip_m}; "
            "lower fusion depth or enlarge strips"
        )
    r = halo if radius is None else radius
    if w < r:
        raise ValueError(
            f"wrap radius {r} exceeds grid width {w}; lower the radius"
        )


def hbm_read_bytes_per_step(shape, strip_m: int, dtype_bytes: int,
                            bands_shape=None) -> int:
    """Analytic HBM read traffic of one strip-substrate kernel launch.

    Each of the ``h/strip_m`` grid cells streams three (strip_m, n) blocks,
    so the grid is read 3x per step (vs 9x for kernels.legacy); the banded
    operand (if any) is re-streamed per grid cell.
    """
    import numpy as np

    h, w = shape
    gm = h // strip_m
    total = gm * STRIP_NEIGHBOR_LOADS * strip_m * w * dtype_bytes
    if bands_shape is not None:
        total += gm * int(np.prod(bands_shape)) * dtype_bytes
    return total
