"""Shared Pallas plumbing: halo-row sub-blocked strip substrate.

TPU Pallas BlockSpecs address non-overlapping blocks (element offset = block
index * block shape), so halo reads cannot be expressed as one overlapping
block.  The seed substrate worked around that by referencing the SAME input
nine times with shifted ``index_map``s -- one full (tile_m, tile_n) block
per 2D neighbor -- which streams 9x the grid through HBM per step even
though only halo-wide edges of eight of those blocks are ever read.

PR 1 replaced that with WHOLE row strips: a 1D grid over (strip_m, N)
bands, each output strip loading itself plus its full top/bottom neighbor
strips (3 loads, modulo wrap in the index map = periodic rows), with the
horizontal periodic halo materialized in-VMEM (``wrap_columns``) at zero
HBM cost.  3x read amplification -- but the two neighbor strips are still
fetched whole although only ``halo`` rows of each are ever read.

This module now implements the halo-row SUB-BLOCKED scheme (DESIGN.md §3):

  * the grid is 2D over (strip, h-block): block height ``h_block`` divides
    ``strip_m`` (``nb = strip_m / h_block`` blocks per strip);
  * ONE input reference of block shape (h_block, N) with index map
    ``(i*nb + j - 1) mod (H/h_block)`` walks, for output strip i, the
    top neighbor's LAST h-block (j=0), the strip's own nb blocks
    (j=1..nb), and the bottom neighbor's FIRST h-block (j=nb+1) -- the
    only neighbor rows that can contain halo rows (h_block >= halo);
  * each block is copied into a VMEM scratch of (strip_m + 2*h_block, N);
    on the final j the kernel computes on the assembled halo-extended
    strip and writes the output strip (``pl.when``), so reads per strip
    are ``strip_m + 2*h_block`` rows:

        reads/step = (1 + 2*h_block/strip_m) * H*W*D

    vs 3x for whole neighbor strips and 9x for the seed scheme.  The
    modulo index map keeps periodic top/bottom boundaries for free, and
    every scratch row is still a TRUE global row, so the horizontal wrap
    re-applies to in-VMEM intermediates at every fused step -- the
    property that enables ``fused_matmul_reuse`` (DESIGN.md §4).

``h_block=0`` (or ``subblocked=False`` at the kernel level) selects the
whole-strip 3-load substrate -- kept registered as the ``*_wholestrip``
benchmark foils so ``benchmarks/traffic.py`` can measure seed / whole-strip
/ sub-blocked three ways.

N-D HALO-PLANE GENERALIZATION (DESIGN.md §9).  The scheme above is the
d=2 instance of a general halo-plane substrate:

  * 3D grids (Z, H, W) run on a (z-slab, strip, block) Pallas grid: each
    output cell is a (z_slab, strip_m, W) slab-strip, assembled from ONE
    input reference of block shape (z_block, h_block, W) whose index map
    walks the cell's own (z_slab/z_block)x(strip_m/h_block) blocks plus
    the single ring of neighbor blocks that can contain halo planes/rows
    (z_block >= halo, h_block >= halo), into a VMEM scratch of
    (z_slab + 2*z_block, strip_m + 2*h_block, W).  Reads per step:

        (1 + 2*h_block/strip_m) * (1 + 2*z_block/z_slab) * Z*H*W*D

    The last axis keeps the free in-VMEM periodic wrap (every scratch row
    is a TRUE global row), so the fused regimes carry over unchanged.
    ``h_block=0`` selects the whole-slab foil (3x3 full neighbor slabs =
    9x reads, the 3D analogue of the 2D 3-load scheme).
  * 1D grids route through the 2D substrate lifted to (1, N): the
    vertical halo is 0, so each strip streams only its own rows
    (read amplification exactly 1) and the x-wrap stays in-VMEM.

COLUMN-TILED W AXIS (DESIGN.md §10).  Both schemes above span the FULL
width in VMEM, so grids with W >> VMEM (weather/fluid planes with W in
the tens of thousands) cannot execute at all.  When the full-width
working set exceeds the VMEM budget the substrate column-tiles the last
axis too:

  * the grid gains a (w-tile, w-block) dimension: each output cell is a
    (strip_m, w_tile) tile (2D) or (z_slab, strip_m, w_tile) cell (3D),
    and the single input reference shrinks to (h_block, w_block) /
    (z_block, h_block, w_block), walking the FULL block ring -- own
    blocks plus every neighbor block that can contain halo rows OR halo
    columns -- into a VMEM scratch of
    (strip_m + 2*h_block, w_tile + 2*w_block) (plus the z axis in 3D);
  * the periodic x-halo is assembled from neighbor COLUMN blocks
    (modulo wrap in the index map, exactly like the vertical axes)
    instead of the in-VMEM ``wrap_columns`` concat -- scratch rows are
    no longer complete global rows, so fused execution must CARRY a
    2*t*r-wide x-halo (``w_block >= t*r``) and shrink it per step, the
    same discipline the leading axes always had.  Reads per step become
    the three-factor product

        (1 + 2*h_block/strip_m)(1 + 2*z_block/z_slab)
            (1 + 2*w_block/w_tile) * Z*H*W*D

  * widths with no usable divisor (primes, awkward W) run through an
    edge-tile remainder path: the input is periodically extended by one
    w_block per side on the host, the column walk drops its modulo wrap
    (the extension carries it), and the padded output columns are
    sliced off -- so ANY width executes at a non-degenerate tile.

``w_tile=0`` is the full-width fast path: the launchers and sizing are
bit-for-bit the pre-column-tiling scheme, and auto-resolution only
column-tiles when full width cannot fit the budget.  The whole-strip /
whole-slab foils never column-tile (they are full-width by
construction), so ``w_tile > 0`` requires the sub-blocked substrate.

``SubstrateGeom`` carries the resolved (z_slab, z_block, strip_m,
h_block, w_tile, w_block) geometry through plans, the selector and the
cache keys; ``resolve_substrate_geom`` is THE shared sizing rule for
every rank.

PER-AXIS BOUNDARIES (DESIGN.md §15).  Every wrap above is the
``periodic`` instance of a per-axis :mod:`repro.stencil.boundary` spec
(``periodic | zero | reflect | replicate``).  Non-periodic axes change
exactly two things, keeping the HBM traffic model (and therefore every
``repro.audit`` block check) bit-identical to periodic:

  * the index maps REFLECT out-of-range block indices at block
    granularity instead of wrapping (``_reflect_block``: -1 -> 1,
    total -> total-2) -- every fetch stays in bounds, no two
    consecutive ring steps fetch the same block, and the fetch count
    per cell is unchanged, so reads/step keep the three-factor product;
  * the halo content those edge fetches assemble is garbage *for the
    mode*, so the kernels re-impose the boundary IN KERNEL before every
    fused step (``apply_boundary_fills`` / ``extend_columns``): the
    out-of-domain depth at step s is (t-s+1)*r, and zero / replicate /
    reflect values are rebuilt from in-domain rows with free ops only
    (slice/flip/broadcast/select -- the jaxpr FLOP audit counts zero
    extra FLOPs).  Re-imposing per step, not once, is what matches the
    oracle, which re-pads every step.

``_launch`` passes the kernels a per-region-axis ``edges`` tuple of
(is_lo, is_hi) grid-edge flags (from ``pl.program_id``) so the fills
fire only on domain-edge cells.  All-periodic specs skip both changes
entirely -- default plans lower through the historical jaxpr bit for
bit.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.stencil.boundary import PAD_MODE, resolve_boundary

#: Vertical neighbor offsets of the whole-strip scheme (up, center, down) --
#: the strip analogue of the seed's 9-entry 2D offset table (kernels.legacy).
NEIGHBOR_OFFSETS_STRIP = (-1, 0, 1)

#: Per-output-strip input block loads issued by the WHOLE-strip substrate.
#: The seed scheme issued 9 (kernels.legacy.NEIGHBOR_OFFSETS_2D); the
#: sub-blocked substrate issues ``strip_m/h_block + 2`` h-row blocks.
STRIP_NEIGHBOR_LOADS = len(NEIGHBOR_OFFSETS_STRIP)

#: Default VMEM working-set budget for strip sizing (bytes).  TPU v4/v5
#: cores have ~16 MB of VMEM; this budget is deliberately HALF of that
#: (8 MB) so the other half stays free for Mosaic's double buffering and
#: pipeline slack.  Override per process with the REPRO_VMEM_BUDGET
#: environment variable (``vmem_budget_bytes``), validated like
#: REPRO_PLAN_CACHE_SIZE.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024


def vmem_budget_bytes() -> int:
    """The effective VMEM sizing budget: ``REPRO_VMEM_BUDGET`` if set
    (must be a positive integer number of bytes), else
    :data:`VMEM_BUDGET_BYTES`.  Read at every geometry resolution, so
    tests and long-running servers can retune without reimporting; the
    plan cache folds the effective value into its keys."""
    from repro.core.envutil import env_int
    return env_int("REPRO_VMEM_BUDGET", VMEM_BUDGET_BYTES, minimum=1)


def strip_in_specs(strip_m: int, n: int, grid_m: int):
    """Three BlockSpecs addressing row strips (i-1, i, i+1) mod grid_m.

    The WHOLE-strip substrate: each spec covers a full-width (strip_m, n)
    band; modulo wrap in the index map yields periodic top/bottom boundaries
    for free (matching the ppermute ring of the distributed runtime).
    """
    specs = []
    for di in NEIGHBOR_OFFSETS_STRIP:
        specs.append(
            pl.BlockSpec(
                (strip_m, n),
                functools.partial(lambda i, di=di: ((i + di) % grid_m, 0)),
            )
        )
    return specs


def subblock_in_spec(h_block: int, n: int, nb: int, total_blocks: int):
    """The single h-block BlockSpec of the sub-blocked substrate.

    Grid cell (i, j), j in [0, nb+2), fetches h-block
    ``(i*nb + j - 1) mod total_blocks``: j=0 is the top neighbor strip's
    last h-block, j=1..nb the strip's own blocks, j=nb+1 the bottom
    neighbor's first h-block.  Modulo wrap = periodic rows, exactly as the
    whole-strip index maps.
    """
    return pl.BlockSpec(
        (h_block, n),
        lambda i, j: ((i * nb + j - 1) % total_blocks, 0),
    )


def subblock_store(scratch_ref, block_ref, h_block: int) -> None:
    """Copy grid cell (i, j)'s h-block into scratch rows [j*h, (j+1)*h)."""
    j = pl.program_id(1)
    scratch_ref[pl.ds(j * h_block, h_block), :] = block_ref[...]


def subblock_extended(scratch_ref, h_block: int, strip_m: int,
                      halo: int) -> jax.Array:
    """The (strip_m + 2*halo, n) halo-extended strip from assembled scratch.

    Scratch rows cover global rows [i*strip_m - h_block,
    (i+1)*strip_m + h_block); the extended strip needs only ``halo`` of the
    ``h_block`` neighbor rows at each end.
    """
    return scratch_ref[h_block - halo : h_block + strip_m + halo, :]


def assemble_strip(top_ref, mid_ref, bot_ref, halo: int) -> jax.Array:
    """Build the (strip_m + 2h, n) vertically halo-extended strip in VMEM.

    Whole-strip substrate: only the bottom ``halo`` rows of the top neighbor
    and the top ``halo`` rows of the bottom neighbor are read.
    """
    h = halo
    return jnp.concatenate(
        [top_ref[...][-h:, :], mid_ref[...], bot_ref[...][:h, :]], axis=0
    )


def wrap_columns(x: jax.Array, halo: int) -> jax.Array:
    """Materialize the periodic last-axis halo in-VMEM: (..., n) -> (..., n+2h).

    Valid whenever every row of ``x`` is a complete global row -- true for
    strips, for assembled sub-block scratch rows (2D and 3D), and for all
    intermediates derived from them, which is what lets fused kernels
    re-wrap at every step instead of carrying a 2*t*r-wide horizontal halo.
    """
    h = halo
    return jnp.concatenate([x[..., -h:], x, x[..., :h]], axis=-1)


def extend_columns(x: jax.Array, halo: int, mode: str = "periodic",
                   lo_edge=True, hi_edge=True) -> jax.Array:
    """Mode-aware last-axis halo materialization: (..., n) -> (..., n+2h).

    The boundary generalization of :func:`wrap_columns` for full-width
    kernels (every row is a complete global row, so the domain edge IS
    the array edge).  ``periodic`` is exactly ``wrap_columns``; the other
    modes synthesize the out-of-domain columns from in-domain ones with
    free ops only (concat / flip / broadcast -- zero counted FLOPs).
    Called per fused step, which is what matches the per-step re-padding
    oracle.  ``lo_edge``/``hi_edge`` (static or traced bools) select the
    boundary fill vs the true wrap halo -- full-width kernels own both
    edges, so the defaults apply; the distributed stepper passes shard
    masks.
    """
    if mode == "periodic":
        return wrap_columns(x, halo)
    h = halo
    wrap_lo, wrap_hi = x[..., -h:], x[..., :h]
    if mode == "zero":
        lo = hi = jnp.zeros_like(wrap_lo)
    elif mode == "replicate":
        reps = (1,) * (x.ndim - 1) + (h,)
        lo = jnp.tile(x[..., :1], reps)
        hi = jnp.tile(x[..., -1:], reps)
    elif mode == "reflect":
        lo = jnp.flip(x[..., 1:h + 1], axis=-1)
        hi = jnp.flip(x[..., -h - 1:-1], axis=-1)
    else:
        raise ValueError(f"unknown boundary mode {mode!r}")
    if lo_edge is not True:
        lo = jnp.where(lo_edge, lo, wrap_lo)
    if hi_edge is not True:
        hi = jnp.where(hi_edge, hi, wrap_hi)
    return jnp.concatenate([lo, x, hi], axis=-1)


def _reflect_block(idx, total: int):
    """Reflect an out-of-range block index into [0, total): -1 -> 1,
    total -> total-2 (identity in range).  The non-periodic analogue of
    the ``% total`` wrap in the ring index maps -- chosen over clamping
    because it never fetches the same block on consecutive ring steps,
    so Pallas's consecutive-revisit dedup (and the audit's exact
    grid-bytes model) sees a fetch sequence identical to periodic.  The
    fetched edge content is then overwritten by the in-kernel boundary
    fills.  Works on plain ints (the auditor enumerates index maps) and
    traced ints (the launched kernel) alike; ring walks stay within one
    block of the domain, so a single reflection suffices.
    """
    if total == 1:
        return idx * 0
    last = total - 1
    return last - abs(last - abs(idx))


def _axis_block_index(idx, total: int, mode: str):
    """One ring-axis block index under its boundary mode: periodic wraps
    (the historical map, bit for bit), every other mode reflects."""
    return idx % total if mode == "periodic" else _reflect_block(idx, total)


def apply_boundary_fills(cur, modes, edges, halo: int, x_pad: int = 0,
                         x_tiled: bool = False):
    """Re-impose non-periodic boundary values on the halo of one region.

    ``cur`` is a halo-extended compute region whose axis ``ax`` carries
    ``halo`` out-of-domain cells per side on domain-edge cells (garbage
    as far as the mode is concerned: reflected-block fetches, stale
    carry, or host padding).  For every non-periodic axis this rebuilds
    those cells from the in-domain part -- zeros, the broadcast edge
    cell, or the mirrored rows -- gated per side by ``edges[ax]``
    (is_lo, is_hi) so interior cells keep their true fetched halo.
    Axes fill in ascending order, so later axes mirror already-filled
    earlier-axis halo cells: exactly ``np.pad``'s sequential corner
    semantics, which the oracle's ``pad_boundary`` shares.

    The last axis fills only when ``x_tiled`` (column-tiled kernels;
    full-width kernels re-extend via :func:`extend_columns` instead).
    ``x_pad`` is the remainder path's right-padding column count: the
    last tile's domain edge sits ``x_pad`` columns INSIDE the block, so
    its fill region shifts left by ``x_pad`` (the pad tail itself is
    left untouched -- it only feeds output columns that are sliced off).
    Free ops only (slice / flip / broadcast / select / concat): the
    traced-FLOP audit must count the same FLOPs as the periodic kernel.
    """
    if edges is None:
        return cur
    ndim = cur.ndim

    def sl(ax, a, b):
        s = [slice(None)] * ndim
        s[ax] = slice(a, b)
        return tuple(s)

    o = halo
    for ax in range(ndim):
        mode = modes[ax]
        last_axis = ax == ndim - 1
        if mode == "periodic" or o == 0 or (last_axis and not x_tiled):
            continue
        pad = x_pad if last_axis else 0
        valid = cur.shape[ax] - 2 * o - pad
        lo_flag, hi_flag = edges[ax]
        if mode == "zero":
            lo_fill = jnp.zeros_like(cur[sl(ax, 0, o)])
            hi_fill = jnp.zeros_like(cur[sl(ax, valid + o, valid + 2 * o)])
        elif mode == "replicate":
            reps = [1] * ndim
            reps[ax] = o
            lo_fill = jnp.tile(cur[sl(ax, o, o + 1)], reps)
            hi_fill = jnp.tile(cur[sl(ax, valid + o - 1, valid + o)], reps)
        elif mode == "reflect":
            lo_fill = jnp.flip(cur[sl(ax, o + 1, 2 * o + 1)], axis=ax)
            hi_fill = jnp.flip(cur[sl(ax, valid - 1, valid + o - 1)],
                               axis=ax)
        else:
            raise ValueError(f"unknown boundary mode {mode!r}")
        lo = lo_fill if lo_flag is True \
            else jnp.where(lo_flag, lo_fill, cur[sl(ax, 0, o)])
        hi = hi_fill if hi_flag is True \
            else jnp.where(hi_flag, hi_fill,
                           cur[sl(ax, valid + o, valid + 2 * o)])
        parts = [lo, cur[sl(ax, o, valid + o)], hi]
        if pad:
            parts.append(cur[sl(ax, valid + 2 * o, None)])
        cur = jnp.concatenate(parts, axis=ax)
    return cur


def choose_tile(n: int, preferred: int = 128) -> int:
    """Column-tile width for the banded MXU contraction: min(n, preferred).

    Cap policy: the tile is NEVER degenerate -- widths that are not
    multiples of ``preferred`` get a full-size tile plus one narrower
    edge tile (both kernels handle the remainder by slicing the banded
    operand, which contains every narrower band as a leading submatrix).
    The historical rule searched for the largest divisor of ``n``, which
    collapsed to 1-wide tiles on prime widths (choose_tile(257) == 1)
    and to awkward off-lane tiles on near-misses (choose_tile(130) ==
    65), silently destroying MXU utilization.
    """
    if n <= 0:
        raise ValueError(f"width must be positive, got {n}")
    return min(n, preferred)


def choose_hblock(strip_m: int, halo: int) -> int:
    """Halo-block height: smallest divisor of strip_m >= max(halo, strip/16).

    ``h_block`` must cover the halo in one neighbor block (>= halo) and
    divide the strip.  Smaller blocks cut traffic (amplification is
    1 + 2h/strip_m) but multiply grid cells and shrink below the TPU
    sublane tile for thin strips, so we floor at ceil(strip_m/16) --
    amplification lands at ~1.125 whenever the halo allows, and degrades
    gracefully toward the whole-strip 3x as the halo forces h_block up
    (h_block = strip_m whenever no proper divisor reaches the halo).
    """
    if strip_m <= 0:
        raise ValueError(f"strip height must be positive, got {strip_m}")
    floor = max(halo, -(-strip_m // 16))      # integer ceil division
    cands = [d for d in range(1, strip_m + 1)
             if strip_m % d == 0 and d >= floor]
    return min(cands) if cands else strip_m


def _strip_working_set(d: int, hb: int, n: int, halo: int,
                       dtype_bytes: int) -> int:
    """Full-width 2D VMEM working set, priced at the WORSE of the two
    substrates -- 3 full strips (whole-strip foil) vs scratch +
    in-flight h-block (sub-blocked) -- plus the horizontally-extended
    f32 compute tile and the output strip."""
    inputs = max(3 * d * n, (d + 2 * hb) * n + hb * n)
    return (inputs + (d + 2 * halo) * (n + 2 * halo) + d * n) * dtype_bytes


def _col_working_set_2d(sm: int, hb: int, wt: int, wb: int, halo: int,
                        x_halo: int, dtype_bytes: int) -> int:
    """Column-tiled 2D VMEM working set: scratch + in-flight block +
    halo-extended compute tile + output tile (sub-blocked only -- the
    whole-strip foil never column-tiles)."""
    scratch = (sm + 2 * hb) * (wt + 2 * wb) + hb * wb
    compute = (sm + 2 * halo) * (wt + 2 * x_halo)
    return (scratch + compute + sm * wt) * dtype_bytes


def _wtile_candidates(w: int, x_halo: int, preferred: int = 128) -> list:
    """Column-tile widths worth considering for a width-``w`` grid.

    Divisors of ``w`` (the aligned path: pure modulo-wrap column walk,
    zero host traffic) that can hold the x-halo, plus the caps
    ``min(w-1, k*preferred)`` for k in (1, 2, 4) -- non-divisor caps run
    the edge-tile remainder path, so prime and awkward widths still get
    a full-size tile instead of a degenerate divisor.  ``w`` itself is
    excluded: that is the full-width fast path, not a column tiling.
    """
    lo = max(x_halo, 1)
    cands = {d for d in range(lo, w) if w % d == 0}
    for k in (1, 2, 4):
        cap = min(w - 1, k * preferred)
        if cap >= lo:
            cands.add(cap)
    return sorted(cands) or [max(w - 1, 1)]


def choose_strip_blocks(
    h: int,
    n: int,
    halo: int,
    dtype_bytes: int = 4,
    vmem_budget: int = None,
    preferred: int = 128,
) -> tuple:
    """Jointly size the full-width (strip_m, h_block) under the VMEM budget.

    ``strip_m``: a divisor of ``h``, >= halo, fitting VMEM; among fitting
    divisors prefer the largest <= ``preferred`` (taller strips both
    amortize per-cell cost and shrink the halo read factor 1 + 2h/strip_m).
    ``h_block``: ``choose_hblock`` of the chosen strip.  The input-side
    working set is priced at the worse of the two substrates
    (``_strip_working_set``), so a strip that fits the budget fits
    whichever substrate the caller ends up running (the ``*_wholestrip``
    foils share this sizing).  When NO full-width strip fits, the
    smallest viable one is returned anyway -- ``resolve_strip_blocks``
    detects that case and escalates to the column-tiled sizing
    (``choose_col_blocks``) instead.
    """
    if vmem_budget is None:
        vmem_budget = vmem_budget_bytes()

    def working_set(d: int) -> int:
        return _strip_working_set(d, choose_hblock(d, halo), n, halo,
                                  dtype_bytes)

    divisors = [d for d in range(1, h + 1) if h % d == 0]
    viable = [d for d in divisors if d >= halo] or [h]
    fitting = [d for d in viable if working_set(d) <= vmem_budget]
    pool = fitting or [min(viable)]
    under = [d for d in pool if d <= preferred]
    strip_m = max(under) if under else min(pool)
    return strip_m, choose_hblock(strip_m, halo)


def choose_strip(
    h: int,
    n: int,
    halo: int,
    dtype_bytes: int = 4,
    vmem_budget: int = None,
    preferred: int = 128,
) -> int:
    """Strip height only (see ``choose_strip_blocks`` for the joint choice)."""
    return choose_strip_blocks(h, n, halo, dtype_bytes, vmem_budget,
                               preferred)[0]


def _axis_candidates(extent: int, halo: int, pin: int,
                     preferred: int = 128) -> list:
    """Leading-axis tile candidates: divisors >= halo capped at
    ``preferred`` (pins pass through verbatim)."""
    if pin is not None:
        return [pin]
    cands = [d for d in range(1, extent + 1)
             if extent % d == 0 and d >= halo] or [extent]
    capped = [d for d in cands if d <= preferred]
    return capped or [min(cands)]


def choose_col_blocks(
    h: int,
    w: int,
    halo: int,
    x_halo: int = None,
    dtype_bytes: int = 4,
    vmem_budget: int = None,
    preferred: int = 128,
    m_pin: int = None,
    w_pin: int = None,
) -> tuple:
    """Jointly size the column-tiled 2D geometry
    (strip_m, h_block, w_tile, w_block) under the VMEM budget.

    Entered when the full-width working set cannot fit (or the caller
    pinned ``w_tile``): the search spans strip candidates (divisors of
    ``h`` >= halo, capped at ``preferred``) x column-tile candidates
    (``_wtile_candidates``); blocks are ``choose_hblock`` of each tile,
    with the w-block floored at the CARRIED x-halo ``x_halo`` (= t*r --
    column-tiled kernels cannot re-wrap, DESIGN.md §10).  Among fitting
    combinations the rule minimizes the read-amplification product
    (1 + 2*h_block/strip_m)(1 + 2*w_block/w_tile), tie-breaking toward
    fewer grid cells (larger tiles); when nothing fits, the smallest
    working set wins.
    """
    if vmem_budget is None:
        vmem_budget = vmem_budget_bytes()
    xh = halo if x_halo is None else x_halo

    def wb_of(wt: int) -> int:
        return choose_hblock(wt, max(xh, 1))

    def ws(sm: int, wt: int) -> int:
        return _col_working_set_2d(sm, choose_hblock(sm, halo), wt,
                                   wb_of(wt), halo, xh, dtype_bytes)

    def amp(sm: int, wt: int) -> float:
        return (substrate_read_amp(sm, choose_hblock(sm, halo))
                * substrate_read_amp(wt, wb_of(wt)))

    pairs = [(sm, wt)
             for sm in _axis_candidates(h, halo, m_pin, preferred)
             for wt in ([w_pin] if w_pin else _wtile_candidates(w, xh,
                                                                preferred))]
    fitting = [p for p in pairs if ws(*p) <= vmem_budget]
    pool = fitting or [min(pairs, key=lambda p: ws(*p))]
    sm, wt = min(pool, key=lambda p: (amp(*p), -p[0] * p[1]))
    return sm, choose_hblock(sm, halo), wt, wb_of(wt)


def choose_slab_blocks(
    z: int,
    h: int,
    n: int,
    halo: int,
    dtype_bytes: int = 4,
    vmem_budget: int = None,
    preferred: int = 128,
    z_pin: int = None,
    m_pin: int = None,
    w_pin: int = None,
    x_halo: int = None,
) -> tuple:
    """Jointly size the 3D geometry
    (z_slab, z_block, strip_m, h_block, w_tile, w_block).

    ``z_slab`` divides Z and ``strip_m`` divides H, both >= halo;
    ``z_block``/``h_block`` are ``choose_hblock`` of each (smallest
    halo-covering divisor above the 1/16 floor).  Two phases:

      * FULL WIDTH (w_tile = w_block = 0, the fast path): the input
        working set is priced at the WORSE of the two substrates -- 9
        full neighbor slabs (whole-slab foil) vs scratch + in-flight
        block (sub-blocked) -- plus the f32 halo-extended compute slab
        and the output slab, so a geometry that fits the budget fits
        whichever substrate ends up running.  Taken whenever any
        full-width pair fits (or ``w_pin=0`` forces it).
      * COLUMN-TILED (DESIGN.md §10): when no full-width pair fits (or
        ``w_pin`` > 0), the search adds the (w_tile, w_block) axis --
        ``_wtile_candidates`` of W, w_block floored at the carried
        x-halo ``x_halo`` (= t*r) -- and prices the sub-blocked scratch
        + compute + output cell only (the whole-slab foil never
        column-tiles).

    Among fitting combinations (free axes capped at ``preferred``) the
    rule minimizes the analytic read-amplification product, tie-breaking
    toward fewer grid cells (larger cells).  ``z_pin``/``m_pin``/
    ``w_pin`` fix axes to explicit user pins: the search sizes only the
    FREE axes conditioned on the pins.  Pins are exempt from the
    divisor/halo/``preferred`` filters (explicit values are validated
    strictly by the caller).
    """
    if vmem_budget is None:
        vmem_budget = vmem_budget_bytes()
    xh = halo if x_halo is None else x_halo

    def blocks(zs: int, sm: int) -> tuple:
        return choose_hblock(zs, halo), choose_hblock(sm, halo)

    def wb_of(wt: int) -> int:
        return choose_hblock(wt, max(xh, 1))

    def working_set(zs: int, sm: int) -> int:
        zb, hb = blocks(zs, sm)
        scratch = (zs + 2 * zb) * (sm + 2 * hb) * n + zb * hb * n
        whole = 9 * zs * sm * n
        inputs = max(whole, scratch)
        compute = (zs + 2 * halo) * (sm + 2 * halo) * (n + 2 * halo)
        return (inputs + compute + zs * sm * n) * dtype_bytes

    def working_set_col(zs: int, sm: int, wt: int) -> int:
        zb, hb = blocks(zs, sm)
        wb = wb_of(wt)
        scratch = ((zs + 2 * zb) * (sm + 2 * hb) * (wt + 2 * wb)
                   + zb * hb * wb)
        compute = (zs + 2 * halo) * (sm + 2 * halo) * (wt + 2 * xh)
        return (scratch + compute + zs * sm * wt) * dtype_bytes

    def amp(zs: int, sm: int) -> float:
        zb, hb = blocks(zs, sm)
        return substrate_read_amp(sm, hb) * substrate_read_amp(zs, zb)

    pairs = [(zs, sm) for zs in _axis_candidates(z, halo, z_pin, preferred)
             for sm in _axis_candidates(h, halo, m_pin, preferred)]
    if not w_pin:
        fitting = [p for p in pairs if working_set(*p) <= vmem_budget]
        if fitting or w_pin == 0:
            pool = fitting or [min(pairs, key=lambda p: working_set(*p))]
            zs, sm = min(pool, key=lambda p: (amp(*p), -p[0] * p[1]))
            zb, hb = blocks(zs, sm)
            return zs, zb, sm, hb, 0, 0

    w_cands = [w_pin] if w_pin else _wtile_candidates(n, xh, preferred)
    triples = [(zs, sm, wt) for zs, sm in pairs for wt in w_cands]
    fitting = [t for t in triples if working_set_col(*t) <= vmem_budget]
    pool = fitting or [min(triples, key=lambda t: working_set_col(*t))]
    zs, sm, wt = min(
        pool, key=lambda t: (amp(t[0], t[1])
                             * substrate_read_amp(t[2], wb_of(t[2])),
                             -t[0] * t[1] * t[2]))
    zb, hb = blocks(zs, sm)
    return zs, zb, sm, hb, wt, wb_of(wt)


@dataclasses.dataclass(frozen=True)
class SubstrateGeom:
    """Resolved halo-plane substrate geometry for one kernel launch.

    ``dim`` is the grid rank (1D executes lifted through the 2D substrate
    with strip_m=1 and zero vertical halo).  ``h_block=0`` selects the
    whole-strip/whole-slab foil substrate (and forces ``z_block=0``);
    otherwise both block heights are >= the halo and divide their tile.
    ``w_tile=0`` is the full-width fast path; ``w_tile > 0`` selects the
    column-tiled substrate (DESIGN.md §10: sub-blocked only, with
    ``w_block`` >= the carried x-halo t*r and dividing ``w_tile``).
    """

    dim: int
    strip_m: int
    h_block: int                 # 0 = whole-strip/whole-slab foil
    z_slab: int = 1              # 3D only; 1 otherwise
    z_block: int = 0             # 3D only; 0 = whole-slab (with h_block=0)
    w_tile: int = 0              # 0 = full width (fast path)
    w_block: int = 0             # column halo block; 0 iff w_tile == 0

    @property
    def read_amp(self) -> float:
        """Analytic grid-read amplification of this geometry (DESIGN.md
        §9/§10): 1 (lifted 1D), 1 + 2h/strip_m (2D), times
        (1 + 2z_block/z_slab) (3D), times (1 + 2w_block/w_tile) when
        column-tiled; the full-width foils read 3x (2D) and 9x (3D)."""
        if self.dim == 1:
            return 1.0
        amp = substrate_read_amp(self.strip_m, self.h_block)
        if self.dim == 3:
            amp *= substrate_read_amp(self.z_slab, self.z_block)
        if self.w_tile:
            amp *= substrate_read_amp(self.w_tile, self.w_block)
        return amp

    def describe(self) -> str:
        """The substrate clause of decision reason strings -- formatted
        from resolved numbers only, so ``ops.explain`` and plan decisions
        agree verbatim whenever they resolve the same geometry."""
        if self.dim == 3:
            geo = (f"z_slab={self.z_slab}, z_block={self.z_block}, "
                   f"strip_m={self.strip_m}, h_block={self.h_block}")
        elif self.dim == 1:
            geo = f"1D lifted, strip_m={self.strip_m}"
        else:
            geo = f"strip_m={self.strip_m}, h_block={self.h_block}"
        if self.dim >= 2:
            if self.w_tile:
                geo += f", w_tile={self.w_tile}, w_block={self.w_block}"
            else:
                geo += ", w_tile=full"
        return f"substrate read_amp={self.read_amp:.3f}x ({geo})"


def _resolve_z_block(h_block: int, z_block: int, z_slab: int,
                     halo: int) -> int:
    """z_block under the shared pin rules: forced 0 by the whole foil
    (h_block=0), rejected as a lone 0 (no hybrid substrate exists),
    otherwise the explicit pin or ``choose_hblock`` of the slab.  Both
    ``resolve_substrate_geom`` and ``pricing_geom`` route through here, so
    plan building and grid-free pricing can never disagree on the rule.
    """
    if h_block == 0:
        return 0
    if z_block == 0:
        raise ValueError(
            "z_block=0 (whole-slab) is only valid together with "
            "h_block=0 (the whole-slab foil substrate)")
    return z_block if z_block is not None else choose_hblock(z_slab, halo)


def _resolve_w_block(w_tile: int, w_block: int, h_block: int,
                     x_halo: int) -> tuple:
    """(w_tile, w_block) under the shared pin rules: ``w_tile`` in
    (None, 0) is the full-width fast path (w_block forced 0; a lone
    w_block pin is rejected); a positive ``w_tile`` requires the
    sub-blocked substrate (the whole-strip/whole-slab foils are
    full-width by construction) and gets ``choose_hblock`` of the tile
    floored at the carried x-halo unless ``w_block`` is pinned too.
    Both ``resolve_substrate_geom`` and ``pricing_geom`` route through
    here, so plan building and grid-free pricing can never disagree.
    """
    if not w_tile:
        if w_block:
            raise ValueError(
                f"w_block={w_block} without a w_tile names no substrate; "
                "pin w_tile too (or drop both for full width)")
        return 0, 0
    if h_block == 0:
        raise ValueError(
            "the whole-strip/whole-slab foil substrate (h_block=0) spans "
            "the full width; column tiling (w_tile > 0) requires the "
            "sub-blocked substrate")
    if w_block is None or w_block == 0:
        return w_tile, choose_hblock(w_tile, max(x_halo, 1))
    return w_tile, w_block


def pricing_geom(dim: int, halo: int, strip_m: int = 128,
                 h_block: int = None, z_slab: int = None,
                 z_block: int = None, w_tile: int = None,
                 w_block: int = None) -> SubstrateGeom:
    """Grid-free geometry resolution for pricing paths (the selector has
    no grid to size against): dim 1 is always the lifted substrate; dim 2
    takes ``strip_m`` as given with ``choose_hblock`` filling ``h_block``;
    dim 3 defaults ``z_slab`` to ``strip_m`` and resolves ``z_block``
    under the same shared rule as ``resolve_substrate_geom``.  ``w_tile``
    in (None, 0) prices the full-width fast path; a positive ``w_tile``
    prices the column-tiled substrate (w_block auto-resolved at the
    fused x-halo ``halo`` unless pinned)."""
    if dim == 1:
        return SubstrateGeom(dim=1, strip_m=1, h_block=1)
    hb = choose_hblock(strip_m, halo) if h_block is None else h_block
    wt, wb = _resolve_w_block(w_tile, w_block, hb, halo)
    if dim == 2:
        return SubstrateGeom(dim=2, strip_m=strip_m, h_block=hb,
                             w_tile=wt, w_block=wb)
    if dim != 3:
        raise ValueError(f"substrate supports 1D/2D/3D grids, got dim {dim}")
    zs = strip_m if z_slab is None else z_slab
    zb = _resolve_z_block(hb, z_block, zs, halo)
    return SubstrateGeom(dim=3, strip_m=strip_m, h_block=hb,
                         z_slab=zs, z_block=zb, w_tile=wt, w_block=wb)


def _normalize_w_pin(w_tile, w_block, wid: int):
    """Clamp explicit width pins to the grid: ``w_tile >= W`` IS the
    full-width fast path (existing geometry bit-for-bit unchanged)."""
    if w_tile is not None and w_tile >= wid:
        return 0, 0
    return w_tile, w_block


def resolve_substrate_geom(grid_shape, halo: int, dtype_bytes: int,
                           tile_m: int = None, h_block: int = None,
                           z_slab: int = None, z_block: int = None,
                           w_tile: int = None, w_block: int = None,
                           x_halo: int = None) -> SubstrateGeom:
    """Resolve the full substrate geometry from possibly-``None`` requests.

    THE shared N-D auto-sizing rule: the kernels, ``stencil_plan`` pricing
    and ``registry.PlanContext.resolve_geom`` all call this, so plan-level
    and kernel-level sizing can never drift apart.  Rank comes from
    ``len(grid_shape)``:

      * 1D: lifted 2D geometry (strip_m=1, zero vertical halo, read amp 1;
        never column-tiled);
      * 2D: exactly ``resolve_strip_blocks`` (z fields stay inert);
      * 3D: joint ``choose_slab_blocks`` when unpinned; explicit ``tile_m``
        / ``z_slab`` are clamped to the grid and get ``choose_hblock``
        blocks unless those are pinned too.  ``h_block=0`` selects the
        whole-slab foil and forces ``z_block=0``; a lone ``z_block=0``
        under a sub-blocked h_block is rejected (no hybrid substrate).

    Width (DESIGN.md §10): ``w_tile=None`` auto-resolves -- full width
    whenever the full-width working set fits the VMEM budget, the
    column-tiled substrate otherwise; ``w_tile=0`` (or >= W) pins full
    width; a positive ``w_tile`` pins the column tile.  ``x_halo`` is the
    CARRIED per-side x-halo of column-tiled fused execution (t*r; the
    column-tiled kernels cannot re-wrap partial rows) and defaults to
    ``halo`` -- exact for the square kernels this repo builds.
    """
    dim = len(grid_shape)
    if dim == 1:
        hb = 0 if h_block == 0 else 1
        return SubstrateGeom(dim=1, strip_m=1, h_block=hb)
    xh = halo if x_halo is None else x_halo
    if dim == 2:
        strip_m, hb, wt, wb = resolve_strip_blocks(
            grid_shape, halo, dtype_bytes, tile_m, h_block,
            w_tile, w_block, xh)
        return SubstrateGeom(dim=2, strip_m=strip_m, h_block=hb,
                             w_tile=wt, w_block=wb)
    if dim != 3:
        raise ValueError(f"substrate supports 1D/2D/3D grids, got rank {dim}")
    z, h, wid = grid_shape
    w_tile, w_block = _normalize_w_pin(w_tile, w_block, wid)
    if w_block and w_tile is None:
        _resolve_w_block(0, w_block, h_block, xh)    # raises: lone w_block
        # pins are rejected on every path (see resolve_strip_blocks)
    if h_block == 0 and w_tile:
        _resolve_w_block(w_tile, w_block, 0, halo)   # raises: foil is
        # full-width by construction
    # One pin-aware joint search: a pinned axis is fixed (clamped to the
    # grid) and only the free axes are sized -- conditioned on the pins, so
    # the VMEM fit and amp-minimization always describe the geometry that
    # actually runs.  The whole-slab foil (h_block=0) never column-tiles.
    zs, auto_zb, sm, auto_hb, wt, auto_wb = choose_slab_blocks(
        z, h, wid, halo, dtype_bytes,
        z_pin=min(z_slab, z) if z_slab is not None else None,
        m_pin=min(tile_m, h) if tile_m is not None else None,
        w_pin=0 if h_block == 0 else w_tile,
        x_halo=xh)
    hb = h_block if h_block is not None else auto_hb
    zb = _resolve_z_block(hb, z_block, zs, halo)
    wt, wb = _resolve_w_block(wt, w_block if w_block else auto_wb, hb, xh)
    return SubstrateGeom(dim=3, strip_m=sm, h_block=hb, z_slab=zs,
                         z_block=zb, w_tile=wt, w_block=wb)


def _check_wrap_radius(w: int, r: int, mode: str = "periodic") -> None:
    """THE per-axis radius guard, shared by every rank's validation branch
    (historically copy-pasted across the 1D/2D/3D paths).

    Periodic axes wrap, so only ``w < r`` is impossible (the historical
    check, message unchanged).  Non-periodic axes have no wrap at all:
    a stencil whose support reaches across the whole axis (``r >= w``)
    would read nothing but synthesized boundary cells, so it raises with
    a mode-specific message instead of the misleading "lower the
    radius" wrap phrasing.
    """
    if mode == "periodic":
        if w < r:
            raise ValueError(
                f"wrap radius {r} exceeds grid width {w}; lower the radius")
        return
    if r >= w:
        raise ValueError(
            f"stencil radius {r} spans the whole {mode!r} axis "
            f"(extent {w}); a non-periodic axis needs extent > radius "
            "-- enlarge the grid or use a narrower stencil")


def _check_reflect_extent(extent: int, halo: int, axis: str,
                          mode: str) -> None:
    """Reflect needs ``halo`` in-domain mirror cells beyond the edge cell:
    cell ``-k`` reads cell ``+k``, so the axis extent must exceed the
    total (fused) halo depth."""
    if mode == "reflect" and extent < halo + 1:
        raise ValueError(
            f"reflect boundary on the {axis} axis needs extent >= "
            f"halo+1 = {halo + 1}, got {extent}; mirror cells would "
            "fall outside the domain")


def validate_tiling(shape, strip_m: int, tile_n: int, halo: int,
                    radius: int = None, h_block: int = None,
                    z_slab: int = None, z_block: int = None,
                    w_tile: int = None, w_block: int = None,
                    x_halo: int = None, boundary=None) -> None:
    """Halo-plane substrate tiling constraints (1D, 2D and 3D grids).

    ``strip_m`` is the strip height (rows per output block); ``tile_n`` is
    the column-chunk width of the banded MXU contraction (pass the full
    width for the VPU path) -- any width in [1, W] is legal, the kernels
    handle a narrower final chunk by slicing the banded operand.
    ``radius`` is the per-step wrap radius (defaults to ``halo`` for
    callers that run a single step at the full radius).  ``h_block``
    (sub-blocked substrate) must divide ``strip_m`` and cover the
    vertical halo; pass ``None``/0 for the whole-strip substrate.
    3D grids additionally constrain ``z_slab`` (divides Z, >= halo) and
    ``z_block`` (divides ``z_slab``, >= halo when sub-blocked).
    Column-tiled launches (``w_tile`` > 0, DESIGN.md §10) require the
    sub-blocked substrate and a ``w_block`` that divides ``w_tile`` and
    covers the CARRIED x-halo ``x_halo`` (= t*r; defaults to ``halo``) --
    ``w_tile`` need NOT divide W (edge tiles run the remainder path).
    ``boundary`` is the per-axis mode spec (DESIGN.md §15): non-periodic
    axes swap the wrap-radius guard for the mode-specific one, and
    reflect axes additionally need extent >= halo+1 (the mirror depth).
    """
    r = halo if radius is None else radius
    w = shape[-1]
    modes = resolve_boundary(boundary, len(shape))
    if len(shape) == 1:
        # Lifted-1D: no vertical support, so only the x-axis guard binds.
        _check_wrap_radius(w, r, modes[-1])
        _check_reflect_extent(w, halo, "x", modes[-1])
        return
    if len(shape) == 3:
        z, h, w = shape
        zs = z if z_slab is None else z_slab
        if z % zs:
            raise ValueError(
                f"grid depth {z} not divisible by z_slab {zs}")
        if zs < halo:
            raise ValueError(
                f"halo {halo} exceeds z_slab {zs}; "
                "lower fusion depth or enlarge slabs")
        if z_block:
            if zs % z_block:
                raise ValueError(
                    f"z_block {z_block} does not divide z_slab {zs}")
            if z_block < halo:
                raise ValueError(
                    f"halo {halo} exceeds z_block {z_block}; "
                    "enlarge z_block or lower fusion depth")
    else:
        h, w = shape
    if h % strip_m:
        raise ValueError(
            f"grid {shape} rows not divisible by strip height {strip_m}")
    if not 1 <= tile_n <= w:
        raise ValueError(
            f"column tile {tile_n} outside [1, {w}] for grid {shape}")
    if strip_m < halo:
        raise ValueError(
            f"halo {halo} exceeds strip height {strip_m}; "
            "lower fusion depth or enlarge strips"
        )
    if h_block:
        if strip_m % h_block:
            raise ValueError(
                f"h_block {h_block} does not divide strip height {strip_m}"
            )
        if h_block < halo:
            raise ValueError(
                f"halo {halo} exceeds h_block {h_block}; "
                "enlarge h_block or lower fusion depth"
            )
    if w_tile:
        if not h_block:
            raise ValueError(
                "column tiling (w_tile > 0) requires the sub-blocked "
                "substrate; the whole-strip/whole-slab foil (h_block=0) "
                "spans the full width")
        if w_tile > w:
            raise ValueError(
                f"w_tile {w_tile} exceeds grid width {w}")
        xh = halo if x_halo is None else x_halo
        if not w_block:
            raise ValueError(
                f"column tiling needs w_block >= the carried x-halo {xh}")
        if w_tile % w_block:
            raise ValueError(
                f"w_block {w_block} does not divide w_tile {w_tile}")
        if w_block < xh:
            raise ValueError(
                f"carried x-halo {xh} exceeds w_block {w_block}; "
                "enlarge w_block or lower fusion depth")
    _check_wrap_radius(w, r, modes[-1])
    _check_reflect_extent(w, halo, "x", modes[-1])
    lead = shape[:-1]
    for extent, mode, name in zip(lead, modes[:-1],
                                  ("z", "y")[-len(lead):]):
        # Periodic leading axes never had a radius guard (any extent
        # wraps -- the 1D lift runs extent 1) -- keep that bit of
        # history; non-periodic axes get the mode-specific guards.
        if mode == "periodic":
            continue
        _check_wrap_radius(extent, r, mode)
        _check_reflect_extent(extent, halo, name, mode)


#: Exact-arity all-zero index-map factories for grid-constant operands
#: (banded weights): Pallas wants the index map's arity to match the grid
#: rank, and the operand's block index never moves.
_ZERO_INDEX_MAPS = {
    1: lambda z: (lambda i: z),
    2: lambda z: (lambda i, j: z),
    3: lambda z: (lambda i, j, k: z),
    4: lambda z: (lambda i, j, k, l: z),
}


@dataclasses.dataclass(frozen=True)
class LaunchGeometry:
    """Complete, introspectable description of ONE Pallas substrate launch.

    Everything the launchers hand to ``pl.pallas_call`` -- the grid, the
    input/output block shapes and their index maps, the VMEM scratch
    shape, the mixed-radix ring decomposition that turns the last grid
    axis into scratch write slots, the compute fire step and the
    halo-extended read window -- lives here as data, built by
    ``strip_launch_geometry`` / ``slab_launch_geometry`` and consumed by
    ``_launch``.  ``repro.audit`` enumerates the SAME object statically
    (the index maps are pure-Python closures over ints, so calling them
    with concrete grid indices is exact enumeration, no tracing): the
    audited geometry IS the launched geometry, never a re-derivation.

    ``kind`` is one of "flat", "wholestrip", "subblocked", "coltiled",
    "wholeslab", "slab_subblocked", "slab_coltiled".  Ring kinds (those
    with a scratch) put the ring on the LAST grid axis; ``ring_dims`` is
    its row-major mixed-radix shape (e.g. (nb+2, ring_w)) and
    ``block_dims`` the matching per-ringed-axis scratch block sizes.
    ``out_shape`` is the launch output BEFORE the remainder-path column
    slice; ``src_shape`` the input AFTER any host-side column extension
    (``_extend_columns_for_tiling``) -- both equal the grid shape on
    aligned launches.
    """

    kind: str
    grid: tuple
    in_block: tuple
    in_index_maps: tuple
    out_block: tuple
    out_index_map: object
    out_shape: tuple
    src_shape: tuple
    halo: int
    x_halo: int
    scratch_shape: tuple = None
    ring_dims: tuple = ()
    block_dims: tuple = ()
    read_bounds: tuple = ()      # per-scratch-axis (lo, hi) compute window
    aligned: bool = True
    boundary: tuple = ()         # per-grid-axis modes; () = all periodic

    @property
    def periodic(self) -> bool:
        """True iff every axis wraps (the historical substrate)."""
        return all(m == "periodic" for m in self.boundary)

    @property
    def ring(self) -> int:
        """Grid steps per output cell (1 when there is no ring axis)."""
        return math.prod(self.ring_dims) if self.ring_dims else 1

    @property
    def fire_step(self) -> int:
        """Ring step on which compute fires: always the LAST ring step
        (every scratch slot must be written before the halo-extended
        read -- the invariant the scratch dependence audit proves)."""
        return self.ring - 1

    @property
    def cells(self) -> int:
        """Output cells in the launch (= grid size / ring length)."""
        return math.prod(self.grid) // self.ring

    def ring_indices(self, j):
        """Row-major mixed-radix decomposition of ring step ``j`` over
        ``ring_dims`` (last axis fastest).  Works on traced ints inside
        the kernel and on plain ints inside the auditor."""
        idxs = []
        for d in reversed(self.ring_dims):
            idxs.append(j % d)
            j = j // d
        return tuple(reversed(idxs))

    def scratch_slot(self, j):
        """Per-ringed-axis (start, size) scratch write slot of ring step
        ``j``; trailing (full-width) scratch axes are not listed."""
        return tuple((k * b, b)
                     for k, b in zip(self.ring_indices(j), self.block_dims))


def strip_launch_geometry(x_shape, strip_m: int, h_block: int, halo: int,
                          w_tile: int = 0, w_block: int = 0,
                          x_halo: int = 0,
                          boundary=None) -> LaunchGeometry:
    """Build the 2D (and lifted-1D) launch geometry: the single source of
    truth for what ``strip_substrate_call`` launches.

    ``halo=0`` -> "flat" (one load per strip, read amp exactly 1);
    ``h_block=0`` -> "wholestrip" (3 shifted full-strip refs);
    otherwise "subblocked" ((strip, h-block) ring into VMEM scratch);
    ``w_tile>0`` -> "coltiled" (DESIGN.md §10, full 2-axis block ring,
    edge-tile remainder path on non-dividing widths).

    ``boundary`` is the per-axis (rows, cols) mode pair: non-periodic
    axes reflect out-of-range block indices at block granularity
    (``_reflect_block``) instead of wrapping -- same fetch count, all in
    bounds, content overwritten by the kernels' in-kernel fills.
    """
    h, n = x_shape
    by, bx = resolve_boundary(boundary, 2)
    gm = h // strip_m
    if w_tile:
        nb = strip_m // h_block
        nbw = w_tile // w_block
        ring_w = nbw + 2
        gw = -(-n // w_tile)
        aligned = n % w_tile == 0
        total_h = h // h_block
        if aligned:
            total_w = n // w_block
            src_shape, out_w = (h, n), n

            def col_index(iw, jw):
                return _axis_block_index(iw * nbw + jw - 1, total_w, bx)
        else:
            src_shape = (h, gw * w_tile + 2 * w_block)
            out_w = gw * w_tile

            def col_index(iw, jw):
                return iw * nbw + jw   # the extension carries the boundary

        lg = LaunchGeometry(
            kind="coltiled",
            grid=(gm, gw, (nb + 2) * ring_w),
            in_block=(h_block, w_block),
            in_index_maps=(lambda i, iw, j: (
                _axis_block_index(i * nb + j // ring_w - 1, total_h, by),
                col_index(iw, j % ring_w)),),
            out_block=(strip_m, w_tile),
            out_index_map=lambda i, iw, j: (i, iw),
            out_shape=(h, out_w),
            src_shape=src_shape,
            halo=halo, x_halo=x_halo,
            scratch_shape=(strip_m + 2 * h_block, w_tile + 2 * w_block),
            ring_dims=(nb + 2, ring_w),
            block_dims=(h_block, w_block),
            read_bounds=((h_block - halo, h_block + strip_m + halo),
                         (w_block - x_halo, w_block + w_tile + x_halo)),
            aligned=aligned,
            boundary=(by, bx),
        )
    elif halo == 0:
        # No vertical halo => no neighbor loads on either substrate
        # (they coincide here): one load per strip, read amp exactly 1.
        lg = LaunchGeometry(
            kind="flat", grid=(gm,),
            in_block=(strip_m, n),
            in_index_maps=(lambda i: (i, 0),),
            out_block=(strip_m, n),
            out_index_map=lambda i: (i, 0),
            out_shape=(h, n), src_shape=(h, n), halo=0, x_halo=x_halo,
            boundary=(by, bx),
        )
    elif not h_block:
        maps = tuple(functools.partial(
            lambda i, di=di: (_axis_block_index(i + di, gm, by), 0))
            for di in NEIGHBOR_OFFSETS_STRIP)
        lg = LaunchGeometry(
            kind="wholestrip", grid=(gm,),
            in_block=(strip_m, n),
            in_index_maps=maps,
            out_block=(strip_m, n),
            out_index_map=lambda i: (i, 0),
            out_shape=(h, n), src_shape=(h, n), halo=halo, x_halo=x_halo,
            boundary=(by, bx),
        )
    else:
        nb = strip_m // h_block
        total = h // h_block
        lg = LaunchGeometry(
            kind="subblocked", grid=(gm, nb + 2),
            in_block=(h_block, n),
            in_index_maps=(lambda i, j: (
                _axis_block_index(i * nb + j - 1, total, by), 0),),
            out_block=(strip_m, n),
            out_index_map=lambda i, j: (i, 0),
            out_shape=(h, n), src_shape=(h, n), halo=halo, x_halo=x_halo,
            scratch_shape=(strip_m + 2 * h_block, n),
            ring_dims=(nb + 2,), block_dims=(h_block,),
            read_bounds=((h_block - halo, h_block + strip_m + halo),
                         (0, n)),
            boundary=(by, bx),
        )
    from repro.testing.faults import corrupt_geometry
    return corrupt_geometry(lg)


def slab_launch_geometry(x_shape, geom: SubstrateGeom, halo: int,
                         x_halo: int = 0, boundary=None) -> LaunchGeometry:
    """Build the 3D launch geometry: the single source of truth for what
    ``slab_substrate_call`` launches ("wholeslab" / "slab_subblocked" /
    "slab_coltiled", mirroring the 2D kinds one rank up).  ``boundary``
    is the per-axis (z, y, x) mode triple (see
    :func:`strip_launch_geometry`)."""
    z, h, n = x_shape
    bz, by, bx = resolve_boundary(boundary, 3)
    zs, sm = geom.z_slab, geom.strip_m
    gz, gm = z // zs, h // sm
    if geom.w_tile:
        zb, hb, wb = geom.z_block, geom.h_block, geom.w_block
        wt = geom.w_tile
        nbz, nby, nbw = zs // zb, sm // hb, wt // wb
        ring_y, ring_w = nby + 2, nbw + 2
        gw = -(-n // wt)
        aligned = n % wt == 0
        total_z, total_y = z // zb, h // hb
        if aligned:
            total_w = n // wb
            src_shape, out_w = (z, h, n), n

            def col_index(iw, jw):
                return _axis_block_index(iw * nbw + jw - 1, total_w, bx)
        else:
            src_shape = (z, h, gw * wt + 2 * wb)
            out_w = gw * wt

            def col_index(iw, jw):
                return iw * nbw + jw   # the extension carries the boundary

        def block_index(iz, iy, iw, j):
            jz = j // (ring_y * ring_w)
            jy = (j // ring_w) % ring_y
            jw = j % ring_w
            return (_axis_block_index(iz * nbz + jz - 1, total_z, bz),
                    _axis_block_index(iy * nby + jy - 1, total_y, by),
                    col_index(iw, jw))

        lg = LaunchGeometry(
            kind="slab_coltiled",
            grid=(gz, gm, gw, (nbz + 2) * ring_y * ring_w),
            in_block=(zb, hb, wb),
            in_index_maps=(block_index,),
            out_block=(zs, sm, wt),
            out_index_map=lambda iz, iy, iw, j: (iz, iy, iw),
            out_shape=(z, h, out_w),
            src_shape=src_shape,
            halo=halo, x_halo=x_halo,
            scratch_shape=(zs + 2 * zb, sm + 2 * hb, wt + 2 * wb),
            ring_dims=(nbz + 2, ring_y, ring_w),
            block_dims=(zb, hb, wb),
            read_bounds=((zb - halo, zb + zs + halo),
                         (hb - halo, hb + sm + halo),
                         (wb - x_halo, wb + wt + x_halo)),
            aligned=aligned,
            boundary=(bz, by, bx),
        )
    elif not geom.h_block:
        maps = tuple(
            functools.partial(lambda iz, iy, dz=dz, dy=dy:
                              (_axis_block_index(iz + dz, gz, bz),
                               _axis_block_index(iy + dy, gm, by), 0))
            for dz in (-1, 0, 1) for dy in (-1, 0, 1))
        lg = LaunchGeometry(
            kind="wholeslab", grid=(gz, gm),
            in_block=(zs, sm, n),
            in_index_maps=maps,
            out_block=(zs, sm, n),
            out_index_map=lambda iz, iy: (iz, iy, 0),
            out_shape=(z, h, n), src_shape=(z, h, n),
            halo=halo, x_halo=x_halo,
            boundary=(bz, by, bx),
        )
    else:
        zb, hb = geom.z_block, geom.h_block
        nbz, nby = zs // zb, sm // hb
        ring_y = nby + 2
        total_z, total_y = z // zb, h // hb

        def block_index(iz, iy, j):
            jz, jy = j // ring_y, j % ring_y
            return (_axis_block_index(iz * nbz + jz - 1, total_z, bz),
                    _axis_block_index(iy * nby + jy - 1, total_y, by), 0)

        lg = LaunchGeometry(
            kind="slab_subblocked", grid=(gz, gm, (nbz + 2) * ring_y),
            in_block=(zb, hb, n),
            in_index_maps=(block_index,),
            out_block=(zs, sm, n),
            out_index_map=lambda iz, iy, j: (iz, iy, 0),
            out_shape=(z, h, n), src_shape=(z, h, n),
            halo=halo, x_halo=x_halo,
            scratch_shape=(zs + 2 * zb, sm + 2 * hb, n),
            ring_dims=(nbz + 2, ring_y), block_dims=(zb, hb),
            read_bounds=((zb - halo, zb + zs + halo),
                         (hb - halo, hb + sm + halo),
                         (0, n)),
            boundary=(bz, by, bx),
        )
    from repro.testing.faults import corrupt_geometry
    return corrupt_geometry(lg)


def lift_boundary_1d(boundary) -> tuple:
    """The (rows, cols) boundary of a 1D grid lifted through the 2D
    substrate: the synthetic unit row axis is periodic (it has no halo at
    all), the real axis keeps its mode."""
    (bx,) = resolve_boundary(boundary, 1)
    return ("periodic", bx)


def launch_geometry(grid_shape, geom: SubstrateGeom, halo: int,
                    x_halo: int = 0, boundary=None) -> LaunchGeometry:
    """The launch geometry the substrate would build for ``grid_shape``
    under ``geom``: rank dispatch matching the kernels exactly (1D grids
    lift to (1, N) with strip_m=1 and zero vertical halo)."""
    if geom.dim == 1 or len(grid_shape) == 1:
        return strip_launch_geometry(
            (1, grid_shape[-1]), 1, 0, 0,
            boundary=lift_boundary_1d(boundary))
    if len(grid_shape) == 2:
        return strip_launch_geometry(
            grid_shape, geom.strip_m, geom.h_block, halo,
            geom.w_tile, geom.w_block, x_halo, boundary=boundary)
    return slab_launch_geometry(grid_shape, geom, halo, x_halo,
                                boundary=boundary)


def _assemble_foil(lg: LaunchGeometry, ins):
    """In-kernel halo assembly of the scratch-free kinds: identity for
    "flat", the 3-strip concat for "wholestrip", the 3x3 neighbor-slab
    concat for "wholeslab" (only halo-deep edges of the neighbors are
    ever read -- that is the foils' read amplification)."""
    halo = lg.halo
    if lg.kind == "flat":
        return ins[0][...]
    if lg.kind == "wholestrip":
        return assemble_strip(*ins, halo)

    def yrow(r_up, r_mid, r_dn):
        return jnp.concatenate(
            [r_up[...][:, -halo:, :], r_mid[...], r_dn[...][:, :halo, :]],
            axis=1)

    rows = [yrow(*ins[3 * i: 3 * i + 3]) for i in range(3)]
    return jnp.concatenate(
        [rows[0][-halo:], rows[1], rows[2][:halo]], axis=0)


def _edge_flags(lg: LaunchGeometry):
    """Per-region-axis (is_lo, is_hi) domain-edge flags of the current
    grid cell, traced from ``pl.program_id`` -- called INSIDE the kernel
    body.  Cell axes are the leading grid axes (the ring, when present,
    is the last); region axes beyond the cell axes span the full extent
    in every cell (full-width x), so both of their flags are statically
    True.  Non-periodic kernels gate their boundary fills on these."""
    has_ring = lg.scratch_shape is not None
    cell_axes = len(lg.grid) - (1 if has_ring else 0)
    flags = []
    for ax in range(len(lg.out_block)):
        if ax < cell_axes:
            pid = pl.program_id(ax)
            flags.append((pid == 0, pid == lg.grid[ax] - 1))
        else:
            flags.append((True, True))
    return tuple(flags)


def _launch(lg: LaunchGeometry, compute, x: jax.Array, interpret: bool,
            consts=()) -> jax.Array:
    """Execute one launch geometry: THE place every substrate kind lowers
    through.  Grid, BlockSpecs, scratch, ring slots, fire step and read
    window all come from ``lg`` -- the kernel body only dispatches on
    whether a scratch exists (foil assembly vs ring assembly).

    ``compute(cur, edges, *const_refs)`` receives the f32 halo-extended
    region and the per-axis domain-edge flags (``None`` on all-periodic
    launches, where no fill can ever fire -- keeping the default jaxpr
    bit-identical to the historical substrate)."""
    out_dtype = x.dtype
    rank = len(lg.grid)
    zero_map = _ZERO_INDEX_MAPS[rank]
    in_specs = ([pl.BlockSpec(lg.in_block, im) for im in lg.in_index_maps]
                + [pl.BlockSpec(c.shape, zero_map((0,) * c.ndim))
                   for c in consts])
    src = x
    if lg.src_shape != x.shape:
        # Edge-tile remainder path: boundary-extend + zero-pad the last
        # axis on the host so the non-wrapping column walk is in bounds
        # everywhere (DESIGN.md §10).
        src = _extend_columns_for_tiling(
            x, lg.block_dims[-1], lg.grid[-2], lg.out_block[-1],
            mode=lg.boundary[-1] if lg.boundary else "periodic")
    n_in = len(lg.in_index_maps)
    edged = lg.boundary and not lg.periodic

    if lg.scratch_shape is None:
        def kern(*refs):
            ins = refs[:n_in]
            *const_refs, out_ref = refs[n_in:]
            edges = _edge_flags(lg) if edged else None
            cur = _assemble_foil(lg, ins).astype(jnp.float32)
            out_ref[...] = compute(cur, edges, *const_refs).astype(out_dtype)

        extra = {}
    else:
        full = (slice(None),) * (len(lg.scratch_shape) - len(lg.block_dims))
        read_ix = tuple(slice(lo, hi) for lo, hi in lg.read_bounds)
        ring_axis = rank - 1
        fire = lg.fire_step

        def kern(blk_ref, *rest):
            *const_refs, out_ref, scratch_ref = rest
            j = pl.program_id(ring_axis)
            slot = tuple(pl.ds(s, b) for s, b in lg.scratch_slot(j))
            scratch_ref[slot + full] = blk_ref[...]
            # program_id must be read at kernel top level: the interpret
            # path only substitutes it outside pl.when bodies.
            edges = _edge_flags(lg) if edged else None

            @pl.when(j == fire)
            def _compute():
                cur = scratch_ref[read_ix].astype(jnp.float32)
                out_ref[...] = compute(cur, edges,
                                       *const_refs).astype(out_dtype)

        extra = {"scratch_shapes": [pltpu.VMEM(lg.scratch_shape, x.dtype)]}

    y = pl.pallas_call(
        kern,
        grid=lg.grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(lg.out_block, lg.out_index_map),
        out_shape=jax.ShapeDtypeStruct(lg.out_shape, x.dtype),
        interpret=interpret,
        **extra,
    )(*((src,) * n_in), *consts)
    if lg.out_shape != x.shape:
        y = y[..., : x.shape[-1]]
    return y


def strip_substrate_call(compute, x: jax.Array, strip_m: int, h_block: int,
                         halo: int, interpret: bool, consts=(),
                         w_tile: int = 0, w_block: int = 0,
                         x_halo: int = 0, boundary=None) -> jax.Array:
    """Launch ``compute`` over every output strip, on any halo substrate.

    The ONE place both strip kernels lower through -- substrate changes
    (semantics, buffering, a third scheme) happen here, never per kernel.
    ``compute(cur, edges, *const_refs)`` receives the f32 halo-extended
    region, the per-axis domain-edge flags (``None`` on all-periodic
    launches) plus one VMEM ref per ``consts`` operand (operands
    constant across the grid, e.g. banded weights) and returns the
    output region; the launcher casts back to ``x.dtype``.  ``boundary``
    is the per-axis mode pair threaded into the launch geometry
    (DESIGN.md §15).  ``h_block=0`` runs the
    whole-strip 3-load pipeline; otherwise the sub-blocked
    (strip, h-block) grid with VMEM scratch assembly (module docstring).
    ``halo=0`` (the lifted-1D case: no vertical support at all) drops
    the neighbor loads entirely on either substrate -- each strip
    streams only its own rows, read amplification exactly 1.

    Full width (``w_tile=0``): ``compute`` maps (strip_m + 2*halo, n) ->
    (strip_m, n) and re-wraps the x-halo in-VMEM itself (every row is a
    complete global row).  Column-tiled (``w_tile`` > 0, DESIGN.md §10):
    the grid gains a w-tile dimension and the walk covers the full
    (h_block, w_block) block ring; ``compute`` maps
    (strip_m + 2*halo, w_tile + 2*x_halo) -> (strip_m, w_tile) and must
    CARRY the ``x_halo``-deep x support (scratch rows are partial, so no
    re-wrap is possible).  Widths not divisible by ``w_tile`` run the
    edge-tile remainder path: the input is periodically extended by one
    w_block per side on the host, the column walk drops its modulo wrap,
    and the padded output columns are sliced off.
    """
    # Fault-injection hooks (repro.testing.faults): each plan traces its
    # jitted runner exactly once, so a hook here models "the Nth kernel
    # compile fails" / "the VMEM estimate lied".  No-ops unless armed.
    from repro.testing.faults import maybe_fail
    maybe_fail("compile")
    maybe_fail("vmem")

    lg = strip_launch_geometry(x.shape, strip_m, h_block, halo,
                               w_tile, w_block, x_halo, boundary=boundary)
    return _launch(lg, compute, x, interpret, consts)


def _extend_columns_for_tiling(x: jax.Array, w_block: int, gw: int,
                               w_tile: int,
                               mode: str = "periodic") -> jax.Array:
    """Edge-tile remainder path's host-side input: boundary-extend the
    last axis by one w_block per side (so the non-wrapping column walk
    still finds halo columns at both grid edges), then zero-pad on the
    right up to ``gw * w_tile + 2 * w_block`` columns so every fetched
    block is in bounds.  The pad region is only ever read by output
    columns beyond W, which the launcher slices off.

    ``mode`` generalizes the historical "periodic host extension" to a
    boundary host extension (DESIGN.md §15): non-periodic modes extend
    with their pad values -- though step-1 values are all the extension
    could supply, and the kernels re-impose the boundary in kernel at
    EVERY fused step anyway, so the non-periodic extension only has to
    be finite and in-bounds.
    """
    n = x.shape[-1]
    if mode == "periodic":
        ext = jnp.concatenate([x[..., -w_block:], x, x[..., :w_block]],
                              axis=-1)
    else:
        pad = [(0, 0)] * x.ndim
        pad[-1] = (w_block, w_block)
        ext = jnp.pad(x, pad, mode=PAD_MODE[mode])
    pad_cols = gw * w_tile - n
    if pad_cols:
        pad = [(0, 0)] * x.ndim
        pad[-1] = (0, pad_cols)
        ext = jnp.pad(ext, pad)
    return ext


def slab_substrate_call(compute, x: jax.Array, geom: SubstrateGeom,
                        halo: int, interpret: bool, consts=(),
                        x_halo: int = 0, boundary=None) -> jax.Array:
    """Launch ``compute`` over every (z-slab, strip) output cell of a 3D
    grid, on either halo-plane substrate (module docstring, DESIGN.md §9).

    The 3D analogue of ``strip_substrate_call`` -- and like it, the ONE
    place the 3D kernels lower through.
    ``compute(cur, edges, *const_refs)``
    receives the (z_slab + 2*halo, strip_m + 2*halo, W) f32 halo-extended
    slab (periodic in z and y via the modulo index maps; the x-wrap is the
    kernels' own in-VMEM job) and returns the (z_slab, strip_m, W) output
    slab.  ``geom.h_block=0`` runs the whole-slab foil: 3x3 full neighbor
    slabs referenced through nine shifted index maps (9x reads).
    Otherwise the sub-blocked scheme: ONE (z_block, h_block, W) input
    reference walks, for output cell (iz, iy), the
    (z_slab/z_block + 2) x (strip_m/h_block + 2) block ring -- own blocks
    plus the single neighbor blocks that can contain halo planes/rows --
    into a VMEM scratch of (z_slab + 2*z_block, strip_m + 2*h_block, W);
    compute fires on the ring's final block (``pl.when``).  Both paths
    assemble byte-identical extended slabs, so (with the kernels'
    optimization_barrier between assembly and compute) their outputs are
    bit-for-bit equal.

    ``geom.w_tile`` > 0 selects the column-tiled scheme (DESIGN.md §10):
    the grid gains a w-tile dimension, the input reference shrinks to
    (z_block, h_block, w_block) walking the full 3-axis block ring, and
    ``compute`` maps (z_slab + 2*halo, strip_m + 2*halo,
    w_tile + 2*x_halo) -> (z_slab, strip_m, w_tile), CARRYING the x-halo
    instead of re-wrapping (scratch rows are partial).  Widths not
    divisible by w_tile run the host-extended edge-tile remainder path.
    """
    # Same fault-injection hooks as strip_substrate_call (trace-time).
    from repro.testing.faults import maybe_fail
    maybe_fail("compile")
    maybe_fail("vmem")

    lg = slab_launch_geometry(x.shape, geom, halo, x_halo,
                              boundary=boundary)
    return _launch(lg, compute, x, interpret, consts)


def fold_batch(run, mode: str):
    """Fold a leading batch axis through a single-grid runner (DESIGN.md
    §12): the returned callable consumes ``(B,) + grid_shape`` and is
    bitwise-equal to stacking ``B`` calls of ``run``.

    ``mode="vmap"`` batches the kernels themselves -- Pallas's batching
    rule prepends a batch grid dimension, so one launch covers the whole
    bucket (the right shape on real hardware, where the extra grid
    dimension is free).  ``mode="map"`` scans ``run`` over the batch
    inside one jitted computation -- per-request VMEM working set and
    numerics are IDENTICAL to the unbatched plan, and the host dispatch +
    sync cost is paid once per bucket instead of once per request (the
    right shape under interpret mode, where emulated kernels make Python
    dispatch the bottleneck).  The serving engine picks via the plan's
    ``batch_mode`` ("auto" resolves per DESIGN.md §12).
    """
    if mode == "vmap":
        return jax.vmap(run)
    if mode == "map":
        return lambda xb: jax.lax.map(run, xb)
    raise ValueError(f"fold_batch mode must be 'vmap' or 'map', got {mode!r}")


def substrate_read_amp(strip_m: int, h_block: int) -> float:
    """Analytic grid-read amplification of one kernel launch.

    Sub-blocked substrate: each output strip streams its own rows once plus
    one h-block of each vertical neighbor -> 1 + 2*h_block/strip_m.
    Whole-strip substrate (``h_block=0``): 3 full strips -> 3.0.  ``None``
    is rejected: everywhere else in the kernel API it means "auto", which
    this function cannot resolve (it has no halo) -- resolve first
    (``choose_hblock``) or pass 0 explicitly.
    """
    if h_block is None:
        raise ValueError("h_block=None is 'auto' in the kernel API; resolve "
                         "it via choose_hblock first, or pass 0 for the "
                         "whole-strip substrate")
    if h_block == 0:
        return float(STRIP_NEIGHBOR_LOADS)
    return 1.0 + 2.0 * h_block / strip_m


def resolve_strip_blocks(grid_shape, halo: int, dtype_bytes: int,
                         tile_m: int = None, h_block: int = None,
                         w_tile: int = None, w_block: int = None,
                         x_halo: int = None) -> tuple:
    """Resolve (strip_m, h_block, w_tile, w_block) from possibly-``None``
    user requests.

    The 2D slice of the shared sizing rule -- ``resolve_substrate_geom``
    delegates its dim-2 branch here, so plan-level and kernel-level sizing
    can never drift apart.  ``tile_m=None`` sizes both jointly
    (``choose_strip_blocks``); an explicit ``tile_m`` is clamped to the
    grid and, when ``h_block`` is also ``None``, gets ``choose_hblock``
    of the clamped strip.  ``h_block=0`` passes through (whole-strip).

    Width (DESIGN.md §10): full width (w_tile=0) whenever pinned so, the
    foil substrate is requested (h_block=0, full-width by construction),
    or the full-width working set fits the VMEM budget; otherwise the
    column-tiled joint sizing ``choose_col_blocks`` runs, conditioned on
    any strip/width pins.
    """
    h, wid = grid_shape
    xh = halo if x_halo is None else x_halo
    w_tile, w_block = _normalize_w_pin(w_tile, w_block, wid)
    if w_block and w_tile is None:
        # Uniform lone-pin rejection: a w_block without a w_tile names no
        # substrate on EITHER resolution path -- acceptance must not flip
        # with the VMEM budget (the auto w_tile need not be divisible).
        _resolve_w_block(0, w_block, h_block, xh)
    budget = vmem_budget_bytes()

    def fullwidth() -> tuple:
        if tile_m is None:
            strip_m, auto_hb = choose_strip_blocks(h, wid, halo, dtype_bytes,
                                                   budget)
        else:
            strip_m, auto_hb = min(tile_m, h), None
        hb = h_block
        if hb is None:
            hb = choose_hblock(strip_m, halo) if auto_hb is None else auto_hb
        return strip_m, hb

    if w_tile == 0 or h_block == 0:
        sm, hb = fullwidth()
        # Reject lone w_block pins, and column tiling pinned onto the
        # full-width-by-construction foil substrate.
        _resolve_w_block(w_tile if h_block == 0 else 0, w_block, hb, xh)
        return sm, hb, 0, 0
    if w_tile is None:
        sm, hb = fullwidth()
        ws_hb = hb if hb else choose_hblock(sm, halo)
        if _strip_working_set(sm, ws_hb, wid, halo, dtype_bytes) <= budget:
            _resolve_w_block(0, w_block, hb, xh)
            return sm, hb, 0, 0
    sm, auto_hb, wt, auto_wb = choose_col_blocks(
        h, wid, halo, xh, dtype_bytes, budget,
        m_pin=min(tile_m, h) if tile_m is not None else None,
        w_pin=w_tile)
    hb = h_block if h_block is not None else auto_hb
    wt, wb = _resolve_w_block(wt, w_block if w_block else auto_wb, hb, xh)
    return sm, hb, wt, wb


def hbm_read_bytes_per_step(shape, strip_m: int, dtype_bytes: int,
                            bands_shape=None, h_block: int = 0,
                            w_tile: int = 0, w_block: int = 0) -> int:
    """Analytic HBM read traffic of one strip-substrate kernel launch.

    Whole-strip (``h_block=0``, the default -- this is an analytic model
    with no halo to auto-resolve from, so ``None`` is rejected just like
    ``substrate_read_amp``): each of the ``h/strip_m`` grid cells streams
    three (strip_m, n) blocks -> the grid is read 3x per step (vs 9x for
    kernels.legacy).  Sub-blocked (``h_block > 0``): each output strip
    streams ``strip_m/h_block + 2`` (h_block, n) blocks -> the grid is
    read ``1 + 2*h_block/strip_m`` times.  Column-tiled (``w_tile`` > 0,
    DESIGN.md §10): each of the ``(h/strip_m)(ceil(w/w_tile))`` output
    tiles streams its (strip_m + 2*h_block, w_tile + 2*w_block) block
    neighborhood -> the product amplification
    (1 + 2*h_block/strip_m)(1 + 2*w_block/w_tile) on aligned widths
    (the remainder path adds one partial tile column plus the one-off
    host extension, which is not per-step traffic).  The banded operand
    (if any) is charged once per output cell (its block index is
    constant within a cell's revisit chain).
    """
    import numpy as np

    h, w = shape
    gm = h // strip_m
    # One formula per axis: substrate_read_amp is the model (and rejects
    # the h_block=None 'auto' sentinel); rows = strip_m * amp is exact
    # (3*strip_m whole-strip, strip_m + 2*h_block sub-blocked).
    rows_per_strip = round(strip_m * substrate_read_amp(strip_m, h_block))
    if w_tile:
        gw = -(-w // w_tile)
        cols_per_tile = round(w_tile * substrate_read_amp(w_tile, w_block))
        cells = gm * gw
        total = cells * rows_per_strip * cols_per_tile * dtype_bytes
    else:
        cells = gm
        total = gm * rows_per_strip * w * dtype_bytes
    if bands_shape is not None:
        total += cells * int(np.prod(bands_shape)) * dtype_bytes
    return total


def hbm_read_bytes_per_step_3d(shape, geom: SubstrateGeom, dtype_bytes: int,
                               bands_shape=None) -> int:
    """Analytic HBM read traffic of one 3D slab-substrate kernel launch.

    Whole-slab foil (``geom.h_block=0``): each of the (Z/z_slab)(H/strip_m)
    cells streams 9 full (z_slab, strip_m, W) slabs -> the grid is read 9x
    per step.  Sub-blocked: each cell streams the
    (z_slab + 2*z_block)(strip_m + 2*h_block) block ring -> the grid is
    read (1 + 2*h_block/strip_m)(1 + 2*z_block/z_slab) times.
    Column-tiled (``geom.w_tile`` > 0): the x axis joins the ring and
    the amplification gains the (1 + 2*w_block/w_tile) factor
    (DESIGN.md §10).  The banded operand (if any) is charged once per
    output cell, as in 2D.
    """
    import numpy as np

    z, h, w = shape
    if geom.dim != 3:
        raise ValueError(f"3D traffic model needs a 3D geometry, got {geom}")
    cells = (z // geom.z_slab) * (h // geom.strip_m)
    planes = round(geom.z_slab
                   * substrate_read_amp(geom.z_slab, geom.z_block))
    rows = round(geom.strip_m
                 * substrate_read_amp(geom.strip_m, geom.h_block))
    if geom.w_tile:
        gw = -(-w // geom.w_tile)
        cols = round(geom.w_tile
                     * substrate_read_amp(geom.w_tile, geom.w_block))
        cells *= gw
        total = cells * planes * rows * cols * dtype_bytes
    else:
        total = cells * planes * rows * w * dtype_bytes
    if bands_shape is not None:
        total += cells * int(np.prod(bands_shape)) * dtype_bytes
    return total
