"""Shared Pallas plumbing: halo-row sub-blocked strip substrate.

TPU Pallas BlockSpecs address non-overlapping blocks (element offset = block
index * block shape), so halo reads cannot be expressed as one overlapping
block.  The seed substrate worked around that by referencing the SAME input
nine times with shifted ``index_map``s -- one full (tile_m, tile_n) block
per 2D neighbor -- which streams 9x the grid through HBM per step even
though only halo-wide edges of eight of those blocks are ever read.

PR 1 replaced that with WHOLE row strips: a 1D grid over (strip_m, N)
bands, each output strip loading itself plus its full top/bottom neighbor
strips (3 loads, modulo wrap in the index map = periodic rows), with the
horizontal periodic halo materialized in-VMEM (``wrap_columns``) at zero
HBM cost.  3x read amplification -- but the two neighbor strips are still
fetched whole although only ``halo`` rows of each are ever read.

This module now implements the halo-row SUB-BLOCKED scheme (DESIGN.md §3):

  * the grid is 2D over (strip, h-block): block height ``h_block`` divides
    ``strip_m`` (``nb = strip_m / h_block`` blocks per strip);
  * ONE input reference of block shape (h_block, N) with index map
    ``(i*nb + j - 1) mod (H/h_block)`` walks, for output strip i, the
    top neighbor's LAST h-block (j=0), the strip's own nb blocks
    (j=1..nb), and the bottom neighbor's FIRST h-block (j=nb+1) -- the
    only neighbor rows that can contain halo rows (h_block >= halo);
  * each block is copied into a VMEM scratch of (strip_m + 2*h_block, N);
    on the final j the kernel computes on the assembled halo-extended
    strip and writes the output strip (``pl.when``), so reads per strip
    are ``strip_m + 2*h_block`` rows:

        reads/step = (1 + 2*h_block/strip_m) * H*W*D

    vs 3x for whole neighbor strips and 9x for the seed scheme.  The
    modulo index map keeps periodic top/bottom boundaries for free, and
    every scratch row is still a TRUE global row, so the horizontal wrap
    re-applies to in-VMEM intermediates at every fused step -- the
    property that enables ``fused_matmul_reuse`` (DESIGN.md §4).

``h_block=0`` (or ``subblocked=False`` at the kernel level) selects the
whole-strip 3-load substrate -- kept registered as the ``*_wholestrip``
benchmark foils so ``benchmarks/traffic.py`` can measure seed / whole-strip
/ sub-blocked three ways.

N-D HALO-PLANE GENERALIZATION (DESIGN.md §9).  The scheme above is the
d=2 instance of a general halo-plane substrate:

  * 3D grids (Z, H, W) run on a (z-slab, strip, block) Pallas grid: each
    output cell is a (z_slab, strip_m, W) slab-strip, assembled from ONE
    input reference of block shape (z_block, h_block, W) whose index map
    walks the cell's own (z_slab/z_block)x(strip_m/h_block) blocks plus
    the single ring of neighbor blocks that can contain halo planes/rows
    (z_block >= halo, h_block >= halo), into a VMEM scratch of
    (z_slab + 2*z_block, strip_m + 2*h_block, W).  Reads per step:

        (1 + 2*h_block/strip_m) * (1 + 2*z_block/z_slab) * Z*H*W*D

    The last axis keeps the free in-VMEM periodic wrap (every scratch row
    is a TRUE global row), so the fused regimes carry over unchanged.
    ``h_block=0`` selects the whole-slab foil (3x3 full neighbor slabs =
    9x reads, the 3D analogue of the 2D 3-load scheme).
  * 1D grids route through the 2D substrate lifted to (1, N): the
    vertical halo is 0, so each strip streams only its own rows
    (read amplification exactly 1) and the x-wrap stays in-VMEM.

``SubstrateGeom`` carries the resolved (z_slab, z_block, strip_m,
h_block) geometry through plans, the selector and the cache keys;
``resolve_substrate_geom`` is THE shared sizing rule for every rank.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

#: Vertical neighbor offsets of the whole-strip scheme (up, center, down) --
#: the strip analogue of the seed's 9-entry 2D offset table (kernels.legacy).
NEIGHBOR_OFFSETS_STRIP = (-1, 0, 1)

#: Per-output-strip input block loads issued by the WHOLE-strip substrate.
#: The seed scheme issued 9 (kernels.legacy.NEIGHBOR_OFFSETS_2D); the
#: sub-blocked substrate issues ``strip_m/h_block + 2`` h-row blocks.
STRIP_NEIGHBOR_LOADS = len(NEIGHBOR_OFFSETS_STRIP)

#: Default VMEM working-set budget for strip sizing (bytes).  ~16 MB per
#: core on TPU v4/v5; leave half for double buffering and the output strip.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024


def strip_in_specs(strip_m: int, n: int, grid_m: int):
    """Three BlockSpecs addressing row strips (i-1, i, i+1) mod grid_m.

    The WHOLE-strip substrate: each spec covers a full-width (strip_m, n)
    band; modulo wrap in the index map yields periodic top/bottom boundaries
    for free (matching the ppermute ring of the distributed runtime).
    """
    specs = []
    for di in NEIGHBOR_OFFSETS_STRIP:
        specs.append(
            pl.BlockSpec(
                (strip_m, n),
                functools.partial(lambda i, di=di: ((i + di) % grid_m, 0)),
            )
        )
    return specs


def subblock_in_spec(h_block: int, n: int, nb: int, total_blocks: int):
    """The single h-block BlockSpec of the sub-blocked substrate.

    Grid cell (i, j), j in [0, nb+2), fetches h-block
    ``(i*nb + j - 1) mod total_blocks``: j=0 is the top neighbor strip's
    last h-block, j=1..nb the strip's own blocks, j=nb+1 the bottom
    neighbor's first h-block.  Modulo wrap = periodic rows, exactly as the
    whole-strip index maps.
    """
    return pl.BlockSpec(
        (h_block, n),
        lambda i, j: ((i * nb + j - 1) % total_blocks, 0),
    )


def subblock_store(scratch_ref, block_ref, h_block: int) -> None:
    """Copy grid cell (i, j)'s h-block into scratch rows [j*h, (j+1)*h)."""
    j = pl.program_id(1)
    scratch_ref[pl.ds(j * h_block, h_block), :] = block_ref[...]


def subblock_extended(scratch_ref, h_block: int, strip_m: int,
                      halo: int) -> jax.Array:
    """The (strip_m + 2*halo, n) halo-extended strip from assembled scratch.

    Scratch rows cover global rows [i*strip_m - h_block,
    (i+1)*strip_m + h_block); the extended strip needs only ``halo`` of the
    ``h_block`` neighbor rows at each end.
    """
    return scratch_ref[h_block - halo : h_block + strip_m + halo, :]


def assemble_strip(top_ref, mid_ref, bot_ref, halo: int) -> jax.Array:
    """Build the (strip_m + 2h, n) vertically halo-extended strip in VMEM.

    Whole-strip substrate: only the bottom ``halo`` rows of the top neighbor
    and the top ``halo`` rows of the bottom neighbor are read.
    """
    h = halo
    return jnp.concatenate(
        [top_ref[...][-h:, :], mid_ref[...], bot_ref[...][:h, :]], axis=0
    )


def wrap_columns(x: jax.Array, halo: int) -> jax.Array:
    """Materialize the periodic last-axis halo in-VMEM: (..., n) -> (..., n+2h).

    Valid whenever every row of ``x`` is a complete global row -- true for
    strips, for assembled sub-block scratch rows (2D and 3D), and for all
    intermediates derived from them, which is what lets fused kernels
    re-wrap at every step instead of carrying a 2*t*r-wide horizontal halo.
    """
    h = halo
    return jnp.concatenate([x[..., -h:], x, x[..., :h]], axis=-1)


def choose_tile(n: int, preferred: int = 128) -> int:
    """Largest divisor of ``n`` that is <= preferred (MXU-friendly when 128)."""
    if n <= preferred:
        return n
    for cand in range(preferred, 0, -1):
        if n % cand == 0:
            return cand
    return n


def choose_hblock(strip_m: int, halo: int) -> int:
    """Halo-block height: smallest divisor of strip_m >= max(halo, strip/16).

    ``h_block`` must cover the halo in one neighbor block (>= halo) and
    divide the strip.  Smaller blocks cut traffic (amplification is
    1 + 2h/strip_m) but multiply grid cells and shrink below the TPU
    sublane tile for thin strips, so we floor at strip_m/16 -- amplification
    lands at ~1.125 whenever the halo allows, and degrades gracefully
    toward the whole-strip 3x as the halo forces h_block up (h_block =
    strip_m whenever no proper divisor reaches the halo).
    """
    if strip_m <= 0:
        raise ValueError(f"strip height must be positive, got {strip_m}")
    floor = max(halo, strip_m / 16)
    cands = [d for d in range(1, strip_m + 1)
             if strip_m % d == 0 and d >= floor]
    return min(cands) if cands else strip_m


def choose_strip_blocks(
    h: int,
    n: int,
    halo: int,
    dtype_bytes: int = 4,
    vmem_budget: int = VMEM_BUDGET_BYTES,
    preferred: int = 128,
) -> tuple:
    """Jointly size (strip_m, h_block) under the VMEM budget.

    ``strip_m``: a divisor of ``h``, >= halo, fitting VMEM; among fitting
    divisors prefer the largest <= ``preferred`` (taller strips both
    amortize per-cell cost and shrink the halo read factor 1 + 2h/strip_m).
    ``h_block``: ``choose_hblock`` of the chosen strip.  The input-side
    working set is priced at the WORSE of the two substrates -- 3 full
    strips (whole-strip) vs scratch + in-flight h-block (sub-blocked) --
    so a strip that fits the budget fits whichever substrate the caller
    ends up running (the ``*_wholestrip`` foils share this sizing);
    both substrates add the horizontally-extended compute tile and the
    output strip.
    """

    def working_set(d: int) -> int:
        hb = choose_hblock(d, halo)
        inputs = max(3 * d * n, (d + 2 * hb) * n + hb * n)
        return (inputs
                + (d + 2 * halo) * (n + 2 * halo) + d * n) * dtype_bytes

    divisors = [d for d in range(1, h + 1) if h % d == 0]
    viable = [d for d in divisors if d >= halo] or [h]
    fitting = [d for d in viable if working_set(d) <= vmem_budget]
    pool = fitting or [min(viable)]
    under = [d for d in pool if d <= preferred]
    strip_m = max(under) if under else min(pool)
    return strip_m, choose_hblock(strip_m, halo)


def choose_strip(
    h: int,
    n: int,
    halo: int,
    dtype_bytes: int = 4,
    vmem_budget: int = VMEM_BUDGET_BYTES,
    preferred: int = 128,
) -> int:
    """Strip height only (see ``choose_strip_blocks`` for the joint choice)."""
    return choose_strip_blocks(h, n, halo, dtype_bytes, vmem_budget,
                               preferred)[0]


def choose_slab_blocks(
    z: int,
    h: int,
    n: int,
    halo: int,
    dtype_bytes: int = 4,
    vmem_budget: int = VMEM_BUDGET_BYTES,
    preferred: int = 128,
    z_pin: int = None,
    m_pin: int = None,
) -> tuple:
    """Jointly size the 3D geometry (z_slab, z_block, strip_m, h_block).

    ``z_slab`` divides Z and ``strip_m`` divides H, both >= halo;
    ``z_block``/``h_block`` are ``choose_hblock`` of each (smallest
    halo-covering divisor above the 1/16 floor).  The input working set is
    priced at the WORSE of the two substrates -- 9 full neighbor slabs
    (whole-slab foil) vs scratch + in-flight block (sub-blocked) -- plus
    the f32 halo-extended compute slab and the output slab, so a geometry
    that fits the budget fits whichever substrate ends up running.  Among
    fitting (z_slab, strip_m) pairs (free axes capped at ``preferred``)
    the rule minimizes the analytic read amplification
    (1 + 2*h_block/strip_m)(1 + 2*z_block/z_slab), tie-breaking toward
    fewer grid cells (larger slabs).

    ``z_pin``/``m_pin`` fix one (or both) axes to an explicit user pin:
    the search then sizes only the FREE axis, conditioned on the pinned
    value -- so a pinned strip of 1024 rows shrinks the chosen slab until
    the joint working set fits, instead of being sized as if the strip
    were auto.  Pins are exempt from the divisor/halo/``preferred``
    filters (explicit values are validated strictly by the caller).
    """

    def blocks(zs: int, sm: int) -> tuple:
        return choose_hblock(zs, halo), choose_hblock(sm, halo)

    def working_set(zs: int, sm: int) -> int:
        zb, hb = blocks(zs, sm)
        scratch = (zs + 2 * zb) * (sm + 2 * hb) * n + zb * hb * n
        whole = 9 * zs * sm * n
        inputs = max(whole, scratch)
        compute = (zs + 2 * halo) * (sm + 2 * halo) * (n + 2 * halo)
        return (inputs + compute + zs * sm * n) * dtype_bytes

    def amp(zs: int, sm: int) -> float:
        zb, hb = blocks(zs, sm)
        return substrate_read_amp(sm, hb) * substrate_read_amp(zs, zb)

    def axis_candidates(extent: int, pin: int) -> list:
        if pin is not None:
            return [pin]
        cands = [d for d in range(1, extent + 1)
                 if extent % d == 0 and d >= halo] or [extent]
        capped = [d for d in cands if d <= preferred]
        return capped or [min(cands)]

    pairs = [(zs, sm) for zs in axis_candidates(z, z_pin)
             for sm in axis_candidates(h, m_pin)]
    fitting = [p for p in pairs if working_set(*p) <= vmem_budget]
    pool = fitting or [min(pairs, key=lambda p: working_set(*p))]
    zs, sm = min(pool, key=lambda p: (amp(*p), -p[0] * p[1]))
    zb, hb = blocks(zs, sm)
    return zs, zb, sm, hb


@dataclasses.dataclass(frozen=True)
class SubstrateGeom:
    """Resolved halo-plane substrate geometry for one kernel launch.

    ``dim`` is the grid rank (1D executes lifted through the 2D substrate
    with strip_m=1 and zero vertical halo).  ``h_block=0`` selects the
    whole-strip/whole-slab foil substrate (and forces ``z_block=0``);
    otherwise both block heights are >= the halo and divide their tile.
    """

    dim: int
    strip_m: int
    h_block: int                 # 0 = whole-strip/whole-slab foil
    z_slab: int = 1              # 3D only; 1 otherwise
    z_block: int = 0             # 3D only; 0 = whole-slab (with h_block=0)

    @property
    def read_amp(self) -> float:
        """Analytic grid-read amplification of this geometry (DESIGN.md §9):
        1 (lifted 1D), 1 + 2h/strip_m (2D), the product
        (1 + 2h/strip_m)(1 + 2z_block/z_slab) (3D); the foils read 3x (2D)
        and 9x (3D)."""
        if self.dim == 1:
            return 1.0
        amp = substrate_read_amp(self.strip_m, self.h_block)
        if self.dim == 3:
            amp *= substrate_read_amp(self.z_slab, self.z_block)
        return amp

    def describe(self) -> str:
        """The substrate clause of decision reason strings -- formatted
        from resolved numbers only, so ``ops.explain`` and plan decisions
        agree verbatim whenever they resolve the same geometry."""
        if self.dim == 3:
            geo = (f"z_slab={self.z_slab}, z_block={self.z_block}, "
                   f"strip_m={self.strip_m}, h_block={self.h_block}")
        elif self.dim == 1:
            geo = f"1D lifted, strip_m={self.strip_m}"
        else:
            geo = f"strip_m={self.strip_m}, h_block={self.h_block}"
        return f"substrate read_amp={self.read_amp:.3f}x ({geo})"


def _resolve_z_block(h_block: int, z_block: int, z_slab: int,
                     halo: int) -> int:
    """z_block under the shared pin rules: forced 0 by the whole foil
    (h_block=0), rejected as a lone 0 (no hybrid substrate exists),
    otherwise the explicit pin or ``choose_hblock`` of the slab.  Both
    ``resolve_substrate_geom`` and ``pricing_geom`` route through here, so
    plan building and grid-free pricing can never disagree on the rule.
    """
    if h_block == 0:
        return 0
    if z_block == 0:
        raise ValueError(
            "z_block=0 (whole-slab) is only valid together with "
            "h_block=0 (the whole-slab foil substrate)")
    return z_block if z_block is not None else choose_hblock(z_slab, halo)


def pricing_geom(dim: int, halo: int, strip_m: int = 128,
                 h_block: int = None, z_slab: int = None,
                 z_block: int = None) -> SubstrateGeom:
    """Grid-free geometry resolution for pricing paths (the selector has
    no grid to size against): dim 1 is always the lifted substrate; dim 2
    takes ``strip_m`` as given with ``choose_hblock`` filling ``h_block``;
    dim 3 defaults ``z_slab`` to ``strip_m`` and resolves ``z_block``
    under the same shared rule as ``resolve_substrate_geom``."""
    if dim == 1:
        return SubstrateGeom(dim=1, strip_m=1, h_block=1)
    hb = choose_hblock(strip_m, halo) if h_block is None else h_block
    if dim == 2:
        return SubstrateGeom(dim=2, strip_m=strip_m, h_block=hb)
    if dim != 3:
        raise ValueError(f"substrate supports 1D/2D/3D grids, got dim {dim}")
    zs = strip_m if z_slab is None else z_slab
    zb = _resolve_z_block(hb, z_block, zs, halo)
    return SubstrateGeom(dim=3, strip_m=strip_m, h_block=hb,
                         z_slab=zs, z_block=zb)


def resolve_substrate_geom(grid_shape, halo: int, dtype_bytes: int,
                           tile_m: int = None, h_block: int = None,
                           z_slab: int = None,
                           z_block: int = None) -> SubstrateGeom:
    """Resolve the full substrate geometry from possibly-``None`` requests.

    THE shared N-D auto-sizing rule: the kernels, ``stencil_plan`` pricing
    and ``registry.PlanContext.resolve_geom`` all call this, so plan-level
    and kernel-level sizing can never drift apart.  Rank comes from
    ``len(grid_shape)``:

      * 1D: lifted 2D geometry (strip_m=1, zero vertical halo, read amp 1);
      * 2D: exactly ``resolve_strip_blocks`` (z fields stay inert);
      * 3D: joint ``choose_slab_blocks`` when unpinned; explicit ``tile_m``
        / ``z_slab`` are clamped to the grid and get ``choose_hblock``
        blocks unless those are pinned too.  ``h_block=0`` selects the
        whole-slab foil and forces ``z_block=0``; a lone ``z_block=0``
        under a sub-blocked h_block is rejected (no hybrid substrate).
    """
    dim = len(grid_shape)
    if dim == 1:
        hb = 0 if h_block == 0 else 1
        return SubstrateGeom(dim=1, strip_m=1, h_block=hb)
    if dim == 2:
        strip_m, hb = resolve_strip_blocks(grid_shape, halo, dtype_bytes,
                                           tile_m, h_block)
        return SubstrateGeom(dim=2, strip_m=strip_m, h_block=hb)
    if dim != 3:
        raise ValueError(f"substrate supports 1D/2D/3D grids, got rank {dim}")
    z, h, _ = grid_shape
    # One pin-aware joint search: a pinned axis is fixed (clamped to the
    # grid) and only the free axis is sized -- conditioned on the pin, so
    # the VMEM fit and amp-minimization always describe the geometry that
    # actually runs.
    zs, auto_zb, sm, auto_hb = choose_slab_blocks(
        z, h, grid_shape[-1], halo, dtype_bytes,
        z_pin=min(z_slab, z) if z_slab is not None else None,
        m_pin=min(tile_m, h) if tile_m is not None else None)
    hb = h_block if h_block is not None else auto_hb
    zb = _resolve_z_block(hb, z_block, zs, halo)
    return SubstrateGeom(dim=3, strip_m=sm, h_block=hb, z_slab=zs, z_block=zb)


def validate_tiling(shape, strip_m: int, tile_n: int, halo: int,
                    radius: int = None, h_block: int = None,
                    z_slab: int = None, z_block: int = None) -> None:
    """Halo-plane substrate tiling constraints (1D, 2D and 3D grids).

    ``strip_m`` is the strip height (rows per output block); ``tile_n`` is
    the column-tile width of the banded MXU contraction (pass the full width
    for the VPU path, which never column-tiles).  ``radius`` is the per-step
    wrap radius -- the only width constraint, since the horizontal halo is
    re-wrapped at radius r each step regardless of fusion depth (defaults
    to ``halo`` for callers that run a single step at the full radius).
    ``h_block`` (sub-blocked substrate) must divide ``strip_m`` and cover
    the vertical halo; pass ``None``/0 for the whole-strip substrate.
    3D grids additionally constrain ``z_slab`` (divides Z, >= halo) and
    ``z_block`` (divides ``z_slab``, >= halo when sub-blocked).
    """
    if len(shape) == 1:
        # Lifted-1D: no vertical support, so only the wrap radius binds.
        w = shape[0]
        r = halo if radius is None else radius
        if w < r:
            raise ValueError(
                f"wrap radius {r} exceeds grid width {w}; lower the radius")
        return
    if len(shape) == 2:
        h, w = shape
    else:
        z, h, w = shape
        zs = z if z_slab is None else z_slab
        if z % zs:
            raise ValueError(
                f"grid depth {z} not divisible by z_slab {zs}")
        if zs < halo:
            raise ValueError(
                f"halo {halo} exceeds z_slab {zs}; "
                "lower fusion depth or enlarge slabs")
        if z_block:
            if zs % z_block:
                raise ValueError(
                    f"z_block {z_block} does not divide z_slab {zs}")
            if z_block < halo:
                raise ValueError(
                    f"halo {halo} exceeds z_block {z_block}; "
                    "enlarge z_block or lower fusion depth")
    if h % strip_m or w % tile_n:
        raise ValueError(
            f"grid {shape} not divisible by tiles ({strip_m},{tile_n})"
        )
    if strip_m < halo:
        raise ValueError(
            f"halo {halo} exceeds strip height {strip_m}; "
            "lower fusion depth or enlarge strips"
        )
    if h_block:
        if strip_m % h_block:
            raise ValueError(
                f"h_block {h_block} does not divide strip height {strip_m}"
            )
        if h_block < halo:
            raise ValueError(
                f"halo {halo} exceeds h_block {h_block}; "
                "enlarge h_block or lower fusion depth"
            )
    r = halo if radius is None else radius
    if w < r:
        raise ValueError(
            f"wrap radius {r} exceeds grid width {w}; lower the radius"
        )


def strip_substrate_call(compute, x: jax.Array, strip_m: int, h_block: int,
                         halo: int, interpret: bool, consts=()) -> jax.Array:
    """Launch ``compute`` over every output strip, on either halo substrate.

    The ONE place both strip kernels lower through -- substrate changes
    (semantics, buffering, a third scheme) happen here, never per kernel.
    ``compute(cur, *const_refs)`` receives the (strip_m + 2*halo, n) f32
    halo-extended strip plus one VMEM ref per ``consts`` operand (operands
    constant across the grid, e.g. banded weights) and returns the
    (strip_m, n) f32 output strip; the launcher casts back to ``x.dtype``.
    ``h_block=0`` runs the whole-strip 3-load pipeline; otherwise the
    sub-blocked (strip, h-block) grid with VMEM scratch assembly (module
    docstring).  ``halo=0`` (the lifted-1D case: no vertical support at
    all) drops the neighbor loads entirely on either substrate -- each
    strip streams only its own rows, read amplification exactly 1.
    """
    h, n = x.shape
    gm = h // strip_m
    out_dtype = x.dtype

    def const_spec(c, n_grid_dims):
        zeros = (0,) * c.ndim
        if n_grid_dims == 1:
            return pl.BlockSpec(c.shape, lambda i, z=zeros: z)
        return pl.BlockSpec(c.shape, lambda i, j, z=zeros: z)

    if halo == 0:
        # No vertical halo => no neighbor strips to fetch; one load per
        # strip on both substrates (they coincide here).
        def kern_flat(mid_ref, *rest):
            *const_refs, out_ref = rest
            cur = mid_ref[...].astype(jnp.float32)
            out_ref[...] = compute(cur, *const_refs).astype(out_dtype)

        return pl.pallas_call(
            kern_flat,
            grid=(gm,),
            in_specs=[pl.BlockSpec((strip_m, n), lambda i: (i, 0))]
            + [const_spec(c, 1) for c in consts],
            out_specs=pl.BlockSpec((strip_m, n), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=interpret,
        )(x, *consts)

    if not h_block:
        def kern_strip(top_ref, mid_ref, bot_ref, *rest):
            *const_refs, out_ref = rest
            cur = assemble_strip(top_ref, mid_ref, bot_ref,
                                 halo).astype(jnp.float32)
            out_ref[...] = compute(cur, *const_refs).astype(out_dtype)

        return pl.pallas_call(
            kern_strip,
            grid=(gm,),
            in_specs=strip_in_specs(strip_m, n, gm)
            + [const_spec(c, 1) for c in consts],
            out_specs=pl.BlockSpec((strip_m, n), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=interpret,
        )(x, x, x, *consts)

    nb = strip_m // h_block

    def kern_sub(blk_ref, *rest):
        *const_refs, out_ref, scratch_ref = rest
        subblock_store(scratch_ref, blk_ref, h_block)

        @pl.when(pl.program_id(1) == nb + 1)
        def _compute():
            cur = subblock_extended(scratch_ref, h_block, strip_m,
                                    halo).astype(jnp.float32)
            out_ref[...] = compute(cur, *const_refs).astype(out_dtype)

    return pl.pallas_call(
        kern_sub,
        grid=(gm, nb + 2),
        in_specs=[subblock_in_spec(h_block, n, nb, h // h_block)]
        + [const_spec(c, 2) for c in consts],
        out_specs=pl.BlockSpec((strip_m, n), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((strip_m + 2 * h_block, n), x.dtype)],
        interpret=interpret,
    )(x, *consts)


def slab_substrate_call(compute, x: jax.Array, geom: SubstrateGeom,
                        halo: int, interpret: bool, consts=()) -> jax.Array:
    """Launch ``compute`` over every (z-slab, strip) output cell of a 3D
    grid, on either halo-plane substrate (module docstring, DESIGN.md §9).

    The 3D analogue of ``strip_substrate_call`` -- and like it, the ONE
    place the 3D kernels lower through.  ``compute(cur, *const_refs)``
    receives the (z_slab + 2*halo, strip_m + 2*halo, W) f32 halo-extended
    slab (periodic in z and y via the modulo index maps; the x-wrap is the
    kernels' own in-VMEM job) and returns the (z_slab, strip_m, W) output
    slab.  ``geom.h_block=0`` runs the whole-slab foil: 3x3 full neighbor
    slabs referenced through nine shifted index maps (9x reads).
    Otherwise the sub-blocked scheme: ONE (z_block, h_block, W) input
    reference walks, for output cell (iz, iy), the
    (z_slab/z_block + 2) x (strip_m/h_block + 2) block ring -- own blocks
    plus the single neighbor blocks that can contain halo planes/rows --
    into a VMEM scratch of (z_slab + 2*z_block, strip_m + 2*h_block, W);
    compute fires on the ring's final block (``pl.when``).  Both paths
    assemble byte-identical extended slabs, so (with the kernels'
    optimization_barrier between assembly and compute) their outputs are
    bit-for-bit equal.
    """
    z, h, n = x.shape
    zs, sm = geom.z_slab, geom.strip_m
    gz, gm = z // zs, h // sm
    out_dtype = x.dtype

    def const_spec(c, n_grid_dims):
        zeros = (0,) * c.ndim
        if n_grid_dims == 2:
            return pl.BlockSpec(c.shape, lambda i, j, zz=zeros: zz)
        return pl.BlockSpec(c.shape, lambda i, j, k, zz=zeros: zz)

    if not geom.h_block:
        def slab_spec(dz, dy):
            return pl.BlockSpec(
                (zs, sm, n),
                functools.partial(
                    lambda iz, iy, dz=dz, dy=dy:
                    ((iz + dz) % gz, (iy + dy) % gm, 0)),
            )

        def kern_whole(*refs):
            nbr = refs[:9]
            *const_refs, out_ref = refs[9:]

            def yrow(r_up, r_mid, r_dn):
                return jnp.concatenate(
                    [r_up[...][:, -halo:, :], r_mid[...],
                     r_dn[...][:, :halo, :]], axis=1)

            rows = [yrow(*nbr[3 * i: 3 * i + 3]) for i in range(3)]
            cur = jnp.concatenate(
                [rows[0][-halo:], rows[1], rows[2][:halo]],
                axis=0).astype(jnp.float32)
            out_ref[...] = compute(cur, *const_refs).astype(out_dtype)

        return pl.pallas_call(
            kern_whole,
            grid=(gz, gm),
            in_specs=[slab_spec(dz, dy)
                      for dz in (-1, 0, 1) for dy in (-1, 0, 1)]
            + [const_spec(c, 2) for c in consts],
            out_specs=pl.BlockSpec((zs, sm, n), lambda iz, iy: (iz, iy, 0)),
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=interpret,
        )(*([x] * 9), *consts)

    zb, hb = geom.z_block, geom.h_block
    nbz, nby = zs // zb, sm // hb
    ring_y = nby + 2
    nj = (nbz + 2) * ring_y
    total_z, total_y = z // zb, h // hb

    def block_index(iz, iy, j):
        jz, jy = j // ring_y, j % ring_y
        return ((iz * nbz + jz - 1) % total_z,
                (iy * nby + jy - 1) % total_y, 0)

    def kern_sub(blk_ref, *rest):
        *const_refs, out_ref, scratch_ref = rest
        j = pl.program_id(2)
        jz, jy = j // ring_y, j % ring_y
        scratch_ref[pl.ds(jz * zb, zb), pl.ds(jy * hb, hb), :] = blk_ref[...]

        @pl.when(j == nj - 1)
        def _compute():
            cur = scratch_ref[zb - halo: zb + zs + halo,
                              hb - halo: hb + sm + halo,
                              :].astype(jnp.float32)
            out_ref[...] = compute(cur, *const_refs).astype(out_dtype)

    return pl.pallas_call(
        kern_sub,
        grid=(gz, gm, nj),
        in_specs=[pl.BlockSpec((zb, hb, n), block_index)]
        + [const_spec(c, 3) for c in consts],
        out_specs=pl.BlockSpec((zs, sm, n), lambda iz, iy, j: (iz, iy, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((zs + 2 * zb, sm + 2 * hb, n), x.dtype)],
        interpret=interpret,
    )(x, *consts)


def substrate_read_amp(strip_m: int, h_block: int) -> float:
    """Analytic grid-read amplification of one kernel launch.

    Sub-blocked substrate: each output strip streams its own rows once plus
    one h-block of each vertical neighbor -> 1 + 2*h_block/strip_m.
    Whole-strip substrate (``h_block=0``): 3 full strips -> 3.0.  ``None``
    is rejected: everywhere else in the kernel API it means "auto", which
    this function cannot resolve (it has no halo) -- resolve first
    (``choose_hblock``) or pass 0 explicitly.
    """
    if h_block is None:
        raise ValueError("h_block=None is 'auto' in the kernel API; resolve "
                         "it via choose_hblock first, or pass 0 for the "
                         "whole-strip substrate")
    if h_block == 0:
        return float(STRIP_NEIGHBOR_LOADS)
    return 1.0 + 2.0 * h_block / strip_m


def resolve_strip_blocks(grid_shape, halo: int, dtype_bytes: int,
                         tile_m: int = None, h_block: int = None) -> tuple:
    """Resolve (strip_m, h_block) from possibly-``None`` user requests.

    The 2D slice of the shared sizing rule -- ``resolve_substrate_geom``
    delegates its dim-2 branch here, so plan-level and kernel-level sizing
    can never drift apart.  ``tile_m=None`` sizes both jointly
    (``choose_strip_blocks``); an explicit ``tile_m`` is clamped to the
    grid and, when ``h_block`` is also ``None``, gets ``choose_hblock``
    of the clamped strip.  ``h_block=0`` passes through (whole-strip).
    """
    h, wid = grid_shape
    if tile_m is None:
        strip_m, auto_hb = choose_strip_blocks(h, wid, halo, dtype_bytes)
    else:
        strip_m, auto_hb = min(tile_m, h), None
    if h_block is None:
        h_block = choose_hblock(strip_m, halo) if auto_hb is None else auto_hb
    return strip_m, h_block


def hbm_read_bytes_per_step(shape, strip_m: int, dtype_bytes: int,
                            bands_shape=None, h_block: int = 0) -> int:
    """Analytic HBM read traffic of one strip-substrate kernel launch.

    Whole-strip (``h_block=0``, the default -- this is an analytic model
    with no halo to auto-resolve from, so ``None`` is rejected just like
    ``substrate_read_amp``): each of the ``h/strip_m`` grid cells streams
    three (strip_m, n) blocks -> the grid is read 3x per step (vs 9x for
    kernels.legacy).  Sub-blocked (``h_block > 0``): each output strip
    streams ``strip_m/h_block + 2`` (h_block, n) blocks -> the grid is
    read ``1 + 2*h_block/strip_m`` times.  The banded operand (if any) is
    charged once per output strip (its block index is constant within a
    strip's revisit chain).
    """
    import numpy as np

    h, w = shape
    gm = h // strip_m
    # One formula for both substrates: substrate_read_amp is the model (and
    # rejects the h_block=None 'auto' sentinel); rows = strip_m * amp is
    # exact (3*strip_m whole-strip, strip_m + 2*h_block sub-blocked).
    rows_per_strip = round(strip_m * substrate_read_amp(strip_m, h_block))
    total = gm * rows_per_strip * w * dtype_bytes
    if bands_shape is not None:
        total += gm * int(np.prod(bands_shape)) * dtype_bytes
    return total


def hbm_read_bytes_per_step_3d(shape, geom: SubstrateGeom, dtype_bytes: int,
                               bands_shape=None) -> int:
    """Analytic HBM read traffic of one 3D slab-substrate kernel launch.

    Whole-slab foil (``geom.h_block=0``): each of the (Z/z_slab)(H/strip_m)
    cells streams 9 full (z_slab, strip_m, W) slabs -> the grid is read 9x
    per step.  Sub-blocked: each cell streams the
    (z_slab + 2*z_block)(strip_m + 2*h_block) block ring -> the grid is
    read (1 + 2*h_block/strip_m)(1 + 2*z_block/z_slab) times.  The banded
    operand (if any) is charged once per output cell, as in 2D.
    """
    import numpy as np

    z, h, w = shape
    if geom.dim != 3:
        raise ValueError(f"3D traffic model needs a 3D geometry, got {geom}")
    cells = (z // geom.z_slab) * (h // geom.strip_m)
    planes = round(geom.z_slab
                   * substrate_read_amp(geom.z_slab, geom.z_block))
    rows = round(geom.strip_m
                 * substrate_read_amp(geom.strip_m, geom.h_block))
    total = cells * planes * rows * w * dtype_bytes
    if bands_shape is not None:
        total += cells * int(np.prod(bands_shape)) * dtype_bytes
    return total
