"""Shared Pallas plumbing: halo-row sub-blocked strip substrate.

TPU Pallas BlockSpecs address non-overlapping blocks (element offset = block
index * block shape), so halo reads cannot be expressed as one overlapping
block.  The seed substrate worked around that by referencing the SAME input
nine times with shifted ``index_map``s -- one full (tile_m, tile_n) block
per 2D neighbor -- which streams 9x the grid through HBM per step even
though only halo-wide edges of eight of those blocks are ever read.

PR 1 replaced that with WHOLE row strips: a 1D grid over (strip_m, N)
bands, each output strip loading itself plus its full top/bottom neighbor
strips (3 loads, modulo wrap in the index map = periodic rows), with the
horizontal periodic halo materialized in-VMEM (``wrap_columns``) at zero
HBM cost.  3x read amplification -- but the two neighbor strips are still
fetched whole although only ``halo`` rows of each are ever read.

This module now implements the halo-row SUB-BLOCKED scheme (DESIGN.md §3):

  * the grid is 2D over (strip, h-block): block height ``h_block`` divides
    ``strip_m`` (``nb = strip_m / h_block`` blocks per strip);
  * ONE input reference of block shape (h_block, N) with index map
    ``(i*nb + j - 1) mod (H/h_block)`` walks, for output strip i, the
    top neighbor's LAST h-block (j=0), the strip's own nb blocks
    (j=1..nb), and the bottom neighbor's FIRST h-block (j=nb+1) -- the
    only neighbor rows that can contain halo rows (h_block >= halo);
  * each block is copied into a VMEM scratch of (strip_m + 2*h_block, N);
    on the final j the kernel computes on the assembled halo-extended
    strip and writes the output strip (``pl.when``), so reads per strip
    are ``strip_m + 2*h_block`` rows:

        reads/step = (1 + 2*h_block/strip_m) * H*W*D

    vs 3x for whole neighbor strips and 9x for the seed scheme.  The
    modulo index map keeps periodic top/bottom boundaries for free, and
    every scratch row is still a TRUE global row, so the horizontal wrap
    re-applies to in-VMEM intermediates at every fused step -- the
    property that enables ``fused_matmul_reuse`` (DESIGN.md §4).

``h_block=0`` (or ``subblocked=False`` at the kernel level) selects the
whole-strip 3-load substrate -- kept registered as the ``*_wholestrip``
benchmark foils so ``benchmarks/traffic.py`` can measure seed / whole-strip
/ sub-blocked three ways.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

#: Vertical neighbor offsets of the whole-strip scheme (up, center, down) --
#: the strip analogue of the seed's 9-entry 2D offset table (kernels.legacy).
NEIGHBOR_OFFSETS_STRIP = (-1, 0, 1)

#: Per-output-strip input block loads issued by the WHOLE-strip substrate.
#: The seed scheme issued 9 (kernels.legacy.NEIGHBOR_OFFSETS_2D); the
#: sub-blocked substrate issues ``strip_m/h_block + 2`` h-row blocks.
STRIP_NEIGHBOR_LOADS = len(NEIGHBOR_OFFSETS_STRIP)

#: Default VMEM working-set budget for strip sizing (bytes).  ~16 MB per
#: core on TPU v4/v5; leave half for double buffering and the output strip.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024


def strip_in_specs(strip_m: int, n: int, grid_m: int):
    """Three BlockSpecs addressing row strips (i-1, i, i+1) mod grid_m.

    The WHOLE-strip substrate: each spec covers a full-width (strip_m, n)
    band; modulo wrap in the index map yields periodic top/bottom boundaries
    for free (matching the ppermute ring of the distributed runtime).
    """
    specs = []
    for di in NEIGHBOR_OFFSETS_STRIP:
        specs.append(
            pl.BlockSpec(
                (strip_m, n),
                functools.partial(lambda i, di=di: ((i + di) % grid_m, 0)),
            )
        )
    return specs


def subblock_in_spec(h_block: int, n: int, nb: int, total_blocks: int):
    """The single h-block BlockSpec of the sub-blocked substrate.

    Grid cell (i, j), j in [0, nb+2), fetches h-block
    ``(i*nb + j - 1) mod total_blocks``: j=0 is the top neighbor strip's
    last h-block, j=1..nb the strip's own blocks, j=nb+1 the bottom
    neighbor's first h-block.  Modulo wrap = periodic rows, exactly as the
    whole-strip index maps.
    """
    return pl.BlockSpec(
        (h_block, n),
        lambda i, j: ((i * nb + j - 1) % total_blocks, 0),
    )


def subblock_store(scratch_ref, block_ref, h_block: int) -> None:
    """Copy grid cell (i, j)'s h-block into scratch rows [j*h, (j+1)*h)."""
    j = pl.program_id(1)
    scratch_ref[pl.ds(j * h_block, h_block), :] = block_ref[...]


def subblock_extended(scratch_ref, h_block: int, strip_m: int,
                      halo: int) -> jax.Array:
    """The (strip_m + 2*halo, n) halo-extended strip from assembled scratch.

    Scratch rows cover global rows [i*strip_m - h_block,
    (i+1)*strip_m + h_block); the extended strip needs only ``halo`` of the
    ``h_block`` neighbor rows at each end.
    """
    return scratch_ref[h_block - halo : h_block + strip_m + halo, :]


def assemble_strip(top_ref, mid_ref, bot_ref, halo: int) -> jax.Array:
    """Build the (strip_m + 2h, n) vertically halo-extended strip in VMEM.

    Whole-strip substrate: only the bottom ``halo`` rows of the top neighbor
    and the top ``halo`` rows of the bottom neighbor are read.
    """
    h = halo
    return jnp.concatenate(
        [top_ref[...][-h:, :], mid_ref[...], bot_ref[...][:h, :]], axis=0
    )


def wrap_columns(x: jax.Array, halo: int) -> jax.Array:
    """Materialize the periodic horizontal halo in-VMEM: (m, n) -> (m, n+2h).

    Valid whenever every row of ``x`` is a complete global row -- true for
    strips, for assembled sub-block scratch rows, and for all intermediates
    derived from them, which is what lets fused kernels re-wrap at every
    step instead of carrying a 2*t*r-wide horizontal halo.
    """
    h = halo
    return jnp.concatenate([x[:, -h:], x, x[:, :h]], axis=1)


def choose_tile(n: int, preferred: int = 128) -> int:
    """Largest divisor of ``n`` that is <= preferred (MXU-friendly when 128)."""
    if n <= preferred:
        return n
    for cand in range(preferred, 0, -1):
        if n % cand == 0:
            return cand
    return n


def choose_hblock(strip_m: int, halo: int) -> int:
    """Halo-block height: smallest divisor of strip_m >= max(halo, strip/16).

    ``h_block`` must cover the halo in one neighbor block (>= halo) and
    divide the strip.  Smaller blocks cut traffic (amplification is
    1 + 2h/strip_m) but multiply grid cells and shrink below the TPU
    sublane tile for thin strips, so we floor at strip_m/16 -- amplification
    lands at ~1.125 whenever the halo allows, and degrades gracefully
    toward the whole-strip 3x as the halo forces h_block up (h_block =
    strip_m whenever no proper divisor reaches the halo).
    """
    if strip_m <= 0:
        raise ValueError(f"strip height must be positive, got {strip_m}")
    floor = max(halo, strip_m / 16)
    cands = [d for d in range(1, strip_m + 1)
             if strip_m % d == 0 and d >= floor]
    return min(cands) if cands else strip_m


def choose_strip_blocks(
    h: int,
    n: int,
    halo: int,
    dtype_bytes: int = 4,
    vmem_budget: int = VMEM_BUDGET_BYTES,
    preferred: int = 128,
) -> tuple:
    """Jointly size (strip_m, h_block) under the VMEM budget.

    ``strip_m``: a divisor of ``h``, >= halo, fitting VMEM; among fitting
    divisors prefer the largest <= ``preferred`` (taller strips both
    amortize per-cell cost and shrink the halo read factor 1 + 2h/strip_m).
    ``h_block``: ``choose_hblock`` of the chosen strip.  The input-side
    working set is priced at the WORSE of the two substrates -- 3 full
    strips (whole-strip) vs scratch + in-flight h-block (sub-blocked) --
    so a strip that fits the budget fits whichever substrate the caller
    ends up running (the ``*_wholestrip`` foils share this sizing);
    both substrates add the horizontally-extended compute tile and the
    output strip.
    """

    def working_set(d: int) -> int:
        hb = choose_hblock(d, halo)
        inputs = max(3 * d * n, (d + 2 * hb) * n + hb * n)
        return (inputs
                + (d + 2 * halo) * (n + 2 * halo) + d * n) * dtype_bytes

    divisors = [d for d in range(1, h + 1) if h % d == 0]
    viable = [d for d in divisors if d >= halo] or [h]
    fitting = [d for d in viable if working_set(d) <= vmem_budget]
    pool = fitting or [min(viable)]
    under = [d for d in pool if d <= preferred]
    strip_m = max(under) if under else min(pool)
    return strip_m, choose_hblock(strip_m, halo)


def choose_strip(
    h: int,
    n: int,
    halo: int,
    dtype_bytes: int = 4,
    vmem_budget: int = VMEM_BUDGET_BYTES,
    preferred: int = 128,
) -> int:
    """Strip height only (see ``choose_strip_blocks`` for the joint choice)."""
    return choose_strip_blocks(h, n, halo, dtype_bytes, vmem_budget,
                               preferred)[0]


def validate_tiling(shape, strip_m: int, tile_n: int, halo: int,
                    radius: int = None, h_block: int = None) -> None:
    """Strip-substrate tiling constraints.

    ``strip_m`` is the strip height (rows per output block); ``tile_n`` is
    the column-tile width of the banded MXU contraction (pass the full width
    for the VPU path, which never column-tiles).  ``radius`` is the per-step
    wrap radius -- the only width constraint, since the horizontal halo is
    re-wrapped at radius r each step regardless of fusion depth (defaults
    to ``halo`` for callers that run a single step at the full radius).
    ``h_block`` (sub-blocked substrate) must divide ``strip_m`` and cover
    the vertical halo; pass ``None``/0 for the whole-strip substrate.
    """
    h, w = shape
    if h % strip_m or w % tile_n:
        raise ValueError(
            f"grid {shape} not divisible by tiles ({strip_m},{tile_n})"
        )
    if strip_m < halo:
        raise ValueError(
            f"halo {halo} exceeds strip height {strip_m}; "
            "lower fusion depth or enlarge strips"
        )
    if h_block:
        if strip_m % h_block:
            raise ValueError(
                f"h_block {h_block} does not divide strip height {strip_m}"
            )
        if h_block < halo:
            raise ValueError(
                f"halo {halo} exceeds h_block {h_block}; "
                "enlarge h_block or lower fusion depth"
            )
    r = halo if radius is None else radius
    if w < r:
        raise ValueError(
            f"wrap radius {r} exceeds grid width {w}; lower the radius"
        )


def strip_substrate_call(compute, x: jax.Array, strip_m: int, h_block: int,
                         halo: int, interpret: bool, consts=()) -> jax.Array:
    """Launch ``compute`` over every output strip, on either halo substrate.

    The ONE place both strip kernels lower through -- substrate changes
    (semantics, buffering, a third scheme) happen here, never per kernel.
    ``compute(cur, *const_refs)`` receives the (strip_m + 2*halo, n) f32
    halo-extended strip plus one VMEM ref per ``consts`` operand (operands
    constant across the grid, e.g. banded weights) and returns the
    (strip_m, n) f32 output strip; the launcher casts back to ``x.dtype``.
    ``h_block=0`` runs the whole-strip 3-load pipeline; otherwise the
    sub-blocked (strip, h-block) grid with VMEM scratch assembly (module
    docstring).
    """
    h, n = x.shape
    gm = h // strip_m
    out_dtype = x.dtype

    def const_spec(c, n_grid_dims):
        zeros = (0,) * c.ndim
        if n_grid_dims == 1:
            return pl.BlockSpec(c.shape, lambda i, z=zeros: z)
        return pl.BlockSpec(c.shape, lambda i, j, z=zeros: z)

    if not h_block:
        def kern_strip(top_ref, mid_ref, bot_ref, *rest):
            *const_refs, out_ref = rest
            cur = assemble_strip(top_ref, mid_ref, bot_ref,
                                 halo).astype(jnp.float32)
            out_ref[...] = compute(cur, *const_refs).astype(out_dtype)

        return pl.pallas_call(
            kern_strip,
            grid=(gm,),
            in_specs=strip_in_specs(strip_m, n, gm)
            + [const_spec(c, 1) for c in consts],
            out_specs=pl.BlockSpec((strip_m, n), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=interpret,
        )(x, x, x, *consts)

    nb = strip_m // h_block

    def kern_sub(blk_ref, *rest):
        *const_refs, out_ref, scratch_ref = rest
        subblock_store(scratch_ref, blk_ref, h_block)

        @pl.when(pl.program_id(1) == nb + 1)
        def _compute():
            cur = subblock_extended(scratch_ref, h_block, strip_m,
                                    halo).astype(jnp.float32)
            out_ref[...] = compute(cur, *const_refs).astype(out_dtype)

    return pl.pallas_call(
        kern_sub,
        grid=(gm, nb + 2),
        in_specs=[subblock_in_spec(h_block, n, nb, h // h_block)]
        + [const_spec(c, 2) for c in consts],
        out_specs=pl.BlockSpec((strip_m, n), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((strip_m + 2 * h_block, n), x.dtype)],
        interpret=interpret,
    )(x, *consts)


def substrate_read_amp(strip_m: int, h_block: int) -> float:
    """Analytic grid-read amplification of one kernel launch.

    Sub-blocked substrate: each output strip streams its own rows once plus
    one h-block of each vertical neighbor -> 1 + 2*h_block/strip_m.
    Whole-strip substrate (``h_block=0``): 3 full strips -> 3.0.  ``None``
    is rejected: everywhere else in the kernel API it means "auto", which
    this function cannot resolve (it has no halo) -- resolve first
    (``choose_hblock``) or pass 0 explicitly.
    """
    if h_block is None:
        raise ValueError("h_block=None is 'auto' in the kernel API; resolve "
                         "it via choose_hblock first, or pass 0 for the "
                         "whole-strip substrate")
    if h_block == 0:
        return float(STRIP_NEIGHBOR_LOADS)
    return 1.0 + 2.0 * h_block / strip_m


def resolve_strip_blocks(grid_shape, halo: int, dtype_bytes: int,
                         tile_m: int = None, h_block: int = None) -> tuple:
    """Resolve (strip_m, h_block) from possibly-``None`` user requests.

    THE shared auto-sizing rule: both strip kernels and
    ``registry.PlanContext.resolve_blocks`` call this, so plan-level and
    kernel-level sizing can never drift apart.  ``tile_m=None`` sizes both
    jointly (``choose_strip_blocks``); an explicit ``tile_m`` is clamped to
    the grid and, when ``h_block`` is also ``None``, gets ``choose_hblock``
    of the clamped strip.  ``h_block=0`` passes through (whole-strip).
    """
    h, wid = grid_shape
    if tile_m is None:
        strip_m, auto_hb = choose_strip_blocks(h, wid, halo, dtype_bytes)
    else:
        strip_m, auto_hb = min(tile_m, h), None
    if h_block is None:
        h_block = choose_hblock(strip_m, halo) if auto_hb is None else auto_hb
    return strip_m, h_block


def hbm_read_bytes_per_step(shape, strip_m: int, dtype_bytes: int,
                            bands_shape=None, h_block: int = 0) -> int:
    """Analytic HBM read traffic of one strip-substrate kernel launch.

    Whole-strip (``h_block=0``, the default -- this is an analytic model
    with no halo to auto-resolve from, so ``None`` is rejected just like
    ``substrate_read_amp``): each of the ``h/strip_m`` grid cells streams
    three (strip_m, n) blocks -> the grid is read 3x per step (vs 9x for
    kernels.legacy).  Sub-blocked (``h_block > 0``): each output strip
    streams ``strip_m/h_block + 2`` (h_block, n) blocks -> the grid is
    read ``1 + 2*h_block/strip_m`` times.  The banded operand (if any) is
    charged once per output strip (its block index is constant within a
    strip's revisit chain).
    """
    import numpy as np

    h, w = shape
    gm = h // strip_m
    # One formula for both substrates: substrate_read_amp is the model (and
    # rejects the h_block=None 'auto' sentinel); rows = strip_m * amp is
    # exact (3*strip_m whole-strip, strip_m + 2*h_block sub-blocked).
    rows_per_strip = round(strip_m * substrate_read_amp(strip_m, h_block))
    total = gm * rows_per_strip * w * dtype_bytes
    if bands_shape is not None:
        total += gm * int(np.prod(bands_shape)) * dtype_bytes
    return total
