"""Shared Pallas plumbing: 9-strip halo BlockSpecs and tile assembly.

TPU Pallas BlockSpecs address non-overlapping blocks (element offset = block
index * block shape), so halo reads cannot be expressed as one overlapping
block.  The TPU-idiomatic pattern is to reference the SAME input array once
per neighbor block with shifted ``index_map``s -- the Mosaic pipeline then
streams center + neighbor tiles HBM->VMEM and the kernel assembles the
halo-extended tile in VMEM.  Modulo wrap in the index maps yields periodic
boundaries for free (matches the ppermute ring of the distributed runtime).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


NEIGHBOR_OFFSETS_2D = [(-1, -1), (-1, 0), (-1, 1),
                       (0, -1), (0, 0), (0, 1),
                       (1, -1), (1, 0), (1, 1)]


def neighbor_in_specs(tile_m: int, tile_n: int, grid_m: int, grid_n: int):
    """Nine BlockSpecs addressing (i+di, j+dj) mod grid for one 2D input."""
    specs = []
    for di, dj in NEIGHBOR_OFFSETS_2D:
        specs.append(
            pl.BlockSpec(
                (tile_m, tile_n),
                functools.partial(
                    lambda i, j, di=di, dj=dj: ((i + di) % grid_m, (j + dj) % grid_n)
                ),
            )
        )
    return specs


def assemble_extended(refs: Sequence, halo: int) -> jax.Array:
    """Build the (tile_m + 2h, tile_n + 2h) halo-extended tile in VMEM.

    ``refs`` are the nine neighbor refs in NEIGHBOR_OFFSETS_2D order.  Only
    the needed edges/corners of the neighbor tiles are read.
    """
    tl, t, tr, l, c, r, bl, b, br = [ref[...] for ref in refs]
    h = halo
    top = jnp.concatenate([tl[-h:, -h:], t[-h:, :], tr[-h:, :h]], axis=1)
    mid = jnp.concatenate([l[:, -h:], c, r[:, :h]], axis=1)
    bot = jnp.concatenate([bl[:h, -h:], b[:h, :], br[:h, :h]], axis=1)
    return jnp.concatenate([top, mid, bot], axis=0)


def choose_tile(n: int, preferred: int = 128) -> int:
    """Largest divisor of ``n`` that is <= preferred (MXU-friendly when 128)."""
    if n <= preferred:
        return n
    for cand in range(preferred, 0, -1):
        if n % cand == 0:
            return cand
    return n


def validate_tiling(shape, tile_m, tile_n, halo):
    h, w = shape
    if h % tile_m or w % tile_n:
        raise ValueError(f"grid {shape} not divisible by tiles ({tile_m},{tile_n})")
    if tile_m < halo or tile_n < halo:
        raise ValueError(
            f"halo {halo} exceeds tile ({tile_m},{tile_n}); "
            "lower fusion depth or enlarge tiles"
        )
