"""Pallas TPU kernels for the stencil hot paths (VPU direct, MXU banded),
on the strip-mined halo substrate (kernels.common; seed scheme preserved in
kernels.legacy for traffic benchmarking)."""
from .ops import stencil_apply, explain, BACKENDS
from .stencil_direct import stencil_direct
from .stencil_matmul import stencil_matmul, build_bands, band_sparsity
from .common import choose_strip, choose_tile, strip_in_specs
