"""Pallas TPU kernels for the stencil hot paths (VPU direct, MXU banded)."""
from .ops import stencil_apply, explain, BACKENDS
from .stencil_direct import stencil_direct
from .stencil_matmul import stencil_matmul, build_bands, band_sparsity
