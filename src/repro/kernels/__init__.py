"""Pallas TPU kernels for the stencil hot paths (VPU direct, MXU banded),
on the strip-mined halo substrate (kernels.common; seed scheme preserved in
kernels.legacy for traffic benchmarking).

The public surface is the plan API: ``stencil_plan`` compiles the paper's
decision procedure + kernel lowering into a reusable ``StencilPlan``;
``stencil_apply`` is the one-shot compatibility wrapper over it; backends
register through ``repro.kernels.registry``.  ``guarded_stencil_plan``
wraps a plan in the guarded execution layer (failure taxonomy +
degradation ladder, DESIGN.md §11)."""
from .ops import stencil_apply, explain
from .plan import (StencilPlan, stencil_plan, spec_from_weights,
                   plan_cache_stats, plan_cache_max, clear_plan_cache)
from .registry import (register_backend, unregister_backend,
                       registered_backends, get_backend, fallback_ladder)
from .guard import (GuardedExecutionError, GuardedPlan, HaloExchangeError,
                    KernelCompileError, NumericalFaultError, PlanBuildError,
                    VmemOverflowError, classify_failure,
                    guarded_stencil_plan)
from .stencil_direct import stencil_direct
from .stencil_matmul import (stencil_matmul, build_bands, build_bands_nd,
                             band_sparsity)
from .common import (SubstrateGeom, choose_col_blocks, choose_hblock,
                     choose_slab_blocks, choose_strip, choose_strip_blocks,
                     choose_tile, pricing_geom, resolve_strip_blocks,
                     resolve_substrate_geom, strip_in_specs,
                     substrate_read_amp, vmem_budget_bytes)


def __getattr__(name):
    # Delegates to ops.__getattr__: BACKENDS is computed on access so
    # late-registered plug-in backends show up.
    if name == "BACKENDS":
        from . import ops
        return ops.BACKENDS
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
