"""Sparse-compacted MXU stencil kernel: the Sparse-Tensor-Core regime.

The banded operands ``build_bands_nd`` emits for star stencils are mostly
structural zeros: a single-tap row (e.g. the (dz=0, dy=+1) row of a 3D
star) still materializes a (tile_n + 2r, tile_n) band of which only
``tile_n`` rows along the contraction axis carry data.  The paper's
sequels (SPIDER, SparStencil -- PAPERS.md) show that structured
compaction of exactly this pattern is how Sparse Tensor Cores widen the
MXU sweet spot.  This module executes that regime (DESIGN.md §14):

  * **host-side compaction** (:func:`compact_bands`): each band keeps
    only the contiguous hull of its structurally-nonzero contraction
    rows.  For a row whose taps span [dx_min, dx_max] the nonzero band
    rows are the union of [dx, dx + tile_n) over its taps -- contiguous
    because tile_n >= the tap span -- i.e. exactly
    [dx_min, dx_max + tile_n): ``tile_n + span`` rows instead of
    ``tile_n + 2r``, span = dx_max - dx_min in [0, 2r].  The kept rows
    of every band are stacked into ONE packed operand (a single launch
    const), shrinking VMEM residency by the kept-row fraction S;
  * **in-kernel gather** (:func:`_sparse_banded_step`): the matching
    input rows are gathered by slicing the shifted slab at offset
    ``lo = dx_min`` with width ``wcur + span`` -- the per-step MXU
    K-dimension shrinks by the same S (2:4-style structured compaction
    generalized to the band pattern: the "metadata" is the per-band
    (lo, span, row_start) triple, static on the host);
  * **bitwise equality**: the dropped band rows are exact zeros, and an
    additive identity never changes a float sum regardless of where the
    reduction tree absorbs it, so the compacted contraction is
    bit-for-bit equal to the dense ``stencil_matmul`` path (asserted in
    tests and in the benchmark sweep).

Box kernels compact to span = 2r on every row (S = 1): the backend still
builds and runs -- identically to the dense path -- so the guard ladder
can route through it unconditionally; it just never wins on price.

Both fusion regimes of stencil_matmul are mirrored: ``t=1`` on composed
weights (monolithic) and ``t>1`` with VMEM-resident intermediates
(``fused_sparse_matmul``, the reuse regime).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import (apply_boundary_fills, choose_tile, extend_columns,
                     lift_boundary_1d, resolve_substrate_geom,
                     slab_substrate_call, strip_substrate_call,
                     validate_tiling)
from .stencil_matmul import build_bands_nd
from repro.stencil.boundary import resolve_boundary


def compact_bands(offsets, bands: np.ndarray):
    """Compact banded operands to their structurally-nonzero band rows.

    ``offsets``/``bands`` as returned by ``build_bands_nd``: one
    (tile_n + 2r, tile_n) band per surviving leading-shift tuple.
    Returns ``(row_index, packed_bands)``:

      * ``row_index``: per band, the np.arange of kept contraction-row
        indices -- the contiguous hull [dx_min, dx_max + tile_n) of the
        nonzero rows (a superset hull is always safe: a kept all-zero
        row contracts to exact zeros);
      * ``packed_bands``: the kept rows of all bands stacked along axis
        0 into one (sum_p(tile_n + span_p), tile_n) array -- a single
        VMEM-resident launch const whose row count over
        n_offsets * (tile_n + 2r) IS the kept-row fraction S.
    """
    bands = np.asarray(bands)
    if len(offsets) != bands.shape[0]:
        raise ValueError(f"{len(offsets)} offsets != {bands.shape[0]} bands")
    row_index = []
    packed = []
    for p in range(bands.shape[0]):
        nz = np.flatnonzero(np.any(bands[p] != 0, axis=1))
        if nz.size == 0:
            raise ValueError(f"band {p} is all-zero (offset {offsets[p]}); "
                             "build_bands_nd should have dropped it")
        lo, hi = int(nz[0]), int(nz[-1]) + 1
        row_index.append(np.arange(lo, hi))
        packed.append(bands[p, lo:hi])
    return tuple(row_index), np.concatenate(packed, axis=0)


def band_row_meta(row_index, tile_n: int):
    """Static gather metadata from ``compact_bands`` row indices.

    Per band: ``(lo, span, row_start)`` -- the input-gather offset, the
    tap span (kept rows = tile_n + span), and the band's first row in
    the packed operand.
    """
    meta = []
    start = 0
    for idx in row_index:
        lo = int(idx[0])
        span = int(idx.size) - tile_n
        if span < 0:
            raise ValueError(f"band keeps {idx.size} rows < tile_n {tile_n}")
        meta.append((lo, span, start))
        start += int(idx.size)
    return tuple(meta)


def kept_row_fraction(weights, tile_n: int) -> float:
    """Kept-row fraction S of the compacted operand (<= 1; 1 for box).

    S = sum_p(tile_n + span_p) / (n_offsets * (tile_n + 2r)): the factor
    by which compaction shrinks both the VMEM-resident operand and the
    per-step MXU K-dimension.  This is the *achievable* row-structured
    sparsity -- ``band_sparsity`` measures element nonzeros, which row
    compaction cannot fully reach (a multi-tap band row keeps its
    in-row zeros).
    """
    w = np.asarray(weights, dtype=np.float32)
    if w.ndim == 1:
        w = w[None, :]
    offsets, bands = build_bands_nd(w, tile_n)
    row_index, packed = compact_bands(offsets, bands)
    radius = (bands.shape[1] - bands.shape[2]) // 2
    return packed.shape[0] / (len(offsets) * (tile_n + 2 * radius))


def _sparse_banded_step(z: jax.Array, packed_ref, offsets, row_meta,
                        lead_extents, radius: int, tile_n: int,
                        compute_dtype, wrap_x: bool = True,
                        mode_x: str = "periodic") -> jax.Array:
    """One radius-r compacted banded contraction, any rank.

    Mirrors ``stencil_matmul._banded_step`` exactly, except each offset
    contracts only its kept band rows: the input slab is gathered at
    ``lo_p`` with width ``wcur + span_p`` and multiplied against the
    band's rows of the packed operand.  A final chunk narrower than
    ``tile_n`` re-expands to the DENSE band prefix (the kept rows for
    width wcur are [lo, lo + wcur + span) -- zero-padded back to
    [0, wcur + 2r)): XLA's small-dot rewrites reassociate degenerate
    reductions, so keeping the remainder chunk graph-identical to the
    dense path is what preserves bitwise equality; the compaction win
    comes from the full-width chunks, which dominate.
    """
    if wrap_x:
        zw = extend_columns(z, radius, mode_x)         # (..., n + 2r)
        n_out = z.shape[-1]
    else:
        zw = z                                         # halo carried
        n_out = z.shape[-1] - 2 * radius
    lead = tuple(z.shape[i] - (lead_extents[i] - 1)
                 for i in range(len(lead_extents)))
    m = 1
    for d in lead:
        m *= d
    bands_w = packed_ref.shape[-1]
    cols = []
    start = 0
    while start < n_out:
        wcur = min(tile_n, n_out - start)
        acc = jnp.zeros((m, wcur), jnp.float32)
        for p, off in enumerate(offsets):
            lo, span, rs = row_meta[p]
            sl = tuple(slice(off[i], off[i] + lead[i])
                       for i in range(len(lead)))
            if wcur == bands_w:
                a = zw[sl + (slice(start + lo, start + lo + wcur + span),)]
                a = a.reshape(m, wcur + span)
                b = packed_ref[rs:rs + wcur + span]   # compacted rows
            else:
                # remainder chunk: dense-shaped contraction (see docstring)
                a = zw[sl + (slice(start, start + wcur + 2 * radius),)]
                a = a.reshape(m, wcur + 2 * radius)
                kept = packed_ref[rs:rs + wcur + span, :wcur]
                b = jnp.pad(kept, ((lo, 2 * radius - span - lo), (0, 0)))
            acc = acc + jax.lax.dot(a.astype(compute_dtype),
                                    b.astype(compute_dtype),
                                    preferred_element_type=jnp.float32)
        cols.append(acc)
        start += wcur
    out = cols[0] if len(cols) == 1 else jnp.concatenate(cols, axis=1)
    return out.reshape(lead + (n_out,))


def _sparse_banded_steps(cur: jax.Array, edges, packed_ref, offsets,
                         row_meta, lead_extents, t: int, radius: int,
                         tile_n: int, compute_dtype, modes,
                         wrap_x: bool = True, x_pad: int = 0) -> jax.Array:
    # Same assembly/compute barrier as the dense banded kernel: keeps the
    # substrates' compute graphs identical so outputs stay bit-for-bit
    # equal across substrate choices.  Non-periodic launches re-impose
    # the boundary on the shrinking out-of-domain halo before every
    # step, exactly like the dense kernels (DESIGN.md §15).
    cur = jax.lax.optimization_barrier(cur)
    for k in range(t):
        if edges is not None:
            cur = apply_boundary_fills(cur, modes, edges, (t - k) * radius,
                                       x_pad=x_pad, x_tiled=not wrap_x)
        cur = _sparse_banded_step(cur, packed_ref, offsets, row_meta,
                                  lead_extents, radius, tile_n,
                                  compute_dtype, wrap_x, modes[-1])
    return cur


def stencil_sparse_matmul(
    x: jax.Array,
    weights,
    t: int = 1,
    tile_m: int = None,
    tile_n: int = None,
    h_block: int = None,
    z_slab: int = None,
    z_block: int = None,
    w_tile: int = None,
    w_block: int = None,
    interpret: bool = False,
    compute_dtype=None,
    boundary=None,
) -> jax.Array:
    """``t`` stencil steps via sparse-compacted MXU contractions.

    Drop-in, bitwise-equal replacement for ``stencil_matmul`` that
    contracts each banded operand over only its structurally-nonzero
    band rows (kept-row fraction S = ``kept_row_fraction``).  Same
    fusion regimes: ``t=1`` monolithic on (possibly fused) weights,
    ``t>1`` intermediate reuse with VMEM-resident steps
    (``fused_sparse_matmul`` in the registry).  All substrate/tiling
    parameters behave exactly as in ``stencil_matmul``.
    """
    w = np.asarray(weights)
    if x.ndim != w.ndim:
        raise ValueError(f"grid rank {x.ndim} != kernel rank {w.ndim}")
    if x.ndim == 1:
        hb = h_block if h_block in (None, 0) else 1
        y = stencil_sparse_matmul(x[None, :], w[None, :], t=t, tile_m=1,
                                  tile_n=tile_n, h_block=hb, w_tile=0,
                                  interpret=interpret,
                                  compute_dtype=compute_dtype,
                                  boundary=lift_boundary_1d(boundary))
        return y[0]

    modes = resolve_boundary(boundary, x.ndim)
    radius = (w.shape[-1] - 1) // 2
    halo = t * ((w.shape[0] - 1) // 2)        # 0 for the lifted-1D kernel
    wid = x.shape[-1]
    x_halo = t * radius                       # carried if column-tiled
    geom = resolve_substrate_geom(x.shape, halo, x.dtype.itemsize,
                                  tile_m, h_block, z_slab, z_block,
                                  w_tile, w_block, x_halo)
    tile_n = choose_tile(wid) if tile_n is None else min(tile_n, wid)
    validate_tiling(x.shape, geom.strip_m, tile_n, halo, radius,
                    geom.h_block, geom.z_slab if x.ndim == 3 else None,
                    geom.z_block, geom.w_tile, geom.w_block, x_halo,
                    boundary=modes)
    if compute_dtype is None:
        compute_dtype = x.dtype
    x_pad = (-wid) % geom.w_tile if geom.w_tile else 0  # remainder path

    offsets, bands_np = build_bands_nd(w.astype(np.float32), tile_n)
    row_index, packed_np = compact_bands(offsets, bands_np)
    row_meta = band_row_meta(row_index, tile_n)
    packed = jnp.asarray(packed_np)
    lead_extents = w.shape[:-1]

    def compute(cur, edges, packed_ref):
        return _sparse_banded_steps(cur, edges, packed_ref, offsets,
                                    row_meta, lead_extents, t, radius,
                                    tile_n, compute_dtype, modes,
                                    wrap_x=not geom.w_tile, x_pad=x_pad)

    if x.ndim == 3:
        return slab_substrate_call(compute, x, geom, halo, interpret,
                                   consts=(packed,),
                                   x_halo=x_halo if geom.w_tile else 0,
                                   boundary=modes)
    return strip_substrate_call(compute, x, geom.strip_m, geom.h_block,
                                halo, interpret, consts=(packed,),
                                w_tile=geom.w_tile, w_block=geom.w_block,
                                x_halo=x_halo if geom.w_tile else 0,
                                boundary=modes)
