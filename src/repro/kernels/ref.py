"""Pure-jnp oracles for every kernel entry point (the ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.stencil.reference import apply_stencil, apply_stencil_steps
from repro.stencil.weights import fuse_weights


def stencil_direct_ref(x: jax.Array, weights, t: int = 1,
                       boundary=None) -> jax.Array:
    """Oracle for kernels.stencil_direct: t boundary-aware stencil steps
    (``boundary`` per-axis, ``None`` = periodic)."""
    b = "periodic" if boundary is None else boundary
    return apply_stencil_steps(x, jnp.asarray(weights, x.dtype), t, b)


def stencil_matmul_ref(x: jax.Array, weights, boundary=None) -> jax.Array:
    """Oracle for kernels.stencil_matmul: one boundary-aware step of
    ``weights`` (which may itself be a fused kernel)."""
    b = "periodic" if boundary is None else boundary
    return apply_stencil(x, jnp.asarray(weights, x.dtype), b)


def stencil_fused_matmul_ref(x: jax.Array, weights, t: int,
                             boundary=None) -> jax.Array:
    """Oracle for the fused-matmul path: t steps == one fused-kernel step."""
    b = "periodic" if boundary is None else boundary
    return apply_stencil_steps(x, jnp.asarray(weights, x.dtype), t, b)


def fused_kernel(weights, t: int):
    return fuse_weights(weights, t)
