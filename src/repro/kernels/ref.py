"""Pure-jnp oracles for every kernel entry point (the ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.stencil.reference import apply_stencil, apply_stencil_steps
from repro.stencil.weights import fuse_weights


def stencil_direct_ref(x: jax.Array, weights, t: int = 1) -> jax.Array:
    """Oracle for kernels.stencil_direct: t periodic stencil steps."""
    return apply_stencil_steps(x, jnp.asarray(weights, x.dtype), t, "periodic")


def stencil_matmul_ref(x: jax.Array, weights) -> jax.Array:
    """Oracle for kernels.stencil_matmul: one periodic step of ``weights``
    (which may itself be a fused kernel)."""
    return apply_stencil(x, jnp.asarray(weights, x.dtype), "periodic")


def stencil_fused_matmul_ref(x: jax.Array, weights, t: int) -> jax.Array:
    """Oracle for the fused-matmul path: t steps == one fused-kernel step."""
    return apply_stencil_steps(x, jnp.asarray(weights, x.dtype), t, "periodic")


def fused_kernel(weights, t: int):
    return fuse_weights(weights, t)
