"""Backend registry: every execution regime registers through one interface.

A *backend* is one way to advance the grid ``t`` time steps -- the five
regimes of the strip substrate (VPU direct/fused, MXU sequential /
monolithic / intermediate-reuse), the seed 9-tile foil (``legacy_*``), and
the pure-jnp reference oracle all register here via :func:`register_backend`
and are addressed by name from ``stencil_plan`` / ``stencil_apply``.

Each :class:`BackendDef` carries two callables:

  * ``build(ctx)`` -- consume a :class:`PlanContext` (stencil spec, dense
    weights, grid geometry, tiling, dtype) and return the executable
    ``run(x) -> y`` for ``t`` steps.  All host-side analysis (tile sizing,
    weight composition, validation) happens HERE, once per plan; ``run`` is
    jitted by the plan, so nothing in it re-executes per call.
  * ``price(pctx)`` -- optional analytic throughput (effective stencil
    FLOP/s) under a :class:`repro.core.selector.PricingContext`, or ``None``
    when the backend is not a candidate for that workload (e.g. the reuse
    regime degenerates at t=1).  ``select_backend`` enumerates priced
    backends instead of a hard-coded dict, so new regimes (e.g. a sparse
    unit) become selectable just by registering.

The five strip regimes run on the halo-row sub-blocked substrate by
default (kernels.common, DESIGN.md §3); each also registers a
``*_wholestrip`` foil (3-load substrate, unpriced) for benchmarking and
substrate-equivalence tests.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.core import perfmodel as pm
from repro.stencil.boundary import is_periodic, resolve_boundary
from repro.stencil.spec import StencilSpec
from repro.stencil.weights import fuse_weights
from .common import (SubstrateGeom, choose_tile, launch_geometry,
                     resolve_substrate_geom, validate_tiling)
from . import legacy as _legacy
from . import ref as _ref
from .stencil_direct import stencil_direct
from .stencil_matmul import build_bands_nd, stencil_matmul
from .stencil_sparse import compact_bands, stencil_sparse_matmul


# ---------------------------------------------------------------------------
# Plan-build context handed to backend builders
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class PlanContext:
    """Everything a backend builder may consume, resolved once per plan."""

    spec: StencilSpec
    weights: np.ndarray          # dense (2r+1)^d base kernel, host-side
    grid_shape: Tuple[int, ...]
    dtype: np.dtype
    t: int
    tile_m: Optional[int]        # user-requested; None = auto per kernel rule
    tile_n: Optional[int]
    interpret: bool
    compute_dtype: object = None
    h_block: Optional[int] = None   # None = auto, 0 = whole-strip/slab foil
    z_slab: Optional[int] = None    # 3D grids: slab depth (None = auto)
    z_block: Optional[int] = None   # 3D grids: halo-plane block (None = auto)
    w_tile: Optional[int] = None    # None = auto, 0 = full width (fast path)
    w_block: Optional[int] = None   # column halo block (None = auto)
    #: Per-axis boundary spec (DESIGN.md §15), resolved by the plan layer
    #: to one mode per grid axis; ``None`` = all periodic (historical).
    boundary: Optional[Tuple[str, ...]] = None

    @property
    def radius(self) -> int:
        return (self.weights.shape[0] - 1) // 2

    def fused_weights(self) -> np.ndarray:
        """Radius-``t*r`` composed kernel (monolithic fusion operand)."""
        return fuse_weights(self.weights, self.t)

    def resolve_geom(self, halo: int) -> SubstrateGeom:
        """Full substrate geometry under the kernels' own N-D rule.

        ``halo`` is the vertical/leading halo of the regime being built;
        the carried x-halo of a column-tiled launch equals it for the
        square kernels this repo builds, so it doubles as ``x_halo``.
        """
        return resolve_substrate_geom(self.grid_shape, halo,
                                      np.dtype(self.dtype).itemsize,
                                      self.tile_m, self.h_block,
                                      self.z_slab, self.z_block,
                                      self.w_tile, self.w_block, halo)

    def resolve_tile_n(self) -> int:
        """Column-chunk width of the banded contraction (MXU paths)."""
        wid = self.grid_shape[-1]
        return choose_tile(wid) if self.tile_n is None else min(self.tile_n, wid)

    def kernel_kwargs(self, geom: SubstrateGeom) -> dict:
        """The substrate-geometry kwargs both strip kernels accept."""
        kw = dict(tile_m=geom.strip_m, h_block=geom.h_block,
                  boundary=self.boundary)
        if geom.dim >= 2:
            kw.update(w_tile=geom.w_tile, w_block=geom.w_block)
        if geom.dim == 3:
            kw.update(z_slab=geom.z_slab, z_block=geom.z_block)
        return kw

    def validate(self, geom: SubstrateGeom, tile_n: int, halo: int,
                 radius: int) -> None:
        validate_tiling(self.grid_shape, geom.strip_m, tile_n, halo, radius,
                        geom.h_block,
                        geom.z_slab if geom.dim == 3 else None, geom.z_block,
                        geom.w_tile, geom.w_block, halo,
                        boundary=self.boundary)


# ---------------------------------------------------------------------------
# Audit hooks: what a backend declares it will launch (repro.audit)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LaunchAudit:
    """One declared kernel launch of a backend, in auditable terms.

    The static auditor (``repro.audit``) turns this into the
    :class:`~repro.kernels.common.LaunchGeometry` the substrate builds for
    it and proves the analytic model against that structure -- the hook
    resolves geometry through the SAME ``PlanContext`` methods the builder
    uses, so the declaration cannot drift from the built plan.
    """

    geom: SubstrateGeom
    grid_shape: Tuple[int, ...]   # TRUE user grid (pre-lift)
    halo: int                     # leading/vertical halo of this launch
    x_halo: int                   # carried x-halo (column-tiled only)
    t_inner: int                  # in-VMEM steps inside the launch
    weights: np.ndarray           # kernel-rank operand (1D grids lifted)
    radius: int                   # per-step x radius of ``weights``
    engine: str                   # "direct" | "matmul" | "sparse_matmul"
    tile_n: int = 0               # MXU column-chunk width
    bands_shape: Optional[Tuple[int, ...]] = None
    n_offsets: int = 0            # banded operand rows actually built
    #: Sparse-compacted launches (engine "sparse_matmul") also declare the
    #: per-band gather metadata: ``band_lo[p]`` the first kept contraction
    #: row (the input-gather offset) and ``band_spans[p]`` the tap span
    #: (kept rows = tile_n + span).  ``bands_shape`` is then the PACKED
    #: operand's shape, whose row count proves the kept-row fraction S.
    band_lo: Optional[Tuple[int, ...]] = None
    band_spans: Optional[Tuple[int, ...]] = None
    #: Per-axis boundary modes at the TRUE grid rank (``None`` = periodic);
    #: ``launch_geometry`` lifts 1D grids exactly as the kernels do.
    boundary: Optional[Tuple[str, ...]] = None

    def launch_geometry(self):
        """The exact structure the substrate launches for this geometry."""
        return launch_geometry(self.grid_shape, self.geom,
                               self.halo, self.x_halo,
                               boundary=self.boundary)


@dataclasses.dataclass(frozen=True)
class AuditSpec:
    """A backend's full audit declaration: its launches, in run order."""

    launches: Tuple[LaunchAudit, ...] = ()
    #: Non-None opts the backend out with a recorded reason (the seed
    #: foils predate the substrate model; the reference oracle has no
    #: launch structure to audit).
    exempt: Optional[str] = None


def _launch_audit(ctx: PlanContext, geom: SubstrateGeom, w_op, t_inner: int,
                  engine: str) -> LaunchAudit:
    """Describe one launch exactly as the kernels resolve it: 1D grids
    lift the operand to (1, N) with zero vertical halo; column-tiled
    launches carry ``t_inner * radius`` of x support."""
    w_op = np.asarray(w_op)
    if len(ctx.grid_shape) == 1:
        w_op = w_op[None, :]
    radius = (w_op.shape[-1] - 1) // 2
    halo = t_inner * ((w_op.shape[0] - 1) // 2)
    x_halo = t_inner * radius if geom.w_tile else 0
    extra = {}
    if engine == "matmul":
        tile_n = ctx.resolve_tile_n()
        offsets, bands = build_bands_nd(w_op.astype(np.float32), tile_n)
        extra = dict(tile_n=tile_n, bands_shape=tuple(bands.shape),
                     n_offsets=len(offsets))
    elif engine == "sparse_matmul":
        tile_n = ctx.resolve_tile_n()
        offsets, bands = build_bands_nd(w_op.astype(np.float32), tile_n)
        row_index, packed = compact_bands(offsets, bands)
        extra = dict(tile_n=tile_n, bands_shape=tuple(packed.shape),
                     n_offsets=len(offsets),
                     band_lo=tuple(int(ix[0]) for ix in row_index),
                     band_spans=tuple(int(ix.size) - tile_n
                                      for ix in row_index))
    return LaunchAudit(geom=geom, grid_shape=tuple(ctx.grid_shape),
                       halo=halo, x_halo=x_halo, t_inner=t_inner,
                       weights=w_op, radius=radius, engine=engine,
                       boundary=resolve_boundary(ctx.boundary,
                                                 len(ctx.grid_shape)),
                       **extra)


def _audit_direct(ctx: PlanContext) -> AuditSpec:
    l = _launch_audit(ctx, ctx.resolve_geom(ctx.radius), ctx.weights,
                      1, "direct")
    return AuditSpec(launches=(l,) * ctx.t)


def _audit_fused_direct(ctx: PlanContext) -> AuditSpec:
    l = _launch_audit(ctx, ctx.resolve_geom(ctx.t * ctx.radius), ctx.weights,
                      ctx.t, "direct")
    return AuditSpec(launches=(l,))


def _audit_matmul(ctx: PlanContext) -> AuditSpec:
    l = _launch_audit(ctx, ctx.resolve_geom(ctx.radius), ctx.weights,
                      1, "matmul")
    return AuditSpec(launches=(l,) * ctx.t)


def _audit_fused_matmul(ctx: PlanContext) -> AuditSpec:
    wf = ctx.fused_weights()
    R = (wf.shape[0] - 1) // 2
    l = _launch_audit(ctx, ctx.resolve_geom(R), wf, 1, "matmul")
    return AuditSpec(launches=(l,))


def _audit_fused_matmul_reuse(ctx: PlanContext) -> AuditSpec:
    l = _launch_audit(ctx, ctx.resolve_geom(ctx.t * ctx.radius), ctx.weights,
                      ctx.t, "matmul")
    return AuditSpec(launches=(l,))


def _audit_sparse_matmul(ctx: PlanContext) -> AuditSpec:
    l = _launch_audit(ctx, ctx.resolve_geom(ctx.radius), ctx.weights,
                      1, "sparse_matmul")
    return AuditSpec(launches=(l,) * ctx.t)


def _audit_fused_sparse_matmul(ctx: PlanContext) -> AuditSpec:
    l = _launch_audit(ctx, ctx.resolve_geom(ctx.t * ctx.radius), ctx.weights,
                      ctx.t, "sparse_matmul")
    return AuditSpec(launches=(l,))


def _wholestrip_audit(audit: Callable) -> Callable:
    """Audit the same regime on the whole-strip substrate (h_block=0),
    mirroring :func:`_wholestrip` exactly."""
    def audit_ws(ctx: PlanContext) -> AuditSpec:
        return audit(dataclasses.replace(ctx, h_block=0))
    return audit_ws


def _audit_exempt(reason: str) -> Callable:
    def audit(ctx: PlanContext) -> AuditSpec:
        return AuditSpec(exempt=reason)
    return audit


@dataclasses.dataclass(frozen=True)
class BackendDef:
    name: str
    build: Callable[[PlanContext], Callable]
    price: Optional[Callable] = None   # price(PricingContext) -> float | None
    description: str = ""
    unit: Optional[str] = None         # "vector" | "matrix" | None (other)
    #: Position on the guard layer's degradation ladder (DESIGN.md §11):
    #: lower = more aggressive, higher = more conservative.  ``None`` means
    #: the backend is never a fallback target (legacy 2D-only foils,
    #: matmul wholestrip foils).  The reference oracle carries the largest
    #: rank so the ladder always terminates on it.
    fallback_rank: Optional[int] = None
    #: ``audit(ctx) -> AuditSpec`` declares the backend's launches for the
    #: static auditor (repro.audit); ``None`` means "not yet auditable"
    #: (plug-ins), reported as exempt rather than violating.
    audit: Optional[Callable] = None


_REGISTRY: Dict[str, BackendDef] = {}
#: Bumped on every (un)registration; folded into plan-cache keys so plans
#: built against an older registry never mask a newly registered candidate.
_generation = 0


def generation() -> int:
    return _generation


def register_backend(name: str, build: Callable, price: Callable = None,
                     description: str = "", unit: str = None,
                     overwrite: bool = False,
                     fallback_rank: Optional[int] = None,
                     audit: Callable = None) -> BackendDef:
    """Register an execution backend under ``name``.

    ``build(ctx: PlanContext) -> run(x)`` constructs the executable;
    ``price(pctx) -> Optional[float]`` (optional) makes it an auto-selection
    candidate; ``unit`` classifies it for Decision bookkeeping ("vector" or
    "matrix" -- the predicted matrix-vs-vector speedup considers only
    matrix-unit candidates); ``fallback_rank`` (optional) places it on the
    guard layer's degradation ladder (see :func:`fallback_ladder`);
    ``audit(ctx) -> AuditSpec`` (optional) declares its launches for the
    static auditor (repro.audit).
    Re-registering an existing name raises unless ``overwrite``.
    """
    global _generation
    if name == "auto":
        raise ValueError("'auto' is the selection policy, not a backend")
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {name!r} already registered "
                         "(pass overwrite=True to replace)")
    bd = BackendDef(name=name, build=build, price=price,
                    description=description, unit=unit,
                    fallback_rank=fallback_rank, audit=audit)
    _REGISTRY[name] = bd
    _generation += 1
    return bd


def unregister_backend(name: str) -> None:
    """Remove a registered backend (primarily for tests/plug-in teardown)."""
    global _generation
    if _REGISTRY.pop(name, None) is not None:
        _generation += 1


def get_backend(name: str) -> BackendDef:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; registered: "
            f"{tuple(_REGISTRY)} (or 'auto')") from None


def registered_backends() -> Tuple[str, ...]:
    """Names of all registered backends, in registration order."""
    return tuple(_REGISTRY)


def priced_candidates(pctx) -> Dict[str, float]:
    """Evaluate every priced backend under ``pctx``; skip non-candidates."""
    out: Dict[str, float] = {}
    for bd in _REGISTRY.values():
        if bd.price is None:
            continue
        v = bd.price(pctx)
        if v is not None:
            out[bd.name] = v
    return out


def candidate_units() -> Dict[str, Optional[str]]:
    """Registered name -> unit classification ("vector"/"matrix"/None)."""
    return {name: bd.unit for name, bd in _REGISTRY.items()}


def fallback_ladder(after: Optional[str] = None) -> Tuple[str, ...]:
    """Ranked backends in degradation order (most aggressive first).

    ``after=name`` returns only the rungs strictly more conservative than
    ``name`` -- the remaining ladder once ``name`` has failed.  A backend
    with no rank (foils, plug-ins) yields the FULL ladder: an unranked
    regime that fails falls back onto the standard sequence from the top.
    """
    ranked = sorted((bd for bd in _REGISTRY.values()
                     if bd.fallback_rank is not None),
                    key=lambda bd: bd.fallback_rank)
    names = tuple(bd.name for bd in ranked)
    if after is None:
        return names
    cut = _REGISTRY.get(after)
    if cut is None or cut.fallback_rank is None:
        return names
    return tuple(bd.name for bd in ranked
                 if bd.fallback_rank > cut.fallback_rank)


# ---------------------------------------------------------------------------
# Builders: the five strip-substrate regimes + reference + legacy foil.
# Each resolves its tiling/operands at build time and closes over them, so
# plan execution re-derives nothing.
# ---------------------------------------------------------------------------
def _build_reference(ctx: PlanContext) -> Callable:
    w, t, b = ctx.weights, ctx.t, ctx.boundary

    def run(x):
        return _ref.stencil_direct_ref(x, w, t, boundary=b)
    return run


def _build_direct(ctx: PlanContext) -> Callable:
    """t sequential VPU kernel launches, halo r per step."""
    w, t, r = ctx.weights, ctx.t, ctx.radius
    geom = ctx.resolve_geom(r)
    ctx.validate(geom, ctx.grid_shape[-1], r, r)
    kw = ctx.kernel_kwargs(geom)
    interp = ctx.interpret

    def run(x):
        for _ in range(t):
            x = stencil_direct(x, w, t=1, interpret=interp, **kw)
        return x
    return run


def _build_fused_direct(ctx: PlanContext) -> Callable:
    """One VPU kernel, t in-VMEM steps (temporal fusion, halo t*r)."""
    w, t, r = ctx.weights, ctx.t, ctx.radius
    geom = ctx.resolve_geom(t * r)
    ctx.validate(geom, ctx.grid_shape[-1], t * r, r)
    kw = ctx.kernel_kwargs(geom)
    interp = ctx.interpret

    def run(x):
        return stencil_direct(x, w, t=t, interpret=interp, **kw)
    return run


def _build_matmul(ctx: PlanContext) -> Callable:
    """t sequential MXU banded contractions, halo r per step."""
    w, t, r = ctx.weights, ctx.t, ctx.radius
    geom, tile_n = ctx.resolve_geom(r), ctx.resolve_tile_n()
    ctx.validate(geom, tile_n, r, r)
    kw = ctx.kernel_kwargs(geom)
    interp, cdt = ctx.interpret, ctx.compute_dtype

    def run(x):
        for _ in range(t):
            x = stencil_matmul(x, w, t=1, tile_n=tile_n, interpret=interp,
                               compute_dtype=cdt, **kw)
        return x
    return run


def _build_fused_matmul(ctx: PlanContext) -> Callable:
    """Monolithic fusion: ONE contraction of the composed radius-t*r kernel."""
    if ctx.t > 1 and not is_periodic(ctx.boundary):
        # One application of the composed kernel sees ONE boundary
        # extension at depth t*r, but every non-periodic mode re-applies
        # per step (DESIGN.md §15) -- the regime cannot represent that.
        raise ValueError(
            "fused_matmul (monolithic fusion) cannot honor non-periodic "
            f"boundaries at t={ctx.t}: the composed radius-t*r kernel "
            "bakes a single boundary extension into all t steps; use "
            "fused_matmul_reuse (per-step fills) or t=1")
    wf = ctx.fused_weights()
    R = (wf.shape[0] - 1) // 2
    geom, tile_n = ctx.resolve_geom(R), ctx.resolve_tile_n()
    ctx.validate(geom, tile_n, R, R)
    kw = ctx.kernel_kwargs(geom)
    interp, cdt = ctx.interpret, ctx.compute_dtype

    def run(x):
        return stencil_matmul(x, wf, t=1, tile_n=tile_n, interpret=interp,
                              compute_dtype=cdt, **kw)
    return run


def _build_fused_matmul_reuse(ctx: PlanContext) -> Callable:
    """Intermediate reuse: t radius-r contractions, VMEM intermediates."""
    w, t, r = ctx.weights, ctx.t, ctx.radius
    geom, tile_n = ctx.resolve_geom(t * r), ctx.resolve_tile_n()
    ctx.validate(geom, tile_n, t * r, r)
    kw = ctx.kernel_kwargs(geom)
    interp, cdt = ctx.interpret, ctx.compute_dtype

    def run(x):
        return stencil_matmul(x, w, t=t, tile_n=tile_n, interpret=interp,
                              compute_dtype=cdt, **kw)
    return run


def _build_sparse_matmul(ctx: PlanContext) -> Callable:
    """t sequential sparse-compacted MXU contractions, halo r per step."""
    w, t, r = ctx.weights, ctx.t, ctx.radius
    geom, tile_n = ctx.resolve_geom(r), ctx.resolve_tile_n()
    ctx.validate(geom, tile_n, r, r)
    kw = ctx.kernel_kwargs(geom)
    interp, cdt = ctx.interpret, ctx.compute_dtype

    def run(x):
        for _ in range(t):
            x = stencil_sparse_matmul(x, w, t=1, tile_n=tile_n,
                                      interpret=interp, compute_dtype=cdt,
                                      **kw)
        return x
    return run


def _build_fused_sparse_matmul(ctx: PlanContext) -> Callable:
    """Intermediate reuse on the compacted operand: t radius-r sparse
    contractions in one kernel, VMEM intermediates."""
    w, t, r = ctx.weights, ctx.t, ctx.radius
    geom, tile_n = ctx.resolve_geom(t * r), ctx.resolve_tile_n()
    ctx.validate(geom, tile_n, t * r, r)
    kw = ctx.kernel_kwargs(geom)
    interp, cdt = ctx.interpret, ctx.compute_dtype

    def run(x):
        return stencil_sparse_matmul(x, w, t=t, tile_n=tile_n,
                                     interpret=interp, compute_dtype=cdt,
                                     **kw)
    return run


def _wholestrip(build: Callable) -> Callable:
    """Same regime on the whole-strip (3-load) substrate: force h_block=0."""
    def build_ws(ctx: PlanContext) -> Callable:
        return build(dataclasses.replace(ctx, h_block=0))
    return build_ws


def _require_2d(ctx: PlanContext, name: str) -> None:
    if len(ctx.grid_shape) != 2:
        raise ValueError(
            f"backend {name!r} is the seed 2D 9-tile foil and supports only "
            f"2D grids, got rank {len(ctx.grid_shape)}; use the halo-plane "
            "substrate regimes (direct/matmul families) for 1D/3D")
    if not is_periodic(ctx.boundary):
        raise ValueError(
            f"backend {name!r} is the seed periodic-only foil and does not "
            f"support boundary={ctx.boundary!r}; use the halo-plane "
            "substrate regimes (direct/matmul families) for non-periodic "
            "boundaries (DESIGN.md §15)")


def _build_legacy_direct(ctx: PlanContext) -> Callable:
    """Seed 9-neighbor full-tile VPU scheme (benchmark foil)."""
    _require_2d(ctx, "legacy_direct")
    w, t = ctx.weights, ctx.t
    tile_m = 128 if ctx.tile_m is None else ctx.tile_m
    tile_n = 128 if ctx.tile_n is None else ctx.tile_n
    interp = ctx.interpret

    def run(x):
        return _legacy.stencil_direct_9pt(x, w, t=t, tile_m=tile_m,
                                          tile_n=tile_n, interpret=interp)
    return run


def _build_legacy_matmul(ctx: PlanContext) -> Callable:
    """Seed 9-neighbor monolithic MXU scheme on the composed kernel."""
    _require_2d(ctx, "legacy_matmul")
    wf = ctx.fused_weights()
    tile_m = 128 if ctx.tile_m is None else ctx.tile_m
    tile_n = 128 if ctx.tile_n is None else ctx.tile_n
    interp, cdt = ctx.interpret, ctx.compute_dtype

    def run(x):
        return _legacy.stencil_matmul_9pt(x, wf, tile_m=tile_m, tile_n=tile_n,
                                          interpret=interp, compute_dtype=cdt)
    return run


# ---------------------------------------------------------------------------
# Pricers: the selector's candidate set, one per selectable regime.  The
# unfused/fused VPU and MXU pairs share a throughput model and partition on
# fusion depth, preserving the historical candidate naming (``direct`` vs
# ``fused_direct`` etc.).  Legacy and reference backends are unpriced: they
# exist for benchmarking/debugging and must never win selection.
# ---------------------------------------------------------------------------
def _price_direct(p):
    return p.comparison.vector.actual_flops if p.workload.t == 1 else None


def _price_fused_direct(p):
    return p.comparison.vector.actual_flops if p.workload.t > 1 else None


def _price_matmul(p):
    return p.comparison.matrix.actual_flops if p.workload.t == 1 else None


def _price_fused_matmul(p):
    return p.comparison.matrix.actual_flops if p.workload.t > 1 else None


def _price_fused_matmul_reuse(p):
    # t=1 reuse degenerates to "matmul"; only offered at depth.  z_slab
    # (3D) and w_tile (column-tiled substrate) feed the dim-aware beta;
    # both are None/0 for full-width 1D/2D workloads.
    if p.workload.t == 1:
        return None
    return pm.perf_matrix_reuse(p.workload, p.hw, p.s_reuse,
                                p.strip_m, p.z_slab,
                                p.w_tile or None).actual_flops


def _price_sparse_matmul(p):
    # Candidates only when the user opts into the sparse unit (DESIGN.md
    # §14): compaction's effective-FLOP reduction is real on any MXU, but
    # the selection policy treats it as the Sparse-Tensor-Core regime the
    # paper prices, flipped on explicitly.  Priced from the COMPACTED
    # operand: kept-row fraction * (1 + gather overhead) scales the dense
    # matrix FLOPs.
    if not p.use_sparse_unit or p.workload.t != 1:
        return None
    return pm.perf_sparse_banded(
        p.workload, p.hw, p.s_mono, p.kept_mono,
        pm.compaction_overhead(p.tile_n)).actual_flops


def _price_fused_sparse_matmul(p):
    if not p.use_sparse_unit or p.workload.t == 1:
        return None
    return pm.perf_sparse_banded_reuse(
        p.workload, p.hw, p.s_reuse, p.kept_reuse,
        pm.compaction_overhead(p.tile_n), p.strip_m, p.z_slab,
        p.w_tile or None).actual_flops


# Fallback ranks order the degradation ladder from most aggressive (deep
# fusion, MXU, VMEM-hungry) to most conservative (reference oracle): each
# rung drops one source of fragility -- intermediate reuse, then the MXU,
# then temporal fusion, then halo-row sub-blocking, then Pallas entirely.
register_backend("direct", _build_direct, _price_direct,
                 "t sequential VPU kernel steps (halo r per step)",
                 unit="vector", fallback_rank=50, audit=_audit_direct)
register_backend("fused_direct", _build_fused_direct, _price_fused_direct,
                 "one VPU kernel, t in-VMEM steps (temporal fusion)",
                 unit="vector", fallback_rank=40, audit=_audit_fused_direct)
register_backend("matmul", _build_matmul, _price_matmul,
                 "t sequential MXU banded contractions", unit="matrix",
                 fallback_rank=30, audit=_audit_matmul)
register_backend("fused_matmul", _build_fused_matmul, _price_fused_matmul,
                 "monolithic fusion: one radius-t*r banded contraction",
                 unit="matrix", fallback_rank=20, audit=_audit_fused_matmul)
register_backend("fused_matmul_reuse", _build_fused_matmul_reuse,
                 _price_fused_matmul_reuse,
                 "one MXU kernel, t radius-r contractions, VMEM intermediates",
                 unit="matrix", fallback_rank=10,
                 audit=_audit_fused_matmul_reuse)
# Sparse-compacted pair (DESIGN.md §14): ladder rungs between the reuse
# regime and monolithic fusion -- compaction only drops exact-zero band
# rows, so these rungs are bitwise-safe fallbacks for box kernels too.
register_backend("fused_sparse_matmul", _build_fused_sparse_matmul,
                 _price_fused_sparse_matmul,
                 "one MXU kernel, t sparse-compacted radius-r contractions, "
                 "VMEM intermediates", unit="matrix", fallback_rank=12,
                 audit=_audit_fused_sparse_matmul)
register_backend("sparse_matmul", _build_sparse_matmul, _price_sparse_matmul,
                 "t sequential sparse-compacted MXU contractions",
                 unit="matrix", fallback_rank=16, audit=_audit_sparse_matmul)
register_backend("reference", _build_reference,
                 description="pure-jnp oracle (debug)", fallback_rank=1000,
                 audit=_audit_exempt("pure-jnp oracle: no launch structure "
                                     "to audit"))
register_backend("legacy_direct", _build_legacy_direct,
                 description="seed 9-tile VPU scheme (benchmark foil)",
                 unit="vector",
                 audit=_audit_exempt("seed 9-tile foil predates the "
                                     "substrate traffic model"))
register_backend("legacy_matmul", _build_legacy_matmul,
                 description="seed 9-tile monolithic MXU scheme (foil)",
                 unit="matrix",
                 audit=_audit_exempt("seed 9-tile foil predates the "
                                     "substrate traffic model"))

# Whole-strip (3-load) substrate foils: the same five regimes with halo-row
# sub-blocking disabled, unpriced so they never win selection -- they exist
# so benchmarks/traffic.py can measure seed / whole-strip / sub-blocked
# three ways and tests can assert bit-for-bit substrate equivalence.
# The direct-family wholestrip foils also serve as the ladder's
# penultimate rungs (DESIGN.md §11): after every sub-blocked regime has
# failed, the 3-load substrate drops halo-row sub-blocking -- the last
# Pallas configuration before surrendering to the reference oracle.
for _name, _build, _audit, _unit, _rank in (
    ("direct", _build_direct, _audit_direct, "vector", 60),
    ("fused_direct", _build_fused_direct, _audit_fused_direct, "vector", 55),
    ("matmul", _build_matmul, _audit_matmul, "matrix", None),
    ("fused_matmul", _build_fused_matmul, _audit_fused_matmul,
     "matrix", None),
    ("fused_matmul_reuse", _build_fused_matmul_reuse,
     _audit_fused_matmul_reuse, "matrix", None),
):
    register_backend(f"{_name}_wholestrip", _wholestrip(_build),
                     description=f"{_name} on the whole-strip 3-load "
                                 "substrate (benchmark foil)",
                     unit=_unit, fallback_rank=_rank,
                     audit=_wholestrip_audit(_audit))
