"""Block-access auditor: enumerate every BlockSpec index map statically.

A Pallas launch's HBM read traffic is fully determined by its
:class:`repro.kernels.common.LaunchGeometry`: the index maps are pure
Python closures over ints, so calling them with every concrete grid index
-- no tracing, no execution -- yields the exact sequence of block indices
each input reference visits.  Pallas's pipeline only refetches a block
when the index CHANGES between consecutive grid steps (the revisit
optimization), so the audited traffic applies consecutive deduplication
per reference; on every non-degenerate substrate geometry the walk never
revisits consecutively and the deduplicated count equals the analytic
step count exactly.

Checks emitted per launch:

  * ``blocks/in-bounds``     -- every fetched block lies inside the
    (possibly host-extended) source array; every written output block
    inside the launch output.
  * ``blocks/out-cover``     -- the output blocks tile ``out_shape``
    exactly once, and the out index map is constant across the ring.
  * ``blocks/grid-bytes-model`` -- deduplicated grid-input bytes/step ==
    ``hbm_read_bytes_per_step{,_3d}`` (grid term), exact integer
    equality.
  * ``blocks/read-amp-geom`` -- audited bytes / (padded output bytes) ==
    ``SubstrateGeom.read_amp`` (rtol 1e-9; the padded output absorbs the
    remainder path's edge tile exactly as the model does).
  * ``blocks/bands-term``    -- for MXU launches, the model's banded
    operand term charges exactly ``cells * prod(bands_shape) * D``.
"""
from __future__ import annotations

import itertools
import math
from typing import List

from .report import AuditCheck

#: Exact enumeration cap: grids whose launch exceeds this many grid steps
#: skip the byte-level checks (recorded as skipped, never violations).
#: Substrate grids are block-granular, so realistic launches are far
#: below it; the cap only guards pathological plan-attached audits.
MAX_GRID_STEPS = 2_000_000


def enumerate_fetches(lg):
    """Walk the full launch grid; return per-input-ref fetch counts under
    Pallas revisit semantics plus the raw step count.

    Returns ``(fetch_counts, n_steps, ring_steps)`` where ``fetch_counts``
    has one entry per input reference.
    """
    grid = lg.grid
    n_steps = math.prod(grid)
    counts = [0] * len(lg.in_index_maps)
    prev = [None] * len(lg.in_index_maps)
    for ix in itertools.product(*map(range, grid)):
        for k, im in enumerate(lg.in_index_maps):
            idx = im(*ix)
            if idx != prev[k]:
                counts[k] += 1
                prev[k] = idx
    return counts, n_steps


def _block_limits(shape, block):
    """Max valid block index per axis (our launches never use partial
    edge blocks: the remainder path pads the source instead)."""
    return tuple(s // b for s, b in zip(shape, block))


def _degenerate_axes(lg):
    """Ringed axes whose modulo-wrapped block walk aliases consecutively
    (total extent 1 block).  The analytic model charges such axes as if
    every step fetched; Pallas's revisit optimization would not.  The
    byte comparison is skipped there -- the model is conservative."""
    out = []
    for ax, b in enumerate(lg.block_dims):
        if ax == len(lg.block_dims) - 1 and not lg.aligned:
            continue            # remainder walk never wraps: no aliasing
        if lg.src_shape[ax] // b == 1:
            out.append(ax)
    return out


def audit_blocks(lg, launch, dtype_bytes: int) -> List[AuditCheck]:
    """All block-access checks for one launch geometry.

    ``launch`` is the registry's :class:`LaunchAudit` (engine, geometry,
    bands shape); ``lg`` the :class:`LaunchGeometry` it launches.
    """
    checks: List[AuditCheck] = []
    n_steps = math.prod(lg.grid)
    if n_steps > MAX_GRID_STEPS:
        checks.append(AuditCheck(
            "blocks/grid-bytes-model", True, skipped=True,
            detail=f"grid has {n_steps} steps > {MAX_GRID_STEPS}; exact "
                   "enumeration skipped"))
        return checks

    # ---- enumerate, checking bounds and output coverage as we walk ----
    in_lim = _block_limits(lg.src_shape, lg.in_block)
    out_lim = _block_limits(lg.out_shape, lg.out_block)
    oob = []
    out_blocks = {}
    ring_drift = []
    counts = [0] * len(lg.in_index_maps)
    prev = [None] * len(lg.in_index_maps)
    for ix in itertools.product(*map(range, lg.grid)):
        for k, im in enumerate(lg.in_index_maps):
            idx = im(*ix)
            if any(not 0 <= b < lim for b, lim in zip(idx, in_lim)):
                if len(oob) < 8:
                    oob.append((ix, k, idx))
            if idx != prev[k]:
                counts[k] += 1
                prev[k] = idx
        oidx = lg.out_index_map(*ix)
        if any(not 0 <= b < lim for b, lim in zip(oidx, out_lim)):
            if len(oob) < 8:
                oob.append((ix, "out", oidx))
        cell = ix[:-1] if lg.ring_dims else ix
        seen = out_blocks.setdefault(cell, oidx)
        if seen != oidx and len(ring_drift) < 8:
            ring_drift.append((ix, seen, oidx))

    checks.append(AuditCheck(
        "blocks/in-bounds", not oob, expected="all blocks in bounds",
        actual=oob or "ok",
        detail="" if not oob else "block index escapes the source array"))

    n_out_blocks = math.prod(
        s // b for s, b in zip(lg.out_shape, lg.out_block))
    cover_ok = (not ring_drift
                and len(out_blocks) == lg.cells == n_out_blocks
                and len(set(out_blocks.values())) == n_out_blocks)
    checks.append(AuditCheck(
        "blocks/out-cover", cover_ok,
        expected={"cells": lg.cells, "distinct_out_blocks": n_out_blocks},
        actual={"cells_seen": len(out_blocks),
                "distinct": len(set(out_blocks.values())),
                "ring_drift": ring_drift or "none"},
        detail="output blocks must tile out_shape exactly once, "
               "constant across the ring"))

    # ---- deduplicated grid bytes vs the analytic traffic model --------
    audited = sum(c * math.prod(lg.in_block) for c in counts) * dtype_bytes
    model = _model_grid_bytes(launch, dtype_bytes)
    degenerate = _degenerate_axes(lg)
    if degenerate:
        checks.append(AuditCheck(
            "blocks/grid-bytes-model", True, skipped=True,
            expected=model, actual=audited,
            detail=f"ringed axes {degenerate} hold a single block: the "
                   "revisit optimization dedups what the model charges "
                   "(model is conservative)"))
    else:
        checks.append(AuditCheck(
            "blocks/grid-bytes-model", audited == model,
            expected=model, actual=audited,
            detail="dedup'd BlockSpec walk vs hbm_read_bytes_per_step "
                   "grid term"))

        out_bytes = math.prod(lg.out_shape) * dtype_bytes
        audited_amp = audited / out_bytes
        model_amp = launch.geom.read_amp
        checks.append(AuditCheck(
            "blocks/read-amp-geom",
            math.isclose(audited_amp, model_amp, rel_tol=1e-9),
            expected=model_amp, actual=audited_amp,
            detail="audited bytes / padded-output bytes vs "
                   "SubstrateGeom.read_amp"))

    # ---- banded operand term (MXU launches) ---------------------------
    if launch.bands_shape is not None:
        with_bands = _model_grid_bytes(launch, dtype_bytes,
                                       bands_shape=launch.bands_shape)
        expected_term = lg.cells * math.prod(launch.bands_shape) \
            * dtype_bytes
        checks.append(AuditCheck(
            "blocks/bands-term", with_bands - model == expected_term,
            expected=expected_term, actual=with_bands - model,
            detail="model must charge the banded operand once per output "
                   "cell at its actual built shape"))
    return checks


def _model_grid_bytes(launch, dtype_bytes: int, bands_shape=None) -> int:
    """The analytic model's read traffic for this launch's geometry."""
    from repro.kernels.common import (hbm_read_bytes_per_step,
                                      hbm_read_bytes_per_step_3d)
    geom = launch.geom
    shape = launch.grid_shape
    if geom.dim == 1 or len(shape) == 1:
        # Lifted 1D streams each point exactly once (read amp 1 --
        # DESIGN.md §9); the 2D formula does not apply to the lift.
        total = math.prod(shape) * dtype_bytes
        if bands_shape is not None:
            total += int(math.prod(bands_shape)) * dtype_bytes
        return total
    if len(shape) == 3:
        return hbm_read_bytes_per_step_3d(shape, geom, dtype_bytes,
                                          bands_shape=bands_shape)
    return hbm_read_bytes_per_step(shape, geom.strip_m, dtype_bytes,
                                   bands_shape=bands_shape,
                                   h_block=geom.h_block,
                                   w_tile=geom.w_tile,
                                   w_block=geom.w_block)


def audited_read_amp(lg, dtype_bytes: int) -> float:
    """Audited read amplification of one launch: dedup'd grid-input bytes
    over padded-output bytes (the third witness of the explain==decision
    parity sweep -- tests/test_audit.py)."""
    counts, _ = enumerate_fetches(lg)
    audited = sum(c * math.prod(lg.in_block) for c in counts) * dtype_bytes
    return audited / (math.prod(lg.out_shape) * dtype_bytes)
