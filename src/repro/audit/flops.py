"""Jaxpr FLOP counter: prove the model's arithmetic terms on traced code.

``jax.make_jaxpr`` on a built plan runner yields the exact computation
the backend launches -- every ``pallas_call`` with its grid, every
``dot_general`` with its contraction dims, every vector ``add``/``mul``.
Counting FLOPs there (dot-general MACs as 2*B*M*N*K, elementwise float
ops at their output size, scaled by grid size and ring-gated fire
frequency) gives a ground truth that is independent of both the analytic
performance model AND the per-kernel mirror walks below, so each can be
checked against it:

  * ``flops/structural``       -- jaxpr-counted (vector, dot) FLOPs ==
    the plain-Python mirror of ``_stencil_steps`` / ``_banded_step``
    over the audited launch geometries, exact integer equality, and the
    traced runner launches exactly the declared number of pallas calls.
  * ``flops/alpha``            -- the fused kernel's audited tap-count
    ratio nnz(w_fused) / (t * nnz(w)) equals ``perfmodel.fusion_alpha``
    (the paper's alpha; only provable when the base weights realize the
    spec, else skipped).
  * ``flops/beta``             -- executed stencil points per output
    point across a t-step in-VMEM launch equal ``perfmodel.reuse_beta``
    (the paper's beta halo-recompute factor), rtol 1e-9 -- the audited
    shrinking-region sum telescopes to exactly (1/t) sum_j prod_m
    (1 + 2*r*j/size_m).
  * ``flops/matrix-reuse-model`` -- audited MXU FLOPs per output point
    of the reuse backends match ``(beta / S) * flops_vector`` with S the
    measured band sparsity (``flops_matrix_reuse``; the sparse-compacted
    launch is additionally scaled by its kept-row fraction); rtol 5e-2
    absorbs final-chunk remainders on widths not divisible by tile_n.
  * ``flops/sparse-compaction`` -- the compacted contraction (engine
    ``"sparse_matmul"``, DESIGN.md §14) does exactly the packed-row FLOP
    count -- S * dense on tile-aligned widths -- integer-exact against
    the traced jaxpr, and never exceeds the dense count.  The expectation
    is derived from ``bands_shape`` alone, so tampered gather metadata
    cannot hide a mis-compaction.

All model lookups go through the ``perfmodel`` module attribute at check
time so a monkeypatched (i.e. wrong) model is caught, not baked in.
"""
from __future__ import annotations

import math
from typing import List

import numpy as np

from .report import AuditCheck


# --------------------------------------------------------------------------
# Jaxpr walk
# --------------------------------------------------------------------------
_ELEMENTWISE = {"add", "sub", "mul", "add_any"}


def _is_float(aval) -> bool:
    try:
        return np.issubdtype(aval.dtype, np.floating)
    except Exception:
        return False


def count_jaxpr_flops(jaxpr, launches):
    """Count (vector_flops, dot_flops, n_pallas_calls) in a closed jaxpr.

    ``launches`` is the audit spec's ordered :class:`LaunchAudit` tuple;
    pallas calls are matched to it in trace order so each body's
    ring-gated compute branch is weighted by its fire frequency
    (grid_steps / ring).  Only floating-dtype outputs count -- integer
    index arithmetic inside kernel bodies is free.
    """
    state = {"vector": 0, "dot": 0, "pallas": 0}
    _walk(getattr(jaxpr, "jaxpr", jaxpr), 1, 1, launches, state)
    return state["vector"], state["dot"], state["pallas"]


def _walk(jaxpr, mult, ring, launches, state):
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "pallas_call":
            inner = eqn.params["jaxpr"]
            gm = eqn.params.get("grid_mapping")
            grid = gm.grid if gm is not None else eqn.params.get("grid", ())
            steps = math.prod(grid) if grid else 1
            k = state["pallas"]
            state["pallas"] += 1
            lg_ring = 1
            if k < len(launches):
                lg_ring = launches[k].launch_geometry().ring
            _walk(getattr(inner, "jaxpr", inner), mult * steps, lg_ring,
                  launches, state)
        elif prim == "cond":
            # pl.when lowers to cond; inside a ringed pallas body the
            # taken branch fires once per cell = steps / ring.  The
            # untaken branch is empty, so summing branches stays exact.
            for br in eqn.params["branches"]:
                _walk(getattr(br, "jaxpr", br), mult // ring, 1,
                      launches, state)
        elif prim == "dot_general":
            if _is_float(eqn.outvars[0].aval):
                (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
                lshape = eqn.invars[0].aval.shape
                rshape = eqn.invars[1].aval.shape
                b = math.prod(lshape[i] for i in lb)
                k_dim = math.prod(lshape[i] for i in lc)
                m = math.prod(lshape) // max(b * k_dim, 1)
                n = math.prod(rshape) // max(
                    math.prod(rshape[i] for i in rb) * k_dim, 1)
                state["dot"] += mult * 2 * b * m * n * k_dim
        elif prim in _ELEMENTWISE:
            if _is_float(eqn.outvars[0].aval):
                state["vector"] += mult * math.prod(eqn.outvars[0].aval.shape)
        else:
            for v in eqn.params.values():
                for j in _jaxprs_in(v):
                    _walk(j, mult, ring, launches, state)


def _jaxprs_in(v):
    """Jaxpr-valued params (pjit bodies etc.), unwrapped."""
    import jax.core as jcore
    vals = v if isinstance(v, (tuple, list)) else (v,)
    out = []
    for item in vals:
        item = getattr(item, "jaxpr", item)
        if isinstance(item, jcore.Jaxpr):
            out.append(item)
    return out


# --------------------------------------------------------------------------
# Plain-Python mirrors of the kernel compute walks
# --------------------------------------------------------------------------
def _region(lg):
    """Shape of the f32 region ``compute`` receives (DESIGN.md §9/§10):
    the scratch read window, the flat block, or the foil concat."""
    if lg.scratch_shape is not None:
        return tuple(hi - lo for lo, hi in lg.read_bounds)
    if lg.kind == "flat":
        return lg.in_block
    shape = list(lg.in_block)           # wholestrip / wholeslab concat
    for ax in range(len(shape) - 1):
        shape[ax] += 2 * lg.halo
    return tuple(shape)


def mirror_launch_flops(launch, lg):
    """(vector_flops, dot_flops, executed_points) of one launch, walked
    exactly as ``_stencil_steps`` / ``_banded_step`` trace: shrinking
    regions per inner step, per-tap mul+add on VPU, per-chunk-per-offset
    dot + accumulate add on MXU.  Totals scaled by the launch's cells."""
    w = np.asarray(launch.weights)
    r = launch.radius
    wrap = lg.kind not in ("coltiled", "slab_coltiled")
    cur = list(_region(lg))
    vec = dot = points = 0
    for _ in range(launch.t_inner):
        n = cur[-1] if wrap else cur[-1] - 2 * r
        lead = [cur[i] - (w.shape[i] - 1) for i in range(w.ndim - 1)]
        m = math.prod(lead)
        points += m * n
        if launch.engine == "matmul":
            start = 0
            while start < n:
                wcur = min(launch.tile_n, n - start)
                dot += launch.n_offsets * 2 * m * wcur * (wcur + 2 * r)
                vec += launch.n_offsets * m * wcur    # acc = acc + dot
                start += wcur
        elif launch.engine == "sparse_matmul":
            # Compacted contraction (DESIGN.md §14): full-width chunks
            # contract only the packed rows -- summed over offsets that
            # is exactly 2*m*tile_n*bands_shape[0] (= S * dense) -- while
            # remainder chunks re-expand to the dense band prefix to stay
            # graph-identical to the dense path (bitwise equality).
            start = 0
            while start < n:
                wcur = min(launch.tile_n, n - start)
                if wcur == launch.tile_n:
                    dot += 2 * m * wcur * launch.bands_shape[0]
                else:
                    dot += launch.n_offsets * 2 * m * wcur * (wcur + 2 * r)
                vec += launch.n_offsets * m * wcur    # acc = acc + dot
                start += wcur
        else:
            nnz = int(np.count_nonzero(w))
            vec += 2 * nnz * m * n                    # per tap: mul + add
        cur = lead + [n]
    return vec * lg.cells, dot * lg.cells, points * lg.cells


def _sparse_dense_dots(launch, lg):
    """(compacted, dense) MXU FLOPs of one sparse launch.

    The compacted expectation is derived from ``bands_shape`` alone --
    independent of the ``band_lo``/``band_spans`` gather metadata -- so a
    mis-compacted packed operand surfaces as a FLOP mismatch against the
    traced jaxpr rather than silently passing.  The dense count is the
    same walk with the full ``wcur + 2r`` contraction depth; on widths
    divisible by tile_n their ratio is exactly the kept-row fraction S."""
    w = np.asarray(launch.weights)
    r = launch.radius
    wrap = lg.kind not in ("coltiled", "slab_coltiled")
    cur = list(_region(lg))
    s_dot = d_dot = 0
    for _ in range(launch.t_inner):
        n = cur[-1] if wrap else cur[-1] - 2 * r
        lead = [cur[i] - (w.shape[i] - 1) for i in range(w.ndim - 1)]
        m = math.prod(lead)
        start = 0
        while start < n:
            wcur = min(launch.tile_n, n - start)
            d = launch.n_offsets * 2 * m * wcur * (wcur + 2 * r)
            s_dot += 2 * m * wcur * launch.bands_shape[0] \
                if wcur == launch.tile_n else d
            d_dot += d
            start += wcur
        cur = lead + [n]
    return s_dot * lg.cells, d_dot * lg.cells


# --------------------------------------------------------------------------
# Checks
# --------------------------------------------------------------------------
def audit_flops(ctx, audit_spec, run) -> List[AuditCheck]:
    """FLOP checks for one backend's audited launches against its traced
    runner ``run`` (the registry-built callable, pre-jit)."""
    import jax
    import jax.numpy as jnp
    from repro.core import perfmodel as pm
    from repro.kernels.stencil_matmul import band_sparsity

    checks: List[AuditCheck] = []
    launches = audit_spec.launches
    x = jnp.zeros(ctx.grid_shape, ctx.dtype)
    try:
        jx = jax.make_jaxpr(run)(x)
    except Exception as e:  # pragma: no cover - tracing never executes
        checks.append(AuditCheck(
            "flops/structural", False, actual=repr(e),
            detail="plan runner failed to trace"))
        return checks

    traced_vec, traced_dot, n_pallas = count_jaxpr_flops(jx, launches)
    mirror_vec = mirror_dot = 0
    per_launch = []
    for launch in launches:
        lg = launch.launch_geometry()
        v, d, p = mirror_launch_flops(launch, lg)
        mirror_vec += v
        mirror_dot += d
        per_launch.append((launch, lg, p))
    structural_ok = (traced_vec == mirror_vec and traced_dot == mirror_dot
                     and n_pallas == len(launches))
    checks.append(AuditCheck(
        "flops/structural", structural_ok,
        expected={"vector": mirror_vec, "dot": mirror_dot,
                  "pallas_calls": len(launches)},
        actual={"vector": traced_vec, "dot": traced_dot,
                "pallas_calls": n_pallas},
        detail="jaxpr-counted FLOPs vs the kernel-walk mirror over the "
               "audited launch geometries"))

    # ---- sparse compaction: traced MXU FLOPs == packed-row expectation
    if launches and all(l.engine == "sparse_matmul" for l in launches):
        expected_dot = dense_dot = 0
        for launch, lg, _ in per_launch:
            s_d, d_d = _sparse_dense_dots(launch, lg)
            expected_dot += s_d
            dense_dot += d_d
        checks.append(AuditCheck(
            "flops/sparse-compaction",
            traced_dot == expected_dot and expected_dot <= dense_dot,
            expected={"dot": expected_dot, "dense_dot": dense_dot},
            actual={"dot": traced_dot,
                    "kept": traced_dot / dense_dot if dense_dot else None},
            detail="traced MXU FLOPs of the compacted contraction must "
                   "equal the packed-row expectation (S * dense on "
                   "tile-aligned widths), integer-exact, and never exceed "
                   "the dense count"))

    spec, t = ctx.spec, ctx.t
    base_nnz = int(np.count_nonzero(np.asarray(ctx.weights)))
    canonical = base_nnz == spec.num_points

    # ---- alpha: fused tap count vs the paper's fusion model -----------
    fused = [l for l in launches
             if l.t_inner == 1 and l.radius == t * spec.radius and t > 1]
    if fused and launches[0].engine == "matmul":
        if canonical:
            wf_nnz = int(np.count_nonzero(np.asarray(fused[0].weights)))
            audited_alpha = wf_nnz / (t * base_nnz)
            model_alpha = pm.fusion_alpha(spec, t)
            checks.append(AuditCheck(
                "flops/alpha",
                math.isclose(audited_alpha, model_alpha, rel_tol=1e-9),
                expected=model_alpha, actual=audited_alpha,
                detail="nnz(fused)/ (t * nnz(base)) vs fusion_alpha"))
        else:
            checks.append(AuditCheck(
                "flops/alpha", True, skipped=True,
                detail="base weights do not realize the spec tap set; "
                       "alpha is a spec-level model term"))

    # ---- beta: audited halo recompute of t-step in-VMEM launches ------
    for launch, lg, points in per_launch:
        if launch.t_inner <= 1:
            continue
        geom = launch.geom
        audited_beta = points / (launch.t_inner * lg.cells
                                 * math.prod(lg.out_block))
        model_beta = pm.reuse_beta(
            spec, launch.t_inner, strip_m=geom.strip_m,
            z_slab=geom.z_slab if geom.dim == 3 else None,
            w_tile=geom.w_tile or None)
        checks.append(AuditCheck(
            "flops/beta",
            math.isclose(audited_beta, model_beta, rel_tol=1e-9),
            expected=model_beta, actual=audited_beta,
            detail=f"executed points per output point, {launch.engine} "
                   f"t_inner={launch.t_inner} vs reuse_beta"))

        # ---- full matrix-reuse FLOP model on the MXU reuse backends ----
        if launch.engine in ("matmul", "sparse_matmul") and canonical:
            s_meas = band_sparsity(np.asarray(launch.weights),
                                   launch.tile_n)
            audited_per_point = mirror_dot / (lg.cells
                                              * math.prod(lg.out_block))
            model_per_point = (model_beta / s_meas) \
                * launch.t_inner * 2 * spec.num_points
            if launch.engine == "sparse_matmul":
                # The compacted launch executes the kept-row fraction of
                # the dense model (DESIGN.md §14).
                model_per_point *= launch.bands_shape[0] / (
                    launch.n_offsets * (launch.tile_n + 2 * launch.radius))
            checks.append(AuditCheck(
                "flops/matrix-reuse-model",
                math.isclose(audited_per_point, model_per_point,
                             rel_tol=5e-2),
                expected=model_per_point, actual=audited_per_point,
                detail="audited MXU FLOPs per output point vs "
                       "(beta/S) * flops_vector (* kept-row fraction for "
                       "the compacted launch), S measured from the built "
                       "bands"))
    return checks
