"""repro.audit -- static plan auditor (DESIGN.md §13).

Proves the analytic performance model (Eq. 6/8/11, DESIGN.md §2-§4)
against the compiled kernel structure WITHOUT executing anything:

  * :mod:`.blocks`  enumerates every Pallas BlockSpec index map over the
    full launch grid (pure Python closures) and cross-checks the
    deduplicated read traffic against ``hbm_read_bytes_per_step{,_3d}``
    and ``SubstrateGeom.read_amp``;
  * :mod:`.scratch` verifies the VMEM ring assembly: disjoint write
    slots, full halo coverage at true global coordinates, compute only
    on the final ring step;
  * :mod:`.flops`   counts FLOPs in the traced jaxpr and cross-checks
    the model's alpha/beta/matrix-reuse terms.

Entry points: :func:`audit_context` audits one backend under one
:class:`~repro.kernels.registry.PlanContext` (the plan layer attaches
its report via ``stencil_plan(..., audit=True)`` / ``REPRO_AUDIT=1``);
``scripts/audit.py`` sweeps the registry x grid matrix into
``AUDIT_report.json`` and gates CI on zero violations.
"""
from __future__ import annotations

import math
import re

import numpy as np

from .report import AuditCheck, AuditReport
from .blocks import audit_blocks, audited_read_amp, enumerate_fetches
from .scratch import audit_scratch
from .flops import audit_flops

__all__ = [
    "AuditCheck", "AuditReport", "audit_context", "audit_reason_read_amp",
    "audit_blocks", "audit_scratch", "audit_flops", "audited_read_amp",
    "enumerate_fetches",
]


def audit_context(ctx, backend_name: str, flops: bool = True) -> AuditReport:
    """Audit one backend's declared launches under a plan context.

    Returns the report; never raises on violations (callers decide --
    the CLI exits nonzero, the plan layer counts and attaches).
    """
    from repro.kernels import registry

    bd = registry.get_backend(backend_name)
    report = AuditReport(backend=backend_name,
                         grid_shape=tuple(ctx.grid_shape), t=ctx.t,
                         dtype=str(np.dtype(ctx.dtype)))
    if bd.audit is None:
        report.exempt = "backend declares no audit hook"
        return report
    spec = bd.audit(ctx)
    if spec.exempt is not None:
        report.exempt = spec.exempt
        return report

    dtype_bytes = np.dtype(ctx.dtype).itemsize
    seen = set()
    for launch in spec.launches:
        if id(launch) in seen:      # t identical sequential launches
            continue
        seen.add(id(launch))
        lg = launch.launch_geometry()
        report.extend(audit_blocks(lg, launch, dtype_bytes))
        report.extend(audit_scratch(lg, launch))
    if flops:
        report.extend(audit_flops(ctx, spec, bd.build(ctx)))
    return report


_READ_AMP_RE = re.compile(r"read_amp=([0-9.]+)x")


def audit_reason_read_amp(reason: str, grid_shape, geom_px, halo: int,
                          dtype_bytes: int) -> AuditCheck:
    """Third witness of the explain==decision parity sweep: the selector's
    reason string quotes the PRICED geometry's read_amp
    (``SubstrateGeom.describe``); re-derive that number from the audited
    BlockSpec walk of the same geometry and compare at the string's
    printed precision (%.3f => 5.0005e-4 absolute).
    """
    from repro.kernels.common import launch_geometry
    from .blocks import _degenerate_axes

    m = _READ_AMP_RE.search(reason or "")
    if not m:
        return AuditCheck(
            "blocks/reason-read-amp", False,
            expected="read_amp=<amp>x in the decision reason",
            actual=reason,
            detail="selector reason string must quote the priced "
                   "substrate geometry")
    quoted = float(m.group(1))
    lg = launch_geometry(grid_shape, geom_px, halo,
                         halo if geom_px.w_tile else 0)
    if _degenerate_axes(lg):
        return AuditCheck(
            "blocks/reason-read-amp", True, skipped=True,
            detail="priced geometry is degenerate (single-block ringed "
                   "axis): audited dedup traffic undercuts the model's "
                   "conservative charge")
    audited = audited_read_amp(lg, dtype_bytes)
    return AuditCheck(
        "blocks/reason-read-amp",
        math.isclose(audited, quoted, abs_tol=5.0005e-4),
        expected=quoted, actual=audited,
        detail="reason-string read_amp vs audited BlockSpec walk of the "
               "priced geometry")
