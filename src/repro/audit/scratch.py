"""Scratch dependence checker: prove the VMEM ring assembly correct.

The sub-blocked substrates ((strip, ring), (strip, w-tile, ring) and
(z-slab, strip, w-tile, ring) -- DESIGN.md §3/§9/§10) stream halo blocks
into a VMEM scratch over the last grid axis and fire compute on the final
ring step.  This module verifies, statically and per launch geometry:

  * ``scratch/slots-partition`` -- the ring's write slots are pairwise
    disjoint and exactly tile the scratch's ringed extents (every slot
    written once per cell, no conflicting overlapping writes);
  * ``scratch/read-window``     -- the compute read window lies inside
    the scratch and spans exactly the output tile plus its halos
    (leading axes: 2*halo; carried-x axis: 2*x_halo), i.e. full halo
    coverage with nothing unwritten;
  * ``scratch/fire-last``       -- compute fires on the LAST ring step,
    after every slot of the cell's ring has been written (the grid walks
    the ring axis fastest, so steps 0..ring-1 of a cell are consecutive);
  * ``scratch/gather-window``   -- sparse-compacted launches (engine
    ``"sparse_matmul"``, DESIGN.md §14) declare per-band gather metadata
    (``band_lo``, ``band_spans``); every band's gathered input window
    ``[lo, lo + tile + span)`` must lie inside the dense band support
    (``0 <= lo``, ``lo + span <= 2*radius``) and the packed operand's
    row count must equal ``sum(tile_n + span_p)`` -- full coverage of
    the kept contraction rows with nothing read out of bounds;
  * ``scratch/coverage-global`` -- for each sampled output cell and every
    ring step, the fetched source block lands in the slot whose scratch
    coordinates correspond to its true global coordinates: scratch
    position ``p`` on ringed axis ``ax`` must hold global index
    ``cell*tile + (p - block)`` (periodic), which pins slot ``k`` to
    global start ``(cell*tile + (k-1)*block) mod extent`` on aligned
    axes and to extended-source start ``cell*tile + k*block`` on the
    remainder path's non-wrapping column axis.  This is the PR 5 class
    of halo off-by-ones the auditor exists to catch.
"""
from __future__ import annotations

import itertools
import math
from typing import List

from .report import AuditCheck


def _sample_cells(cell_dims, limit: int = 64):
    """All cells when few; otherwise the corner/mid lattice per axis
    (the index maps are affine-with-modulo per axis, so corners + an
    interior point witness every residue behavior)."""
    if math.prod(cell_dims) <= limit:
        return list(itertools.product(*map(range, cell_dims)))
    axes = []
    for d in cell_dims:
        pts = sorted({0, d // 2, d - 1})
        axes.append(pts)
    return list(itertools.product(*axes))


def _gather_window_check(launch) -> AuditCheck:
    """Sparse-compacted launches: prove the per-band gather metadata
    covers exactly the kept contraction rows inside the dense band
    support (the compacted analogue of ``scratch/read-window``)."""
    r, tile_n = launch.radius, launch.tile_n
    lo, spans = launch.band_lo, launch.band_spans
    problems = []
    if lo is None or spans is None:
        problems.append("missing band_lo/band_spans metadata")
    elif not (len(lo) == len(spans) == launch.n_offsets):
        problems.append(f"{len(lo)} band_lo / {len(spans)} band_spans "
                        f"!= {launch.n_offsets} offsets")
    else:
        for p, (l, s) in enumerate(zip(lo, spans)):
            if not (0 <= l and 0 <= s and l + s <= 2 * r):
                problems.append(f"band {p}: window [lo={l}, lo+span={l+s}) "
                                f"outside dense support [0, {2*r}]")
        kept = sum(tile_n + s for s in spans)
        if launch.bands_shape is None or kept != launch.bands_shape[0]:
            problems.append(f"packed rows {launch.bands_shape} != "
                            f"sum(tile_n + span) = {kept}")
    return AuditCheck(
        "scratch/gather-window", not problems,
        expected="every band gathers [lo, lo + tile + span) inside the "
                 "dense band support; packed rows == sum(tile_n + span)",
        actual=problems or "ok",
        detail="sparse-compacted gather metadata must cover exactly the "
               "kept contraction rows (DESIGN.md §14)")


def audit_scratch(lg, launch) -> List[AuditCheck]:
    """All scratch-pipeline checks for one launch geometry (empty list
    for the scratch-free foil/flat kinds -- nothing to prove beyond the
    sparse gather window, which is substrate-independent)."""
    checks: List[AuditCheck] = []
    if launch.engine == "sparse_matmul":
        checks.append(_gather_window_check(launch))
    if lg.scratch_shape is None:
        return checks
    ring = lg.ring
    n_ring_axes = len(lg.block_dims)

    # ---- write slots partition the ringed scratch extents -------------
    slots = [lg.scratch_slot(j) for j in range(ring)]
    distinct = len(set(slots)) == ring
    in_extent = all(
        start >= 0 and start + size <= lg.scratch_shape[ax]
        for slot in slots for ax, (start, size) in enumerate(slot))
    exact_tile = all(
        lg.ring_dims[ax] * lg.block_dims[ax] == lg.scratch_shape[ax]
        for ax in range(n_ring_axes))
    checks.append(AuditCheck(
        "scratch/slots-partition", distinct and in_extent and exact_tile,
        expected={"distinct_slots": ring, "exact_tiling": True},
        actual={"distinct_slots": len(set(slots)),
                "in_extent": in_extent, "exact_tiling": exact_tile},
        detail="ring write slots must be disjoint and tile the scratch"))

    # ---- read window: inside the scratch, spanning tile + halos -------
    expected_spans = []
    for ax in range(len(lg.scratch_shape)):
        tile = lg.out_block[ax]
        if ax < n_ring_axes:
            is_w = ax == len(lg.scratch_shape) - 1
            tile += 2 * (lg.x_halo if is_w else lg.halo)
        expected_spans.append(tile)
    window_ok = len(lg.read_bounds) == len(lg.scratch_shape)
    spans = []
    if window_ok:
        for ax, (lo, hi) in enumerate(lg.read_bounds):
            window_ok &= 0 <= lo <= hi <= lg.scratch_shape[ax]
            spans.append(hi - lo)
        window_ok &= spans == expected_spans
    checks.append(AuditCheck(
        "scratch/read-window", window_ok,
        expected=expected_spans,
        actual=spans,
        detail="compute must read exactly the output tile + halo from "
               "inside the scratch"))

    # ---- compute fires on the final ring step -------------------------
    fire_ok = (lg.fire_step == ring - 1
               and lg.ring_indices(lg.fire_step)
               == tuple(d - 1 for d in lg.ring_dims))
    checks.append(AuditCheck(
        "scratch/fire-last", fire_ok,
        expected=ring - 1, actual=lg.fire_step,
        detail="compute may only fire once every slot is written"))

    # ---- global-coordinate coverage per sampled cell ------------------
    from repro.kernels.common import _reflect_block
    cell_dims = lg.grid[:-1]
    modes = lg.boundary or ()
    bad = []
    for cell in _sample_cells(cell_dims):
        for j in range(ring):
            idx = lg.in_index_maps[0](*cell, j)
            ks = lg.ring_indices(j)
            for ax in range(n_ring_axes):
                b = lg.block_dims[ax]
                tile = lg.out_block[ax]
                actual = idx[ax] * b
                # Cell-grid axes list the ringed source axes 1:1 in
                # order for every scratch kind (subblocked, coltiled
                # and their slab lifts), so cell[ax] feeds ring axis ax
                # -- and lg.boundary[ax] names its mode (DESIGN.md §15).
                mode = modes[ax] if ax < len(modes) else "periodic"
                last_unaligned = (ax == n_ring_axes - 1
                                  and not lg.aligned)
                if last_unaligned:
                    # Remainder path: non-wrapping walk over the
                    # host-extended source, shifted one block right
                    # (the extension carries the boundary, any mode).
                    expect = cell[ax] * tile + ks[ax] * b
                    ok = actual == expect
                elif mode == "periodic":
                    extent = lg.src_shape[ax]
                    expect = (cell[ax] * tile + (ks[ax] - 1) * b) % extent
                    ok = actual % extent == expect
                else:
                    # Non-periodic axes must REFLECT out-of-range block
                    # indices (never wrap, never revisit a block on
                    # consecutive ring steps): the exact in-bounds start
                    # the kernels' in-kernel fills assume.
                    total = lg.src_shape[ax] // b
                    expect = _reflect_block(
                        cell[ax] * (tile // b) + ks[ax] - 1, total) * b
                    ok = actual == expect
                if not ok and len(bad) < 8:
                    bad.append({"cell": cell, "ring_step": j, "axis": ax,
                                "mode": mode,
                                "expected_start": expect,
                                "actual_start": actual})
    checks.append(AuditCheck(
        "scratch/coverage-global", not bad,
        expected="every slot holds its true global halo block",
        actual=bad or "ok",
        detail="scratch slot k on axis ax must hold global rows "
               "(cell*tile + (k-1)*block) mod extent on periodic axes, "
               "reflect_block(cell*nb + k - 1) * block on non-periodic "
               "axes (DESIGN.md §15)"))
    return checks
