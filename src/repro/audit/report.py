"""Audit report containers: typed check results with JSON serialization.

The auditor (repro.audit) proves the analytic performance model against
the compiled kernel structure; every proof obligation is one
:class:`AuditCheck` -- named, with expected/actual values -- and one
backend x grid audit collects its checks into an :class:`AuditReport`.
Checks come in three states:

  * passed   -- the obligation holds exactly (or within its stated tol);
  * failed   -- model and code disagree: a VIOLATION (``report.ok`` is
    False; ``scripts/audit.py`` exits nonzero; CI gates on it);
  * skipped  -- the obligation is not provable here (grid too large for
    exact enumeration, non-canonical weights for a spec-based model
    term); recorded with a reason, never counted as a violation.

Reports serialize via :meth:`AuditReport.to_dict` into
``AUDIT_report.json`` (machine-readable, uploaded as a CI artifact).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass
class AuditCheck:
    """One proof obligation of the model==code audit."""

    name: str                    # e.g. "blocks/grid-bytes-model"
    passed: bool
    expected: object = None
    actual: object = None
    detail: str = ""
    skipped: bool = False        # not provable here (reason in detail)

    def to_dict(self) -> dict:
        d = {"name": self.name,
             "status": ("skipped" if self.skipped
                        else "passed" if self.passed else "VIOLATION")}
        if self.expected is not None:
            d["expected"] = _jsonable(self.expected)
        if self.actual is not None:
            d["actual"] = _jsonable(self.actual)
        if self.detail:
            d["detail"] = self.detail
        return d


@dataclasses.dataclass
class AuditReport:
    """All checks of one backend x (grid, t, dtype) audit."""

    backend: str
    grid_shape: Tuple[int, ...]
    t: int
    dtype: str
    checks: List[AuditCheck] = dataclasses.field(default_factory=list)
    #: Non-None when the backend declared itself exempt (legacy foils,
    #: the pure-jnp reference oracle -- registry audit hooks).
    exempt: Optional[str] = None

    @property
    def ok(self) -> bool:
        return not self.violations

    @property
    def violations(self) -> List[AuditCheck]:
        return [c for c in self.checks if not c.passed and not c.skipped]

    def extend(self, checks) -> None:
        self.checks.extend(checks)

    def summary(self) -> str:
        if self.exempt is not None:
            return (f"{self.backend} grid={self.grid_shape} t={self.t}: "
                    f"EXEMPT ({self.exempt})")
        n_skip = sum(1 for c in self.checks if c.skipped)
        head = (f"{self.backend} grid={self.grid_shape} t={self.t}: "
                f"{len(self.checks)} checks, "
                f"{len(self.violations)} violations"
                + (f", {n_skip} skipped" if n_skip else ""))
        lines = [head]
        for c in self.violations:
            lines.append(f"  VIOLATION {c.name}: expected {c.expected!r}, "
                         f"got {c.actual!r} {c.detail}".rstrip())
        return "\n".join(lines)

    def to_dict(self) -> dict:
        return {
            "backend": self.backend,
            "grid_shape": list(self.grid_shape),
            "t": self.t,
            "dtype": self.dtype,
            "ok": self.ok,
            "exempt": self.exempt,
            "n_violations": len(self.violations),
            "checks": [c.to_dict() for c in self.checks],
        }


def _jsonable(v):
    """Best-effort JSON-safe rendering of expected/actual values."""
    import numpy as np
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, (tuple, list)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    return repr(v)
