"""Test-support utilities shipped with the library (fault injection).

Lives under ``repro`` (not ``tests/``) because the CI fault-matrix legs
and ``scripts/fault_sweep.py`` need it importable from an installed
tree, and because the injection points inside the kernels must import it
unconditionally.
"""
from .faults import (  # noqa: F401
    FaultSpec,
    active_faults,
    corrupt_output,
    fault_hits,
    inject,
    maybe_fail,
    parse_faults,
    reset_faults,
)

__all__ = [
    "FaultSpec",
    "active_faults",
    "corrupt_output",
    "fault_hits",
    "inject",
    "maybe_fail",
    "parse_faults",
    "reset_faults",
]
