"""Deterministic fault injection for the guarded execution layer.

Every failure class the degradation ladder (DESIGN.md §11) must survive
has an injection point wired through this module:

- ``compile``: raise a Mosaic-shaped compile error inside the substrate
  launchers (``strip_substrate_call`` / ``slab_substrate_call``) at trace
  time -- each plan traces its jitted runner exactly once, so "the Nth
  compile" is well-defined.
- ``vmem``: raise a RESOURCE_EXHAUSTED-shaped VMEM overflow at the same
  point, as if the tile estimate lied.
- ``nan``: corrupt a guarded step's output with NaN (consumed by
  ``GuardedPlan`` via :func:`corrupt_output`) to exercise the watchdog.
- ``halo``: raise inside the distributed stepper's halo exchange
  (``stencil.distributed._extend``).
- ``geometry``: corrupt the next built :class:`LaunchGeometry` (consumed
  by the substrate's geometry builders via :func:`corrupt_geometry`) so
  its block walk repeats the previous ring step -- the launched/audited
  structure silently drifts from the analytic traffic model, which is
  exactly the violation class ``repro.audit`` must catch (negative
  tests in ISSUE 8).

Faults come from two sources, checked in order:

1. the :func:`inject` context manager (tests -- scoped, nestable), and
2. the ``REPRO_FAULTS`` env var (CI matrix legs and subprocess tests),
   a comma list of ``kind[:times[@skip]]`` terms: ``compile`` fires
   once; ``compile:3`` fires three times; ``vmem:1@2`` skips two hits
   then fires once; ``compile:inf`` fires forever.

Both are process-local and deterministic -- no randomness, so a fault
sweep is exactly reproducible.  When no fault is configured every hook
is a few-nanosecond no-op; the guard layer stays invisible in
production (the clean-run acceptance bar in ISSUE 6).
"""
from __future__ import annotations

import math
import os
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional

from ..core.envutil import env_str

ENV_VAR = "REPRO_FAULTS"

KINDS = ("compile", "vmem", "nan", "halo", "geometry")

# Messages mimic the shape of real failures so ``classify_failure`` in
# repro.kernels.guard exercises the same patterns production errors hit.
# "(injected)" marks them unambiguously in logs and event dumps.
_MESSAGES = {
    "compile": ("INTERNAL: Mosaic failed to compile TPU kernel: "
                "unsupported lowering (injected)"),
    "vmem": ("RESOURCE_EXHAUSTED: Ran out of memory in memory space vmem "
             "while allocating scratch (injected)"),
    "halo": "injected fault: halo exchange ppermute failed",
}


@dataclass
class FaultSpec:
    """One armed fault: fire ``times`` times after ``skip`` initial hits."""

    kind: str
    times: float = 1  # math.inf for "always"
    skip: int = 0
    fired: int = field(default=0, compare=False)
    hits: int = field(default=0, compare=False)

    def should_fire(self) -> bool:
        self.hits += 1
        if self.hits <= self.skip:
            return False
        if self.fired >= self.times:
            return False
        self.fired += 1
        return True


def parse_faults(raw: str) -> List[FaultSpec]:
    """Parse a ``REPRO_FAULTS`` value; raises ValueError on malformed
    terms so a typo'd CI matrix leg fails loudly, not silently clean."""
    specs: List[FaultSpec] = []
    for term in raw.split(","):
        term = term.strip()
        if not term:
            continue
        kind, times, skip = term, 1.0, 0
        if ":" in term:
            kind, _, rest = term.partition(":")
            times_s, _, skip_s = rest.partition("@")
            try:
                times = math.inf if times_s.strip() == "inf" \
                    else float(int(times_s))
                skip = int(skip_s) if skip_s else 0
            except ValueError:
                raise ValueError(
                    f"{ENV_VAR}: malformed term {term!r}; expected "
                    f"kind[:times[@skip]] with integer or 'inf' times"
                ) from None
        kind = kind.strip()
        if kind not in KINDS:
            raise ValueError(
                f"{ENV_VAR}: unknown fault kind {kind!r}; "
                f"expected one of {', '.join(KINDS)}")
        if times < 1 or skip < 0:
            raise ValueError(
                f"{ENV_VAR}: malformed term {term!r}; "
                f"times must be >= 1 and skip >= 0")
        specs.append(FaultSpec(kind, times, skip))
    return specs


# --------------------------------------------------------------------------
# Active-fault state: an explicit stack from inject() layered over the
# env-derived specs.  Env specs are re-parsed only when the raw string
# changes, so counters (fired/hits) persist across hook calls within one
# configuration -- that is what makes "fail the Nth compile" meaningful.
# --------------------------------------------------------------------------
_STACK: List[List[FaultSpec]] = []
_ENV_RAW: Optional[str] = None
_ENV_SPECS: List[FaultSpec] = []


def _env_specs() -> List[FaultSpec]:
    global _ENV_RAW, _ENV_SPECS
    raw = env_str(ENV_VAR)
    if raw != _ENV_RAW:
        _ENV_RAW = raw
        _ENV_SPECS = parse_faults(raw) if raw else []
    return _ENV_SPECS


def active_faults() -> List[FaultSpec]:
    """All armed specs, innermost inject() scope first, env last."""
    out: List[FaultSpec] = []
    for layer in reversed(_STACK):
        out.extend(layer)
    out.extend(_env_specs())
    return out


def reset_faults() -> None:
    """Drop all injected scopes and force env re-parse (test hygiene)."""
    global _ENV_RAW, _ENV_SPECS
    _STACK.clear()
    _ENV_RAW = None
    _ENV_SPECS = []


def fault_hits() -> Dict[str, int]:
    """How many times each kind actually fired (for assertions)."""
    counts: Dict[str, int] = {}
    for spec in active_faults():
        counts[spec.kind] = counts.get(spec.kind, 0) + spec.fired
    return counts


@contextmanager
def inject(kind: str, times: float = 1, skip: int = 0) -> Iterator[FaultSpec]:
    """Arm one fault for the dynamic extent of the block.

    Yields the spec so tests can assert ``spec.fired`` afterwards.
    """
    if kind not in KINDS:
        raise ValueError(f"unknown fault kind {kind!r}; "
                         f"expected one of {', '.join(KINDS)}")
    spec = FaultSpec(kind, times, skip)
    layer = [spec]
    _STACK.append(layer)
    try:
        yield spec
    finally:
        _STACK.remove(layer)


# --------------------------------------------------------------------------
# Hooks called from production code.
# --------------------------------------------------------------------------
def maybe_fail(kind: str) -> None:
    """Raise the configured failure for ``kind`` if a matching fault is
    armed and due.  No-op (beyond one env read) when nothing is armed."""
    if not _STACK and ENV_VAR not in os.environ:
        return  # fast path: nothing armed anywhere
    for spec in active_faults():
        if spec.kind == kind and spec.should_fire():
            raise RuntimeError(_MESSAGES.get(kind,
                                             f"injected fault: {kind}"))


def corrupt_output(y):
    """If a ``nan`` fault is due, poison one element of ``y`` (the
    guarded step's output) with NaN; otherwise return ``y`` unchanged.
    Called only from the guard layer, never from kernels themselves."""
    if not _STACK and ENV_VAR not in os.environ:
        return y
    for spec in active_faults():
        if spec.kind == "nan" and spec.should_fire():
            import jax.numpy as jnp
            idx = (0,) * y.ndim
            return y.at[idx].set(jnp.nan)
    return y


def corrupt_geometry(lg):
    """If a ``geometry`` fault is due, return a copy of the launch
    geometry whose input block walk repeats the previous last-grid-axis
    step (step ``j`` fetches step ``j-1``'s block): a consecutive
    duplicate that shrinks the fetched block multiset AND stores the
    wrong global rows -- the model/code drift the static auditor exists
    to flag.  Identity (beyond one env read) when nothing is armed, so
    production launches never pay for the hook."""
    if not _STACK and ENV_VAR not in os.environ:
        return lg
    for spec in active_faults():
        if spec.kind == "geometry" and spec.should_fire():
            import dataclasses
            orig = lg.in_index_maps[0]

            def warped(*ix):
                j = ix[-1]
                return orig(*ix[:-1], j - 1 if isinstance(j, int) and j > 0
                            else j)

            return dataclasses.replace(
                lg, in_index_maps=(warped,) + lg.in_index_maps[1:])
    return lg
