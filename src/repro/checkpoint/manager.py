"""Fault-tolerant checkpointing: atomic writes, keep-k, elastic restore.

Save: pytree -> flat {path: ndarray} -> .npz written to a temp name then
os.replace'd (atomic on POSIX) + a JSON metadata sidecar (step, keys,
wall time).  A crash mid-save can never corrupt the latest checkpoint.

Restore: arrays are device_put with the *current* mesh's NamedShardings --
restoring onto a different mesh shape (elastic rescale: lost pod, grown
cluster) reshards transparently because shardings are reconstructed from
the ParamDef logical axes, not stored device layouts."""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_key_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _key_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, tree) -> str:
        flat = _flatten(tree)
        final = os.path.join(self.dir, f"ckpt_{step:08d}.npz")
        tmp = final + f".tmp.{os.getpid()}"
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, final)                      # atomic
        meta = {"step": step, "time": time.time(), "keys": sorted(flat)}
        mtmp = final + ".json.tmp"
        with open(mtmp, "w") as f:
            json.dump(meta, f)
        os.replace(mtmp, final + ".json")
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            for suffix in (".npz", ".npz.json"):
                p = os.path.join(self.dir, f"ckpt_{s:08d}{suffix}")
                if os.path.exists(p):
                    os.remove(p)

    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("ckpt_") and name.endswith(".npz"):
                out.append(int(name[5:13]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------
    def restore(self, step: int, like_tree, shardings=None):
        """Load into the structure of ``like_tree``.

        ``shardings``: optional matching pytree of NamedSharding -- restore
        reshards onto the current mesh (elastic restart path)."""
        path = os.path.join(self.dir, f"ckpt_{step:08d}.npz")
        with np.load(path) as z:
            flat = {k: z[k] for k in z.files}
        paths, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
        leaves = []
        for p, like in paths:
            key = "/".join(_key_str(x) for x in p)
            if key not in flat:
                raise KeyError(f"checkpoint missing {key}")
            arr = flat[key]
            if tuple(arr.shape) != tuple(like.shape):
                raise ValueError(
                    f"{key}: checkpoint shape {arr.shape} != expected {like.shape}"
                )
            leaves.append(arr.astype(like.dtype))
        tree = jax.tree_util.tree_unflatten(treedef, [l for l in leaves])
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        else:
            tree = jax.tree.map(jax.device_put, tree)
        return tree

    def restore_latest(self, like_tree, shardings=None):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, like_tree, shardings)
