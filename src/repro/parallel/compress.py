"""Gradient compression for bandwidth-bound data-parallel all-reduce.

``fake_quantize_tree``: per-tensor symmetric int8 quantize -> dequantize
around the (implicit, GSPMD-inserted) gradient all-reduce.  Placed on the
*output* of value_and_grad, the quantize happens before XLA's reduce --
lowering the DP all-reduce payload 4x (f32) / 2x (bf16).  Stochastic
rounding keeps the quantizer unbiased so SGD/Adam convergence is preserved
in expectation; tests check bias < tolerance empirically.

This is the paper-agnostic "distributed-optimization trick" slot of the
framework; it composes with any model config (flag in launch/train.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _quantize(x, key):
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    scaled = x / scale
    # stochastic rounding: floor + Bernoulli(frac)
    lo = jnp.floor(scaled)
    frac = scaled - lo
    rnd = jax.random.uniform(key, x.shape)
    q = (lo + (rnd < frac)).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def fake_quantize_tree(grads, seed: int = 0):
    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
    out = []
    for k, g in zip(keys, leaves):
        q, s = _quantize(g.astype(jnp.float32), k)
        out.append(_dequantize(q, s).astype(g.dtype))
    return treedef.unflatten(out)
