"""Logical-axis -> mesh-axis rules (t5x-style), DP/TP/SP/EP/FSDP.

Mesh axes:
  * ``pod``   -- inter-pod axis (multi-pod mesh only); folds into data
                 parallelism by default, or hosts pipeline stages.
  * ``data``  -- data parallelism (+ FSDP parameter sharding when enabled).
  * ``model`` -- tensor parallelism (heads / mlp / vocab / experts) and
                 sequence parallelism for the residual stream & KV caches.

Logical axes used by the models:
  batch, seq(residual seq), kv_seq, heads, head_dim, embed, mlp, vocab,
  experts, expert_mlp, layers, state, conv
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import base


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes that implement data parallelism (pod folds into data)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def make_rules(mesh: Mesh, fsdp: bool = False, pure_dp: bool = False):
    """logical axis -> mesh axes (None = replicated).

    ``pure_dp``: fold the `model` axis into data parallelism -- for
    attention-free/low-width archs where tensor parallelism only buys
    collectives (EXPERIMENTS.md §Perf A4).  Weights shard over everything
    (ZeRO), activations shard batch over all axes."""
    dp = data_axes(mesh)
    msize = mesh.shape["model"]
    if pure_dp:
        alldp = dp + ("model",)
        return {
            "batch": alldp, "seq": None, "kv_seq": None, "embed": None,
            "w_embed": alldp if fsdp else None,
            "heads": None, "head_dim": None, "mlp": None, "vocab": None,
            "experts": None, "expert_mlp": None, "layers": None,
            "state": None, "conv": None, None: None,
        }
    rules = {
        # --- activations ---
        "batch": dp,
        "seq": "model",        # Megatron-style sequence sharding of residuals
        "kv_seq": "model",     # decode KV caches sharded along sequence
        "embed": None,         # residual d_model dim: replicated
        # --- weights ---
        "w_embed": dp if fsdp else None,  # ZeRO-3: weight d_model dim over data
        "heads": "model",
        "head_dim": None,
        "mlp": "model",
        "vocab": "model",
        "experts": "model",
        "expert_mlp": None,
        "layers": None,
        "state": None,
        "conv": None,
        None: None,
    }
    return rules


def resolve_axes(axes: Tuple[Optional[str], ...], rules, shape=None, mesh=None) -> P:
    """Logical axes tuple -> PartitionSpec, dropping non-divisible shardings.

    Tuple mesh-axis assignments degrade gracefully: if the dim doesn't
    divide the full product, progressively drop trailing mesh axes (e.g.
    batch 256 on (pod,data,model)=512 chips falls back to (pod,data)=32)
    instead of replicating outright."""
    out = []
    for i, a in enumerate(axes):
        m = rules.get(a, None)
        if m is not None and shape is not None and mesh is not None:
            if isinstance(m, str):
                if shape[i] % _mesh_size(mesh, m) != 0:
                    m = None  # e.g. kv_heads=2 on model=16 -> replicate
            else:
                m = tuple(m)
                while m and shape[i] % _mesh_size(mesh, m) != 0:
                    m = m[:-1]
                m = m or None
        # PartitionSpec treats ('data',) and 'data' as distinct entries;
        # normalize so rule authors may write either without changing specs.
        if isinstance(m, (tuple, list)):
            m = m[0] if len(m) == 1 else tuple(m)
        out.append(m)
    return P(*out)


def _mesh_size(mesh: Mesh, axes) -> int:
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def param_pspecs(defs, mesh: Mesh, fsdp: bool = False, pure_dp: bool = False):
    """Pytree of PartitionSpec for a ParamDef tree (divisibility-checked)."""
    rules = make_rules(mesh, fsdp, pure_dp)
    return jax.tree.map(
        lambda d: resolve_axes(d.axes, rules, d.shape, mesh),
        defs, is_leaf=base.is_def,
    )


def param_shardings(defs, mesh: Mesh, fsdp: bool = False):
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec),
        param_pspecs(defs, mesh, fsdp),
        is_leaf=lambda x: isinstance(x, P),
    )


class _Ctx:
    mesh: Optional[Mesh] = None
    rules = None


_CTX = _Ctx()


class use_mesh:
    """Context manager binding the mesh+rules used by ``logical()`` below.

    Model code stays mesh-agnostic: ``logical(h, "batch", "seq", "embed")``
    is a no-op outside the context (single-device smoke tests) and a
    ``with_sharding_constraint`` inside it (pjit dry-runs / training).
    """

    def __init__(self, mesh: Optional[Mesh], fsdp: bool = False,
                 pure_dp: bool = False):
        self.mesh = mesh
        self.rules = (make_rules(mesh, fsdp, pure_dp)
                      if mesh is not None else None)

    def __enter__(self):
        self._prev = (_CTX.mesh, _CTX.rules)
        _CTX.mesh, _CTX.rules = self.mesh, self.rules
        return self

    def __exit__(self, *exc):
        _CTX.mesh, _CTX.rules = self._prev
        return False


def logical(x: jax.Array, *axes):
    """with_sharding_constraint by logical axis names (no-op off-mesh)."""
    mesh = _CTX.mesh
    if mesh is None or mesh.empty:
        return x
    spec = resolve_axes(tuple(axes), _CTX.rules, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Decode-state (KV cache / SSM state) shardings, keyed by leaf name
# ---------------------------------------------------------------------------
_CACHE_AXES = {
    # leaf-name -> logical axes (leading stacked "layers"/"sites" dim first)
    "k": ("layers", "batch", "kv_seq", None, None),
    "v": ("layers", "batch", "kv_seq", None, None),
    "cross_k": ("layers", "batch", "kv_seq", None, None),
    "cross_v": ("layers", "batch", "kv_seq", None, None),
    "ssm": ("layers", "batch", "heads", None, None),
    "conv": ("layers", "batch", None, "mlp"),
    "tm_last": ("layers", "batch", None, None),
    "cm_last": ("layers", "batch", None, None),
    "wkv": ("layers", "batch", "heads", None, None),
    "pos": (),
}


def cache_pspecs(caches_aval, mesh: Mesh):
    """PartitionSpec pytree for a decode-state pytree (by leaf name)."""
    rules = make_rules(mesh)
    flat, treedef = jax.tree_util.tree_flatten_with_path(caches_aval)
    specs = []
    for path, leaf in flat:
        name = None
        for p in reversed(path):
            if hasattr(p, "key"):
                name = str(p.key)
                break
        axes = _CACHE_AXES.get(name)
        if axes is None or len(axes) != len(leaf.shape):
            axes = (None,) * len(leaf.shape)
        specs.append(resolve_axes(axes, rules, leaf.shape, mesh))
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_pspecs(batch_aval, mesh: Mesh):
    """Shard every batch input on dim 0 over the DP axes."""
    rules = make_rules(mesh)

    def one(x):
        axes = ("batch",) + (None,) * (len(x.shape) - 1)
        return resolve_axes(axes, rules, x.shape, mesh)

    return jax.tree.map(one, batch_aval)
