"""GPipe-style pipeline parallelism over a mesh axis (normally ``pod``).

The model's layer stack is split into S contiguous stages (S = size of the
pipeline axis).  Each microbatch flows stage->stage via ``ppermute``; the
schedule is the classic GPipe fill-drain loop expressed as one lax.scan of
(M + S - 1) ticks, running under shard_map so every stage executes the
same program on its own parameter shard (SPMD-friendly: no per-stage
programs to compile).

Cost model (surfaces in the §Roofline collective term): per tick one
boundary activation crosses the pod link; bubble fraction = (S-1)/(M+S-1).

This is the optional large-scale alternative to folding ``pod`` into data
parallelism; ``launch/dryrun.py --arch glm4-9b-pp`` exercises it.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipelined_forward(
    layer_fn: Callable,          # (layer_params, x) -> x  (one layer)
    stage_params,                # params with leading dim L/S (this stage's)
    x_microbatches,              # (M, mb, ...) microbatched inputs
    mesh: Mesh,
    axis: str = "pod",
):
    """Run the layer stack over all microbatches through the pipeline.

    Called INSIDE shard_map (axis present).  Returns (M, mb, ...) outputs
    (valid on the LAST stage; other stages hold garbage -- caller
    ppermutes/psums as needed).
    """
    S = jax.lax.psum(1, axis)
    stage = jax.lax.axis_index(axis)
    M = x_microbatches.shape[0]
    ticks = M + S - 1

    def stage_apply(carry_x):
        def body(x, lp):
            return layer_fn(lp, x), None
        y, _ = jax.lax.scan(body, carry_x, stage_params)
        return y

    buf = jnp.zeros_like(x_microbatches)         # output collector
    state = jnp.zeros_like(x_microbatches[0])    # in-flight activation

    def tick(carry, t):
        state, buf = carry
        # stage 0 ingests microbatch t (if valid)
        mb_idx = jnp.clip(t, 0, M - 1)
        injected = jnp.where(
            (stage == 0) & (t < M),
            x_microbatches[mb_idx],
            state,
        )
        out = stage_apply(injected)
        # last stage retires microbatch t - (S-1)
        ret_idx = jnp.clip(t - (S - 1), 0, M - 1)
        buf = jnp.where(
            (stage == S - 1) & (t >= S - 1),
            buf.at[ret_idx].set(out),
            buf,
        )
        # shift boundary activations to the next stage
        perm = [(i, (i + 1) % S) for i in range(S)]
        state = jax.lax.ppermute(out, axis, perm)
        return (state, buf), None

    (_, buf), _ = jax.lax.scan(tick, (state, buf), jnp.arange(ticks))
    return buf


def make_pipelined_step(layer_fn, n_layers: int, mesh: Mesh,
                        axis: str = "pod", microbatches: int = 4):
    """Build f(stacked_params, x) running layers split over ``axis``.

    stacked_params leaves have leading dim n_layers; x is (B, ...).  The
    batch is cut into ``microbatches`` along dim 0.
    """
    S = mesh.shape[axis]
    if n_layers % S:
        raise ValueError(f"{n_layers} layers not divisible into {S} stages")
    per_stage = n_layers // S

    def split_stage(params):
        # executed inside shard_map: leading L dim is sharded by in_specs
        return params

    def fn(params, x):
        B = x.shape[0]
        mb = B // microbatches
        xm = x.reshape(microbatches, mb, *x.shape[1:])
        out = pipelined_forward(layer_fn, params, xm, mesh, axis)
        out = out.reshape(B, *x.shape[1:])
        # broadcast the last stage's result to all stages (masked psum)
        stage = jax.lax.axis_index(axis)
        masked = jnp.where(stage == S - 1, out, jnp.zeros_like(out))
        return jax.lax.psum(masked, axis) if S > 1 else out

    in_specs = (P(axis), P())        # params layer-sharded; x replicated
    out_specs = P()
    return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    """GPipe bubble overhead: (S-1)/(M+S-1)."""
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
