"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \\
        --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ck

* ``--smoke`` selects the reduced same-family config (CPU-runnable);
  without it the full assigned config is used (TPU-scale -- on this
  container use the dry-run instead).
* Resumes automatically from the latest checkpoint in --ckpt-dir.
* ``--mesh dxm`` runs pjit-sharded on a (data, model) host-device mesh
  (requires XLA_FLAGS=--xla_force_host_platform_device_count=N).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs.registry import ARCHS, SMOKE
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.api import get_model
from repro.optim import adamw
from repro.train.loop import LoopConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compression", choices=["int8"], default=None)
    args = ap.parse_args()

    cfg = (SMOKE if args.smoke else ARCHS)[args.arch]
    model = get_model(cfg)
    print(f"arch={cfg.name} family={cfg.family} params={model.param_count():,}")

    dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                    global_batch=args.batch, seed=0)
    if cfg.family == "whisper":
        dc = dataclasses.replace(dc, frames_dim=cfg.d_model, n_frames=args.seq)
    if cfg.family == "vlm":
        dc = dataclasses.replace(dc, img_dim=cfg.d_model,
                                 n_patches=cfg.n_img_patches)
    data = SyntheticLM(dc)
    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=10,
                                total_steps=args.steps)
    loop_cfg = LoopConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                          ckpt_dir=args.ckpt_dir, log_every=10,
                          grad_compression=args.grad_compression)
    _, _, hist = train(model, data, opt_cfg, loop_cfg)
    print(f"done: loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
