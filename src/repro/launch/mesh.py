"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (smoke tests must keep seeing 1 CPU device)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2 pods x 256 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/examples (e.g. (2,2) on 4 host devices)."""
    return jax.make_mesh(tuple(shape), tuple(axes))
