import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: prove every (arch x shape x mesh) cell lowers,
compiles, shards coherently and fits memory -- without TPU hardware.

For each cell we jit the train/prefill/serve step with production
in/out shardings, ``.lower().compile()`` it against ShapeDtypeStructs
(no allocation), then record:
  * memory_analysis()  -- per-device bytes (proves it fits HBM),
  * cost_analysis()    -- FLOPs / bytes for the roofline,
  * collective payload parsed from the optimized HLO,
  * the 3-term roofline + MODEL_FLOPS useful-fraction.

Results are cached as JSON under results/dryrun/ so reruns are
incremental.  Usage:

  python -m repro.launch.dryrun --arch llama3.2-1b --cell train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--force]
  python -m repro.launch.dryrun --stencil            # paper-workload cells
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import ARCHS, SHAPES, cells_for
from repro.core import hlo_roofline
from repro.launch.mesh import make_production_mesh
from repro.models import base
from repro.models.api import get_model
from repro.optim import adamw
from repro.parallel import sharding
from repro.train.steps import make_train_step, make_serve_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "../../../results/dryrun")


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, cell_name: str, multi_pod: bool,
               extra_opts: dict | None = None):
    """Build avals + shardings for one cell and lower+compile it."""
    cfg = ARCHS[arch]
    if extra_opts:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, **extra_opts)
    cell = SHAPES[cell_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = get_model(cfg)
    pdefs = model.param_defs()
    pshapes = base.shape_tree(pdefs)
    pure_dp = getattr(cfg, 'pure_dp', False)
    # sharding policy: pure DP only fills the machine while batch >= chips
    # (EXPERIMENTS.md §Perf A4 multi-pod note) -- fall back to TP otherwise.
    n_chips = int(np.prod(list(mesh.shape.values())))
    if pure_dp and cell.global_batch < n_chips:
        pure_dp = False
    pspecs = sharding.param_pspecs(pdefs, mesh, cfg.fsdp, pure_dp)
    inputs = model.input_specs(cell)

    with sharding.use_mesh(mesh, cfg.fsdp, pure_dp):
        if cell.kind in ("train", "prefill"):
            # prefill cells lower the same loss-bearing full-sequence pass
            # without the optimizer (forward only == serving prefill cost).
            if cell.kind == "train":
                ocfg = adamw.AdamWConfig()
                step = make_train_step(model, ocfg)
                opt_aval = jax.eval_shape(adamw.init, pshapes)
                opt_specs = adamw.AdamWState(
                    P(), jax.tree.map(lambda s: s, pspecs),
                    jax.tree.map(lambda s: s, pspecs))
                batch_specs = sharding.batch_pspecs(inputs, mesh)
                jf = jax.jit(
                    step,
                    in_shardings=(_ns(mesh, pspecs), _ns(mesh, opt_specs),
                                  _ns(mesh, batch_specs)),
                    out_shardings=(_ns(mesh, pspecs), _ns(mesh, opt_specs),
                                   None),
                    donate_argnums=(0, 1),
                )
                lowered = jf.lower(pshapes, opt_aval, inputs)
            else:
                def fwd(params, batch):
                    loss, aux = model.loss_fn(params, batch)
                    return loss

                batch_specs = sharding.batch_pspecs(inputs, mesh)
                jf = jax.jit(fwd, in_shardings=(_ns(mesh, pspecs),
                                                _ns(mesh, batch_specs)))
                lowered = jf.lower(pshapes, inputs)
        else:
            step = make_serve_step(model)
            caches = inputs["caches"]
            cspecs = sharding.cache_pspecs(caches, mesh)
            tok = inputs["token"]
            jf = jax.jit(
                step,
                in_shardings=(_ns(mesh, pspecs), _ns(mesh, cspecs),
                              NamedSharding(mesh, sharding.batch_pspecs(
                                  {"t": tok}, mesh)["t"]),
                              NamedSharding(mesh, P())),
                donate_argnums=(1,),
            )
            lowered = jf.lower(pshapes, caches, tok, inputs["pos"])
    return lowered, cfg, cell, mesh


def run_cell(arch: str, cell_name: str, multi_pod: bool, force=False,
             tag: str = "", extra_opts=None):
    mesh_name = "multi" if multi_pod else "single"
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out_path = os.path.join(
        RESULTS_DIR, f"{arch}__{cell_name}__{mesh_name}{tag}.json")
    if os.path.exists(out_path) and not force:
        print(f"[skip] {out_path} exists")
        return json.load(open(out_path))
    t0 = time.time()
    rec = {"arch": arch, "cell": cell_name, "mesh": mesh_name, "tag": tag}
    try:
        lowered, cfg, cell, mesh = lower_cell(arch, cell_name, multi_pod,
                                              extra_opts)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        mf = hlo_roofline.model_flops_for(cfg, cell)
        n_chips = int(np.prod(list(mesh.shape.values())))
        terms = hlo_roofline.roofline_from_compiled(compiled, mf, n_chips)
        coll = hlo_roofline.parse_collective_bytes(compiled.as_text())
        rec.update(
            ok=True,
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            n_chips=n_chips,
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            },
            roofline=terms.as_dict(),
            collectives={k: v for k, v in coll.items()},
        )
        print(f"[ok] {arch} {cell_name} {mesh_name}{tag}: "
              f"compute={terms.compute_s*1e3:.2f}ms mem={terms.memory_s*1e3:.2f}ms "
              f"coll={terms.collective_s*1e3:.2f}ms bottleneck={terms.bottleneck} "
              f"useful={terms.useful_fraction and round(terms.useful_fraction,3)} "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
    except Exception as e:
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   tb=traceback.format_exc()[-2000:])
        print(f"[FAIL] {arch} {cell_name} {mesh_name}{tag}: {e}")
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def run_stencil(multi_pod: bool, force=False):
    """Dry-run the paper's own workload: distributed 2D/3D stencil steps."""
    from repro.stencil import StencilSpec, make_weights
    from repro.stencil.distributed import make_distributed_stepper

    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "multi" if multi_pod else "single"
    os.makedirs(RESULTS_DIR, exist_ok=True)
    cases = [
        ("Box-2D1R", (10240, 10240), ("data", "model"), 4),
        ("Star-2D3R", (10240, 10240), ("data", "model"), 2),
        ("Box-3D1R", (1024, 1024, 1024), ("data", "model", None) if not multi_pod
         else ("pod", "data", "model"), 2),
    ]
    out = []
    for name, shape, dims, t in cases:
        dims = dims[: len(shape)]
        out_path = os.path.join(
            RESULTS_DIR, f"stencil-{name}__t{t}__{mesh_name}.json")
        if os.path.exists(out_path) and not force:
            print(f"[skip] {out_path}")
            continue
        rec = {"arch": f"stencil-{name}", "cell": f"t{t}", "mesh": mesh_name}
        try:
            spec = StencilSpec.from_name(name)
            w = make_weights(spec, seed=0)
            if multi_pod and len(shape) == 2:
                d = ("data", "model")
                gspec = P(("pod", d[0]), d[1])
                dims = (("pod", "data"), "model")
            step = make_distributed_stepper(mesh, dims, w, t=t, mode="fused")
            x_aval = jax.ShapeDtypeStruct(shape, jnp.float32)
            in_spec = P(*dims)
            jf = jax.jit(step, in_shardings=NamedSharding(mesh, in_spec),
                         out_shardings=NamedSharding(mesh, in_spec))
            lowered = jf.lower(x_aval)
            compiled = lowered.compile()
            n_chips = int(np.prod(list(mesh.shape.values())))
            K = spec.num_points
            mf = 2.0 * K * t * float(np.prod(shape))
            terms = hlo_roofline.roofline_from_compiled(compiled, mf, n_chips)
            mem = compiled.memory_analysis()
            rec.update(ok=True, roofline=terms.as_dict(),
                       memory={"peak_bytes": getattr(mem, "peak_memory_in_bytes", None)})
            print(f"[ok] stencil {name} t={t} {mesh_name}: "
                  f"bottleneck={terms.bottleneck} useful={terms.useful_fraction}")
        except Exception as e:
            rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                       tb=traceback.format_exc()[-2000:])
            print(f"[FAIL] stencil {name}: {e}")
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1)
        out.append(rec)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--cell")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--stencil", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    if args.stencil:
        for mp in meshes:
            run_stencil(mp, force=args.force)
        return
    if args.all:
        for arch in ARCHS:
            for cell in cells_for(arch):
                for mp in meshes:
                    run_cell(arch, cell, mp, force=args.force)
        return
    for mp in meshes:
        run_cell(args.arch, args.cell, mp, force=args.force)


if __name__ == "__main__":
    main()
