"""Batched greedy-decoding server driver: prefill -> decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \\
        --batch 4 --prompt-len 16 --gen 32

Exercises the runnable serving path end-to-end on CPU with the reduced
configs: cache init, full-sequence prefill, then one-token steps with the
same stacked-scan decode the decode_32k/long_500k dry-run cells lower at
production shapes.  Reports tokens/s and verifies the KV-cached stream
matches the uncached forward pass (greedy consistency check).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS, SMOKE
from repro.models.api import get_model
from repro.models import layers as nn_layers
from repro.models import transformer, rwkv_model


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog="repro.launch.serve")
    ap.add_argument("--arch", default="llama3.2-1b", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--check", action="store_true",
                    help="verify cached decode == uncached forward argmax")
    return ap


def parse_args(argv=None) -> argparse.Namespace:
    ap = build_parser()
    args = ap.parse_args(argv)
    # Reject degenerate loop bounds up front: --prompt-len 0 would leave
    # the prefill loop body unexecuted and crash on the undefined next
    # token; --gen 0 similarly empties the decode loop.  ap.error exits
    # with a usage message and status 2, the argparse convention.
    for name in ("batch", "prompt_len", "gen"):
        value = getattr(args, name)
        if value < 1:
            ap.error(f"--{name.replace('_', '-')} must be >= 1, got {value}")
    return args


def main(argv=None):
    args = parse_args(argv)

    cfg = (SMOKE if args.smoke else ARCHS)[args.arch]
    if cfg.family in ("whisper", "vlm", "hybrid", "moe"):
        print(f"note: serve CLI drives dense/rwkv families; {cfg.family} "
              "decode is exercised by tests + the decode dry-run cells")
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    B, P, G = args.batch, args.prompt_len, args.gen
    max_seq = P + G + 1
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, size=(B, P)).astype(np.int32)

    serve = jax.jit(model.decode_step)

    # prefill by streaming the prompt through the decode path (simple and
    # family-agnostic; transformer families also have a batched prefill)
    caches = model.init_caches(B, max_seq)
    t0 = time.perf_counter()
    for i in range(P):
        nxt, caches = serve(params, caches, jnp.asarray(prompts[:, i:i+1]),
                            jnp.asarray(i, jnp.int32))
    jax.block_until_ready(nxt)
    t_prefill = time.perf_counter() - t0

    out = [np.asarray(nxt)]
    t0 = time.perf_counter()
    for i in range(P, P + G - 1):
        nxt, caches = serve(params, caches, jnp.asarray(out[-1]),
                            jnp.asarray(i, jnp.int32))
        out.append(np.asarray(nxt))
    jax.block_until_ready(nxt)
    t_gen = time.perf_counter() - t0
    gen = np.concatenate(out, axis=1)

    print(f"arch={cfg.name} B={B} prompt={P} gen={G}")
    print(f"prefill: {t_prefill*1e3:8.1f} ms  ({B*P/t_prefill:8.0f} tok/s)")
    print(f"decode : {t_gen*1e3:8.1f} ms  ({B*(G-1)/t_gen:8.0f} tok/s)")
    print(f"sample completions (first 8 ids): {gen[:2, :8].tolist()}")

    if args.check and cfg.family == "dense":
        full = np.concatenate([prompts, gen[:, :-1]], axis=1)
        h, _, _ = transformer.forward(params, jnp.asarray(full), cfg)
        logits = nn_layers.lm_logits(params, h, cfg)
        want = np.asarray(jnp.argmax(logits[:, P - 1:], -1))
        ok = np.array_equal(want, gen)
        print(f"greedy consistency vs uncached forward: "
              f"{'OK' if ok else 'MISMATCH'}")
        if not ok:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
