"""Serving drivers: LLM decode loop + the batched stencil engine.

Default (no subcommand): the batched greedy-decoding server driver --
prefill -> decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \\
        --batch 4 --prompt-len 16 --gen 32

Exercises the runnable serving path end-to-end on CPU with the reduced
configs: cache init, full-sequence prefill, then one-token steps with the
same stacked-scan decode the decode_32k/long_500k dry-run cells lower at
production shapes.  Reports tokens/s and verifies the KV-cached stream
matches the uncached forward pass (greedy consistency check).

``stencil`` subcommand: drive the batched plan-sharing stencil engine
(``repro.serve``, DESIGN.md §12) with a closed-loop client -- a fixed
window of outstanding requests over one plan signature -- and report
requests/s, batch occupancy, and P50/P99 latency.

    PYTHONPATH=src python -m repro.launch.serve stencil \\
        --requests 256 --window 16 --shape star --t 2 --grid 32,32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS, SMOKE
from repro.models.api import get_model
from repro.models import layers as nn_layers
from repro.models import transformer, rwkv_model


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog="repro.launch.serve")
    ap.add_argument("--arch", default="llama3.2-1b", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--check", action="store_true",
                    help="verify cached decode == uncached forward argmax")

    sub = ap.add_subparsers(dest="cmd")
    st = sub.add_parser(
        "stencil",
        help="batched plan-sharing stencil serving engine (repro.serve)")
    st.add_argument("--requests", type=int, default=256,
                    help="total requests the closed loop issues")
    st.add_argument("--window", type=int, default=16,
                    help="closed-loop concurrency (outstanding requests)")
    st.add_argument("--shape", choices=("box", "star"), default="star")
    st.add_argument("--radius", type=int, default=1)
    st.add_argument("--t", type=int, default=2, dest="depth",
                    help="fusion depth (time steps per request)")
    st.add_argument("--grid", default="32,32",
                    help="comma-separated grid shape, e.g. 32,32 or 8,16,16")
    st.add_argument("--dtype", choices=("float32", "bfloat16"),
                    default="float32")
    st.add_argument("--max-batch", type=int, default=None,
                    help="override REPRO_SERVE_MAX_BATCH")
    st.add_argument("--timeout-ms", type=int, default=None,
                    help="override REPRO_SERVE_QUEUE_TIMEOUT_MS")
    st.add_argument("--no-guard", action="store_true",
                    help="skip the guarded-execution ladder (DESIGN.md §11)")
    return ap


def parse_args(argv=None) -> argparse.Namespace:
    ap = build_parser()
    args = ap.parse_args(argv)
    if getattr(args, "cmd", None) == "stencil":
        # Same fail-fast convention as the LLM flags: degenerate loop
        # bounds die with a usage error, not a hang in the closed loop.
        for name in ("requests", "window", "radius", "depth"):
            value = getattr(args, name)
            if value < 1:
                flag = {"depth": "t"}.get(name, name.replace("_", "-"))
                ap.error(f"--{flag} must be >= 1, got {value}")
        for name in ("max_batch", "timeout_ms"):
            value = getattr(args, name)
            floor = 1 if name == "max_batch" else 0
            if value is not None and value < floor:
                ap.error(f"--{name.replace('_', '-')} must be >= {floor}, "
                         f"got {value}")
        try:
            grid = tuple(int(n) for n in args.grid.split(","))
        except ValueError:
            ap.error(f"--grid must be comma-separated integers, "
                     f"got {args.grid!r}")
        if not grid or any(n < 1 for n in grid) or len(grid) > 3:
            ap.error(f"--grid needs 1-3 positive dims, got {args.grid!r}")
        args.grid_shape = grid
        return args
    # Reject degenerate loop bounds up front: --prompt-len 0 would leave
    # the prefill loop body unexecuted and crash on the undefined next
    # token; --gen 0 similarly empties the decode loop.  ap.error exits
    # with a usage message and status 2, the argparse convention.
    for name in ("batch", "prompt_len", "gen"):
        value = getattr(args, name)
        if value < 1:
            ap.error(f"--{name.replace('_', '-')} must be >= 1, got {value}")
    return args


def serve_stencil(args) -> dict:
    """Closed-loop drive of the batched stencil engine; returns (and
    prints) the metrics snapshot."""
    from repro.serve import StencilServer
    from repro.stencil.spec import StencilSpec
    from repro.stencil.weights import jacobi_weights

    spec = StencilSpec(args.shape, len(args.grid_shape), args.radius)
    weights = jacobi_weights(spec)
    dtype = jnp.bfloat16 if args.dtype == "bfloat16" else np.float32
    rng = np.random.default_rng(0)
    xs = [jnp.asarray(rng.normal(size=args.grid_shape), dtype=dtype)
          for _ in range(min(args.window, args.requests))]

    with StencilServer(max_batch=args.max_batch,
                       queue_timeout_ms=args.timeout_ms,
                       guard=not args.no_guard) as server:
        # closed loop: keep `window` requests outstanding, issue a new one
        # as each completes; reuse the window's input arrays round-robin
        outstanding = []
        issued = 0
        t0 = time.perf_counter()
        while issued < args.requests or outstanding:
            while issued < args.requests and len(outstanding) < len(xs):
                outstanding.append(server.submit(
                    weights, xs[issued % len(xs)], t=args.depth))
                issued += 1
            outstanding.pop(0).result()
        wall = time.perf_counter() - t0
        snap = server.stats()

    lat = snap["latency"]
    print(f"stencil serve: {spec.name} t={args.depth} "
          f"grid={args.grid_shape} dtype={args.dtype} "
          f"guard={not args.no_guard}")
    print(f"  requests   : {snap['responded']}/{snap['submitted']} "
          f"in {wall:.2f}s wall ({snap['responded']/wall:.0f} req/s)")
    print(f"  batches    : {snap['batches']} "
          f"(occupancy {snap['batch_occupancy']:.2f}, "
          f"degraded {snap['degraded_batches']})")
    print(f"  latency ms : p50={lat['p50_ms']:.2f} p99={lat['p99_ms']:.2f} "
          f"mean={lat['mean_ms']:.2f} max={lat['max_ms']:.2f}")
    pc = snap["plan_cache"]
    print(f"  plan cache : {pc['hits']} hits / {pc['misses']} misses "
          f"({snap['engine_plans']} engine plans)")
    return snap


def main(argv=None):
    args = parse_args(argv)
    if getattr(args, "cmd", None) == "stencil":
        serve_stencil(args)
        return

    cfg = (SMOKE if args.smoke else ARCHS)[args.arch]
    if cfg.family in ("whisper", "vlm", "hybrid", "moe"):
        print(f"note: serve CLI drives dense/rwkv families; {cfg.family} "
              "decode is exercised by tests + the decode dry-run cells")
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    B, P, G = args.batch, args.prompt_len, args.gen
    max_seq = P + G + 1
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, size=(B, P)).astype(np.int32)

    serve = jax.jit(model.decode_step)

    # prefill by streaming the prompt through the decode path (simple and
    # family-agnostic; transformer families also have a batched prefill)
    caches = model.init_caches(B, max_seq)
    t0 = time.perf_counter()
    for i in range(P):
        nxt, caches = serve(params, caches, jnp.asarray(prompts[:, i:i+1]),
                            jnp.asarray(i, jnp.int32))
    jax.block_until_ready(nxt)
    t_prefill = time.perf_counter() - t0

    out = [np.asarray(nxt)]
    t0 = time.perf_counter()
    for i in range(P, P + G - 1):
        nxt, caches = serve(params, caches, jnp.asarray(out[-1]),
                            jnp.asarray(i, jnp.int32))
        out.append(np.asarray(nxt))
    jax.block_until_ready(nxt)
    t_gen = time.perf_counter() - t0
    gen = np.concatenate(out, axis=1)

    print(f"arch={cfg.name} B={B} prompt={P} gen={G}")
    print(f"prefill: {t_prefill*1e3:8.1f} ms  ({B*P/t_prefill:8.0f} tok/s)")
    print(f"decode : {t_gen*1e3:8.1f} ms  ({B*(G-1)/t_gen:8.0f} tok/s)")
    print(f"sample completions (first 8 ids): {gen[:2, :8].tolist()}")

    if args.check and cfg.family == "dense":
        full = np.concatenate([prompts, gen[:, :-1]], axis=1)
        h, _, _ = transformer.forward(params, jnp.asarray(full), cfg)
        logits = nn_layers.lm_logits(params, h, cfg)
        want = np.asarray(jnp.argmax(logits[:, P - 1:], -1))
        ok = np.array_equal(want, gen)
        print(f"greedy consistency vs uncached forward: "
              f"{'OK' if ok else 'MISMATCH'}")
        if not ok:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
