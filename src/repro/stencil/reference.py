"""Pure-jnp reference implementations (oracles) for stencil computation.

Boundary conditions follow :mod:`repro.stencil.boundary`: per-axis
``periodic`` (toroidal wrap, matching the distributed halo-exchange
ppermute ring), ``zero``, ``reflect`` and ``replicate``, passed either
as one mode string for every axis or a per-axis tuple such as
``("reflect", "periodic")``.

``apply_stencil`` is the shift-and-accumulate oracle: O(K) rolls (or
mode-padded slices), trivially correct, used to validate every other
execution path (Pallas kernels, the conv-based fast path, and the
distributed runtime).
"""
from __future__ import annotations

import functools
import itertools
import string
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .boundary import PAD_MODE, BoundaryLike, is_periodic, resolve_boundary
from .spec import StencilSpec


def _offsets(radius: int, dim: int):
    """All kernel offsets of a radius-R, d-dimensional box, any rank.

    Row-major (``np.ndindex``) order -- the accumulation order every
    oracle and the Pallas kernels share.
    """
    rng = range(-radius, radius + 1)
    return list(itertools.product(rng, repeat=dim))


def pad_boundary(x: jax.Array, radius: int, modes) -> jax.Array:
    """Pad ``radius`` cells per side with each axis's boundary mode.

    Axes pad sequentially in ascending order, so a later axis's halo is
    built from the already-padded earlier axes -- exactly ``np.pad``'s
    corner semantics, and the contract the in-kernel fills reproduce.
    """
    xp = x
    for ax, m in enumerate(modes):
        pad = [(0, 0)] * x.ndim
        pad[ax] = (radius, radius)
        xp = jnp.pad(xp, pad, mode=PAD_MODE[m])
    return xp


def apply_stencil(
    x: jax.Array,
    weights: jax.Array,
    boundary: BoundaryLike = "periodic",
) -> jax.Array:
    """One stencil update:  y[i] = sum_o w[o] * x[i+o].

    ``weights`` is a dense ``(2R+1,)*d`` kernel (zeros outside support);
    its radius R may exceed the base spec's r (fused kernels).
    ``boundary`` is one mode for every axis or a per-axis tuple.
    """
    dim = weights.ndim
    if x.ndim != dim:
        raise ValueError(f"grid rank {x.ndim} != kernel rank {dim}")
    radius = (weights.shape[0] - 1) // 2
    w = jnp.asarray(weights, dtype=x.dtype)

    modes = resolve_boundary(boundary, dim)
    periodic = is_periodic(modes)
    xp = None if periodic else pad_boundary(x, radius, modes)

    y = jnp.zeros_like(x)
    for off in _offsets(radius, dim):
        widx = tuple(o + radius for o in off)
        if periodic:
            shifted = jnp.roll(x, shift=tuple(-o for o in off), axis=tuple(range(dim)))
        else:
            sl = tuple(slice(radius + o, radius + o + n) for o, n in zip(off, x.shape))
            shifted = xp[sl]
        y = y + w[widx] * shifted
    return y


def apply_stencil_steps(
    x: jax.Array,
    weights: jax.Array,
    t: int,
    boundary: BoundaryLike = "periodic",
) -> jax.Array:
    """``t`` sequential stencil updates (the un-fused ground truth)."""
    def body(carry, _):
        return apply_stencil(carry, weights, boundary), None

    y, _ = jax.lax.scan(body, x, None, length=t)
    return y


def apply_stencil_conv(
    x: jax.Array,
    weights: jax.Array,
    boundary: BoundaryLike = "periodic",
) -> jax.Array:
    """Fast path via ``lax.conv_general_dilated`` (XLA-optimized oracle #2).

    conv_general_dilated computes a correlation with the kernel as given,
    which matches our stencil definition directly.  One N-D path: the
    dimension numbers are generated for any rank (spatial letters drawn
    from the alphabet minus the reserved N/C/O/I), so 1D, 2D and 3D share
    the same code instead of per-rank special cases.
    """
    dim = weights.ndim
    if x.ndim != dim:
        raise ValueError(f"grid rank {x.ndim} != kernel rank {dim}")
    radius = (weights.shape[0] - 1) // 2
    if boundary == "periodic":
        pad = [(radius, radius)] * dim
        xin = jnp.pad(x, pad, mode="wrap")
        padding = "VALID"
    elif boundary == "zero":
        xin = x
        padding = "SAME"
    else:
        xin = pad_boundary(x, radius, resolve_boundary(boundary, dim))
        padding = "VALID"
    lhs = xin[jnp.newaxis, jnp.newaxis]          # NC + spatial
    rhs = jnp.asarray(weights, x.dtype)[jnp.newaxis, jnp.newaxis]  # OI + spatial
    spatial = "".join(
        c for c in string.ascii_uppercase if c not in "NCOI")[:dim]
    dn = jax.lax.conv_dimension_numbers(
        lhs.shape, rhs.shape,
        ("NC" + spatial, "OI" + spatial, "NC" + spatial))
    out = jax.lax.conv_general_dilated(lhs, rhs, (1,) * dim, padding, dimension_numbers=dn)
    return out[0, 0]


@functools.partial(jax.jit, static_argnames=("t", "boundary"))
def jacobi_reference(x, weights, t: int = 1, boundary: str = "periodic"):
    """Jit'd t-step reference, used by benchmarks."""
    return apply_stencil_steps(x, weights, t, boundary)
