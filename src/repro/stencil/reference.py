"""Pure-jnp reference implementations (oracles) for stencil computation.

Two boundary conditions are supported:
  * ``periodic`` -- toroidal wrap (matches the distributed halo-exchange
    runtime, which uses a ppermute ring);
  * ``zero``     -- zero padding outside the domain.

``apply_stencil`` is the shift-and-accumulate oracle: O(K) rolls, trivially
correct, used to validate every other execution path (Pallas kernels, the
conv-based fast path, and the distributed runtime).
"""
from __future__ import annotations

import functools
import itertools
import string
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .spec import StencilSpec


def _offsets(radius: int, dim: int):
    """All kernel offsets of a radius-R, d-dimensional box, any rank.

    Row-major (``np.ndindex``) order -- the accumulation order every
    oracle and the Pallas kernels share.
    """
    rng = range(-radius, radius + 1)
    return list(itertools.product(rng, repeat=dim))


def apply_stencil(
    x: jax.Array,
    weights: jax.Array,
    boundary: str = "periodic",
) -> jax.Array:
    """One stencil update:  y[i] = sum_o w[o] * x[i+o].

    ``weights`` is a dense ``(2R+1,)*d`` kernel (zeros outside support);
    its radius R may exceed the base spec's r (fused kernels).
    """
    dim = weights.ndim
    if x.ndim != dim:
        raise ValueError(f"grid rank {x.ndim} != kernel rank {dim}")
    radius = (weights.shape[0] - 1) // 2
    w = jnp.asarray(weights, dtype=x.dtype)

    if boundary == "zero":
        pad = [(radius, radius)] * dim
        xp = jnp.pad(x, pad)
    elif boundary == "periodic":
        xp = None
    else:
        raise ValueError(f"unknown boundary {boundary!r}")

    y = jnp.zeros_like(x)
    for off in _offsets(radius, dim):
        widx = tuple(o + radius for o in off)
        if boundary == "periodic":
            shifted = jnp.roll(x, shift=tuple(-o for o in off), axis=tuple(range(dim)))
        else:
            sl = tuple(slice(radius + o, radius + o + n) for o, n in zip(off, x.shape))
            shifted = xp[sl]
        y = y + w[widx] * shifted
    return y


def apply_stencil_steps(
    x: jax.Array,
    weights: jax.Array,
    t: int,
    boundary: str = "periodic",
) -> jax.Array:
    """``t`` sequential stencil updates (the un-fused ground truth)."""
    def body(carry, _):
        return apply_stencil(carry, weights, boundary), None

    y, _ = jax.lax.scan(body, x, None, length=t)
    return y


def apply_stencil_conv(
    x: jax.Array,
    weights: jax.Array,
    boundary: str = "periodic",
) -> jax.Array:
    """Fast path via ``lax.conv_general_dilated`` (XLA-optimized oracle #2).

    conv_general_dilated computes a correlation with the kernel as given,
    which matches our stencil definition directly.  One N-D path: the
    dimension numbers are generated for any rank (spatial letters drawn
    from the alphabet minus the reserved N/C/O/I), so 1D, 2D and 3D share
    the same code instead of per-rank special cases.
    """
    dim = weights.ndim
    if x.ndim != dim:
        raise ValueError(f"grid rank {x.ndim} != kernel rank {dim}")
    radius = (weights.shape[0] - 1) // 2
    if boundary == "periodic":
        pad = [(radius, radius)] * dim
        xin = jnp.pad(x, pad, mode="wrap")
        padding = "VALID"
    else:
        xin = x
        padding = "SAME"
    lhs = xin[jnp.newaxis, jnp.newaxis]          # NC + spatial
    rhs = jnp.asarray(weights, x.dtype)[jnp.newaxis, jnp.newaxis]  # OI + spatial
    spatial = "".join(
        c for c in string.ascii_uppercase if c not in "NCOI")[:dim]
    dn = jax.lax.conv_dimension_numbers(
        lhs.shape, rhs.shape,
        ("NC" + spatial, "OI" + spatial, "NC" + spatial))
    out = jax.lax.conv_general_dilated(lhs, rhs, (1,) * dim, padding, dimension_numbers=dn)
    return out[0, 0]


@functools.partial(jax.jit, static_argnames=("t", "boundary"))
def jacobi_reference(x, weights, t: int = 1, boundary: str = "periodic"):
    """Jit'd t-step reference, used by benchmarks."""
    return apply_stencil_steps(x, weights, t, boundary)
