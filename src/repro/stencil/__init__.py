"""Stencil problem domain: specs, weights, references, distribution."""
from .spec import StencilSpec, box, star
from .weights import make_weights, jacobi_weights, fuse_weights, fused_num_points, alpha

__all__ = [
    "StencilSpec",
    "box",
    "star",
    "make_weights",
    "jacobi_weights",
    "fuse_weights",
    "fused_num_points",
    "alpha",
]
