"""Stencil problem domain: specs, weights, references, distribution."""
from .boundary import MODES as BOUNDARY_MODES
from .boundary import BoundarySpec, is_periodic, resolve_boundary
from .spec import StencilSpec, box, star
from .weights import make_weights, jacobi_weights, fuse_weights, fused_num_points, alpha

__all__ = [
    "StencilSpec",
    "box",
    "star",
    "make_weights",
    "jacobi_weights",
    "fuse_weights",
    "fused_num_points",
    "alpha",
    "BOUNDARY_MODES",
    "BoundarySpec",
    "is_periodic",
    "resolve_boundary",
]
