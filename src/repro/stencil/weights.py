"""Stencil weight generation and temporal-fusion composition.

A linear stencil update is a cross-correlation:

    y[i] = sum_o  w[o] * x[i + o],        o in support(spec)

Composing two linear stencil applications is again a linear stencil whose
kernel is the *convolution* of the two kernels:

    corr(w1, corr(w2, x)) == corr(conv(w1, w2), x)

Temporal "kernel fusion" (paper §2.2.3) therefore composes the stencil with
itself ``t`` times; the fused kernel spans radius ``t*r`` and its point count
``K^(t)`` drives the redundancy factor  ``alpha = K^(t) / (t*K)``  (Eq. 9).

This module computes fused kernels *numerically* (exact, shape-agnostic), so
``alpha`` can always be derived from the actual composed support -- matching
the paper's closed form for box stencils (Eq. 10) and providing the correct
value for star stencils (whose fused support is an L1 ball, not a star).
"""
from __future__ import annotations

from typing import Optional

import numpy as np
from scipy.signal import convolve as _convolve


def convolve(a, b, mode="full"):
    """Direct-method convolution: FFT convolution leaves ~1e-18 junk
    outside the true support, which corrupts structural-zero accounting
    (sparsity factors, fused support counts)."""
    return _convolve(a, b, mode=mode, method="direct")

from .spec import StencilSpec


def make_weights(
    spec: StencilSpec,
    seed: Optional[int] = 0,
    normalize: bool = True,
    dtype=np.float32,
) -> np.ndarray:
    """Dense ``(2r+1)^d`` kernel with zeros outside the stencil support.

    ``normalize=True`` scales weights to sum to 1 (a smoothing/Jacobi-like
    kernel) which keeps iterated application numerically stable -- important
    for deep temporal fusion tests.
    """
    rng = np.random.default_rng(seed)
    mask = spec.support_mask()
    w = rng.uniform(0.1, 1.0, size=spec.kernel_shape) * mask
    if normalize:
        w = w / w.sum()
    return w.astype(dtype)


def jacobi_weights(spec: StencilSpec, dtype=np.float32) -> np.ndarray:
    """Uniform averaging kernel (the classic Jacobi iteration weights)."""
    mask = spec.support_mask().astype(np.float64)
    return (mask / mask.sum()).astype(dtype)


def fuse_weights(w: np.ndarray, t: int) -> np.ndarray:
    """Kernel of ``t`` composed applications of ``w`` (full convolution).

    The result spans radius ``t*r``:  shape ``(2*t*r + 1,)*d`` for an input
    kernel of shape ``(2r+1,)*d``.
    """
    if t < 1:
        raise ValueError(f"fusion depth must be >= 1, got {t}")
    out = w.astype(np.float64)
    for _ in range(t - 1):
        out = convolve(out, w.astype(np.float64), mode="full")
    return out.astype(w.dtype)


def fused_num_points(spec: StencilSpec, t: int) -> int:
    """K^(t): support size of the t-fused kernel (numerically exact).

    For box stencils this equals the paper's closed form ``(2rt+1)^d``.
    For star stencils the fused support is the d-dimensional L1 ball of
    radius ``r*t`` (computed here by composing the support masks).
    """
    if t == 1:
        return spec.num_points
    if spec.shape == "box":
        return (2 * spec.radius * t + 1) ** spec.dim
    mask = spec.support_mask().astype(np.float64)
    out = mask
    for _ in range(t - 1):
        out = convolve(out, mask, mode="full")
    return int(np.count_nonzero(out))


def alpha(spec: StencilSpec, t: int) -> float:
    """Fusion redundancy factor ``alpha = K^(t) / (t*K)`` (paper Eq. 9/10)."""
    return fused_num_points(spec, t) / (t * spec.num_points)
