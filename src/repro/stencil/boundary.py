"""Per-axis boundary specification for the halo substrate.

A :data:`BoundarySpec` names, for every grid axis, how out-of-domain
neighbor cells are synthesized:

``periodic``
    The domain wraps (the historical — and default — behavior: halo
    fetches walk ``(i±1) mod nb`` and full-width kernels wrap columns).
``zero``
    Out-of-domain cells read as 0 (Dirichlet-0 / zero padding).
``reflect``
    Mirror about the edge *cell*, excluding it (``np.pad`` mode
    ``"reflect"``): cell ``-k`` reads cell ``+k``.  Requires the axis
    extent to exceed the halo depth (``extent >= t*r + 1``).
``replicate``
    The edge cell extends outward (``np.pad`` mode ``"edge"`` /
    clamp-to-edge).

The spec is resolved once at plan time into a per-axis tuple and flows
through the plan-cache key, the launch geometry (index maps +
in-kernel halo fills), the oracle, the auditor and the distributed
stepper.  ``None`` and all-``periodic`` specs take exactly the
historical code paths, bit for bit.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

#: The supported per-axis modes.
MODES: Tuple[str, ...] = ("periodic", "zero", "reflect", "replicate")

#: What callers may pass: nothing, one mode for every axis, or a
#: per-axis sequence (entries may be None meaning periodic).
BoundaryLike = Union[None, str, Sequence[Optional[str]]]

#: A fully resolved spec: one mode string per grid axis.
BoundarySpec = Tuple[str, ...]

#: ``jnp.pad`` / ``np.pad`` mode implementing each boundary mode.
PAD_MODE = {"periodic": "wrap", "zero": "constant",
            "reflect": "reflect", "replicate": "edge"}


def resolve_boundary(boundary: BoundaryLike, dim: int) -> BoundarySpec:
    """Normalize a user-facing boundary argument to a per-axis tuple.

    ``None`` -> all periodic; a bare string applies to every axis; a
    sequence must have one entry per grid axis (``None`` entries mean
    periodic).  Raises ``ValueError`` on unknown modes or a length
    mismatch -- plan-signature validation calls this, so bad specs fail
    in the caller's frame before any plan is built.
    """
    if boundary is None:
        return ("periodic",) * dim
    if isinstance(boundary, str):
        if boundary not in MODES:
            raise ValueError(f"unknown boundary mode {boundary!r}; "
                             f"expected one of {MODES}")
        return (boundary,) * dim
    modes = tuple("periodic" if m is None else m for m in boundary)
    if len(modes) != dim:
        raise ValueError(f"boundary spec {tuple(boundary)!r} has "
                         f"{len(modes)} entries for a {dim}-D grid")
    for m in modes:
        if m not in MODES:
            raise ValueError(f"unknown boundary mode {m!r}; "
                             f"expected one of {MODES}")
    return modes


def is_periodic(boundary: BoundaryLike) -> bool:
    """True iff the spec resolves to all-periodic (the historical paths)."""
    if boundary is None:
        return True
    if isinstance(boundary, str):
        return boundary == "periodic"
    return all(m in (None, "periodic") for m in boundary)


def boundary_label(modes: Sequence[str]) -> str:
    """Compact human-readable form, e.g. ``reflect×periodic``."""
    return "×".join(modes)
